"""Distribution substrate tests: sharding rules validity for every arch,
plus a real multi-device pjit train step in a subprocess (8 fake devices)."""
import json
import math
import subprocess
import sys
import textwrap

import jax
import pytest

from repro.configs import SHAPES, get_config, list_archs


def _check_specs_divisible(shapes_tree, shardings_tree, mesh_shape):
    flat_s = jax.tree.leaves(shapes_tree)
    flat_sh = jax.tree.leaves(
        shardings_tree, is_leaf=lambda x: hasattr(x, "spec"))
    assert len(flat_s) == len(flat_sh)
    for leaf, sh in zip(flat_s, flat_sh):
        spec = sh.spec
        for dim, names in zip(leaf.shape, tuple(spec) + (None,) * 8):
            if names is None:
                continue
            names = names if isinstance(names, tuple) else (names,)
            size = math.prod(mesh_shape[n] for n in names)
            assert dim % size == 0, (leaf.shape, spec)


@pytest.mark.parametrize("arch", list_archs())
def test_param_shardings_divisible(arch):
    """Every parameter sharding must divide evenly on the production mesh
    (jax rejects uneven argument shardings)."""
    from repro.launch.mesh import ShardingRules, make_test_mesh
    from repro.models.transformer import init_model

    # abstract mesh stand-in: only axis sizes matter for the divisibility
    class FakeMesh:
        axis_names = ("data", "model")
        shape = {"data": 16, "model": 16}

    rules = ShardingRules.__new__(ShardingRules)
    rules.mesh = FakeMesh()
    rules.axes = FakeMesh.axis_names
    rules.model_size = 16
    rules.dp = "data"
    rules.fsdp_axis = "data"
    rules.shard_cache_seq_for_mqa = True

    cfg = get_config(arch)
    shapes = jax.eval_shape(lambda: init_model(cfg, jax.random.PRNGKey(0)))
    flat, _ = jax.tree_util.tree_flatten_with_path(shapes)
    for path, leaf in flat:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                       for p in path)
        spec = rules.param_spec(key, tuple(leaf.shape))
        for dim, names in zip(leaf.shape, tuple(spec) + (None,) * 8):
            if names is None:
                continue
            names = names if isinstance(names, tuple) else (names,)
            size = math.prod(FakeMesh.shape[n] for n in names)
            assert dim % size == 0, (arch, key, leaf.shape, spec)


SUBPROC = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import jax, jax.numpy as jnp, numpy as np, json, dataclasses
    from repro.configs import get_config, smoke_config
    from repro.core.abft import ABFTConfig
    from repro.launch.mesh import ShardingRules, make_test_mesh
    from repro.launch.steps import init_train_state, make_train_step
    from repro.optim import AdamWConfig

    cfg = smoke_config(get_config("gemma-2b"))
    cfg = dataclasses.replace(cfg, d_model=64, n_heads=4, n_kv_heads=1,
                              head_dim=16, d_ff=128, vocab_size=256)
    mesh = make_test_mesh((2, 4), ("data", "model"))
    rules = ShardingRules(mesh)
    abft = ABFTConfig(mode="fused", threshold=5e-2, relative=True)
    state = init_train_state(cfg, jax.random.PRNGKey(0))
    pshapes = jax.eval_shape(lambda: state["params"])
    pshard = rules.params_shardings(pshapes)
    oshard = {"m": pshard, "v": pshard, "step": rules.replicated()}
    state = {
      "params": jax.device_put(state["params"], pshard),
      "opt": {"m": jax.device_put(state["opt"]["m"], pshard),
              "v": jax.device_put(state["opt"]["v"], pshard),
              "step": jax.device_put(state["opt"]["step"], rules.replicated())},
    }
    batch = {
      "tokens": jnp.zeros((8, 16), jnp.int32),
      "labels": jnp.ones((8, 16), jnp.int32),
    }
    bshard = rules.batch_shardings(jax.eval_shape(lambda: batch))
    batch = jax.device_put(batch, bshard)
    step = jax.jit(make_train_step(cfg, abft, AdamWConfig()),
                   in_shardings=(({"params": pshard, "opt": oshard}), bshard),
                   out_shardings=(({"params": pshard, "opt": oshard}),
                                  rules.replicated()))
    with mesh:
        l0 = None
        for i in range(4):
            state, m = step(state, batch)
            if l0 is None: l0 = float(m["loss"])
    print(json.dumps({
        "loss0": l0, "loss": float(m["loss"]),
        "flag": bool(m["abft_flag"]),
        "max_rel": float(m["abft_max_rel"]),
        "devices": len(jax.devices())}))
""")


def test_multidevice_train_step_subprocess():
    """Actually execute a sharded train step across 8 host devices; ABFT
    checks (which psum across the mesh) must stay clean and loss must move.
    """
    out = subprocess.run(
        [sys.executable, "-c", SUBPROC], capture_output=True, text=True,
        timeout=600, env={**__import__("os").environ, "PYTHONPATH": "src"})
    assert out.returncode == 0, out.stderr[-2000:]
    rec = json.loads(out.stdout.strip().splitlines()[-1])
    assert rec["devices"] == 8
    assert not rec["flag"], rec
    assert rec["loss"] < rec["loss0"] + 1e-3     # optimizer applied
