"""Sharded checksum parity (ISSUE 2): shard_map block-ELL aggregation.

The stripe-sharded engine must be semantically indistinguishable from the
single-device engine: same logits, same ABFTReport (flag / n_checks exact,
max_rel at the rounding floor), and a bit flip landing in one shard's
stripe must trip the *global* (psum-reduced) check.

Tests run in-process when the host already exposes >= 8 devices (the CI
multi-device job sets XLA_FLAGS=--xla_force_host_platform_device_count=8)
and otherwise re-exec themselves in a subprocess with the flag set, so the
default single-device tier-1 run still exercises the sharded path.
"""
import json
import subprocess
import sys
import textwrap

import jax
import pytest

NEED = 8


def _mesh8():
    from repro.launch.mesh import make_graph_mesh
    return make_graph_mesh(NEED)


def _build(seed=0, n=256, f=24):
    import jax.numpy as jnp
    import numpy as np

    from repro.core.gcn import init_gcn, normalized_adjacency_dense
    from repro.kernels.spmm_abft import dense_to_block_ell

    rng = np.random.default_rng(seed)
    m = n * 2
    e = rng.integers(0, n, size=(3 * m + 16, 2), dtype=np.int64)
    e = e[e[:, 0] != e[:, 1]]
    e = np.unique(np.sort(e, axis=1), axis=0)[:m]
    s_d = normalized_adjacency_dense(e, n)
    bell = dense_to_block_ell(s_d, block_m=32, block_k=32)
    h0 = jnp.asarray(rng.normal(0, 0.5, size=(n, f)).astype(np.float32))
    params = init_gcn(jax.random.PRNGKey(seed), (f, 16, 5))
    return s_d, bell, h0, params


def _parity_case() -> dict:
    """Single-device vs 8-way sharded engine; returns JSONable verdicts."""
    import numpy as np

    from repro.core.abft import ABFTConfig
    from repro.engine import Graph, Partition, gcn_apply

    _, bell, h0, params = _build()
    cfg = ABFTConfig(mode="fused", threshold=1e-3, relative=True)
    graph = Graph(s=bell, h0=h0)
    logits_1, rep_1 = gcn_apply(params, graph, cfg, backend="block_ell",
                                block_g=32)
    part = Partition(_mesh8(), "graph")
    logits_8, rep_8 = gcn_apply(params, graph, cfg, backend="block_ell",
                                block_g=32, partition=part)
    # the single-pass fused-layer kernel must compose with the sharding:
    # same logits, same psum'd report as the two-pass sharded path
    logits_8f, rep_8f = gcn_apply(params, graph, cfg, backend="block_ell",
                                  block_g=32, partition=part,
                                  fused_layer=True)
    return {
        "devices": len(jax.devices()),
        "logit_err": float(np.abs(np.asarray(logits_8)
                                  - np.asarray(logits_1)).max()),
        "fused_logit_err": float(np.abs(np.asarray(logits_8f)
                                        - np.asarray(logits_1)).max()),
        "flag_1": bool(rep_1.flag), "flag_8": bool(rep_8.flag),
        "flag_8f": bool(rep_8f.flag),
        "n_1": int(rep_1.n_checks), "n_8": int(rep_8.n_checks),
        "n_8f": int(rep_8f.n_checks),
        "max_rel_1": float(rep_1.max_rel), "max_rel_8": float(rep_8.max_rel),
        "max_rel_8f": float(rep_8f.max_rel),
    }


def _fault_case() -> dict:
    """Bit flip into one shard's stripe of X -> global flag must trip."""
    import jax.numpy as jnp
    import numpy as np

    from repro.core.abft import ABFTConfig
    from repro.core.fault import flip_bit_f32
    from repro.engine import Partition, make_backend

    _, bell, h0, params = _build(seed=1)
    tau = 1e-4
    cfg = ABFTConfig(mode="fused", threshold=tau, relative=False)
    part = Partition(_mesh8(), "graph")
    bk = make_backend(bell, cfg, partition=part, block_g=32)
    w = params["layers"][0]["w"]
    x = h0 @ w
    x_r = h0 @ w.sum(axis=1)
    _, chk_clean = bk.aggregate(x, x_r)
    clean = abs(float(chk_clean.predicted) - float(chk_clean.actual))

    # flip a high exponent bit of an X element whose row lies in shard 5's
    # stripe range (rows 160..191 of 8x32); the self-loop in S guarantees
    # the delta lands in shard 5's output stripe, and detection happens in
    # the psum-reduced global check.
    x_np = np.asarray(x).copy()
    rows = np.arange(5 * 32, 6 * 32)
    sub = np.argwhere(np.abs(x_np[rows]) >= 1e-2)
    ri, j = sub[3]
    i = int(rows[ri])
    x_np[i, j] = flip_bit_f32(np.float32(x_np[i, j]), 27)
    _, chk_bad = bk.aggregate(jnp.asarray(x_np), x_r)
    div = abs(float(chk_bad.predicted) - float(chk_bad.actual))
    return {"clean": clean, "div": div, "tau": tau}


def _assert_parity(rec: dict):
    assert rec["logit_err"] < 1e-5, rec
    assert rec["fused_logit_err"] < 1e-4, rec
    assert rec["flag_1"] is False and rec["flag_8"] is False, rec
    assert rec["flag_8f"] is False, rec
    assert rec["n_1"] == rec["n_8"] == rec["n_8f"] == 2, rec
    assert rec["max_rel_1"] < 2.5e-4 and rec["max_rel_8"] < 2.5e-4, rec
    assert rec["max_rel_8f"] < 2.5e-4, rec


def _assert_fault(rec: dict):
    assert rec["clean"] < rec["tau"] / 4, rec
    assert rec["div"] > rec["tau"], rec


# -- in-process variants (CI multi-device job; XLA_FLAGS set in the env) ----

multidevice = pytest.mark.skipif(
    len(jax.devices()) < NEED,
    reason=f"needs {NEED} devices (XLA_FLAGS="
           f"--xla_force_host_platform_device_count={NEED})")


@multidevice
def test_sharded_parity_direct():
    _assert_parity(_parity_case())


@multidevice
def test_sharded_fault_detected_direct():
    _assert_fault(_fault_case())


# -- subprocess variants (always run, incl. single-device tier-1) -----------

SUBPROC = textwrap.dedent("""
    import os, json
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import test_sharded_engine as t
    print(json.dumps({"parity": t._parity_case(), "fault": t._fault_case()}))
""")


def test_sharded_engine_subprocess():
    import os
    from pathlib import Path
    here = Path(__file__).resolve().parent
    env = {**os.environ,
           "PYTHONPATH": f"src:{here}",
           "XLA_FLAGS": "--xla_force_host_platform_device_count=8",
           "JAX_PLATFORMS": "cpu"}
    out = subprocess.run([sys.executable, "-c", SUBPROC],
                         capture_output=True, text=True, timeout=600,
                         cwd=here.parent, env=env)
    assert out.returncode == 0, out.stderr[-2000:]
    rec = json.loads(out.stdout.strip().splitlines()[-1])
    assert rec["parity"]["devices"] == NEED
    _assert_parity(rec["parity"])
    _assert_fault(rec["fault"])
