"""CheckedOp protocol tests (ISSUE 10 tentpole).

The engine's unit of ABFT coverage is a *checked op*: operands + folded
check vectors in, ``(out, Check)`` at a declared granularity out.  These
tests pin the protocol contract:

  (a) ``Check`` is a registered pytree whose ``granularity`` is static
      aux data (survives jit), and its comparisons are NaN-safe — a NaN
      divergence FLAGS where the naive ``d > tau`` is silent;
  (b) the reference ops (``MatmulOp`` split eqs. 2–3, ``ChainOp`` fused
      eqs. 4–6) conform: clean runs unflagged, predicted side computed
      from inputs + folds only, corruption of the output detected;
  (c) ``fold_w_r_tree`` is the one offline fold for every surface —
      flat denses, and layer-stacked transformer segments via
      ``lead_axes=1``;
  (d) ``per_op_report`` expands stacked checks into per-layer ids so a
      flagged op names the layer it fired in;
  (e) the Pallas ``matmul_abft`` kernel op returns the same registered
      ``Check`` (granularity aux included), not ad-hoc arrays.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.abft import (
    ABFTConfig,
    ChainOp,
    Check,
    MatmulOp,
    check_chain,
    fold_w_r_tree,
    per_op_report,
)
from repro.kernels.flash_checksum.ops import chain_check
from repro.kernels.matmul_abft.ops import MatmulAbftOp

CFG = ABFTConfig(mode="fused", threshold=1e-3, relative=True)
OFF = ABFTConfig(mode="none")


def _rand(seed, *shape, scale=0.3):
    rng = np.random.default_rng(seed)
    return jnp.asarray(rng.normal(0, scale, size=shape).astype(np.float32))


# ---------------------------------------------------------------------------
# (a) Check: registered pytree + NaN-safe comparison
# ---------------------------------------------------------------------------

def test_check_is_registered_pytree_with_static_granularity():
    c = Check(predicted=jnp.float32(2.0), actual=jnp.float32(2.0),
              granularity="stripe")
    leaves, treedef = jax.tree_util.tree_flatten(c)
    assert len(leaves) == 2
    c2 = jax.tree_util.tree_unflatten(treedef, leaves)
    assert c2.granularity == "stripe"
    # granularity is static aux: it crosses the jit boundary untouched
    c3 = jax.jit(lambda ch: ch)(c)
    assert c3.granularity == "stripe"
    assert not bool(c3.flag(CFG))


def test_nan_divergence_flags_where_naive_compare_is_silent():
    c = Check(predicted=jnp.float32(float("nan")), actual=jnp.float32(1.0))
    d = float(np.abs(np.nan - 1.0))
    assert not (d > CFG.threshold)          # the naive verdict: silent
    assert bool(c.flag(CFG))                # the shipped verdict: flags
    f, _rel = c.elementwise(CFG)
    assert bool(np.asarray(f).all())


# ---------------------------------------------------------------------------
# (b) reference op conformance
# ---------------------------------------------------------------------------

def test_matmul_op_clean_and_corrupted():
    a, b = _rand(0, 24, 16), _rand(1, 16, 8)
    out, chk = MatmulOp()(CFG, a, b)
    np.testing.assert_allclose(np.asarray(out), np.asarray(a @ b),
                               atol=1e-5)
    assert not bool(chk.flag(CFG))
    # corrupting the served output moves the actual corner off the
    # prediction (the predicted side never reads the output)
    bad = np.asarray(out, np.float64).copy()
    bad[3, 4] += 10.0
    div = abs(float(chk.predicted) - bad.sum())
    assert div > CFG.threshold
    out_off, chk_off = MatmulOp()(OFF, a, b)
    assert chk_off is None
    np.testing.assert_array_equal(np.asarray(out_off), np.asarray(out))


def test_chain_op_folded_w_r_matches_unfolded():
    mats = [_rand(2, 20, 12), _rand(3, 12, 10), _rand(4, 10, 6)]
    out, chk = ChainOp()(CFG, *mats)
    folded = fold_w_r_tree({"w": mats[-1]}, CFG)
    out_f, chk_f = ChainOp()(CFG, *mats, w_r=folded["w_r"])
    np.testing.assert_array_equal(np.asarray(out_f), np.asarray(out))
    ref = float(np.asarray(out, np.float64).sum())
    scale = max(1.0, abs(ref))
    assert abs(float(chk_f.predicted) - float(chk.predicted)) / scale < 1e-5
    assert abs(float(chk_f.predicted) - ref) / scale < 1e-4
    assert not bool(chk_f.flag(CFG))
    # the folded-op check equals the reference eq. 4-6 chain check
    ref_chk = check_chain(mats, out, CFG)
    assert abs(float(chk_f.predicted) - float(ref_chk.predicted)) \
        / scale < 1e-5


def test_op_fold_default_is_tree_generic():
    params = {"w": _rand(5, 14, 6), "b": jnp.zeros(6)}
    folded = MatmulOp().fold(params, CFG)
    assert folded["w_r"].shape == (14,)
    np.testing.assert_allclose(
        np.asarray(folded["w_r"]),
        np.asarray(params["w"].astype(CFG.dtype).sum(-1)), atol=1e-6)


# ---------------------------------------------------------------------------
# (c) tree-generic fold: flat + layer-stacked segments
# ---------------------------------------------------------------------------

def test_fold_w_r_tree_stacked_segments():
    w = _rand(6, 2, 16, 3, 8)                  # [L, d_in, heads, hd]
    tree = {"segments": [{"unit0": {"attn": {"wq": {"w": w}},
                                    "ln": {"scale": jnp.ones((2, 16))}}}]}
    folded = {"segments": [fold_w_r_tree(s, CFG, lead_axes=1)
                           for s in tree["segments"]]}
    wq = folded["segments"][0]["unit0"]["attn"]["wq"]
    assert wq["w_r"].shape == (2, 16)          # [L, d_in]: per-layer folds
    np.testing.assert_allclose(
        np.asarray(wq["w_r"]),
        np.asarray(w.astype(CFG.dtype).reshape(2, 16, -1).sum(-1)),
        atol=1e-6)
    # the 2-D ln scale is below ndim >= 2 + lead_axes: passes untouched
    assert "w_r" not in folded["segments"][0]["unit0"]["ln"]
    # disabled config is the identity
    assert fold_w_r_tree(tree, OFF, lead_axes=1) is tree


# ---------------------------------------------------------------------------
# (d) per-op report: stacked checks name their layer
# ---------------------------------------------------------------------------

def test_per_op_report_expands_stacked_checks():
    scalar = Check(predicted=jnp.float32(1.0), actual=jnp.float32(1.0))
    stacked = Check(predicted=jnp.asarray([2.0, 3.0]),
                    actual=jnp.asarray([2.0, 3.5]))     # layer 1 corrupted
    # ids are positional among the PRESENT checks (None = op disabled),
    # stable across steps of one compiled serving trace
    ids, flags, rels = per_op_report([scalar, None, stacked], CFG,
                                     prefix="op")
    assert ids == ("op0", "op1:L0", "op1:L1")
    assert np.asarray(flags).tolist() == [False, False, True]
    assert float(np.asarray(rels)[2]) > CFG.threshold


# ---------------------------------------------------------------------------
# (e) kernel ops return the registered Check
# ---------------------------------------------------------------------------

def test_matmul_abft_kernel_op_conforms():
    a, b = _rand(7, 40, 24), _rand(8, 24, 16)
    op = MatmulAbftOp(block_m=16, block_n=16, block_k=16, interpret=True)
    out, chk = op(CFG, a, b)
    assert isinstance(chk, Check) and chk.granularity == "layer"
    np.testing.assert_allclose(np.asarray(out), np.asarray(a @ b),
                               atol=1e-4, rtol=1e-4)
    assert not bool(chk.flag(CFG))
    # the folded w_r path produces the same clean verdict
    folded = op.fold({"w": b}, CFG)
    out2, chk2 = op(CFG, a, b, w_r=folded["w_r"])
    assert not bool(chk2.flag(CFG))
    assert op(OFF, a, b)[1] is None


def test_flash_chain_check_is_nan_safe_check():
    o_extra = jnp.asarray([1.0, 2.0, 3.0])
    out = jnp.asarray([[1.5, 1.5], [1.0, 2.0]])
    chk = chain_check(o_extra, out)
    assert isinstance(chk, Check) and chk.granularity == "layer"
    assert not bool(chk.flag(CFG))
    bad = chain_check(o_extra, out.at[0, 0].set(jnp.float32(float("nan"))))
    assert bool(bad.flag(CFG))
