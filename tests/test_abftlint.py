"""abftlint (ISSUE 8 tentpole): the static-analysis subsystem's own tests.

Acceptance properties:
  (a) falsifiability — a fixture with a deliberately unchecked
      ``dot_general`` is flagged with this file's provenance, and
      injecting an unchecked matmul into the (clean) GCN forward flips
      its manifest from 0 unchecked to non-zero;
  (b) the GCN fused-network serve step verifies 100% coverage at slot
      granularity;
  (c) golden manifest parity across dense | bcoo | block_ell backends
      (every backend fully covered, same sink structure dense vs bcoo);
  (d) the marker primitive is inert: tagging changes no numerics and is
      OFF by default, so production traces carry zero sinks;
  (e) the static VMEM checker and the runtime fused_* fallback
      predicates are the SAME objects (shared-model identity), and an
      over-budget RungTable is rejected by ``assert_rung_table_fits``
      at lint time, before anything compiles;
  (f) every syncs-lint rule fires on a minimal fixture, suppression
      comments silence them, and the repo's own engine/ + launch/ trees
      sweep clean;
  (g) CLI smoke: ``--step gcn-serve --granularity slot`` exits 0 with a
      valid manifest; the unguarded LM-style trace exits non-zero.
"""
import json
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.analysis.coverage import analyze_jaxpr, analyze_step
from repro.analysis.syncs import scan_source, scan_tree
from repro.analysis.vmem import (
    FUSED_VMEM_BUDGET,
    assert_rung_table_fits,
    jaxpr_vmem_report,
    lint_rung_table,
)
from repro.core.abft import ABFTConfig, check_matmul, summarize
from repro.core.gcn import init_gcn
from repro.core.marker import check_tagging, tagging_enabled
from repro.engine import Graph, gcn_forward
from repro.engine.api import fold_w_r
from repro.engine.batching import pack_graphs
from repro.engine.streaming import (
    Rung,
    RungTable,
    make_packed_serve_step,
    packed_step_args,
)

CFG = ABFTConfig(mode="fused")
REPO = Path(__file__).resolve().parents[1]


def _graph(nodes=12, feat=6, seed=0):
    rng = np.random.default_rng(seed)
    s = (rng.random((nodes, nodes)) < 0.4).astype(np.float32)
    s += np.eye(nodes, dtype=np.float32)
    h0 = rng.random((nodes, feat)).astype(np.float32)
    return s, h0


def _params(dims, seed=0):
    return init_gcn(jax.random.PRNGKey(seed), dims)


# ---------------------------------------------------------------------------
# (a) falsifiability
# ---------------------------------------------------------------------------

class TestFalsifiability:
    def test_unchecked_dot_general_is_flagged_with_provenance(self):
        w1 = jnp.ones((6, 5))
        w2 = jnp.ones((5, 4))

        def fixture(x):
            y1 = x @ w1
            c = check_matmul(x, w1, y1, CFG)      # checked product
            y2 = y1 @ w2                          # deliberately unchecked
            rep = summarize([c], CFG)
            return y2, rep.flag

        m = analyze_step(fixture, jnp.ones((3, 6)), step="fixture")
        assert m.n_sinks >= 1
        assert m.n_unchecked == 1
        assert m.n_checked >= 1
        site = m.unchecked_ops[0]
        assert site.kind == "dot_general"
        # provenance points at THIS file's y2 line
        assert "test_abftlint.py" in site.provenance

    def test_fully_checked_fixture_is_clean(self):
        w = jnp.ones((6, 5))

        def fixture(x):
            y = x @ w
            rep = summarize([check_matmul(x, w, y, CFG)], CFG)
            return y, rep.flag

        m = analyze_step(fixture, jnp.ones((3, 6)))
        assert m.n_unchecked == 0 and m.n_checked >= 1
        assert m.coverage == 1.0

    def test_injected_unchecked_matmul_flips_gcn_manifest(self):
        dims = [6, 8, 3]
        params = _params(dims)
        s, h0 = _graph(feat=dims[0])
        s, h0 = jnp.asarray(s), jnp.asarray(h0)
        w_x = jnp.ones((dims[-1], 7))

        def clean(h0):
            logits, checks = gcn_forward(params, Graph(s=s, h0=h0), CFG)
            rep = summarize(checks, CFG)
            return logits, rep.flag

        def injected(h0):
            logits, flag = clean(h0)
            return logits @ w_x, flag             # unchecked extra product

        m0 = analyze_step(clean, h0, step="gcn-clean")
        m1 = analyze_step(injected, h0, step="gcn-injected")
        assert m0.n_unchecked == 0 and m0.n_checked >= 4
        assert m1.n_unchecked == 1                # the verifier is falsifiable
        assert m1.n_checked == m0.n_checked

    def test_detection_survives_jit(self):
        w1, w2 = jnp.ones((6, 5)), jnp.ones((5, 4))

        def fixture(x):
            y1 = x @ w1
            rep = summarize([check_matmul(x, w1, y1, CFG)], CFG)
            return y1 @ w2, rep.flag

        m = analyze_step(jax.jit(fixture), jnp.ones((3, 6)))
        assert m.n_unchecked == 1
        assert "pjit" in m.unchecked_ops[0].path


# ---------------------------------------------------------------------------
# (b) GCN fused-network slot coverage; (c) backend manifest parity
# ---------------------------------------------------------------------------

def _packed_manifest(granularity, *, fused_layer=False, fused_network=False,
                     dims=(8, 8, 3), n_graphs=3, nodes=16, block=8):
    params = fold_w_r(_params(list(dims)), CFG)
    graphs = [_graph(nodes, dims[0], seed=i) for i in range(n_graphs)]
    pb = pack_graphs(graphs, block=block, n_slots=n_graphs)
    step = make_packed_serve_step(params, CFG, pb.n_slots,
                                  granularity=granularity,
                                  fused_layer=fused_layer,
                                  fused_network=fused_network)
    with check_tagging():
        closed = jax.make_jaxpr(step)(*packed_step_args(pb))
    return analyze_jaxpr(closed, step=f"packed/{granularity}"), closed


class TestGCNCoverage:
    def test_fused_network_full_slot_coverage(self):
        m, _ = _packed_manifest("slot", fused_network=True)
        assert m.n_unchecked == 0
        assert m.n_checked >= 1
        assert m.coverage == 1.0
        assert "slot" in m.sink_granularities
        # the fused-network pallas kernel itself is a checked matmul site
        assert any(s.kind == "pallas_call" for s in m.checked_ops)

    @pytest.mark.parametrize("granularity", ["graph", "stripe", "slot"])
    def test_packed_serve_clean_at_every_granularity(self, granularity):
        m, _ = _packed_manifest(granularity)
        assert m.n_unchecked == 0
        # the two-pass path derives slot verdicts from stripe-granularity
        # check corners, so the traced sinks report stripe for slot too
        want = "stripe" if granularity == "slot" else granularity
        assert want in m.sink_granularities

    def test_manifest_parity_across_backends(self):
        dims = [6, 8, 3]
        params = _params(dims)
        s_np, h0_np = _graph(feat=dims[0])
        manifests = {}
        for backend in ("dense", "bcoo"):
            s = jnp.asarray(s_np)
            if backend == "bcoo":
                from jax.experimental import sparse as jsparse
                s = jsparse.BCOO.fromdense(s)

            def fwd(h0, s=s, backend=backend):
                logits, checks = gcn_forward(params, Graph(s=s, h0=h0), CFG,
                                             backend=backend)
                rep = summarize(checks, CFG)
                return logits, rep.flag, rep.max_rel

            manifests[backend] = analyze_step(fwd, jnp.asarray(h0_np),
                                              step=backend)
        m_ell, _ = _packed_manifest("graph")
        manifests["block_ell"] = m_ell

        # golden parity: every backend fully covered...
        for backend, m in manifests.items():
            assert m.n_unchecked == 0, (backend, m.to_dict())
            assert m.coverage == 1.0
        # ...and the dense/bcoo engines share one check structure (site
        # counts differ: dense aggregation is itself a dot_general, the
        # BCOO spmm is not)
        assert manifests["dense"].n_sinks == manifests["bcoo"].n_sinks
        assert manifests["dense"].sink_granularities == \
            manifests["bcoo"].sink_granularities

    def test_unguarded_trace_reports_everything_unchecked(self):
        # mode=none -> no sinks -> every matmul listed (the LM-lane shape)
        off = ABFTConfig(mode="none")
        params = _params([6, 8, 3])
        s, h0 = map(jnp.asarray, _graph(feat=6))

        def fwd(h0):
            logits, checks = gcn_forward(params, Graph(s=s, h0=h0), off)
            return logits

        m = analyze_step(fwd, h0)
        assert m.n_sinks == 0
        assert m.n_checked == 0
        assert m.n_unchecked >= 4
        assert all(s.provenance for s in m.unchecked_ops)


# ---------------------------------------------------------------------------
# (d) marker inertness
# ---------------------------------------------------------------------------

class TestMarkerInertness:
    def test_tagging_off_by_default(self):
        assert not tagging_enabled()
        w = jnp.ones((6, 5))

        def fixture(x):
            y = x @ w
            rep = summarize([check_matmul(x, w, y, CFG)], CFG)
            return y, rep.flag

        closed = jax.make_jaxpr(fixture)(jnp.ones((3, 6)))
        m = analyze_jaxpr(closed)
        assert m.n_sinks == 0  # production traces carry no marker

    def test_tagging_changes_no_numerics(self):
        rng = np.random.default_rng(1)
        x = jnp.asarray(rng.random((4, 6)), jnp.float32)
        w = jnp.asarray(rng.random((6, 5)), jnp.float32)

        def fixture(x):
            y = x @ w
            rep = summarize([check_matmul(x, w, y, CFG)], CFG)
            return y, rep.max_rel

        y0, r0 = fixture(x)
        with check_tagging():
            y1, r1 = jax.jit(fixture)(x)
        np.testing.assert_array_equal(np.asarray(y0), np.asarray(y1))
        np.testing.assert_array_equal(np.asarray(r0), np.asarray(r1))

    def test_tagging_transparent_to_grad(self):
        w = jnp.ones((6, 5))

        def loss(x):
            y = x @ w
            rep = summarize([check_matmul(x, w, y, CFG)], CFG)
            return y.sum() + 0.0 * rep.max_rel

        x = jnp.ones((3, 6))
        g0 = jax.grad(loss)(x)
        with check_tagging():
            g1 = jax.grad(loss)(x)
        np.testing.assert_array_equal(np.asarray(g0), np.asarray(g1))


# ---------------------------------------------------------------------------
# (e) VMEM: shared identity + lint-time rung rejection + static estimates
# ---------------------------------------------------------------------------

class TestVmem:
    def test_runtime_and_static_checker_are_the_same_objects(self):
        from repro.analysis import vmem
        from repro.kernels.gcn_fused import ops as fused_ops
        assert fused_ops.fused_layer_fits is vmem.fused_layer_fits
        assert fused_ops.fused_network_fits is vmem.fused_network_fits
        assert fused_ops.fused_vmem_bytes is vmem.fused_vmem_bytes
        assert fused_ops.network_vmem_bytes is vmem.network_vmem_bytes
        assert fused_ops.FUSED_VMEM_BUDGET is vmem.FUSED_VMEM_BUDGET

    def test_over_budget_rung_table_rejected_before_compile(self):
        table = RungTable(rungs=(Rung(4, 4, 2), Rung(64, 64, 4)),
                          block=8, stripe_multiple=4, width_multiple=4)
        dims = [128, 256, 64]
        # a tiny budget must reject, naming the rung, without compiling
        with pytest.raises(ValueError, match="rung"):
            assert_rung_table_fits(table, dims, block=8, budget=4096)
        # the real budget admits this menu; verdicts carry both tiers
        verdicts = assert_rung_table_fits(table, dims, block=8,
                                          budget=FUSED_VMEM_BUDGET)
        assert len(verdicts) == 2
        assert all(v.fits and v.layer_fits for v in verdicts)

    def test_lint_rung_table_network_tier(self):
        table = RungTable(rungs=(Rung(2, 4, 2),), block=8,
                          stripe_multiple=4, width_multiple=4)
        v, = lint_rung_table(table, [8, 8, 3], block=8,
                             budget=FUSED_VMEM_BUDGET, fused_network=True)
        assert v.network_bytes is not None and v.network_fits
        assert v.rows == 2 * 8

    def test_static_pallas_estimates_from_trace(self):
        m, closed = _packed_manifest("slot", fused_network=True)
        ests = jaxpr_vmem_report(closed, budget=FUSED_VMEM_BUDGET)
        assert len(ests) >= 1
        for e in ests:
            assert e.total_bytes > 0
            assert e.fits


# ---------------------------------------------------------------------------
# (f) syncs lint rules
# ---------------------------------------------------------------------------

SYNC_SNIPPETS = {
    "implicit-sync-in-loop": "for r in batch:\n    x = float(vals[r])\n",
    "backend-query-in-loop":
        "import jax\nwhile run:\n    b = jax.default_backend()\n",
    "jit-in-loop": "import jax\nfor s in steps:\n    f = jax.jit(step)\n",
    "pack-without-caps": "pb = pack_graphs(graphs, block=8)\n",
    "mutable-default": "def f(x, acc=[]):\n    return acc\n",
    "fold-in-loop": "for s in steps:\n    p = fold_w_r(params, cfg)\n",
}


class TestSyncsLint:
    @pytest.mark.parametrize("rule", sorted(SYNC_SNIPPETS))
    def test_rule_fires(self, rule):
        findings = scan_source(SYNC_SNIPPETS[rule], path=f"<{rule}>")
        assert any(f.rule == rule for f in findings), findings

    @pytest.mark.parametrize("tag", ["ok", "sync-ok",
                                     "implicit-sync-in-loop-ok"])
    def test_suppression(self, tag):
        src = ("for r in batch:\n"
               f"    x = float(vals[r])  # abftlint: {tag}\n")
        assert scan_source(src) == []

    def test_suppression_is_rule_scoped(self):
        # a fold-in-loop tag must NOT silence a sync finding
        src = ("for r in batch:\n"
               "    x = float(vals[r])  # abftlint: fold-ok\n")
        assert [f.rule for f in scan_source(src)] == \
            ["implicit-sync-in-loop"]

    def test_sync_methods_and_numpy_copies(self):
        src = ("import numpy as np\n"
               "for r in batch:\n"
               "    a = out.block_until_ready()\n"
               "    b = np.asarray(out)\n"
               "    c = vals.item()\n")
        rules = [f.rule for f in scan_source(src)]
        assert rules == ["implicit-sync-in-loop"] * 3

    def test_constants_and_top_level_calls_are_fine(self):
        src = ("x = float(vals[0])\n"            # not in a loop
               "for r in batch:\n"
               "    y = int(8)\n")               # constant operand
        assert scan_source(src) == []

    def test_repo_dispatch_layers_sweep_clean(self):
        findings = scan_tree(REPO)
        assert findings == [], "\n".join(str(f) for f in findings)


# ---------------------------------------------------------------------------
# (g) CLI
# ---------------------------------------------------------------------------

class TestCLI:
    def test_gcn_serve_slot_exits_zero_with_manifest(self, tmp_path, capsys):
        from repro.analysis.lint import main
        manifest = tmp_path / "gcn-serve.json"
        rc = main(["--step", "gcn-serve", "--granularity", "slot",
                   "--graphs", "2", "--nodes", "12",
                   "--manifest", str(manifest)])
        out = capsys.readouterr().out
        assert rc == 0, out
        payload = json.loads(manifest.read_text())
        assert payload["n_unchecked"] == 0
        assert payload["n_checked"] >= 1
        assert payload["sink_granularities"]
        assert "abftlint: clean" in out

    def test_unguarded_step_exits_nonzero_with_provenance(self, capsys):
        # --mode none is the LM-lane shape: no sinks, every matmul listed
        from repro.analysis.lint import main
        rc = main(["--step", "gcn-serve", "--mode", "none",
                   "--graphs", "2", "--nodes", "12", "--passes", "coverage"])
        out = capsys.readouterr().out
        assert rc == 1
        assert "UNCHECKED" in out and ".py:" in out

    def test_expect_unchecked_inverts_the_gate(self, capsys):
        from repro.analysis.lint import main
        rc = main(["--step", "gcn-serve", "--mode", "none",
                   "--graphs", "2", "--nodes", "12",
                   "--passes", "coverage", "--expect-unchecked"])
        assert rc == 0
        rc = main(["--step", "gcn-serve", "--granularity", "slot",
                   "--graphs", "2", "--nodes", "12",
                   "--passes", "coverage", "--expect-unchecked"])
        assert rc == 1  # fully covered -> the inverted gate must fail

    def test_gcn_stream_rung_lint_runs_before_traces(self, capsys):
        from repro.analysis.lint import main
        rc = main(["--step", "gcn-stream", "--granularity", "stripe",
                   "--passes", "coverage,vmem"])
        out = capsys.readouterr().out
        assert rc == 0, out
        assert "rung" in out.lower()

    def test_bad_pass_is_usage_error(self):
        from repro.analysis.lint import main
        assert main(["--passes", "nope"]) == 2
