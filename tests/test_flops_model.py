"""Validate the analytic FLOPs model against XLA's counts on UNROLLED tiny
configs (XLA undercounts scan bodies — the probe in this file demonstrates
it — so the analytic model is the roofline's FLOPs source)."""
import dataclasses

import jax
import jax.numpy as jnp
import pytest

from repro.configs import SHAPES, get_config, list_archs, smoke_config
from repro.configs.base import ShapeConfig
from repro.core.abft import ABFTConfig

from benchmarks.flops_model import count_step, param_count, xla_flops


def test_scan_undercount_probe():
    """XLA HloCostAnalysis counts while bodies once (the reason the roofline
    uses the analytic model)."""
    def f_scan(x, w):
        def body(c, wi):
            return jnp.tanh(c @ wi), None
        return jax.lax.scan(body, x, w)[0].sum()

    def f_unroll(x, w):
        for i in range(8):
            x = jnp.tanh(x @ w[i])
        return x.sum()

    xs = jnp.ones((64, 64))
    ws = jnp.ones((8, 64, 64))
    c_scan = xla_flops(jax.jit(f_scan).lower(xs, ws).compile())
    c_unr = xla_flops(jax.jit(f_unroll).lower(xs, ws).compile())
    assert c_unr > 6 * c_scan          # ~8× modulo fusion noise


@pytest.mark.slow
@pytest.mark.parametrize("arch", ["gemma-2b", "chatglm3-6b", "rwkv6-7b"])
def test_analytic_matches_xla_unrolled(arch):
    """Unrolled (scan_layers=False, single-chunk attention) tiny config:
    analytic forward FLOPs within 25% of XLA's count (fusion makes XLA's
    number slightly smaller; gross mismatches would signal a modeling bug).
    """
    if arch == "rwkv6-7b":
        pytest.skip("rwkv time scan cannot unroll — analytic-only path")
    from repro.models.transformer import model_forward

    cfg = smoke_config(get_config(arch))
    cfg = dataclasses.replace(cfg, scan_layers=False, remat=False,
                              attn_chunk=64)
    shape = ShapeConfig("probe", seq_len=32, global_batch=2, kind="prefill")
    abft = ABFTConfig(mode="none")

    params_s = jax.eval_shape(
        lambda: __import__("repro.models.transformer",
                           fromlist=["init_model"]).init_model(
            cfg, jax.random.PRNGKey(0)))
    tokens = jax.ShapeDtypeStruct((2, 32), jnp.int32)

    def fwd(p, t):
        logits, _, _ = model_forward(p, cfg, {"tokens": t}, abft)
        return logits.sum()

    comp = jax.jit(fwd).lower(params_s, tokens).compile()
    xla = xla_flops(comp)
    an = count_step(cfg, shape, "none")["flops"]
    # analytic includes elementwise estimates; xla fuses — allow slack
    assert 0.5 < an / xla < 2.0, (an, xla)


@pytest.mark.slow
def test_param_count_matches_real_init():
    for arch in list_archs():
        cfg = smoke_config(get_config(arch))
        from repro.models.transformer import init_model
        shapes = jax.eval_shape(lambda c=cfg: init_model(c, jax.random.PRNGKey(0)))
        real = sum(int(jnp.prod(jnp.asarray(x.shape)))
                   for x in jax.tree.leaves(shapes))
        an = param_count(cfg)
        assert abs(an - real) / real < 0.05, (arch, an, real)


def test_moe_flops_scale_with_topk():
    cfg = get_config("qwen3-moe-30b-a3b")
    shape = SHAPES["train_4k"]
    full = count_step(cfg, shape, "none")["flops"]
    import dataclasses as dc
    cfg2 = dc.replace(cfg, moe=dc.replace(cfg.moe, top_k=4))
    half = count_step(cfg2, shape, "none")["flops"]
    assert half < full
