"""Tests for the numpy fault-injection engine (paper Table I mechanics)."""
import numpy as np
import pytest

from repro.core.datasets import make_reduced, make_dataset, STATS
from repro.core.fault import (
    NumpyGCN,
    flip_bit_f32,
    flip_bit_f64,
    run_campaign,
    run_campaigns,
)
from repro.core.opcount import gcn_op_counts


def test_bit_flip_involution():
    rng = np.random.default_rng(0)
    for _ in range(50):
        x = np.float32(rng.normal() * 10.0 ** float(rng.integers(-3, 4)))
        bit = int(rng.integers(32))
        assert flip_bit_f32(flip_bit_f32(x, bit), bit) == x
    for _ in range(50):
        x = np.float64(rng.normal())
        bit = int(rng.integers(64))
        y = flip_bit_f64(x, bit)
        assert y != x or bit == 63 and x == 0  # sign flip of 0 gives -0
        assert flip_bit_f64(y, bit) == x


@pytest.fixture(scope="module")
def model():
    ds = make_reduced("cora", scale=8, seed=0)
    return NumpyGCN(ds, seed=0)


def test_forward_residuals_small(model):
    """Fault-free residuals are pure float-rounding noise."""
    for st in model.layers:
        assert abs(st.sum_x - st.pred1) < 1e-2 * max(1.0, abs(st.sum_x))
        assert abs(st.sum_hout - st.pred2) < 1e-2 * max(1.0, abs(st.sum_hout))


def test_prefix_matches_full_dot(model):
    """Prefix at t = n_terms-1 equals the final element value."""
    st0 = model.layers[0]
    i, j = 3, 2
    nt = model.comb_terms(0, i)
    part, _ = model.comb_prefix(0, i, j, nt - 1)
    np.testing.assert_allclose(part, st0.x[i, j], rtol=1e-4, atol=1e-6)
    nt = model.agg_terms(i)
    part, _ = model.agg_prefix(0, i, j, nt - 1)
    np.testing.assert_allclose(part, st0.h_out[i, j], rtol=1e-4, atol=1e-6)


def test_campaigns_run_and_categorize(model):
    rng = np.random.default_rng(1)
    cats = set()
    for _ in range(100):
        o = run_campaign(model, "fused", rng)
        assert o.mode == "fused"
        assert set(o.diffs) == {1e-4, 1e-5, 1e-6, 1e-7}
        cats.add(o.target)
    assert cats == {"mm", "check"}


@pytest.mark.parametrize("mode", ["split", "fused"])
def test_big_fault_always_detected(mode):
    """A sign-bit flip on a large partial must always flag at tau=1e-4."""
    ds = make_reduced("cora", scale=16, seed=1)
    m = NumpyGCN(ds, seed=1)
    st = m.layers[1]
    # emulate a large fault directly: delta large in final output
    delta = 1e4
    d2 = (st.sum_hout - st.pred2) + delta
    assert abs(d2) > 1e-4


def test_summary_percentages(model):
    s = run_campaigns(model, "fused", n=200, seed=2)
    for tau in (1e-4, 1e-7):
        # paper taxonomy: 3 exclusive categories (masked ⊂ silent)
        total = s.detected[tau] + s.false_pos[tau] + s.silent[tau]
        assert abs(total - 100.0) < 1e-6
        assert s.masked[tau] <= s.silent[tau] + 1e-9
    # at the tight threshold, nothing corrupted stays silent (paper finding)
    assert s.silent[1e-7] <= s.silent[1e-4] + 1e-9


def test_split_has_more_false_positives_tendency():
    """Paper: fused has fewer FPs (less check state).  Statistical, so use a
    generous margin on a decent sample."""
    ds = make_reduced("citeseer", scale=8, seed=3)
    m = NumpyGCN(ds, seed=3)
    sp = run_campaigns(m, "split", n=400, seed=4)
    fu = run_campaigns(m, "fused", n=400, seed=4)
    assert fu.false_pos[1e-7] <= sp.false_pos[1e-7] + 2.0


def test_full_dataset_stats_table():
    """Dataset stats reproduce paper Table II 'True Out' to <1%."""
    paper_true = {"cora": 2.8e6, "citeseer": 4.6e6, "pubmed": 37.6e6,
                  "nell": 1745.9e6}
    for name, want in paper_true.items():
        got = gcn_op_counts(name).true_out
        # paper values are rounded to 1 decimal (e.g. "4.6 M"), so allow 1.5%
        assert abs(got - want) / want < 0.015, (name, got, want)


def test_dataset_generation_matches_stats():
    ds = make_dataset("cora", seed=0)
    st = STATS["cora"]
    assert ds.s.shape == (st.nodes, st.nodes)
    assert ds.s.nnz == st.adj_nnz
    assert ds.features.nnz == st.feat_nnz
    # normalized adjacency is symmetric-ish in value range
    assert ds.s.data.min() > 0
    assert ds.s.data.max() <= 1.0 + 1e-6
