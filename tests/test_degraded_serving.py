"""Degraded-backend serving (ISSUE 9): sticky-fault discrimination in
the guard, the streaming engine's backend ladder, the hung-dispatch
watchdog wiring, and the periodic check-path self-check.

Acceptance properties:
  (a) watchdog satellites: ``stop()`` without a prior ``start()`` is a
      no-op (no TypeError, no phantom sample) and warmup uses a TRUE
      running mean, not a pairwise EWMA blend;
  (b) the headline e2e contract — with a sticky accumulator fault baked
      into the level-0 backend, the guard classifies the site persistent
      within the configured window, the engine checkpoints, degrades
      down its ladder, and KEEPS SERVING: every submitted request gets a
      verdict, none dropped, none hung;
  (c) the degraded dense fallback is numerically clean (no flags on
      clean traffic) and its logits match the packed backend's;
  (d) ``hang_timeout`` forces adjudication of a stuck in-flight batch
      through ``pump`` (fake clock);
  (e) the engine's periodic self-check catches a corrupted eq.-5 fold
      mid-stream, refolds, rebuilds its steps, and the stream continues.
"""
import numpy as np
import pytest

from repro.core.abft import ABFTConfig
from repro.engine import StreamingEngine, plan_rungs, synth_graph_stream
from repro.runtime import ABFTGuard, GuardConfig
from repro.runtime.watchdog import StragglerWatchdog

FEAT, HIDDEN, CLASSES = 8, 16, 4


def _stream(n=12, seed=0):
    return synth_graph_stream(n, n_lo=16, n_hi=40, feat=FEAT, seed=seed)


def _params(seed=0):
    rng = np.random.default_rng(seed)
    return {"layers": [
        {"w": (rng.normal(size=(FEAT, HIDDEN)) * 0.3).astype(np.float32),
         "b": np.zeros(HIDDEN, np.float32)},
        {"w": (rng.normal(size=(HIDDEN, CLASSES)) * 0.3).astype(
            np.float32),
         "b": np.zeros(CLASSES, np.float32)}]}


def _engine(stream, *, guard=None, **kw):
    rungs = plan_rungs(stream[:4], n_slots=4, block=8)
    return StreamingEngine(_params(), ABFTConfig(threshold=1e-3), rungs,
                           guard=guard, keep_logits=True, **kw)


def _serve_all(engine, stream):
    results = []
    for s, h0 in stream:
        engine.submit(s, h0)
        results.extend(engine.take_results())
    results.extend(engine.drain())
    return results


# ---------------------------------------------------------------------------
# (a) watchdog satellites
# ---------------------------------------------------------------------------

def test_watchdog_stop_without_start_is_noop():
    wd = StragglerWatchdog()
    assert wd.stop() is False           # regression: raised TypeError
    assert wd.n == 0 and wd.ewma == 0.0  # no phantom sample recorded


def test_watchdog_warmup_is_true_running_mean():
    times = iter([0.0, 1.0, 1.0, 5.0, 5.0, 6.0])
    wd = StragglerWatchdog(warmup=3, clock=lambda: next(times))
    for _ in range(3):
        wd.start()
        wd.stop()
    # samples 1.0, 4.0, 1.0 -> mean 2.0 (the pairwise EWMA blend gave
    # 0.5*(0.5*(1+4)+1) = 1.75)
    assert wd.ewma == pytest.approx(2.0)


def test_watchdog_slow_steps_tracked_without_polluting_ewma():
    t = {"now": 0.0}
    wd = StragglerWatchdog(threshold=2.0, warmup=2,
                           clock=lambda: t["now"])
    for dt in (1.0, 1.0):
        wd.start()
        t["now"] += dt
        wd.stop()
    base = wd.ewma
    wd.start()
    t["now"] += 50.0                    # a straggler
    assert wd.stop() is True
    assert wd.events == 1 and wd.slow_streak == 1
    assert wd.ewma == base              # outlier kept out of the estimate


# ---------------------------------------------------------------------------
# (b)+(c) the e2e degrade contract
# ---------------------------------------------------------------------------

def _sticky_guard():
    return ABFTGuard(GuardConfig(max_retries=1, max_restores=1,
                                 persistent_window=4,
                                 persistent_threshold=2))


@pytest.mark.parametrize("fusion", [{}, {"fused_network": True}],
                         ids=["two-pass", "fused-network"])
def test_sticky_fault_degrades_backend_and_keeps_serving(fusion, tmp_path):
    """A stuck accumulator in the level-0 backend: retries re-execute
    through the same poisoned backend (doomed), the guard classifies the
    site persistent, and the engine checkpoints + walks its ladder while
    every request still gets served."""
    stream = _stream(12)
    engine = _engine(stream, guard=_sticky_guard(),
                     inject=(0, 0, 0, 100.0),
                     watchdog=StragglerWatchdog(warmup=2),
                     hang_timeout=30.0,
                     checkpoint_dir=str(tmp_path / "ckpt"),
                     selfcheck_interval=4, **fusion)
    assert engine.stats()["backend_ladder"][-1] == "dense"
    results = _serve_all(engine, stream)

    stats = engine.stats(results)
    assert stats["served"] == stats["submitted"] == len(stream)
    assert sorted(r.rid for r in results) == list(range(len(stream)))
    assert all(r.status == "served" for r in results)
    assert stats["degrades"] >= 1 and stats["failovers"] >= 1
    assert stats["degrade_level"] >= 1          # left the poisoned level
    assert stats["active_backend"] != stats["backend_ladder"][0] or \
        stats["degrade_level"] >= 1
    # the sticky site was discriminated, not retried forever
    tiers = stats["repair_tiers"]
    assert tiers["persistent_sites"] or tiers["persistent_escalations"] \
        or stats["failovers"] >= 1
    # checkpoint written at the failover boundary
    ckpts = list((tmp_path / "ckpt").iterdir())
    assert ckpts, "no checkpoint written on degrade"
    # post-degrade traffic is clean: later results carry no flags
    tail = [r for r in results if r.rid >= 8]
    assert tail and not any(r.flag for r in tail)


def test_dense_fallback_matches_packed_logits():
    """The terminal dense backend must agree with the packed backend on
    clean traffic — degraded service returns the same answers."""
    stream = _stream(6)
    packed = _engine(stream)
    dense = _engine(stream)
    dense._degrade("test: force dense")
    while not dense._active_dense():
        dense._degrade("test: force dense")
    rp = {r.rid: r for r in _serve_all(packed, stream)}
    rd = {r.rid: r for r in _serve_all(dense, stream)}
    assert sorted(rp) == sorted(rd)
    assert dense.stats()["active_backend"] == "dense"
    assert dense.dense_dispatches >= 1
    for rid in rp:
        assert rp[rid].status == rd[rid].status == "served"
        assert not rd[rid].flag
        np.testing.assert_allclose(rp[rid].logits, rd[rid].logits,
                                   rtol=2e-4, atol=2e-5)


def test_degrade_reroutes_oversize_singletons():
    stream = _stream(6)
    big = synth_graph_stream(1, n_lo=220, n_hi=240, feat=FEAT, seed=9)[0]
    engine = _engine(stream)
    engine._degrade("test: force dense")
    while not engine._active_dense():
        engine._degrade("test: force dense")
    results = _serve_all(engine, stream + [big])
    assert len(results) == 7 and all(r.status == "served" for r in results)
    assert engine.singleton_dispatches == 1


# ---------------------------------------------------------------------------
# (d) hung-dispatch timeout through pump
# ---------------------------------------------------------------------------

def test_hang_timeout_flushes_inflight_batch():
    t = {"now": 0.0}
    stream = _stream(8)
    engine = _engine(stream, hang_timeout=5.0, flush_deadline=0.001,
                     clock=lambda: t["now"])
    for s, h0 in stream[:4]:
        engine.submit(s, h0)
    t["now"] += 0.01
    engine.pump()                       # deadline flush -> dispatch
    assert engine._inflight is not None
    t["now"] += 10.0                    # the dispatch "hangs"
    engine.pump()
    assert engine.hang_flushes == 1
    assert engine._inflight is None     # forced adjudication resolved it
    results = engine.take_results()
    assert len(results) == 4 and all(r.status == "served" for r in results)
    results.extend(engine.drain())


# ---------------------------------------------------------------------------
# (e) periodic self-check wiring in the engine
# ---------------------------------------------------------------------------

def test_engine_selfcheck_repairs_corrupted_fold_midstream():
    from repro.faults import FaultInjector, FaultModel, verify_w_r
    stream = _stream(12)
    engine = _engine(stream, selfcheck_interval=1)
    # corrupt the carried eq.-5 fold in place mid-stream (a NaN stuck-at:
    # the nastiest case — a naive comparison would never flag again)
    inj = FaultInjector(FaultModel(site="w_r", kind="stuck",
                                   stuck_value=float("nan")))
    assert inj.fires(0)
    engine.params = inj.apply_params(engine.params)
    assert verify_w_r(engine.params, engine.cfg) == [0]
    results = _serve_all(engine, stream)
    stats = engine.stats(results)
    assert stats["selfcheck_trips"] >= 1
    assert stats["selfcheck_repairs"] >= 1
    assert verify_w_r(engine.params, engine.cfg) == []   # refolded
    assert stats["served"] == len(stream)
    assert all(r.status == "served" for r in results)


def test_selfcheck_interval_validation():
    stream = _stream(4)
    with pytest.raises(ValueError):
        _engine(stream, selfcheck_interval=0)
    with pytest.raises(ValueError):
        _engine(stream, hang_timeout=0.0)


def test_stats_surface_robustness_counters():
    stream = _stream(4)
    engine = _engine(stream)
    stats = engine.stats(_serve_all(engine, stream))
    for key in ("repair_tiers", "backend_ladder", "active_backend",
                "degrade_level", "degrades", "failovers",
                "dense_dispatches", "hang_flushes", "watchdog_events",
                "selfcheck_runs", "selfcheck_trips", "selfcheck_repairs"):
        assert key in stats, key
    assert stats["degrades"] == 0 and stats["failovers"] == 0
    assert stats["repair_tiers"]["slot"] == 0
