"""Unified GCN engine tests (ISSUE 2 tentpole).

Acceptance properties:
  (a) all three backends (dense | bcoo | block_ell) produce identical
      logits (atol 1e-4) and identical ABFT flag / max_rel / n_checks
      semantics through the single ``gcn_apply(..., backend=...)`` entry
      point, for every ABFT mode;
  (b) a combination-matmul fault (bit flip in X, eq.-5 column taken from
      the independent H w_r path) is flagged by every backend at the
      paper's 1e-4 absolute threshold;
  (c) bucketed multi-graph batching is exact: the batched dense engine
      step reproduces per-graph logits on the logical rows, and padded
      slots can never flag;
  (d) ABFTGuard: per-instance config (no shared mutable default) and the
      rolling flag-rate window driving should_evict;
  (e) [slow] the Table I smoke campaign through the JAX engine agrees
      with the numpy fault engine on injected bit flips.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.abft import ABFTConfig
from repro.core.fault import flip_bit_f32
from repro.core.gcn import (
    init_gcn,
    normalized_adjacency_bcoo,
    normalized_adjacency_dense,
)
from repro.engine import (
    Graph,
    backend_names,
    gcn_apply,
    gcn_layer,
    infer_backend,
    make_backend,
    make_batches,
    pick_bucket,
    synth_graph_stream,
)
from repro.kernels.spmm_abft import dense_to_block_ell
from repro.runtime import ABFTGuard, GuardConfig

BACKENDS = ("dense", "bcoo", "block_ell")


def _graph_triple(seed, n, f, avg_deg=4):
    """(dense S, BCOO S, BlockEll S, H0) of one random undirected graph."""
    rng = np.random.default_rng(seed)
    m = n * avg_deg // 2
    e = rng.integers(0, n, size=(3 * m + 16, 2), dtype=np.int64)
    e = e[e[:, 0] != e[:, 1]]
    e = np.unique(np.sort(e, axis=1), axis=0)[:m]
    s_d = normalized_adjacency_dense(e, n)
    s_b = normalized_adjacency_bcoo(e, n)
    bell = dense_to_block_ell(s_d, block_m=32, block_k=32)
    h0 = jnp.asarray(rng.normal(0, 0.5, size=(n, f)).astype(np.float32))
    return jnp.asarray(s_d), s_b, bell, h0


def _apply(params, s, h0, cfg, backend):
    opts = {"block_g": 32} if backend == "block_ell" else {}
    return gcn_apply(params, Graph(s=s, h0=h0), cfg, backend=backend, **opts)


# ---------------------------------------------------------------------------
# (a) three-backend parity through the one entry point
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("mode", ["none", "split", "fused"])
@pytest.mark.parametrize("seed,n", [(0, 96), (7, 160)])
def test_backend_parity(seed, n, mode):
    s_d, s_b, bell, h0 = _graph_triple(seed, n, f=24)
    params = init_gcn(jax.random.PRNGKey(seed), (24, 16, 5))
    cfg = ABFTConfig(mode=mode, threshold=1e-3, relative=True)

    results = {b: _apply(params, s, h0, cfg, b)
               for b, s in zip(BACKENDS, (s_d, s_b, bell))}
    ref_logits, ref_rep = results["dense"]
    for b, (logits, rep) in results.items():
        np.testing.assert_allclose(np.asarray(logits), np.asarray(ref_logits),
                                   atol=1e-4, rtol=1e-4, err_msg=b)
        assert bool(rep.flag) == bool(ref_rep.flag) is False, b
        assert int(rep.n_checks) == int(ref_rep.n_checks), b
        if cfg.enabled:
            # clean max_rel is each backend's rounding floor — far under tau
            assert float(rep.max_rel) < cfg.threshold / 4, (b, rep)


@pytest.mark.parametrize("backend", ["dense", "bcoo"])
def test_gcn_apply_stashes_s_c_on_graph(backend):
    """Repeated gcn_apply calls on the same staged Graph must not recompute
    the O(nnz) column checksum: the first call stashes the backend's s_c
    back on the Graph, and later calls hand that same array to the backend
    constructor (ISSUE 4 satellite fix)."""
    s_d, s_b, _, h0 = _graph_triple(5, 96, f=12)
    s = {"dense": s_d, "bcoo": s_b}[backend]
    params = init_gcn(jax.random.PRNGKey(5), (12, 8, 3))
    cfg = ABFTConfig(mode="fused", threshold=1e-3, relative=True)

    g = Graph(s=s, h0=h0)
    assert g.s_c is None
    logits_1, rep_1 = gcn_apply(params, g, cfg, backend=backend)
    assert g.s_c is not None
    stashed = g.s_c
    logits_2, rep_2 = gcn_apply(params, g, cfg, backend=backend)
    assert g.s_c is stashed                    # reused, not recomputed
    np.testing.assert_array_equal(np.asarray(logits_1),
                                  np.asarray(logits_2))
    assert float(rep_1.max_rel) == float(rep_2.max_rel)

    # a different checksum dtype must NOT reuse the auto-stash (it would
    # silently run the new cfg's checks at the stale precision) — while a
    # user-provided s_c is trusted verbatim across cfgs
    cfg64 = ABFTConfig(mode="fused", threshold=1e-3, relative=True,
                       dtype=jnp.float64)
    gcn_apply(params, g, cfg64, backend=backend)
    assert g.s_c is not stashed
    user = Graph(s=s, h0=h0, s_c=stashed)
    gcn_apply(params, user, cfg64, backend=backend)
    assert user.s_c is stashed


def test_backend_registry_and_inference():
    s_d, s_b, bell, _ = _graph_triple(3, 64, f=8)
    assert set(BACKENDS) <= set(backend_names())
    assert infer_backend(s_d) == "dense"
    assert infer_backend(s_b) == "bcoo"
    assert infer_backend(bell) == "block_ell"
    with pytest.raises(ValueError):
        make_backend(s_d, ABFTConfig(), backend="nope")
    with pytest.raises(ValueError):
        make_backend(s_d, ABFTConfig(), partition=object())
    with pytest.raises(TypeError):
        make_backend(s_d, ABFTConfig(), backend="block_ell")


# ---------------------------------------------------------------------------
# (b) fault in the combination output flags in every backend
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("backend", BACKENDS)
def test_backend_detects_combination_fault(backend):
    tau = 1e-4
    s_d, s_b, bell, h0 = _graph_triple(11, 128, f=16)
    s = {"dense": s_d, "bcoo": s_b, "block_ell": bell}[backend]
    w = init_gcn(jax.random.PRNGKey(11), (16, 12, 4))["layers"][0]["w"]
    cfg = ABFTConfig(mode="fused", threshold=tau, relative=False)
    opts = {"block_g": 32} if backend == "block_ell" else {}
    bk = make_backend(s, cfg, **opts)

    x = h0 @ w
    x_r = h0 @ w.sum(axis=1)                   # independent eq.-5 path
    _, chk_clean = bk.aggregate(x, x_r)
    assert abs(float(chk_clean.predicted) - float(chk_clean.actual)) < tau / 4

    # bit-flip a combination output element the fault engine's way; pick a
    # site big enough that an exponent flip cannot hide under tau
    x_np = np.asarray(x).copy()
    big = np.argwhere(np.abs(x_np) >= 1e-2)
    i, j = big[7]
    x_np[i, j] = flip_bit_f32(np.float32(x_np[i, j]), 27)
    _, chk_bad = bk.aggregate(jnp.asarray(x_np), x_r)
    div = abs(float(chk_bad.predicted) - float(chk_bad.actual))
    assert div > tau, (backend, div)


# ---------------------------------------------------------------------------
# (c) bucketed multi-graph batching
# ---------------------------------------------------------------------------

def test_pick_bucket():
    assert pick_bucket(17, [32, 64]) == 32
    assert pick_bucket(33, [32, 64]) == 64
    with pytest.raises(ValueError):
        pick_bucket(65, [32, 64])


def test_batched_serving_matches_per_graph():
    stream = synth_graph_stream(10, n_lo=20, n_hi=60, feat=12, seed=4)
    batches = make_batches(stream, batch_size=4, buckets=[32, 64])
    assert sum(b.n_graphs for b in batches) == 10
    assert all(b.s.shape[0] == 4 for b in batches)

    params = init_gcn(jax.random.PRNGKey(4), (12, 8, 3))
    cfg = ABFTConfig(mode="fused", threshold=1e-3, relative=True)
    step = jax.jit(lambda s, h: gcn_apply(params, Graph(s=s, h0=h), cfg,
                                          backend="dense"))
    # index the stream by (bucket, order) the same way make_batches does
    per_graph = {id(s): gcn_apply(params, Graph(jnp.asarray(s),
                                                jnp.asarray(h)), cfg)[0]
                 for s, h in stream}
    by_bucket = {}
    for s, h in stream:
        by_bucket.setdefault(pick_bucket(s.shape[0], [32, 64]),
                             []).append((s, h))
    it = {b: iter(v) for b, v in by_bucket.items()}
    for batch in batches:
        logits, rep = step(jnp.asarray(batch.s), jnp.asarray(batch.h0))
        assert not bool(rep.flag)          # padded slots must stay silent
        for bi in range(batch.n_graphs):
            s, h = next(it[batch.bucket])
            n = s.shape[0]
            np.testing.assert_allclose(
                np.asarray(logits[bi, :n]), np.asarray(per_graph[id(s)]),
                atol=1e-5, rtol=1e-5)
            # padded rows are exactly zero (zero-padding is exact)
            assert float(np.abs(np.asarray(logits[bi, n:])).max(initial=0.0)) \
                == 0.0


def test_serve_gcn_driver_smoke(capsys):
    from repro.launch.serve_gcn import main
    stats = main(["--graphs", "8", "--batch", "4", "--buckets", "32,64",
                  "--nodes", "16,56", "--feat", "8", "--hidden", "8",
                  "--classes", "3"])
    assert stats["graphs"] == 8
    assert stats["graphs_per_sec"] > 0
    assert stats["flags"] == 0
    assert "graphs/sec" in capsys.readouterr().out


# ---------------------------------------------------------------------------
# (d) ABFTGuard config isolation + rolling window
# ---------------------------------------------------------------------------

def test_guard_config_not_shared():
    g1, g2 = ABFTGuard(), ABFTGuard()
    assert g1.cfg is not g2.cfg
    g1.cfg.max_retries = 99
    assert g2.cfg.max_retries == 2


def _flagged_once_step():
    """A step that flags on its first attempt and passes the retry — the
    rolling window records it as a flagged step without entering the
    restore path (whose replay is now re-verified)."""
    calls = {"n": 0}

    def step():
        calls["n"] += 1
        return "ok", {"abft_flag": calls["n"] == 1, "abft_max_rel": 0.0}
    return step


def _clean_step():
    return "ok", {"abft_flag": False, "abft_max_rel": 0.0}


def test_guard_rolling_window_evicts_on_recent_flags():
    cfg = GuardConfig(max_retries=1, evict_rate=0.05, window=20,
                      min_samples=20)
    g = ABFTGuard(cfg)

    for _ in range(200):                       # long clean history
        g.run_step(_clean_step)
    assert not g.should_evict()
    for _ in range(20):                        # chip goes bad NOW
        g.run_step(_flagged_once_step())
    assert g.flag_rate == 1.0                  # window sees only the bad run
    assert g.should_evict()
    assert g.lifetime_flag_rate < 0.1          # lifetime average still tiny
    for _ in range(20):                        # recovers: window drains
        g.run_step(_clean_step)
    assert g.flag_rate == 0.0
    assert not g.should_evict()


def test_guard_window_not_judged_before_min_samples():
    cfg = GuardConfig(max_retries=1, evict_rate=0.0, window=50,
                      min_samples=10)
    g = ABFTGuard(cfg)
    for _ in range(5):
        g.run_step(_flagged_once_step())
    assert not g.should_evict()                # 5 < min_samples
    for _ in range(5):
        g.run_step(_flagged_once_step())
    assert g.should_evict()


# ---------------------------------------------------------------------------
# (e) Table I smoke campaign through the JAX engine (slow-marked: gated out
#     of the default CI matrix, runs in the full job)
# ---------------------------------------------------------------------------

@pytest.mark.slow
def test_table1_jax_engine_agrees_with_numpy():
    import sys
    from pathlib import Path
    sys.path.insert(0, str(Path(__file__).resolve().parent.parent))
    from benchmarks.table1_fault_detection import run_jax_engine

    stats = run_jax_engine([], n_campaigns=50)
    assert stats["agree"] + stats["grey"] == stats["n"]
    assert stats["agree"] >= stats["n"] // 2   # grey zone stays a minority
