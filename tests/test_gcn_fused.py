"""Single-pass fused GCN layer kernel (ISSUE 4 tentpole).

Acceptance properties:
  (a) interpret-mode parity: the fused-layer engine path (combination +
      aggregation + checksum in one kernel sweep) matches the two-pass
      block-ELL path AND the dense backend within atol 1e-4 for every ABFT
      mode, single graphs and block-diagonal packed batches alike;
  (b) a bit flip injected into the fused kernel's accumulator mid-sweep is
      flagged by the same eq.-6 check corner — and on the packed path by
      ONLY the corner of the graph whose stripes it landed in;
  (c) the VMEM-budget fallback: layers whose [f, g] working set exceeds
      the budget run the two-pass path (same results), and the budget
      decision itself is monotone in g;
  (d) the HBM traffic model: the fused layer moves strictly fewer modeled
      bytes than two-pass at every paper-scale width (16–186).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.abft import ABFTConfig
from repro.core.checksum import row_checksum
from repro.core.gcn import init_gcn, normalized_adjacency_dense
from repro.engine import Graph, gcn_apply, gcn_forward, make_backend, \
    pack_graphs
from repro.engine.backends import BlockEllBackend
from repro.kernels.gcn_fused import (
    fused_layer_fits,
    fused_vmem_bytes,
    gcn_fused_layer,
    gcn_fused_packed,
    gcn_fused_ref,
    hbm_bytes_fused,
    hbm_bytes_twopass,
)
from repro.kernels.spmm_abft import dense_to_block_ell


def random_graph_dense(seed, n, avg_deg=4):
    rng = np.random.default_rng(seed)
    m = n * avg_deg // 2
    e = rng.integers(0, n, size=(3 * m + 16, 2), dtype=np.int64)
    e = e[e[:, 0] != e[:, 1]]
    e = np.unique(np.sort(e, axis=1), axis=0)[:m]
    return normalized_adjacency_dense(e, n)


# ---------------------------------------------------------------------------
# (a) parity: fused kernel vs f64 reference, vs two-pass engine, vs dense
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("seed,n,f,g", [(0, 96, 24, 7), (1, 160, 16, 16),
                                        (2, 200, 33, 12)])
def test_fused_kernel_matches_reference(seed, n, f, g):
    rng = np.random.default_rng(seed)
    s = random_graph_dense(seed, n)
    bell = dense_to_block_ell(s, block_m=32, block_k=32)
    h = rng.normal(0, 0.5, size=(n, f)).astype(np.float32)
    w = rng.normal(0, 0.3, size=(f, g)).astype(np.float32)

    out, chk = gcn_fused_layer(bell, jnp.asarray(h), jnp.asarray(w),
                               jnp.asarray(w.sum(axis=1)), block_g=32,
                               interpret=True)
    ref_out, ref_pred, ref_act = gcn_fused_ref(bell, h, w)
    np.testing.assert_allclose(np.asarray(out), ref_out, atol=1e-4)
    scale = max(1.0, abs(ref_act))
    assert abs(float(chk.predicted) - ref_pred) / scale < 1e-5
    assert abs(float(chk.actual) - ref_act) / scale < 1e-5
    assert abs(float(chk.predicted) - float(chk.actual)) / scale < 1e-5


@pytest.mark.parametrize("mode", ["none", "split", "fused"])
@pytest.mark.parametrize("seed,n", [(0, 96), (7, 160)])
def test_fused_layer_engine_parity(seed, n, mode):
    """gcn_apply(fused_layer=True) == two-pass block_ell == dense, every
    mode.  Split mode exercises the documented fallback (the split check
    needs X materialized), so its parity is with identical execution."""
    rng = np.random.default_rng(seed)
    s_d = random_graph_dense(seed, n)
    bell = dense_to_block_ell(s_d, block_m=32, block_k=32)
    h0 = jnp.asarray(rng.normal(0, 0.5, size=(n, 24)).astype(np.float32))
    params = init_gcn(jax.random.PRNGKey(seed), (24, 16, 5))
    cfg = ABFTConfig(mode=mode, threshold=1e-3, relative=True)

    logits_d, rep_d = gcn_apply(params, Graph(s=jnp.asarray(s_d), h0=h0),
                                cfg, backend="dense")
    logits_2, rep_2 = gcn_apply(params, Graph(s=bell, h0=h0), cfg,
                                backend="block_ell", block_g=32)
    logits_f, rep_f = gcn_apply(params, Graph(s=bell, h0=h0), cfg,
                                backend="block_ell", block_g=32,
                                fused_layer=True)
    np.testing.assert_allclose(np.asarray(logits_f), np.asarray(logits_2),
                               atol=1e-4, rtol=1e-4)
    np.testing.assert_allclose(np.asarray(logits_f), np.asarray(logits_d),
                               atol=1e-4, rtol=1e-4)
    assert bool(rep_f.flag) is False
    assert int(rep_f.n_checks) == int(rep_2.n_checks) == int(rep_d.n_checks)
    if cfg.enabled:
        assert float(rep_f.max_rel) < cfg.threshold / 4


def test_fused_layer_split_mode_materializes_x():
    """Split mode must run two-pass even with fused_layer=True: the
    backend's whole-layer hook is never consulted (fused_hits stays 0)."""
    s_d = random_graph_dense(3, 96)
    bell = dense_to_block_ell(s_d, block_m=32, block_k=32)
    h0 = jnp.asarray(np.random.default_rng(3).normal(
        0, 0.5, size=(96, 16)).astype(np.float32))
    params = init_gcn(jax.random.PRNGKey(3), (16, 8, 4))
    cfg = ABFTConfig(mode="split", threshold=1e-3, relative=True)
    bk = make_backend(bell, cfg, backend="block_ell", block_g=32,
                      fused_layer=True)
    _, checks = gcn_forward(params, Graph(s=bell, h0=h0), cfg, backend=bk)
    assert bk.fused_hits == 0 and bk.fused_fallbacks == 0
    assert len(checks) == 4                   # 2 layers x (split + corner)

    cfg_f = ABFTConfig(mode="fused", threshold=1e-3, relative=True)
    bk_f = make_backend(bell, cfg_f, backend="block_ell", block_g=32,
                        fused_layer=True)
    _, checks_f = gcn_forward(params, Graph(s=bell, h0=h0), cfg_f,
                              backend=bk_f)
    assert bk_f.fused_hits == 2 and len(checks_f) == 2


# ---------------------------------------------------------------------------
# (b) fault injection inside the fused sweep
# ---------------------------------------------------------------------------

def test_fused_accumulator_fault_flags():
    """A delta injected into the fused kernel's accumulator mid-sweep
    reaches the output and the actual checksum but never the predicted
    side — the eq.-6 corner must flag it, and the output perturbation must
    land exactly in the injected stripe."""
    tau = 1e-4
    rng = np.random.default_rng(5)
    n = 160
    bell = dense_to_block_ell(random_graph_dense(5, n), block_m=32,
                              block_k=32)
    h = jnp.asarray(rng.normal(0, 0.5, size=(n, 16)).astype(np.float32))
    w = jnp.asarray(rng.normal(0, 0.3, size=(16, 8)).astype(np.float32))
    w_r = jnp.asarray(np.asarray(w).sum(axis=1))

    out, chk = gcn_fused_layer(bell, h, w, w_r, block_g=32, interpret=True)
    clean = abs(float(chk.predicted) - float(chk.actual))
    assert clean < tau / 4

    delta = 0.25
    out_bad, chk_bad = gcn_fused_layer(bell, h, w, w_r, block_g=32,
                                       interpret=True, inject=(1, 0, delta))
    div = abs(float(chk_bad.predicted) - float(chk_bad.actual))
    assert div > tau and abs(div - delta) < 1e-4
    diff = np.abs(np.asarray(out_bad) - np.asarray(out))
    assert diff[32, 0] > delta / 2            # stripe 1, element (0, 0)
    diff[32, 0] = 0.0
    assert float(diff.max(initial=0.0)) < 1e-6


def test_fused_packed_fault_isolated_to_one_graph():
    """Packed batch: parity with the two-pass packed path, and an injected
    accumulator fault flags ONLY the graph owning the hit stripe."""
    tau = 1e-4
    rng = np.random.default_rng(9)
    sizes = (40, 56, 24)
    graphs = []
    for i, n in enumerate(sizes):
        s = random_graph_dense(20 + i, n)
        h = rng.normal(0, 0.5, size=(n, 12)).astype(np.float32)
        graphs.append((s, h))
    pb = pack_graphs(graphs, block=16)
    w = rng.normal(0, 0.3, size=(12, 6)).astype(np.float32)
    w_r = w.sum(axis=1)
    cfg = ABFTConfig(mode="fused", threshold=tau, relative=False)

    bk = make_backend(pb, cfg, backend="block_ell", block_g=16,
                      fused_layer=True, interpret=True)
    h0 = jnp.asarray(pb.h0)
    x = h0 @ jnp.asarray(w)
    x_r = h0 @ jnp.asarray(w_r)
    out_2, chk_2 = bk.aggregate(x, x_r)
    out_f, chk_f = gcn_fused_packed(bk.cols, bk.vals, h0, jnp.asarray(w),
                                    jnp.asarray(w_r), bk.segments,
                                    num_segments=pb.n_slots, block_g=16,
                                    interpret=True)
    np.testing.assert_allclose(np.asarray(out_f), np.asarray(out_2),
                               atol=1e-4)
    np.testing.assert_allclose(np.asarray(chk_f.predicted),
                               np.asarray(chk_2.predicted), atol=1e-4)
    assert chk_f.predicted.shape == (pb.n_slots,)
    clean = np.abs(np.asarray(chk_f.predicted) - np.asarray(chk_f.actual))
    assert float(clean.max()) < tau / 4

    # hit a stripe owned by graph 1
    stripe = int(np.argwhere(pb.stripe_graph == 1)[0, 0])
    _, chk_bad = gcn_fused_packed(bk.cols, bk.vals, h0, jnp.asarray(w),
                                  jnp.asarray(w_r), bk.segments,
                                  num_segments=pb.n_slots, block_g=16,
                                  interpret=True, inject=(stripe, 0, 0.5))
    div = np.abs(np.asarray(chk_bad.predicted) - np.asarray(chk_bad.actual))
    assert div[1] > tau
    assert float(np.delete(div, 1).max()) < tau / 4


def test_fused_packed_serving_matches_twopass():
    """End-to-end guarded serving: --fused-layer and the default two-pass
    packed path agree on logits shape, per-graph verdicts, and throughput
    accounting on the same stream."""
    from repro.engine import make_packed_batches, synth_graph_stream
    from repro.launch.serve_gcn import serve

    stream = synth_graph_stream(10, n_lo=16, n_hi=56, feat=8, seed=6)
    params = init_gcn(jax.random.PRNGKey(6), (8, 8, 3))
    cfg = ABFTConfig(mode="fused", threshold=1e-3, relative=True)
    batches = make_packed_batches(stream, 4, block=16, stripe_multiple=4,
                                  width_multiple=4)
    two = serve(batches, params, cfg, verbose=False)
    fused = serve(batches, params, cfg, verbose=False, fused_layer=True)
    assert two["graphs"] == fused["graphs"] == 10
    assert fused["flags"] == 0
    np.testing.assert_array_equal(two["graph_flags"], fused["graph_flags"])
    np.testing.assert_allclose(two["graph_max_rel"], fused["graph_max_rel"],
                               atol=1e-5)


# ---------------------------------------------------------------------------
# (c) VMEM-budget fallback
# ---------------------------------------------------------------------------

def test_vmem_budget_fallback_runs_twopass():
    rng = np.random.default_rng(4)
    n = 96
    bell = dense_to_block_ell(random_graph_dense(4, n), block_m=32,
                              block_k=32)
    h0 = jnp.asarray(rng.normal(0, 0.5, size=(n, 16)).astype(np.float32))
    params = init_gcn(jax.random.PRNGKey(4), (16, 8, 4))
    cfg = ABFTConfig(mode="fused", threshold=1e-3, relative=True)

    bk_small = make_backend(bell, cfg, backend="block_ell", block_g=32,
                            fused_layer=True, vmem_budget=1024)
    logits_fb, _ = gcn_forward(params, Graph(s=bell, h0=h0), cfg,
                               backend=bk_small)
    assert bk_small.fused_hits == 0 and bk_small.fused_fallbacks == 2

    bk_big = make_backend(bell, cfg, backend="block_ell", block_g=32,
                          fused_layer=True)
    logits_f, _ = gcn_forward(params, Graph(s=bell, h0=h0), cfg,
                              backend=bk_big)
    assert bk_big.fused_hits == 2 and bk_big.fused_fallbacks == 0
    np.testing.assert_allclose(np.asarray(logits_fb), np.asarray(logits_f),
                               atol=1e-4)


def test_vmem_model_monotone_and_paper_widths_fit():
    bm = bk = 128
    for width in (16, 32, 64, 128, 186):
        assert fused_layer_fits(width, width, bm, bk)
    # a transformer-scale output width cannot keep W resident
    assert not fused_layer_fits(128, 100_000, bm, bk)
    assert fused_vmem_bytes(16, 16, bm, bk) \
        <= fused_vmem_bytes(16, 186, bm, bk) \
        <= fused_vmem_bytes(186, 186, bm, bk)


# ---------------------------------------------------------------------------
# (d) HBM traffic model
# ---------------------------------------------------------------------------

def test_fused_moves_fewer_modeled_bytes_at_paper_widths():
    bell = dense_to_block_ell(random_graph_dense(8, 512), block_m=128,
                              block_k=128)
    for width in (16, 32, 64, 128, 186):
        two = hbm_bytes_twopass(bell, width, width)
        fused = hbm_bytes_fused(bell, width, width)
        assert fused < two, (width, fused, two)
    # asymmetric widths: skinny-in/wide-out fuses even better (X is the
    # wide tensor that never round-trips)
    assert hbm_bytes_fused(bell, 16, 186) < hbm_bytes_twopass(bell, 16, 186)
