"""Block-diagonal packed block-ELL serving (ISSUE 3 tentpole) + the
guard/batching correctness fixes that ride along.

Acceptance properties:
  (a) the packer builds exactly diag(S_1, …, S_G): per-graph diagonal
      blocks reproduce each S, everything off the diagonal is zero, and H0
      rows land at each graph's padded offset;
  (b) packed engine parity: per-graph logit rows match the single-graph
      dense engine (atol 1e-4) and clean streams never flag;
  (c) per-graph check isolation: a bit flip in one packed graph's
      combination output diverges ONLY that graph's check corner;
  (d) ABFTGuard restore path: restore is followed by a replayed, re-verified
      step (bounded by max_restores; raises rather than adopting flagged
      state), and run_step_graphs retries only the flagged graphs;
  (e) batching keeps input dtypes (f64 streams stay f64, bf16 stays bf16)
      and mixed feature dims fail fast with the offending graph named;
  (f) the w_r fold (engine.fold_w_r) is bitwise-parity with the per-step
      row_checksum recompute;
  (g) serve_gcn --backend block_ell serves a mixed-size stream with
      per-graph verdicts matching the dense backend graph-for-graph.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.abft import ABFTConfig, per_graph_report
from repro.core.fault import flip_bit_f32
from repro.core.gcn import init_gcn
from repro.engine import (
    Graph,
    fold_w_r,
    gcn_apply,
    gcn_forward,
    make_backend,
    make_batches,
    make_packed_batches,
    pack_graphs,
    pad_graph,
    synth_graph_stream,
)
from repro.runtime import ABFTGuard, GuardConfig


def _stream(n_graphs=3, seed=1, feat=8, n_lo=20, n_hi=70):
    return synth_graph_stream(n_graphs, n_lo=n_lo, n_hi=n_hi, feat=feat,
                              seed=seed)


# ---------------------------------------------------------------------------
# (a) the packer builds the block-diagonal system
# ---------------------------------------------------------------------------

def test_pack_graphs_is_block_diagonal():
    stream = _stream(3)
    pb = pack_graphs(stream, block=16, stripe_multiple=4, width_multiple=2)
    dense = pb.bell.todense()
    assert pb.bell.n_block_rows % 4 == 0          # stripe residue padded
    assert pb.bell.width % 2 == 0
    off_diag = dense.copy()
    for g, (s, h0) in enumerate(stream):
        o, n = pb.row_offsets[g], pb.n_nodes[g]
        assert o % 16 == 0 and n == s.shape[0]
        np.testing.assert_allclose(dense[o:o + n, o:o + n], s, atol=1e-6)
        np.testing.assert_allclose(pb.h0[o:o + n], h0, atol=0)
        off_diag[o:o + n, o:o + n] = 0.0
    assert np.abs(off_diag).max() == 0.0          # nothing off the diagonal
    # stripe segments: contiguous per graph, padding in overflow segment
    per_graph_stripes = [int((pb.stripe_graph == g).sum())
                        for g in range(pb.n_slots)]
    assert sum(per_graph_stripes) + int(
        (pb.stripe_graph == pb.n_slots).sum()) == pb.bell.n_block_rows
    for g, (s, _) in enumerate(stream):
        assert per_graph_stripes[g] == -(-s.shape[0] // 16)


def test_pack_graphs_empty_slots_pad_to_n_slots():
    stream = _stream(2)
    pb = pack_graphs(stream, block=16, n_slots=4)
    assert pb.n_slots == 4 and pb.n_graphs == 2
    assert (pb.n_nodes[2:] == 0).all()
    # empty slots own no stripes, so their check corner is 0 = 0
    assert not np.isin([2, 3], pb.stripe_graph).any()


# ---------------------------------------------------------------------------
# (b) packed engine parity vs the per-graph dense engine
# ---------------------------------------------------------------------------

def test_packed_parity_vs_dense_per_graph():
    stream = _stream(4, seed=3)
    pb = pack_graphs(stream, block=16, stripe_multiple=4)
    params = init_gcn(jax.random.PRNGKey(0), (8, 8, 3))
    cfg = ABFTConfig(mode="fused", threshold=1e-3, relative=True)

    logits, checks = gcn_forward(params, Graph(s=pb, h0=jnp.asarray(pb.h0)),
                                 cfg)
    assert all(c.predicted.shape == (pb.n_slots,) for c in checks)
    flags, rels = per_graph_report(checks, cfg, pb.n_slots)
    assert not bool(np.asarray(flags).any())
    for g, (s, h0) in enumerate(stream):
        ref, rep = gcn_apply(params, Graph(s=jnp.asarray(s),
                                           h0=jnp.asarray(h0)), cfg)
        assert not bool(rep.flag)
        o, n = pb.row_offsets[g], pb.n_nodes[g]
        np.testing.assert_allclose(np.asarray(logits[o:o + n]),
                                   np.asarray(ref), atol=1e-4, rtol=1e-4,
                                   err_msg=f"graph {g}")
        # padded rows between graphs are exactly zero
        pad_rows = np.asarray(logits[o + n:o + (-(-n // 16)) * 16])
        assert np.abs(pad_rows).max(initial=0.0) == 0.0


def test_packed_split_mode_emits_per_graph_checks():
    """Split mode (eq. 2–3) on the packed path: BOTH checks segment per
    graph — the combination check must not collapse to one scalar that
    would smear a single graph's fault over the whole batch."""
    stream = _stream(3, seed=7)
    pb = pack_graphs(stream, block=16)
    params = init_gcn(jax.random.PRNGKey(7), (8, 8, 3))
    cfg = ABFTConfig(mode="split", threshold=1e-3, relative=True)

    logits, checks = gcn_forward(params, Graph(s=pb, h0=jnp.asarray(pb.h0)),
                                 cfg)
    assert len(checks) == 4                       # 2 layers x 2 checks
    assert all(c.predicted.shape == (pb.n_slots,) for c in checks)
    flags, _ = per_graph_report(checks, cfg, pb.n_slots)
    assert not bool(np.asarray(flags).any())
    for g, (s, h0) in enumerate(stream):
        ref, rep = gcn_apply(params, Graph(s=jnp.asarray(s),
                                           h0=jnp.asarray(h0)), cfg)
        assert not bool(rep.flag)
        o, n = pb.row_offsets[g], pb.n_nodes[g]
        np.testing.assert_allclose(np.asarray(logits[o:o + n]),
                                   np.asarray(ref), atol=1e-4, rtol=1e-4)


def test_per_graph_report_rejects_unattributable_checks():
    from repro.core.abft import Check

    cfg = ABFTConfig(mode="fused", threshold=1e-3)
    scalar = Check(predicted=jnp.float32(1.0), actual=jnp.float32(1.0))
    with pytest.raises(ValueError, match="batched checks"):
        per_graph_report([scalar], cfg, 4)


# ---------------------------------------------------------------------------
# (c) a fault in one packed graph flags only that graph's corner
# ---------------------------------------------------------------------------

def test_packed_fault_flags_only_that_graph():
    tau = 1e-4
    stream = _stream(3, seed=5, feat=16, n_lo=30, n_hi=80)
    pb = pack_graphs(stream, block=16)
    w = init_gcn(jax.random.PRNGKey(5), (16, 12, 4))["layers"][0]["w"]
    cfg = ABFTConfig(mode="fused", threshold=tau, relative=False)
    bk = make_backend(pb, cfg)

    h = jnp.asarray(pb.h0)
    x = h @ w
    x_r = h @ w.sum(axis=1)                       # independent eq.-5 path
    _, chk = bk.aggregate(x, x_r)
    diffs = np.abs(np.asarray(chk.predicted) - np.asarray(chk.actual))
    assert chk.predicted.shape == (3,)
    assert (diffs < tau / 4).all()

    victim = 1
    o, n = pb.row_offsets[victim], pb.n_nodes[victim]
    x_np = np.asarray(x).copy()
    band = x_np[o:o + n]
    i, j = np.argwhere(np.abs(band) >= 1e-2)[5]
    x_np[o + i, j] = flip_bit_f32(np.float32(x_np[o + i, j]), 27)
    _, chk_bad = bk.aggregate(jnp.asarray(x_np), x_r)
    diffs = np.abs(np.asarray(chk_bad.predicted) - np.asarray(chk_bad.actual))
    assert diffs[victim] > tau                    # the victim flags ...
    others = np.delete(diffs, victim)
    assert (others < tau / 4).all()               # ... and only the victim


# ---------------------------------------------------------------------------
# (d) guard: restore->replay->verify + per-graph retry
# ---------------------------------------------------------------------------

def _metrics(flag, gflags=None):
    m = {"abft_flag": flag, "abft_max_rel": 1.0 if flag else 0.0}
    if gflags is not None:
        m["abft_graph_flags"] = np.asarray(gflags, bool)
    return m


def test_guard_restore_then_verify():
    fault = {"on": True}

    def step(state):
        return state + 1, _metrics(fault["on"])

    def restore():
        fault["on"] = False                       # checkpoint reload heals

    g = ABFTGuard(GuardConfig(max_retries=1), restore_fn=restore)
    out, m = g.run_step(step, 10)
    # the adopted output comes from the verified replay, with clean metrics
    assert out == 11
    assert bool(m["abft_flag"]) is False
    assert g.restores == 1 and g.flags == 1


def test_guard_restore_bounded_and_raises_unverified():
    def always_bad(state):
        return state, _metrics(True)

    g = ABFTGuard(GuardConfig(max_retries=0, max_restores=2),
                  restore_fn=lambda: None)
    with pytest.raises(RuntimeError, match="still flagged after 2"):
        g.run_step(always_bad, 0)
    assert g.restores == 2

    g2 = ABFTGuard(GuardConfig(max_retries=0))    # no restore_fn at all
    with pytest.raises(RuntimeError, match="no restore_fn"):
        g2.run_step(always_bad, 0)


def test_guard_per_graph_retry_retries_only_flagged():
    retried = []

    def step():
        m = _metrics(True, [False, True, False, True])
        m["abft_graph_max_rel"] = np.asarray([0.0, 0.3, 0.0, 0.2],
                                             np.float32)
        m["abft_max_rel"] = 0.3
        return np.zeros(4), m

    def retry(out, idx):
        retried.append(list(idx))
        out = out.copy()
        out[idx] = 7.0
        return out, _metrics(False, np.zeros(len(idx), bool)) | {
            "abft_graph_max_rel": np.full(len(idx), 1e-7, np.float32)}

    g = ABFTGuard(GuardConfig(max_retries=2))
    out, m = g.run_step_graphs(step, retry)
    assert retried == [[1, 3]]                    # only the flagged graphs
    np.testing.assert_array_equal(out, [0.0, 7.0, 0.0, 7.0])
    assert bool(m["abft_flag"]) is False
    assert not m["abft_graph_flags"].any()
    # metrics reflect the ADOPTED executions, not the failed attempt
    assert float(m["abft_max_rel"]) < 1e-3
    assert float(np.asarray(m["abft_graph_max_rel"]).max()) < 1e-3
    assert g.graph_retries == 2 and g.retries == 1 and g.flags == 1


def test_guard_per_graph_retry_narrows_then_restores():
    fault = {"on": True}

    def step():
        flag = fault["on"]
        return np.zeros(3), _metrics(flag, [flag, flag, False])

    def retry(out, idx):
        # graph 0 heals on retry; graph 1 is persistent
        return out, _metrics(True, [i == 1 for i in idx])

    def restore():
        fault["on"] = False

    g = ABFTGuard(GuardConfig(max_retries=2), restore_fn=restore)
    out, m = g.run_step_graphs(step, retry)
    # retries narrowed to graph 1, still flagged -> restore + full replay
    assert g.restores == 1
    assert bool(np.asarray(m["abft_flag"]).any()) is False


def test_guard_restore_returning_state_is_adopted_for_replay():
    # the train.py convention: restore_fn returns the checkpointed state,
    # and the replay must run FROM it, not from the in-memory state
    seen = []

    def step(state):
        seen.append(state)
        return state * 2, _metrics(state != 100)

    g = ABFTGuard(GuardConfig(max_retries=0), restore_fn=lambda: 100)
    out, m = g.run_step(step, 3)
    assert seen == [3, 100]                       # replay got restored state
    assert out == 200 and bool(m["abft_flag"]) is False
    assert g.restores == 1


def test_guard_graphs_restore_never_splices_state_into_data_args():
    # serving steps take DATA operands; a state-returning restore_fn must
    # not replace the batch adjacency on the run_step_graphs restore path
    fault = {"on": True}
    seen = []

    def step(data):
        seen.append(data)
        return np.zeros(2), _metrics(fault["on"], [fault["on"], False])

    def restore():
        fault["on"] = False
        return {"params": "ckpt"}                 # state-returning restore

    def retry(out, idx):
        return out, _metrics(True, [True] * len(idx))

    g = ABFTGuard(GuardConfig(max_retries=1), restore_fn=restore)
    out, m = g.run_step_graphs(step, retry, "batch-0")
    assert seen == ["batch-0", "batch-0"]         # replay kept the data arg
    assert bool(np.asarray(m["abft_flag"]).any()) is False


def test_guard_graphs_drops_unreconstructable_max_rel():
    # step emits abft_max_rel but no per-graph max_rel: after a clean
    # retry the stale flagged value must not ride under a clean flag
    def step():
        return np.zeros(2), _metrics(True, [True, False])  # max_rel = 1.0

    def retry(out, idx):
        return out, _metrics(False, [False] * len(idx))

    g = ABFTGuard(GuardConfig(max_retries=1))
    out, m = g.run_step_graphs(step, retry)
    assert bool(m["abft_flag"]) is False
    assert "abft_max_rel" not in m


def test_pack_graphs_records_quantization_for_retries():
    pb = pack_graphs(_stream(2), block=16, stripe_multiple=4,
                     width_multiple=2)
    assert pb.stripe_multiple == 4 and pb.width_multiple == 2


# ---------------------------------------------------------------------------
# (e) batching dtype preservation + mixed-feat validation
# ---------------------------------------------------------------------------

def test_pad_graph_preserves_dtype():
    s = np.eye(5, dtype=np.float64)
    h = np.ones((5, 3), np.float16)
    sp, hp = pad_graph(s, h, 8)
    assert sp.dtype == np.float64 and hp.dtype == np.float16
    assert sp.shape == (8, 8) and hp.shape == (8, 3)


def test_make_batches_preserves_and_promotes_dtype():
    rng = np.random.default_rng(0)

    def graph(n, s_dt, h_dt):
        return (np.eye(n, dtype=s_dt),
                rng.normal(size=(n, 4)).astype(h_dt))

    # uniform f64 stays f64 (reference streams)
    batches = make_batches([graph(10, np.float64, np.float64)], 2, [16])
    assert batches[0].s.dtype == np.float64
    assert batches[0].h0.dtype == np.float64
    # bf16 features survive batching
    bf16 = jnp.bfloat16.dtype
    batches = make_batches([graph(10, np.float32, bf16)], 2, [16])
    assert batches[0].h0.dtype == bf16
    # mixed f32/f64 in one bucket promotes (no silent downcast)
    batches = make_batches([graph(10, np.float32, np.float32),
                            graph(12, np.float64, np.float64)], 2, [16])
    assert batches[0].s.dtype == np.float64
    assert batches[0].h0.dtype == np.float64


def test_mixed_feature_dims_raise_up_front():
    rng = np.random.default_rng(0)
    good = (np.eye(10, dtype=np.float32),
            rng.normal(size=(10, 4)).astype(np.float32))
    bad = (np.eye(12, dtype=np.float32),
           rng.normal(size=(12, 6)).astype(np.float32))
    with pytest.raises(ValueError, match="graph 1 has feature dim 6"):
        make_batches([good, bad], 2, [16])
    with pytest.raises(ValueError, match="graph 1 has feature dim 6"):
        pack_graphs([good, bad], block=16)


# ---------------------------------------------------------------------------
# (f) the offline w_r fold is parity with the per-step recompute
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("mode", ["split", "fused"])
def test_fold_w_r_parity(mode):
    stream = _stream(1, seed=9)
    s, h0 = stream[0]
    params = init_gcn(jax.random.PRNGKey(9), (8, 16, 4))
    cfg = ABFTConfig(mode=mode, threshold=1e-3, relative=True)
    folded = fold_w_r(params, cfg)
    assert all("w_r" in layer for layer in folded["layers"])
    assert folded["layers"][0]["w_r"].shape == (8,)

    g = Graph(s=jnp.asarray(s), h0=jnp.asarray(h0))
    logits_a, rep_a = gcn_apply(params, g, cfg)
    logits_b, rep_b = gcn_apply(folded, g, cfg)
    # identical algebra, identical dtype -> bitwise-equal logits and report
    np.testing.assert_array_equal(np.asarray(logits_a), np.asarray(logits_b))
    assert float(rep_a.max_rel) == float(rep_b.max_rel)
    assert int(rep_a.n_checks) == int(rep_b.n_checks)


def test_fold_w_r_disabled_mode_is_noop():
    params = init_gcn(jax.random.PRNGKey(0), (4, 4, 2))
    assert fold_w_r(params, ABFTConfig(mode="none")) is params


# ---------------------------------------------------------------------------
# (g) packed serving driver: per-graph verdicts match dense graph-for-graph
# ---------------------------------------------------------------------------

def test_serve_block_ell_matches_dense_graph_for_graph():
    from repro.launch.serve_gcn import serve

    stream = _stream(10, seed=4, feat=12, n_lo=16, n_hi=60)
    params = init_gcn(jax.random.PRNGKey(4), (12, 8, 3))
    cfg = ABFTConfig(mode="fused", threshold=1e-3, relative=True)

    dense = serve(make_batches(stream, 4, [32, 64]), params, cfg,
                  verbose=False)
    packed = serve(make_packed_batches(stream, 4, block=16,
                                       stripe_multiple=4, width_multiple=2),
                   params, cfg, verbose=False)
    assert dense["graphs"] == packed["graphs"] == 10
    np.testing.assert_array_equal(dense["graph_flags"],
                                  packed["graph_flags"])
    assert not packed["graph_flags"].any()
    assert packed["graphs_per_sec"] > 0


def test_serve_gcn_driver_block_ell_smoke(capsys):
    from repro.launch.serve_gcn import main

    stats = main(["--graphs", "8", "--batch", "4", "--backend", "block_ell",
                  "--block", "16", "--nodes", "16,56", "--feat", "8",
                  "--hidden", "8", "--classes", "3"])
    assert stats["graphs"] == 8
    assert stats["flags"] == 0 and not stats["graph_flags"].any()
    assert "packed block_ell" in capsys.readouterr().out


# ---------------------------------------------------------------------------
# (h) size-aware pack scheduling (ISSUE 4 satellite): FFD by stripe count
# ---------------------------------------------------------------------------

def test_schedule_packs_equalizes_stripe_loads():
    from repro.engine import schedule_packs

    # adversarial arrival order: big graphs clustered at the front, so
    # arrival chunking makes one huge batch and one tiny one
    stripes = [8, 8, 7, 7, 1, 1, 1, 1]
    groups = schedule_packs(stripes, batch_size=4, stripe_multiple=1)
    assert sorted(gi for g in groups for gi in g) == list(range(8))
    assert all(len(g) <= 4 for g in groups)
    loads = sorted(sum(stripes[i] for i in g) for g in groups)
    arrival_loads = sorted((sum(stripes[:4]), sum(stripes[4:])))
    assert loads == [16, 18]                  # FFD splits 34 near-evenly
    assert arrival_loads == [4, 30]           # arrival order does not
    # determinism
    assert groups == schedule_packs(stripes, 4, 1)


def test_schedule_packs_respects_stripe_multiple_quantum():
    from repro.engine import schedule_packs

    stripes = [5, 4, 3, 3, 2, 1]
    groups = schedule_packs(stripes, batch_size=3, stripe_multiple=4)
    loads = [sum(stripes[i] for i in g) for g in groups]
    # capacity is the mean (9) rounded up to the quantum (12); both bins
    # land within one quantum of each other
    assert max(loads) <= 12
    assert sorted(gi for g in groups for gi in g) == list(range(6))


def test_make_packed_batches_size_schedule_cuts_padding():
    stream = _stream(8, seed=11, n_lo=16, n_hi=120)
    by_size = make_packed_batches(stream, 4, block=16, stripe_multiple=4)
    arrival = make_packed_batches(stream, 4, block=16, stripe_multiple=4,
                                  schedule="arrival")
    with pytest.raises(ValueError):
        make_packed_batches(stream, 4, block=16, schedule="nope")

    # every graph served exactly once, stream positions preserved
    idx = sorted(int(i) for b in by_size for i in b.indices if i >= 0)
    assert idx == list(range(8))
    # FFD never allocates more total padded stripes than arrival chunking
    total = sum(b.bell.n_block_rows for b in by_size)
    assert total <= sum(b.bell.n_block_rows for b in arrival)
    # and the batch stripe counts are more even (max batch no larger)
    assert max(b.bell.n_block_rows for b in by_size) \
        <= max(b.bell.n_block_rows for b in arrival)


def test_serve_size_scheduled_verdicts_stay_stream_ordered():
    """Size-aware reordering must not scramble per-graph verdicts: serving a
    size-scheduled packed stream matches the dense backend graph-for-graph
    in STREAM order, exactly like arrival-order packing."""
    from repro.launch.serve_gcn import serve

    stream = _stream(10, seed=12, feat=12, n_lo=16, n_hi=90)
    params = init_gcn(jax.random.PRNGKey(12), (12, 8, 3))
    cfg = ABFTConfig(mode="fused", threshold=1e-3, relative=True)
    dense = serve(make_batches(stream, 4, [32, 64, 128]), params, cfg,
                  verbose=False)
    packed = serve(make_packed_batches(stream, 4, block=16,
                                       stripe_multiple=4, width_multiple=2),
                   params, cfg, verbose=False)
    assert dense["graphs"] == packed["graphs"] == 10
    np.testing.assert_array_equal(dense["graph_flags"],
                                  packed["graph_flags"])
    np.testing.assert_allclose(dense["graph_max_rel"],
                               packed["graph_max_rel"], atol=1e-5)
