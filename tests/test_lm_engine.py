"""Guarded LM serving tests (ISSUE 10 tentpole).

The acceptance properties of the checked-op LM engine:

  (a) ``fold_lm_w_r`` folds every stacked segment dense to a per-layer
      ``w_r`` (the params stay layer-stacked regardless of
      ``cfg.scan_layers``) and the head flat;
  (b) guarded logits are bit-identical to the unguarded ``mode="none"``
      forward on clean runs — checks are side computations;
  (c) a transient attention-accumulator fault (the ``attn_inject``
      operand) is detected and repaired by the guard's retry tier, with
      bit-identical final outputs;
  (d) post-load weight corruption (the ``qkv_w``/``mlp_w`` fault sites)
      is detected — the fold predates the corruption — and repaired by
      restore-and-refold from the pristine master;
  (e) the fault-campaign LM lane gates hold on a representative model:
      100% detection, zero clean false positives.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config, smoke_config
from repro.core.abft import ABFTConfig
from repro.engine.lm import LMEngine, fold_lm_w_r
from repro.faults.campaign import run_lm_fault_campaign
from repro.faults.injectors import FaultInjector
from repro.faults.model import FaultModel, lm_sweep_models
from repro.models.transformer import init_model, model_prefill

PROMPT, CACHE = 8, 16


@pytest.fixture(scope="module")
def setup():
    cfg = smoke_config(get_config("gemma-2b"))
    abft = ABFTConfig(mode="fused", dtype=jnp.float32, threshold=1e-3,
                      relative=True)
    eng = LMEngine.init(cfg, abft, jax.random.PRNGKey(0), cache_len=CACHE)
    rng = np.random.default_rng(0)
    tokens = jnp.asarray(rng.integers(1, cfg.vocab_size, size=(1, PROMPT)),
                         jnp.int32)
    off = ABFTConfig(mode="none")
    ref_logits, ref_states, _ = jax.jit(
        lambda p, b: model_prefill(p, cfg, b, off, CACHE)
    )(eng._master, {"tokens": tokens})
    return cfg, abft, eng, tokens, np.asarray(ref_logits)


# ---------------------------------------------------------------------------
# (a) the offline fold
# ---------------------------------------------------------------------------

def test_fold_folds_stacked_segments_per_layer(setup):
    cfg, abft, eng, _tokens, _ref = setup
    folded = fold_lm_w_r(eng._master, cfg, abft)

    def assert_folds(node):
        found = 0
        if isinstance(node, dict):
            w = node.get("w")
            if w is not None and getattr(w, "ndim", 0) >= 3:
                assert node["w_r"].shape == w.shape[:2]   # [L, d_in]
                found += 1
            for v in node.values():
                found += assert_folds(v)
        elif isinstance(node, (list, tuple)):
            for v in node:
                found += assert_folds(v)
        return found

    assert assert_folds(folded["segments"]) > 0
    # master untouched: the fold returns a new tree
    assert "w_r" not in next(iter(eng._master["segments"][0].values()))


# ---------------------------------------------------------------------------
# (b) clean bit-identity
# ---------------------------------------------------------------------------

def test_clean_guarded_logits_bit_identical(setup):
    _cfg, _abft, eng, tokens, ref = setup
    flags0 = eng.guard.flags
    logits, states, m = eng.prefill(tokens)
    assert eng.guard.flags == flags0
    np.testing.assert_array_equal(np.asarray(logits), ref)
    assert len(m["abft_op_ids"]) == len(np.asarray(m["abft_op_flags"]))
    assert not np.asarray(m["abft_op_flags"]).any()
    # one clean decode step, also unflagged
    nxt = jnp.argmax(logits[:, -1], -1)[:, None].astype(jnp.int32)
    _logits2, _states2, m2 = eng.decode(states, nxt, PROMPT)
    assert eng.guard.flags == flags0
    assert not bool(np.asarray(m2["abft_flag"]))


# ---------------------------------------------------------------------------
# (c) transient accumulator fault: detect + retry
# ---------------------------------------------------------------------------

def test_transient_inject_detected_and_repaired(setup):
    _cfg, _abft, eng, tokens, ref = setup
    flags0, retries0 = eng.guard.flags, eng.guard.retries
    logits, _states, _m = eng.prefill(tokens, inject=30.0)
    assert eng.guard.flags > flags0
    assert eng.guard.retries == retries0 + 1
    np.testing.assert_array_equal(np.asarray(logits), ref)   # repaired


# ---------------------------------------------------------------------------
# (d) weight corruption: detect + restore-and-refold
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("site", ["qkv_w", "mlp_w"])
def test_weight_fault_detected_and_restored(setup, site):
    _cfg, _abft, eng, tokens, ref = setup
    inj = FaultInjector(FaultModel(site=site, kind="bitflip", step=0,
                                   bit=30, seed=3))
    eng.params = inj.apply_lm_params(eng.params)
    flags0, restores0 = eng.guard.flags, eng.guard.restores
    logits, _states, _m = eng.prefill(tokens)
    assert eng.guard.flags > flags0
    assert eng.guard.restores == restores0 + 1    # refolded from master
    np.testing.assert_array_equal(np.asarray(logits), ref)
    # the restore left the engine clean for the next step
    flags1 = eng.guard.flags
    logits2, _s, _m = eng.prefill(tokens)
    assert eng.guard.flags == flags1
    np.testing.assert_array_equal(np.asarray(logits2), ref)


# ---------------------------------------------------------------------------
# (e) the campaign LM lane gate
# ---------------------------------------------------------------------------

def test_lm_campaign_gate_on_representative_models():
    models = [FaultModel(site="attn_accumulator", kind="bitflip", step=1,
                         delta=25.0),
              FaultModel(site="qkv_w", kind="stuck", step=1, bit=30)]
    payload = run_lm_fault_campaign(models, n_decode=2)
    assert payload["clean_control"]["flagged"] == 0
    for agg in payload["by_site_kind"].values():
        assert agg["detection_rate"] == 1.0
        assert agg["sdc_rate"] == 0.0
    assert payload["benchmark"] == "lm_fault_campaign"
    assert {"interpret", "authoritative"} <= payload.keys()


def test_lm_sweep_grid_shape():
    models = lm_sweep_models(reps=1)
    assert {m.site for m in models} == {"qkv_w", "mlp_w",
                                        "attn_accumulator"}
    assert all(m.step == 1 for m in models)
