"""Fault-injection subsystem (ISSUE 9 tentpole): declarative fault
models, bitcast/sticky injectors, the check-path self-check, and the
campaign driver.

Acceptance properties:
  (a) ``flip_bits`` is an involution (re-flip restores bitwise) and the
      injector's sticky kinds re-apply the SAME corruption each step —
      a clean rewrite between steps is undone, which is what makes a
      retry on re-read operands doomed;
  (b) check-path corruption coverage: a bit-flip in the folded ``w_r``
      or the staged ``s_c`` is caught by the periodic self-check
      (bitwise re-derivation), a NaN stuck-at is flagged by the shipped
      NaN-safe comparison while the naive ``d > tau`` verdict stays
      silent — the campaign reports it as a would-be false negative;
  (c) the campaign detects every above-threshold accumulator upset,
      records zero flags on the clean control, measures (not asserts)
      SDC for the architecturally-silent consistent-corruption sites,
      and surfaces the guard's repair-tier distribution including
      persistent-site classification for sticky kinds.
"""
import math

import numpy as np
import pytest

from repro.core.abft import ABFTConfig, Check
from repro.faults import (
    CHECK_PATH_SITES,
    CheckPathSelfCheck,
    FaultInjector,
    FaultModel,
    flip_bits,
    run_fault_campaign,
    sweep_models,
    verify_s_c,
    verify_w_r,
)


# ---------------------------------------------------------------------------
# model + injector mechanics
# ---------------------------------------------------------------------------

def test_fault_model_validates():
    with pytest.raises(ValueError):
        FaultModel(site="nonsense")
    with pytest.raises(ValueError):
        FaultModel(site="weights", kind="nonsense")
    with pytest.raises(ValueError):
        FaultModel(site="weights", timing="nonsense")
    m = FaultModel(site="w_r", kind="stuck", stuck_value=float("nan"))
    assert m.sticky and m.check_path
    assert m.to_dict()["stuck_value"] == "nan"   # JSON round-trippable


def test_flip_bits_is_involution():
    rng = np.random.default_rng(0)
    for dtype in (np.float32, np.float64, np.int32):
        a = (rng.normal(size=8) * 10).astype(dtype)
        b = flip_bits(a, 3, 30)
        assert not np.array_equal(a, b)
        assert np.array_equal(flip_bits(b, 3, 30), a)
        assert b.dtype == a.dtype


def test_transient_fires_once_sticky_latches():
    t = FaultInjector(FaultModel(site="weights", kind="bitflip", step=2))
    assert [t.fires(i) for i in range(5)] == [False, False, True, False,
                                             False]
    s = FaultInjector(FaultModel(site="weights", kind="stuck", step=2,
                                 stuck_value=9.0))
    assert [s.fires(i) for i in range(5)] == [False, False, True, True,
                                             True]


def test_sticky_reapplies_same_corruption():
    inj = FaultInjector(FaultModel(site="weights", kind="stuck",
                                   stuck_value=7.0, seed=3))
    params = {"layers": [{"w": np.zeros((4, 4), np.float32)}]}
    a = inj.apply_params(params)["layers"][0]["w"]
    # the operand was rewritten clean between steps; the stuck cell
    # comes back at the same coordinate with the same value
    b = inj.apply_params(params)["layers"][0]["w"]
    assert np.array_equal(a, b)
    assert (a == 7.0).sum() == 1
    assert not np.shares_memory(a, params["layers"][0]["w"])


def test_bernoulli_timing_is_memoized():
    inj = FaultInjector(FaultModel(site="weights", timing="bernoulli",
                                   p=0.5, seed=1))
    draws = [inj.fires(i) for i in range(16)]
    assert draws == [inj.fires(i) for i in range(16)]  # replay-stable
    assert any(draws)


def test_cols_table_corruption_stays_in_range():
    inj = FaultInjector(FaultModel(site="cols_table", kind="bitflip",
                                   seed=0))
    cols = np.arange(12, dtype=np.int32).reshape(3, 4) % 5
    c2, _, _ = inj.apply_batch(cols, None, None)
    assert c2.max() < 5 and c2.min() >= 0   # valid index, silent corruption
    assert not np.array_equal(c2, cols)


# ---------------------------------------------------------------------------
# NaN-safe comparison + check-path self-check  (satellite: check-path
# corruption coverage)
# ---------------------------------------------------------------------------

def test_check_flag_is_nan_safe():
    import jax.numpy as jnp
    cfg = ABFTConfig(threshold=1e-3)
    chk = Check(predicted=jnp.float32(float("nan")),
                actual=jnp.float32(1.0))
    assert bool(chk.flag(cfg))          # NaN divergence must flag...
    d = abs(float("nan") - 1.0)
    assert not d > cfg.threshold        # ...though the naive verdict is
    #                                     silent: the would-be FN


def _folded_params(seed=0):
    from repro.engine.api import fold_w_r
    rng = np.random.default_rng(seed)
    params = {"layers": [
        {"w": (rng.normal(size=(4, 6)) * 0.3).astype(np.float32),
         "b": np.zeros(6, np.float32)},
        {"w": (rng.normal(size=(6, 3)) * 0.3).astype(np.float32),
         "b": np.zeros(3, np.float32)}]}
    return fold_w_r(params, ABFTConfig())


@pytest.mark.parametrize("corrupt", ["bitflip", "nan"])
def test_selfcheck_catches_w_r_corruption(corrupt):
    cfg = ABFTConfig(threshold=1e-3)
    params = _folded_params()
    assert verify_w_r(params, cfg) == []
    inj = FaultInjector(FaultModel(
        site="w_r", kind="stuck" if corrupt == "nan" else "bitflip",
        stuck_value=float("nan") if corrupt == "nan" else None, layer=1))
    assert inj.fires(0)
    bad = inj.apply_params(params)
    assert verify_w_r(bad, cfg) == [1]
    # repair: refold from source weights -> clean again
    sc = CheckPathSelfCheck(cfg, interval=1)
    assert sc.maybe_check(bad, 0) == [1] and sc.trips == 1
    assert verify_w_r(sc.repair(bad), cfg) == []


def test_selfcheck_catches_s_c_corruption():
    import jax.numpy as jnp
    from repro.core.abft import sparse_col_checksum
    from repro.engine.api import Graph
    cfg = ABFTConfig(threshold=1e-3)
    s = jnp.asarray(np.eye(6, dtype=np.float32))
    g = Graph(s=s, h0=jnp.ones((6, 4), jnp.float32),
              s_c=sparse_col_checksum(s, cfg.dtype))
    assert not verify_s_c(g, cfg)
    inj = FaultInjector(FaultModel(site="s_c", kind="stuck",
                                   stuck_value=float("nan")))
    assert inj.fires(0)
    inj.apply_graph(g)
    assert verify_s_c(g, cfg)


def test_selfcheck_cadence():
    cfg = ABFTConfig(threshold=1e-3)
    params = _folded_params()
    sc = CheckPathSelfCheck(cfg, interval=4)
    ran = [sc.maybe_check(params, t) is not None for t in range(8)]
    assert ran == [True, False, False, False, True, False, False, False]
    assert sc.checks_run == 2 and sc.trips == 0
    with pytest.raises(ValueError):
        CheckPathSelfCheck(cfg, interval=0)


# ---------------------------------------------------------------------------
# campaign driver
# ---------------------------------------------------------------------------

def test_sweep_models_grid():
    models = sweep_models(reps=1)
    labels = {m.label() for m in models}
    assert "accumulator/bitflip/targeted" in labels
    # check-path sites gain the NaN stuck-at extras
    nan_models = [m for m in models if m.stuck_value is not None
                  and math.isnan(m.stuck_value)]
    assert {m.site for m in nan_models} == set(CHECK_PATH_SITES)


@pytest.fixture(scope="module")
def campaign_payload():
    models = [
        FaultModel(site="accumulator", kind="bitflip", step=1,
                   delta=100.0),
        FaultModel(site="accumulator", kind="stuck", step=1, delta=100.0),
        FaultModel(site="weights", kind="stuck", step=1, stuck_value=7.0,
                   seed=2),
        FaultModel(site="features", kind="bitflip", step=1, bit=30,
                   seed=3),
        FaultModel(site="w_r", kind="stuck", step=1,
                   stuck_value=float("nan"), seed=6),
        FaultModel(site="s_c", kind="stuck", step=1,
                   stuck_value=float("nan"), seed=7),
    ]
    return run_fault_campaign(models, n_steps=4)


def test_campaign_detects_accumulator_upsets(campaign_payload):
    for kind in ("bitflip", "stuck"):
        agg = campaign_payload["by_site_kind"][f"accumulator/{kind}"]
        assert agg["detection_rate"] == 1.0
        assert agg["mean_detection_latency"] == 0.0
    # sticky accumulator: retries are doomed -> the guard escalates
    assert campaign_payload["by_site_kind"]["accumulator/stuck"][
        "escalations"] == 1


def test_campaign_clean_control_has_no_false_positives(campaign_payload):
    assert campaign_payload["clean_control"]["flagged"] == 0
    assert campaign_payload["clean_control"]["false_positive_rate"] == 0.0


def test_campaign_reports_would_be_false_negatives(campaign_payload):
    """A NaN in the check path silences the naive ``d > tau`` comparison;
    the NaN-safe check + self-check still catch it, and the campaign
    reports the discrepancy as a would-be false negative."""
    for site in ("w_r", "s_c"):
        [e] = [e for e in campaign_payload["experiments"]
               if e["model"]["site"] == site]
        assert e["would_be_false_negative"]
        assert e["naive_flagged_steps"] == []     # naive verdict: silent
        assert e["flagged_steps"]                 # NaN-safe verdict: loud
        assert e["selfcheck_detected"]            # root cause pinpointed
        assert e["false_positive_steps"]          # and data was CLEAN


def test_campaign_classifies_sticky_sites_persistent(campaign_payload):
    [e] = [e for e in campaign_payload["experiments"]
           if e["model"]["site"] == "weights"]
    assert e["escalated"]
    tiers = e["repair_tiers"]
    assert tiers["suspect"] and tiers["persistent_sites"]
    total = campaign_payload["repair_tiers_total"]
    assert total["graph"] > 0 and total["persistent_escalations"] > 0


def test_campaign_measures_consistent_corruption(campaign_payload):
    """features/cols_table corruption feeds both sides of eq. 4-6, so
    ABFT may be silent there — the campaign measures the outcome rather
    than asserting detection, and any divergence it finds without a flag
    is recorded as SDC."""
    [e] = [e for e in campaign_payload["experiments"]
           if e["model"]["site"] == "features"]
    assert e["fired_steps"] == [1]
    # every fired step is accounted: detected, SDC, or masked
    accounted = set(e["sdc_steps"]) | set(e["masked_steps"]) | \
        set(e["flagged_steps"])
    assert set(e["fired_steps"]) <= accounted


def test_campaign_payload_is_json_ready(campaign_payload):
    import json
    text = json.dumps(campaign_payload)
    assert '"interpret"' in text and '"authoritative"' in text
    assert campaign_payload["authoritative"] == \
        (not campaign_payload["interpret"])
