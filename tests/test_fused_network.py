"""Whole-network fusion + slot-granular localization (ISSUE 7 tentpole).

Acceptance properties:
  (a) parity: the whole-network kernel (one HBM traversal, activations
      ping-ponging in VMEM) matches the sequential per-layer fused chain
      BIT-FOR-BIT at every depth, and emits one pre-activation check per
      layer (ReLU still breaks the chain — fusing it into the epilogue
      must not coarsen the check granularity);
  (b) VMEM fallback: a network whose depth-wide working set exceeds the
      budget falls back to the per-layer ladder mid-serve — same logits,
      counters tell the operator which path ran;
  (c) slot corners: a fault injected at every (layer, stripe, slot) flags
      exactly ONE telescoped slot corner at the injected coordinates, and
      the slot-surgical repair splices bit-for-bit while re-executing no
      more rows than the stripe tier;
  (d) X-stash two-pass repair: with fused_layer=False the serve step
      stashes each layer's combination output X, so the stripe-surgical
      tier replays the faulted aggregation bitwise instead of escalating;
  (e) guard ladder: slot tier runs before stripe; its accounting
      (slot_retries, recomputed_rows) is exact; a clean adoption strips
      the stash keys; serve/stream stats surface the fusion counters.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.abft import ABFTConfig
from repro.core.gcn import init_gcn
from repro.engine import (
    Graph,
    fold_w_r,
    gcn_forward,
    make_backend,
    pack_graphs,
    synth_graph_stream,
)
from repro.engine.localize import surgical_slot_retry
from repro.engine.streaming import (
    PackedRunner,
    make_packed_serve_step,
    packed_step_args,
)
from repro.runtime import ABFTGuard


def _stream(n_graphs=3, seed=1, feat=8, n_lo=20, n_hi=44):
    return synth_graph_stream(n_graphs, n_lo=n_lo, n_hi=n_hi, feat=feat,
                              seed=seed)


def _cfg(**kw):
    return ABFTConfig(mode="fused", threshold=1e-3, relative=True, **kw)


def _setup(dims=(8, 8, 3), seed=1, n_graphs=3, block=16):
    stream = _stream(n_graphs, seed=seed, feat=dims[0])
    pb = pack_graphs(stream, block=block)
    cfg = _cfg()
    params = fold_w_r(init_gcn(jax.random.PRNGKey(seed), dims), cfg)
    return pb, cfg, params


# ---------------------------------------------------------------------------
# (a) whole-network parity
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("dims", [(8, 8, 3), (8, 16, 8, 3)])
def test_network_matches_per_layer_fused_bitwise(dims):
    pb, cfg, params = _setup(dims=dims)
    args = packed_step_args(pb)
    ref = make_packed_serve_step(params, cfg, pb.n_slots, block_g=16,
                                 fused_layer=True)
    net = make_packed_serve_step(params, cfg, pb.n_slots, block_g=16,
                                 fused_network=True)
    out_ref, m_ref = ref(*args)
    out_net, m_net = net(*args)
    assert not bool(m_net["abft_flag"])
    assert np.array_equal(np.asarray(out_net), np.asarray(out_ref))


def test_network_emits_one_pre_activation_check_per_layer():
    pb, cfg, params = _setup(dims=(8, 16, 8, 3))
    bk = make_backend(pb, cfg, fused_network=True)
    _, checks = gcn_forward(params, Graph(s=pb, h0=jnp.asarray(pb.h0)),
                            cfg, backend=bk)
    assert bk.network_hits == 1 and bk.network_fallbacks == 0
    assert len(checks) == len(params["layers"])
    # per-graph corners at the default packed granularity, one per layer
    assert all(c.granularity == "graph" for c in checks)
    assert all(c.actual.shape == (pb.n_slots,) for c in checks)


def test_network_matches_two_pass_numerically():
    pb, cfg, params = _setup(dims=(8, 16, 8, 3), seed=3)
    args = packed_step_args(pb)
    two = make_packed_serve_step(params, cfg, pb.n_slots, block_g=16)
    net = make_packed_serve_step(params, cfg, pb.n_slots, block_g=16,
                                 fused_network=True)
    out_two, _ = two(*args)
    out_net, _ = net(*args)
    np.testing.assert_allclose(np.asarray(out_net), np.asarray(out_two),
                               atol=1e-4)


# ---------------------------------------------------------------------------
# (b) VMEM fallback
# ---------------------------------------------------------------------------

def test_network_vmem_fallback_preserves_logits_and_counts():
    pb, cfg, params = _setup()
    args = packed_step_args(pb)
    ref = make_packed_serve_step(params, cfg, pb.n_slots, block_g=16,
                                 fused_layer=True)
    out_ref, _ = ref(*args)
    # a budget far below the ping-pong activation buffers: the network hook
    # must decline and the per-layer ladder run instead — same logits
    fb = make_packed_serve_step(params, cfg, pb.n_slots, block_g=16,
                                fused_network=True, fused_layer=True,
                                vmem_budget=1)
    out_fb, m_fb = fb(*args)
    assert not bool(m_fb["abft_flag"])
    # budget=1 also evicts the per-layer fused kernel -> two-pass numerics
    np.testing.assert_allclose(np.asarray(out_fb), np.asarray(out_ref),
                               atol=1e-4)
    runner = PackedRunner(params, cfg, 16, fused_layer=True,
                          fused_network=True, vmem_budget=1)
    counts = runner.fusion_counts(pb)
    assert counts["network_hits"] == 0 and counts["network_fallbacks"] == 1
    assert counts["fused_hits"] == 0
    assert counts["fused_fallbacks"] == len(params["layers"])


def test_network_hit_subsumes_layer_decisions():
    pb, cfg, params = _setup()
    runner = PackedRunner(params, cfg, 16, fused_layer=True,
                          fused_network=True)
    counts = runner.fusion_counts(pb)
    assert counts == {"fused_hits": 0, "fused_fallbacks": 0,
                      "network_hits": 1, "network_fallbacks": 0}


# ---------------------------------------------------------------------------
# (c) slot corners: exact detection + sub-stripe surgical repair
# ---------------------------------------------------------------------------

def test_slot_fault_sweep_exact_detection_and_repair():
    """Inject at every (layer, stripe, slot): exactly ONE slot corner — at
    the injected coordinates — flags, and the slot-surgical splice is
    bit-for-bit while reaching no more rows than the stripe tier."""
    pb, cfg, params = _setup(seed=5, n_graphs=2)
    args = packed_step_args(pb)
    clean = make_packed_serve_step(params, cfg, pb.n_slots, block_g=16,
                                   fused_network=True, granularity="slot")
    logits_clean, m_clean = clean(*args)
    assert not bool(np.asarray(m_clean["abft_graph_flags"]).any())
    logits_clean = np.asarray(logits_clean)

    nbm, width = pb.bell.n_block_rows, pb.bell.width
    stripe_graph = np.asarray(pb.stripe_graph)
    n_layers = len(params["layers"])
    real = [s for s in range(nbm) if stripe_graph[s] < pb.n_slots]
    for layer in range(n_layers):
        for stripe in real[::2]:
            for slot in range(width):
                step = make_packed_serve_step(
                    params, cfg, pb.n_slots, block_g=16,
                    fused_network=True, granularity="slot",
                    inject=(layer, stripe, slot, 64.0))
                out_bad, m_bad = step(*args)
                slf = np.asarray(m_bad["abft_slot_flags"])
                assert slf.shape == (n_layers, nbm, width)
                hits = np.argwhere(slf)
                assert hits.shape == (1, 3) and \
                    tuple(hits[0]) == (layer, stripe, slot), \
                    (layer, stripe, slot, hits.tolist())
                repaired, sub = surgical_slot_retry(
                    pb, params, cfg, out_bad, m_bad, block_g=16)
                assert not sub["abft_graph_flags"].any()
                assert np.array_equal(repaired, logits_clean), \
                    (layer, stripe, slot)
                assert sub["abft_rows_recomputed"] >= pb.block


def test_slot_tier_reaches_fewer_rows_than_stripe_tier():
    """Summed over a fault sweep the slot tier must re-execute strictly
    fewer rows: its downstream reach only follows rows the splice actually
    CHANGED, while the stripe tier follows every repaired row.  Negative
    deltas on already-negative pre-activations are ReLU-masked — the check
    still flags (it reads the pre-activation corner) but the splice changes
    no post-ReLU row, so the slot tier stops at the flagged stripe."""
    from repro.engine.localize import surgical_stripe_retry
    stream = _stream(3, seed=7, n_lo=36, n_hi=72)
    pb = pack_graphs(stream, block=16)
    cfg = _cfg()
    params = fold_w_r(init_gcn(jax.random.PRNGKey(7), (8, 8, 3)), cfg)
    args = packed_step_args(pb)
    stripe_graph = np.asarray(pb.stripe_graph)
    real = [s for s in range(pb.bell.n_block_rows)
            if stripe_graph[s] < pb.n_slots]
    slot_rows = stripe_rows = 0
    for stripe in real:
        for delta in (64.0, -64.0):
            step = make_packed_serve_step(
                params, cfg, pb.n_slots, block_g=16, fused_network=True,
                granularity="slot", inject=(0, stripe, 0, delta))
            out_bad, m_bad = step(*args)
            assert bool(m_bad["abft_flag"]), (stripe, delta)
            _, sub_sl = surgical_slot_retry(pb, params, cfg, out_bad,
                                            m_bad, block_g=16)
            _, sub_st = surgical_stripe_retry(pb, params, cfg, out_bad,
                                              m_bad, block_g=16)
            assert sub_sl["abft_rows_recomputed"] <= \
                sub_st["abft_rows_recomputed"]
            slot_rows += int(sub_sl["abft_rows_recomputed"])
            stripe_rows += int(sub_st["abft_rows_recomputed"])
    assert slot_rows < stripe_rows, (slot_rows, stripe_rows)


def test_mixed_granularity_two_pass_degrades_slot_to_stripe():
    """granularity='slot' on the two-pass path (no per-slot telescopes)
    must degrade to stripe corners, not fabricate slot flags: the slot
    report emits all-False slabs for stripe-granular checks."""
    pb, cfg, params = _setup(seed=9)
    step = make_packed_serve_step(params, cfg, pb.n_slots, block_g=16,
                                  granularity="slot",
                                  inject=(0, 0, 0, 64.0))
    _, m = step(*packed_step_args(pb))
    slf = np.asarray(m["abft_slot_flags"])
    sf = np.asarray(m["abft_stripe_flags"])
    assert not slf.any()                       # no slot telescopes exist
    assert sf.sum() == 1 and sf[0, 0]          # stripe corner still exact


# ---------------------------------------------------------------------------
# (d) X-stash: surgical repair on the two-pass path
# ---------------------------------------------------------------------------

def test_two_pass_stash_enables_bitwise_stripe_repair():
    from repro.engine.localize import surgical_stripe_retry
    pb, cfg, params = _setup(seed=13, n_graphs=2)
    args = packed_step_args(pb)
    clean = make_packed_serve_step(params, cfg, pb.n_slots, block_g=16,
                                   granularity="stripe")
    logits_clean, m_clean = clean(*args)
    assert all(x is not None for x in m_clean["abft_x_layers"])
    logits_clean = np.asarray(logits_clean)
    n_layers = len(params["layers"])
    # a last-layer fault replays from the exact stashed X -> bitwise splice
    step = make_packed_serve_step(params, cfg, pb.n_slots, block_g=16,
                                  granularity="stripe",
                                  inject=(n_layers - 1, 0, 0, 64.0))
    out_bad, m_bad = step(*args)
    from repro.engine.localize import surgical_stripe_retry as retry
    repaired, sub = retry(pb, params, cfg, out_bad, m_bad, block_g=16)
    assert not sub["abft_graph_flags"].any()
    assert np.array_equal(repaired, logits_clean)
    # an earlier-layer fault refreshes downstream stale X rows; the result
    # re-verifies clean and matches the clean logits numerically
    step0 = make_packed_serve_step(params, cfg, pb.n_slots, block_g=16,
                                   granularity="stripe",
                                   inject=(0, 0, 0, 64.0))
    out_bad0, m_bad0 = step0(*args)
    repaired0, sub0 = surgical_stripe_retry(pb, params, cfg, out_bad0,
                                            m_bad0, block_g=16)
    assert not sub0["abft_graph_flags"].any()
    np.testing.assert_allclose(repaired0, logits_clean, atol=1e-5)


# ---------------------------------------------------------------------------
# (e) guard ladder + serve/stream accounting
# ---------------------------------------------------------------------------

def test_guard_slot_tier_adopts_before_stripe():
    pb, cfg, params = _setup(seed=5, n_graphs=2)
    args = packed_step_args(pb)
    clean = make_packed_serve_step(params, cfg, pb.n_slots, block_g=16,
                                   fused_network=True, granularity="slot")
    logits_clean = np.asarray(clean(*args)[0])
    step = make_packed_serve_step(params, cfg, pb.n_slots, block_g=16,
                                  fused_network=True, granularity="slot",
                                  inject=(0, 1, 0, 64.0))
    out_bad, m_bad = step(*args)
    runner = PackedRunner(params, cfg, 16, granularity="slot",
                          fused_network=True)
    guard = ABFTGuard()
    out, m = guard.adjudicate(out_bad, m_bad, runner.retry_fn(pb),
                              stripe_retry_fn=runner.stripe_retry_fn(pb),
                              slot_retry_fn=runner.slot_retry_fn(pb))
    assert np.array_equal(np.asarray(out), logits_clean)
    assert guard.slot_retries > 0 and guard.stripe_retries == 0
    assert guard.graph_retries == 0 and guard.recomputed_rows > 0
    assert not bool(m["abft_flag"])
    assert not np.asarray(m["abft_slot_flags"]).any()
    # adoption strips the repair-only stash keys
    assert "abft_h_layers" not in m and "abft_x_layers" not in m


def test_guard_slot_tier_falls_back_to_stripe_then_graph():
    """A slot_retry_fn that cannot verify must hand the (possibly
    partially repaired) output down the ladder, not adopt it."""
    pb, cfg, params = _setup(seed=5, n_graphs=2)
    args = packed_step_args(pb)
    clean = make_packed_serve_step(params, cfg, pb.n_slots, block_g=16,
                                   fused_network=True, granularity="slot")
    logits_clean = np.asarray(clean(*args)[0])
    step = make_packed_serve_step(params, cfg, pb.n_slots, block_g=16,
                                  fused_network=True, granularity="slot",
                                  inject=(0, 1, 0, 64.0))
    out_bad, m_bad = step(*args)
    runner = PackedRunner(params, cfg, 16, granularity="slot",
                          fused_network=True)

    def broken_slot_retry(out, metrics):
        sub = {"abft_graph_flags":
               np.asarray(metrics["abft_graph_flags"], bool).copy(),
               "abft_graph_max_rel":
               np.asarray(metrics["abft_graph_max_rel"]).copy(),
               "abft_stripes_recomputed": 0, "abft_rows_recomputed": 0}
        return out, sub

    guard = ABFTGuard()
    out, m = guard.adjudicate(out_bad, m_bad, runner.retry_fn(pb),
                              stripe_retry_fn=runner.stripe_retry_fn(pb),
                              slot_retry_fn=broken_slot_retry)
    assert np.array_equal(np.asarray(out), logits_clean)
    assert guard.slot_retries == 0          # nothing was re-executed
    assert guard.stripe_retries > 0         # the stripe tier repaired it
    assert not bool(m["abft_flag"])


def test_serve_stats_carry_fusion_counters():
    from repro.launch.serve_gcn import serve
    from repro.engine import make_packed_batches
    stream = _stream(6, seed=2)
    batches = make_packed_batches(stream, 3, block=16)
    params = init_gcn(jax.random.PRNGKey(2), (8, 8, 3))
    stats = serve(batches, params, _cfg(), verbose=False, block_g=16,
                  fused_network=True, granularity="slot")
    assert stats["network_hits"] == len(batches)
    assert stats["network_fallbacks"] == 0
    assert stats["slot_retries"] == 0
    assert not stats["graph_flags"].any()


def test_streaming_stats_carry_fusion_counters():
    from repro.engine import StreamingEngine, plan_rungs
    stream = _stream(8, seed=4)
    rungs = plan_rungs(stream, n_slots=4, block=16)
    params = init_gcn(jax.random.PRNGKey(4), (8, 8, 3))
    eng = StreamingEngine(params, _cfg(), rungs, fused_network=True,
                          granularity="slot")
    for s, h0 in stream:
        eng.submit(s, h0)
    results = eng.drain()
    stats = eng.stats(results)
    assert stats["served"] == len(stream)
    assert stats["network_hits"] == stats["batches"]
    assert stats["network_fallbacks"] == 0
    assert {"fused_hits", "fused_fallbacks"} <= set(stats)
    assert all(not r.flag for r in results)
