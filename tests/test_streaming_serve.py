"""Streaming serve engine (ISSUE 6 tentpole) + the serve/guard bugs
closed batches were hiding.

Acceptance properties:
  (a) exact-shape packing: ``pack_graphs(stripe_cap=, width_cap=)`` pins
      the jit-visible shape, so different streams padded to the same rung
      share one compile; undersized caps fail fast;
  (b) rung planning: ``plan_rungs`` admits every profiled graph, caps are
      quantized and monotone, ``RungTable.fit`` picks the smallest
      admitting rung;
  (c) the headline contract — a ragged 200-graph stream serves with
      jit-compile count <= rung-table size, per-graph parity with the
      dense single-graph engine, and p50/p99 latency stats;
  (d) backpressure: submits beyond ``queue_capacity`` resolve to explicit
      ``rejected`` verdicts, never silent drops or unbounded buffering;
  (e) oversize degradation (bugfix): a 10x graph mid-stream is served via
      a dedicated singleton shape (or explicitly rejected under
      ``oversize_policy="reject"``) — the stream never crashes;
  (f) flush-on-deadline: a partial bin older than the deadline dispatches
      instead of starving behind a bin that will not fill;
  (g) retry-ladder compile bounds (bugfix): packed and dense per-graph
      retries pad flagged subsets up a power-of-two ladder, so distinct
      flagged counts share O(log) compiles instead of one each;
  (h) activation-retention bugfix: adopted metrics never carry
      ``abft_h_layers`` (the per-layer activation stash the surgical
      closure needs) — the closures still see it;
  (i) repair-accounting bugfix: ``retry_fn`` reports LOGICAL rows
      (sum n_nodes x layers), not the padded sub-pack rows.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.abft import ABFTConfig
from repro.core.gcn import init_gcn
from repro.engine import (
    Graph,
    StreamingEngine,
    fold_w_r,
    gcn_apply,
    graph_pack_stats,
    make_batches,
    pack_graphs,
    plan_rungs,
    synth_graph_stream,
)
from repro.engine.streaming import (
    PackedRunner,
    RungTable,
    dense_retry_fn,
    make_packed_serve_step,
    next_pow2,
    packed_step_args,
)
from repro.runtime import ABFTGuard, GuardConfig

FEAT, HIDDEN, CLASSES = 4, 4, 3
BLOCK = 8


def _stream(n, seed=0, n_lo=6, n_hi=28):
    return synth_graph_stream(n, n_lo=n_lo, n_hi=n_hi, feat=FEAT, seed=seed)


def _params(seed=0):
    return init_gcn(jax.random.PRNGKey(seed), (FEAT, HIDDEN, CLASSES))


def _cfg():
    return ABFTConfig(mode="fused", threshold=1e-3, relative=True)


def _engine(stream, *, n_slots=4, profile=None, **kw):
    rungs = plan_rungs(profile if profile is not None else stream,
                       n_slots=n_slots, block=BLOCK, stripe_multiple=4,
                       width_multiple=4)
    return StreamingEngine(_params(), _cfg(), rungs, **kw)


def _dense_ref(s, h0):
    logits, rep = gcn_apply(_params(), Graph(s=jnp.asarray(s),
                                             h0=jnp.asarray(h0)), _cfg())
    assert not bool(rep.flag)
    return np.asarray(logits)


# ---------------------------------------------------------------------------
# (a) exact-shape packing against a rung
# ---------------------------------------------------------------------------

def test_pack_graphs_caps_pin_exact_shape():
    a, b = _stream(3, seed=1), _stream(3, seed=2)
    kw = dict(block=BLOCK, n_slots=4, stripe_multiple=4, width_multiple=4,
              stripe_cap=24, width_cap=4)
    pa = pack_graphs(a, **kw)
    pb = pack_graphs(b, **kw)
    assert pa.bell.values.shape == (24, 4, BLOCK, BLOCK)
    # the bounded-compile contract IS this: same rung -> same jit key
    assert pa.bell.values.shape == pb.bell.values.shape
    assert pa.h0.shape == pb.h0.shape
    assert pa.stripe_graph.shape == pb.stripe_graph.shape
    # cap padding stripes sit in the overflow segment and alias col-block 0
    assert (np.asarray(pa.stripe_graph) == pa.n_slots).sum() > 0
    for g, (s, h0) in enumerate(a):
        o, n = pa.row_offsets[g], pa.n_nodes[g]
        np.testing.assert_allclose(pa.bell.todense()[o:o + n, o:o + n], s,
                                   atol=1e-6)


def test_pack_graphs_caps_too_small_raise():
    stream = _stream(3, seed=1)
    stripes = sum(graph_pack_stats(s, BLOCK)[0] for s, _ in stream)
    with pytest.raises(ValueError):
        pack_graphs(stream, block=BLOCK, stripe_cap=stripes - 1)
    with pytest.raises(ValueError):
        pack_graphs(stream, block=BLOCK, width_cap=0)


# ---------------------------------------------------------------------------
# (b) rung planning
# ---------------------------------------------------------------------------

def test_plan_rungs_admits_every_profiled_graph():
    profile = _stream(24, seed=3, n_lo=6, n_hi=60)
    rungs = plan_rungs(profile, n_slots=4, block=BLOCK, stripe_multiple=4,
                       width_multiple=4, max_rungs=4)
    assert 1 <= len(rungs) <= 4
    caps = [r.stripe_cap for r in rungs.rungs]
    assert caps == sorted(caps)
    assert all(r.stripe_cap % 4 == 0 and r.width_cap % 4 == 0
               for r in rungs.rungs)
    for s, _ in profile:
        st, w = graph_pack_stats(s, BLOCK)
        assert rungs.fit(st, w) is not None, (st, w, rungs.rungs)


def test_rung_table_fit_smallest_and_oversize():
    from repro.engine.streaming import Rung
    t = RungTable(rungs=(Rung(8, 4, 4), Rung(16, 4, 4), Rung(32, 4, 4)),
                  block=BLOCK)
    assert t.fit(5, 2) == t.rungs[0]
    assert t.fit(9, 4) == t.rungs[1]
    assert t.fit(33, 1) is None          # stripe overflow
    assert t.fit(4, 5) is None           # width overflow


# ---------------------------------------------------------------------------
# (c) the headline contract: 200-graph ragged stream, bounded compiles
# ---------------------------------------------------------------------------

def test_stream_200_graphs_bounded_compiles_with_latency_stats():
    stream = _stream(200, seed=4)
    eng = _engine(stream[:32], profile=stream[:32], n_slots=4,
                  queue_capacity=64, flush_deadline=None)
    assert eng.warmup() == len(eng.rungs)
    results = []
    for s, h0 in stream:
        eng.submit(s, h0)
        results.extend(eng.take_results())
    results.extend(eng.drain())

    assert len(results) == 200
    assert [r.rid for r in results] == sorted(r.rid for r in results)
    assert all(r.status == "served" for r in results)
    assert not any(r.flag for r in results)
    # THE contract: compiles bounded by the rung table, not the traffic
    assert eng.compile_count <= len(eng.rungs), \
        (eng.compile_count, len(eng.rungs))
    stats = eng.stats(results)
    assert stats["served"] == 200 and stats["rejected"] == 0
    assert stats["compiles"] <= stats["rung_table_size"]
    assert stats["latency_p50_ms"] is not None
    assert stats["latency_p99_ms"] >= stats["latency_p50_ms"]
    # per-request logits match the single-graph dense engine
    for r in results[::37]:
        s, h0 = stream[r.rid]
        np.testing.assert_allclose(r.logits, _dense_ref(s, h0),
                                   atol=1e-4, rtol=1e-4,
                                   err_msg=f"rid {r.rid}")


# ---------------------------------------------------------------------------
# (d) backpressure: explicit rejection verdicts
# ---------------------------------------------------------------------------

def test_stream_queue_full_rejects_explicitly():
    stream = _stream(10, seed=5)
    # one 8-slot rung + capacity 2: the bin can never fill before the
    # queue bound trips, so submits 3..10 must reject
    eng = _engine(stream, n_slots=8, queue_capacity=2, flush_deadline=None)
    for s, h0 in stream:
        eng.submit(s, h0)
    results = eng.drain()
    by_status = {}
    for r in results:
        by_status.setdefault(r.status, []).append(r)
    assert len(by_status.get("served", [])) == 2
    rejected = by_status["rejected"]
    assert len(rejected) == 8
    assert all("queue full" in r.reason for r in rejected)
    assert all(r.t_verdict is not None for r in rejected)
    assert all(r.logits is None for r in rejected)
    stats = eng.stats(results)
    assert stats["rejected"] == 8 and stats["served"] == 2


# ---------------------------------------------------------------------------
# (e) oversize degradation — the 10x graph that used to kill the stream
# ---------------------------------------------------------------------------

def _with_oversized(seed=6, n=12, at=6, factor=10):
    stream = list(_stream(n, seed=seed, n_lo=6, n_hi=20))
    big = synth_graph_stream(1, n_lo=20 * factor, n_hi=20 * factor,
                             feat=FEAT, seed=seed + 99)[0]
    stream.insert(at, big)
    return stream, at


def test_stream_oversized_graph_served_as_singleton():
    stream, at = _with_oversized()
    eng = _engine([g for i, g in enumerate(stream) if i != at],
                  n_slots=4, oversize_policy="singleton")
    results = []
    for s, h0 in stream:                 # must not raise at the big graph
        eng.submit(s, h0)
        results.extend(eng.take_results())
    results.extend(eng.drain())
    assert len(results) == len(stream)
    assert all(r.status == "served" for r in results)
    assert eng.singleton_dispatches == 1
    # the singleton adds at most one ladder shape beyond the rung table
    assert eng.compile_count <= len(eng.rungs) + 1
    big_s, big_h0 = stream[at]
    big_res = next(r for r in results if r.rid == at)
    np.testing.assert_allclose(big_res.logits, _dense_ref(big_s, big_h0),
                               atol=1e-4, rtol=1e-4)


def test_stream_oversized_graph_reject_policy():
    stream, at = _with_oversized()
    eng = _engine([g for i, g in enumerate(stream) if i != at],
                  n_slots=4, oversize_policy="reject")
    for s, h0 in stream:
        eng.submit(s, h0)
    results = eng.drain()
    big = next(r for r in results if r.rid == at)
    assert big.status == "rejected_oversize"
    assert "stripes" in big.reason and big.logits is None
    others = [r for r in results if r.rid != at]
    assert all(r.status == "served" for r in others)
    assert eng.stats(results)["rejected_oversize"] == 1


def test_oversize_policy_validated():
    with pytest.raises(ValueError, match="oversize_policy"):
        _engine(_stream(2), oversize_policy="explode")


# ---------------------------------------------------------------------------
# (f) flush-on-deadline
# ---------------------------------------------------------------------------

def test_stream_deadline_flushes_partial_bin():
    stream = _stream(2, seed=7)
    eng = _engine(stream, n_slots=4, flush_deadline=1.0)
    eng.submit(*stream[0], now=0.0)
    assert eng.batches_dispatched == 0           # bin open, under deadline
    eng.pump(now=0.5)
    assert eng.batches_dispatched == 0
    eng.pump(now=1.5)                            # oldest waited >= deadline
    assert eng.batches_dispatched == 1
    eng.submit(*stream[1], now=1.6)
    results = eng.drain(now=1.7)
    assert eng.batches_dispatched == 2
    assert [r.status for r in results] == ["served", "served"]
    # partial bins padded to the SAME rung shape: still one compile
    assert eng.compile_count <= len(eng.rungs)


# ---------------------------------------------------------------------------
# (g) bugfix: retry ladders bound recompiles
# ---------------------------------------------------------------------------

def test_packed_retry_ladder_shares_compiles_across_flag_counts():
    # 5 equal one-stripe graphs, quantization 1: flagged subsets of 3 and
    # 4 graphs must pad to the SAME (4-slot) sub-pack shape and share one
    # jitted step — pre-fix each flagged count compiled its own shape
    stream = synth_graph_stream(5, n_lo=8, n_hi=8, feat=FEAT, seed=8)
    pb = pack_graphs(stream, block=BLOCK, stripe_multiple=1,
                     width_multiple=1)
    params = fold_w_r(_params(), _cfg())
    runner = PackedRunner(params, _cfg(), BLOCK)
    out = np.asarray(runner.step_for(pb)(*packed_step_args(pb))[0])
    base = runner.compile_count

    s3 = runner._retry_shape(pb, [pb.items[i] for i in (0, 1, 2)])
    s4 = runner._retry_shape(pb, [pb.items[i] for i in (0, 1, 2, 3)])
    assert s3 == s4 and s3["n_slots"] == 4

    retry = runner.retry_fn(pb)
    out3, m3 = retry(out, np.asarray([0, 1, 2]))
    out4, m4 = retry(out, np.asarray([0, 1, 2, 3]))
    assert runner.compile_count == base + 1, \
        "flagged counts 3 and 4 must share one ladder compile"
    # sliced metrics align to flagged_idx, not the padded sub-pack
    assert m3["abft_graph_flags"].shape == (3,)
    assert m4["abft_graph_flags"].shape == (4,)
    np.testing.assert_allclose(out4, out, atol=1e-5)  # clean re-run patches


def test_dense_retry_pads_up_pow2_ladder():
    stream = _stream(5, seed=9, n_lo=10, n_hi=10)
    b = make_batches(stream, 5, buckets=[16])[0]
    shapes = []

    def recording_step(s, h0):
        shapes.append(tuple(s.shape))
        from repro.engine.streaming import make_serve_step
        return make_serve_step(fold_w_r(_params(), _cfg()), _cfg())(s, h0)

    retry = dense_retry_fn(recording_step, b)
    out = np.zeros((5, 16, CLASSES), np.float32)
    _, m3 = retry(out, np.asarray([0, 1, 2]))
    _, m4 = retry(out, np.asarray([0, 2, 3, 4]))
    # both flagged counts present the SAME padded shape to jit
    assert shapes == [(4, 16, 16), (4, 16, 16)]
    assert m3["abft_graph_flags"].shape == (3,)
    assert m4["abft_graph_flags"].shape == (4,)
    # the all-zero pad slots contribute 0 = 0 checks — never flagged
    assert not m3["abft_graph_flags"].any()


def test_next_pow2():
    assert [next_pow2(n) for n in (1, 2, 3, 4, 5, 7, 8, 9)] == \
        [1, 2, 4, 4, 8, 8, 8, 16]


# ---------------------------------------------------------------------------
# (h) bugfix: adopted metrics never retain abft_h_layers
# ---------------------------------------------------------------------------

def test_guard_strips_h_layers_from_adopted_metrics():
    def step():
        return np.zeros(2), {
            "abft_flag": False, "abft_max_rel": 0.0,
            "abft_graph_flags": np.zeros(2, bool),
            "abft_h_layers": [np.ones((64, 4))]}

    g = ABFTGuard()
    _, m = g.run_step_graphs(step, lambda out, idx: (out, {}))
    assert "abft_h_layers" not in m
    assert "abft_graph_flags" in m               # the rest survives


def test_guard_h_layers_visible_to_stripe_closure_stripped_after():
    seen = {}

    def step():
        return np.zeros(2), {
            "abft_flag": True, "abft_max_rel": 1.0,
            "abft_graph_flags": np.asarray([True, False]),
            "abft_stripe_flags": np.asarray([[True, False]]),
            "abft_h_layers": [np.ones((64, 4))]}

    def sretry(out, metrics):
        # the surgical closure is WHY the stash exists — it must see it
        seen["h_layers"] = "abft_h_layers" in metrics
        return out, {"abft_graph_flags": np.zeros(2, bool),
                     "abft_stripes_recomputed": 1,
                     "abft_rows_recomputed": 8}

    g = ABFTGuard(GuardConfig(max_retries=1))
    _, m = g.run_step_graphs(step, lambda out, idx: (out, {}),
                             stripe_retry_fn=sretry)
    assert seen["h_layers"] is True
    assert "abft_h_layers" not in m
    assert not m["abft_graph_flags"].any()


def test_packed_stripe_step_emits_h_layers_engine_result_does_not():
    stream = _stream(3, seed=10)
    pb = pack_graphs(stream, block=BLOCK, stripe_multiple=4)
    params = fold_w_r(_params(), _cfg())
    step = make_packed_serve_step(params, _cfg(), pb.n_slots, block_g=BLOCK,
                                  fused_layer=True, granularity="stripe")
    out, raw = step(*packed_step_args(pb))
    assert "abft_h_layers" in raw                # the closure's operands
    runner = PackedRunner(params, _cfg(), BLOCK, True, "stripe")
    g = ABFTGuard()
    _, adopted = g.adjudicate(out, raw, runner.retry_fn(pb),
                              stripe_retry_fn=runner.stripe_retry_fn(pb))
    assert "abft_h_layers" not in adopted


def test_guard_adjudicate_without_replay_raises_on_escalation():
    def step():
        return np.zeros(1), {"abft_flag": True, "abft_max_rel": 1.0,
                             "abft_graph_flags": np.ones(1, bool)}

    def bad_retry(out, idx):
        return out, {"abft_graph_flags": np.ones(len(idx), bool)}

    g = ABFTGuard(GuardConfig(max_retries=1), restore_fn=lambda: None)
    out, m = step()
    with pytest.raises(RuntimeError, match="no replay"):
        g.adjudicate(out, m, bad_retry)


# ---------------------------------------------------------------------------
# (i) bugfix: retry accounting counts logical rows
# ---------------------------------------------------------------------------

def test_retry_reports_logical_rows_not_padded():
    # 13-node graphs at block 8: 16 padded rows each — the padded basis
    # would report 16 rows/graph/layer, the logical basis 13
    stream = synth_graph_stream(4, n_lo=13, n_hi=13, feat=FEAT, seed=11)
    pb = pack_graphs(stream, block=BLOCK, stripe_multiple=1,
                     width_multiple=1)
    params = fold_w_r(_params(), _cfg())
    runner = PackedRunner(params, _cfg(), BLOCK)
    out = np.asarray(runner.step_for(pb)(*packed_step_args(pb))[0])
    n_layers = len(params["layers"])
    _, m = runner.retry_fn(pb)(out, np.asarray([1]))
    assert int(m["abft_rows_recomputed"]) == 13 * n_layers
    _, m2 = runner.retry_fn(pb)(out, np.asarray([0, 2]))
    assert int(m2["abft_rows_recomputed"]) == 26 * n_layers
