"""Unit + property tests for the ABFT core (the paper's contribution)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

hypothesis = pytest.importorskip(
    "hypothesis", reason="property tests need hypothesis (requirements-dev.txt)")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.core import (
    ABFTConfig,
    check_chain,
    check_matmul,
    checked_matmul,
    gcn_layer_fused,
    gcn_layer_split,
    fused_chain_checksum,
    kahan_total,
    predicted_matmul_checksum,
    summarize,
)
from repro.core.checksum import col_checksum, row_checksum, total_checksum

CFG = ABFTConfig(mode="fused", threshold=1e-3, relative=True)


def rand(shape, seed, scale=1.0):
    return jax.random.normal(jax.random.PRNGKey(seed), shape) * scale


# ---------------------------------------------------------------------------
# checksum identities
# ---------------------------------------------------------------------------

dims = st.integers(min_value=1, max_value=17)


@settings(max_examples=25, deadline=None)
@given(m=dims, k=dims, n=dims, seed=st.integers(0, 2**20))
def test_matmul_checksum_identity_int(m, k, n, seed):
    """e^T (AB) e == (e^T A)(B e) exactly over integers."""
    rng = np.random.default_rng(seed)
    a = rng.integers(-5, 6, size=(m, k)).astype(np.int64)
    b = rng.integers(-5, 6, size=(k, n)).astype(np.int64)
    lhs = (a @ b).sum()
    rhs = a.sum(0) @ b.sum(1)
    assert lhs == rhs


@settings(max_examples=25, deadline=None)
@given(m=dims, k=dims, j=dims, n=dims, seed=st.integers(0, 2**20))
def test_three_chain_identity_int(m, k, j, n, seed):
    """The paper's eq. (4): e^T (SHW) e == (e^T S) H (W e), exact in ints."""
    rng = np.random.default_rng(seed)
    s = rng.integers(-3, 4, size=(m, k)).astype(np.int64)
    h = rng.integers(-3, 4, size=(k, j)).astype(np.int64)
    w = rng.integers(-3, 4, size=(j, n)).astype(np.int64)
    lhs = (s @ h @ w).sum()
    rhs = (s.sum(0) @ h) @ w.sum(1)
    assert lhs == rhs


def test_fused_chain_checksum_float():
    mats = tuple(rand((d1, d2), i) for i, (d1, d2) in
                 enumerate([(8, 16), (16, 12), (12, 6)]))
    pred = fused_chain_checksum(mats, dtype=jnp.float32)
    out = mats[0] @ mats[1] @ mats[2]
    np.testing.assert_allclose(pred, out.sum(), rtol=2e-4)


def test_predicted_matmul_checksum_batched():
    a = rand((3, 8, 5), 0)
    b = rand((3, 5, 7), 1)
    pred = predicted_matmul_checksum(a, b)
    act = jnp.einsum("bij,bjk->bik", a, b).sum((-2, -1))
    np.testing.assert_allclose(pred, act, rtol=3e-4, atol=1e-4)


def test_kahan_total_precision():
    # f32 naive summation loses ~1e-2 on this adversarial stream; Kahan holds.
    x = jnp.concatenate([jnp.full((1,), 1e8), jnp.full((4096,), 0.1),
                         jnp.full((1,), -1e8)]).reshape(1, -1)
    naive = float(total_checksum(x, jnp.float32))
    kah = float(kahan_total(x))
    exact = 0.1 * 4096
    assert abs(kah - exact) < 0.05          # compensation term still f32
    assert abs(kah - exact) <= abs(naive - exact) * 1e-3


# ---------------------------------------------------------------------------
# checks: clean data passes, corrupted data flags
# ---------------------------------------------------------------------------

def test_checked_matmul_clean():
    a, b = rand((64, 32), 0), rand((32, 48), 1)
    c, chk = checked_matmul(a, b, CFG)
    assert not bool(chk.flag(CFG))
    np.testing.assert_allclose(c, a @ b, rtol=1e-6)


@pytest.mark.parametrize("mode", ["split", "fused"])
def test_gcn_layer_detects_output_corruption(mode):
    s = jnp.abs(rand((32, 32), 0)) / 32
    h = rand((32, 24), 1)
    w = rand((24, 16), 2)
    cfg = ABFTConfig(mode=mode, threshold=1e-3, relative=True)
    if mode == "split":
        h_out, checks = gcn_layer_split(s, h, w, cfg)
        checks = list(checks)
    else:
        h_out, chk = gcn_layer_fused(s, h, w, cfg)
        checks = [chk]
    assert not bool(summarize(checks, cfg).flag)

    # corrupt one element of the final output -> actual checksum diverges
    bad = h_out.at[3, 5].add(100.0)
    actual_bad = bad.sum()
    chk_bad = checks[-1]._replace(actual=actual_bad)
    assert bool(chk_bad.flag(cfg))


def test_split_and_fused_agree_on_final_prediction():
    """The fused prediction equals split's second-check prediction (same
    s_c·x_r contraction) — the savings come from dropping check state, not
    from changing the final comparison."""
    s = jnp.abs(rand((20, 20), 3)) / 20
    h = rand((20, 12), 4)
    w = rand((12, 8), 5)
    _, (c1, c2) = gcn_layer_split(s, h, w, CFG)
    _, cf = gcn_layer_fused(s, h, w, CFG)
    np.testing.assert_allclose(c2.predicted, cf.predicted, rtol=1e-6)


def test_zero_column_masking_tradeoff():
    """Paper §III: a zero column in S masks first-step faults from GCN-ABFT
    while split ABFT still catches them."""
    s = jnp.abs(rand((16, 16), 6)) / 16
    s = s.at[:, 7].set(0.0)          # kill column 7
    h = rand((16, 8), 7)
    w = rand((8, 4), 8)
    cfg = ABFTConfig(mode="split", threshold=1e-4, relative=True)

    x = h @ w
    x_bad = x.at[7, 2].add(50.0)     # fault lands in row 7 of X
    # split check 1 sees sum(X) diverge
    c1 = check_matmul(h, w, x_bad, cfg)
    assert bool(c1.flag(cfg))
    # fused check: S @ X_bad is identical to S @ X (column 7 of S is zero)
    h_out_bad = s @ x_bad
    from repro.core.checksum import col_checksum as cc, row_checksum as rc
    pred = cc(s, jnp.float32) @ (h.astype(jnp.float32) @ rc(w, jnp.float32))
    diff = jnp.abs(pred - h_out_bad.sum())
    assert float(diff) < 1e-2        # fault invisible to the fused check


def test_chain_check_batched():
    a = jnp.abs(rand((2, 10, 10), 9))
    b = rand((10, 6), 10)
    c = rand((6, 4), 11)
    out = jnp.einsum("bij,jk,kl->bil", a, b, c)
    chk = check_chain([a, b, c], out, CFG)
    assert chk.predicted.shape == (2,)
    assert not bool(chk.flag(CFG))


# ---------------------------------------------------------------------------
# GCN model end-to-end
# ---------------------------------------------------------------------------

def test_gcn_apply_and_grad():
    from repro.core.gcn import gcn_apply, gcn_loss, init_gcn
    n, f, h, c = 40, 12, 8, 4
    rng = np.random.default_rng(0)
    s = jnp.asarray(np.abs(rng.normal(size=(n, n))).astype(np.float32) / n)
    x0 = jnp.asarray(rng.normal(size=(n, f)).astype(np.float32))
    labels = jnp.asarray(rng.integers(0, c, size=n))
    params = init_gcn(jax.random.PRNGKey(0), (f, h, c))
    logits, report = jax.jit(
        lambda p: gcn_apply(p, s, x0, CFG))(params)
    assert logits.shape == (n, c)
    assert not bool(report.flag)
    assert np.isfinite(np.asarray(logits)).all()

    (loss, rep), grads = jax.value_and_grad(
        lambda p: gcn_loss(p, s, x0, labels, None, CFG), has_aux=True)(params)
    assert np.isfinite(float(loss))
    flat = jax.tree.leaves(grads)
    assert all(np.isfinite(np.asarray(g)).all() for g in flat)
