"""GAT eq. 4–6 tests (ISSUE 10 satellite).

GAT's attention-weighted aggregation is still a three-matrix product
``H' = A (H W)``, so the paper's fused chain check applies verbatim:

  (a) chain-vs-split parity: the fused single-corner prediction
      ``s_att · (H w_r)`` equals the split composition's eq. 2–3 check of
      the last multiply, both matching the f64 reference sum;
  (b) bit-flip fault-detection sweep mirroring ``tests/test_sparse_abft``:
      an exponent bit flip in the served output trips the check at the
      Table I thresholds, sub-threshold deltas stay silent, and clean
      runs are unflagged;
  (c) one corner covers BOTH matmuls: corrupting W after the offline
      fold (the detectable memory-fault class) flags, even though the
      corruption enters through the inner product H·W;
  (d) the guarded engine detects an injected accumulator fault in any
      layer and repairs it through the ABFTGuard ladder end-to-end,
      returning bit-identical outputs.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.abft import ABFTConfig, check_matmul
from repro.core.fault import THRESHOLDS, flip_bit_f32
from repro.engine.gat import (
    GATEngine,
    fold_gat_w_r,
    gat_forward,
    gat_layer,
    init_gat,
    make_gat_serve_step,
)
from repro.faults.injectors import flip_bits

CFG = ABFTConfig(mode="fused", threshold=1e-3, relative=True)
DIMS = (12, 16, 8, 4)


def random_adj(seed, n, p=0.25):
    """Symmetric random adjacency with self-loops (nonzero = edge)."""
    rng = np.random.default_rng(seed)
    a = rng.random((n, n)) < p
    a = np.logical_or(a, a.T)
    np.fill_diagonal(a, True)
    return jnp.asarray(a.astype(np.float32))


def random_inputs(seed, n, f):
    rng = np.random.default_rng(seed)
    return (jnp.asarray(rng.normal(0, 0.5, size=(n, f)).astype(np.float32)),
            random_adj(seed + 1, n))


def _att(p, h):
    """The layer's attention matrix, recomputed reference-style."""
    x = h @ p["w"].astype(h.dtype)
    scores = (x @ p["a_l"].astype(x.dtype))[:, None] \
        + (x @ p["a_r"].astype(x.dtype))[None, :]
    return x, jax.nn.leaky_relu(scores, 0.2)


# ---------------------------------------------------------------------------
# (a) chain == split composition
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("seed,n", [(0, 24), (1, 48), (2, 96)])
def test_chain_equals_split_composition(seed, n):
    params = init_gat(jax.random.PRNGKey(seed), (8, 6))
    p = params["layers"][0]
    h, adj = random_inputs(seed + 10, n, 8)
    out, chk = gat_layer(p, h, adj, CFG)
    # split composition: eq. 2-3 on the LAST multiply A @ X with its true
    # left operand (the softmaxed attention matrix)
    x, scores = _att(p, h)
    att = jax.nn.softmax(jnp.where(adj > 0, scores, -1e30), axis=-1)
    np.testing.assert_allclose(np.asarray(att @ x), np.asarray(out),
                               atol=1e-6)
    split = check_matmul(att, x, out, CFG)
    ref = float(np.asarray(out, np.float64).sum())
    scale = max(1.0, abs(ref))
    assert abs(float(chk.predicted) - float(split.predicted)) / scale < 1e-4
    assert abs(float(chk.predicted) - ref) / scale < 1e-4
    assert not bool(chk.flag(CFG))


# ---------------------------------------------------------------------------
# (b) bit-flip sweep at Table I thresholds
# ---------------------------------------------------------------------------

def _gat_fault_property(seed, threshold):
    params = init_gat(jax.random.PRNGKey(seed), (12, 16))
    # small feature magnitudes keep the f32 accumulation noise of the two
    # checksum corners under tau/4 at the tightest Table I threshold
    rng = np.random.default_rng(seed + 20)
    h = jnp.asarray(rng.normal(0, 0.1, size=(48, 12)).astype(np.float32))
    adj = random_adj(seed + 21, 48)
    out, chk = gat_layer(params["layers"][0], h, adj, CFG)
    clean_div = abs(float(chk.predicted) - float(chk.actual))
    assert clean_div < threshold / 4, (clean_div, threshold)

    rng = np.random.default_rng(seed)
    out_np = np.asarray(out).copy()
    big = np.argwhere(np.abs(out_np) >= 1e-3)
    assert big.size, "attention collapsed every value below threshold"
    i, j = big[int(rng.integers(len(big)))]
    old = out_np[i, j]
    new = flip_bit_f32(np.float32(old), 27)
    delta = float(new) - float(old)
    out_np[i, j] = new
    div = abs(float(chk.predicted) - float(out_np.astype(np.float64).sum()))
    assert div > threshold, (div, delta, threshold)
    assert abs(div - abs(delta)) < max(1e-5 * abs(delta), threshold / 4)


@pytest.mark.parametrize("threshold", list(THRESHOLDS[:2]))   # 1e-4, 1e-5
@pytest.mark.parametrize("seed", [0, 5])
def test_bitflip_detected(seed, threshold):
    _gat_fault_property(seed, threshold)


def test_small_fault_below_threshold_is_silent():
    params = init_gat(jax.random.PRNGKey(3), (12, 16))
    h, adj = random_inputs(30, 48, 12)
    out, chk = gat_layer(params["layers"][0], h, adj, CFG)
    bad = np.asarray(out, np.float64).copy()
    bad[5, 3] += 2e-5                          # below tau = 1e-4
    assert abs(float(chk.predicted) - bad.sum()) < 1e-4


# ---------------------------------------------------------------------------
# (c) one corner covers the inner matmul too
# ---------------------------------------------------------------------------

def test_weight_corruption_after_fold_flags():
    params = fold_gat_w_r(init_gat(jax.random.PRNGKey(4), (12, 16)), CFG)
    h, adj = random_inputs(40, 48, 12)
    p = dict(params["layers"][0])
    assert p["w_r"].shape == (12,)
    p["w"] = jnp.asarray(flip_bits(np.asarray(p["w"]), 37, 30))
    _out, chk = gat_layer(p, h, adj, CFG)      # w_r predates the corruption
    assert bool(chk.flag(CFG))


def test_multilayer_forward_clean_and_injected():
    params = fold_gat_w_r(init_gat(jax.random.PRNGKey(5), DIMS), CFG)
    h, adj = random_inputs(50, 40, DIMS[0])
    _out, checks = gat_forward(params, h, adj, CFG)
    assert len(checks) == len(DIMS) - 1
    assert not any(bool(c.flag(CFG)) for c in checks)
    for target in range(len(DIMS) - 1):
        _out, checks = gat_forward(params, h, adj, CFG,
                                   inject_layer=target, inject_delta=7.0)
        flagged = [i for i, c in enumerate(checks) if bool(c.flag(CFG))]
        assert flagged == [target]


# ---------------------------------------------------------------------------
# (d) the guarded engine end-to-end
# ---------------------------------------------------------------------------

def test_engine_detects_and_repairs_injected_fault():
    eng = GATEngine.init(CFG, jax.random.PRNGKey(6), DIMS)
    h, adj = random_inputs(60, 40, DIMS[0])
    ref, m = eng.forward(h, adj)
    assert eng.guard.flags == 0
    assert m["abft_op_ids"] == tuple(f"gat{i}" for i in range(len(DIMS) - 1))
    for layer in range(len(DIMS) - 1):
        flags0, retries0 = eng.guard.flags, eng.guard.retries
        out, m = eng.forward(h, adj, inject_layer=layer, inject_delta=9.0)
        assert eng.guard.flags == flags0 + 1
        assert eng.guard.retries == retries0 + 1       # transient: retried
        np.testing.assert_array_equal(np.asarray(out), np.asarray(ref))
    stats = eng.stats()
    assert stats["flags"] == len(DIMS) - 1 and stats["restores"] == 0


def test_serve_step_per_op_verdicts():
    params = fold_gat_w_r(init_gat(jax.random.PRNGKey(7), DIMS), CFG)
    h, adj = random_inputs(70, 32, DIMS[0])
    step = make_gat_serve_step(CFG)
    _out, m = step(params, h, adj)
    assert m["abft_op_ids"] == ("gat0", "gat1", "gat2")
    assert not np.asarray(m["abft_op_flags"]).any()
    _out, m = step(params, h, adj, inject_layer=1, inject_delta=9.0)
    assert np.asarray(m["abft_op_flags"]).tolist() == [False, True, False]
