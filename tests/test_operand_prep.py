"""Edge cases of the kernel operand contract (ISSUE 4 satellite).

``prepare_operands`` / ``trim_output`` (spmm_abft) and
``prepare_fused_operands`` (gcn_fused) were only exercised implicitly
through full layer runs.  These pin the tricky paths down directly:

  * the row-TRIM path: when trailing column stripes of S hold no nonzero
    tiles, X/H rows beyond the last referenced stripe are dropped (sound:
    no stored tile can read them) — and the kernel result still matches
    the dense product;
  * non-lane-multiple feature dims padding up and trimming back;
  * trim_output round-trips through stripe and lane padding.
"""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.gcn_fused import gcn_fused_layer, prepare_fused_operands
from repro.kernels.spmm_abft import dense_to_block_ell, spmm_abft
from repro.kernels.spmm_abft.ops import (
    fit_rows,
    prepare_operands,
    trim_output,
)


def _bell_with_empty_tail_cols(n=96, block=32, seed=0):
    """S [n, n] whose nonzeros all sit in column block 0 — the trailing
    column stripes are empty, so padded_cols < n and the x operand TRIMS."""
    rng = np.random.default_rng(seed)
    s = np.zeros((n, n), np.float32)
    s[:, :block] = rng.random((n, block)).astype(np.float32) \
        * (rng.random((n, block)) < 0.3)
    bell = dense_to_block_ell(s, block_m=block, block_k=block)
    assert bell.padded_cols == block < n
    return s, bell


def test_fit_rows_pads_and_trims():
    x = jnp.arange(12, dtype=jnp.float32).reshape(6, 2)
    up = fit_rows(x, 9)
    assert up.shape == (9, 2)
    assert float(jnp.abs(up[6:]).max()) == 0.0
    down = fit_rows(x, 4)
    np.testing.assert_array_equal(np.asarray(down), np.asarray(x[:4]))
    same = fit_rows(x, 6)
    assert same.shape == (6, 2)


def test_prepare_operands_row_trim_path():
    s, bell = _bell_with_empty_tail_cols()
    n = s.shape[0]
    rng = np.random.default_rng(1)
    x = jnp.asarray(rng.normal(0, 0.5, size=(n, 8)).astype(np.float32))
    xp, xrp = prepare_operands(bell, x, None, block_g=32)
    # trimmed to exactly the referenced stripes, features padded to lanes
    assert xp.shape == (32, 32)
    assert xrp.shape == (32, 1)
    np.testing.assert_allclose(np.asarray(xp[:, :8]), np.asarray(x[:32]))
    # and the kernel math over the trimmed operand equals the dense product
    out, chk = spmm_abft(bell, x, block_g=32, interpret=True)
    np.testing.assert_allclose(np.asarray(out), s @ np.asarray(x),
                               atol=1e-5)
    assert abs(float(chk.predicted) - float(chk.actual)) < 1e-4


@pytest.mark.parametrize("g", [1, 7, 31, 33])
def test_non_lane_multiple_feature_dims(g):
    rng = np.random.default_rng(g)
    n = 64
    s = (rng.random((n, n)) < 0.1).astype(np.float32) * 0.5
    bell = dense_to_block_ell(s, block_m=32, block_k=32)
    x = jnp.asarray(rng.normal(0, 0.5, size=(n, g)).astype(np.float32))
    xp, _ = prepare_operands(bell, x, None, block_g=32)
    assert xp.shape[1] == -(-g // 32) * 32
    assert float(jnp.abs(xp[:, g:]).max(initial=0.0)) == 0.0
    out, _ = spmm_abft(bell, x, block_g=32, interpret=True)
    assert out.shape == (n, g)
    np.testing.assert_allclose(np.asarray(out), s @ np.asarray(x), atol=1e-5)


def test_trim_output_round_trip():
    rng = np.random.default_rng(2)
    n, g = 90, 5                      # n not a block multiple, g not lanes
    s = (rng.random((n, n)) < 0.15).astype(np.float32) * 0.3
    bell = dense_to_block_ell(s, block_m=32, block_k=32)
    padded = jnp.asarray(rng.normal(size=(bell.padded_rows, 32))
                         .astype(np.float32))
    trimmed = trim_output(bell, padded, g)
    assert trimmed.shape == (n, g)
    np.testing.assert_array_equal(np.asarray(trimmed),
                                  np.asarray(padded[:n, :g]))
    # full round-trip through the kernel: padded shapes in, logical out
    x = jnp.asarray(rng.normal(0, 0.5, size=(n, g)).astype(np.float32))
    out, _ = spmm_abft(bell, x, block_g=32, interpret=True)
    assert out.shape == (n, g)
    np.testing.assert_allclose(np.asarray(out), s @ np.asarray(x), atol=1e-5)


def test_prepare_fused_operands_contract():
    s, bell = _bell_with_empty_tail_cols()
    rng = np.random.default_rng(3)
    f, g = 10, 6
    h = jnp.asarray(rng.normal(size=(s.shape[0], f)).astype(np.float32))
    w = jnp.asarray(rng.normal(size=(f, g)).astype(np.float32))
    hp, wp, wrp = prepare_fused_operands(bell, h, w, None, block_g=32)
    assert hp.shape == (32, 32)        # rows trimmed, features padded
    assert wp.shape == (32, 32) and wrp.shape == (32, 1)
    assert float(jnp.abs(wrp).max(initial=0.0)) == 0.0   # check disabled
    assert float(jnp.abs(wp[f:]).max(initial=0.0)) == 0.0
    assert float(jnp.abs(wp[:, g:]).max(initial=0.0)) == 0.0
    # and the fused layer over the trimmed H equals the dense chain
    out, chk = gcn_fused_layer(bell, h, w, jnp.asarray(np.asarray(w)
                                                       .sum(axis=1)),
                               block_g=32, interpret=True)
    np.testing.assert_allclose(np.asarray(out),
                               s @ (np.asarray(h) @ np.asarray(w)),
                               atol=1e-5)
    assert abs(float(chk.predicted) - float(chk.actual)) < 1e-4
