"""Tentpole tests: sparse-adjacency GCN path with the fused ABFT check.

Three acceptance properties (ISSUE 1):
  (a) fused check_chain prediction == split-check composition on random
      matrix chains, within accumulation tolerance;
  (b) gcn_apply_sparse (BCOO aggregation) logits == dense gcn_apply on
      random graphs (atol 1e-4), clean runs unflagged in both;
  (c) a single injected fault in the SpMM output trips the fused check at
      the paper's Table I absolute thresholds (parity with core/fault.py's
      bit-flip model).

Runs WITHOUT hypothesis (seeded deterministic cases, so the acceptance
criteria hold on minimal installs); with hypothesis installed the same
properties are additionally fuzzed over shapes and seeds.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (
    ABFTConfig,
    check_chain,
    check_matmul,
    gcn_layer_fused_sparse,
    sparse_col_checksum,
)
from repro.core.datasets import make_reduced
from repro.core.fault import THRESHOLDS, flip_bit_f32
from repro.core.gcn import (
    dataset_to_dense,
    dataset_to_sparse,
    gcn_apply,
    gcn_apply_sparse,
    init_gcn,
    normalized_adjacency_bcoo,
    normalized_adjacency_dense,
    precompute_s_c,
)
from repro.kernels.spmm_abft import dense_to_block_ell, spmm_abft

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:          # minimal install: seeded tests below still run
    HAVE_HYPOTHESIS = False

CFG = ABFTConfig(mode="fused", threshold=1e-3, relative=True)


def random_chain(seed, dims, scale=1.0):
    rng = np.random.default_rng(seed)
    return [jnp.asarray(rng.normal(0, scale, size=(a, b)).astype(np.float32))
            for a, b in zip(dims[:-1], dims[1:])]


def random_graph(seed, n, avg_deg=4):
    """Distinct undirected ER edges (i<j) as an [m, 2] int array."""
    rng = np.random.default_rng(seed)
    m = n * avg_deg // 2
    e = rng.integers(0, n, size=(3 * m + 16, 2), dtype=np.int64)
    e = e[e[:, 0] != e[:, 1]]
    e = np.unique(np.sort(e, axis=1), axis=0)
    return e[:m]


# ---------------------------------------------------------------------------
# (a) fused chain check == split composition
# ---------------------------------------------------------------------------

def _chain_property(mats):
    out = mats[0]
    for m in mats[1:]:
        out = out @ m
    fused = check_chain(mats, out, CFG)
    # split composition: check the LAST multiply with its true left operand
    left = mats[0]
    for m in mats[1:-1]:
        left = left @ m
    split = check_matmul(left, mats[-1], out, CFG)
    ref = float(np.asarray(out, np.float64).sum())
    scale = max(1.0, abs(ref))
    assert abs(float(fused.predicted) - float(split.predicted)) / scale < 1e-4
    assert abs(float(fused.predicted) - ref) / scale < 1e-4
    assert abs(float(fused.actual) - float(split.actual)) < 1e-6 * scale


@pytest.mark.parametrize("seed,dims", [
    (0, (16, 8, 12)),
    (1, (64, 32, 16)),
    (2, (33, 7, 19, 5)),          # ragged 4-matrix chain
    (3, (128, 64, 64, 32, 8)),    # 5-matrix chain
])
def test_chain_equals_split_composition(seed, dims):
    _chain_property(random_chain(seed, dims, scale=0.3))


# ---------------------------------------------------------------------------
# (b) sparse == dense GCN forward
# ---------------------------------------------------------------------------

def _parity_property(seed, n, f, h, c, mode):
    edges = random_graph(seed, n)
    rng = np.random.default_rng(seed + 1)
    s_dense = jnp.asarray(normalized_adjacency_dense(edges, n))
    s_bcoo = normalized_adjacency_bcoo(edges, n)
    np.testing.assert_allclose(np.asarray(s_bcoo.todense()),
                               np.asarray(s_dense), atol=1e-7)
    h0 = jnp.asarray(rng.normal(0, 0.5, size=(n, f)).astype(np.float32))
    params = init_gcn(jax.random.PRNGKey(seed), (f, h, c))
    cfg = ABFTConfig(mode=mode, threshold=1e-3, relative=True)

    logits_d, rep_d = gcn_apply(params, s_dense, h0, cfg)
    s_c = precompute_s_c(s_bcoo, cfg) if cfg.enabled else None
    logits_s, rep_s = jax.jit(
        lambda p, s, x, sc: gcn_apply_sparse(p, s, x, cfg, sc)
    )(params, s_bcoo, h0, s_c)

    np.testing.assert_allclose(np.asarray(logits_s), np.asarray(logits_d),
                               atol=1e-4, rtol=1e-4)
    if cfg.enabled:
        assert not bool(rep_d.flag) and not bool(rep_s.flag), \
            (float(rep_d.max_rel), float(rep_s.max_rel))
        assert int(rep_s.n_checks) == int(rep_d.n_checks)


@pytest.mark.parametrize("mode", ["none", "split", "fused"])
@pytest.mark.parametrize("seed,n", [(0, 96), (7, 200), (13, 333)])
def test_sparse_matches_dense_gcn(seed, n, mode):
    _parity_property(seed, n, f=24, h=16, c=5, mode=mode)


def test_dataset_sparse_matches_dense():
    """End-to-end over the synthetic reduced Cora dataset (jit'd)."""
    ds = make_reduced("cora", scale=8, seed=0)
    s_np, h_np, _ = dataset_to_dense(ds)
    s_sp, h_sp, _ = dataset_to_sparse(ds)
    params = init_gcn(jax.random.PRNGKey(0), ds.stats.layer_dims)
    logits_d, _ = gcn_apply(params, jnp.asarray(s_np), jnp.asarray(h_np), CFG)
    s_c = precompute_s_c(s_sp, CFG)
    logits_s, rep = jax.jit(
        lambda p, s, x, sc: gcn_apply_sparse(p, s, x, CFG, sc)
    )(params, s_sp, h_sp, s_c)
    np.testing.assert_allclose(np.asarray(logits_s), np.asarray(logits_d),
                               atol=1e-4, rtol=1e-4)
    assert not bool(rep.flag)


def test_offline_s_c_matches_online():
    ds = make_reduced("citeseer", scale=8, seed=1)
    s_sp, _, _ = dataset_to_sparse(ds)
    offline = precompute_s_c(s_sp, CFG)
    online = sparse_col_checksum(s_sp, CFG.dtype)
    np.testing.assert_allclose(np.asarray(offline), np.asarray(online))
    # and both equal the numpy fault engine's f64 s_c within f32 tolerance
    np.testing.assert_allclose(np.asarray(offline, np.float64),
                               ds.s.col_sums(), atol=1e-5)


# ---------------------------------------------------------------------------
# (c) fault injection trips the fused check at Table I thresholds
# ---------------------------------------------------------------------------

def _spmm_fault_property(seed, threshold):
    """A single bit-flip-style corruption of the SpMM output must move the
    actual checksum away from the kernel's prediction by ≈ the injected
    delta (prefix-delta model, core/fault.py) — detected at |delta| > tau,
    with the clean divergence safely below tau."""
    rng = np.random.default_rng(seed)
    n = 160
    edges = random_graph(seed, n)
    s_dense = normalized_adjacency_dense(edges, n)
    bell = dense_to_block_ell(s_dense, block_m=32, block_k=32)
    x = rng.normal(0, 0.1, size=(n, 16)).astype(np.float32)

    out, chk = spmm_abft(bell, jnp.asarray(x), interpret=True, block_g=32)
    clean_div = abs(float(chk.predicted) - float(chk.actual))
    assert clean_div < threshold / 4, (clean_div, threshold)

    # corrupt one element the way the fault engine does: flip a high
    # exponent bit of an output value.  The element must not be tiny —
    # an exponent flip can SHRINK the value (delta ≈ -old), so |old| must
    # exceed the threshold for the fault to be detectable at all.
    out_np = np.asarray(out).copy()
    big = np.argwhere(np.abs(out_np) >= 1e-3)
    assert big.size, "graph too disconnected for a detectable fault site"
    i, j = big[int(rng.integers(len(big)))]
    old = out_np[i, j]
    new = flip_bit_f32(np.float32(old), 27)
    delta = float(new) - float(old)
    out_np[i, j] = new
    actual_bad = float(out_np.astype(np.float64).sum())
    div = abs(float(chk.predicted) - actual_bad)
    assert div > threshold, (div, delta, threshold)
    # and the divergence is the injected delta, modulo accumulation noise
    assert abs(div - abs(delta)) < max(1e-5 * abs(delta), threshold / 4)


@pytest.mark.parametrize("threshold", list(THRESHOLDS[:2]))   # 1e-4, 1e-5
@pytest.mark.parametrize("seed", [0, 5])
def test_spmm_fault_detected(seed, threshold):
    _spmm_fault_property(seed, threshold)


def test_small_fault_below_threshold_is_silent():
    """Deltas below tau stay silent — threshold semantics, not noise."""
    rng = np.random.default_rng(3)
    n = 128
    s_dense = normalized_adjacency_dense(random_graph(3, n), n)
    bell = dense_to_block_ell(s_dense, block_m=32, block_k=32)
    x = rng.normal(0, 0.1, size=(n, 16)).astype(np.float32)
    out, chk = spmm_abft(bell, jnp.asarray(x), interpret=True, block_g=32)
    out_np = np.asarray(out).astype(np.float64)
    out_np[5, 3] += 2e-5                       # below tau = 1e-4
    div = abs(float(chk.predicted) - float(out_np.sum()))
    assert div < 1e-4


def test_fused_sparse_layer_detects_fault():
    """Core-path (BCOO) fused layer check catches a corrupted H_out."""
    ds = make_reduced("cora", scale=8, seed=2)
    s_sp, h_sp, _ = dataset_to_sparse(ds)
    params = init_gcn(jax.random.PRNGKey(2), ds.stats.layer_dims)
    h_out, chk = gcn_layer_fused_sparse(s_sp, h_sp,
                                        params["layers"][0]["w"], CFG)
    bad = np.asarray(h_out).astype(np.float64)
    bad[11, 7] += 10.0 * max(float(np.abs(bad).max()), 1.0)
    div = abs(float(chk.predicted) - float(bad.sum()))
    assert div > 1e-4
    clean = abs(float(chk.predicted) - float(chk.actual))
    assert clean < 1e-4


# ---------------------------------------------------------------------------
# hypothesis fuzzing of the same properties (skipped on minimal installs)
# ---------------------------------------------------------------------------

if HAVE_HYPOTHESIS:

    @settings(max_examples=25, deadline=None)
    @given(st.integers(0, 2**31 - 1),
           st.lists(st.integers(4, 48), min_size=3, max_size=6))
    def test_chain_property_fuzz(seed, dims):
        _chain_property(random_chain(seed, dims, scale=0.3))

    @settings(max_examples=10, deadline=None)
    @given(st.integers(0, 2**31 - 1), st.integers(48, 160),
           st.sampled_from(["split", "fused"]))
    def test_sparse_dense_parity_fuzz(seed, n, mode):
        _parity_property(seed, n, f=12, h=8, c=4, mode=mode)

    @settings(max_examples=10, deadline=None)
    @given(st.integers(0, 2**31 - 1))
    def test_spmm_fault_fuzz(seed):
        _spmm_fault_property(seed, THRESHOLDS[0])
