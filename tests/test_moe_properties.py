"""Property tests for MoE routing/combine invariants + the fused combine
check (hypothesis over token counts, experts, top-k)."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

hypothesis = pytest.importorskip(
    "hypothesis", reason="property tests need hypothesis (requirements-dev.txt)")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.configs.base import ModelConfig, MoECfg
from repro.core.abft import ABFTConfig
from repro.models.moe import _capacity, init_moe, moe_block


def mk_cfg(n_experts, top_k, capf=8.0, shared=0):
    return ModelConfig(
        name="t", n_layers=1, d_model=32, n_heads=4, n_kv_heads=4,
        d_ff=48, vocab_size=64, dtype="float32",
        moe=MoECfg(n_experts=n_experts, top_k=top_k, d_ff_expert=16,
                   n_shared=shared, d_ff_shared=16,
                   capacity_factor=capf))


@settings(max_examples=12, deadline=None)
@given(n_experts=st.sampled_from([4, 8]),
       top_k=st.integers(1, 3),
       b=st.integers(1, 3),
       t=st.sampled_from([4, 8]),
       seed=st.integers(0, 50))
def test_moe_fused_check_clean(n_experts, top_k, b, t, seed):
    """On clean data, the fused combine checksum must agree."""
    cfg = mk_cfg(n_experts, top_k)
    abft = ABFTConfig(mode="fused", threshold=1e-2, relative=True)
    p = init_moe(jax.random.PRNGKey(seed), cfg)
    x = jax.random.normal(jax.random.PRNGKey(seed + 1), (b, t, cfg.d_model))
    y, checks, aux = moe_block(p, x, cfg, abft)
    assert y.shape == x.shape
    assert np.isfinite(np.asarray(y)).all()
    for c in checks:
        scale = max(1.0, abs(float(c.actual)))
        assert abs(float(c.predicted) - float(c.actual)) / scale < 1e-2


def test_moe_combine_detects_corruption():
    """Corrupting the combine output must trip the fused chain check."""
    cfg = mk_cfg(8, 2)
    abft = ABFTConfig(mode="fused", threshold=1e-3, relative=True)
    p = init_moe(jax.random.PRNGKey(0), cfg)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 8, cfg.d_model))
    y, checks, _ = moe_block(p, x, cfg, abft)
    # emulate an SDC on the combine output: actual checksum diverges
    combine_chk = checks[-1]
    bad_actual = combine_chk.actual + 50.0
    assert abs(float(combine_chk.predicted) - float(bad_actual)) > 10.0


@settings(max_examples=15, deadline=None)
@given(tokens=st.integers(1, 200), top_k=st.integers(1, 8),
       n_experts=st.sampled_from([8, 64, 128]),
       capf=st.floats(0.5, 4.0))
def test_capacity_bounds(tokens, top_k, n_experts, capf):
    cfg_moe = MoECfg(n_experts=n_experts, top_k=top_k, d_ff_expert=8,
                     capacity_factor=capf)
    cap = _capacity(tokens, cfg_moe)
    assert cap >= top_k                       # never below top_k
    assert cap * n_experts >= tokens * top_k * capf * 0.5  # sane sizing


def test_moe_dropless_equals_dense_sum():
    """With capacity ≥ all assignments, Y must equal the explicit per-token
    gated sum of expert outputs (routing correctness oracle)."""
    cfg = mk_cfg(4, 2, capf=64.0)
    p = init_moe(jax.random.PRNGKey(3), cfg)
    x = jax.random.normal(jax.random.PRNGKey(4), (1, 6, cfg.d_model))
    y, _, _ = moe_block(p, x, cfg, ABFTConfig(mode="none"))

    xt = x.reshape(-1, cfg.d_model)
    logits = xt @ p["router"]["w"]
    probs = jax.nn.softmax(logits, -1)
    gv, ge = jax.lax.top_k(probs, 2)
    gv = gv / gv.sum(-1, keepdims=True)
    ref = jnp.zeros_like(xt)
    for n in range(xt.shape[0]):
        acc = jnp.zeros((cfg.d_model,))
        for j in range(2):
            e = int(ge[n, j])
            up = xt[n] @ p["w_up"][e]
            gt = xt[n] @ p["w_gate"][e]
            z = (jax.nn.silu(gt) * up) @ p["w_down"][e]
            acc += gv[n, j] * z
        ref = ref.at[n].set(acc)
    np.testing.assert_allclose(np.asarray(y.reshape(-1, cfg.d_model)),
                               np.asarray(ref), rtol=2e-3, atol=2e-3)
