"""Stripe-granular fault localization + surgical retry (ISSUE 5 tentpole)
and the guard/fold correctness fixes that ride along.

Acceptance properties:
  (a) granularity plumbing: stripe corners sum (per graph / in total) to
      exactly the coarser corners, clean streams never flag at any
      granularity, and unsupported (backend, granularity) pairs raise;
  (b) fault-injection sweep: a single accumulator fault injected at every
      (layer, stripe, slot) of a packed batch flags exactly ONE stripe of
      exactly ONE graph, and the surgical retry's spliced output matches a
      clean run bit-for-bit;
  (c) guard escalation ladder: the stripe tier runs first and its repair
      is adopted; an unverifiable repair escalates to the per-graph tier
      and then to restore->replay; retry/rows accounting is exact;
  (d) satellite fixes: a folded w_r whose dtype no longer matches
      cfg.dtype raises (no silent stale-precision checks); a retry_fn
      returning full-batch-aligned vectors raises instead of being
      misattributed; guard.retries counts re-executions performed in BOTH
      run_step and run_step_graphs;
  (e) serve_gcn --check-granularity stripe serves with per-graph verdicts
      identical to graph granularity, and the sharded stripe path
      concatenates per-shard corners into the single-device vector.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.abft import (
    ABFTConfig,
    Check,
    per_graph_report,
    per_stripe_report,
)
from repro.core.gcn import init_gcn
from repro.engine import (
    Graph,
    fold_w_r,
    gcn_forward,
    make_backend,
    pack_graphs,
    synth_graph_stream,
)
from repro.engine.localize import surgical_stripe_retry
from repro.launch.serve_gcn import _packed_args, make_packed_serve_step
from repro.runtime import ABFTGuard, GuardConfig


def _stream(n_graphs=3, seed=1, feat=8, n_lo=32, n_hi=64):
    return synth_graph_stream(n_graphs, n_lo=n_lo, n_hi=n_hi, feat=feat,
                              seed=seed)


def _cfg(**kw):
    return ABFTConfig(mode="fused", threshold=1e-3, relative=True, **kw)


# ---------------------------------------------------------------------------
# (a) granularity plumbing
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("fused_layer", [False, True])
def test_stripe_corners_sum_to_graph_corners(fused_layer):
    stream = _stream(3)
    pb = pack_graphs(stream, block=16, stripe_multiple=4)
    params = init_gcn(jax.random.PRNGKey(0), (8, 8, 3))
    cfg = _cfg()
    g = Graph(s=pb, h0=jnp.asarray(pb.h0))

    bk_s = make_backend(pb, cfg, granularity="stripe",
                        fused_layer=fused_layer)
    logits_s, checks_s = gcn_forward(params, g, cfg, backend=bk_s)
    bk_g = make_backend(pb, cfg, fused_layer=fused_layer)
    logits_g, checks_g = gcn_forward(params, g, cfg, backend=bk_g)

    np.testing.assert_array_equal(np.asarray(logits_s), np.asarray(logits_g))
    nbm = pb.bell.n_block_rows
    seg = np.asarray(pb.stripe_graph)
    for c_s, c_g in zip(checks_s, checks_g):
        assert c_s.granularity == "stripe"
        assert c_g.granularity == "graph"
        assert c_s.actual.shape == (nbm,)
        for field in ("predicted", "actual"):
            per_graph = np.zeros(pb.n_slots + 1, np.float64)
            np.add.at(per_graph, seg, np.asarray(getattr(c_s, field),
                                                 np.float64))
            np.testing.assert_allclose(per_graph[:pb.n_slots],
                                       np.asarray(getattr(c_g, field)),
                                       rtol=1e-5, atol=1e-5)
    # clean stream: no stripe flags, and the segment-reduced per-graph
    # verdicts agree with the native graph-granularity report
    sflags, _ = per_stripe_report(checks_s, cfg, nbm)
    assert not bool(np.asarray(sflags).any())
    gf_s, _ = per_graph_report(checks_s, cfg, pb.n_slots,
                               segments=jnp.asarray(pb.stripe_graph))
    gf_g, _ = per_graph_report(checks_g, cfg, pb.n_slots)
    np.testing.assert_array_equal(np.asarray(gf_s), np.asarray(gf_g))


def test_split_mode_emits_stripe_corners_for_both_checks():
    stream = _stream(2, seed=3)
    pb = pack_graphs(stream, block=16)
    params = init_gcn(jax.random.PRNGKey(3), (8, 8, 3))
    cfg = ABFTConfig(mode="split", threshold=1e-3, relative=True)
    bk = make_backend(pb, cfg, granularity="stripe")
    _, checks = gcn_forward(params, Graph(s=pb, h0=jnp.asarray(pb.h0)),
                            cfg, backend=bk)
    assert len(checks) == 4                       # 2 layers x 2 checks
    nbm = pb.bell.n_block_rows
    assert all(c.actual.shape == (nbm,) for c in checks)
    sflags, _ = per_stripe_report(checks, cfg, nbm)
    assert sflags.shape == (4, nbm)
    assert not bool(np.asarray(sflags).any())


def test_unsupported_granularities_raise():
    stream = _stream(1)
    s, h0 = stream[0]
    cfg = _cfg()
    with pytest.raises(ValueError, match="block_ell kernel"):
        make_backend(jnp.asarray(s), cfg, backend="dense",
                     granularity="stripe")
    pb = pack_graphs(stream, block=16)
    with pytest.raises(ValueError, match="granularity"):
        make_backend(pb, cfg, granularity="layer")  # packed: graph|stripe
    with pytest.raises(ValueError, match="not in"):
        make_backend(pb, cfg, granularity="bogus")
    scalar = Check(predicted=jnp.float32(1.0), actual=jnp.float32(1.0))
    with pytest.raises(ValueError, match="stripe-granular"):
        per_stripe_report([scalar], cfg, 4)


def test_inject_validates_tuple_shape():
    # the hook now exists on BOTH kernels (fused and two-pass), so inject
    # no longer requires fused_layer — but a malformed tuple still raises
    pb = pack_graphs(_stream(1), block=16)
    with pytest.raises(ValueError, match="layer, stripe, slot, delta"):
        make_backend(pb, _cfg(), granularity="stripe", inject=(0, 0, 1.0))


def test_inject_fires_on_two_pass_path():
    """The accumulator hook on the two-pass spmm kernel: a fused_layer=False
    step must detect the injected fault at the right (layer, stripe) —
    VMEM-fallback layers stay injectable."""
    pb = pack_graphs(_stream(2, seed=11), block=16)
    cfg = _cfg()
    params = fold_w_r(init_gcn(jax.random.PRNGKey(11), (8, 8, 3)), cfg)
    step = make_packed_serve_step(params, cfg, pb.n_slots, block_g=16,
                                  granularity="stripe",
                                  inject=(1, 0, 0, 64.0))
    _, m = step(*_packed_args(pb))
    sf = np.asarray(m["abft_stripe_flags"])
    assert sf.sum() == 1 and sf[1, 0], np.argwhere(sf).tolist()


def test_per_graph_report_dispatches_on_granularity_not_shape():
    """A batch whose stripe count equals its slot count must NOT read
    stripe corners as per-graph verdicts: the fault would be attributed to
    the wrong graph and the corrupted one adopted as verified."""
    cfg = ABFTConfig(mode="fused", threshold=1e-3, relative=False)
    # 4 stripes, 4 slots; stripe 1 belongs to graph 0 (graphs own 2,1,1)
    seg = jnp.asarray(np.array([0, 0, 1, 2], np.int32))
    stripe_chk = Check(predicted=jnp.asarray([0.0, 9.0, 0.0, 0.0]),
                       actual=jnp.zeros(4), granularity="stripe")
    flags, _ = per_graph_report([stripe_chk], cfg, 4, segments=seg)
    np.testing.assert_array_equal(np.asarray(flags),
                                  [True, False, False, False])
    # without the segments map a stripe check is unattributable — raise,
    # never shape-match it into the per-graph branch
    with pytest.raises(ValueError, match="per-graph"):
        per_graph_report([stripe_chk], cfg, 4)


# ---------------------------------------------------------------------------
# (b) the fault sweep: exact localization + bit-for-bit surgical repair
# ---------------------------------------------------------------------------

def test_fault_sweep_localizes_and_repairs_bit_for_bit():
    """Inject a single accumulator fault at EVERY (layer, stripe, slot) of
    a packed batch: exactly one stripe of exactly one graph flags, and the
    surgical retry's spliced output equals a clean run bit-for-bit."""
    stream = _stream(2, seed=5, n_lo=20, n_hi=40)
    pb = pack_graphs(stream, block=16)
    cfg = _cfg()
    params = fold_w_r(init_gcn(jax.random.PRNGKey(5), (8, 8, 3)), cfg)
    args = _packed_args(pb)

    clean_step = make_packed_serve_step(params, cfg, pb.n_slots,
                                        block_g=16, fused_layer=True,
                                        granularity="stripe")
    logits_clean, m_clean = clean_step(*args)
    assert not bool(np.asarray(m_clean["abft_graph_flags"]).any())
    logits_clean = np.asarray(logits_clean)

    nbm, width = pb.bell.n_block_rows, pb.bell.width
    stripe_graph = np.asarray(pb.stripe_graph)
    n_layers = len(params["layers"])
    real = [s for s in range(nbm) if stripe_graph[s] < pb.n_slots]
    assert len(real) >= 3 and width >= 2
    last_layer_rows = []
    for layer in range(n_layers):
        for stripe in real:
            for slot in range(width):
                step = make_packed_serve_step(
                    params, cfg, pb.n_slots, block_g=16, fused_layer=True,
                    granularity="stripe",
                    inject=(layer, stripe, slot, 64.0))
                out_bad, m_bad = step(*args)
                sf = np.asarray(m_bad["abft_stripe_flags"])
                gf = np.asarray(m_bad["abft_graph_flags"])
                # exactly one stripe of exactly one graph flags, at the
                # injected (layer, stripe) — downstream layers see the
                # corruption CONSISTENTLY (their x_r is computed from the
                # same corrupted H), so their corners stay clean
                assert sf.sum() == 1 and sf[layer, stripe], \
                    (layer, stripe, slot, np.argwhere(sf).tolist())
                victim = int(stripe_graph[stripe])
                assert gf.sum() == 1 and gf[victim]
                repaired, sub = surgical_stripe_retry(
                    pb, params, cfg, out_bad, m_bad, block_g=16)
                assert not sub["abft_graph_flags"].any()
                assert np.array_equal(repaired, logits_clean), \
                    (layer, stripe, slot)
                assert sub["abft_rows_recomputed"] >= pb.block
                if layer == n_layers - 1:
                    last_layer_rows.append(sub["abft_rows_recomputed"])
    # a final-layer fault needs exactly one stripe re-executed
    assert all(r == pb.block for r in last_layer_rows)


def test_surgical_rows_strictly_below_graph_retry():
    """Every injection must cost the surgical tier strictly fewer
    re-executed rows than re-running the owning graph at every layer."""
    stream = _stream(2, seed=7, n_lo=36, n_hi=60)   # >= 2 stripes per graph
    pb = pack_graphs(stream, block=16)
    cfg = _cfg()
    params = fold_w_r(init_gcn(jax.random.PRNGKey(7), (8, 8, 3)), cfg)
    args = _packed_args(pb)
    stripe_graph = np.asarray(pb.stripe_graph)
    n_layers = len(params["layers"])
    for layer in range(n_layers):
        for stripe in (0, int(np.argwhere(stripe_graph == 1)[0, 0])):
            step = make_packed_serve_step(
                params, cfg, pb.n_slots, block_g=16, fused_layer=True,
                granularity="stripe", inject=(layer, stripe, 0, 64.0))
            out_bad, m_bad = step(*args)
            _, sub = surgical_stripe_retry(pb, params, cfg, out_bad, m_bad,
                                           block_g=16)
            victim = int(stripe_graph[stripe])
            graph_rows = int((stripe_graph == victim).sum()) * pb.block \
                * n_layers
            assert 0 < sub["abft_rows_recomputed"] < graph_rows, \
                (layer, stripe, sub["abft_rows_recomputed"], graph_rows)


# ---------------------------------------------------------------------------
# (c) guard escalation ladder
# ---------------------------------------------------------------------------

def _metrics(flag, gflags=None, sflags=None):
    m = {"abft_flag": flag, "abft_max_rel": 1.0 if flag else 0.0}
    if gflags is not None:
        m["abft_graph_flags"] = np.asarray(gflags, bool)
        m["abft_graph_max_rel"] = np.where(m["abft_graph_flags"], 1.0,
                                           0.0).astype(np.float32)
    if sflags is not None:
        m["abft_stripe_flags"] = np.asarray(sflags, bool)
    return m


def test_guard_stripe_tier_runs_first_and_adopts():
    calls = []

    def step():
        return np.zeros(3), _metrics(True, [False, True, False],
                                     [[False, True, False, False]])

    def sretry(out, metrics):
        calls.append("stripe")
        out = out.copy()
        out[1] = 5.0
        return out, {"abft_graph_flags": np.zeros(3, bool),
                     "abft_graph_max_rel": np.asarray([0, 1e-7, 0],
                                                      np.float32),
                     "abft_rows_recomputed": 16,
                     "abft_stripes_recomputed": 1}

    def retry(out, idx):
        calls.append("graph")
        return out, _metrics(False, np.zeros(len(idx), bool))

    g = ABFTGuard(GuardConfig(max_retries=2))
    out, m = g.run_step_graphs(step, retry, stripe_retry_fn=sretry)
    assert calls == ["stripe"]                     # graph tier never ran
    np.testing.assert_array_equal(out, [0.0, 5.0, 0.0])
    assert bool(m["abft_flag"]) is False
    assert not np.asarray(m["abft_stripe_flags"]).any()   # cleared on adopt
    assert "abft_stripe_max_rel" not in m   # discarded run's divergences
    assert float(m["abft_max_rel"]) < 1e-3
    assert g.retries == 1 and g.stripe_retries == 1
    assert g.recomputed_rows == 16 and g.graph_retries == 0


def test_guard_zero_work_escalation_counts_no_retry():
    """A surgical tier that bails before re-executing anything performed
    zero re-executions — guard.retries must not count the intent."""
    def step():
        return np.zeros(2), _metrics(True, [True, False], [[True, False]])

    def sretry(out, metrics):
        return out, {"abft_graph_flags": np.asarray([True, False]),
                     "abft_rows_recomputed": 0,
                     "abft_stripes_recomputed": 0}

    def retry(out, idx):
        return out, _metrics(False, np.zeros(len(idx), bool))

    g = ABFTGuard(GuardConfig(max_retries=2))
    g.run_step_graphs(step, retry, stripe_retry_fn=sretry)
    # only the graph-tier re-execution counted
    assert g.retries == 1 and g.stripe_retries == 0
    assert g.graph_retries == 1


def test_guard_stripe_tier_escalates_to_graph_then_restore():
    fault = {"on": True}
    calls = []

    def step():
        f = fault["on"]
        return np.zeros(2), _metrics(f, [f, False], [[f, False]])

    def sretry(out, metrics):
        calls.append("stripe")
        m = dict(metrics)
        return out, {"abft_graph_flags":
                     np.asarray(m["abft_graph_flags"], bool),
                     "abft_rows_recomputed": 16,
                     "abft_stripes_recomputed": 1}

    def retry(out, idx):
        calls.append("graph")
        return out, _metrics(True, [True] * len(idx))

    def restore():
        calls.append("restore")
        fault["on"] = False

    g = ABFTGuard(GuardConfig(max_retries=1), restore_fn=restore)
    out, m = g.run_step_graphs(step, retry, stripe_retry_fn=sretry)
    assert calls == ["stripe", "graph", "restore"]
    assert bool(np.asarray(m["abft_flag"]).any()) is False
    # accounting: one surgical attempt + one graph retry, both performed
    assert g.retries == 2 and g.stripe_retries == 1 and g.graph_retries == 1
    assert g.restores == 1


def test_guard_validates_retry_fn_shapes():
    def step():
        return np.zeros(4), _metrics(True, [False, True, False, True])

    def bad_retry(out, idx):
        # full-batch-aligned vector: would be misattributed if truncated
        return out, _metrics(False, np.zeros(4, bool))

    g = ABFTGuard(GuardConfig(max_retries=1))
    with pytest.raises(ValueError, match="aligned to"):
        g.run_step_graphs(step, bad_retry)

    def bad_rel_retry(out, idx):
        m = _metrics(False, np.zeros(len(idx), bool))
        m["abft_graph_max_rel"] = np.zeros(4, np.float32)     # full batch
        return out, m

    g2 = ABFTGuard(GuardConfig(max_retries=1))
    with pytest.raises(ValueError, match="abft_graph_max_rel"):
        g2.run_step_graphs(step, bad_rel_retry)

    def bad_sretry(out, metrics):
        return out, {"abft_graph_flags": np.zeros(1, bool)}   # wrong shape

    def step_s():
        return np.zeros(2), _metrics(True, [True, False], [[True, False]])

    g3 = ABFTGuard(GuardConfig(max_retries=1))
    with pytest.raises(ValueError, match="FULL batch"):
        g3.run_step_graphs(step_s, bad_retry, stripe_retry_fn=bad_sretry)


def test_guard_retries_count_reexecutions_in_both_paths():
    """satellite: guard.retries means re-executions PERFORMED, identically
    for run_step and run_step_graphs."""
    # run_step: flagged twice, clean on the 3rd execution -> 2 re-executions
    n_calls = {"n": 0}

    def step(state):
        n_calls["n"] += 1
        return state, _metrics(n_calls["n"] < 3)

    g = ABFTGuard(GuardConfig(max_retries=2))
    g.run_step(step, 0)
    assert n_calls["n"] == 3
    assert g.retries == n_calls["n"] - 1          # first call is not a retry

    # run_step: flagged at the final attempt -> every re-execution counted,
    # the restore replay counted under restores, not retries
    g2 = ABFTGuard(GuardConfig(max_retries=2),
                   restore_fn=lambda: None)
    n2 = {"n": 0}

    def step2(state):
        n2["n"] += 1
        return state, _metrics(n2["n"] < 4)       # heals only on replay

    g2.run_step(step2, 0)
    assert n2["n"] == 4
    assert g2.retries == 2 and g2.restores == 1

    # run_step_graphs: one partial re-execution
    def gstep():
        return np.zeros(2), _metrics(True, [True, False])

    def gretry(out, idx):
        return out, _metrics(False, np.zeros(len(idx), bool))

    g3 = ABFTGuard(GuardConfig(max_retries=2))
    g3.run_step_graphs(gstep, gretry)
    assert g3.retries == 1 and g3.graph_retries == 1


# ---------------------------------------------------------------------------
# (d) folded w_r dtype validation (satellite)
# ---------------------------------------------------------------------------

def test_stale_w_r_dtype_raises():
    stream = _stream(1, seed=9)
    s, h0 = stream[0]
    params = init_gcn(jax.random.PRNGKey(9), (8, 8, 3))
    cfg16 = ABFTConfig(mode="fused", dtype=jnp.float16)
    folded16 = fold_w_r(params, cfg16)
    assert folded16["layers"][0]["w_r"].dtype == jnp.float16
    g = Graph(s=jnp.asarray(s), h0=jnp.asarray(h0))
    # consuming the f16 fold under an f32 config must raise, not silently
    # run the checks at the stale precision
    with pytest.raises(ValueError, match="fold_w_r"):
        gcn_forward(params | {"layers": folded16["layers"]}, g, _cfg())
    # re-folding at the new dtype heals it
    refolded = fold_w_r(params, _cfg())
    logits, _ = gcn_forward(refolded, g, _cfg())
    ref, _ = gcn_forward(params, g, _cfg())
    np.testing.assert_array_equal(np.asarray(logits), np.asarray(ref))


def test_w_r_dtype_respects_x64_canonicalization():
    # a requested f64 checksum realizes as f32 when x64 is disabled; the
    # validation must compare realized dtypes, not requested ones
    stream = _stream(1, seed=11)
    s, h0 = stream[0]
    params = init_gcn(jax.random.PRNGKey(11), (8, 8, 3))
    cfg64 = ABFTConfig(mode="fused", dtype=jnp.float64)
    folded = fold_w_r(params, cfg64)
    g = Graph(s=jnp.asarray(s), h0=jnp.asarray(h0))
    logits, _ = gcn_forward(folded, g, cfg64)     # must not raise
    assert np.asarray(logits).shape == (s.shape[0], 3)


# ---------------------------------------------------------------------------
# (e) serving + sharding at stripe granularity
# ---------------------------------------------------------------------------

def test_serve_stripe_granularity_matches_graph():
    from repro.engine import make_batches, make_packed_batches
    from repro.launch.serve_gcn import serve

    stream = _stream(8, seed=4, feat=12, n_lo=16, n_hi=60)
    params = init_gcn(jax.random.PRNGKey(4), (12, 8, 3))
    cfg = _cfg()
    batches = make_packed_batches(stream, 4, block=16, stripe_multiple=4,
                                  width_multiple=2)
    by_graph = serve(batches, params, cfg, verbose=False)
    by_stripe = serve(batches, params, cfg, verbose=False,
                      granularity="stripe")
    fused_stripe = serve(batches, params, cfg, verbose=False,
                         granularity="stripe", fused_layer=True)
    assert by_graph["graphs"] == by_stripe["graphs"] == 8
    np.testing.assert_array_equal(by_graph["graph_flags"],
                                  by_stripe["graph_flags"])
    # stripe rel divergences normalize by per-stripe scales, so the values
    # differ from graph granularity only at the f32 rounding floor
    np.testing.assert_allclose(by_graph["graph_max_rel"],
                               by_stripe["graph_max_rel"], atol=1e-5)
    np.testing.assert_array_equal(by_graph["graph_flags"],
                                  fused_stripe["graph_flags"])
    # dense batches cannot do stripes
    with pytest.raises(ValueError, match="row-stripes"):
        serve(make_batches(stream, 4, [64]), params, cfg, verbose=False,
              granularity="stripe")


def test_serve_gcn_driver_stripe_smoke(capsys):
    from repro.launch.serve_gcn import main

    stats = main(["--graphs", "6", "--batch", "3", "--backend", "block_ell",
                  "--block", "16", "--nodes", "16,48", "--feat", "8",
                  "--hidden", "8", "--classes", "3",
                  "--check-granularity", "stripe", "--fused-layer"])
    assert stats["graphs"] == 6
    assert stats["flags"] == 0 and not stats["graph_flags"].any()
    assert stats["stripe_retries"] == 0 and stats["recomputed_rows"] == 0
    assert "[stripe corners]" in capsys.readouterr().out


def test_sharded_stripe_corners_concatenate():
    """Stripe granularity composes with the stripe-sharded path: per-shard
    partials concatenate (not psum) into exactly the single-device
    per-stripe corners.  Runs on however many host devices exist (1 is
    fine — shard_map still exercises the concat out_specs)."""
    from repro.engine import Partition
    from repro.kernels.spmm_abft import dense_to_block_ell
    from repro.launch.mesh import make_graph_mesh

    stream = _stream(1, seed=13, n_lo=60, n_hi=60)
    s, h0 = stream[0]
    bell = dense_to_block_ell(s, block_m=16, block_k=16)
    cfg = _cfg()
    n_dev = len(jax.devices())
    part = Partition(make_graph_mesh(n_dev), "graph")
    h0 = jnp.asarray(h0)
    w = np.random.default_rng(13).normal(0, 0.3, (8, 8)).astype(np.float32)
    x = h0 @ jnp.asarray(w)
    x_r = h0 @ jnp.asarray(w.sum(axis=1))

    bk_1 = make_backend(bell, cfg, backend="block_ell", block_g=16,
                        granularity="stripe")
    out_1, chk_1 = bk_1.aggregate(x, x_r)
    bk_n = make_backend(bell, cfg, backend="block_ell", block_g=16,
                        granularity="stripe", partition=part)
    out_n, chk_n = bk_n.aggregate(x, x_r)
    assert chk_n.granularity == "stripe"
    nbm_padded = bk_n.vals.shape[0]
    assert chk_n.actual.shape == (nbm_padded,)
    np.testing.assert_allclose(np.asarray(out_n), np.asarray(out_1),
                               atol=1e-5)
    nbm = bell.n_block_rows
    np.testing.assert_allclose(np.asarray(chk_n.actual)[:nbm],
                               np.asarray(chk_1.actual), rtol=1e-6)
    np.testing.assert_allclose(np.asarray(chk_n.predicted)[:nbm],
                               np.asarray(chk_1.predicted), rtol=1e-6)
    # padding stripes (shard-divisibility) compare 0 = 0
    assert np.abs(np.asarray(chk_n.actual)[nbm:]).max(initial=0.0) == 0.0
