"""Pallas kernel validation: interpret=True (CPU) against the pure-jnp
oracles, swept over shapes and dtypes.  TPU is the compile target; interpret
mode executes the same kernel body for correctness."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.abft import ABFTConfig
from repro.kernels.matmul_abft.ops import matmul_abft
from repro.kernels.matmul_abft.ref import matmul_abft_ref
from repro.kernels.flash_checksum.ops import flash_attention_checksum
from repro.kernels.flash_checksum.ref import flash_checksum_ref
from repro.kernels.spmm_abft.layout import coo_to_block_ell, dense_to_block_ell
from repro.kernels.spmm_abft.ops import (
    gcn_layer_fused_sparse_kernel,
    spmm_abft,
)
from repro.kernels.spmm_abft.ref import spmm_abft_ref

CFG = ABFTConfig(mode="fused", threshold=1e-2, relative=True)


def rnd(key, shape, dtype):
    x = jax.random.normal(jax.random.PRNGKey(key), shape, jnp.float32)
    return x.astype(dtype)


# ---------------------------------------------------------------------------
# matmul_abft
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("m,k,n", [
    (128, 128, 128),
    (256, 384, 128),
    (200, 100, 72),      # padding path
    (128, 512, 256),
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_matmul_abft_matches_ref(m, k, n, dtype):
    a = rnd(m * 7 + 1, (m, k), dtype)
    b = rnd(n * 13 + 2, (k, n), dtype)
    c, chk = matmul_abft(a, b, block_m=128, block_n=128, block_k=128,
                         interpret=True)
    c_ref, actual_ref, _ = matmul_abft_ref(a, b,
                                           b.astype(jnp.float32).sum(1, keepdims=True))
    tol = 2e-2 if dtype == jnp.bfloat16 else 2e-5
    np.testing.assert_allclose(np.asarray(c, np.float32),
                               np.asarray(c_ref, np.float32),
                               rtol=tol, atol=tol * 8)
    # checksum consistency: predicted ≈ actual on clean data
    rel = abs(float(chk.predicted) - float(chk.actual)) / \
        max(1.0, abs(float(chk.actual)))
    assert rel < (5e-2 if dtype == jnp.bfloat16 else 1e-4), rel
    assert not bool(chk.flag(ABFTConfig(mode="fused", threshold=0.2,
                                        relative=True)))


def test_matmul_abft_detects_corruption():
    """The kernel check must catch output corruption: emulate by comparing
    a corrupted C's true sum against the kernel's predicted checksum."""
    a = rnd(3, (128, 128), jnp.float32)
    b = rnd(4, (128, 128), jnp.float32)
    c, chk = matmul_abft(a, b, interpret=True)
    c_bad = c.at[7, 9].add(100.0)
    diff = abs(float(chk.predicted) - float(c_bad.sum()))
    assert diff > 50.0


# ---------------------------------------------------------------------------
# flash_checksum
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("b,h,kh,t,s,dh", [
    (1, 4, 4, 128, 128, 64),
    (2, 4, 2, 128, 256, 64),     # GQA
    (1, 4, 1, 256, 256, 128),    # MQA
    (1, 2, 2, 100, 128, 64),     # q padding path
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_checksum_matches_ref(b, h, kh, t, s, dh, dtype):
    q = rnd(1, (b, t, h, dh), dtype)
    k = rnd(2, (b, s, kh, dh), dtype)
    v = rnd(3, (b, s, kh, dh), dtype)
    w_or = rnd(4, (h, dh), jnp.float32)

    o, ex = flash_attention_checksum(q, k, v, w_or, causal=True,
                                     block_q=128, block_k=128, interpret=True)
    g = h // kh
    k_e = jnp.repeat(k, g, axis=2).transpose(0, 2, 1, 3).reshape(b * h, s, dh)
    v_e = jnp.repeat(v, g, axis=2).transpose(0, 2, 1, 3).reshape(b * h, s, dh)
    qf = q.transpose(0, 2, 1, 3).reshape(b * h, t, dh)
    vr = jnp.einsum("nsd,nd->ns",
                    v_e.astype(jnp.float32),
                    jnp.tile(w_or, (b, 1)).reshape(b * h, dh))[..., None]
    o_ref, ex_ref = flash_checksum_ref(qf, k_e, v_e, vr.astype(dtype),
                                       causal=True)
    o_ref = o_ref.reshape(b, h, t, dh).transpose(0, 2, 1, 3)
    ex_ref = ex_ref[..., 0].reshape(b, h, t).transpose(0, 2, 1)

    tol = 3e-2 if dtype == jnp.bfloat16 else 2e-4
    np.testing.assert_allclose(np.asarray(o, np.float32),
                               np.asarray(o_ref, np.float32),
                               rtol=tol, atol=tol * 4)
    np.testing.assert_allclose(np.asarray(ex), np.asarray(ex_ref),
                               rtol=tol * 2, atol=tol * 8)


def test_flash_checksum_equals_chain_identity():
    """Σ o_extra must equal eᵀ(A·V·W_o)e computed the slow way."""
    b, h, t, dh, d = 1, 2, 128, 64, 96
    q = rnd(11, (b, t, h, dh), jnp.float32)
    k = rnd(12, (b, t, h, dh), jnp.float32)
    v = rnd(13, (b, t, h, dh), jnp.float32)
    wo = rnd(14, (h * dh, d), jnp.float32)
    w_or = wo.sum(axis=1).reshape(h, dh)

    o, ex = flash_attention_checksum(q, k, v, w_or, causal=True,
                                     interpret=True)
    out = o.reshape(b, t, h * dh) @ wo
    np.testing.assert_allclose(float(ex.sum()), float(out.sum()),
                               rtol=1e-4)


# ---------------------------------------------------------------------------
# spmm_abft (block-ELL sparse aggregation)
# ---------------------------------------------------------------------------

def sparse_rnd(key, m, k, density, scale=0.2):
    rng = np.random.default_rng(key)
    dense = np.where(rng.random((m, k)) < density,
                     rng.normal(0, scale, size=(m, k)), 0.0)
    return dense.astype(np.float32)


@pytest.mark.parametrize("m,k,g,bm,bk,density", [
    (128, 128, 128, 32, 32, 0.10),
    (256, 256, 64, 64, 64, 0.05),
    (100, 100, 20, 32, 32, 0.08),     # ragged rows/cols/features (padding)
    (200, 130, 7, 64, 32, 0.15),      # rectangular + ragged everything
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_spmm_abft_matches_ref(m, k, g, bm, bk, density, dtype):
    dense = sparse_rnd(m * 3 + k, m, k, density)
    bell = coo_to_block_ell(*np.nonzero(dense), dense[np.nonzero(dense)],
                            (m, k), block_m=bm, block_k=bk)
    np.testing.assert_allclose(bell.todense(), dense)
    x = rnd(g * 11 + 5, (k, g), dtype)
    xr = x.astype(jnp.float32).sum(axis=1, keepdims=True)

    out, chk = spmm_abft(bell, x, interpret=True, block_g=bm)
    out_ref, actual_ref, extra_ref = spmm_abft_ref(jnp.asarray(dense), x, xr)

    tol = 2e-2 if dtype == jnp.bfloat16 else 2e-5
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(out_ref, np.float32),
                               rtol=tol, atol=tol * 8)
    scale = max(1.0, abs(float(actual_ref)))
    assert abs(float(chk.actual) - float(actual_ref)) < tol * scale
    assert abs(float(chk.predicted) - float(extra_ref.sum())) < tol * scale
    # checksum consistency on clean data
    rel = abs(float(chk.predicted) - float(chk.actual)) / scale
    assert rel < (5e-2 if dtype == jnp.bfloat16 else 1e-4), rel
    assert not bool(chk.flag(ABFTConfig(mode="fused", threshold=0.2,
                                        relative=True)))


def test_spmm_abft_detects_corruption():
    dense = sparse_rnd(42, 128, 128, 0.1)
    bell = dense_to_block_ell(dense, block_m=32, block_k=32)
    x = rnd(6, (128, 16), jnp.float32)
    out, chk = spmm_abft(bell, x, interpret=True, block_g=32)
    bad = out.at[17, 3].add(100.0)
    diff = abs(float(chk.predicted) - float(bad.sum()))
    assert diff > 50.0


def test_spmm_abft_carried_column_chain():
    """Threading x_r = H w_r through the kernel yields the eq.-4 chain
    prediction s_c H w_r — the full fused GCN-ABFT layer check."""
    n, f, g = 160, 24, 16
    dense = sparse_rnd(7, n, n, 0.07)
    bell = dense_to_block_ell(dense, block_m=32, block_k=32)
    h = rnd(8, (n, f), jnp.float32) * 0.3
    w = rnd(9, (f, g), jnp.float32)

    h_out, chk = gcn_layer_fused_sparse_kernel(bell, h, w, interpret=True,
                                               block_g=32)
    ref = dense @ np.asarray(h @ w)
    np.testing.assert_allclose(np.asarray(h_out), ref, rtol=1e-5, atol=1e-5)
    s_c = dense.astype(np.float64).sum(axis=0)
    w_r = np.asarray(w, np.float64).sum(axis=1)
    pred_ref = float(s_c @ (np.asarray(h, np.float64) @ w_r))
    scale = max(1.0, abs(pred_ref))
    assert abs(float(chk.predicted) - pred_ref) / scale < 1e-5
    assert abs(float(chk.actual) - ref.sum()) / scale < 1e-4


def test_spmm_abft_empty_trailing_column_block():
    """All nonzeros in the leading columns: padded_cols < K, so ops must
    TRIM x instead of padding it (regression: negative jnp.pad widths)."""
    dense = np.zeros((64, 64), np.float32)
    dense[:, :30] = sparse_rnd(11, 64, 30, 0.3)
    bell = dense_to_block_ell(dense, block_m=32, block_k=32)
    assert bell.padded_cols < 64
    x = rnd(12, (64, 8), jnp.float32)
    out, chk = spmm_abft(bell, x, interpret=True, block_g=32)
    np.testing.assert_allclose(np.asarray(out), dense @ np.asarray(x),
                               rtol=1e-5, atol=1e-5)
    rel = abs(float(chk.predicted) - float(chk.actual)) / \
        max(1.0, abs(float(chk.actual)))
    assert rel < 1e-4
