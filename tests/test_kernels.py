"""Pallas kernel validation: interpret=True (CPU) against the pure-jnp
oracles, swept over shapes and dtypes.  TPU is the compile target; interpret
mode executes the same kernel body for correctness."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.abft import ABFTConfig
from repro.kernels.matmul_abft.ops import matmul_abft
from repro.kernels.matmul_abft.ref import matmul_abft_ref
from repro.kernels.flash_checksum.ops import flash_attention_checksum
from repro.kernels.flash_checksum.ref import flash_checksum_ref

CFG = ABFTConfig(mode="fused", threshold=1e-2, relative=True)


def rnd(key, shape, dtype):
    x = jax.random.normal(jax.random.PRNGKey(key), shape, jnp.float32)
    return x.astype(dtype)


# ---------------------------------------------------------------------------
# matmul_abft
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("m,k,n", [
    (128, 128, 128),
    (256, 384, 128),
    (200, 100, 72),      # padding path
    (128, 512, 256),
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_matmul_abft_matches_ref(m, k, n, dtype):
    a = rnd(m * 7 + 1, (m, k), dtype)
    b = rnd(n * 13 + 2, (k, n), dtype)
    c, chk = matmul_abft(a, b, block_m=128, block_n=128, block_k=128,
                         interpret=True)
    c_ref, actual_ref, _ = matmul_abft_ref(a, b,
                                           b.astype(jnp.float32).sum(1, keepdims=True))
    tol = 2e-2 if dtype == jnp.bfloat16 else 2e-5
    np.testing.assert_allclose(np.asarray(c, np.float32),
                               np.asarray(c_ref, np.float32),
                               rtol=tol, atol=tol * 8)
    # checksum consistency: predicted ≈ actual on clean data
    rel = abs(float(chk.predicted) - float(chk.actual)) / \
        max(1.0, abs(float(chk.actual)))
    assert rel < (5e-2 if dtype == jnp.bfloat16 else 1e-4), rel
    assert not bool(chk.flag(ABFTConfig(mode="fused", threshold=0.2,
                                        relative=True)))


def test_matmul_abft_detects_corruption():
    """The kernel check must catch output corruption: emulate by comparing
    a corrupted C's true sum against the kernel's predicted checksum."""
    a = rnd(3, (128, 128), jnp.float32)
    b = rnd(4, (128, 128), jnp.float32)
    c, chk = matmul_abft(a, b, interpret=True)
    c_bad = c.at[7, 9].add(100.0)
    diff = abs(float(chk.predicted) - float(c_bad.sum()))
    assert diff > 50.0


# ---------------------------------------------------------------------------
# flash_checksum
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("b,h,kh,t,s,dh", [
    (1, 4, 4, 128, 128, 64),
    (2, 4, 2, 128, 256, 64),     # GQA
    (1, 4, 1, 256, 256, 128),    # MQA
    (1, 2, 2, 100, 128, 64),     # q padding path
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_checksum_matches_ref(b, h, kh, t, s, dh, dtype):
    q = rnd(1, (b, t, h, dh), dtype)
    k = rnd(2, (b, s, kh, dh), dtype)
    v = rnd(3, (b, s, kh, dh), dtype)
    w_or = rnd(4, (h, dh), jnp.float32)

    o, ex = flash_attention_checksum(q, k, v, w_or, causal=True,
                                     block_q=128, block_k=128, interpret=True)
    g = h // kh
    k_e = jnp.repeat(k, g, axis=2).transpose(0, 2, 1, 3).reshape(b * h, s, dh)
    v_e = jnp.repeat(v, g, axis=2).transpose(0, 2, 1, 3).reshape(b * h, s, dh)
    qf = q.transpose(0, 2, 1, 3).reshape(b * h, t, dh)
    vr = jnp.einsum("nsd,nd->ns",
                    v_e.astype(jnp.float32),
                    jnp.tile(w_or, (b, 1)).reshape(b * h, dh))[..., None]
    o_ref, ex_ref = flash_checksum_ref(qf, k_e, v_e, vr.astype(dtype),
                                       causal=True)
    o_ref = o_ref.reshape(b, h, t, dh).transpose(0, 2, 1, 3)
    ex_ref = ex_ref[..., 0].reshape(b, h, t).transpose(0, 2, 1)

    tol = 3e-2 if dtype == jnp.bfloat16 else 2e-4
    np.testing.assert_allclose(np.asarray(o, np.float32),
                               np.asarray(o_ref, np.float32),
                               rtol=tol, atol=tol * 4)
    np.testing.assert_allclose(np.asarray(ex), np.asarray(ex_ref),
                               rtol=tol * 2, atol=tol * 8)


def test_flash_checksum_equals_chain_identity():
    """Σ o_extra must equal eᵀ(A·V·W_o)e computed the slow way."""
    b, h, t, dh, d = 1, 2, 128, 64, 96
    q = rnd(11, (b, t, h, dh), jnp.float32)
    k = rnd(12, (b, t, h, dh), jnp.float32)
    v = rnd(13, (b, t, h, dh), jnp.float32)
    wo = rnd(14, (h * dh, d), jnp.float32)
    w_or = wo.sum(axis=1).reshape(h, dh)

    o, ex = flash_attention_checksum(q, k, v, w_or, causal=True,
                                     interpret=True)
    out = o.reshape(b, t, h * dh) @ wo
    np.testing.assert_allclose(float(ex.sum()), float(out.sum()),
                               rtol=1e-4)
