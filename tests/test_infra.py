"""Checkpoint / optimizer / data / runtime substrate tests."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

hypothesis = pytest.importorskip(
    "hypothesis", reason="property tests need hypothesis (requirements-dev.txt)")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.checkpoint import CheckpointManager, load_checkpoint, save_checkpoint
from repro.data.synthetic import SyntheticLM
from repro.optim import (
    AdamWConfig,
    adamw_init,
    adamw_update,
    clip_by_global_norm,
    compress_int8,
    cosine_warmup,
    decompress_int8,
    ef_compress_grads,
    global_norm,
)
from repro.runtime import ABFTGuard, StragglerWatchdog


# ---------------------------------------------------------------------------
# checkpoint
# ---------------------------------------------------------------------------

def _tree(seed=0):
    rng = np.random.default_rng(seed)
    return {"a": jnp.asarray(rng.normal(size=(4, 8)), jnp.float32),
            "b": [jnp.asarray(rng.normal(size=(3,)), jnp.float32),
                  {"c": jnp.asarray(7, jnp.int32)}]}


def test_checkpoint_roundtrip(tmp_path):
    tree = _tree()
    save_checkpoint(str(tmp_path), 5, tree)
    restored, step = load_checkpoint(str(tmp_path), tree)
    assert step == 5
    for x, y in zip(jax.tree.leaves(tree), jax.tree.leaves(restored)):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))


def test_checkpoint_manager_retention_and_latest(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=2, async_write=False)
    tree = _tree()
    for s in (1, 2, 3):
        mgr.save(s, tree)
    dirs = sorted(d for d in os.listdir(tmp_path) if d.startswith("step_"))
    assert dirs == ["step_00000002", "step_00000003"]
    restored, step = mgr.restore(tree)
    assert step == 3


def test_checkpoint_async(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=2, async_write=True)
    mgr.save(1, _tree())
    mgr.wait()
    _, step = mgr.restore(_tree())
    assert step == 1


def test_elastic_reshard_restore(tmp_path):
    from repro.checkpoint import reshard_restore
    tree = _tree()
    save_checkpoint(str(tmp_path), 9, tree)
    shardings = jax.tree.map(lambda _: None, tree)
    restored, step = reshard_restore(str(tmp_path), tree, shardings)
    assert step == 9


# ---------------------------------------------------------------------------
# optimizer
# ---------------------------------------------------------------------------

def test_adamw_reduces_quadratic():
    w = {"w": jnp.asarray([3.0, -2.0])}
    state = adamw_init(w)
    cfg = AdamWConfig(lr=0.1, weight_decay=0.0)
    for _ in range(150):
        g = jax.grad(lambda p: jnp.sum(p["w"] ** 2))(w)
        w, state = adamw_update(w, g, state, cfg, 1.0)
    assert float(jnp.abs(w["w"]).max()) < 0.05


def test_clip_by_global_norm():
    g = {"a": jnp.full((10,), 10.0)}
    clipped, gn = clip_by_global_norm(g, 1.0)
    assert float(global_norm(clipped)) <= 1.0 + 1e-5
    assert float(gn) > 30


def test_cosine_warmup_monotone_then_decay():
    import numpy as np
    xs = [float(cosine_warmup(jnp.asarray(s), 10, 100)) for s in range(0, 100, 5)]
    assert xs[0] < xs[1] <= 1.0
    assert xs[-1] < xs[3]


@settings(max_examples=20, deadline=None)
@given(scale=st.floats(1e-3, 1e3), seed=st.integers(0, 100))
def test_int8_compression_bounded_error(scale, seed):
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.normal(size=(64,)) * scale, jnp.float32)
    q, s = compress_int8(x)
    err = jnp.abs(decompress_int8(q, s) - x).max()
    assert float(err) <= float(s) * 0.5 + 1e-6


def test_error_feedback_preserves_mass():
    """Error feedback: compressed + residual == original (exactly)."""
    g = {"w": jnp.asarray([0.1, -0.25, 3.0], jnp.float32)}
    ef = {"w": jnp.zeros(3, jnp.float32)}
    deq, ef2 = ef_compress_grads(g, ef)
    np.testing.assert_allclose(np.asarray(deq["w"]) + np.asarray(ef2["w"]),
                               np.asarray(g["w"]), rtol=1e-6)


# ---------------------------------------------------------------------------
# data
# ---------------------------------------------------------------------------

def test_synthetic_lm_deterministic_and_learnable():
    d1 = SyntheticLM(vocab_size=64, seq_len=32, batch_size=4, seed=1)
    d2 = SyntheticLM(vocab_size=64, seq_len=32, batch_size=4, seed=1)
    b1, b2 = next(d1.batches()), next(d2.batches())
    np.testing.assert_array_equal(b1["tokens"], b2["tokens"])
    # labels are next-token shifted
    np.testing.assert_array_equal(b1["tokens"][:, 1:], b1["labels"][:, :-1])
    # structure: successor function fires often
    succ = d1._succ
    hits = (succ[b1["tokens"][:, :-1]] == b1["tokens"][:, 1:]).mean()
    assert hits > 0.5


def test_synthetic_lm_host_sharding_differs():
    d = SyntheticLM(vocab_size=64, seq_len=16, batch_size=2, seed=1)
    b0 = next(d.batches(host_id=0))
    b1 = next(d.batches(host_id=1))
    assert not np.array_equal(b0["tokens"], b1["tokens"])


# ---------------------------------------------------------------------------
# runtime
# ---------------------------------------------------------------------------

def test_abft_guard_retry_then_restore():
    calls = {"n": 0}

    def flaky_step(state):
        calls["n"] += 1
        flagged = calls["n"] <= 2
        return state + 1, {"abft_flag": flagged, "abft_max_rel": 0.5}

    g = ABFTGuard()
    out, m = g.run_step(flaky_step, 0)
    assert out == 1 and calls["n"] == 3      # two retries then success

    # persistent flag: restore must be followed by a verified replay —
    # the guard adopts the replayed step's output, not the failed attempt's
    fault = {"on": True}

    def bad_until_restore(state):
        return state + 1, {"abft_flag": fault["on"], "abft_max_rel": 1.0}

    def restore():
        fault["on"] = False

    g2 = ABFTGuard(restore_fn=restore)
    out, m = g2.run_step(bad_until_restore, 0)
    assert out == 1 and bool(m["abft_flag"]) is False
    assert g2.restores == 1


def test_straggler_watchdog():
    import time
    wd = StragglerWatchdog(threshold=5.0, warmup=3)
    for _ in range(6):
        wd.start(); time.sleep(0.001); wd.stop()
    wd.start(); time.sleep(0.05)
    assert wd.stop() is True
    assert wd.events == 1
