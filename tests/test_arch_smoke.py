"""Per-architecture smoke tests: reduced same-family config, one forward +
grad step and one prefill→decode step on CPU; asserts shapes and finiteness.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config, list_archs, smoke_config
from repro.core.abft import ABFTConfig
from repro.models.transformer import (
    init_decode_state,
    init_model,
    lm_loss,
    model_decode,
    model_forward,
    model_prefill,
)

ABFT = ABFTConfig(mode="fused", threshold=5e-2, relative=True)
B, T = 2, 16


def make_batch(cfg, rng):
    batch = {"tokens": jnp.asarray(
        rng.integers(0, cfg.vocab_size, size=(B, T)), jnp.int32)}
    if cfg.family == "encdec":
        batch["src_embeds"] = jnp.asarray(
            rng.normal(size=(B, T, cfg.d_model)), jnp.float32)
    elif cfg.frontend:
        batch["prefix_embeds"] = jnp.asarray(
            rng.normal(size=(B, 4, cfg.d_model)), jnp.float32)
    return batch


@pytest.mark.parametrize("arch", list_archs())
def test_forward_and_grad(arch):
    cfg = smoke_config(get_config(arch))
    rng = np.random.default_rng(0)
    params = init_model(cfg, jax.random.PRNGKey(0))
    batch = make_batch(cfg, rng)
    labels = batch["tokens"]

    def loss_fn(p):
        logits, report, aux = model_forward(p, cfg, batch, ABFT)
        return lm_loss(logits, labels) + 1e-2 * aux, (logits, report)

    (loss, (logits, report)), grads = jax.jit(
        jax.value_and_grad(loss_fn, has_aux=True))(params)
    assert logits.shape == (B, T, cfg.padded_vocab)
    assert np.isfinite(float(loss)), arch
    assert not bool(report.flag), (arch, float(report.max_rel))
    assert float(report.n_checks) > 0
    for g in jax.tree.leaves(grads):
        assert np.isfinite(np.asarray(g)).all(), arch


@pytest.mark.parametrize("arch", list_archs())
def test_prefill_decode(arch):
    cfg = smoke_config(get_config(arch))
    rng = np.random.default_rng(1)
    params = init_model(cfg, jax.random.PRNGKey(1))
    batch = make_batch(cfg, rng)
    cache_len = T + 4

    logits, states, report = jax.jit(
        lambda p, b: model_prefill(p, cfg, b, ABFT, cache_len))(params, batch)
    assert logits.shape == (B, 1, cfg.padded_vocab)
    assert not bool(report.flag), (arch, float(report.max_rel))

    tok = jnp.argmax(logits[:, -1], -1)[:, None].astype(jnp.int32)
    step = jax.jit(lambda p, s, t, pos: model_decode(p, cfg, s, t, pos, ABFT))
    for i in range(2):
        logits, states, report = step(params, states,
                                      tok, jnp.asarray(T + i, jnp.int32))
        assert logits.shape == (B, 1, cfg.padded_vocab)
        assert np.isfinite(np.asarray(logits)).all(), arch
        assert not bool(report.flag), (arch, float(report.max_rel))
        tok = jnp.argmax(logits[:, -1], -1)[:, None].astype(jnp.int32)


@pytest.mark.parametrize("arch", ["gemma-2b", "rwkv6-7b", "recurrentgemma-9b",
                                  "deepseek-moe-16b", "whisper-medium"])
def test_decode_matches_forward(arch):
    """Prefill+decode must agree with full forward on the same tokens
    (recurrence/cache correctness)."""
    cfg = smoke_config(get_config(arch))
    if cfg.moe is not None:
        # capacity drops legitimately differ between batched forward (B*T
        # tokens) and decode (B tokens); disable drops for the equivalence
        # check so it isolates cache correctness.
        import dataclasses as dc
        cfg = dc.replace(cfg, moe=dc.replace(cfg.moe, capacity_factor=16.0))
    rng = np.random.default_rng(2)
    params = init_model(cfg, jax.random.PRNGKey(2))
    batch = make_batch(cfg, rng)
    none = ABFTConfig(mode="none")

    logits_full, _, _ = jax.jit(
        lambda p, b: model_forward(p, cfg, b, none))(params, batch)

    # prefill on T-1 tokens, decode token T-1, compare its logits
    batch_pre = dict(batch)
    batch_pre["tokens"] = batch["tokens"][:, :-1]
    _, states, _ = jax.jit(
        lambda p, b: model_prefill(p, cfg, b, none, T + 2))(params, batch_pre)
    pos = T - 1
    if "prefix_embeds" in batch:
        pos = T - 1 + batch["prefix_embeds"].shape[1]
    logits_dec, _, _ = jax.jit(
        lambda p, s, t: model_decode(p, cfg, s, t,
                                     jnp.asarray(pos, jnp.int32), none))(
        params, states, batch["tokens"][:, -1:])
    np.testing.assert_allclose(
        np.asarray(logits_dec[:, 0]), np.asarray(logits_full[:, -1]),
        rtol=2e-2, atol=2e-2)


def test_smoke_config_preserves_structure():
    for arch in list_archs():
        full = get_config(arch)
        sm = smoke_config(full)
        assert sm.block_pattern == full.block_pattern
        assert (sm.moe is None) == (full.moe is None)
        assert (sm.n_kv_heads < sm.n_heads) == (full.n_kv_heads < full.n_heads)
        assert sm.family == full.family
