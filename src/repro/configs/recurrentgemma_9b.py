"""recurrentgemma-9b [hybrid]: 38L, d=4096, 16H (MQA kv=1), ff=12288,
vocab 256000.  Griffin pattern: (RG-LRU, RG-LRU, local-attn) repeating,
local window 2048.  [arXiv:2402.19427]"""
from . import register
from .base import ModelConfig

CONFIG = register(ModelConfig(
    name="recurrentgemma-9b",
    n_layers=38,
    d_model=4096,
    n_heads=16,
    n_kv_heads=1,
    head_dim=256,
    d_ff=12288,
    vocab_size=256000,
    block_pattern=("rglru", "rglru", "attn"),
    local_window=2048,
    mlp_act="geglu",
    embed_scale=True,
    tie_embeddings=True,
))
