"""gemma-2b [dense]: 18L, d=2048, 8H (MQA kv=1), head_dim=256, GeGLU
ff=16384, vocab 256000.  Embeddings scaled by sqrt(d); RMSNorm (1+w).
[arXiv:2403.08295]"""
from . import register
from .base import ModelConfig

CONFIG = register(ModelConfig(
    name="gemma-2b",
    n_layers=18,
    d_model=2048,
    n_heads=8,
    n_kv_heads=1,
    head_dim=256,
    d_ff=16384,
    vocab_size=256000,
    mlp_act="geglu",
    embed_scale=True,
    tie_embeddings=True,
))
