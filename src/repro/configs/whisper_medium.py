"""whisper-medium [audio]: 24L enc + 24L dec, d=1024, 16H (kv=16), ff=4096,
vocab 51865.  Conv/mel frontend is a STUB: input_specs supplies precomputed
frame embeddings [B, T, d].  [arXiv:2212.04356]"""
from . import register
from .base import ModelConfig

CONFIG = register(ModelConfig(
    name="whisper-medium",
    family="encdec",
    n_layers=24,
    enc_layers=24,
    d_model=1024,
    n_heads=16,
    n_kv_heads=16,
    d_ff=4096,
    vocab_size=51865,
    mlp_act="gelu",
    norm="ln",
    rope_frac=0.0,          # whisper uses absolute positions (sinusoid here)
    qkv_bias=True,
    tie_embeddings=True,
    frontend="audio",
))
