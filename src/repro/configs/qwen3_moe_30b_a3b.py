"""qwen3-moe-30b-a3b [moe]: 48L, d=2048, 32H (GQA kv=4), vocab 151936.
128 experts (ff=768) top-8, no shared expert.  [hf:Qwen/Qwen3-30B-A3B]"""
from . import register
from .base import ModelConfig, MoECfg

CONFIG = register(ModelConfig(
    name="qwen3-moe-30b-a3b",
    n_layers=48,
    d_model=2048,
    n_heads=32,
    n_kv_heads=4,
    head_dim=128,
    d_ff=768,
    vocab_size=151936,
    moe=MoECfg(n_experts=128, top_k=8, d_ff_expert=768),
    mlp_act="swiglu",
    rope_theta=1e6,
    tie_embeddings=False,
))
