"""rwkv6-7b [ssm] "Finch": 32L, d=4096, attention-free (data-dependent decay
time-mix), ff=14336 channel-mix, vocab 65536.  [arXiv:2404.05892]"""
from . import register
from .base import ModelConfig

CONFIG = register(ModelConfig(
    name="rwkv6-7b",
    n_layers=32,
    d_model=4096,
    n_heads=64,             # head_size 64
    n_kv_heads=64,
    d_ff=14336,
    vocab_size=65536,
    block_pattern=("rwkv",),
    rope_frac=0.0,
    tie_embeddings=False,
))
