"""Config registry: one module per assigned architecture + the paper's GCN."""
from __future__ import annotations

from typing import Dict, List

from .base import ModelConfig, MoECfg, ShapeConfig, SHAPES, smoke_config  # noqa: F401

_REGISTRY: Dict[str, ModelConfig] = {}


def register(cfg: ModelConfig) -> ModelConfig:
    _REGISTRY[cfg.name] = cfg
    return cfg


def get_config(name: str) -> ModelConfig:
    _ensure_loaded()
    return _REGISTRY[name]


def list_archs() -> List[str]:
    _ensure_loaded()
    return sorted(_REGISTRY)


def _ensure_loaded() -> None:
    if _REGISTRY:
        return
    from . import (  # noqa: F401
        whisper_medium,
        chatglm3_6b,
        qwen15_4b,
        h2o_danube3_4b,
        gemma_2b,
        rwkv6_7b,
        deepseek_moe_16b,
        qwen3_moe_30b_a3b,
        recurrentgemma_9b,
        internvl2_26b,
    )
