"""Model / shape configuration system.

One frozen dataclass covers every assigned architecture family (dense,
GQA/MQA, SWA, MoE, RWKV6, RG-LRU hybrid, encoder-decoder, VLM/audio stubs).
Configs are hashable so they ride through jit as static arguments.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple


@dataclasses.dataclass(frozen=True)
class MoECfg:
    n_experts: int
    top_k: int
    d_ff_expert: int
    n_shared: int = 0
    d_ff_shared: int = 0          # width of the shared-expert block
    capacity_factor: float = 1.25


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    family: str = "decoder"            # 'decoder' | 'encdec'
    head_dim: int = 0                  # 0 -> d_model // n_heads
    norm_eps: float = 1e-5
    norm: str = "rms"                  # 'rms' | 'ln'
    rope_theta: float = 10000.0
    rope_frac: float = 1.0             # chatglm applies RoPE to half the dims
    qkv_bias: bool = False
    window: int = 0                    # 0 = full attention; >0 = SWA width
    mlp_act: str = "swiglu"            # 'swiglu' | 'geglu' | 'gelu'
    rms_offset: float = 0.0            # gemma RMSNorm uses (1 + w)
    embed_scale: bool = False          # gemma scales embeddings by sqrt(d)
    tie_embeddings: bool = True
    moe: Optional[MoECfg] = None
    # repeating block-type unit; layer i gets block_pattern[i % len]
    block_pattern: Tuple[str, ...] = ("attn",)   # 'attn' | 'rglru' | 'rwkv'
    local_window: int = 2048           # window of 'attn' blocks in hybrids
    conv1d_width: int = 4              # RG-LRU temporal conv
    rglru_d: int = 0                   # recurrence width (0 -> d_model)
    # encoder (whisper); encoder is bidirectional, decoder cross-attends
    enc_layers: int = 0
    frontend: str = ""                 # '' | 'audio' | 'vision'  (stubs)
    causal: bool = True
    scan_layers: bool = True
    remat: bool = True
    attn_chunk: int = 1024             # KV chunk for the streaming softmax
    attn_impl: str = "xla"             # "xla" | "pallas" (TPU kernel)
    pallas_interpret: bool = False     # CPU validation of the kernel
    dtype: str = "bfloat16"

    @property
    def hd(self) -> int:
        return self.head_dim or self.d_model // self.n_heads

    @property
    def padded_vocab(self) -> int:
        """Vocab padded to a multiple of 512 (= pod·data·model worst case)
        so embedding/head shard evenly; pad logits are masked to -inf in the
        LM head (standard MaxText-style practice)."""
        return -(-self.vocab_size // 512) * 512

    @property
    def kv_groups(self) -> int:
        assert self.n_heads % self.n_kv_heads == 0
        return self.n_heads // self.n_kv_heads

    def block_type(self, i: int) -> str:
        return self.block_pattern[i % len(self.block_pattern)]

    @property
    def attention_free(self) -> bool:
        return all(b in ("rglru", "rwkv") for b in self.block_pattern)

    @property
    def sub_quadratic(self) -> bool:
        """Can this arch run the 500k-token decode shape?  True when no block
        attends over unbounded history (SWA/local windows are bounded)."""
        has_full_attn = any(
            self.block_type(i) == "attn" and self.window == 0
            and len(self.block_pattern) == 1
            for i in range(self.n_layers)
        )
        if len(self.block_pattern) > 1:
            # hybrid: 'attn' blocks use local_window (bounded)
            has_full_attn = False
        return not has_full_attn or self.window > 0


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: str                          # 'train' | 'prefill' | 'decode'


SHAPES = {
    "train_4k":    ShapeConfig("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32768, 32, "prefill"),
    "decode_32k":  ShapeConfig("decode_32k", 32768, 128, "decode"),
    "long_500k":   ShapeConfig("long_500k", 524288, 1, "decode"),
}


def smoke_config(cfg: ModelConfig) -> ModelConfig:
    """Reduced same-family twin for CPU smoke tests: tiny dims, same block
    structure / attention flavour / MoE routing shape."""
    pat_len = len(cfg.block_pattern)
    moe = None
    if cfg.moe is not None:
        moe = MoECfg(
            n_experts=min(8, cfg.moe.n_experts),
            top_k=min(2, cfg.moe.top_k),
            d_ff_expert=32,
            n_shared=min(1, cfg.moe.n_shared),
            d_ff_shared=32 if cfg.moe.n_shared else 0,
            capacity_factor=cfg.moe.capacity_factor,
        )
    heads = 4
    kv = max(1, heads // min(cfg.kv_groups, heads))   # preserve GQA/MQA ratio
    return dataclasses.replace(
        cfg,
        name=cfg.name + "-smoke",
        n_layers=max(2, pat_len),
        d_model=64,
        n_heads=heads,
        n_kv_heads=kv,
        head_dim=16,
        d_ff=96,
        vocab_size=256,
        moe=moe,
        enc_layers=2 if cfg.enc_layers else 0,
        window=min(cfg.window, 32) if cfg.window else 0,
        local_window=16,
        rglru_d=0,
        attn_chunk=32,
        dtype="float32",
    )
