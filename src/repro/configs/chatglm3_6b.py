"""chatglm3-6b [dense]: 28L, d=4096, 32H (GQA kv=2), ff=13696, vocab 65024.
2d (half-dim) RoPE, QKV bias, SwiGLU.  [arXiv:2406.12793]"""
from . import register
from .base import ModelConfig

CONFIG = register(ModelConfig(
    name="chatglm3-6b",
    n_layers=28,
    d_model=4096,
    n_heads=32,
    n_kv_heads=2,
    d_ff=13696,
    vocab_size=65024,
    rope_frac=0.5,          # ChatGLM rotates half the head dims
    qkv_bias=True,
    mlp_act="swiglu",
    tie_embeddings=False,
))
