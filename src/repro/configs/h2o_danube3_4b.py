"""h2o-danube-3-4b [dense]: 24L, d=3840, 32H (GQA kv=8), ff=10240,
vocab 32000.  Llama+Mistral mix with sliding-window attention.
[arXiv:2401.16818]"""
from . import register
from .base import ModelConfig

CONFIG = register(ModelConfig(
    name="h2o-danube-3-4b",
    n_layers=24,
    d_model=3840,
    n_heads=32,
    n_kv_heads=8,
    d_ff=10240,
    vocab_size=32000,
    window=4096,            # mistral-style SWA -> bounded cache, runs 500k
    mlp_act="swiglu",
    tie_embeddings=False,
))
