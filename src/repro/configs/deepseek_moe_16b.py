"""deepseek-moe-16b [moe]: 28L, d=2048, 16H (kv=16), vocab 102400.
Fine-grained MoE: 64 routed experts (ff=1408) top-6 + 2 shared experts.
[arXiv:2401.06066]"""
from . import register
from .base import ModelConfig, MoECfg

CONFIG = register(ModelConfig(
    name="deepseek-moe-16b",
    n_layers=28,
    d_model=2048,
    n_heads=16,
    n_kv_heads=16,
    d_ff=1408,
    vocab_size=102400,
    moe=MoECfg(n_experts=64, top_k=6, d_ff_expert=1408,
               n_shared=2, d_ff_shared=2816),
    mlp_act="swiglu",
    tie_embeddings=False,
))
