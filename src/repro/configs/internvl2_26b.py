"""internvl2-26b [vlm]: InternLM2-20b backbone: 48L, d=6144, 48H (GQA kv=8),
ff=16384, vocab 92553.  InternViT frontend is a STUB: input_specs supplies
patch embeddings prepended to the token stream.  [arXiv:2404.16821]"""
from . import register
from .base import ModelConfig

CONFIG = register(ModelConfig(
    name="internvl2-26b",
    n_layers=48,
    d_model=6144,
    n_heads=48,
    n_kv_heads=8,
    d_ff=16384,
    vocab_size=92553,
    mlp_act="swiglu",
    frontend="vision",
    tie_embeddings=False,
))
