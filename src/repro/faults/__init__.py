"""Declarative fault injection + chaos campaigns for the ABFT stack.

``model`` declares WHAT goes wrong (site x kind x timing), ``injectors``
makes it happen (bitcast bit-flips, sticky re-application, the kernel
accumulator hook), ``selfcheck`` guards the check path itself (periodic
re-derivation of the eq.-5 fold and the staged s_c), and ``campaign``
sweeps the grid and measures detection / SDC / false-positive rates plus
the guard's repair-tier distribution.
"""
from repro.faults.campaign import (ExperimentResult, run_experiment,
                                   run_fault_campaign)
from repro.faults.injectors import FaultInjector, flip_bits
from repro.faults.model import (CHECK_PATH_SITES, CONSISTENT_SITES, KINDS,
                                SITES, TIMINGS, FaultModel, sweep_models)
from repro.faults.selfcheck import (CheckPathSelfCheck, refold, verify_s_c,
                                    verify_w_r)

__all__ = [
    "FaultModel", "sweep_models", "SITES", "KINDS", "TIMINGS",
    "CHECK_PATH_SITES", "CONSISTENT_SITES",
    "FaultInjector", "flip_bits",
    "CheckPathSelfCheck", "verify_w_r", "verify_s_c", "refold",
    "run_fault_campaign", "run_experiment", "ExperimentResult",
]
