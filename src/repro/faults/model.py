"""Declarative fault models for the chaos campaign.

A :class:`FaultModel` names WHERE a fault lands (``site``), WHAT it does
(``kind``) and WHEN it fires (``timing``) — the axes PyGFI-style GNN
robustness campaigns sweep.  The model is pure data; the matching
stateful process (choosing coordinates, latching sticky corruption,
re-applying it each step) lives in :mod:`repro.faults.injectors`.

Sites (what the bits belong to):

* ``weights``     — an element of a layer's weight matrix W.  The fold
  ``w_r = W·e`` predates the corruption, so the eq. 4–6 check sees the
  divergence: this is the *detectable memory fault* class.
* ``features``    — an element of the request's node features H0.  The
  carried column x_r = H·w_r is computed from the SAME corrupted H, so
  the check is consistent by construction — ABFT does not claim this
  site; the campaign measures its SDC rate honestly.
* ``cols_table``  — an entry of the packed block-ELL column-index table
  (a corrupted pointer landing on a valid but wrong column block).  Both
  the aggregation and its checksum corner read the same table, so this
  site is also architecturally silent — measured, not asserted.
* ``accumulator`` — the paper's fault model: a delta added into one
  (layer, stripe, slot) accumulation step inside the kernel, via the
  existing ``inject=`` hook.  Single upsets above threshold must be
  detected 100% (the CI gate).
* ``w_r``         — the folded eq.-5 checksum-column source; corrupting
  it corrupts the carried column x_r = H·w_r, i.e. the CHECK path, not
  the data path.  Caught by the periodic self-check
  (:mod:`repro.faults.selfcheck`).
* ``s_c``         — the offline adjacency column checksum e^T·S (dense /
  BCOO serving path).  Check path again; self-check territory.

LM sites (the guarded transformer lane — :class:`~repro.engine.lm.LMEngine`):

* ``qkv_w``       — an element of a layer's stacked attention projection
  weights (Q by convention; ``index`` addresses the flat slice).  The
  offline fold predates the corruption → detectable, repaired by the
  guard's restore-and-refold.
* ``mlp_w``       — same class, the layer's MLP input projection.
* ``attn_accumulator`` — the attention output accumulator O = A·V, via
  the ``attn_inject`` operand: the carried column o_extra is accumulated
  independently, so the fused chain check must flag it 100% (the LM CI
  gate, mirroring the GCN ``accumulator`` gate).

Kinds: ``bitflip`` (transient single-event upset — fires once, the
corrupted value is overwritten by the next clean write/retry),
``stuck`` (sticky stuck-at — the corruption re-applies every step from
its first firing; retries on the same unit are doomed), ``multi``
(multi-bit/multi-element upset in one event).

Timing: ``targeted`` (fires at ``step``; sticky kinds stay latched from
there) or ``bernoulli`` (each step fires with probability ``p``; sticky
kinds latch on the first firing).
"""
from __future__ import annotations

import dataclasses
import math
from typing import Optional, Tuple

SITES = ("weights", "features", "cols_table", "accumulator", "w_r", "s_c",
         "qkv_w", "mlp_w", "attn_accumulator")
# the LM lane's sites (guarded transformer serving)
LM_SITES = ("qkv_w", "mlp_w", "attn_accumulator")
# the GCN serving lane's sites (everything the packed/dense hooks serve)
GCN_SITES = tuple(s for s in SITES if s not in LM_SITES)
KINDS = ("bitflip", "stuck", "multi")
TIMINGS = ("targeted", "bernoulli")

# sites that corrupt the checksum path itself rather than the data path
CHECK_PATH_SITES = ("w_r", "s_c")
# sites the eq. 4-6 algebra cannot see by construction (consistent
# corruption of both sides) — expected-silent, measured for SDC rate
CONSISTENT_SITES = ("features", "cols_table")


@dataclasses.dataclass(frozen=True)
class FaultModel:
    """One declarative fault: site x kind x timing + coordinates.

    ``index`` pins the flat element index inside the target array (or the
    (stripe, slot) pair of a ``cols_table`` entry); ``None`` draws it from
    the injector's seeded rng.  ``bit`` is the IEEE bit to flip
    (``bitflip``/``multi``); ``stuck_value`` overrides the stuck-at value
    (default: the bit-flipped value sticks — stuck-at the upset).
    ``delta`` / ``stripe`` / ``slot`` parameterize the ``accumulator``
    site's kernel ``inject=`` tuple.
    """

    site: str
    kind: str = "bitflip"
    timing: str = "targeted"
    step: int = 0                 # targeted firing step (latch point)
    p: float = 0.0                # bernoulli per-step firing probability
    layer: int = 0                # weights / w_r / accumulator sites
    index: Optional[int] = None   # flat element index; None = seeded draw
    bit: int = 30                 # IEEE-754 bit to flip
    n_upsets: int = 1             # elements hit per event (kind="multi")
    stuck_value: Optional[float] = None
    delta: float = 1.0            # accumulator injection magnitude
    stripe: int = 0               # accumulator stripe coordinate
    slot: int = 0                 # accumulator ell-slot coordinate
    seed: int = 0

    def __post_init__(self):
        if self.site not in SITES:
            raise ValueError(f"fault site {self.site!r} not in {SITES}")
        if self.kind not in KINDS:
            raise ValueError(f"fault kind {self.kind!r} not in {KINDS}")
        if self.timing not in TIMINGS:
            raise ValueError(f"fault timing {self.timing!r} not in "
                             f"{TIMINGS}")
        if self.timing == "bernoulli" and not (0.0 < self.p <= 1.0):
            raise ValueError("bernoulli timing needs 0 < p <= 1, got "
                             f"{self.p}")
        if not (0 <= self.bit < 64):
            raise ValueError(f"bit {self.bit} out of range [0, 64)")
        if self.kind == "multi" and self.n_upsets < 2:
            raise ValueError("kind='multi' needs n_upsets >= 2")
        if self.kind != "multi" and self.n_upsets != 1:
            raise ValueError("n_upsets != 1 is kind='multi' only")
        if self.stuck_value is not None and self.kind != "stuck":
            raise ValueError("stuck_value is kind='stuck' only")
        if self.site in ("accumulator", "attn_accumulator") \
                and not math.isfinite(self.delta):
            raise ValueError("accumulator delta must be finite (the hook "
                             "adds it into one accumulation step)")

    @property
    def sticky(self) -> bool:
        """Sticky faults re-apply every step once latched."""
        return self.kind == "stuck"

    @property
    def check_path(self) -> bool:
        return self.site in CHECK_PATH_SITES

    @property
    def expected_silent(self) -> bool:
        """Sites the eq. 4-6 algebra cannot flag by construction."""
        return self.site in CONSISTENT_SITES

    def label(self) -> str:
        return f"{self.site}/{self.kind}/{self.timing}"

    def to_dict(self) -> dict:
        d = dataclasses.asdict(self)
        # NaN stuck values must survive the JSON round trip
        if d["stuck_value"] is not None and math.isnan(d["stuck_value"]):
            d["stuck_value"] = "nan"
        return d


def lm_sweep_models(*, reps: int = 2, step: int = 1, bit: int = 30,
                    delta: float = 25.0, seed: int = 0) -> list:
    """The LM lane's grid: weight sites x {bitflip, stuck} plus the
    attention-accumulator transient (the LM analog of the GCN
    ``accumulator`` gate site)."""
    models = []
    for site in ("qkv_w", "mlp_w"):
        for kind in ("bitflip", "stuck"):
            for r in range(reps):
                models.append(FaultModel(site=site, kind=kind, step=step,
                                         bit=bit, seed=seed + 1000 * r))
    for r in range(reps):
        models.append(FaultModel(site="attn_accumulator", kind="bitflip",
                                 step=step, delta=delta,
                                 seed=seed + 1000 * r))
    return models


def sweep_models(sites: Tuple[str, ...] = GCN_SITES,
                 kinds: Tuple[str, ...] = ("bitflip", "stuck"),
                 *, reps: int = 2, step: int = 1, bit: int = 30,
                 seed: int = 0) -> list:
    """The default campaign grid: ``reps`` seeded models per (site, kind),
    plus the check-path NaN stuck-at that exercises the would-be
    false-negative path (a naive ``d > tau`` comparison is silent on
    NaN)."""
    models = []
    for site in sites:
        for kind in kinds:
            for r in range(reps):
                models.append(FaultModel(
                    site=site, kind=kind, step=step, bit=bit,
                    seed=seed + 1000 * r))
        if site in CHECK_PATH_SITES and "stuck" in kinds:
            models.append(FaultModel(site=site, kind="stuck", step=step,
                                     stuck_value=float("nan"), seed=seed))
    return models
