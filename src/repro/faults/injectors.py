"""Fault injectors: bitcast bit-flips + stateful sticky re-application.

The injector is the stateful half of a :class:`~repro.faults.model.
FaultModel`: it decides when the fault fires, draws the target
coordinates once (seeded), and — for sticky kinds — RE-APPLIES the same
corruption every step, which is what distinguishes a stuck-at cell from
a transient upset: a retry that rereads the operand gets the corruption
back.

All corruption happens host-side on the operand copies handed to the
jitted step (modelling memory corruption of weights / features / index
tables); the one device-side site, the kernel accumulator, reuses the
existing ``inject=(layer, stripe, slot, delta)`` hook that all three
spmm/fused/network kernels honour.
"""
from __future__ import annotations

from typing import Dict, List, Optional, Tuple

import numpy as np

from .model import FaultModel

_UINT_FOR = {4: np.uint32, 8: np.uint64}


def flip_bits(arr: np.ndarray, flat_index: int, bit: int) -> np.ndarray:
    """Return a copy of ``arr`` with ``bit`` XOR-flipped in the element at
    ``flat_index`` — the bitcast upset model (works for f32/f64 via the
    matching uint view, and for integer dtypes directly)."""
    arr = np.array(arr)          # contiguous writable copy
    flat = arr.reshape(-1)
    if arr.dtype.kind == "f":
        u = _UINT_FOR.get(arr.dtype.itemsize)
        if u is None:
            raise ValueError(f"no uint view for dtype {arr.dtype}")
        bits = flat.view(u)
        bits[flat_index] ^= u(1 << (bit % (8 * arr.dtype.itemsize)))
    elif arr.dtype.kind in "iu":
        width = 8 * arr.dtype.itemsize
        flat[flat_index] = flat[flat_index] ^ arr.dtype.type(
            1 << (bit % width))
    else:
        raise ValueError(f"cannot bit-flip dtype {arr.dtype}")
    return arr


class FaultInjector:
    """Stateful fault process for one :class:`FaultModel` over a run.

    Usage per step ``t``::

        if inj.fires(t):
            params = inj.apply_params(params)        # weights / w_r
            cols, vals, h0 = inj.apply_batch(cols, vals, h0)
            inject = inj.kernel_inject()             # accumulator

    ``fires`` latches sticky kinds; the ``apply_*`` hooks then corrupt
    the SAME coordinates to the SAME values on every subsequent step —
    re-applying (not accumulating) the corruption, so a clean rewrite of
    the cell between steps is undone exactly once.
    """

    def __init__(self, model: FaultModel):
        self.model = model
        self.rng = np.random.default_rng(model.seed)
        self.latched = False
        self.first_fired_step: Optional[int] = None
        self._bern: Dict[int, bool] = {}
        # per-target-array sticky state: key -> [(flat_index, value)]
        self._stuck: Dict[str, List[Tuple[int, np.generic]]] = {}

    # -- timing -----------------------------------------------------------

    def fires(self, step_idx: int) -> bool:
        m = self.model
        if m.sticky and self.latched:
            return True
        if m.timing == "targeted":
            fired = (step_idx >= m.step) if m.sticky \
                else (step_idx == m.step)
        else:
            if step_idx not in self._bern:
                self._bern[step_idx] = bool(self.rng.random() < m.p)
            fired = self._bern[step_idx]
        if fired:
            self.latched = self.latched or m.sticky
            if self.first_fired_step is None:
                self.first_fired_step = step_idx
        return fired

    # -- corruption core --------------------------------------------------

    def _coords(self, key: str, size: int) -> List[int]:
        n = self.model.n_upsets
        if self.model.index is not None:
            base = self.model.index % size
            return [(base + k) % size for k in range(n)]
        state = self._stuck.get(key)
        if state is not None:
            return [i for i, _ in state]
        return list(self.rng.choice(size, size=min(n, size),
                                    replace=False))

    def corrupt_array(self, key: str, arr: np.ndarray) -> np.ndarray:
        """Corrupt (a copy of) one target array, latching sticky values."""
        m = self.model
        arr = np.array(arr)
        state = self._stuck.get(key)
        if state is not None:
            # sticky re-application: same cells, same stuck values
            flat = arr.reshape(-1)
            for i, v in state:
                flat[i] = v
            return arr
        coords = self._coords(key, arr.size)
        for i in coords:
            if m.kind == "stuck" and m.stuck_value is not None:
                flat = arr.reshape(-1)
                flat[i] = arr.dtype.type(m.stuck_value)
            else:
                arr = flip_bits(arr, i, m.bit)
        if m.sticky:
            flat = arr.reshape(-1)
            # scalar indexing copies, so the latched value is immutable
            self._stuck[key] = [(i, flat[i]) for i in coords]
        return arr

    # -- site hooks -------------------------------------------------------

    def apply_params(self, params):
        """weights / w_r sites: corrupt one layer's W or its folded
        checksum column source, returning a shallow-copied params tree."""
        m = self.model
        if m.site not in ("weights", "w_r"):
            return params
        field = "w" if m.site == "weights" else "w_r"
        layers = list(params["layers"])
        layer = dict(layers[m.layer % len(layers)])
        if field not in layer:
            raise ValueError(f"fault site {m.site!r} needs params with a "
                             f"folded {field!r} entry (run fold_w_r first)")
        layer[field] = self.corrupt_array(
            field, np.asarray(layer[field]))
        layers[m.layer % len(layers)] = layer
        return {**params, "layers": layers}

    def apply_batch(self, cols: np.ndarray, vals: np.ndarray,
                    h0: np.ndarray):
        """features / cols_table sites: corrupt the packed operands."""
        m = self.model
        if m.site == "features":
            h0 = self.corrupt_array("h0", np.asarray(h0))
        elif m.site == "cols_table":
            cols = np.array(cols)
            n_cols = int(cols.max()) + 1 if cols.size else 1
            flat = cols.reshape(-1)
            state = self._stuck.get("cols")
            if state is not None:
                for i, v in state:
                    flat[i] = v
            else:
                coords = self._coords("cols", flat.size)
                for i in coords:
                    if m.kind == "stuck" and m.stuck_value is not None:
                        v = int(m.stuck_value)  # abftlint: sync-ok
                        flat[i] = v % n_cols
                    else:
                        # a corrupted index must still land on a valid
                        # column block (a wild pointer traps instead of
                        # silently corrupting — the interesting case is
                        # the silent one)
                        v = int(flat[i])  # abftlint: sync-ok (host)
                        flat[i] = (v ^ (1 << (m.bit % 8))) % n_cols
                if m.sticky:
                    self._stuck["cols"] = [(i, flat[i]) for i in coords]
        return cols, vals, h0

    def apply_graph(self, graph):
        """s_c site: corrupt the dense/BCOO path's offline adjacency
        column checksum stashed on the Graph (trusted verbatim by the
        engine — exactly why the self-check must re-derive it)."""
        if self.model.site != "s_c":
            return graph
        if graph.s_c is None:
            raise ValueError("fault site 's_c' needs a Graph with a "
                             "staged s_c (run one forward first or pass "
                             "it explicitly)")
        graph.s_c = self.corrupt_array("s_c", np.asarray(graph.s_c))
        graph._s_c_auto = False      # user-provided values are trusted
        return graph

    def kernel_inject(self) -> Optional[Tuple[int, int, int, float]]:
        """accumulator site: the kernel ``inject=`` tuple, or None."""
        m = self.model
        if m.site != "accumulator":
            return None
        return (m.layer, m.stripe, m.slot, m.delta)

    # -- LM site hooks ----------------------------------------------------

    def apply_lm_params(self, params):
        """qkv_w / mlp_w sites: corrupt one layer's slice of the stacked
        transformer weights (``attn.wq.w`` / ``mlp.wi.w``, shape
        ``[L, d_in, *out]``) in a shallow-copied param tree.  The offline
        fold (``w_r``) is left pristine, so the corruption is the
        detectable post-load memory-fault class."""
        m = self.model
        if m.site not in ("qkv_w", "mlp_w"):
            return params
        path = ("attn", "wq") if m.site == "qkv_w" else ("mlp", "wi")
        segments = list(params["segments"])
        for si, seg in enumerate(segments):
            for uname in sorted(seg):
                unit = seg[uname]
                blk = unit.get(path[0]) if isinstance(unit, dict) else None
                dns = blk.get(path[1]) if isinstance(blk, dict) else None
                if not (isinstance(dns, dict) and "w" in dns):
                    continue
                w = np.array(dns["w"])  # [L, d_in, *out] # abftlint: sync-ok
                li = m.layer % w.shape[0]
                w[li] = self.corrupt_array(
                    m.site, w[li]).reshape(w[li].shape)
                segments[si] = {**seg, uname: {
                    **unit, path[0]: {**blk, path[1]: {**dns, "w": w}}}}
                return {**params, "segments": segments}
        raise ValueError(f"fault site {m.site!r}: no "
                         f"{'/'.join(path)} dense in the param tree")

    def lm_inject(self) -> float:
        """attn_accumulator site: the ``attn_inject`` operand delta for
        this step (0.0 when the site is something else)."""
        m = self.model
        return m.delta if m.site == "attn_accumulator" else 0.0
