"""Check-the-check: periodic re-derivation of the checksum path.

The eq. 4–6 corners compare the computation against *precomputed*
checksum operands — the folded per-layer ``w_r = W·e`` (the source of
the carried eq.-5 column ``x_r = H·w_r``) and, on the dense/BCOO path,
the offline adjacency column checksum ``s_c = e^T·S``.  A memory fault
in those operands makes every check a lie: a finite corruption turns the
stream into a false-positive storm (burning the guard's retry ladder on
phantom faults), and a NaN corruption would — under a naive ``d > tau``
comparison — silently pass every check forever, disabling ABFT without
any observable symptom.

The defense is cheap because the fold is tiny (one f32 vector per layer,
one per graph): on a sampled cadence, re-derive the fold from its source
operand and compare BITWISE.  The derivation is deterministic (same
reduction on the same input), so any discrepancy is corruption — of the
fold, or of the source weights *after* folding; either way the fold is
stale and must be rebuilt.  ``repair`` refolds from the current source,
which restores check integrity (data-path weight corruption remains the
ordinary checks' job — and with a consistent refold it is invisible to
ABFT by construction, which is exactly the consistent-corruption caveat
the README documents).
"""
from __future__ import annotations

import dataclasses
from typing import List, Optional

import numpy as np

from repro.core.abft import ABFTConfig
from repro.core.checksum import row_checksum


def _mismatch(a, b) -> bool:
    """Bitwise inequality that treats NaN as corruption (NaN != NaN is
    exactly the property we want here: a NaN fold can never be the honest
    derivation of finite weights)."""
    a = np.asarray(a)
    b = np.asarray(b)
    return a.shape != b.shape or not np.array_equal(a, b)


def verify_w_r(params, cfg: ABFTConfig) -> List[int]:
    """Re-derive every layer's eq.-5 fold and compare against the folded
    copy; returns the indices of mismatched layers (empty = clean)."""
    if not cfg.enabled:
        return []
    bad = []
    for i, layer in enumerate(params["layers"]):
        w_r = layer.get("w_r")
        if w_r is None:
            continue            # unfolded layer: derived per step, no copy
        if _mismatch(row_checksum(layer["w"], cfg.dtype), w_r):
            bad.append(i)
    return bad


def verify_s_c(graph, cfg: ABFTConfig) -> bool:
    """Re-derive a Graph's staged adjacency column checksum; True when the
    stash diverges from e^T·S (corruption, or a stale stash)."""
    if not cfg.enabled or graph.s_c is None:
        return False
    from repro.core.abft import sparse_col_checksum
    return _mismatch(sparse_col_checksum(graph.s, cfg.dtype), graph.s_c)


def refold(params, cfg: ABFTConfig):
    """Rebuild every folded w_r from its source weights (the repair)."""
    from repro.engine.api import fold_w_r
    return fold_w_r(params, cfg)


@dataclasses.dataclass
class CheckPathSelfCheck:
    """Sampled-cadence self-check of the checksum operands.

    ``maybe_check(params, step)`` runs the w_r verification every
    ``interval`` calls (step 0 included, so corruption predating a run is
    caught before the first flagged dispatch) and returns the mismatched
    layer indices, or ``None`` when this step was off-cadence.  The
    caller decides the repair policy — the streaming engine refolds and
    rebuilds its steps; the campaign records the detection.
    """

    cfg: ABFTConfig
    interval: int = 64
    checks_run: int = 0
    trips: int = 0
    last_bad: Optional[List[int]] = None

    def __post_init__(self):
        if self.interval < 1:
            raise ValueError("selfcheck interval must be >= 1")

    def maybe_check(self, params, step: int) -> Optional[List[int]]:
        if step % self.interval != 0:
            return None
        self.checks_run += 1
        bad = verify_w_r(params, self.cfg)
        if bad:
            self.trips += 1
            self.last_bad = list(bad)
        return bad

    def repair(self, params):
        return refold(params, self.cfg)
