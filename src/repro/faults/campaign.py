"""Chaos-campaign driver: sweep fault models across sites x kinds and
measure what the eq. 4-6 checks actually catch.

Each experiment runs one :class:`~repro.faults.model.FaultModel` against a
deterministic synthetic serving workload and classifies every step:

* **detected**      — data-path corruption active AND the online check
  flagged (true positive); detection latency is steps from first firing
  to first flag.
* **sdc**           — data-path corruption active, outputs diverged from
  the clean reference, NO flag: a silent data corruption (the measured
  false-negative class — ``features``/``cols_table`` corrupt both sides
  of the check consistently, so ABFT is architecturally blind there and
  the campaign *measures* rather than asserts).
* **masked**        — corruption fired but the outputs match the clean
  reference bitwise (the flip landed somewhere the forward never used).
* **false_positive** — flag with clean data.  Finite check-path
  corruption (``w_r``/``s_c``) lands here by construction: the data path
  is untouched, every verdict is a lie.  The periodic self-check
  (:mod:`repro.faults.selfcheck`) is the defense, and the campaign
  records its detections separately.
* **would-be false negative** — check-path corruption where the NAIVE
  comparison (``d > tau``: False for NaN) reports clean.  The shipped
  NaN-safe comparison (``~(d <= tau*scale)``) flags it, and the
  self-check catches the corruption at its root; the campaign reports
  the naive verdict recomputed host-side so the report shows what a
  naive implementation would have silently missed.

Every flagged step is also adjudicated through a real
:class:`~repro.runtime.ABFTGuard` so the campaign reports the
repair-tier distribution (slot/stripe/graph/restore + persistent-site
escalations): retries re-read CLEAN operands for transient kinds and the
CORRUPTED operands for sticky kinds — a stuck-at cell re-corrupts every
re-execution, which is exactly what drives the guard's persistent
classification and the streaming engine's backend degrade.

All forwards are eager (no jit): the campaign is a measurement harness,
not a serving benchmark, and eager replay keeps it deterministic with
zero compile-cache interactions.  The packed block-ELL path serves every
site except ``s_c`` (a dense/BCOO-path operand), which runs per-graph
dense forwards.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, List, Optional, Tuple

import numpy as np

from repro.core.abft import ABFTConfig, per_graph_report, summarize
from repro.faults.injectors import FaultInjector
from repro.faults.model import (
    CHECK_PATH_SITES,
    FaultModel,
    lm_sweep_models,
    sweep_models,
)
from repro.faults.selfcheck import verify_s_c, verify_w_r
from repro.runtime import ABFTGuard, GuardConfig


# ---------------------------------------------------------------------------
# eager forwards
# ---------------------------------------------------------------------------

def _packed_forward(params, cfg: ABFTConfig, pb, *, block_g: int,
                    interpret: bool, inject=None, cols=None, h0=None
                    ) -> Tuple[np.ndarray, np.ndarray, np.ndarray]:
    """One eager packed step: (logits, per-graph flags, per-graph max_rel).
    ``cols``/``h0`` override the packed operands (the features/cols_table
    corruption surface); ``inject`` is the kernel accumulator hook."""
    import jax.numpy as jnp

    from repro.engine.api import Graph, gcn_forward
    from repro.engine.backends import BlockEllBackend

    cols = pb.bell.block_cols if cols is None else cols
    h0 = pb.h0 if h0 is None else h0
    bk = BlockEllBackend.from_staged(
        jnp.asarray(cols), jnp.asarray(pb.bell.values),
        jnp.asarray(pb.stripe_graph), pb.n_slots, cfg,
        block_g=block_g, interpret=interpret, inject=inject)
    logits, checks = gcn_forward(params, Graph(s=None, h0=jnp.asarray(h0)),
                                 cfg, backend=bk)
    gflags, grel = per_graph_report(checks, cfg, pb.n_slots)
    return (np.asarray(logits), np.asarray(gflags, bool),
            np.asarray(grel, np.float32))


def _dense_forward(params, cfg: ABFTConfig, graphs
                   ) -> Tuple[List[np.ndarray], np.ndarray, np.ndarray]:
    """Per-graph eager dense forwards over prebuilt Graph objects (the
    ``s_c`` site's path — the corruption lives on the Graph itself)."""
    from repro.engine.api import gcn_forward

    outs, flags, rels = [], [], []
    for g in graphs:
        logits, checks = gcn_forward(params, g, cfg, backend="dense")
        rep = summarize(checks, cfg)
        outs.append(np.asarray(logits))        # abftlint: sync-ok (eager campaign harness)
        flags.append(bool(np.asarray(rep.flag)))    # abftlint: sync-ok
        rels.append(float(np.asarray(rep.max_rel)))  # abftlint: sync-ok
    return outs, np.array(flags), np.array(rels, np.float32)


def _make_dense_graphs(items, cfg: ABFTConfig):
    """Graphs with an explicit (honest) staged s_c — the injector needs a
    stash to corrupt, and an explicit stash is trusted verbatim by the
    engine, which is exactly why the self-check must re-derive it."""
    import jax.numpy as jnp

    from repro.core.abft import sparse_col_checksum
    from repro.engine.api import Graph

    graphs = []
    for s, h0 in items:
        sj = jnp.asarray(s)
        graphs.append(Graph(s=sj, h0=jnp.asarray(h0),
                            s_c=sparse_col_checksum(sj, cfg.dtype)))
    return graphs


# ---------------------------------------------------------------------------
# one experiment
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class ExperimentResult:
    """Per-fault-model outcome record (JSON-ready via ``to_dict``)."""

    model: FaultModel
    steps: int
    fired_steps: List[int]
    flagged_steps: List[int]
    naive_flagged_steps: List[int]      # the would-be d > tau verdicts
    detected: bool
    detection_latency: Optional[int]
    sdc_steps: List[int]
    masked_steps: List[int]
    false_positive_steps: List[int]
    selfcheck_detected: bool
    selfcheck_step: Optional[int]
    would_be_false_negative: bool
    escalated: bool                     # guard refused to verify (evict)
    repair_tiers: Dict[str, Any]

    def to_dict(self) -> dict:
        d = dataclasses.asdict(self)
        d["model"] = self.model.to_dict()
        d["label"] = self.model.label()
        return d


def _adjudicate(guard: ABFTGuard, out, gflags, grel, pb, rerun) -> bool:
    """Run one flagged step through the guard's repair ladder.  ``rerun``
    re-executes the batch (with corrupted operands for sticky kinds,
    clean for transient) and the retry patches only the flagged graphs'
    rows — the campaign's repair-tier distribution comes from these
    adjudications.  Returns True when the guard escalated (raised):
    eviction/degrade advice for the serving layer."""
    def retry(out, idx):
        logits2, gflags2, grel2 = rerun()
        out = np.asarray(out).copy()
        for gi in idx:
            o, n = pb.row_offsets[gi], pb.n_nodes[gi]
            out[o:o + n] = logits2[o:o + n]   # abftlint: sync-ok (eager retry patch)
        return out, {"abft_graph_flags": gflags2[idx],
                     "abft_graph_max_rel": grel2[idx]}

    metrics = {"abft_flag": bool(gflags.any()),
               "abft_max_rel": float(np.nanmax(grel, initial=0.0)),
               "abft_graph_flags": gflags, "abft_graph_max_rel": grel}
    try:
        guard.adjudicate(out, metrics, retry)
        return False
    except RuntimeError:
        return True


def _adjudicate_dense(guard: ABFTGuard, outs, flags, rels, rerun) -> bool:
    """Dense-path analog of :func:`_adjudicate` (per-graph verdicts)."""
    def retry(out, idx):
        outs2, flags2, rels2 = rerun()
        return out, {"abft_graph_flags": flags2[idx],
                     "abft_graph_max_rel": rels2[idx]}

    metrics = {"abft_flag": bool(flags.any()),
               "abft_max_rel": float(np.nanmax(rels, initial=0.0)),
               "abft_graph_flags": flags, "abft_graph_max_rel": rels}
    try:
        guard.adjudicate(outs, metrics, retry)
        return False
    except RuntimeError:
        return True


def run_experiment(model: FaultModel, *, params, cfg: ABFTConfig, pb,
                   items, ref_packed, ref_dense, block_g: int,
                   interpret: bool, n_steps: int,
                   guard_cfg: Optional[GuardConfig] = None
                   ) -> ExperimentResult:
    """Run one fault model for ``n_steps`` serving steps and classify."""
    inj = FaultInjector(model)
    guard = ABFTGuard(guard_cfg if guard_cfg is not None
                      else GuardConfig(max_retries=1, max_restores=1,
                                       persistent_window=4,
                                       persistent_threshold=2))
    dense_site = model.site == "s_c"
    fired_steps: List[int] = []
    flagged_steps: List[int] = []
    naive_steps: List[int] = []
    sdc_steps: List[int] = []
    masked_steps: List[int] = []
    fp_steps: List[int] = []
    selfcheck_step: Optional[int] = None
    escalations = 0

    ref_logits = ref_dense[0] if dense_site else ref_packed[0]

    for t in range(n_steps):
        fired = inj.fires(t)
        if fired:
            fired_steps.append(t)
        if dense_site:
            graphs = _make_dense_graphs(items, cfg)
            if fired:
                # the fault hits one graph's staged checksum; graph 0 is
                # the deterministic target
                inj.apply_graph(graphs[0])
            outs, gflags, grel = _dense_forward(params, cfg, graphs)
            diverged = any(
                not np.array_equal(a, b) for a, b in zip(outs, ref_logits))
            if fired and selfcheck_step is None \
                    and verify_s_c(graphs[0], cfg):
                selfcheck_step = t
            rerun = (lambda: _dense_forward(params, cfg, graphs)) \
                if model.sticky else \
                (lambda: _dense_forward(params, cfg,
                                        _make_dense_graphs(items, cfg)))
            out_for_guard = outs
        else:
            p_t, cols_t, h0_t, inject_t = params, None, None, None
            if fired:
                p_t = inj.apply_params(params)
                cols_t, _vals, h0_t = inj.apply_batch(
                    pb.bell.block_cols, pb.bell.values, pb.h0)
                if model.site != "features":
                    h0_t = None
                if model.site != "cols_table":
                    cols_t = None
                inject_t = inj.kernel_inject()
            outs, gflags, grel = _packed_forward(
                p_t, cfg, pb, block_g=block_g, interpret=interpret,
                inject=inject_t, cols=cols_t, h0=h0_t)
            diverged = not np.array_equal(outs, ref_logits)
            if fired and selfcheck_step is None and verify_w_r(p_t, cfg):
                selfcheck_step = t
            args = dict(block_g=block_g, interpret=interpret)
            if model.sticky:
                rerun = (lambda: _packed_forward(
                    p_t, cfg, pb, inject=inject_t, cols=cols_t, h0=h0_t,
                    **args))
            else:
                rerun = (lambda: _packed_forward(params, cfg, pb, **args))
            out_for_guard = outs

        flagged = bool(gflags.any())     # abftlint: sync-ok (eager campaign harness)
        with np.errstate(invalid="ignore"):
            # the naive d > tau comparison, recomputed host-side: NaN
            # compares False, which is precisely the would-be silent
            # false negative the NaN-safe check closes
            naive = bool(  # abftlint: sync-ok (host numpy)
                (grel > cfg.threshold).any())
        if flagged:
            flagged_steps.append(t)
        if naive:
            naive_steps.append(t)
        data_corrupt = fired and model.site not in CHECK_PATH_SITES
        if data_corrupt and not flagged:
            (sdc_steps if diverged else masked_steps).append(t)
        if not data_corrupt and flagged:
            fp_steps.append(t)
        if flagged:
            # adjudicate EVERY flagged step (a real serving layer degrades
            # after the first escalation; the campaign keeps going so a
            # sticky site recurs and the guard's persistent classification
            # is exercised and reported)
            adj = _adjudicate_dense if dense_site else _adjudicate
            adj_args = (guard, out_for_guard, gflags, grel) \
                + ((rerun,) if dense_site else (pb, rerun))
            escalations += adj(*adj_args)

    detected_steps = [t for t in flagged_steps if t in fired_steps] \
        if model.site not in CHECK_PATH_SITES else flagged_steps
    detected = bool(detected_steps)
    latency = (detected_steps[0] - fired_steps[0]
               if detected and fired_steps else None)
    selfcheck_detected = selfcheck_step is not None
    would_be_fn = (model.check_path and bool(fired_steps)
                   and not naive_steps
                   and (detected or selfcheck_detected))
    return ExperimentResult(
        model=model, steps=n_steps, fired_steps=fired_steps,
        flagged_steps=flagged_steps, naive_flagged_steps=naive_steps,
        detected=detected, detection_latency=latency,
        sdc_steps=sdc_steps, masked_steps=masked_steps,
        false_positive_steps=fp_steps,
        selfcheck_detected=selfcheck_detected,
        selfcheck_step=selfcheck_step,
        would_be_false_negative=would_be_fn,
        escalated=escalations > 0,
        repair_tiers=guard.repair_tiers())


# ---------------------------------------------------------------------------
# the campaign
# ---------------------------------------------------------------------------

def _aggregate(experiments: List[ExperimentResult]) -> Dict[str, dict]:
    """Per-(site, kind) rates over the experiment grid."""
    groups: Dict[str, List[ExperimentResult]] = {}
    for e in experiments:
        groups.setdefault(f"{e.model.site}/{e.model.kind}", []).append(e)
    out = {}
    for key, es in sorted(groups.items()):
        n = len(es)
        lat = [e.detection_latency for e in es
               if e.detection_latency is not None]
        clean_steps = sum(
            e.steps - len(set(e.fired_steps)
                          if e.model.site not in CHECK_PATH_SITES
                          else set()) for e in es)
        fp_steps = sum(len(e.false_positive_steps) for e in es)
        out[key] = {
            "n": n,
            "detection_rate": sum(e.detected for e in es) / n,
            "sdc_rate":
                sum(bool(e.sdc_steps)  # abftlint: sync-ok (host lists)
                    for e in es) / n,
            "masked_rate":
                sum(bool(e.masked_steps)  # abftlint: sync-ok
                    for e in es) / n,
            "false_positive_step_rate":
                fp_steps / clean_steps if clean_steps else 0.0,
            "mean_detection_latency":
                (sum(lat) / len(lat)) if lat else None,
            "selfcheck_detection_rate":
                sum(e.selfcheck_detected for e in es) / n,
            "would_be_false_negatives":
                sum(e.would_be_false_negative for e in es),
            "escalations": sum(e.escalated for e in es),
        }
    return out


def run_fault_campaign(models: Optional[List[FaultModel]] = None, *,
                       n_graphs: int = 4, n_steps: int = 4,
                       n_lo: int = 12, n_hi: int = 32, feat: int = 8,
                       hidden: int = 16, n_out: int = 4, block: int = 8,
                       block_g: int = 128, threshold: float = 1e-3,
                       seed: int = 0, interpret: Optional[bool] = None,
                       guard_cfg: Optional[GuardConfig] = None,
                       verbose: bool = False) -> dict:
    """Sweep ``models`` (default: :func:`sweep_models` grid) over a
    deterministic synthetic workload; returns the JSON-ready payload."""
    import jax

    from repro.engine.api import fold_w_r
    from repro.engine.batching import pack_graphs, synth_graph_stream
    from repro.kernels.runtime import resolve_interpret

    interp = resolve_interpret(interpret)
    if models is None:
        models = sweep_models(step=1, seed=seed)
    rng = np.random.default_rng(seed)
    params = {"layers": [
        {"w": (rng.normal(size=(feat, hidden)) * 0.3).astype(np.float32),
         "b": np.zeros(hidden, np.float32)},
        {"w": (rng.normal(size=(hidden, n_out)) * 0.3).astype(np.float32),
         "b": np.zeros(n_out, np.float32)}]}
    cfg = ABFTConfig(threshold=threshold)
    params = fold_w_r(params, cfg)
    items = synth_graph_stream(n_graphs, n_lo=n_lo, n_hi=n_hi, feat=feat,
                               seed=seed)
    # one fixed batch for the whole campaign: a single packed shape,
    # no shape menu to quantize
    pb = pack_graphs(items, block=block,  # abftlint: pack-ok
                     n_slots=n_graphs)

    # clean reference + clean control: the workload is deterministic and
    # eager, so one evaluation IS every clean step — any flag here is a
    # false positive on clean data and fails the campaign gate
    ref_packed = _packed_forward(params, cfg, pb, block_g=block_g,
                                 interpret=interp)
    need_dense = any(m.site == "s_c" for m in models)
    ref_dense = (_dense_forward(params, cfg, _make_dense_graphs(items, cfg))
                 if need_dense else None)
    clean_flags = int(ref_packed[1].sum()) + (
        int(ref_dense[1].sum()) if ref_dense is not None else 0)

    experiments = []
    for m in models:
        if verbose:
            print(f"fault_campaign: {m.label()} (seed={m.seed})")
        experiments.append(run_experiment(
            m, params=params, cfg=cfg, pb=pb, items=items,
            ref_packed=ref_packed, ref_dense=ref_dense, block_g=block_g,
            interpret=interp, n_steps=n_steps, guard_cfg=guard_cfg))

    tiers_total: Dict[str, Any] = {"slot": 0, "stripe": 0, "graph": 0,
                                   "restore": 0,
                                   "persistent_escalations": 0}
    persistent_sites: List[str] = []
    for e in experiments:
        for k in ("slot", "stripe", "graph", "restore",
                  "persistent_escalations"):
            tiers_total[k] += e.repair_tiers[k]
        persistent_sites.extend(e.repair_tiers["persistent_sites"])

    return {
        "benchmark": "fault_campaign",
        "backend": jax.default_backend(),
        "interpret": bool(interp),
        "authoritative": not bool(interp),
        "config": {"n_graphs": n_graphs, "n_steps": n_steps,
                   "n_lo": n_lo, "n_hi": n_hi, "feat": feat,
                   "hidden": hidden, "n_out": n_out, "block": block,
                   "threshold": threshold, "seed": seed,
                   "n_models": len(models)},
        "clean_control": {
            "flagged": clean_flags,
            "false_positive_rate":
                clean_flags / (pb.n_slots + (len(items) if need_dense
                                             else 0)),
        },
        "experiments": [e.to_dict() for e in experiments],
        "by_site_kind": _aggregate(experiments),
        "repair_tiers_total": {**tiers_total,
                               "persistent_sites":
                                   sorted(set(persistent_sites))},
    }


# ---------------------------------------------------------------------------
# the LM lane — guarded transformer serving under the same fault grid
# ---------------------------------------------------------------------------

def run_lm_experiment(model: FaultModel, *, prefill, decode, master, fold,
                      ref_logits, ref_tokens, tokens, prompt_len: int,
                      n_steps: int,
                      guard_cfg: Optional[GuardConfig] = None
                      ) -> ExperimentResult:
    """Run one LM fault model over a prefill + decode trajectory.

    The trajectory replays the CLEAN reference's greedy tokens, so every
    step's operands match the reference bitwise and divergence is a pure
    fault signal.  Weight sites corrupt the working params (the fold
    stays pristine — the post-load memory-fault class the offline eq.-5
    fold makes detectable); ``attn_accumulator`` rides the ``attn_inject``
    operand and fires once per step (the transient convention — the
    guard's retry re-executes clean).  Every step runs through a real
    :class:`ABFTGuard` whose restore refolds from the master, so flagged
    steps come back repaired and the repair-tier distribution is real.
    The naive-comparison / self-check columns are GCN-lane concepts and
    stay empty here (LM sites are all data-path)."""
    import jax.numpy as jnp

    inj = FaultInjector(model)
    state = {"params": fold(master)}

    def restore():
        state["params"] = fold(master)
        return state["params"]

    guard = ABFTGuard(guard_cfg if guard_cfg is not None
                      else GuardConfig(max_retries=1, max_restores=1,
                                       persistent_window=4,
                                       persistent_threshold=2),
                      restore_fn=restore)
    fired_steps: List[int] = []
    flagged_steps: List[int] = []
    sdc_steps: List[int] = []
    masked_steps: List[int] = []
    fp_steps: List[int] = []
    escalations = 0
    states = None

    for t in range(n_steps):          # t=0 prefill, t>=1 decode steps
        fired = inj.fires(t)
        if fired:
            fired_steps.append(t)
            if model.site in ("qkv_w", "mlp_w"):
                state["params"] = inj.apply_lm_params(state["params"])
        # fire-once box: a transient inject strikes the first attempt
        # only, so retries/replays re-execute clean
        box = {"v": float(inj.lm_inject()) if fired else 0.0}  # abftlint: sync-ok (host-side fault model)

        def pop():
            v, box["v"] = box["v"], 0.0
            return v

        flags0 = guard.flags
        try:
            if t == 0:
                (lg, states), _m = guard.run_step(
                    lambda params, batch: prefill(params, batch, pop()),
                    state["params"], {"tokens": tokens})
            else:
                (lg, states), _m = guard.run_step(
                    lambda params, st, tk, pos:
                        decode(params, st, tk, pos, pop()),
                    state["params"], states, ref_tokens[t - 1],
                    prompt_len + t - 1)
        except RuntimeError:
            # guard refused to verify after max_restores — eviction
            # advice.  Recover with a clean unguarded step so the
            # trajectory (decode states) can continue.
            escalations += 1
            flagged_steps.append(t)
            state["params"] = fold(master)
            if t == 0:
                (lg, states), _m = prefill(state["params"],
                                           {"tokens": tokens})
            else:
                (lg, states), _m = decode(state["params"], states,
                                          ref_tokens[t - 1],
                                          prompt_len + t - 1)
            continue

        flagged = guard.flags > flags0
        if flagged:
            flagged_steps.append(t)
        diverged = not np.array_equal(  # abftlint: sync-ok (host classify)
            np.asarray(lg), ref_logits[t])  # abftlint: sync-ok (host classify)
        if fired and not flagged:
            (sdc_steps if diverged else masked_steps).append(t)
        if not fired and flagged:
            fp_steps.append(t)

    detected_steps = [t for t in flagged_steps if t in fired_steps]
    detected = bool(detected_steps)
    latency = (detected_steps[0] - fired_steps[0]
               if detected and fired_steps else None)
    return ExperimentResult(
        model=model, steps=n_steps, fired_steps=fired_steps,
        flagged_steps=flagged_steps, naive_flagged_steps=[],
        detected=detected, detection_latency=latency,
        sdc_steps=sdc_steps, masked_steps=masked_steps,
        false_positive_steps=fp_steps,
        selfcheck_detected=False, selfcheck_step=None,
        would_be_false_negative=False,
        escalated=escalations > 0,
        repair_tiers=guard.repair_tiers())


def run_lm_fault_campaign(models: Optional[List[FaultModel]] = None, *,
                          n_decode: int = 3, prompt_len: int = 8,
                          batch: int = 1, cache_len: int = 32,
                          threshold: float = 1e-3, seed: int = 0,
                          guard_cfg: Optional[GuardConfig] = None,
                          verbose: bool = False) -> dict:
    """Sweep ``models`` (default: :func:`lm_sweep_models` grid) over a
    guarded smoke-LM serving trajectory; returns the JSON-ready payload
    in the same shape as :func:`run_fault_campaign`.

    The LM lane's CI gate mirrors the GCN ``accumulator`` gate: every
    above-threshold ``attn_accumulator`` upset must be detected, and the
    clean control must not flag."""
    import jax
    import jax.numpy as jnp

    from repro.configs import get_config, smoke_config
    from repro.engine.lm import (
        fold_lm_w_r,
        make_guarded_decode_step,
        make_guarded_prefill_step,
    )
    from repro.kernels.runtime import resolve_interpret
    from repro.models.transformer import init_model

    interp = resolve_interpret(None)
    cfg = smoke_config(get_config("gemma-2b"))
    abft = ABFTConfig(mode="fused", dtype=jnp.float32, threshold=threshold)
    master = init_model(cfg, jax.random.PRNGKey(seed))

    def fold(p):
        return fold_lm_w_r(p, cfg, abft)

    # one pair of jitted steps shared by every experiment (same shapes
    # throughout — exactly two compiles for the whole campaign)
    prefill = make_guarded_prefill_step(cfg, abft, cache_len)
    decode = make_guarded_decode_step(cfg, abft)
    if models is None:
        models = lm_sweep_models(step=1, seed=seed)
    n_steps = 1 + n_decode

    rng = np.random.default_rng(seed)
    tokens = jnp.asarray(rng.integers(1, cfg.vocab_size,
                                      size=(batch, prompt_len)), jnp.int32)

    # clean reference trajectory — greedy tokens recorded so every
    # experiment replays identical operands; any flag here is a clean
    # false positive and fails the campaign gate
    params0 = fold(master)
    (lg, states), m0 = prefill(params0, {"tokens": tokens})
    clean_flags = int(bool(np.asarray(m0["abft_flag"])))  # abftlint: sync-ok
    ref_logits = [np.asarray(lg)]
    ref_tokens = []
    for i in range(n_decode):
        nxt = np.asarray(  # abftlint: sync-ok (host greedy sample)
            lg[:, -1].argmax(-1)).astype(np.int32)[:, None]
        ref_tokens.append(jnp.asarray(nxt))
        (lg, states), mi = decode(params0, states, ref_tokens[-1],
                                  prompt_len + i)
        clean_flags += int(bool(np.asarray(mi["abft_flag"])))  # abftlint: sync-ok
        ref_logits.append(np.asarray(lg))  # abftlint: sync-ok (reference trace)

    experiments = []
    for m in models:
        if verbose:
            print(f"lm_fault_campaign: {m.label()} (seed={m.seed})")
        experiments.append(run_lm_experiment(
            m, prefill=prefill, decode=decode, master=master, fold=fold,
            ref_logits=ref_logits, ref_tokens=ref_tokens, tokens=tokens,
            prompt_len=prompt_len, n_steps=n_steps, guard_cfg=guard_cfg))

    tiers_total: Dict[str, Any] = {"slot": 0, "stripe": 0, "graph": 0,
                                   "restore": 0,
                                   "persistent_escalations": 0}
    persistent_sites: List[str] = []
    for e in experiments:
        for k in ("slot", "stripe", "graph", "restore",
                  "persistent_escalations"):
            tiers_total[k] += e.repair_tiers[k]
        persistent_sites.extend(e.repair_tiers["persistent_sites"])

    return {
        "benchmark": "lm_fault_campaign",
        "backend": jax.default_backend(),
        "interpret": bool(interp),
        "authoritative": not bool(interp),
        "config": {"model": cfg.name, "n_decode": n_decode,
                   "prompt_len": prompt_len, "batch": batch,
                   "cache_len": cache_len, "threshold": threshold,
                   "seed": seed, "n_models": len(models)},
        "clean_control": {
            "flagged": clean_flags,
            "false_positive_rate": clean_flags / n_steps,
        },
        "experiments": [e.to_dict() for e in experiments],
        "by_site_kind": _aggregate(experiments),
        "repair_tiers_total": {**tiers_total,
                               "persistent_sites":
                                   sorted(set(persistent_sites))},
    }
