"""Guarded transformer LM serving driver (benchmark mode).

The LM analog of ``repro.launch.serve_gcn``: prefill + greedy decode
through :class:`~repro.engine.lm.LMEngine`, i.e. under the full ABFT
ladder — every linear chain in the step is a checked op (QKV /
attention-out / MLP split corners, attention's fused carried-column
chain), per-op verdicts are keyed ``op:<id>`` for the guard, a flagged
step retries, a persistent flag refolds the working params from the
pristine master and replays, and recurring sites mark the backend
suspect.

The driver also makes the two acceptance claims executable:

* **clean overhead is checks-only** — on a clean run the guarded logits
  are verified bit-identical to the unguarded (``mode="none"``) forward,
  prefill and every decode step;
* **the ladder repairs** — ``--inject-at`` fires the attention
  accumulator fault operand on one step and the driver verifies it was
  flagged, repaired, and the final tokens match the clean reference.

    PYTHONPATH=src python -m repro.launch.serve_lm --new 16 \
        --inject-at 3 --json BENCH_lm_serve.json

The JSON payload carries the standard ``interpret``/``authoritative``
stamps (interpret-mode kernels make detection results functional but
timings non-authoritative, same convention as every other benchmark).
"""
from __future__ import annotations

import argparse
import json
import sys
import time
from typing import Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config, smoke_config
from repro.core.abft import ABFTConfig
from repro.engine.lm import LMEngine
from repro.kernels.runtime import resolve_interpret
from repro.models.transformer import model_decode, model_prefill


def _clean_reference(engine: LMEngine, tokens, n_new: int):
    """The unguarded ``mode='none'`` trajectory on the MASTER params:
    per-step logits + greedy tokens, the bit-identity baseline."""
    off = ABFTConfig(mode="none")
    cfg, params = engine.cfg, engine._master
    prefill = jax.jit(lambda p, b: model_prefill(p, cfg, b, off,
                                                 engine.cache_len))
    decode = jax.jit(lambda p, s, t, i: model_decode(p, cfg, s, t, i, off))
    logits, states, _ = prefill(params, {"tokens": tokens})
    ref_logits, ref_tokens = [np.asarray(logits)], []
    t0 = tokens.shape[1]
    for i in range(n_new):
        nxt = jnp.argmax(logits[:, -1], -1)[:, None].astype(jnp.int32)
        ref_tokens.append(np.asarray(nxt))  # abftlint: sync-ok (reference trace)
        logits, states, _ = decode(params, states, nxt,
                                   jnp.asarray(t0 + i, jnp.int32))
        ref_logits.append(np.asarray(logits))  # abftlint: sync-ok (reference trace)
    return ref_logits, ref_tokens


def main(argv: Optional[Sequence[str]] = None) -> dict:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="gemma-2b")
    ap.add_argument("--batch", type=int, default=2)
    ap.add_argument("--prompt", type=int, default=16)
    ap.add_argument("--new", type=int, default=16,
                    help="greedy decode steps after the prefill")
    ap.add_argument("--mode", default="fused",
                    choices=["none", "split", "fused"])
    ap.add_argument("--threshold", type=float, default=1e-3)
    ap.add_argument("--inject-at", type=int, default=None,
                    help="fire the attention-accumulator fault operand on "
                         "this decode step (-1 = during prefill) and "
                         "verify the guard detects + repairs it")
    ap.add_argument("--inject-delta", type=float, default=25.0)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--json", default="BENCH_lm_serve.json",
                    help="write the machine-readable payload here "
                         "('' disables)")
    ap.add_argument("--assert-clean", action="store_true",
                    help="exit non-zero unless guarded logits are "
                         "bit-identical to the unguarded forward (and the "
                         "injected fault, if any, was detected+repaired)")
    args = ap.parse_args(argv)

    interp = resolve_interpret(None)
    cfg = smoke_config(get_config(args.arch))
    abft = ABFTConfig(mode=args.mode, threshold=args.threshold,
                      relative=True)
    cache_len = args.prompt + args.new
    engine = LMEngine.init(cfg, abft, jax.random.PRNGKey(args.seed),
                           cache_len=cache_len)
    rng = np.random.default_rng(args.seed)
    tokens = jnp.asarray(rng.integers(1, cfg.vocab_size,
                                      size=(args.batch, args.prompt)),
                         jnp.int32)
    print(f"=== serve_lm: {cfg.name} batch={args.batch} "
          f"prompt={args.prompt} new={args.new} abft={args.mode} "
          f"({jax.default_backend()}) ===")

    # the bit-identity baseline: unguarded mode="none" on the master
    ref_logits, ref_tokens = _clean_reference(engine, tokens, args.new)

    # clean guarded pass (also the compile warmup for the timed phase)
    logits, states, _m = engine.prefill(tokens)
    identical = np.array_equal(np.asarray(logits), ref_logits[0])
    for i in range(args.new):
        nxt = jnp.argmax(logits[:, -1], -1)[:, None].astype(jnp.int32)
        identical &= np.array_equal(np.asarray(nxt), ref_tokens[i])  # abftlint: sync-ok
        logits, states, _m = engine.decode(states, nxt, args.prompt + i)
        identical &= np.array_equal(np.asarray(logits), ref_logits[i + 1])  # abftlint: sync-ok
    clean_flags = engine.guard.flags
    print(f"clean guarded trajectory bit-identical to unguarded: "
          f"{bool(identical)} (flags={clean_flags})")

    # timed sustained phase (shapes warm — measures the guarded steps)
    t0 = time.perf_counter()
    logits, states, _m = engine.prefill(tokens)
    t_prefill = time.perf_counter() - t0
    t0 = time.perf_counter()
    for i in range(args.new):
        nxt = jnp.argmax(logits[:, -1], -1)[:, None].astype(jnp.int32)
        logits, states, _m = engine.decode(states, nxt, args.prompt + i)
    jax.block_until_ready(logits)  # abftlint: sync-ok (benchmark timing barrier)
    t_decode = time.perf_counter() - t0
    ms_step = t_decode / max(args.new, 1) * 1e3
    print(f"prefill {args.batch}x{args.prompt}: {t_prefill*1e3:.0f} ms; "
          f"decoded {args.new} steps in {t_decode:.2f}s "
          f"({ms_step:.1f} ms/step)")

    # fault demo: one transient accumulator upset through the full ladder
    fault = None
    if args.inject_at is not None:
        flags0, retries0 = engine.guard.flags, engine.guard.retries
        toks, _stats = engine.generate(tokens, args.new,
                                       inject_at=args.inject_at,
                                       inject_delta=args.inject_delta)
        detected = engine.guard.flags > flags0
        repaired = np.array_equal(
            np.asarray(toks),
            np.concatenate(ref_tokens, axis=1)[:, :args.new])
        fault = {"inject_at": args.inject_at,
                 "inject_delta": args.inject_delta,
                 "detected": bool(detected),
                 "repaired_bitwise": bool(repaired),
                 "retries": engine.guard.retries - retries0}
        print(f"fault demo: inject_at={args.inject_at} "
              f"delta={args.inject_delta} detected={fault['detected']} "
              f"repaired_bitwise={fault['repaired_bitwise']}")

    stats = engine.stats()
    print(f"guard: steps={stats['steps']} flags={stats['flags']} "
          f"retries={stats['retries']} restores={stats['restores']} "
          f"flag_rate={stats['flag_rate']:.4f}")
    if interp:
        print("WARNING: interpret-mode kernels (no real accelerator) — "
              "detection results are functional, timings would NOT be "
              "authoritative")

    payload = {
        "benchmark": "lm_serve",
        "backend": jax.default_backend(),
        "interpret": bool(interp),
        "authoritative": not bool(interp),
        "config": {"arch": args.arch, "model": cfg.name,
                   "batch": args.batch, "prompt": args.prompt,
                   "new": args.new, "mode": args.mode,
                   "threshold": args.threshold, "seed": args.seed},
        "clean": {"bitwise_identical": bool(identical),
                  "flags": int(clean_flags)},
        "timings": {"prefill_ms": t_prefill * 1e3,
                    "decode_ms_per_step": ms_step},
        "fault": fault,
        "guard": stats,
    }
    if args.json:
        with open(args.json, "w") as fh:
            json.dump(payload, fh, indent=2, sort_keys=True)
            fh.write("\n")
        print(f"wrote {args.json}")

    if args.assert_clean:
        failures = []
        if not identical:
            failures.append("guarded logits diverged from the unguarded "
                            "forward on a clean run")
        if clean_flags:
            failures.append(f"clean run flagged {clean_flags} steps")
        if fault is not None and not (fault["detected"]
                                      and fault["repaired_bitwise"]):
            failures.append(f"injected fault not repaired: {fault}")
        if failures:
            for f in failures:
                print(f"FAIL: {f}", file=sys.stderr)
            sys.exit(1)
        print("gates: clean bit-identity" +
              (", fault detected+repaired" if fault else "") + " — ok")
    return payload


if __name__ == "__main__":
    main()
