"""jit-able train / prefill / decode step factories shared by the drivers
and the dry-run.  Pure (state, batch) -> (state, metrics) functions; the
ABFT flag rides in the metrics AND gates state adoption in-graph (a flagged
step is a no-op, so the runtime guard can retry without corrupting state).
"""
from __future__ import annotations

from functools import partial
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, ShapeConfig
from repro.core.abft import ABFTConfig
from repro.models.transformer import (
    init_decode_state,
    init_model,
    lm_loss,
    model_decode,
    model_forward,
    model_prefill,
)
from repro.optim import (
    AdamWConfig,
    adamw_init,
    adamw_update,
    clip_by_global_norm,
    cosine_warmup,
    ef_compress_grads,
)

Array = jax.Array


def make_train_step(cfg: ModelConfig, abft: ABFTConfig, opt: AdamWConfig,
                    *, total_steps: int = 10000, warmup: int = 200,
                    aux_weight: float = 1e-2, guard_in_graph: bool = True,
                    compress_grads: bool = False) -> Callable:
    def train_step(state: Dict[str, Any], batch: Dict[str, Array]
                   ) -> Tuple[Dict[str, Any], Dict[str, Array]]:
        def loss_fn(params):
            fwd_batch = {k: v for k, v in batch.items() if k != "labels"}
            logits, report, aux = model_forward(params, cfg, fwd_batch, abft)
            loss = lm_loss(logits, batch["labels"]) + aux_weight * aux
            return loss, report

        (loss, report), grads = jax.value_and_grad(
            loss_fn, has_aux=True)(state["params"])
        grads, gnorm = clip_by_global_norm(grads, opt.grad_clip)
        if compress_grads:
            grads, ef = ef_compress_grads(grads, state["ef"])
        lr_scale = cosine_warmup(state["opt"]["step"], warmup, total_steps)
        new_params, new_opt = adamw_update(state["params"], grads,
                                           state["opt"], opt, lr_scale)
        if guard_in_graph and abft.enabled:
            flag = report.flag
            sel = lambda new, old: jnp.where(flag, old, new)
            new_params = jax.tree.map(sel, new_params, state["params"])
            new_opt = jax.tree.map(sel, new_opt, state["opt"])
        new_state = {"params": new_params, "opt": new_opt}
        if compress_grads:
            new_state["ef"] = ef
        metrics = {
            "loss": loss.astype(jnp.float32),
            "grad_norm": gnorm.astype(jnp.float32),
            "abft_flag": report.flag,
            "abft_max_rel": report.max_rel,
            "abft_n_checks": report.n_checks,
        }
        return new_state, metrics

    return train_step


def make_prefill_step(cfg: ModelConfig, abft: ABFTConfig, cache_len: int
                      ) -> Callable:
    def prefill(params, batch):
        logits, states, report = model_prefill(params, cfg, batch, abft,
                                               cache_len)
        return logits, states, {"abft_flag": report.flag,
                                "abft_max_rel": report.max_rel}
    return prefill


def make_decode_step(cfg: ModelConfig, abft: ABFTConfig) -> Callable:
    def decode(params, states, tokens, pos):
        logits, states, report = model_decode(params, cfg, states, tokens,
                                              pos, abft)
        return logits, states, {"abft_flag": report.flag,
                                "abft_max_rel": report.max_rel}
    return decode


def init_train_state(cfg: ModelConfig, key, *, compress_grads: bool = False
                     ) -> Dict[str, Any]:
    params = init_model(cfg, key)
    state = {"params": params, "opt": adamw_init(params)}
    if compress_grads:
        state["ef"] = jax.tree.map(
            lambda x: jnp.zeros(x.shape, jnp.float32), params)
    return state
