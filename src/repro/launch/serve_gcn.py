"""Batched multi-graph GCN serving driver on the unified engine.

Variable-size graphs arrive as a stream, get bucketed/padded into fixed
[B, N, N] shapes (``repro.engine.batching``), and every batch runs one
jitted engine step (dense batched backend — one compile per bucket) under
``ABFTGuard``: a flagged batch retries, a persistently flagged batch would
restore.  Reports graphs/sec over the sustained phase.

    PYTHONPATH=src python -m repro.launch.serve_gcn --graphs 64 --batch 8 \
        --buckets 64,128 --abft fused
"""
from __future__ import annotations

import argparse
import time
from typing import Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.abft import ABFTConfig
from repro.core.gcn import init_gcn
from repro.engine import Graph, GraphBatch, gcn_apply, make_batches, \
    synth_graph_stream
from repro.runtime import ABFTGuard


def make_serve_step(params, cfg: ABFTConfig):
    """Jitted (s, h0) -> (logits, metrics) batched engine step.

    One compile per distinct (batch, bucket) shape; the dense backend
    broadcasts over the leading batch axis, so the whole batch contributes
    batched scalar checks reduced into one replicated report.
    """
    @jax.jit
    def step(s, h0):
        logits, report = gcn_apply(params, Graph(s=s, h0=h0), cfg,
                                   backend="dense")
        return logits, {"abft_flag": report.flag,
                        "abft_max_rel": report.max_rel,
                        "abft_n_checks": report.n_checks}
    return step


def serve(batches: Sequence[GraphBatch], params, cfg: ABFTConfig,
          guard: Optional[ABFTGuard] = None, verbose: bool = True):
    """Run every batch through the guarded jitted step; returns stats."""
    guard = guard if guard is not None else ABFTGuard()
    step = make_serve_step(params, cfg)
    # warmup compiles per bucket shape (excluded from the timed phase)
    shapes = {}
    for b in batches:
        shapes.setdefault((b.s.shape, b.h0.shape), b)
    for b in shapes.values():
        jax.block_until_ready(step(jnp.asarray(b.s), jnp.asarray(b.h0))[0])

    n_graphs = 0
    t0 = time.perf_counter()
    for b in batches:
        logits, _metrics = guard.run_step(step, jnp.asarray(b.s),
                                          jnp.asarray(b.h0))
        jax.block_until_ready(logits)
        n_graphs += b.n_graphs
    dt = time.perf_counter() - t0
    gps = n_graphs / max(dt, 1e-9)
    if verbose:
        print(f"served {n_graphs} graphs in {len(batches)} batches "
              f"({len(shapes)} bucket shapes) in {dt*1e3:.1f} ms "
              f"-> {gps:.1f} graphs/sec")
        print(f"guard: steps={guard.steps} flags={guard.flags} "
              f"retries={guard.retries} flag_rate={guard.flag_rate:.4f} "
              f"evict={guard.should_evict()}")
    return {"graphs": n_graphs, "batches": len(batches), "seconds": dt,
            "graphs_per_sec": gps, "flags": guard.flags}


def main(argv: Optional[Sequence[str]] = None) -> dict:
    ap = argparse.ArgumentParser()
    ap.add_argument("--graphs", type=int, default=64)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--buckets", default="64,128",
                    help="comma list of node-count buckets")
    ap.add_argument("--nodes", default="24,120",
                    help="lo,hi node-count range of the synthetic stream")
    ap.add_argument("--feat", type=int, default=16)
    ap.add_argument("--hidden", type=int, default=16)
    ap.add_argument("--classes", type=int, default=7)
    ap.add_argument("--abft", default="fused",
                    choices=["none", "split", "fused"])
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    buckets = [int(b) for b in args.buckets.split(",")]
    n_lo, n_hi = (int(v) for v in args.nodes.split(","))
    cfg = ABFTConfig(mode=args.abft, threshold=1e-3, relative=True)
    print(f"=== serve_gcn: {args.graphs} graphs, batch {args.batch}, "
          f"buckets {buckets}, abft={args.abft} "
          f"({jax.default_backend()}) ===")

    stream = synth_graph_stream(args.graphs, n_lo=n_lo, n_hi=n_hi,
                                feat=args.feat, seed=args.seed)
    batches = make_batches(stream, args.batch, buckets)
    params = init_gcn(jax.random.PRNGKey(args.seed),
                      (args.feat, args.hidden, args.classes))
    return serve(batches, params, cfg)


if __name__ == "__main__":
    main()
