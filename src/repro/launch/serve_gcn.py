"""Batched multi-graph GCN serving driver on the unified engine.

Variable-size graphs arrive as a stream and are batched one of two ways:

* ``--backend dense``      — bucketed zero-padding into [B, N, N] dense
  batches (one compile per bucket), O(B·N²·F) per bucket regardless of
  sparsity;
* ``--backend block_ell``  — block-diagonal packing into ONE block-ELL
  system per batch (``engine.batching.pack_graphs``): each graph pads only
  to the block size, aggregation runs through the spmm_abft Pallas kernel,
  and the fused epilogue segment-sums the per-stripe checksum partials into
  *per-graph* eq.-6 corners — serving cost scales with nnz, not N².

Both paths run under ``ABFTGuard.run_step_graphs``: the step emits a
per-graph verdict vector, so a flagged batch retries *only the flagged
graphs* (a small re-batch) instead of replaying the whole bucket; a
persistently flagged step falls back to restore->replay->verify.  With
``--check-granularity stripe`` (block_ell backend) the packed epilogue
keeps its per-row-stripe corners and the guard gains the surgical tier:
a flagged stripe's rows are gathered, re-executed through the fused
kernel, spliced, and re-verified (``engine.localize``) before any graph is
re-packed — the retry-escalation ladder is stripe -> graph -> whole-step
restore.  Per-layer ``w_r`` is folded once at weight-load time
(``engine.fold_w_r``), not recomputed per step.  Reports graphs/sec over
the sustained phase plus the stream-order per-graph verdicts.

    PYTHONPATH=src python -m repro.launch.serve_gcn --graphs 64 --batch 8 \
        --backend block_ell --block 32 --abft fused \
        --check-granularity stripe
"""
from __future__ import annotations

import argparse
import time
from typing import List, Optional, Sequence, Tuple, Union

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.abft import ABFTConfig, per_graph_report, \
    per_stripe_report, summarize
from repro.core.gcn import init_gcn
from repro.engine import Graph, GraphBatch, PackedGraphs, fold_w_r, \
    gcn_forward, make_batches, make_packed_batches, pack_graphs, \
    synth_graph_stream
from repro.engine.backends import BlockEllBackend
from repro.runtime import ABFTGuard

Batch = Union[GraphBatch, PackedGraphs]


def make_serve_step(params, cfg: ABFTConfig):
    """Jitted (s, h0) -> (logits, metrics) batched dense engine step.

    One compile per distinct (batch, bucket) shape; the dense backend
    broadcasts over the leading batch axis, so the batch contributes
    batched scalar checks — reduced into one replicated report AND kept
    per-graph for the guard's partial retry.
    """
    @jax.jit
    def step(s, h0):
        logits, checks = gcn_forward(params, Graph(s=s, h0=h0), cfg,
                                     backend="dense")
        report = summarize(checks, cfg)
        gflags, grel = per_graph_report(checks, cfg, s.shape[0])
        return logits, {"abft_flag": report.flag,
                        "abft_max_rel": report.max_rel,
                        "abft_n_checks": report.n_checks,
                        "abft_graph_flags": gflags,
                        "abft_graph_max_rel": grel}
    return step


def make_packed_serve_step(params, cfg: ABFTConfig, n_slots: int, *,
                           block_g: int = 128,
                           interpret: Optional[bool] = None,
                           fused_layer: bool = False,
                           granularity: str = "graph",
                           inject=None):
    """Jitted (cols, vals, segments, h0) -> (logits, metrics) packed step.

    The packed block-ELL arrays are *arguments*, not baked-in constants, so
    every batch of the same packed shape shares one compile; the segmented
    epilogue's per-graph corners feed both the replicated report and the
    per-graph verdict vector.  ``fused_layer=True`` runs each layer through
    the single-pass gcn_fused kernel (combination + aggregation + check in
    one HBM traversal) instead of the two-pass combination-then-spmm path.

    ``granularity="stripe"`` keeps the per-row-stripe corners: the metrics
    gain ``abft_stripe_flags`` / ``abft_stripe_max_rel`` ([checks,
    n_stripes] verdicts, the per-graph vector now segment-reduced from
    them) and ``abft_h_layers`` (every layer's input activations) — the
    operands the guard's surgical stripe retry needs.  ``inject`` is the
    benchmark/CI accumulator fault hook, ``(layer, stripe, slot, delta)``
    threaded to the fused kernel (requires ``fused_layer=True``).
    """
    interpret = (jax.default_backend() != "tpu" if interpret is None
                 else interpret)

    @jax.jit
    def step(cols, vals, segments, h0):
        bk = BlockEllBackend.from_staged(cols, vals, segments, n_slots, cfg,
                                         block_g=block_g,
                                         interpret=interpret,
                                         fused_layer=fused_layer,
                                         granularity=granularity,
                                         inject=inject)
        logits, checks, h_layers = gcn_forward(
            params, Graph(s=None, h0=h0), cfg, backend=bk,
            return_intermediates=True)
        report = summarize(checks, cfg)
        metrics = {"abft_flag": report.flag,
                   "abft_max_rel": report.max_rel,
                   "abft_n_checks": report.n_checks}
        if granularity == "stripe":
            gflags, grel = per_graph_report(checks, cfg, n_slots,
                                            segments=segments)
            sflags, srel = per_stripe_report(checks, cfg, vals.shape[0])
            metrics.update(abft_stripe_flags=sflags,
                           abft_stripe_max_rel=srel,
                           abft_h_layers=h_layers)
        else:
            gflags, grel = per_graph_report(checks, cfg, n_slots)
        metrics.update(abft_graph_flags=gflags, abft_graph_max_rel=grel)
        return logits, metrics
    return step


def _packed_args(pb: PackedGraphs) -> Tuple[jax.Array, ...]:
    return (jnp.asarray(pb.bell.block_cols), jnp.asarray(pb.bell.values),
            jnp.asarray(pb.stripe_graph), jnp.asarray(pb.h0))


class _PackedRunner:
    """Per-shape jitted packed steps + the per-graph retry closure."""

    def __init__(self, params, cfg: ABFTConfig, block_g: int,
                 fused_layer: bool = False, granularity: str = "graph"):
        self.params, self.cfg = params, cfg
        self.block_g = block_g
        self.fused_layer = fused_layer
        self.granularity = granularity
        self._steps = {}

    def step_for(self, pb: PackedGraphs):
        key = (pb.bell.values.shape, pb.h0.shape, pb.n_slots)
        if key not in self._steps:
            if self.fused_layer:
                self._warn_fallbacks(pb)
            self._steps[key] = make_packed_serve_step(
                self.params, self.cfg, pb.n_slots, block_g=self.block_g,
                fused_layer=self.fused_layer, granularity=self.granularity)
        return self._steps[key]

    def _warn_fallbacks(self, pb: PackedGraphs):
        """The VMEM-budget decision happens at trace time inside the jitted
        step, where it is invisible to the operator — so surface it eagerly,
        once per packed shape, from the layer widths we already know."""
        import warnings

        from repro.kernels.gcn_fused.ops import fused_layer_fits

        bm, bk = pb.bell.values.shape[2:4]
        wide = [tuple(layer["w"].shape) for layer in self.params["layers"]
                if not fused_layer_fits(*layer["w"].shape, bm, bk,
                                        block_g=self.block_g)]
        if wide:
            warnings.warn(
                f"--fused-layer: layer widths {wide} exceed the fused VMEM "
                f"budget; those layers run the two-pass kernel instead")

    def retry_fn(self, pb: PackedGraphs):
        """retry(out, idx): re-pack ONLY the flagged graphs into a small
        block-diagonal system (same block size as the parent batch),
        re-run, and patch their logit rows back — the unflagged graphs'
        verified rows are untouched.  Sub-pack steps share the same
        per-shape cache, so a flaky chip retrying one graph per batch
        compiles once, not per batch."""
        def retry(out, idx):
            items = [pb.items[i] for i in idx]
            sub = pack_graphs(items, block=pb.block,
                              stripe_multiple=pb.stripe_multiple,
                              width_multiple=pb.width_multiple)
            sub_logits, sub_metrics = self.step_for(sub)(*_packed_args(sub))
            n_layers = len(self.params["layers"])
            sub_metrics = {**sub_metrics,
                           "abft_rows_recomputed":
                               int(sub.bell.padded_rows) * n_layers}
            out = np.asarray(out).copy()
            for k, gi in enumerate(idx):
                o, n = pb.row_offsets[gi], pb.n_nodes[gi]
                so, sn = sub.row_offsets[k], sub.n_nodes[k]
                out[o:o + n] = np.asarray(sub_logits)[so:so + sn]
            return out, sub_metrics
        return retry

    def stripe_retry_fn(self, pb: PackedGraphs):
        """Surgical tier: gather the flagged stripes' tile rows, re-execute
        them through the fused kernel against the SAME packed operands,
        splice the rows back, and re-verify — no re-packing, no whole-graph
        replay (``engine.localize.surgical_stripe_retry``)."""
        from repro.engine.localize import surgical_stripe_retry

        def sretry(out, metrics):
            return surgical_stripe_retry(pb, self.params, self.cfg, out,
                                         metrics, block_g=self.block_g)
        return sretry


def _dense_retry_fn(step, b: GraphBatch):
    """retry(out, idx): re-run only the flagged slots as a smaller dense
    sub-batch and patch their logits back."""
    def retry(out, idx):
        sub_logits, sub_metrics = step(jnp.asarray(b.s[idx]),
                                       jnp.asarray(b.h0[idx]))
        out = np.asarray(out).copy()
        out[idx] = np.asarray(sub_logits)
        return out, sub_metrics
    return retry


def serve(batches: Sequence[Batch], params, cfg: ABFTConfig,
          guard: Optional[ABFTGuard] = None, verbose: bool = True, *,
          block_g: int = 128, fused_layer: bool = False,
          granularity: str = "graph"):
    """Run every batch through the guarded jitted step; returns stats.

    Dispatches per batch type (GraphBatch -> dense, PackedGraphs -> packed
    block-ELL); both report per-graph verdicts, assembled into stream order
    via each batch's ``indices``.  Retries re-pack at each batch's own
    block size (``PackedGraphs.block``).  ``fused_layer=True`` selects the
    single-pass gcn_fused kernel on the packed path (dense path unaffected).
    ``granularity="stripe"`` (packed batches only) keeps per-stripe check
    corners and arms the guard's surgical retry tier — the escalation
    ladder becomes stripe -> graph -> whole-step restore.
    """
    if granularity not in ("graph", "stripe"):
        raise ValueError(f"serve granularity {granularity!r} not in "
                         f"('graph', 'stripe')")
    guard = guard if guard is not None else ABFTGuard()
    params = fold_w_r(params, cfg)
    dense_step = None
    packed = _PackedRunner(params, cfg, block_g, fused_layer, granularity)

    def run_one(b: Batch, warm: bool):
        nonlocal dense_step
        stripe_retry = None
        if isinstance(b, PackedGraphs):
            step, args = packed.step_for(b), _packed_args(b)
            retry = packed.retry_fn(b)
            if granularity == "stripe":
                stripe_retry = packed.stripe_retry_fn(b)
        else:
            if granularity != "graph":
                raise ValueError("dense batches have no row-stripes; "
                                 "--check-granularity stripe needs "
                                 "--backend block_ell")
            if dense_step is None:
                dense_step = make_serve_step(params, cfg)
            step = dense_step
            args = (jnp.asarray(b.s), jnp.asarray(b.h0))
            retry = _dense_retry_fn(dense_step, b)
        if warm:
            out, metrics = step(*args)
        else:
            out, metrics = guard.run_step_graphs(
                step, retry, *args, stripe_retry_fn=stripe_retry)
        jax.block_until_ready(metrics["abft_graph_flags"])
        return out, metrics

    # warmup compiles per distinct shape (excluded from the timed phase)
    shapes = {}
    for b in batches:
        key = (b.s.shape, b.h0.shape) if isinstance(b, GraphBatch) \
            else (b.bell.values.shape, b.h0.shape, b.n_slots)
        shapes.setdefault(key, b)
    for b in shapes.values():
        jax.block_until_ready(run_one(b, warm=True)[0])

    n_graphs = 0
    n_stream = sum(b.n_graphs for b in batches)
    graph_flags = np.zeros(n_stream, bool)
    graph_max_rel = np.zeros(n_stream, np.float32)
    t0 = time.perf_counter()
    for b in batches:
        logits, metrics = run_one(b, warm=False)
        jax.block_until_ready(logits)
        n_graphs += b.n_graphs
        if b.indices is not None:
            live = b.indices >= 0
            graph_flags[b.indices[live]] = \
                np.asarray(metrics["abft_graph_flags"])[live]
            graph_max_rel[b.indices[live]] = \
                np.asarray(metrics["abft_graph_max_rel"])[live]
    dt = time.perf_counter() - t0
    gps = n_graphs / max(dt, 1e-9)
    kind = "packed block_ell" if any(isinstance(b, PackedGraphs)
                                     for b in batches) else "dense"
    if fused_layer and kind != "dense":
        kind += " (fused-layer)"
    if granularity == "stripe":
        kind += " [stripe corners]"
    if verbose:
        print(f"served {n_graphs} graphs in {len(batches)} {kind} batches "
              f"({len(shapes)} shapes) in {dt*1e3:.1f} ms "
              f"-> {gps:.1f} graphs/sec")
        print(f"guard: steps={guard.steps} flags={guard.flags} "
              f"retries={guard.retries} graph_retries={guard.graph_retries} "
              f"stripe_retries={guard.stripe_retries} "
              f"recomputed_rows={guard.recomputed_rows} "
              f"flag_rate={guard.flag_rate:.4f} "
              f"evict={guard.should_evict()}")
    return {"graphs": n_graphs, "batches": len(batches), "seconds": dt,
            "graphs_per_sec": gps, "flags": guard.flags,
            "graph_retries": guard.graph_retries,
            "stripe_retries": guard.stripe_retries,
            "recomputed_rows": guard.recomputed_rows,
            "graph_flags": graph_flags, "graph_max_rel": graph_max_rel}


def main(argv: Optional[Sequence[str]] = None) -> dict:
    ap = argparse.ArgumentParser()
    ap.add_argument("--graphs", type=int, default=64)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--backend", default="dense",
                    choices=["dense", "block_ell"],
                    help="dense bucketed padding, or block-diagonal packed "
                         "block-ELL on the Pallas kernel path")
    ap.add_argument("--buckets", default="64,128",
                    help="comma list of node-count buckets (dense backend)")
    ap.add_argument("--block", type=int, default=32,
                    help="square block size of the packed block-ELL layout "
                         "(block_ell backend; use 128 on TPU)")
    ap.add_argument("--nodes", default="24,120",
                    help="lo,hi node-count range of the synthetic stream")
    ap.add_argument("--feat", type=int, default=16)
    ap.add_argument("--hidden", type=int, default=16)
    ap.add_argument("--classes", type=int, default=7)
    ap.add_argument("--abft", default="fused",
                    choices=["none", "split", "fused"])
    ap.add_argument("--fused-layer", action="store_true",
                    help="run each packed layer through the single-pass "
                         "gcn_fused kernel (combination + aggregation + "
                         "check in one HBM traversal; block_ell backend)")
    ap.add_argument("--check-granularity", default="graph",
                    choices=["graph", "stripe"],
                    help="fault attribution: per packed graph (default) or "
                         "per row-stripe — stripe arms the guard's "
                         "surgical retry tier (block_ell backend)")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)
    if args.check_granularity == "stripe" and args.backend != "block_ell":
        ap.error("--check-granularity stripe needs --backend block_ell "
                 "(dense batches have no row-stripes)")

    buckets = [int(b) for b in args.buckets.split(",")]
    n_lo, n_hi = (int(v) for v in args.nodes.split(","))
    cfg = ABFTConfig(mode=args.abft, threshold=1e-3, relative=True)
    print(f"=== serve_gcn: {args.graphs} graphs, batch {args.batch}, "
          f"backend={args.backend}, abft={args.abft} "
          f"({jax.default_backend()}) ===")

    stream = synth_graph_stream(args.graphs, n_lo=n_lo, n_hi=n_hi,
                                feat=args.feat, seed=args.seed)
    if args.backend == "block_ell":
        batches: List[Batch] = make_packed_batches(
            stream, args.batch, block=args.block,
            stripe_multiple=4, width_multiple=4)
    else:
        batches = make_batches(stream, args.batch, buckets)
    params = init_gcn(jax.random.PRNGKey(args.seed),
                      (args.feat, args.hidden, args.classes))
    return serve(batches, params, cfg, fused_layer=args.fused_layer,
                 granularity=args.check_granularity)


if __name__ == "__main__":
    main()
