"""Closed-batch multi-graph GCN serving driver (benchmark mode).

This driver materializes a whole stream, packs it once, and replays the
batches — the right harness for apples-to-apples throughput benchmarks
(``benchmarks/serve_backends.py``), where arrival timing must not pollute
the measurement.  For continuous traffic use the streaming server
(``repro.launch.serve_stream`` / ``engine.streaming.StreamingEngine``):
bounded request queue, online packing into canonical rung shapes, p50/p99
latency accounting, and backpressure.  Both run the SAME machinery —
``engine.streaming.PackedRunner``'s jitted steps, retry ladders, and the
``ABFTGuard`` escalation ladder — this module is a thin client of it.

Variable-size graphs batch one of two ways:

* ``--backend dense``      — bucketed zero-padding into [B, N, N] dense
  batches (one compile per bucket), O(B·N²·F) per bucket regardless of
  sparsity;
* ``--backend block_ell``  — block-diagonal packing into ONE block-ELL
  system per batch (``engine.batching.pack_graphs``): each graph pads only
  to the block size, aggregation runs through the spmm_abft Pallas kernel,
  and the fused epilogue segment-sums the per-stripe checksum partials into
  *per-graph* eq.-6 corners — serving cost scales with nnz, not N².

Both paths run under ``ABFTGuard.run_step_graphs``: the step emits a
per-graph verdict vector, so a flagged batch retries *only the flagged
graphs* (a small re-batch) instead of replaying the whole bucket; a
persistently flagged step falls back to restore->replay->verify.  With
``--check-granularity stripe`` (block_ell backend) the packed epilogue
keeps its per-row-stripe corners and the guard gains the surgical tier:
a flagged stripe's rows are gathered, re-executed through the fused
kernel, spliced, and re-verified (``engine.localize``) before any graph is
re-packed — the retry-escalation ladder is stripe -> graph -> whole-step
restore.  Per-layer ``w_r`` is folded once at weight-load time
(``engine.fold_w_r``), not recomputed per step.  Reports graphs/sec over
the sustained phase plus the stream-order per-graph verdicts.

    PYTHONPATH=src python -m repro.launch.serve_gcn --graphs 64 --batch 8 \
        --backend block_ell --block 32 --abft fused \
        --check-granularity stripe
"""
from __future__ import annotations

import argparse
import time
from typing import List, Optional, Sequence, Union

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.abft import ABFTConfig
from repro.core.gcn import init_gcn
from repro.engine import GraphBatch, PackedGraphs, fold_w_r, \
    make_batches, make_packed_batches, synth_graph_stream
from repro.engine.streaming import (
    PackedRunner,
    dense_retry_fn,
    make_packed_serve_step,
    make_serve_step,
    packed_step_args,
)
from repro.runtime import ABFTGuard

Batch = Union[GraphBatch, PackedGraphs]

# long-standing private aliases, kept for callers that grew around the
# pre-streaming layout (benchmarks/localization.py, external notebooks)
_PackedRunner = PackedRunner
_packed_args = packed_step_args
_dense_retry_fn = dense_retry_fn


def serve(batches: Sequence[Batch], params, cfg: ABFTConfig,
          guard: Optional[ABFTGuard] = None, verbose: bool = True, *,
          block_g: int = 128, fused_layer: bool = False,
          fused_network: bool = False, vmem_budget: Optional[int] = None,
          granularity: str = "graph"):
    """Run every batch through the guarded jitted step; returns stats.

    Dispatches per batch type (GraphBatch -> dense, PackedGraphs -> packed
    block-ELL); both report per-graph verdicts, assembled into stream order
    via each batch's ``indices``.  Retries re-pack at each batch's own
    block size (``PackedGraphs.block``).  ``fused_layer=True`` selects the
    single-pass gcn_fused kernel on the packed path (dense path unaffected);
    ``fused_network=True`` tries the whole-network kernel first — every
    layer in ONE HBM traversal with activations resident in VMEM, falling
    back to the per-layer ladder when the depth-wide working set exceeds
    ``vmem_budget``.  ``granularity="stripe"`` (packed batches only) keeps
    per-stripe check corners and arms the guard's surgical retry tier;
    ``"slot"`` keeps per-(stripe, slot) telescoped corners and adds the
    slot-surgical rung below it — the escalation ladder becomes
    slot -> stripe -> graph -> whole-step restore.
    """
    if granularity not in ("graph", "stripe", "slot"):
        raise ValueError(f"serve granularity {granularity!r} not in "
                         f"('graph', 'stripe', 'slot')")
    guard = guard if guard is not None else ABFTGuard()
    params = fold_w_r(params, cfg)
    dense_step = None
    packed = PackedRunner(params, cfg, block_g, fused_layer, granularity,
                          fused_network=fused_network,
                          vmem_budget=vmem_budget)
    fusion = {"fused_hits": 0, "fused_fallbacks": 0,
              "network_hits": 0, "network_fallbacks": 0}

    def run_one(b: Batch, warm: bool):
        nonlocal dense_step
        stripe_retry = slot_retry = None
        if isinstance(b, PackedGraphs):
            step, args = packed.step_for(b), packed_step_args(b)
            retry = packed.retry_fn(b)
            if granularity in ("stripe", "slot"):
                stripe_retry = packed.stripe_retry_fn(b)
            if granularity == "slot":
                slot_retry = packed.slot_retry_fn(b)
            if not warm:
                for key, n in packed.fusion_counts(b).items():
                    fusion[key] += n
        else:
            if granularity != "graph":
                raise ValueError("dense batches have no row-stripes; "
                                 "--check-granularity stripe/slot needs "
                                 "--backend block_ell")
            if dense_step is None:
                dense_step = make_serve_step(params, cfg)
            step = dense_step
            args = (jnp.asarray(b.s), jnp.asarray(b.h0))
            retry = dense_retry_fn(dense_step, b)
        if warm:
            out, metrics = step(*args)
        else:
            out, metrics = guard.run_step_graphs(
                step, retry, *args, stripe_retry_fn=stripe_retry,
                slot_retry_fn=slot_retry)
        jax.block_until_ready(metrics["abft_graph_flags"])
        return out, metrics

    # warmup compiles per distinct shape (excluded from the timed phase)
    shapes = {}
    for b in batches:
        key = (b.s.shape, b.h0.shape) if isinstance(b, GraphBatch) \
            else (b.bell.values.shape, b.h0.shape, b.n_slots)
        shapes.setdefault(key, b)
    for b in shapes.values():
        jax.block_until_ready(run_one(b, warm=True)[0])  # abftlint: sync-ok (benchmark timing barrier)

    n_graphs = 0
    n_stream = sum(b.n_graphs for b in batches)
    graph_flags = np.zeros(n_stream, bool)
    graph_max_rel = np.zeros(n_stream, np.float32)
    t0 = time.perf_counter()
    for b in batches:
        logits, metrics = run_one(b, warm=False)
        jax.block_until_ready(logits)  # abftlint: sync-ok (benchmark timing barrier)
        n_graphs += b.n_graphs
        if b.indices is not None:
            live = b.indices >= 0
            graph_flags[b.indices[live]] = \
                np.asarray(metrics["abft_graph_flags"])[live]  # abftlint: sync-ok (benchmark result collection)
            graph_max_rel[b.indices[live]] = \
                np.asarray(metrics["abft_graph_max_rel"])[live]  # abftlint: sync-ok
    dt = time.perf_counter() - t0
    gps = n_graphs / max(dt, 1e-9)
    kind = "packed block_ell" if any(isinstance(b, PackedGraphs)
                                     for b in batches) else "dense"
    if fused_network and kind != "dense":
        kind += " (fused-network)"
    elif fused_layer and kind != "dense":
        kind += " (fused-layer)"
    if granularity == "stripe":
        kind += " [stripe corners]"
    elif granularity == "slot":
        kind += " [slot corners]"
    if verbose:
        print(f"served {n_graphs} graphs in {len(batches)} {kind} batches "
              f"({len(shapes)} shapes) in {dt*1e3:.1f} ms "
              f"-> {gps:.1f} graphs/sec")
        print(f"guard: steps={guard.steps} flags={guard.flags} "
              f"retries={guard.retries} graph_retries={guard.graph_retries} "
              f"stripe_retries={guard.stripe_retries} "
              f"slot_retries={guard.slot_retries} "
              f"recomputed_rows={guard.recomputed_rows} "
              f"flag_rate={guard.flag_rate:.4f} "
              f"evict={guard.should_evict()}")
        tiers = guard.repair_tiers()
        print(f"repair tiers: slot={tiers['slot']} "
              f"stripe={tiers['stripe']} graph={tiers['graph']} "
              f"restore={tiers['restore']} "
              f"persistent={tiers['persistent_escalations']} "
              f"suspect={tiers['suspect']}")
        if fusion["network_hits"] or fusion["network_fallbacks"] \
                or fusion["fused_hits"] or fusion["fused_fallbacks"]:
            print(f"fusion: network_hits={fusion['network_hits']} "
                  f"network_fallbacks={fusion['network_fallbacks']} "
                  f"fused_hits={fusion['fused_hits']} "
                  f"fused_fallbacks={fusion['fused_fallbacks']}")
    return {"graphs": n_graphs, "batches": len(batches), "seconds": dt,
            "graphs_per_sec": gps, "flags": guard.flags,
            "graph_retries": guard.graph_retries,
            "stripe_retries": guard.stripe_retries,
            "slot_retries": guard.slot_retries,
            "recomputed_rows": guard.recomputed_rows,
            "repair_tiers": guard.repair_tiers(),
            "graph_flags": graph_flags, "graph_max_rel": graph_max_rel,
            **fusion}


def main(argv: Optional[Sequence[str]] = None) -> dict:
    ap = argparse.ArgumentParser()
    ap.add_argument("--graphs", type=int, default=64)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--backend", default="dense",
                    choices=["dense", "block_ell"],
                    help="dense bucketed padding, or block-diagonal packed "
                         "block-ELL on the Pallas kernel path")
    ap.add_argument("--buckets", default="64,128",
                    help="comma list of node-count buckets (dense backend)")
    ap.add_argument("--block", type=int, default=32,
                    help="square block size of the packed block-ELL layout "
                         "(block_ell backend; use 128 on TPU)")
    ap.add_argument("--nodes", default="24,120",
                    help="lo,hi node-count range of the synthetic stream")
    ap.add_argument("--feat", type=int, default=16)
    ap.add_argument("--hidden", type=int, default=16)
    ap.add_argument("--classes", type=int, default=7)
    ap.add_argument("--abft", default="fused",
                    choices=["none", "split", "fused"])
    ap.add_argument("--fused-layer", action="store_true",
                    help="run each packed layer through the single-pass "
                         "gcn_fused kernel (combination + aggregation + "
                         "check in one HBM traversal; block_ell backend)")
    ap.add_argument("--fused-network", action="store_true",
                    help="run the WHOLE network through one kernel sweep "
                         "(activations ping-pong in VMEM, one HBM "
                         "traversal end-to-end; falls back to the "
                         "per-layer ladder when the depth-wide working "
                         "set exceeds the VMEM budget; block_ell backend)")
    ap.add_argument("--vmem-budget", type=int, default=None,
                    help="override the fused-kernel VMEM budget in bytes "
                         "(default: kernels.gcn_fused FUSED_VMEM_BUDGET)")
    ap.add_argument("--check-granularity", default="graph",
                    choices=["graph", "stripe", "slot"],
                    help="fault attribution: per packed graph (default), "
                         "per row-stripe, or per (stripe, slot) tile "
                         "column — stripe/slot arm the guard's surgical "
                         "retry tiers (block_ell backend)")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)
    if args.check_granularity != "graph" and args.backend != "block_ell":
        ap.error(f"--check-granularity {args.check_granularity} needs "
                 f"--backend block_ell (dense batches have no row-stripes)")
    if args.fused_network and args.backend != "block_ell":
        ap.error("--fused-network needs --backend block_ell")

    buckets = [int(b) for b in args.buckets.split(",")]
    n_lo, n_hi = (int(v) for v in args.nodes.split(","))
    cfg = ABFTConfig(mode=args.abft, threshold=1e-3, relative=True)
    print(f"=== serve_gcn: {args.graphs} graphs, batch {args.batch}, "
          f"backend={args.backend}, abft={args.abft} "
          f"({jax.default_backend()}) ===")

    stream = synth_graph_stream(args.graphs, n_lo=n_lo, n_hi=n_hi,
                                feat=args.feat, seed=args.seed)
    if args.backend == "block_ell":
        batches: List[Batch] = make_packed_batches(
            stream, args.batch, block=args.block,
            stripe_multiple=4, width_multiple=4)
    else:
        batches = make_batches(stream, args.batch, buckets)
    params = init_gcn(jax.random.PRNGKey(args.seed),
                      (args.feat, args.hidden, args.classes))
    return serve(batches, params, cfg, fused_layer=args.fused_layer,
                 fused_network=args.fused_network,
                 vmem_budget=args.vmem_budget,
                 granularity=args.check_granularity)


if __name__ == "__main__":
    main()
