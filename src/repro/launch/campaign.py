"""Fault-injection campaign driver: sweep fault models x sites and
report what the online ABFT checks catch, miss, and falsely flag.

    PYTHONPATH=src python -m repro.launch.campaign --steps 4 \
        --json BENCH_fault_campaign.json

``--smoke`` shrinks the sweep to one representative model per
(site, kind) cell for CI; ``--assert-gates`` exits non-zero unless
(a) every above-threshold accumulator upset was detected (the paper's
headline single-upset coverage claim) and (b) the clean control run
produced zero false positives.  Detection of data-path faults, measured
SDC rates for the architecturally-silent consistent-corruption sites
(features / cols_table), false-positive storms from finite check-path
corruption, and the would-be NaN false negatives closed by the NaN-safe
comparison + periodic self-check all land in the JSON payload, stamped
``interpret``/``authoritative`` like every other benchmark here.

``--lane lm`` runs the guarded-transformer grid instead (qkv_w / mlp_w
weight corruption + the attn_accumulator transient, served through
:class:`~repro.engine.lm.LMEngine`-style guarded steps); its gate is
the LM mirror of the accumulator gate — attn_accumulator AND weight
detection 100%, clean control clean:

    PYTHONPATH=src python -m repro.launch.campaign --lane lm \
        --assert-gates --json BENCH_lm_fault_campaign.json
"""
from __future__ import annotations

import argparse
import json
import sys
from typing import Optional, Sequence

from repro.faults.campaign import run_fault_campaign, run_lm_fault_campaign
from repro.faults.model import lm_sweep_models, sweep_models

# the per-lane gate prefixes asserted at 100% detection by --assert-gates
_GATED_SITES = {"gcn": ("accumulator/",),
                "lm": ("attn_accumulator/", "qkv_w/", "mlp_w/")}


def main(argv: Optional[Sequence[str]] = None) -> dict:
    ap = argparse.ArgumentParser()
    ap.add_argument("--lane", choices=("gcn", "lm"), default="gcn",
                    help="gcn: packed GCN serving grid (default); "
                         "lm: guarded transformer prefill/decode grid")
    ap.add_argument("--graphs", type=int, default=4,
                    help="graphs per packed serving batch")
    ap.add_argument("--steps", type=int, default=4,
                    help="serving steps per experiment")
    ap.add_argument("--reps", type=int, default=2,
                    help="seeded repetitions per (site, kind) cell")
    ap.add_argument("--nodes", default="12,32",
                    help="lo,hi node-count range of the synthetic graphs")
    ap.add_argument("--feat", type=int, default=8)
    ap.add_argument("--hidden", type=int, default=16)
    ap.add_argument("--classes", type=int, default=4)
    ap.add_argument("--block", type=int, default=8,
                    help="square block size of the packed block-ELL layout")
    ap.add_argument("--threshold", type=float, default=1e-3)
    ap.add_argument("--bit", type=int, default=30,
                    help="flipped bit position for bitflip kinds")
    ap.add_argument("--fault-step", type=int, default=1,
                    help="targeted-timing injection step")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--smoke", action="store_true",
                    help="one model per (site, kind) cell — the CI lane")
    ap.add_argument("--decode-steps", type=int, default=3,
                    help="[lm] decode steps after the prefill")
    ap.add_argument("--prompt-len", type=int, default=8,
                    help="[lm] prompt length of the prefill")
    ap.add_argument("--json", default=None,
                    help="write the machine-readable payload here "
                         "(default BENCH_<lane>_fault_campaign.json; "
                         "'' disables)")
    ap.add_argument("--assert-gates", action="store_true",
                    help="exit non-zero unless accumulator detection is "
                         "100%% and the clean control has zero flags")
    ap.add_argument("--verbose", action="store_true")
    args = ap.parse_args(argv)
    if args.json is None:
        args.json = ("BENCH_fault_campaign.json" if args.lane == "gcn"
                     else "BENCH_lm_fault_campaign.json")

    if args.lane == "lm":
        models = lm_sweep_models(reps=1 if args.smoke else args.reps,
                                 step=args.fault_step, bit=args.bit,
                                 seed=args.seed)
        print(f"=== lm_fault_campaign: {len(models)} fault models x "
              f"prefill+{args.decode_steps} decode steps ===")
        payload = run_lm_fault_campaign(
            models, n_decode=args.decode_steps, prompt_len=args.prompt_len,
            threshold=args.threshold, seed=args.seed, verbose=args.verbose)
    else:
        n_lo, n_hi = (int(v) for v in args.nodes.split(","))
        models = sweep_models(reps=1 if args.smoke else args.reps,
                              step=args.fault_step, bit=args.bit,
                              seed=args.seed)
        print(f"=== fault_campaign: {len(models)} fault models x "
              f"{args.steps} steps ({args.graphs} graphs/batch) ===")
        payload = run_fault_campaign(
            models, n_graphs=args.graphs, n_steps=args.steps,
            n_lo=n_lo, n_hi=n_hi, feat=args.feat, hidden=args.hidden,
            n_out=args.classes, block=args.block, threshold=args.threshold,
            seed=args.seed, verbose=args.verbose)

    for key, agg in payload["by_site_kind"].items():
        lat = agg["mean_detection_latency"]
        print(f"  {key:24s} det={agg['detection_rate']:.2f} "
              f"sdc={agg['sdc_rate']:.2f} "
              f"fp/step={agg['false_positive_step_rate']:.2f} "
              f"selfcheck={agg['selfcheck_detection_rate']:.2f} "
              + (f"latency={lat:.1f} " if lat is not None else "")
              + (f"would-be-FN={agg['would_be_false_negatives']} "
                 if agg["would_be_false_negatives"] else "")
              + (f"escalations={agg['escalations']}"
                 if agg["escalations"] else ""))
    tiers = payload["repair_tiers_total"]
    print(f"repair tiers: slot={tiers['slot']} stripe={tiers['stripe']} "
          f"graph={tiers['graph']} restore={tiers['restore']} "
          f"persistent_escalations={tiers['persistent_escalations']} "
          f"persistent_sites={len(tiers['persistent_sites'])}")
    print(f"clean control: {payload['clean_control']['flagged']} flags "
          f"(false-positive rate "
          f"{payload['clean_control']['false_positive_rate']:.3f})")
    if payload["interpret"]:
        print("WARNING: interpret-mode kernels (no real accelerator) — "
              "detection results are functional, timings would NOT be "
              "authoritative")

    if args.json:
        with open(args.json, "w") as fh:
            json.dump(payload, fh, indent=2, sort_keys=True)
            fh.write("\n")
        print(f"wrote {args.json}")

    if args.assert_gates:
        gated = _GATED_SITES[args.lane]
        failures = []
        for key, agg in payload["by_site_kind"].items():
            if key.startswith(gated) and agg["detection_rate"] < 1.0:
                failures.append(
                    f"{key}: detection {agg['detection_rate']:.2f} < 1.0 "
                    "for above-threshold gated-site upsets")
        if payload["clean_control"]["flagged"]:
            failures.append(
                f"clean control flagged "
                f"{payload['clean_control']['flagged']} steps "
                "(expected zero false positives)")
        if failures:
            for f in failures:
                print(f"FAIL: {f}", file=sys.stderr)
            sys.exit(1)
        print(f"gates: {'/'.join(g.rstrip('/') for g in gated)} "
              "detection 100%, clean control clean")
    return payload


if __name__ == "__main__":
    main()
