"""Multi-pod dry-run: lower + compile every (arch × shape × mesh) cell.

MUST be the very first two lines — jax locks the device count on first init:
"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

import argparse          # noqa: E402
import json              # noqa: E402
import re                # noqa: E402
import time              # noqa: E402
import traceback         # noqa: E402
from typing import Any, Dict, Optional  # noqa: E402

import jax               # noqa: E402
import numpy as np       # noqa: E402

from repro.configs import SHAPES, get_config, list_archs  # noqa: E402
from repro.configs.base import ModelConfig, ShapeConfig  # noqa: E402
from repro.core.abft import ABFTConfig  # noqa: E402
from repro.data.synthetic import make_batch_specs  # noqa: E402
from repro.launch.mesh import ShardingRules, make_production_mesh  # noqa: E402
from repro.launch.steps import (  # noqa: E402
    make_decode_step,
    make_prefill_step,
    make_train_step,
)
from repro.models.transformer import init_decode_state, init_model  # noqa: E402
from repro.optim import AdamWConfig  # noqa: E402

RESULTS = os.environ.get("DRYRUN_OUT", "results/dryrun")

# long_500k needs sub-quadratic attention — skips recorded per DESIGN.md.
def cell_supported(cfg: ModelConfig, shape: ShapeConfig) -> Optional[str]:
    if shape.name == "long_500k" and not cfg.sub_quadratic:
        return "full-attention arch: 500k decode is quadratic (DESIGN.md)"
    return None


# ---------------------------------------------------------------------------
# collective-byte extraction from the partitioned HLO
# ---------------------------------------------------------------------------

_DTYPE_BYTES = {"f64": 8, "f32": 4, "bf16": 2, "f16": 2, "s32": 4, "u32": 4,
                "s64": 8, "u64": 8, "s8": 1, "u8": 1, "pred": 1, "s16": 2,
                "u16": 2, "f8e4m3fn": 1, "f8e5m2": 1}

_COLL_RE = re.compile(
    r"=\s*(.*?)\s(all-reduce|all-gather|reduce-scatter|"
    r"all-to-all|collective-permute)(-start|-done)?\(")
_SHAPE_RE = re.compile(r"(\w+)\[([0-9,]*)\]")


def _shape_bytes(type_str: str) -> int:
    total = 0
    for m in _SHAPE_RE.finditer(type_str):
        dt, dims = m.group(1), m.group(2)
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)  # abftlint: sync-ok (offline dry run)
        total += n * _DTYPE_BYTES[dt]
    return total


def collective_bytes(hlo_text: str) -> Dict[str, Any]:
    """Per-device bytes moved by collectives, by op kind and loop depth.

    Conventions (EXPERIMENTS.md §Roofline methodology):
      * result-shape bytes per op; all-reduce counted 2× (ring = reduce-
        scatter + all-gather phases);
      * the partitioned module is per-device, so these are per-device bytes;
      * XLA prints while(scan) bodies once — each op records its `while/body`
        nesting depth from its op_name metadata so the roofline tool can
        weight by the known trip counts (layer-scan units, KV chunks, ...).
    """
    by_kind: Dict[str, float] = {}
    by_depth: Dict[str, float] = {}
    count = 0
    for line in hlo_text.splitlines():
        m = _COLL_RE.search(line)
        if not m:
            continue
        if m.group(3) == "-done":
            continue          # async pairs: count the -start only
        kind = m.group(2)
        b = _shape_bytes(m.group(1))
        factor = 2.0 if kind == "all-reduce" else 1.0
        depth = line.count("while/body")
        # scope-tagged depth key: 'time_scan' vs 'attn_chunk_scan' inner
        # loops have very different trip counts (T vs n_chunks)
        tag = str(depth)
        if depth >= 2 or (depth == 1 and ("time_scan" in line or
                                          "attn_chunk_scan" in line)):
            if "time_scan" in line:
                tag = f"{depth}t"
            elif "attn_chunk_scan" in line:
                tag = f"{depth}a"
        by_kind[kind] = by_kind.get(kind, 0.0) + b * factor
        by_depth[tag] = by_depth.get(tag, 0.0) + b * factor
        count += 1
    return {"per_device_bytes_unweighted": sum(by_kind.values()),
            "by_kind": by_kind, "by_depth": by_depth, "n_ops": count}


# ---------------------------------------------------------------------------
# cell construction
# ---------------------------------------------------------------------------

def build_cell(cfg: ModelConfig, shape: ShapeConfig, mesh, abft: ABFTConfig):
    """Returns (jitted_fn, arg_specs) ready for .lower(*arg_specs)."""
    rules = ShardingRules(mesh)
    param_shapes = jax.eval_shape(
        lambda: init_model(cfg, jax.random.PRNGKey(0)))
    pshard = rules.params_shardings(param_shapes)
    batch_specs = make_batch_specs(cfg, shape)
    bshard = rules.batch_shardings(batch_specs)
    rep = rules.replicated()

    if shape.kind == "train":
        opt_shapes = {
            "m": param_shapes, "v": param_shapes,
            "step": jax.ShapeDtypeStruct((), jax.numpy.int32)}
        oshard = {"m": pshard, "v": pshard, "step": rep}
        state_specs = {"params": param_shapes, "opt": opt_shapes}
        sshard = {"params": pshard, "opt": oshard}
        step = make_train_step(cfg, abft, AdamWConfig())
        fn = jax.jit(step, in_shardings=(sshard, bshard),
                     out_shardings=(sshard, rep))
        return fn, (state_specs, batch_specs)

    if shape.kind == "prefill":
        # VLM/audio stubs prepend 64 frame/patch embeddings to the stream
        prefix = 64 if (cfg.frontend and cfg.family != "encdec") else 0
        cache_len = shape.seq_len + prefix
        step = make_prefill_step(cfg, abft, cache_len=cache_len)
        state_shapes = jax.eval_shape(
            lambda: init_decode_state(cfg, shape.global_batch, cache_len))
        st_shard = rules.state_shardings(state_shapes, shape.global_batch,
                                         cfg.n_kv_heads)
        logits_shard = jax.sharding.NamedSharding(
            mesh, rules.batch_spec((shape.global_batch, 1, cfg.vocab_size),
                                   shape.global_batch))
        fn = jax.jit(step, in_shardings=(pshard, bshard),
                     out_shardings=(logits_shard, st_shard, rep))
        return fn, (param_shapes, batch_specs)

    # decode
    cache_len = shape.seq_len
    state_shapes = jax.eval_shape(
        lambda: init_decode_state(cfg, shape.global_batch, cache_len))
    st_shard = rules.state_shardings(state_shapes, shape.global_batch,
                                     cfg.n_kv_heads)
    step = make_decode_step(cfg, abft)
    logits_shard = jax.sharding.NamedSharding(
        mesh, rules.batch_spec((shape.global_batch, 1, cfg.vocab_size),
                               shape.global_batch))
    fn = jax.jit(step, in_shardings=(pshard, st_shard, bshard["tokens"], rep),
                 out_shardings=(logits_shard, st_shard, rep))
    pos_spec = jax.ShapeDtypeStruct((), jax.numpy.int32)
    return fn, (param_shapes, state_shapes, batch_specs["tokens"], pos_spec)


def run_cell(arch: str, shape_name: str, *, multi_pod: bool,
             abft_mode: str = "fused", out_dir: str = RESULTS,
             force: bool = False) -> Dict[str, Any]:
    mesh_tag = "pod2" if multi_pod else "pod1"
    os.makedirs(out_dir, exist_ok=True)
    out_path = os.path.join(
        out_dir, f"{arch}__{shape_name}__{mesh_tag}__{abft_mode}.json")
    if os.path.exists(out_path) and not force:
        with open(out_path) as f:
            cached = json.load(f)
        if cached.get("status") in ("ok", "skipped"):
            return cached        # errors are always retried

    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    rec: Dict[str, Any] = {
        "arch": arch, "shape": shape_name, "mesh": mesh_tag,
        "abft": abft_mode, "status": "?",
    }
    skip = cell_supported(cfg, shape)
    if skip:
        rec.update(status="skipped", reason=skip)
        _write(out_path, rec)
        return rec

    abft = ABFTConfig(mode=abft_mode, threshold=2e-2, relative=True)
    t0 = time.time()
    try:
        mesh = make_production_mesh(multi_pod=multi_pod)
        with mesh:
            fn, specs = build_cell(cfg, shape, mesh, abft)
            lowered = fn.lower(*specs)
            t_lower = time.time() - t0
            compiled = lowered.compile()
            t_compile = time.time() - t0 - t_lower
            mem = compiled.memory_analysis()
            from repro.launch.costs import xla_cost_analysis
            cost = xla_cost_analysis(compiled)
            coll = collective_bytes(compiled.as_text())
        rec.update(
            status="ok",
            lower_s=round(t_lower, 1),
            compile_s=round(t_compile, 1),
            flops_per_device=cost.get("flops", -1.0),
            bytes_per_device=cost.get("bytes accessed", -1.0),
            collectives=coll,
            memory={
                "argument_bytes": getattr(mem, "argument_size_in_bytes", -1),
                "output_bytes": getattr(mem, "output_size_in_bytes", -1),
                "temp_bytes": getattr(mem, "temp_size_in_bytes", -1),
                "peak_bytes": getattr(mem, "peak_memory_in_bytes", -1),
            },
            n_devices=int(np.prod(list(mesh.shape.values()))),
        )
    except Exception as e:  # noqa: BLE001 — a failed cell is a bug to record
        rec.update(status="error", error=f"{type(e).__name__}: {e}",
                   trace=traceback.format_exc()[-2000:])
    _write(out_path, rec)
    return rec


def _write(path: str, rec: Dict[str, Any]) -> None:
    with open(path, "w") as f:
        json.dump(rec, f, indent=1)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="all")
    ap.add_argument("--shape", default="all")
    ap.add_argument("--mesh", default="both", choices=["pod1", "pod2", "both"])
    ap.add_argument("--abft", default="fused")
    ap.add_argument("--out", default=RESULTS)
    ap.add_argument("--force", action="store_true")
    args = ap.parse_args()

    archs = list_archs() if args.arch == "all" else args.arch.split(",")
    shapes = list(SHAPES) if args.shape == "all" else args.shape.split(",")
    meshes = {"pod1": [False], "pod2": [True],
              "both": [False, True]}[args.mesh]
    n_ok = n_skip = n_err = 0
    for arch in archs:
        for shape in shapes:
            for mp in meshes:
                rec = run_cell(arch, shape, multi_pod=mp,
                               abft_mode=args.abft, out_dir=args.out,
                               force=args.force)
                tag = f"{arch:22s} {shape:12s} {'pod2' if mp else 'pod1'}"
                if rec["status"] == "ok":
                    n_ok += 1
                    print(f"OK    {tag} compile={rec['compile_s']}s "
                          f"flops/dev={rec['flops_per_device']:.3e} "
                          f"peak={rec['memory']['peak_bytes']/2**30:.2f}GiB "
                          f"coll(unw)={rec['collectives']['per_device_bytes_unweighted']/2**20:.1f}MiB",
                          flush=True)
                elif rec["status"] == "skipped":
                    n_skip += 1
                    print(f"SKIP  {tag} — {rec['reason']}", flush=True)
                else:
                    n_err += 1
                    print(f"ERROR {tag} — {rec['error']}", flush=True)
    print(f"\ndone: {n_ok} ok, {n_skip} skipped, {n_err} errors")
    if n_err:
        raise SystemExit(1)


if __name__ == "__main__":
    main()
