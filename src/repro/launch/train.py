"""Training driver.

Reduced configs run directly on CPU (this container); full configs target
the production mesh (use dryrun.py to validate the distribution plan
without hardware).

    PYTHONPATH=src python -m repro.launch.train --arch gemma-2b \
        --smoke --steps 100 --abft fused
"""
from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from repro.checkpoint import CheckpointManager
from repro.configs import get_config, smoke_config
from repro.core.abft import ABFTConfig
from repro.data.synthetic import SyntheticLM
from repro.launch.steps import init_train_state, make_train_step
from repro.optim import AdamWConfig
from repro.runtime import ABFTGuard, StragglerWatchdog


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true",
                    help="reduced same-family config (CPU-sized)")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--abft", default="fused",
                    choices=["none", "split", "fused"])
    ap.add_argument("--compress-grads", action="store_true")
    ap.add_argument("--ckpt-dir", default="results/ckpt")
    ap.add_argument("--ckpt-every", type=int, default=100)
    ap.add_argument("--lr", type=float, default=1e-3)
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if args.smoke:
        cfg = smoke_config(cfg)
    abft = ABFTConfig(mode=args.abft, threshold=5e-2, relative=True)

    state = init_train_state(cfg, jax.random.PRNGKey(0),
                             compress_grads=args.compress_grads)
    n = sum(x.size for x in jax.tree.leaves(state["params"]))
    print(f"arch={cfg.name} params={n/1e6:.2f}M abft={args.abft} "
          f"compress={args.compress_grads}")

    step_fn = jax.jit(make_train_step(
        cfg, abft, AdamWConfig(lr=args.lr), total_steps=args.steps,
        warmup=max(args.steps // 10, 1),
        compress_grads=args.compress_grads))
    ckpt = CheckpointManager(args.ckpt_dir, keep=3)
    restored, at = ckpt.restore(state)
    start = 0
    if restored is not None:
        state, start = restored, at
        print(f"resumed from step {at}")

    data = SyntheticLM(vocab_size=cfg.vocab_size, seq_len=args.seq,
                       batch_size=args.batch, seed=0)
    it = data.batches()
    guard = ABFTGuard(restore_fn=lambda: ckpt.restore(state)[0])
    wd = StragglerWatchdog()
    t0 = time.time()
    rng = np.random.default_rng(0)
    for i in range(start, args.steps):
        batch = {k: jax.numpy.asarray(v) for k, v in next(it).items()}
        if cfg.family == "encdec":
            batch["src_embeds"] = jax.numpy.asarray(
                rng.normal(size=(args.batch, args.seq, cfg.d_model)),
                jax.numpy.float32)
        elif cfg.frontend:
            batch["prefix_embeds"] = jax.numpy.zeros(
                (args.batch, 8, cfg.d_model), jax.numpy.float32)
        wd.start()
        state, m = guard.run_step(lambda s, b=batch: step_fn(s, b), state)
        wd.stop()
        if i % 20 == 0 or i == args.steps - 1:
            print(f"step {i:5d} loss={float(m['loss']):.4f} "  # abftlint: sync-ok (per-step logging is the demo)
                  f"abft_rel={float(m['abft_max_rel']):.1e}")  # abftlint: sync-ok
        if i and i % args.ckpt_every == 0:
            ckpt.save(i, state)
    ckpt.save(args.steps, state)
    ckpt.wait()
    dt = time.time() - t0
    print(f"done: {args.steps - start} steps, {dt:.1f}s, "
          f"abft flags {guard.flags}, straggler events {wd.events}")


if __name__ == "__main__":
    main()
