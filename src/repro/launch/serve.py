"""Serving driver: batched prefill + decode loop with ABFT-checked steps.

    PYTHONPATH=src python -m repro.launch.serve --arch gemma-2b --smoke \
        --batch 4 --prompt 64 --new 64 --abft fused
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config, smoke_config
from repro.core.abft import ABFTConfig
from repro.launch.steps import make_decode_step, make_prefill_step
from repro.models.transformer import init_model
from repro.runtime import ABFTGuard


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt", type=int, default=64)
    ap.add_argument("--new", type=int, default=64)
    ap.add_argument("--abft", default="fused",
                    choices=["none", "split", "fused"])
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if args.smoke:
        cfg = smoke_config(cfg)
    abft = ABFTConfig(mode=args.abft, threshold=5e-2, relative=True)
    params = init_model(cfg, jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)

    cache_len = args.prompt + args.new
    batch = {"tokens": jnp.asarray(
        rng.integers(0, cfg.vocab_size, (args.batch, args.prompt)), jnp.int32)}
    if cfg.family == "encdec":
        batch["src_embeds"] = jnp.asarray(
            rng.normal(size=(args.batch, args.prompt, cfg.d_model)),
            jnp.float32)
    elif cfg.frontend:
        batch["prefix_embeds"] = jnp.zeros(
            (args.batch, 8, cfg.d_model), jnp.float32)
        cache_len += 8

    prefill = jax.jit(make_prefill_step(cfg, abft, cache_len))
    decode = jax.jit(make_decode_step(cfg, abft))
    guard = ABFTGuard()

    t0 = time.time()
    logits, states, m = prefill(params, batch)
    print(f"prefill: {time.time()-t0:.2f}s flag={bool(m['abft_flag'])}")
    tok = jnp.argmax(logits[:, -1], -1)[:, None].astype(jnp.int32)
    pos0 = args.prompt + (8 if (cfg.frontend and cfg.family != "encdec") else 0)
    t0 = time.time()
    flags = 0
    for i in range(args.new - 1):
        logits, states, m = decode(params, states, tok,
                                   jnp.asarray(pos0 + i, jnp.int32))
        flags += int(bool(m["abft_flag"]))  # abftlint: sync-ok (benchmark result collection)
        tok = jnp.argmax(logits[:, -1], -1)[:, None].astype(jnp.int32)
    dt = time.time() - t0
    print(f"decode: {args.new - 1} steps in {dt:.2f}s "
          f"({dt/max(args.new-1,1)*1e3:.1f} ms/tok/batch), flags={flags}")


if __name__ == "__main__":
    main()
