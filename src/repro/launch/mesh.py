"""Production mesh + sharding rules.

Mesh: (data=16, model=16) per pod; (pod=2, data=16, model=16) across pods.
Importing this module never touches jax device state — mesh construction is
behind functions.

Sharding rules are path-based (MaxText-style logical axes):
  * parameters: largest non-'model' axis FSDP-shards over ('pod','data');
    head/expert/ff/vocab axes shard over 'model' when divisible;
  * batch shards over ('pod','data');
  * KV caches: kv-heads over 'model' when divisible, otherwise the cache
    *sequence* axis shards over 'model' (MQA case); batch over ('pod','data')
    unless batch == 1 (long_500k), where sequence sharding carries all of it.
"""
from __future__ import annotations

import math
import re
from typing import Any, Optional, Tuple

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P


def make_production_mesh(*, multi_pod: bool = False) -> Mesh:
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    need = math.prod(shape)
    devs = jax.devices()
    if len(devs) == need:
        return jax.make_mesh(shape, axes)
    if len(devs) < need:
        raise RuntimeError(
            f"need {need} devices for {shape}, have {len(devs)} — run under "
            f"XLA_FLAGS=--xla_force_host_platform_device_count=512 (dryrun.py "
            f"sets this before importing jax)")
    return Mesh(np.asarray(devs[:need]).reshape(shape), axes)


def make_test_mesh(shape: Tuple[int, ...], axes: Tuple[str, ...]) -> Mesh:
    need = math.prod(shape)
    devs = jax.devices()
    assert len(devs) >= need
    return Mesh(np.asarray(devs[:need]).reshape(shape), axes)


class ShardingRules:
    """Maps parameter/batch/cache paths to PartitionSpecs for a given mesh."""

    def __init__(self, mesh: Mesh, *, fsdp: bool = True,
                 shard_cache_seq_for_mqa: bool = True):
        self.mesh = mesh
        self.axes = mesh.axis_names
        self.model_size = mesh.shape["model"]
        dp = [a for a in ("pod", "data") if a in self.axes]
        self.dp: Any = tuple(dp) if len(dp) > 1 else dp[0]
        self.fsdp_axis: Any = self.dp if fsdp else None
        self.shard_cache_seq_for_mqa = shard_cache_seq_for_mqa

    # -- helpers ----------------------------------------------------------
    # pjit argument shardings require EXACT divisibility (uneven shards are
    # rejected) — every rule checks strictly and falls back to an alternate
    # axis or replication.

    @property
    def dp_size(self) -> int:
        ax = self.fsdp_axis if isinstance(self.fsdp_axis, tuple) else \
            (self.fsdp_axis,)
        return math.prod(self.mesh.shape[a] for a in ax if a)

    def _model_if_div(self, dim: int) -> Optional[str]:
        return "model" if dim > 0 and dim % self.model_size == 0 else None

    def _fsdp_if_div(self, dim: int):
        if self.fsdp_axis is None:
            return None
        return self.fsdp_axis if dim % self.dp_size == 0 else None

    # -- parameters -------------------------------------------------------

    def param_spec(self, path: str, shape: Tuple[int, ...]) -> P:
        stacked = bool(re.search(r"segments/\d+/", path))
        base = self._param_base(path, shape[1:] if stacked else shape)
        if stacked:
            base = (None,) + base
        assert len(base) == len(shape), (path, shape, base)
        return P(*base)

    def _param_base(self, path: str, s: Tuple[int, ...]) -> Tuple:
        fs = self._fsdp_if_div
        md = self._model_if_div
        # vocab is padded to a mesh multiple (ModelConfig.padded_vocab).
        # NEVER shard d_model of embed/head: the tied-head matmul would
        # contract over a sharded axis and all-reduce [B,T,V] activations
        # (§Perf hillclimb 1 — was ~190 GB/device/step on gemma train_4k).
        if path.endswith("embed/table"):
            return (md(s[0]), None)
        if path.endswith("head/w"):
            return (None, md(s[1]))
        # attention (3-D [d, heads, hd] — rwkv reuses wk/wv names for 2-D).
        # NEVER shard head_dim: a sharded score/AV contraction forces
        # per-chunk all-reduces and carry resharding in the streaming scan
        # (§Perf hillclimb: 32 GiB/chunk-iter on gemma).  Heads that do not
        # divide the model axis replicate (attention params are small; the
        # model axis still carries the MLP).
        for nm in ("wq/w", "wk/w", "wv/w"):
            if path.endswith(nm) and len(s) == 3:
                return (fs(s[0]), md(s[1]), None)
        for nm in ("wq/b", "wk/b", "wv/b"):
            if path.endswith(nm) and len(s) == 2:
                return (md(s[0]), None)
        if path.endswith("wo/w") and len(s) == 2 and ("attn" in path or
                                                      "xattn" in path):
            return (md(s[0]), fs(s[1]))
        # MoE
        if path.endswith("router/w"):
            return (fs(s[0]), md(s[1]))
        if "w_up" in path or "w_gate" in path:
            return (md(s[0]), fs(s[1]), None)
        if "w_down" in path:
            return (md(s[0]), None, fs(s[2]))
        if "gate_x" in path or "gate_a" in path:   # rglru block-diag gates
            return (md(s[0]), None, None)
        # MLP / rwkv / rglru dense params [d_in, d_out]
        if len(s) == 2 and path.endswith("/w"):
            # shard the bigger of ff-style dims over model
            if s[1] >= s[0]:
                if md(s[1]):
                    return (fs(s[0]), md(s[1]))
                return (md(s[0]), fs(s[1]))
            if md(s[0]):
                return (md(s[0]), fs(s[1]))
            return (fs(s[0]), md(s[1]))
        if len(s) == 2 and ("lora" in path or path.endswith("mu")):
            return (None, None)
        if len(s) == 3:      # e.g. rwkv lora_a [d,5,r] / lora_b [5,r,d]
            return (None, None, None) if s[0] <= 8 else (fs(s[0]), None, None)
        if len(s) == 1:
            return (None,)
        return tuple(None for _ in s)

    def _combined_if_div(self, dim: int):
        """('pod','data','model') stacked on one axis when divisible."""
        ax = (self.fsdp_axis if isinstance(self.fsdp_axis, tuple)
              else (self.fsdp_axis,)) if self.fsdp_axis else ()
        combo = tuple(a for a in ax if a) + ("model",)
        size = self.dp_size * self.model_size
        if dim % size == 0:
            return combo
        return self._fsdp_if_div(dim) or self._model_if_div(dim)

    def params_shardings(self, params_shapes) -> Any:
        """pytree of NamedSharding matching a pytree of ShapeDtypeStruct."""
        flat, treedef = jax.tree_util.tree_flatten_with_path(params_shapes)
        out = []
        for path, leaf in flat:
            key = "/".join(_p(p) for p in path)
            spec = self.param_spec(key, tuple(leaf.shape))
            out.append(NamedSharding(self.mesh, spec))
        return jax.tree_util.tree_unflatten(
            jax.tree_util.tree_structure(params_shapes), out)

    # -- batch / activations ----------------------------------------------

    def batch_spec(self, shape: Tuple[int, ...], batch_size: int) -> P:
        dp = self.dp if batch_size > 1 else None
        return P(dp, *(None,) * (len(shape) - 1))

    def batch_shardings(self, batch_specs) -> Any:
        def one(leaf):
            return NamedSharding(self.mesh,
                                 self.batch_spec(leaf.shape, leaf.shape[0]))
        return jax.tree.map(one, batch_specs)

    # -- decode state -----------------------------------------------------

    def cache_spec(self, path: str, shape: Tuple[int, ...], batch: int,
                   n_kv: int) -> P:
        """Shapes carry a leading [count] (stacked units) axis."""
        dp = self.dp if batch > 1 else None
        kv_sharded = n_kv % self.model_size == 0
        if path.endswith("/k") or path.endswith("/v") or \
                path.endswith("xk") or path.endswith("xv"):
            # [count, B, L, Kh, hd]
            if kv_sharded:
                return P(None, dp, None, "model", None)
            if self.shard_cache_seq_for_mqa:
                return P(None, dp, "model", None, None)
            return P(None, dp, None, None, None)
        if path.endswith("/vr") or path.endswith("xvr"):
            # [count, B, L, H] — mirror k's L sharding
            if kv_sharded:
                return P(None, dp, None,
                         self._model_if_div(shape[3]))
            if self.shard_cache_seq_for_mqa:
                return P(None, dp, "model", None)
            return P(None, dp, None, None)
        if path.endswith("/pos"):
            if not kv_sharded and self.shard_cache_seq_for_mqa:
                return P(None, dp, "model")
            return P(None, dp, None)
        if path.endswith("wkv"):          # [count, B, H, hd, hd]
            return P(None, dp, self._model_if_div(shape[2]), None, None)
        if path.endswith("/h"):           # rglru [count, B, dr]
            return P(None, dp, self._model_if_div(shape[2]))
        if path.endswith("conv"):         # [count, B, K-1, dr]
            return P(None, dp, None, self._model_if_div(shape[3]))
        if path.endswith("x_tm") or path.endswith("x_cm"):
            return P(None, dp, None)
        return P(*(None,) * len(shape))

    def state_shardings(self, state_shapes, batch: int, n_kv: int) -> Any:
        flat, treedef = jax.tree_util.tree_flatten_with_path(state_shapes)
        out = []
        for path, leaf in flat:
            key = "/".join(_p(p) for p in path)
            spec = self.cache_spec(key, tuple(leaf.shape), batch, n_kv)
            out.append(NamedSharding(self.mesh, spec))
        return jax.tree_util.tree_unflatten(
            jax.tree_util.tree_structure(state_shapes), out)

    def replicated(self) -> NamedSharding:
        return NamedSharding(self.mesh, P())


# ---------------------------------------------------------------------------
# Graph (GCN engine) sharding rules.  The block-ELL aggregation shards by
# row-stripe: the tile table and its column-index table split on one mesh
# axis, activations stay replicated (any stripe may gather any X row), and
# the per-shard checksum partials psum into a replicated report — so the
# only sharded tensors are the adjacency tiles and the output rows.
# ---------------------------------------------------------------------------

def make_graph_mesh(n_devices: Optional[int] = None,
                    axis: str = "graph") -> Mesh:
    """1-D mesh over (a prefix of) the local devices for stripe sharding."""
    devs = jax.devices()
    n = len(devs) if n_devices is None else n_devices
    if len(devs) < n:
        raise RuntimeError(
            f"need {n} devices for a {axis}={n} mesh, have {len(devs)} — run "
            f"under XLA_FLAGS=--xla_force_host_platform_device_count={n}")
    return Mesh(np.asarray(devs[:n]), (axis,))


class GraphShardingRules:
    """PartitionSpecs for the stripe-sharded block-ELL engine backend."""

    def __init__(self, mesh: Mesh, axis: str = "graph"):
        assert axis in mesh.axis_names, (axis, mesh.axis_names)
        self.mesh = mesh
        self.axis = axis

    @property
    def n_shards(self) -> int:
        return self.mesh.shape[self.axis]

    def stripe_spec(self) -> P:
        """block_cols [nbm, width] — stripes over the graph axis."""
        return P(self.axis)

    def tile_spec(self) -> P:
        """values [nbm, width, bm, bk] — stripes over the graph axis."""
        return P(self.axis)

    def activation_spec(self) -> P:
        """X / x_r stay replicated: column blocks gather arbitrary rows."""
        return P()

    def out_spec(self) -> P:
        """H_out rows live where their stripes live."""
        return P(self.axis)

    def report_spec(self) -> P:
        """Checks psum to replicated scalars."""
        return P()

    def stripe_report_spec(self) -> P:
        """Per-stripe check corners (granularity='stripe'): each shard's
        [nbm_local] partials stay on the stripe axis and concatenate into
        the global per-stripe vector instead of psum-collapsing."""
        return P(self.axis)

    def block_ell_shardings(self) -> Tuple[NamedSharding, NamedSharding]:
        """(cols, values) NamedShardings for device_put staging."""
        return (NamedSharding(self.mesh, self.stripe_spec()),
                NamedSharding(self.mesh, self.tile_spec()))


def _p(p) -> str:
    if hasattr(p, "key"):
        return str(p.key)
    if hasattr(p, "idx"):
        return str(p.idx)
    return str(p)
