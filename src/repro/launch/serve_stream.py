"""Streaming GCN serving driver: continuous traffic through the
``engine.streaming.StreamingEngine``.

Requests arrive one at a time (optionally rate-limited to simulate a
live client), are packed online into the canonical rung shapes planned
from a leading profile of the stream, and dispatch double-buffered under
the ABFT guard.  Reports the latency SLO view a serving deployment
actually watches — per-request enqueue->verdict p50/p99 — alongside
throughput, backpressure rejections, and the bounded-compile accounting
(jit entries vs rung-table size).

    PYTHONPATH=src python -m repro.launch.serve_stream --graphs 200 \
        --slots 8 --block 16 --deadline-ms 50 --assert-bounded-compiles

``--assert-bounded-compiles`` exits non-zero when the engine compiled
more distinct shapes than the rung table holds (no oversize/retry traffic
in the synthetic stream, so rung shapes are the whole budget) — the CI
gate for the streaming engine's central contract.
"""
from __future__ import annotations

import argparse
import json
import sys
import time
from typing import Optional, Sequence

import jax

from repro.core.abft import ABFTConfig
from repro.core.gcn import init_gcn
from repro.engine import StreamingEngine, plan_rungs, synth_graph_stream


def main(argv: Optional[Sequence[str]] = None) -> dict:
    ap = argparse.ArgumentParser()
    ap.add_argument("--graphs", type=int, default=200,
                    help="synthetic stream length (requests)")
    ap.add_argument("--slots", type=int, default=8,
                    help="graph slots per canonical packed shape")
    ap.add_argument("--block", type=int, default=16,
                    help="square block size of the packed block-ELL layout")
    ap.add_argument("--nodes", default="8,48",
                    help="lo,hi node-count range of the synthetic stream")
    ap.add_argument("--feat", type=int, default=8)
    ap.add_argument("--hidden", type=int, default=8)
    ap.add_argument("--classes", type=int, default=3)
    ap.add_argument("--abft", default="fused",
                    choices=["none", "split", "fused"])
    ap.add_argument("--fused-layer", action="store_true")
    ap.add_argument("--fused-network", action="store_true",
                    help="whole-network kernel: every layer in one HBM "
                         "traversal, activations resident in VMEM (falls "
                         "back per batch when over the VMEM budget)")
    ap.add_argument("--vmem-budget", type=int, default=None,
                    help="override the fused-kernel VMEM budget in bytes")
    ap.add_argument("--check-granularity", default="graph",
                    choices=["graph", "stripe", "slot"])
    ap.add_argument("--profile", type=int, default=32,
                    help="leading requests used as the rung-planning "
                         "traffic profile")
    ap.add_argument("--queue-capacity", type=int, default=64)
    ap.add_argument("--deadline-ms", type=float, default=50.0,
                    help="flush-on-deadline for partial bins (<=0 disables)")
    ap.add_argument("--rate", type=float, default=0.0,
                    help="simulated request arrival rate in req/s "
                         "(0 = as fast as possible)")
    ap.add_argument("--oversize", default="singleton",
                    choices=["singleton", "reject"],
                    help="oversized-request policy: dedicated singleton "
                         "shape, or explicit rejection verdict")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--json", default="BENCH_stream.json",
                    help="write machine-readable stats here ('' disables)")
    ap.add_argument("--assert-bounded-compiles", action="store_true",
                    help="exit non-zero if jit entries exceed the rung "
                         "table size")
    args = ap.parse_args(argv)

    n_lo, n_hi = (int(v) for v in args.nodes.split(","))
    cfg = ABFTConfig(mode=args.abft, threshold=1e-3, relative=True)
    interpret = jax.default_backend() != "tpu"
    print(f"=== serve_stream: {args.graphs} requests, slots {args.slots}, "
          f"block {args.block}, abft={args.abft} "
          f"({jax.default_backend()}{', interpret' if interpret else ''}) "
          f"===")

    stream = synth_graph_stream(args.graphs, n_lo=n_lo, n_hi=n_hi,
                                feat=args.feat, seed=args.seed)
    rungs = plan_rungs(stream[:max(args.profile, 1)], n_slots=args.slots,
                       block=args.block, stripe_multiple=4,
                       width_multiple=4)
    print(f"rung table ({len(rungs)} canonical shapes): "
          + ", ".join(f"[{r.stripe_cap} stripes x {r.width_cap} wide "
                      f"x {r.n_slots} graphs]" for r in rungs.rungs))
    params = init_gcn(jax.random.PRNGKey(args.seed),
                      (args.feat, args.hidden, args.classes))
    engine = StreamingEngine(
        params, cfg, rungs,
        queue_capacity=args.queue_capacity,
        flush_deadline=(args.deadline_ms / 1e3
                        if args.deadline_ms > 0 else None),
        oversize_policy=args.oversize,
        fused_layer=args.fused_layer,
        fused_network=args.fused_network,
        vmem_budget=args.vmem_budget,
        granularity=args.check_granularity,
        keep_logits=False)
    engine.warmup()

    results = []
    gap = 1.0 / args.rate if args.rate > 0 else 0.0
    for s, h0 in stream:
        engine.submit(s, h0)
        results.extend(engine.take_results())
        if gap:
            time.sleep(gap)
            engine.pump()
    results.extend(engine.drain())
    stats = engine.stats(results)

    p50 = stats["latency_p50_ms"]
    p99 = stats["latency_p99_ms"]
    print(f"served {stats['served']}/{stats['submitted']} requests in "
          f"{stats['batches']} batches "
          f"(rejected {stats['rejected']}, "
          f"oversize {stats['rejected_oversize']} "
          f"[{args.oversize}], singletons "
          f"{stats['singleton_dispatches']})")
    print(f"latency enqueue->verdict: p50 "
          + (f"{p50:.1f} ms" if p50 is not None else "n/a")
          + ", p99 "
          + (f"{p99:.1f} ms" if p99 is not None else "n/a")
          + (f"; {stats['graphs_per_sec']:.1f} graphs/sec"
             if stats["graphs_per_sec"] else ""))
    print(f"compiles: {stats['compiles']} jit entries vs rung table "
          f"{stats['rung_table_size']} "
          f"(+{stats['singleton_dispatches']} singleton dispatches); "
          f"guard flags={stats['guard_flags']} "
          f"retries={stats['guard_retries']}")
    tiers = stats["repair_tiers"]
    if tiers:
        print(f"repair tiers: slot={tiers['slot']} "
              f"stripe={tiers['stripe']} graph={tiers['graph']} "
              f"restore={tiers['restore']} "
              f"persistent={tiers['persistent_escalations']}; "
              f"backend={stats['active_backend']} "
              f"(degrades={stats['degrades']} "
              f"failovers={stats['failovers']} "
              f"hang_flushes={stats['hang_flushes']})")
    if args.fused_layer or args.fused_network:
        print(f"fusion: network_hits={stats['network_hits']} "
              f"network_fallbacks={stats['network_fallbacks']} "
              f"fused_hits={stats['fused_hits']} "
              f"fused_fallbacks={stats['fused_fallbacks']}")
    if interpret:
        print("WARNING: interpret-mode kernels (no real accelerator) — "
              "latency/throughput numbers are NOT authoritative")

    if args.json:
        rec = {"bench": "serve_stream",
               "device_backend": jax.default_backend(),
               "interpret": interpret,
               "authoritative": not interpret,
               "config": {"graphs": args.graphs, "slots": args.slots,
                          "block": args.block, "nodes": [n_lo, n_hi],
                          "feat": args.feat, "hidden": args.hidden,
                          "classes": args.classes, "abft": args.abft,
                          "fused_layer": args.fused_layer,
                          "fused_network": args.fused_network,
                          "vmem_budget": args.vmem_budget,
                          "granularity": args.check_granularity,
                          "queue_capacity": args.queue_capacity,
                          "deadline_ms": args.deadline_ms,
                          "rate": args.rate, "seed": args.seed},
               "rungs": [vars(r) for r in rungs.rungs],
               "stats": {k: v for k, v in stats.items()}}
        with open(args.json, "w") as fh:
            json.dump(rec, fh, indent=2, sort_keys=True)
            fh.write("\n")
        print(f"wrote {args.json}")

    if args.assert_bounded_compiles and \
            stats["compiles"] > stats["rung_table_size"]:
        print(f"FAIL: {stats['compiles']} jit entries > rung table size "
              f"{stats['rung_table_size']} — compiles are not bounded",
              file=sys.stderr)
        sys.exit(1)
    return stats


if __name__ == "__main__":
    main()
