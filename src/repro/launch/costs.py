"""XLA compiled-cost helpers shared by dryrun and the benchmarks."""
from __future__ import annotations

from typing import Dict


def xla_cost_analysis(compiled) -> Dict[str, float]:
    """Normalized compiled.cost_analysis() across jaxlib versions.

    jaxlib ≤0.4.32 returns one properties dict; newer jaxlibs return a
    list of dicts (per computation).  Walk whichever shape we get and
    merge to a flat {metric: value} dict so callers can index
    ``["flops"]`` unconditionally.
    """
    ca = compiled.cost_analysis()
    if isinstance(ca, dict):
        return dict(ca)
    merged: Dict[str, float] = {}
    for props in ca:
        for key, val in props.items():
            merged[key] = merged.get(key, 0.0) + float(val)  # abftlint: sync-ok (offline cost table)
    return merged
