"""AdamW with decoupled weight decay — no optax in this container, so the
optimizer is implemented natively.  State is a pytree mirroring params
(m, v in float32), FSDP-sharded with the same specs as the parameters.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

Params = Any


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0


def adamw_init(params: Params) -> Dict[str, Any]:
    zeros = lambda p: jax.tree.map(
        lambda x: jnp.zeros(x.shape, jnp.float32), p)
    return {"m": zeros(params), "v": zeros(params),
            "step": jnp.zeros((), jnp.int32)}


def adamw_update(params: Params, grads: Params, state: Dict[str, Any],
                 cfg: AdamWConfig, lr_scale) -> Tuple[Params, Dict[str, Any]]:
    step = state["step"] + 1
    b1t = 1.0 - cfg.b1 ** step.astype(jnp.float32)
    b2t = 1.0 - cfg.b2 ** step.astype(jnp.float32)
    lr = cfg.lr * lr_scale

    def upd(p, g, m, v):
        g = g.astype(jnp.float32)
        m = cfg.b1 * m + (1 - cfg.b1) * g
        v = cfg.b2 * v + (1 - cfg.b2) * jnp.square(g)
        mhat = m / b1t
        vhat = v / b2t
        delta = mhat / (jnp.sqrt(vhat) + cfg.eps)
        if p.ndim >= 2:                       # decay matrices, not norms/bias
            delta = delta + cfg.weight_decay * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - lr * delta).astype(p.dtype), m, v

    flat_p, treedef = jax.tree.flatten(params)
    flat_g = treedef.flatten_up_to(grads)
    flat_m = treedef.flatten_up_to(state["m"])
    flat_v = treedef.flatten_up_to(state["v"])
    out = [upd(p, g, m, v) for p, g, m, v in
           zip(flat_p, flat_g, flat_m, flat_v)]
    new_p = treedef.unflatten([o[0] for o in out])
    new_m = treedef.unflatten([o[1] for o in out])
    new_v = treedef.unflatten([o[2] for o in out])
    return new_p, {"m": new_m, "v": new_v, "step": step}
