"""int8 gradient compression with error feedback for DP all-reduce.

At 1000+ nodes the data-parallel gradient all-reduce is the dominant
cross-pod collective.  Quantizing to int8 with per-tensor scale cuts those
bytes 4× (bf16) / 2× (already-bf16 comms); the quantization residual is fed
back into the next step's gradient (error feedback), which keeps convergence
(Karimireddy et al., 2019).

Usage inside a pjit'd step: quantize -> psum int32 -> dequantize, or (GSPMD
path) simply quantize/dequantize around the autodiff gradient — XLA then
all-reduces the int8 tensor.  The error-feedback buffer lives in the
optimizer state and shares the parameter sharding.
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp


def compress_int8(x: jax.Array) -> Tuple[jax.Array, jax.Array]:
    amax = jnp.max(jnp.abs(x.astype(jnp.float32)))
    scale = jnp.maximum(amax, 1e-12) / 127.0
    q = jnp.clip(jnp.round(x.astype(jnp.float32) / scale), -127, 127
                 ).astype(jnp.int8)
    return q, scale


def decompress_int8(q: jax.Array, scale: jax.Array) -> jax.Array:
    return q.astype(jnp.float32) * scale


def ef_compress_grads(grads, ef_state):
    """Error-feedback int8 round-trip: returns (compressed-then-restored
    grads, new error buffers).  ef_state is a pytree like grads (f32)."""
    def one(g, e):
        gf = g.astype(jnp.float32) + e
        q, s = compress_int8(gf)
        deq = decompress_int8(q, s)
        return deq.astype(g.dtype), gf - deq

    flat_g, treedef = jax.tree.flatten(grads)
    flat_e = treedef.flatten_up_to(ef_state)
    out = [one(g, e) for g, e in zip(flat_g, flat_e)]
    return (treedef.unflatten([o[0] for o in out]),
            treedef.unflatten([o[1] for o in out]))
