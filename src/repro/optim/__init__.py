from .adamw import AdamWConfig, adamw_init, adamw_update  # noqa: F401
from .schedule import cosine_warmup  # noqa: F401
from .clip import clip_by_global_norm, global_norm  # noqa: F401
from .compression import (  # noqa: F401
    compress_int8,
    decompress_int8,
    ef_compress_grads,
)
