"""MLP blocks (gated and plain) with split ABFT checks per matmul.

The nonlinearity between up- and down-projection breaks the linear chain, so
— exactly as the paper prescribes — each matmul is checked individually (the
fused form applies only to uninterrupted matrix chains).
"""
from __future__ import annotations

from typing import Any, Dict, List, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.core.abft import ABFTConfig, Check
from repro.models.common import dense, init_dense

Array = jax.Array
Params = Dict[str, Any]


def init_mlp(key, cfg: ModelConfig, d_ff: int = 0) -> Params:
    d_ff = d_ff or cfg.d_ff
    ks = jax.random.split(key, 3)
    if cfg.mlp_act in ("swiglu", "geglu"):
        return {
            "wi": init_dense(ks[0], cfg.d_model, d_ff),
            "wg": init_dense(ks[1], cfg.d_model, d_ff),
            "wo": init_dense(ks[2], d_ff, cfg.d_model),
        }
    return {
        "wi": init_dense(ks[0], cfg.d_model, d_ff),
        "wo": init_dense(ks[2], d_ff, cfg.d_model),
    }


def mlp_block(p: Params, x: Array, cfg: ModelConfig, abft: ABFTConfig
              ) -> Tuple[Array, List[Check]]:
    checks: List[Check] = []
    if cfg.mlp_act in ("swiglu", "geglu"):
        up, c1 = dense(p["wi"], x, abft)
        gate, c2 = dense(p["wg"], x, abft)
        act = jax.nn.silu if cfg.mlp_act == "swiglu" else jax.nn.gelu
        h = act(gate) * up
        checks += c1 + c2
    else:
        h, c1 = dense(p["wi"], x, abft)
        h = jax.nn.gelu(h)
        checks += c1
    out, c3 = dense(p["wo"], h, abft)
    return out, checks + c3
