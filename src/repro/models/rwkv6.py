"""RWKV6 ("Finch") time-mix + channel-mix blocks.

Data-dependent per-channel decay makes the recurrence a product of
*data-dependent diagonal* maps — the fused GCN-ABFT chain does not factor
through it (DESIGN.md §Arch-applicability), so the projections (r/k/v/g/o,
channel-mix) carry split ABFT checks and the recurrence itself is unchecked.

State per head: S [hd, hd];   wkv_t = S_{t-1} + diag(u) kᵀ_t v_t
                              out_t = r_t · wkv_t
                              S_t   = diag(w_t) S_{t-1} + kᵀ_t v_t
with w_t = exp(-exp(w0 + lora_w(x̄_t))) (data-dependent decay).
Token-shift lerps use the RWKV6 low-rank data-dependent form.
"""
from __future__ import annotations

from typing import Any, Dict, List, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.core.abft import ABFTConfig, Check
from repro.models.common import dense, init_dense, trunc_normal

Array = jax.Array
Params = Dict[str, Any]

HEAD_SIZE = 64
LORA_R = 32


def _heads(cfg: ModelConfig) -> int:
    return cfg.d_model // HEAD_SIZE


def init_rwkv_time_mix(key, cfg: ModelConfig) -> Params:
    d = cfg.d_model
    ks = jax.random.split(key, 12)
    return {
        "mu": 0.5 * jnp.ones((5, d), jnp.float32),       # r,k,v,g,w lerp bases
        "lora_a": trunc_normal(ks[0], (d, 5, LORA_R), std=d ** -0.5),
        "lora_b": trunc_normal(ks[1], (5, LORA_R, d), std=LORA_R ** -0.5),
        "wr": init_dense(ks[2], d, d),
        "wk": init_dense(ks[3], d, d),
        "wv": init_dense(ks[4], d, d),
        "wg": init_dense(ks[5], d, d),
        "wo": init_dense(ks[6], d, d),
        "w0": jnp.full((d,), -5.0, jnp.float32),          # decay base
        "w_lora_a": trunc_normal(ks[7], (d, LORA_R), std=d ** -0.5),
        "w_lora_b": trunc_normal(ks[8], (LORA_R, d), std=LORA_R ** -0.5),
        "u": trunc_normal(ks[9], (d,), std=0.5),          # current-token bonus
        "ln_scale": jnp.ones((d,), jnp.float32),          # per-head groupnorm
    }


def init_rwkv_channel_mix(key, cfg: ModelConfig) -> Params:
    ks = jax.random.split(key, 2)
    return {
        "mu": 0.5 * jnp.ones((2, cfg.d_model), jnp.float32),
        "wk": init_dense(ks[0], cfg.d_model, cfg.d_ff),
        "wv": init_dense(ks[1], cfg.d_ff, cfg.d_model),
    }


def _ddlerp(p: Params, x: Array, x_prev: Array) -> Tuple[Array, ...]:
    """RWKV6 data-dependent token-shift: 5 mixed streams (r,k,v,g,w)."""
    dxprev = x_prev - x
    base = x + dxprev * p["mu"][:, None, None, :].astype(x.dtype)  # [5,B,T,d]
    lora = jnp.einsum("btd,dfr->fbtr", x + 0.5 * dxprev,
                      p["lora_a"].astype(x.dtype))
    adj = jnp.einsum("fbtr,frd->fbtd", jnp.tanh(lora),
                     p["lora_b"].astype(x.dtype))         # [5,B,T,d]
    mixed = base + dxprev[None] * adj
    return tuple(mixed[i] for i in range(5))


def _wkv_scan(r: Array, k: Array, v: Array, w: Array, u: Array,
              state0: Array) -> Tuple[Array, Array]:
    """Sequential WKV recurrence.  r,k,v: [B,T,H,hd]; w: [B,T,H,hd] decay in
    (0,1); u: [H,hd]; state0: [B,H,hd,hd].  Returns (out [B,T,H,hd], state)."""
    def step(s, inp):
        rt, kt, vt, wt = inp                              # [B,H,hd]
        kv = jnp.einsum("bhk,bhv->bhkv", kt, vt)
        wkv = s + u[None, :, :, None] * kv
        out = jnp.einsum("bhk,bhkv->bhv", rt, wkv)
        s = wt[..., None] * s + kv
        return s, out

    rs, ks_, vs, ws = (a.transpose(1, 0, 2, 3) for a in (r, k, v, w))
    with jax.named_scope("time_scan"):
        state, outs = jax.lax.scan(step, state0, (rs, ks_, vs, ws))
    return outs.transpose(1, 0, 2, 3), state


def rwkv_time_mix(p: Params, x: Array, cfg: ModelConfig, abft: ABFTConfig,
                  x_prev: Array, state0: Array
                  ) -> Tuple[Array, Array, Array, List[Check]]:
    """x: [B,T,d]; x_prev: [B,d] (last token of previous segment);
    state0: [B,H,hd,hd].  Returns (out, last_x, state, checks)."""
    b, t, d = x.shape
    h = _heads(cfg)
    shifted = jnp.concatenate([x_prev[:, None], x[:, :-1]], axis=1)
    xr, xk, xv, xg, xw = _ddlerp(p, x, shifted)

    r, c1 = dense(p["wr"], xr, abft)
    k, c2 = dense(p["wk"], xk, abft)
    v, c3 = dense(p["wv"], xv, abft)
    g, c4 = dense(p["wg"], xg, abft)
    dw = jnp.tanh(xw @ p["w_lora_a"].astype(x.dtype)) @ \
        p["w_lora_b"].astype(x.dtype)
    w = jnp.exp(-jnp.exp((p["w0"].astype(jnp.float32) +
                          dw.astype(jnp.float32))))       # (0,1) decay

    hd = HEAD_SIZE
    rh = r.reshape(b, t, h, hd).astype(jnp.float32)
    kh = k.reshape(b, t, h, hd).astype(jnp.float32)
    vh = v.reshape(b, t, h, hd).astype(jnp.float32)
    wh = w.reshape(b, t, h, hd)
    u = p["u"].reshape(h, hd).astype(jnp.float32)
    out, state = _wkv_scan(rh, kh, vh, wh, u, state0)

    # per-head group-norm
    mu = out.mean(-1, keepdims=True)
    var = out.var(-1, keepdims=True)
    out = (out - mu) * jax.lax.rsqrt(var + 1e-5)
    out = out.reshape(b, t, d).astype(x.dtype) * \
        p["ln_scale"].astype(x.dtype)
    out = out * jax.nn.silu(g)
    y, c5 = dense(p["wo"], out, abft)
    return y, x[:, -1], state, c1 + c2 + c3 + c4 + c5


def rwkv_channel_mix(p: Params, x: Array, cfg: ModelConfig, abft: ABFTConfig,
                     x_prev: Array) -> Tuple[Array, Array, List[Check]]:
    b, t, d = x.shape
    shifted = jnp.concatenate([x_prev[:, None], x[:, :-1]], axis=1)
    dxprev = shifted - x
    xk = x + dxprev * p["mu"][0].astype(x.dtype)
    xv = x + dxprev * p["mu"][1].astype(x.dtype)
    k, c1 = dense(p["wk"], xk, abft)
    k = jnp.square(jax.nn.relu(k))
    out, c2 = dense(p["wv"], k, abft)
    _ = xv  # RWKV6 channel-mix receptance folded into residual scale
    return out, x[:, -1], c1 + c2


def rwkv_state_init(cfg: ModelConfig, batch: int) -> Dict[str, Array]:
    h = _heads(cfg)
    return {
        "wkv": jnp.zeros((batch, h, HEAD_SIZE, HEAD_SIZE), jnp.float32),
        "x_tm": jnp.zeros((batch, cfg.d_model), jnp.float32),
        "x_cm": jnp.zeros((batch, cfg.d_model), jnp.float32),
    }
