"""RG-LRU recurrent block (Griffin / RecurrentGemma).

    i_t = sigmoid(W_x x_t)         input gate
    r_t = sigmoid(W_a x_t)         recurrence gate
    a_t = exp(-c · softplus(Λ) · r_t)          per-channel decay, c = 8
    h_t = a_t ⊙ h_{t-1} + sqrt(1 - a_t²) ⊙ (i_t ⊙ x_t)

The full block is: proj-in → conv1d(width 4) → RG-LRU  (gated by a parallel
GeLU branch) → proj-out.  Same ABFT applicability note as RWKV6: the
data-dependent diagonal recurrence breaks the fused chain; projections carry
split checks.
"""
from __future__ import annotations

import math
from typing import Any, Dict, List, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.core.abft import ABFTConfig, Check
from repro.models.common import dense, init_dense, trunc_normal

Array = jax.Array
Params = Dict[str, Any]

RGLRU_C = 8.0
GATE_BLOCKS = 16       # Griffin uses block-diagonal gate matrices; blocks
                       # align with the model axis -> gate matmuls are local
                       # under dr-sharding (§Perf iteration 5)


def _d_rnn(cfg: ModelConfig) -> int:
    return cfg.rglru_d or cfg.d_model


def init_rglru_block(key, cfg: ModelConfig) -> Params:
    d, dr = cfg.d_model, _d_rnn(cfg)
    ks = jax.random.split(key, 7)
    return {
        "proj_x": init_dense(ks[0], d, dr),
        "proj_gate": init_dense(ks[1], d, dr),
        "proj_out": init_dense(ks[2], dr, d),
        "conv_w": trunc_normal(ks[3], (cfg.conv1d_width, dr), std=0.3),
        "conv_b": jnp.zeros((dr,), jnp.float32),
        "gate_x": {"w": trunc_normal(ks[4], (GATE_BLOCKS, dr // GATE_BLOCKS,
                                             dr // GATE_BLOCKS),
                                      std=(dr // GATE_BLOCKS) ** -0.5)},
        "gate_a": {"w": trunc_normal(ks[5], (GATE_BLOCKS, dr // GATE_BLOCKS,
                                             dr // GATE_BLOCKS),
                                      std=(dr // GATE_BLOCKS) ** -0.5)},
        # Λ init so that softplus(Λ)·c gives decays in a useful range
        "lam": jnp.linspace(0.3, 1.5, dr).astype(jnp.float32),
    }


def _conv1d(x: Array, w: Array, b: Array, x_hist: Array) -> Tuple[Array, Array]:
    """Causal depthwise conv, width K.  x: [B,T,dr]; x_hist: [B,K-1,dr] from
    the previous segment.  Returns (y, new_hist)."""
    k = w.shape[0]
    xfull = jnp.concatenate([x_hist.astype(x.dtype), x], axis=1)
    y = sum(xfull[:, i:i + x.shape[1]] * w[i].astype(x.dtype)
            for i in range(k))
    y = y + b.astype(x.dtype)
    return y, xfull[:, -(k - 1):, :] if k > 1 else x_hist


def _rglru_scan(x: Array, i_gate: Array, a: Array, h0: Array
                ) -> Tuple[Array, Array]:
    """h_t = a_t h_{t-1} + sqrt(1-a_t²)(i_t ⊙ x_t).  All [B,T,dr]."""
    gx = (i_gate * x * jnp.sqrt(jnp.maximum(1.0 - jnp.square(a), 1e-9)
                                ).astype(x.dtype))

    def step(h, inp):
        at, gxt = inp
        h = at * h + gxt
        return h, h

    aT = a.transpose(1, 0, 2).astype(jnp.float32)
    gT = gx.transpose(1, 0, 2).astype(jnp.float32)
    with jax.named_scope("time_scan"):
        h, ys = jax.lax.scan(step, h0, (aT, gT))
    return ys.transpose(1, 0, 2), h


def _block_diag_dense(p: Params, x: Array, abft: ABFTConfig):
    """y[..., n, s] = x[..., n, r] @ w[n, r, s]  (block-diagonal gates)."""
    from repro.core.abft import Check
    nb, r, _ = p["w"].shape
    xb = x.reshape(*x.shape[:-1], nb, r)
    w = p["w"].astype(x.dtype)
    y = jnp.einsum("btnr,nrs->btns", xb, w)
    checks = []
    if abft.enabled:
        pred = jnp.einsum("nr,nrs->", xb.astype(abft.dtype).sum((0, 1)),
                          w.astype(abft.dtype))
        checks.append(Check(predicted=pred, actual=y.astype(abft.dtype).sum()))
    return y.reshape(x.shape), checks


def rglru_block(p: Params, x: Array, cfg: ModelConfig, abft: ABFTConfig,
                state: Dict[str, Array]
                ) -> Tuple[Array, Dict[str, Array], List[Check]]:
    """x: [B,T,d]; state = {'h': [B,dr] f32, 'conv': [B,K-1,dr]}."""
    xr, c1 = dense(p["proj_x"], x, abft)
    gate, c2 = dense(p["proj_gate"], x, abft)
    xr, conv_hist = _conv1d(xr, p["conv_w"], p["conv_b"], state["conv"])

    ig, c3 = _block_diag_dense(p["gate_x"], xr, abft)
    rg, c4 = _block_diag_dense(p["gate_a"], xr, abft)
    i_gate = jax.nn.sigmoid(ig)
    log_a = -RGLRU_C * jax.nn.softplus(p["lam"]).astype(jnp.float32) * \
        jax.nn.sigmoid(rg.astype(jnp.float32))
    a = jnp.exp(log_a)

    ys, h = _rglru_scan(xr, i_gate, a.astype(xr.dtype), state["h"])
    out = ys.astype(x.dtype) * jax.nn.gelu(gate)
    y, c5 = dense(p["proj_out"], out, abft)
    new_state = {"h": h, "conv": conv_hist.astype(state["conv"].dtype)}
    return y, new_state, c1 + c2 + c3 + c4 + c5


def rglru_state_init(cfg: ModelConfig, batch: int) -> Dict[str, Array]:
    dr = _d_rnn(cfg)
    return {
        "h": jnp.zeros((batch, dr), jnp.float32),
        "conv": jnp.zeros((batch, cfg.conv1d_width - 1, dr), jnp.float32),
    }
