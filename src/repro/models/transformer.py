"""Model assembly: scanned decoder stacks, encoder-decoder, hybrids.

Layer stacks are grouped into *segments* of a repeating block-pattern unit
(e.g. RecurrentGemma's (rglru, rglru, attn)); each segment's per-unit params
are stacked on a leading axis and applied with ``lax.scan`` so HLO size and
compile time stay bounded at 48-layer/30B scale.  A trailing partial unit
(38 = 12×3 + 2) becomes its own segment.

Everything returns (value, checks, aux): ABFT checks flow out of every block
and are reduced once per step into a replicated ABFTReport.
"""
from __future__ import annotations

import dataclasses
import math
from functools import partial
from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.core.abft import ABFTConfig, ABFTReport, Check, merge_reports, summarize
from repro.models.attention import (
    attention_block,
    attention_decode,
    attention_fault_injection,
    init_attention,
    init_cache,
)
from repro.models.common import (
    cdtype,
    dense,
    embed,
    init_dense,
    init_embed,
    init_norm,
    norm_apply,
    sinusoid_positions,
)
from repro.models.mlp import init_mlp, mlp_block
from repro.models.moe import init_moe, moe_block
from repro.models.rglru import init_rglru_block, rglru_block, rglru_state_init
from repro.models.rwkv6 import (
    init_rwkv_channel_mix,
    init_rwkv_time_mix,
    rwkv_state_init,
    rwkv_time_mix,
    rwkv_channel_mix,
)

Array = jax.Array
Params = Dict[str, Any]


def constrain_batch(x: Array) -> Array:
    """Pin activations to (batch-sharded, replicated...) at block boundaries.

    §Perf iteration 4: without anchors, GSPMD propagates FSDP weight specs
    into the residual stream; on gemma train_4k the LM-head dot then ran
    with a globally-replicated batch ([1M, 16000] per-device dot + 3×62.5
    GiB collectives).  Anchoring the stream keeps every weight-FSDP
    resolution on the weight side (all-gather MBs, not activation GiBs).

    Uses a bare PartitionSpec resolved against the ambient mesh context;
    trace-time no-op when no mesh (CPU tests/examples) — the axis-name
    probe order tries the multi-pod spec first.
    """
    from jax.sharding import PartitionSpec
    for dp in (("pod", "data"), "data"):
        try:
            spec = PartitionSpec(dp, *(None,) * (x.ndim - 1))
            return jax.lax.with_sharding_constraint(x, spec)
        except Exception:
            continue
    return x


def seg_structure(cfg: ModelConfig) -> List[Tuple[Tuple[str, ...], int]]:
    bp, L = cfg.block_pattern, cfg.n_layers
    P = len(bp)
    segs: List[Tuple[Tuple[str, ...], int]] = []
    if L // P:
        segs.append((bp, L // P))
    if L % P:
        segs.append((bp[: L % P], 1))
    return segs


# ---------------------------------------------------------------------------
# init
# ---------------------------------------------------------------------------

def init_layer(key, cfg: ModelConfig, btype: str, cross: bool) -> Params:
    ks = jax.random.split(key, 6)
    d = cfg.d_model
    p: Params = {"ln1": init_norm(d), "ln2": init_norm(d)}
    if btype == "attn":
        p["attn"] = init_attention(ks[0], cfg)
    elif btype == "rglru":
        p["rglru"] = init_rglru_block(ks[0], cfg)
    elif btype == "rwkv":
        p["tm"] = init_rwkv_time_mix(ks[0], cfg)
    else:
        raise ValueError(btype)
    if btype == "rwkv":
        p["cm"] = init_rwkv_channel_mix(ks[1], cfg)
    elif cfg.moe is not None:
        p["moe"] = init_moe(ks[1], cfg)
    else:
        p["mlp"] = init_mlp(ks[1], cfg)
    if cross:
        p["lnx"] = init_norm(d)
        p["xattn"] = init_attention(ks[2], cfg, cross=True)
    return p


def init_unit(key, cfg: ModelConfig, pattern: Tuple[str, ...], cross: bool
              ) -> Params:
    ks = jax.random.split(key, len(pattern))
    return {f"b{i}": init_layer(ks[i], cfg, bt, cross)
            for i, bt in enumerate(pattern)}


def init_model(cfg: ModelConfig, key) -> Params:
    ks = jax.random.split(key, 8)
    p: Params = {"embed": init_embed(ks[0], cfg.padded_vocab, cfg.d_model)}
    cross = cfg.family == "encdec"
    segs = seg_structure(cfg)
    seg_params = []
    for i, (pattern, count) in enumerate(segs):
        kseg = jax.random.split(jax.random.fold_in(ks[1], i), count)
        unit_init = partial(init_unit, cfg=cfg, pattern=pattern, cross=cross)
        seg_params.append(jax.vmap(lambda k: unit_init(k))(kseg))
    p["segments"] = seg_params
    p["final_norm"] = init_norm(cfg.d_model)
    if not cfg.tie_embeddings:
        p["head"] = init_dense(ks[2], cfg.d_model, cfg.padded_vocab)
    if cross:
        enc_cfg = encoder_cfg(cfg)
        esegs = seg_structure(enc_cfg)
        ep = []
        for i, (pattern, count) in enumerate(esegs):
            kseg = jax.random.split(jax.random.fold_in(ks[3], i), count)
            ep.append(jax.vmap(
                lambda k: init_unit(k, enc_cfg, pattern, False))(kseg))
        p["encoder"] = {"segments": ep, "final_norm": init_norm(cfg.d_model)}
    return p


def encoder_cfg(cfg: ModelConfig) -> ModelConfig:
    return dataclasses.replace(
        cfg, n_layers=cfg.enc_layers, causal=False, rope_frac=0.0,
        block_pattern=("attn",), moe=None, window=0)


# ---------------------------------------------------------------------------
# layer application (full-sequence mode: train / prefill)
# ---------------------------------------------------------------------------

def layer_state_init(cfg: ModelConfig, btype: str, batch: int, cache_len: int,
                     dtype, cross: bool) -> Params:
    if btype == "attn":
        st = init_cache(cfg, batch, cache_len, dtype)
        if cross:
            st["xk"] = jnp.zeros((batch, cache_len, cfg.n_kv_heads, cfg.hd), dtype)
            st["xv"] = jnp.zeros((batch, cache_len, cfg.n_kv_heads, cfg.hd), dtype)
            st["xvr"] = jnp.zeros((batch, cache_len, cfg.n_heads), dtype)
        return st
    if btype == "rglru":
        st = rglru_state_init(cfg, batch)
    else:
        st = rwkv_state_init(cfg, batch)
    if cross:
        st["xk"] = jnp.zeros((batch, cache_len, cfg.n_kv_heads, cfg.hd), dtype)
        st["xv"] = jnp.zeros((batch, cache_len, cfg.n_kv_heads, cfg.hd), dtype)
    return st


def _zero_recurrent_state(cfg: ModelConfig, btype: str, batch: int):
    if btype == "rglru":
        return rglru_state_init(cfg, batch)
    if btype == "rwkv":
        return rwkv_state_init(cfg, batch)
    return None


def layer_apply_seq(lp: Params, x: Array, btype: str, cfg: ModelConfig,
                    abft: ABFTConfig, positions: Array,
                    enc_out: Optional[Array], state: Optional[Params],
                    build_cache: bool, cache_len: int
                    ) -> Tuple[Array, List[Check], Array, Optional[Params]]:
    """Returns (x, checks, aux_loss, new_state_or_cache)."""
    checks: List[Check] = []
    aux = jnp.zeros((), jnp.float32)
    b, t, _ = x.shape
    new_state: Optional[Params] = None

    if btype == "rwkv":
        st = state or rwkv_state_init(cfg, b)
        h = norm_apply(x, lp["ln1"], cfg)
        y, x_tm, wkv, cs = rwkv_time_mix(lp["tm"], h, cfg, abft,
                                         st["x_tm"].astype(h.dtype), st["wkv"])
        x = x + y
        checks += cs
        h = norm_apply(x, lp["ln2"], cfg)
        y, x_cm, cs = rwkv_channel_mix(lp["cm"], h, cfg, abft,
                                       st["x_cm"].astype(h.dtype))
        x = x + y
        checks += cs
        if build_cache:
            new_state = {"wkv": wkv, "x_tm": x_tm.astype(jnp.float32),
                         "x_cm": x_cm.astype(jnp.float32)}
    elif btype == "rglru":
        st = state or rglru_state_init(cfg, b)
        h = norm_apply(x, lp["ln1"], cfg)
        y, rgst, cs = rglru_block(lp["rglru"], h, cfg, abft, st)
        x = x + y
        checks += cs
        h = norm_apply(x, lp["ln2"], cfg)
        y, cs = mlp_block(lp["mlp"], h, cfg, abft)
        x = x + y
        checks += cs
        if build_cache:
            new_state = rgst
    else:  # attn
        window = cfg.window
        if len(cfg.block_pattern) > 1:      # hybrid: local attention
            window = cfg.local_window
        h = norm_apply(x, lp["ln1"], cfg)
        y, cs, (k, v, kpos, vr) = attention_block(
            lp["attn"], h, cfg, abft, positions=positions, window=window)
        x = x + y
        checks += cs
        if enc_out is not None:
            h = norm_apply(x, lp["lnx"], cfg)
            y, cs, (xk, xv, _, xvr) = attention_block(
                lp["xattn"], h, cfg, abft, kv_x=enc_out, positions=positions,
                causal=False, use_rope=False)
            x = x + y
            checks += cs
        h = norm_apply(x, lp["ln2"], cfg)
        if "moe" in lp:
            y, cs, aux = moe_block(lp["moe"], h, cfg, abft)
        else:
            y, cs = mlp_block(lp["mlp"], h, cfg, abft)
        x = x + y
        checks += cs
        if build_cache:
            pad = cache_len - t
            if vr is None:
                vr = jnp.zeros((*k.shape[:2], cfg.n_heads), k.dtype)
            new_state = {
                "k": jnp.pad(k, [(0, 0), (0, pad), (0, 0), (0, 0)]),
                "v": jnp.pad(v, [(0, 0), (0, pad), (0, 0), (0, 0)]),
                "vr": jnp.pad(vr.astype(k.dtype),
                              [(0, 0), (0, pad), (0, 0)]),
                "pos": jnp.pad(kpos.astype(jnp.int32), [(0, 0), (0, pad)],
                               constant_values=2 ** 30),  # unwritten -> masked
            }
            if enc_out is not None:
                new_state["xk"] = xk
                new_state["xv"] = xv
                new_state["xvr"] = (xvr.astype(k.dtype) if xvr is not None
                                    else jnp.zeros((*xk.shape[:2],
                                                    cfg.n_heads), k.dtype))
    return x, checks, aux, new_state


def layer_apply_decode(lp: Params, x: Array, btype: str, cfg: ModelConfig,
                       abft: ABFTConfig, pos: Array, state: Params
                       ) -> Tuple[Array, List[Check], Params]:
    checks: List[Check] = []
    b = x.shape[0]
    if btype in ("rwkv", "rglru"):
        positions = jnp.full((b, 1), pos, jnp.int32)
        x, checks, _, new_state = layer_apply_seq(
            lp, x, btype, cfg, abft, positions, None, state,
            build_cache=True, cache_len=1)
        # carry over cross-attn keys untouched if present
        for key in ("xk", "xv"):
            if key in state:
                new_state[key] = state[key]
        return x, checks, new_state
    window = cfg.window
    if len(cfg.block_pattern) > 1:
        window = cfg.local_window
    h = norm_apply(x, lp["ln1"], cfg)
    y, new_state, cs = attention_decode(lp["attn"], h, state, pos, cfg, abft,
                                        window=window)
    x = x + y
    checks += cs
    if "xattn" in lp:
        h = norm_apply(x, lp["lnx"], cfg)
        # cross-attention over the static encoder cache
        s = state["xk"].shape[1]
        kvpos = jnp.broadcast_to(jnp.arange(s)[None], (b, s))
        q, c1 = dense(lp["xattn"]["wq"], h, abft)
        from repro.models.attention import streaming_attention, _fold_wo_checkcol
        vr = None
        if abft.mode == "fused":
            vr = state["xvr"].astype(q.dtype)   # static cross check column
        o, o_extra, _, _ = streaming_attention(
            q, state["xk"], state["xv"], vr,
            q_positions=jnp.full((b, 1), pos, jnp.int32),
            k_positions=kvpos, causal=False, window=0,
            chunk=min(cfg.attn_chunk, s))
        y, c2 = dense(lp["xattn"]["wo"], o.reshape(b, 1, -1).astype(x.dtype),
                      abft if abft.mode == "split" else ABFTConfig(mode="none"))
        checks += c1 + c2
        if abft.mode == "fused":
            checks.append(Check(predicted=o_extra.astype(jnp.float32).sum(),
                                actual=y.astype(abft.dtype).sum()))
        x = x + y
        new_state = dict(new_state)
        new_state["xk"] = state["xk"]
        new_state["xv"] = state["xv"]
        new_state["xvr"] = state["xvr"]
    h = norm_apply(x, lp["ln2"], cfg)
    if "moe" in lp:
        y, cs, _ = moe_block(lp["moe"], h, cfg, abft)
    else:
        y, cs = mlp_block(lp["mlp"], h, cfg, abft)
    x = x + y
    checks += cs
    return x, checks, new_state


# ---------------------------------------------------------------------------
# segment application with lax.scan over stacked units
# ---------------------------------------------------------------------------

def _apply_unit_seq(unit_p, x, pattern, cfg, abft, positions, enc_out,
                    unit_state, build_cache, cache_len):
    checks: List[Check] = []
    aux = jnp.zeros((), jnp.float32)
    new_states = {}
    for i, bt in enumerate(pattern):
        st = unit_state[f"b{i}"] if unit_state is not None else None
        x, cs, a, ns = layer_apply_seq(
            unit_p[f"b{i}"], x, bt, cfg, abft, positions, enc_out, st,
            build_cache, cache_len)
        checks += cs
        aux += a
        if build_cache:
            new_states[f"b{i}"] = ns
    x = constrain_batch(x)
    return x, checks, aux, (new_states if build_cache else None)


def apply_segments(params_segs, cfg: ModelConfig, x: Array, abft: ABFTConfig,
                   positions: Array, enc_out: Optional[Array],
                   states: Optional[List[Params]], build_cache: bool,
                   cache_len: int, segs: List[Tuple[Tuple[str, ...], int]]
                   ) -> Tuple[Array, List[Check], Array, Optional[List[Params]]]:
    all_checks: List[Check] = []
    aux_total = jnp.zeros((), jnp.float32)
    new_states: List[Params] = []
    for si, ((pattern, count), seg_p) in enumerate(zip(segs, params_segs)):
        seg_state = states[si] if states is not None else None

        def unit_fn(x, unit_p, unit_state):
            return _apply_unit_seq(unit_p, x, pattern, cfg, abft, positions,
                                   enc_out, unit_state, build_cache, cache_len)

        if cfg.remat:
            unit_fn = jax.checkpoint(unit_fn)

        if count == 1 or not cfg.scan_layers:
            xs_state = None
            outs = []
            for ui in range(count):
                up = jax.tree.map(lambda a: a[ui], seg_p)
                us = jax.tree.map(lambda a: a[ui], seg_state) \
                    if seg_state is not None else None
                x, cs, aux, ns = unit_fn(x, up, us)
                all_checks += cs
                aux_total += aux
                outs.append(ns)
            if build_cache:
                new_states.append(jax.tree.map(
                    lambda *a: jnp.stack(a), *outs) if len(outs) > 1 else
                    jax.tree.map(lambda a: a[None], outs[0]))
        else:
            def scan_body(x, inp):
                unit_p, unit_state = inp
                x, cs, aux, ns = unit_fn(x, unit_p, unit_state)
                return x, (cs, aux, ns)

            if seg_state is None:
                # dummy per-unit states so scan xs line up
                proto = _apply_unit_seq  # noqa: F841
                dummy = [None] * count
                x, (cs, aux, ns) = _scan_with_optional_state(
                    scan_body, x, seg_p, None, count)
            else:
                x, (cs, aux, ns) = _scan_with_optional_state(
                    scan_body, x, seg_p, seg_state, count)
            all_checks += [cs]           # stacked Check pytree ([count]-leaves)
            aux_total += aux.sum()
            if build_cache:
                new_states.append(ns)
    return x, all_checks, aux_total, (new_states if build_cache else None)


def _scan_with_optional_state(body, x, seg_p, seg_state, count):
    if seg_state is None:
        def body2(x, unit_p):
            return body(x, (unit_p, None))
        return jax.lax.scan(body2, x, seg_p)
    return jax.lax.scan(body, x, (seg_p, seg_state))


# ---------------------------------------------------------------------------
# model-level entry points
# ---------------------------------------------------------------------------

def _flatten_checks(checks) -> List[Check]:
    flat: List[Check] = []
    for c in checks:
        if isinstance(c, Check):
            flat.append(c)
        elif isinstance(c, list):
            flat += _flatten_checks(c)
    return flat


def encode(params: Params, cfg: ModelConfig, src_embeds: Array,
           abft: ABFTConfig) -> Tuple[Array, List[Check]]:
    ecfg = encoder_cfg(cfg)
    b, s, _ = src_embeds.shape
    positions = jnp.broadcast_to(jnp.arange(s)[None], (b, s))
    x = src_embeds.astype(cdtype(cfg)) + sinusoid_positions(
        positions, cfg.d_model, cdtype(cfg))
    segs = seg_structure(ecfg)
    x, checks, _, _ = apply_segments(
        params["encoder"]["segments"], ecfg, x, abft, positions, None, None,
        False, 0, segs)
    x = norm_apply(x, params["encoder"]["final_norm"], cfg)
    return x, checks


def model_forward(params: Params, cfg: ModelConfig, batch: Dict[str, Array],
                  abft: ABFTConfig) -> Tuple[Array, ABFTReport, Array]:
    """Training/eval forward.  batch keys:
      'tokens' [B,T]; optional 'prefix_embeds' [B,P,d] (VLM/audio stub);
      encdec: 'src_embeds' [B,S,d] + 'tokens' (decoder input)."""
    tokens = batch["tokens"]
    b, t = tokens.shape
    x = constrain_batch(embed(params["embed"], tokens, cfg))
    offset = 0
    if "prefix_embeds" in batch and cfg.family != "encdec":
        pre = batch["prefix_embeds"].astype(x.dtype)
        x = jnp.concatenate([pre, x], axis=1)
        offset = pre.shape[1]
    tt = x.shape[1]
    positions = jnp.broadcast_to(jnp.arange(tt)[None], (b, tt))

    enc_out = None
    checks: List[Check] = []
    if cfg.family == "encdec":
        enc_out, ec = encode(params, cfg, batch["src_embeds"], abft)
        checks += ec
        x = x + sinusoid_positions(positions, cfg.d_model, x.dtype)

    segs = seg_structure(cfg)
    x, cs, aux, _ = apply_segments(
        params["segments"], cfg, x, abft, positions, enc_out, None, False, 0,
        segs)
    checks += cs
    x = constrain_batch(norm_apply(x, params["final_norm"], cfg))
    if offset:
        x = x[:, offset:]
    logits, lc = _lm_head(params, cfg, x, abft)
    checks += lc
    report = summarize(_flatten_checks(checks), abft)
    return logits, report, aux


def _lm_head(params, cfg, x, abft):
    from repro.core.abft import check_matmul
    checks: List[Check] = []
    if cfg.tie_embeddings:
        w = params["embed"]["table"].astype(x.dtype)
        logits = jnp.einsum("btd,vd->btv", x, w)
        if abft.enabled:
            checks.append(check_matmul(
                x.reshape(-1, x.shape[-1]), w.T,
                logits.reshape(-1, logits.shape[-1]), abft))
    else:
        logits, checks = dense(params["head"], x, abft)
    logits = logits.astype(jnp.float32)
    if cfg.padded_vocab != cfg.vocab_size:
        # mask pad classes (elementwise on the sharded tensor — no reshard)
        pad_mask = jnp.arange(cfg.padded_vocab) >= cfg.vocab_size
        logits = jnp.where(pad_mask, -1e30, logits)
    return logits, checks


def lm_loss(logits: Array, labels: Array, mask: Optional[Array] = None
    ) -> Array:
    """Scatter-free CE: take_along_axis backward scatters into [B,T,V]
    (62.5 GiB/device all-gather on gemma train_4k — §Perf hillclimb 1);
    the one-hot einsum form keeps fwd+bwd elementwise over the sharded
    vocab axis."""
    lse = jax.nn.logsumexp(logits, axis=-1)
    onehot = (labels[..., None] ==
              jnp.arange(logits.shape[-1])).astype(logits.dtype)
    picked = jnp.sum(logits * onehot, axis=-1)
    nll = lse - picked
    if mask is not None:
        nll = nll * mask
        return nll.sum() / jnp.maximum(mask.sum(), 1.0)
    return nll.mean()


# ---------------------------------------------------------------------------
# prefill + decode
# ---------------------------------------------------------------------------

def init_decode_state(cfg: ModelConfig, batch: int, cache_len: int
                      ) -> List[Params]:
    """Zeroed per-segment stacked decode states (also used as ShapeDtype
    specs by the dry-run)."""
    dtype = cdtype(cfg)
    cross = cfg.family == "encdec"
    states = []
    for pattern, count in seg_structure(cfg):
        unit = {f"b{i}": layer_state_init(cfg, bt, batch, cache_len, dtype,
                                          cross)
                for i, bt in enumerate(pattern)}
        states.append(jax.tree.map(
            lambda a: jnp.broadcast_to(a[None], (count, *a.shape)), unit))
    return states


def model_prefill(params: Params, cfg: ModelConfig, batch: Dict[str, Array],
                  abft: ABFTConfig, cache_len: int, *,
                  return_checks: bool = False,
                  attn_inject: Optional[Array] = None
                  ) -> Tuple[Array, List[Params], ABFTReport]:
    """Run the prompt, build decode state.  Returns (last-token logits,
    states, report) — plus the flat per-op Check list when
    ``return_checks=True`` (the guarded engine's per-op verdict source;
    scanned segments contribute stacked per-layer checks).

    ``attn_inject`` is an optional scalar *operand*: when given, it is
    added to element 0 of every attention accumulator O = A·V (the
    fault-campaign accumulator site).  Pass 0.0 for a fault-free step —
    the operand form lets a jitted step flip the fault at runtime."""
    if attn_inject is not None:
        with attention_fault_injection(attn_inject):
            return model_prefill(params, cfg, batch, abft, cache_len,
                                 return_checks=return_checks)
    tokens = batch["tokens"]
    b, t = tokens.shape
    x = embed(params["embed"], tokens, cfg)
    if "prefix_embeds" in batch and cfg.family != "encdec":
        x = jnp.concatenate([batch["prefix_embeds"].astype(x.dtype), x], axis=1)
    tt = x.shape[1]
    positions = jnp.broadcast_to(jnp.arange(tt)[None], (b, tt))
    enc_out = None
    checks: List[Check] = []
    if cfg.family == "encdec":
        enc_out, ec = encode(params, cfg, batch["src_embeds"], abft)
        checks += ec
        x = x + sinusoid_positions(positions, cfg.d_model, x.dtype)
    segs = seg_structure(cfg)
    x, cs, _, states = apply_segments(
        params["segments"], cfg, x, abft, positions, enc_out, None, True,
        cache_len, segs)
    checks += cs
    x = norm_apply(x, params["final_norm"], cfg)
    logits, lc = _lm_head(params, cfg, x[:, -1:], abft)
    checks += lc
    flat = _flatten_checks(checks)
    rep = summarize(flat, abft)
    if return_checks:
        return logits, states, rep, flat
    return logits, states, rep


def model_decode(params: Params, cfg: ModelConfig, states: List[Params],
                 tokens: Array, pos: Array, abft: ABFTConfig, *,
                 return_checks: bool = False,
                 attn_inject: Optional[Array] = None
                 ) -> Tuple[Array, List[Params], ABFTReport]:
    """One decode step.  tokens: [B,1]; pos: scalar int32 position.
    ``return_checks=True`` appends the flat per-op Check list;
    ``attn_inject`` is the attention-accumulator fault operand (see
    :func:`model_prefill`)."""
    if attn_inject is not None:
        with attention_fault_injection(attn_inject):
            return model_decode(params, cfg, states, tokens, pos, abft,
                                return_checks=return_checks)
    b = tokens.shape[0]
    x = embed(params["embed"], tokens, cfg)
    if cfg.family == "encdec":
        positions = jnp.full((b, 1), pos, jnp.int32)
        x = x + sinusoid_positions(positions, cfg.d_model, x.dtype)
    checks: List[Check] = []
    new_states: List[Params] = []
    segs = seg_structure(cfg)
    for (pattern, count), seg_p, seg_st in zip(segs, params["segments"], states):

        def unit_fn(x, unit_p, unit_state):
            cs_all: List[Check] = []
            ns = {}
            for i, bt in enumerate(pattern):
                x, cs, s2 = layer_apply_decode(
                    unit_p[f"b{i}"], x, bt, cfg, abft, pos, unit_state[f"b{i}"])
                cs_all += cs
                ns[f"b{i}"] = s2
            return constrain_batch(x), cs_all, ns

        if count == 1 or not cfg.scan_layers:
            outs = []
            for ui in range(count):
                up = jax.tree.map(lambda a: a[ui], seg_p)
                us = jax.tree.map(lambda a: a[ui], seg_st)
                x, cs, ns = unit_fn(x, up, us)
                checks += cs
                outs.append(ns)
            new_states.append(
                jax.tree.map(lambda *a: jnp.stack(a), *outs) if len(outs) > 1
                else jax.tree.map(lambda a: a[None], outs[0]))
        else:
            def body(x, inp):
                up, us = inp
                x, cs, ns = unit_fn(x, up, us)
                return x, (cs, ns)
            x, (cs, ns) = jax.lax.scan(body, x, (seg_p, seg_st))
            checks += [cs]
            new_states.append(ns)

    x = norm_apply(x, params["final_norm"], cfg)
    logits, lc = _lm_head(params, cfg, x, abft)
    checks += lc
    flat = _flatten_checks(checks)
    rep = summarize(flat, abft)
    if return_checks:
        return logits, new_states, rep, flat
    return logits, new_states, rep
