"""Attention: GQA/MQA, RoPE variants, sliding windows, KV caches — with the
paper's fused ABFT chain check adapted to streaming (flash) attention.

The ABFT adaptation (DESIGN.md §5): the attention output path is the
three-matrix chain  O = A · V · W_o  with A = softmax(QKᵀ) playing the role
of the GCN's adjacency S.  GCN-ABFT's eq. (4) gives

    eᵀ(A V W_o)e  =  (eᵀA) · V · (W_o e)

A streaming softmax never materializes A, so eᵀA is unavailable — but the
*right* end of the chain is static: fold w_or = W_o·e through V offline into
an extra "checksum column" vr = V·w_or, and carry ONE extra accumulator in
the streaming pass:  o_extra = A·vr.  Then Σ_q o_extra = eᵀ(A V W_o)e, the
fused prediction, at T²·H extra MACs (≈1/head_dim overhead).

Baseline split ABFT *requires* eᵀA, which costs a second scoring pass
(≈2× score FLOPs) in streaming form — implemented here for the baseline
comparison (`mode='split'`), quantified in benchmarks/abft_overhead.py.
"""
from __future__ import annotations

from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.core.abft import ABFTConfig, Check
from repro.models.common import apply_rope, cdtype, dense, init_dense

Array = jax.Array
Params = Dict[str, Any]

NEG = -1e30


def init_attention(key, cfg: ModelConfig, cross: bool = False) -> Params:
    ks = jax.random.split(key, 4)
    hd = cfg.hd
    p = {
        "wq": init_dense(ks[0], cfg.d_model, (cfg.n_heads, hd), cfg.qkv_bias),
        "wk": init_dense(ks[1], cfg.d_model, (cfg.n_kv_heads, hd), cfg.qkv_bias),
        "wv": init_dense(ks[2], cfg.d_model, (cfg.n_kv_heads, hd), cfg.qkv_bias),
        "wo": init_dense(ks[3], cfg.n_heads * hd, cfg.d_model),
    }
    return p


def _fold_wo_checkcol(p: Params, cfg: ModelConfig, dtype) -> Array:
    """w_or[h, hd] = per-head slice of W_o · e (offline in deployment).

    Consumes the tree-generic ``fold_w_r_tree`` fold when present
    (``p["wo"]["w_r"]``, [H*hd]) — the carried column then predicts from
    the load-time master weights, so a post-load W_o corruption trips the
    chain check instead of cancelling."""
    w_r = p["wo"].get("w_r")
    if w_r is not None and w_r.shape == (cfg.n_heads * cfg.hd,):
        return w_r.astype(jnp.float32).reshape(cfg.n_heads,
                                               cfg.hd).astype(dtype)
    wo = p["wo"]["w"].astype(jnp.float32)            # [H*hd, d]
    w_or = wo.sum(axis=1).reshape(cfg.n_heads, cfg.hd)
    return w_or.astype(dtype)


# ---------------------------------------------------------------------------
# fault-injection hook (campaign / e2e repair tests): the device-side
# attention-accumulator site, mirroring the GCN kernels' inject= tuple.
# ---------------------------------------------------------------------------

_ATTN_INJECT = {"value": None}


class attention_fault_injection:
    """Bind a delta operand to the attention-accumulator inject site.

    The model entry points (``model_prefill`` / ``model_decode`` with
    ``attn_inject=...``) set this around their body so that every
    attention call traced inside reads the *same traced scalar* — the
    injection is an **operand** of the step, not a trace-time constant,
    so a jitted step can flip the fault on and off at runtime without
    retracing (mirroring the GCN kernels' ``inject=`` tuple idiom).

    The delta lands on element 0 of the accumulator O = A·V at every
    attention site sharing the trace (scanned/stacked units share one
    trace, so per-layer addressing is impossible here; address layers
    through the weight sites instead).  An accumulator upset is exactly
    what the eq. 4–6 chain check must catch, because the carried column
    o_extra is accumulated independently.
    """

    def __init__(self, value):
        self.value = value

    def __enter__(self):
        self._prev = _ATTN_INJECT["value"]
        _ATTN_INJECT["value"] = self.value
        return self

    def __exit__(self, *exc):
        _ATTN_INJECT["value"] = self._prev
        return False


def _maybe_inject(o: Array) -> Array:
    val = _ATTN_INJECT["value"]
    if val is None:
        return o
    flat = o.reshape(-1)
    return flat.at[0].add(jnp.asarray(val).astype(flat.dtype)).reshape(o.shape)


def _project_qkv(p: Params, x: Array, kv_x: Array, cfg: ModelConfig,
                 abft: ABFTConfig) -> Tuple[Array, Array, Array, List[Check]]:
    q, c1 = dense(p["wq"], x, abft)
    k, c2 = dense(p["wk"], kv_x, abft)
    v, c3 = dense(p["wv"], kv_x, abft)
    return q, k, v, c1 + c2 + c3


def _group(q: Array, n_kv: int) -> Array:
    """[B,T,H,hd] -> [B,T,Kh,G,hd]"""
    b, t, h, hd = q.shape
    return q.reshape(b, t, n_kv, h // n_kv, hd)


def streaming_attention(
    q: Array, k: Array, v: Array, vr: Optional[Array], *,
    q_positions: Array, k_positions: Array, causal: bool, window: int,
    chunk: int,
) -> Tuple[Array, Optional[Array], Array, Array]:
    """Online-softmax attention over KV chunks (never materializes A).

    q: [B,T,H,hd]; k,v: [B,S,Kh,hd]; vr: [B,S,H] fused-ABFT check column.
    q_positions: [B,T] absolute positions; k_positions: [B,S] (entries > any
    q position are treated as invalid/future and masked).
    Returns (o [B,T,H,hd], o_extra [B,T,H] | None, m [B,T,H], l [B,T,H]).
    """
    b, t, h, hd = q.shape
    s = k.shape[1]
    kh = k.shape[2]
    g = h // kh
    qg = _group(q, kh)                                    # [B,T,Kh,G,hd]
    vrg = vr.reshape(b, s, kh, g) if vr is not None else None
    scale = hd ** -0.5
    n_chunks = -(-s // chunk)
    pad = n_chunks * chunk - s
    if pad:
        padw = [(0, 0), (0, pad)] + [(0, 0)] * (k.ndim - 2)
        k = jnp.pad(k, padw)
        v = jnp.pad(v, padw)
        k_positions = jnp.pad(k_positions, [(0, 0), (0, pad)],
                              constant_values=2**30)
        if vrg is not None:
            vrg = jnp.pad(vrg, [(0, 0), (0, pad), (0, 0), (0, 0)])

    has_extra = vrg is not None
    if n_chunks == 1:
        # single-shot path (decode T=1, short contexts): no scan, no carry —
        # with a seq-sharded cache this keeps every collective O(B·H·hd)
        # instead of all-gathering K/V chunks per scan iteration
        # (§Perf hillclimb 2).
        sc = jnp.einsum("btkgh,bskh->btkgs", qg, k,
                        preferred_element_type=jnp.float32) * scale
        kp_b = k_positions[:, None, None, None, :]
        qp_b = q_positions[:, :, None, None, None]
        valid = (kp_b <= qp_b) if causal else (kp_b < 2**30)
        if window > 0:
            valid &= kp_b > qp_b - window
        sc = jnp.where(valid, sc, NEG)
        m = sc.max(axis=-1)
        p = jnp.where(valid, jnp.exp(sc - m[..., None]), 0.0)
        l = p.sum(axis=-1)
        lsafe = jnp.maximum(l, 1e-30)
        o = jnp.einsum("btkgs,bskh->btkgh", p.astype(v.dtype), v,
                       preferred_element_type=jnp.float32) / lsafe[..., None]
        o_extra = None
        if has_extra:
            ex = jnp.einsum("btkgs,bskg->btkg", p.astype(vrg.dtype), vrg,
                            preferred_element_type=jnp.float32) / lsafe
            o_extra = ex.reshape(b, t, h)
        return (o.reshape(b, t, h, hd), o_extra,
                m.reshape(b, t, h), l.reshape(b, t, h))

    kc = k.reshape(b, n_chunks, chunk, kh, hd).transpose(1, 0, 2, 3, 4)
    vc = v.reshape(b, n_chunks, chunk, kh, hd).transpose(1, 0, 2, 3, 4)
    pc = k_positions.reshape(b, n_chunks, chunk).transpose(1, 0, 2)
    if has_extra:
        vrc = vrg.reshape(b, n_chunks, chunk, kh, g).transpose(1, 0, 2, 3, 4)
    else:
        vrc = jnp.zeros((n_chunks, b, 0, kh, g), k.dtype)   # trace-only stub

    m0 = jnp.full((b, t, kh, g), NEG, jnp.float32)
    l0 = jnp.zeros((b, t, kh, g), jnp.float32)
    acc0 = jnp.zeros((b, t, kh, g, hd), jnp.float32)
    ex0 = jnp.zeros((b, t, kh, g), jnp.float32)

    def step(carry, inp):
        m, l, acc, ex = carry
        kch, vch, vrch, kp = inp
        sc = jnp.einsum("btkgh,bskh->btkgs", qg, kch,
                        preferred_element_type=jnp.float32) * scale
        valid = jnp.ones_like(sc, bool)
        kp_b = kp[:, None, None, None, :]                 # [B,1,1,1,c]
        qp_b = q_positions[:, :, None, None, None]        # [B,T,1,1,1]
        if causal:
            valid &= kp_b <= qp_b
        else:
            valid &= kp_b < 2**30
        if window > 0:
            valid &= kp_b > qp_b - window
        sc = jnp.where(valid, sc, NEG)
        m_new = jnp.maximum(m, sc.max(axis=-1))
        p = jnp.exp(sc - m_new[..., None])
        p = jnp.where(valid, p, 0.0)
        corr = jnp.exp(m - m_new)
        l = l * corr + p.sum(axis=-1)
        acc = acc * corr[..., None] + jnp.einsum(
            "btkgs,bskh->btkgh", p.astype(vch.dtype), vch,
            preferred_element_type=jnp.float32)
        if has_extra:
            ex = ex * corr + jnp.einsum(
                "btkgs,bskg->btkg", p.astype(vrch.dtype), vrch,
                preferred_element_type=jnp.float32)
        return (m_new, l, acc, ex), None

    with jax.named_scope("attn_chunk_scan"):
        (m, l, acc, ex), _ = jax.lax.scan(step, (m0, l0, acc0, ex0),
                                          (kc, vc, vrc, pc))
    lsafe = jnp.maximum(l, 1e-30)
    o = (acc / lsafe[..., None]).reshape(b, t, h, hd)
    o_extra = (ex / lsafe).reshape(b, t, h) if vr is not None else None
    return o, o_extra, m.reshape(b, t, h), l.reshape(b, t, h)


def _split_second_pass(q, k, v, m, l, *, q_positions, k_positions, causal,
                       window, chunk, dtype_acc) -> Tuple[Array, Array]:
    """Second scoring pass for baseline split ABFT: accumulates the predicted
    checksum (eᵀA)(V e) and nothing else.  Cost ≈ one extra score matmul.

    Returns (predicted [B], actual-is-not-computed-here placeholder).
    """
    b, t, h, hd = q.shape
    s = k.shape[1]
    kh = k.shape[2]
    g = h // kh
    qg = _group(q, kh)
    scale = hd ** -0.5
    mg = m.reshape(b, t, kh, g)
    lg = jnp.maximum(l.reshape(b, t, kh, g), 1e-30)
    ve = v.astype(jnp.float32).sum(axis=-1)               # [B,S,Kh] = V e
    n_chunks = -(-s // chunk)
    pad = n_chunks * chunk - s
    if pad:
        k = jnp.pad(k, [(0, 0), (0, pad), (0, 0), (0, 0)])
        ve = jnp.pad(ve, [(0, 0), (0, pad), (0, 0)])
        k_positions = jnp.pad(k_positions, [(0, 0), (0, pad)],
                              constant_values=2**30)
    kc = k.reshape(b, n_chunks, chunk, kh, hd).transpose(1, 0, 2, 3, 4)
    vec = ve.reshape(b, n_chunks, chunk, kh).transpose(1, 0, 2, 3)
    pc = k_positions.reshape(b, n_chunks, chunk).transpose(1, 0, 2)

    def step(carry, inp):
        pred = carry
        kch, vech, kp = inp
        sc = jnp.einsum("btkgh,bskh->btkgs", qg, kch,
                        preferred_element_type=jnp.float32) * scale
        valid = jnp.ones_like(sc, bool)
        kp_b = kp[:, None, None, None, :]
        qp_b = q_positions[:, :, None, None, None]
        if causal:
            valid &= kp_b <= qp_b
        else:
            valid &= kp_b < 2**30
        if window > 0:
            valid &= kp_b > qp_b - window
        p = jnp.where(valid, jnp.exp(sc - mg[..., None]), 0.0) / lg[..., None]
        # predicted += Σ_q A[q, s_chunk] · (V e)[s_chunk]
        pred = pred + jnp.einsum("btkgs,bsk->b", p, vech)
        return pred, None

    pred, _ = jax.lax.scan(step, jnp.zeros((b,), jnp.float32),
                           (kc, vec, pc))
    return pred


def attention_block(
    p: Params, x: Array, cfg: ModelConfig, abft: ABFTConfig, *,
    kv_x: Optional[Array] = None,
    positions: Optional[Array] = None,
    kv_positions: Optional[Array] = None,
    causal: Optional[bool] = None,
    window: int = 0,
    use_rope: bool = True,
) -> Tuple[Array, List[Check], Tuple[Array, Array, Array]]:
    """Self- (or cross-) attention for train/prefill.  x: [B,T,d].
    Also returns (k, v, kv_positions, vr) — roped keys + the fused-check
    column, for cache building."""
    b, t, _ = x.shape
    kv_x = x if kv_x is None else kv_x
    s = kv_x.shape[1]
    if positions is None:
        positions = jnp.broadcast_to(jnp.arange(t)[None], (b, t))
    if kv_positions is None:
        kv_positions = positions if kv_x is x else \
            jnp.broadcast_to(jnp.arange(s)[None], (b, s))
    causal = cfg.causal if causal is None else causal

    q, k, v, checks = _project_qkv(p, x, kv_x, cfg, abft)
    if use_rope and cfg.rope_frac > 0:
        q = apply_rope(q, positions, cfg.rope_theta, cfg.rope_frac)
        k = apply_rope(k, kv_positions, cfg.rope_theta, cfg.rope_frac)

    vr = None
    if abft.mode == "fused":
        w_or = _fold_wo_checkcol(p, cfg, q.dtype)         # [H, hd]
        g = cfg.kv_groups
        w_org = w_or.reshape(cfg.n_kv_heads, g, cfg.hd)
        vr = jnp.einsum("bskh,kgh->bskg", v, w_org).reshape(b, s, cfg.n_heads)

    o, o_extra, m, l = streaming_attention(
        q, k, v, vr, q_positions=positions, k_positions=kv_positions,
        causal=causal, window=window, chunk=min(cfg.attn_chunk, s))
    o = _maybe_inject(o)

    out, oc = dense(p["wo"], o.reshape(b, t, -1).astype(x.dtype),
                    abft if abft.mode == "split" else
                    ABFTConfig(mode="none"))
    checks += oc

    if abft.mode == "fused":
        pred = o_extra.astype(jnp.float32).sum()
        actual = out.astype(abft.dtype).sum()
        checks.append(Check(predicted=pred, actual=actual))
    elif abft.mode == "split":
        # second pass for (eᵀA)(V e); actual is Σ O (pre-W_o)
        pred = _split_second_pass(
            q, k, v, m, l, q_positions=positions, k_positions=kv_positions,
            causal=causal, window=window, chunk=min(cfg.attn_chunk, s),
            dtype_acc=abft.dtype).sum()
        checks.append(Check(predicted=pred,
                            actual=o.astype(abft.dtype).sum()))
    return out, checks, (k, v, kv_positions, vr)


# ---------------------------------------------------------------------------
# KV-cache decode
# ---------------------------------------------------------------------------

def init_cache(cfg: ModelConfig, batch: int, length: int, dtype) -> Params:
    """Ring-buffer KV cache for one attention layer.

    ``vr`` is the fused-ABFT check column V·w_or cached *incrementally*
    (§Perf hillclimb 3): recomputing it over the whole cache per step costs
    O(S·kh·hd·H); caching it costs H/(2·kh·hd) ≈ 0.4 % extra cache bytes
    and makes the per-step check O(1) — the paper's offline-checksum-reuse
    idea applied to the KV cache."""
    hd = cfg.hd
    return {
        "k": jnp.zeros((batch, length, cfg.n_kv_heads, hd), dtype),
        "v": jnp.zeros((batch, length, cfg.n_kv_heads, hd), dtype),
        "vr": jnp.zeros((batch, length, cfg.n_heads), dtype),
        "pos": jnp.full((batch, length), 2**30, jnp.int32),  # unwritten -> masked
    }


def _masked_update(buf: Array, new: Array, slot: Array) -> Array:
    """Ring-buffer write as a one-hot masked blend.  Elementwise over the
    (possibly seq-sharded) cache — no involuntary resharding, unlike
    dynamic_update_slice at a traced index (§Perf hillclimb 2)."""
    length = buf.shape[1]
    oh = (jnp.arange(length) == slot)
    oh = oh.reshape((1, length) + (1,) * (buf.ndim - 2))
    return jnp.where(oh, new.astype(buf.dtype), buf)


def attention_decode(
    p: Params, x: Array, cache: Params, pos: Array, cfg: ModelConfig,
    abft: ABFTConfig, *, window: int = 0, use_rope: bool = True,
) -> Tuple[Array, Params, List[Check]]:
    """One-token decode.  x: [B,1,d]; pos: scalar int32 (current position).
    The cache is a ring buffer of fixed length; `pos` entries give absolute
    positions for RoPE-free masking."""
    b = x.shape[0]
    length = cache["k"].shape[1]
    positions = jnp.full((b, 1), pos, jnp.int32)

    q, c1 = dense(p["wq"], x, abft)
    k_new, c2 = dense(p["wk"], x, abft)
    v_new, c3 = dense(p["wv"], x, abft)
    checks = c1 + c2 + c3
    if use_rope and cfg.rope_frac > 0:
        q = apply_rope(q, positions, cfg.rope_theta, cfg.rope_frac)
        k_new = apply_rope(k_new, positions, cfg.rope_theta, cfg.rope_frac)

    slot = jnp.mod(pos, length)
    # masked one-hot ring-buffer writes (§Perf hillclimb 2): elementwise over
    # the seq-sharded cache, no involuntary resharding
    k = _masked_update(cache["k"], k_new, slot)
    v = _masked_update(cache["v"], v_new, slot)
    kpos = _masked_update(cache["pos"][..., None],
                          jnp.broadcast_to(pos, (b, 1, 1)).astype(jnp.int32),
                          slot)[..., 0]
    new_cache = {"k": k, "v": v, "pos": kpos, "vr": cache["vr"]}

    vr = None
    if abft.mode == "fused":
        # incremental check-column update (§Perf hillclimb 3): fold w_or
        # through the NEW token's V only; history is already cached.
        w_or = _fold_wo_checkcol(p, cfg, q.dtype)
        g = cfg.kv_groups
        w_org = w_or.reshape(cfg.n_kv_heads, g, cfg.hd)
        vr_new = jnp.einsum("bskh,kgh->bskg", v_new.astype(q.dtype),
                            w_org).reshape(b, 1, cfg.n_heads)
        vr = _masked_update(cache["vr"], vr_new, slot)
        new_cache["vr"] = vr
        vr = vr.astype(q.dtype)

    # single-shot attention for T=1 (chunk = full length -> no scan)
    o, o_extra, m, l = streaming_attention(
        q, k, v, vr, q_positions=positions, k_positions=kpos,
        causal=True, window=window, chunk=length)
    o = _maybe_inject(o)

    out, oc = dense(p["wo"], o.reshape(b, 1, -1).astype(x.dtype),
                    abft if abft.mode == "split" else ABFTConfig(mode="none"))
    checks += oc
    if abft.mode == "fused":
        checks.append(Check(predicted=o_extra.astype(jnp.float32).sum(),
                            actual=out.astype(abft.dtype).sum()))
    elif abft.mode == "split":
        pred = _split_second_pass(
            q, k, v, m, l, q_positions=positions, k_positions=kpos,
            causal=True, window=window, chunk=min(cfg.attn_chunk, length),
            dtype_acc=abft.dtype).sum()
        checks.append(Check(predicted=pred, actual=o.astype(abft.dtype).sum()))
    return out, new_cache, checks
