"""Shared model components: norms, activations, RoPE, dense layers, embeds.

Pure-functional style: ``init_*`` builds param subtrees from a PRNG key;
``apply`` functions are stateless.  All matmul-bearing blocks accept an
:class:`~repro.core.abft.ABFTConfig` and return the checks they performed, so
ABFT threads through the entire model without globals.
"""
from __future__ import annotations

import math
from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.core.abft import ABFTConfig, Check, check_matmul

Array = jax.Array
Params = Dict[str, Any]


def cdtype(cfg: ModelConfig):
    return jnp.bfloat16 if cfg.dtype == "bfloat16" else jnp.float32


# ---------------------------------------------------------------------------
# initializers — params are stored in float32; compute casts per-config.
# ---------------------------------------------------------------------------

def trunc_normal(key, shape, std):
    return std * jax.random.truncated_normal(key, -2.0, 2.0, shape, jnp.float32)


def init_dense(key, d_in: int, d_out: Tuple[int, ...] | int, bias: bool = False):
    if isinstance(d_out, int):
        d_out = (d_out,)
    w = trunc_normal(key, (d_in, *d_out), std=1.0 / math.sqrt(d_in))
    p = {"w": w}
    if bias:
        p["b"] = jnp.zeros(d_out, jnp.float32)
    return p


def init_norm(d: int):
    return {"scale": jnp.zeros((d,), jnp.float32)}   # offset-style (gemma (1+w))


# ---------------------------------------------------------------------------
# norms / activations
# ---------------------------------------------------------------------------

def rms_norm(x: Array, p: Params, eps: float, offset_base: float = 1.0) -> Array:
    dt = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    y = x * jax.lax.rsqrt(var + eps) * (offset_base + p["scale"])
    return y.astype(dt)


def layer_norm(x: Array, p: Params, eps: float) -> Array:
    dt = x.dtype
    x = x.astype(jnp.float32)
    mu = x.mean(-1, keepdims=True)
    var = x.var(-1, keepdims=True)
    y = (x - mu) * jax.lax.rsqrt(var + eps) * (1.0 + p["scale"])
    if "bias" in p:
        y = y + p["bias"]
    return y.astype(dt)


def norm_apply(x: Array, p: Params, cfg) -> Array:
    if getattr(cfg, "norm", "rms") == "ln":
        return layer_norm(x, p, cfg.norm_eps)
    return rms_norm(x, p, cfg.norm_eps)


def sinusoid_positions(positions: Array, d: int, dtype) -> Array:
    """[B,T] -> [B,T,d] standard transformer sinusoids."""
    half = d // 2
    freqs = jnp.exp(-math.log(10000.0) * jnp.arange(half) / max(half - 1, 1))
    ang = positions[..., None].astype(jnp.float32) * freqs
    return jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], axis=-1).astype(dtype)


def act_fn(name: str):
    return {"gelu": jax.nn.gelu, "silu": jax.nn.silu, "relu": jax.nn.relu}[name]


# ---------------------------------------------------------------------------
# RoPE (full / partial "2d" à la ChatGLM / none)
# ---------------------------------------------------------------------------

def rope_freqs(hd_rot: int, theta: float) -> Array:
    return 1.0 / (theta ** (jnp.arange(0, hd_rot, 2, dtype=jnp.float32) / hd_rot))


def apply_rope(x: Array, positions: Array, theta: float, frac: float = 1.0) -> Array:
    """x: [B, T, H, hd]; positions: [B, T].  frac < 1 rotates only the first
    frac*hd dims (ChatGLM-style partial/2d RoPE)."""
    hd = x.shape[-1]
    hd_rot = int(hd * frac)
    hd_rot -= hd_rot % 2
    if hd_rot == 0:
        return x
    xr, xp = x[..., :hd_rot], x[..., hd_rot:]
    freqs = rope_freqs(hd_rot, theta)                       # [hd_rot/2]
    ang = positions[..., None].astype(jnp.float32) * freqs  # [B,T,hd_rot/2]
    cos, sin = jnp.cos(ang)[:, :, None, :], jnp.sin(ang)[:, :, None, :]
    x1, x2 = xr[..., ::2], xr[..., 1::2]
    out1 = x1 * cos - x2 * sin
    out2 = x2 * cos + x1 * sin
    xr = jnp.stack([out1, out2], axis=-1).reshape(xr.shape)
    return jnp.concatenate([xr, xp], axis=-1).astype(x.dtype)


# ---------------------------------------------------------------------------
# embedding / unembedding
# ---------------------------------------------------------------------------

def init_embed(key, vocab: int, d: int):
    return {"table": trunc_normal(key, (vocab, d), std=1.0)}


def embed(p: Params, tokens: Array, cfg: ModelConfig) -> Array:
    x = jnp.take(p["table"], tokens, axis=0).astype(cdtype(cfg))
    if cfg.embed_scale:
        x = x * jnp.asarray(math.sqrt(cfg.d_model), x.dtype)
    return x


def unembed(p: Params, x: Array, cfg: ModelConfig,
            abft: ABFTConfig) -> Tuple[Array, List[Check]]:
    w = p["table"].astype(cdtype(cfg)) if "table" in p else p["w"].astype(cdtype(cfg))
    logits = jnp.einsum("btd,vd->btv", x, w) if "table" in p else \
        jnp.einsum("btd,dv->btv", x, w)
    checks: List[Check] = []
    if abft.enabled:
        wt = w.T if "table" in p else w
        checks.append(check_matmul(x.reshape(-1, x.shape[-1]), wt,
                                   logits.reshape(-1, logits.shape[-1]), abft))
    return logits.astype(jnp.float32), checks


# ---------------------------------------------------------------------------
# checked dense application (split-ABFT unit for isolated matmuls)
# ---------------------------------------------------------------------------

def dense(p: Params, x: Array, abft: ABFTConfig,
          out_axes: int = 1) -> Tuple[Array, List[Check]]:
    """y = x @ w (+ b).  x: [..., d_in]; w: [d_in, *out].  The ABFT check runs
    on the 2-D flattened product — one scalar per call.

    A folded right checksum ``p["w_r"]`` ([d_in], from ``fold_w_r_tree`` at
    weight load — the paper's offline eq.-5 convention) is consumed instead
    of the per-step row-sum of W: the predicted side then comes from the
    *master* weights, so a post-load weight corruption trips the check (a
    recomputed row-sum of the corrupted W would cancel it).  A fold whose
    shape doesn't match this call's flattened layout is ignored, not
    misapplied."""
    w = p["w"].astype(x.dtype)
    d_in = w.shape[0]
    out_shape = w.shape[1:]
    x2 = x.reshape(-1, d_in)
    w2 = w.reshape(d_in, -1)
    y2 = x2 @ w2
    checks: List[Check] = []
    if abft.enabled:
        w_r = p.get("w_r")
        if w_r is not None and w_r.shape != (d_in,):
            w_r = None
        checks.append(check_matmul(x2, w2, y2, abft, b_r=w_r))
    y = y2.reshape(*x.shape[:-1], *out_shape)
    if "b" in p:
        y = y + p["b"].astype(y.dtype)
    return y, checks
