"""Mixture-of-Experts with top-k routing, capacity, shared experts, expert
parallelism — and the paper's fused ABFT chain on the combine path.

The combine step is structurally the GCN aggregation:  Y = C · Z  where
C [T, E·C] is the sparse gate/combine matrix (nnz = T·k, like the adjacency
S) and Z = G · W₂ are the per-expert down-projections.  GCN-ABFT eq. (4)
fuses the check:

    eᵀ(C · G · W₂)e = (eᵀC) · G · (W₂ e)

`W₂ e` is offline; G carries NO check state (the paper's core saving); eᵀC
is the per-slot gate mass — available for free from the router.  Implemented
as one extra accumulator column per expert (`z_extra = G_e @ w2r_e`).

Dispatch layout: tokens are scattered to a dense [E, cap, d] buffer
(sharding: E over the 'model' mesh axis → GSPMD emits the expert-parallel
all-to-all); gather+weighted-sum combines.  Capacity overflow drops tokens
(standard GShard behaviour) — the combine matrix C reflects the drops, so
the ABFT identity stays exact.
"""
from __future__ import annotations

from typing import Any, Dict, List, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.core.abft import ABFTConfig, Check
from repro.models.common import dense, init_dense, trunc_normal
from repro.models.mlp import init_mlp, mlp_block

Array = jax.Array
Params = Dict[str, Any]


def _pin_experts(x: Array) -> Array:
    """Constrain [E, cap, ...] expert activations to expert-parallel layout
    (E on 'model').  Forces GSPMD to resolve the expert weights' FSDP axis
    by all-gathering WEIGHT shards (~150 MB/layer) instead of all-reducing
    [E,cap,f] activations (7.75 GiB/layer observed on qwen3-moe train —
    §Perf iteration 6).  No-op without a mesh."""
    from jax.sharding import PartitionSpec
    try:
        spec = PartitionSpec("model", *(None,) * (x.ndim - 1))
        return jax.lax.with_sharding_constraint(x, spec)
    except Exception:
        return x


def init_moe(key, cfg: ModelConfig) -> Params:
    mc = cfg.moe
    ks = jax.random.split(key, 5)
    d, f = cfg.d_model, mc.d_ff_expert
    p = {
        "router": {"w": trunc_normal(ks[0], (d, mc.n_experts), std=d ** -0.5)},
        "w_up": trunc_normal(ks[1], (mc.n_experts, d, f), std=d ** -0.5),
        "w_gate": trunc_normal(ks[2], (mc.n_experts, d, f), std=d ** -0.5),
        "w_down": trunc_normal(ks[3], (mc.n_experts, f, d), std=f ** -0.5),
    }
    if mc.n_shared:
        shared_ff = mc.d_ff_shared or mc.n_shared * mc.d_ff_expert
        p["shared"] = init_mlp(ks[4], cfg, d_ff=shared_ff)
    return p


def _capacity(tokens: int, mc) -> int:
    cap = int(tokens * mc.top_k * mc.capacity_factor / mc.n_experts)
    return max(cap, mc.top_k)


def moe_block(p: Params, x: Array, cfg: ModelConfig, abft: ABFTConfig
              ) -> Tuple[Array, List[Check], Array]:
    """x: [B, T, d] -> (y, checks, aux_loss)."""
    mc = cfg.moe
    b, t, d = x.shape
    n_tok = b * t
    xt = x.reshape(n_tok, d)
    checks: List[Check] = []

    # --- routing
    logits, rc = dense(p["router"], xt, abft)
    checks += rc
    probs = jax.nn.softmax(logits.astype(jnp.float32), axis=-1)
    gate_vals, experts = jax.lax.top_k(probs, mc.top_k)       # [N,k]
    gate_vals = gate_vals / jnp.maximum(
        gate_vals.sum(-1, keepdims=True), 1e-9)               # renormalize

    # load-balancing auxiliary loss (Switch-style)
    me = probs.mean(0)
    one_hot_top1 = jax.nn.one_hot(experts[:, 0], mc.n_experts)
    ce = one_hot_top1.mean(0)
    aux = mc.n_experts * jnp.sum(me * ce)

    # --- capacity assignment: position of each (token, slot) in its expert
    cap = _capacity(n_tok, mc)
    flat_expert = experts.reshape(-1)                          # [N*k]
    onehot = jax.nn.one_hot(flat_expert, mc.n_experts, dtype=jnp.int32)
    pos_in_e = jnp.cumsum(onehot, axis=0) * onehot - 1         # [N*k, E]
    slot_pos = pos_in_e.max(axis=1)                            # [N*k]
    keep = slot_pos < cap
    gate_keep = jnp.where(keep, gate_vals.reshape(-1), 0.0)

    # --- dispatch (scatter tokens into [E, cap, d])
    tok_idx = jnp.repeat(jnp.arange(n_tok), mc.top_k)
    safe_slot = jnp.where(keep, slot_pos, cap - 1)
    buf = jnp.zeros((mc.n_experts, cap, d), xt.dtype)
    contrib = jnp.where(keep[:, None], xt[tok_idx], 0.0)
    buf = buf.at[flat_expert, safe_slot].add(contrib)

    # --- expert MLPs (batched over E; E is sharded over 'model')
    up = jnp.einsum("ecd,edf->ecf", buf, p["w_up"].astype(buf.dtype))
    gt = jnp.einsum("ecd,edf->ecf", buf, p["w_gate"].astype(buf.dtype))
    g = jax.nn.silu(gt) * up                                   # [E,cap,f]
    z = jnp.einsum("ecf,efd->ecd", g, p["w_down"].astype(g.dtype))
    if abft.enabled:
        # split checks of the batched expert matmuls (up/gate)
        checks.append(Check(
            predicted=jnp.einsum("ed,edf->", buf.astype(abft.dtype).sum(1),
                                 p["w_up"].astype(abft.dtype)),
            actual=up.astype(abft.dtype).sum()))
        checks.append(Check(
            predicted=jnp.einsum("ed,edf->", buf.astype(abft.dtype).sum(1),
                                 p["w_gate"].astype(abft.dtype)),
            actual=gt.astype(abft.dtype).sum()))

    # --- combine: Y = C · Z  (gather + gate-weighted sum)
    zg = z[flat_expert, safe_slot]                             # [N*k, d]
    y = jnp.zeros((n_tok, d), z.dtype).at[tok_idx].add(
        gate_keep[:, None].astype(z.dtype) * zg)

    if abft.enabled:
        if abft.mode == "fused":
            # fused chain eᵀ(C·G·W₂)e = (eᵀC)·G·(W₂ e): one extra column.
            w2r = p["w_down"].astype(abft.dtype).sum(-1)       # [E,f] offline
            z_extra = jnp.einsum("ecf,ef->ec", g.astype(abft.dtype), w2r)
            pred = jnp.einsum(
                "n,n->", gate_keep.astype(abft.dtype),
                z_extra[flat_expert, safe_slot].astype(abft.dtype))
            checks.append(Check(predicted=pred,
                                actual=y.astype(abft.dtype).sum()))
        else:
            # split: check G@W₂ per expert, then the combine separately.
            checks.append(Check(
                predicted=jnp.einsum("ef,efd->", g.astype(abft.dtype).sum(1),
                                     p["w_down"].astype(abft.dtype)),
                actual=z.astype(abft.dtype).sum()))
            pred = jnp.einsum("n,n->", gate_keep.astype(abft.dtype),
                              zg.astype(abft.dtype).sum(-1))
            checks.append(Check(predicted=pred,
                                actual=y.astype(abft.dtype).sum()))

    y = y.reshape(b, t, d)
    # --- shared experts run densely alongside
    if "shared" in p:
        ys, sc = mlp_block(p["shared"], x, cfg, abft)
        y = y + ys
        checks += sc
    return y, checks, aux
