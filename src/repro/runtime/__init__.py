from .abft_guard import ABFTGuard, GuardConfig  # noqa: F401
from .watchdog import StragglerWatchdog  # noqa: F401
