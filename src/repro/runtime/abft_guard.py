"""ABFT guard: closes the loop from error *detection* to *recovery*.

The paper detects faults; a 1000-node deployment must also act on them.
Policy (per train/serve step):

  1. run the step; the ABFTReport flag is a replicated scalar in the step
     outputs (one host read, no extra collective beyond the checksum psum);
  2. flag set  -> retry the step from the same inputs (bounded retries) —
     transient SDC almost never repeats on identical data;
  3. still flagged -> restore from the last checkpoint and *replay the step*
     — this is the persistent-fault path (bad chip).  The replay is
     re-verified: a restore whose replay still flags is retried up to
     ``max_restores`` times and then raised, so the guard never adopts
     unverified state or reports the failed attempt's metrics as the
     step's outcome;
  4. track flag-rate statistics: a chip flagging above `evict_rate` is
     reported via `should_evict` for the cluster layer to act on.

Batched multi-graph serving uses :meth:`ABFTGuard.run_step_graphs` instead:
the step emits a *per-graph* verdict vector (the packed block-ELL segmented
epilogue or the dense batched checks), and only the flagged graphs are
retried — a bit flip in one packed graph costs one small re-pack, not a
whole-bucket replay.

Because the checked step is pure (params, batch) -> outputs, the retry is
exact replay; no optimizer state was committed for a flagged step (the guard
runs *before* state adoption).  ``restore_fn`` either rewinds external state
by side effect (and returns None), or returns the restored *state*, which
the guard substitutes for the step's first positional argument on replay —
so ``restore_fn=lambda: ckpt.restore(state)[0]`` rolls training back to the
checkpoint and the replayed step runs from it.
"""
from __future__ import annotations

import collections
import dataclasses
import logging
from typing import Any, Callable, Optional, Tuple

import numpy as np

log = logging.getLogger(__name__)


@dataclasses.dataclass
class GuardConfig:
    max_retries: int = 2
    max_restores: int = 1        # bounded restore->replay->verify attempts
    evict_rate: float = 1e-3     # flags per step above which chip is suspect
    window: int = 1000           # rolling window (steps) for should_evict
    min_samples: int = 100       # steps seen before eviction is judged


class ABFTGuard:
    def __init__(self, cfg: Optional[GuardConfig] = None,
                 restore_fn: Optional[Callable[[], Any]] = None):
        # cfg is constructed per guard — a dataclass default instance would
        # be one shared mutable object across every guard in the process.
        self.cfg = cfg if cfg is not None else GuardConfig()
        self.restore_fn = restore_fn
        self.steps = 0
        self.flags = 0           # lifetime count of flagged steps
        self.retries = 0
        self.graph_retries = 0   # individual graphs re-run by partial retry
        self.restores = 0
        # per-step flagged? outcomes, newest last; drives the rolling rate —
        # a chip that degraded an hour in must look bad *now*, not diluted
        # by its clean history.
        self._recent: collections.deque = collections.deque(
            maxlen=max(self.cfg.window, 1))

    def run_step(self, step_fn: Callable[..., Tuple[Any, Any]], *args):
        """step_fn returns (new_state, metrics) where metrics['abft_flag'] is
        the replicated detection scalar.  Returns the adopted (state, metrics)
        — always from a *verified* (unflagged) execution.
        """
        self.steps += 1
        step_flagged = False
        metrics = None
        for attempt in range(self.cfg.max_retries + 1):
            out, metrics = step_fn(*args)
            flagged = bool(metrics["abft_flag"])
            if not flagged:
                if attempt:
                    log.warning("ABFT: retry %d succeeded", attempt)
                self._recent.append(step_flagged)
                return out, metrics
            if not step_flagged:
                step_flagged = True
                self.flags += 1
            self.retries += int(attempt < self.cfg.max_retries)
            log.error("ABFT flag on step %d (attempt %d): max_rel=%.3e",
                      self.steps, attempt, float(metrics.get("abft_max_rel", -1)))
        # persistent failure: roll back, replay, and re-verify
        self._recent.append(True)
        return self._restore_and_replay(step_fn, args)

    def run_step_graphs(self, step_fn: Callable[..., Tuple[Any, Any]],
                        retry_fn: Callable[[Any, np.ndarray],
                                           Tuple[Any, Any]], *args):
        """Per-graph guarded batch step for multi-graph serving.

        ``step_fn(*args)`` returns (out, metrics) where
        ``metrics['abft_graph_flags']`` is the per-graph verdict vector (the
        packed segmented check corners, or the dense batched checks).  When
        any graph flags, ``retry_fn(out, flagged_idx)`` re-runs *only* those
        graphs and returns (patched_out, sub_metrics) with the per-graph
        entries of ``sub_metrics`` aligned to ``flagged_idx`` — linearity of
        the checksum makes the per-graph decomposition exact, so the
        untouched graphs' verified results are kept and the returned metrics
        reflect the *adopted* executions, not the failed attempts.  Bounded
        like :meth:`run_step`; persistently flagged graphs fall back to the
        restore->replay->verify path for the whole step.
        """
        self.steps += 1
        out, metrics = step_fn(*args)
        flags = np.array(metrics["abft_graph_flags"], dtype=bool).copy()
        if not flags.any():
            self._recent.append(False)
            return out, metrics
        self.flags += 1
        grel = None
        if "abft_graph_max_rel" in metrics:
            grel = np.array(metrics["abft_graph_max_rel"],
                            dtype=np.float32).copy()
        for attempt in range(1, self.cfg.max_retries + 1):
            idx = np.nonzero(flags)[0]
            log.error("ABFT: step %d: %d/%d graphs flagged; retrying them "
                      "(attempt %d)", self.steps, len(idx), len(flags),
                      attempt)
            out, sub = retry_fn(out, idx)
            self.retries += 1
            self.graph_retries += len(idx)
            flags[idx] = np.array(sub["abft_graph_flags"],
                                  dtype=bool)[:len(idx)]
            if grel is not None and "abft_graph_max_rel" in sub:
                grel[idx] = np.array(sub["abft_graph_max_rel"],
                                     dtype=np.float32)[:len(idx)]
            if not flags.any():
                log.warning("ABFT: per-graph retry %d succeeded", attempt)
                self._recent.append(True)
                metrics = {**metrics, "abft_flag": False,
                           "abft_graph_flags": flags}
                # adopted metrics only: the failed attempts' divergences
                # were replaced along with their outputs — when we cannot
                # reconstruct max_rel per graph, drop it rather than return
                # the discarded execution's value under a clean flag
                if grel is not None:
                    metrics["abft_graph_max_rel"] = grel
                    metrics["abft_max_rel"] = grel.max(initial=0.0)
                else:
                    metrics.pop("abft_max_rel", None)
                return out, metrics
        self._recent.append(True)
        # batch steps take data operands, not model state: a state-returning
        # restore_fn cannot be spliced into the args (run_step's convention)
        return self._restore_and_replay(step_fn, args, adopt_state=False)

    def _restore_and_replay(self, step_fn, args, *,
                            adopt_state: bool = True) -> Tuple[Any, Any]:
        """Persistent-fault path: restore, replay the step, verify the
        replay.  ``restore_fn`` either rewinds external state by side
        effect (return None) or returns the restored *state*, which — on
        the :meth:`run_step` path, where the first positional argument IS
        the state — replaces it for the replay (the checkpoint-rollback
        convention ``ABFTGuard(restore_fn=lambda: ckpt.restore(state)[0])``
        that train.py uses).  Batch-serving steps (:meth:`run_step_graphs`)
        pass ``adopt_state=False``: their args are data operands, so a
        returned state is ignored.  Never returns flagged metrics; raises
        after ``max_restores`` failed restore+replay rounds."""
        if self.restore_fn is None:
            raise RuntimeError("ABFT: persistent fault and no restore_fn "
                               "given")
        for r in range(1, self.cfg.max_restores + 1):
            log.error("ABFT: persistent fault; restore %d/%d + replay",
                      r, self.cfg.max_restores)
            self.restores += 1
            restored = self.restore_fn()
            replay_args = args
            if adopt_state and restored is not None and args:
                replay_args = (restored,) + tuple(args[1:])
            out, metrics = step_fn(*replay_args)
            # batch steps are only required to emit the per-graph vector
            flag = metrics.get(
                "abft_flag",
                np.asarray(metrics["abft_graph_flags"]).any()
                if "abft_graph_flags" in metrics else True)
            if not bool(np.asarray(flag).any()):
                log.warning("ABFT: replay after restore %d verified clean", r)
                return out, metrics
        raise RuntimeError(
            f"ABFT: step still flagged after {self.cfg.max_restores} "
            f"restore+replay attempt(s) — refusing to adopt unverified "
            f"state (suspect persistent hardware fault; evict this host)")

    @property
    def flag_rate(self) -> float:
        """Flagged-step rate over the rolling window (recent behaviour)."""
        if not self._recent:
            return 0.0
        return sum(self._recent) / len(self._recent)

    @property
    def lifetime_flag_rate(self) -> float:
        return self.flags / max(self.steps, 1)

    def should_evict(self) -> bool:
        seen = len(self._recent)
        need = min(self.cfg.min_samples, self.cfg.window)
        return seen >= need and self.flag_rate > self.cfg.evict_rate
