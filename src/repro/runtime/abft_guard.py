"""ABFT guard: closes the loop from error *detection* to *recovery*.

The paper detects faults; a 1000-node deployment must also act on them.
Policy (per train/serve step):

  1. run the step; the ABFTReport flag is a replicated scalar in the step
     outputs (one host read, no extra collective beyond the checksum psum);
  2. flag set  -> retry the step from the same inputs (bounded retries) —
     transient SDC almost never repeats on identical data;
  3. still flagged -> restore from the last checkpoint and *replay the step*
     — this is the persistent-fault path (bad chip).  The replay is
     re-verified: a restore whose replay still flags is retried up to
     ``max_restores`` times and then raised, so the guard never adopts
     unverified state or reports the failed attempt's metrics as the
     step's outcome;
  4. track flag-rate statistics: a chip flagging above `evict_rate` is
     reported via `should_evict` for the cluster layer to act on.

Sticky-fault discrimination (PR 9): a transient SDC does not recur at one
coordinate, a stuck-at cell does — so the guard remembers the finest
flagged (layer, stripe, slot) sites of its recent flagged steps, and a
site recurring ``persistent_threshold`` times within a
``persistent_window`` of flagged steps is classified *persistent*.  From
then on that site's flags skip the doomed surgical/graph retry tiers
(every re-execution on the same unit re-reads the same stuck state) and
escalate straight to restore->replay with exponential backoff
(``restore_backoff``/``max_backoff``); the guard marks itself ``suspect``
so the serving layer (``engine.streaming.StreamingEngine``) can drain,
checkpoint, and swap to a degraded backend.  ``repair_tiers()`` surfaces
the slot/stripe/graph/restore repair distribution plus the
persistent-site and backoff state for serve stats and BENCH payloads.

Batched multi-graph serving uses :meth:`ABFTGuard.run_step_graphs` instead:
the step emits a *per-graph* verdict vector (the packed block-ELL segmented
epilogue or the dense batched checks), and only the flagged graphs are
retried — a bit flip in one packed graph costs one small re-pack, not a
whole-bucket replay.

At stripe granularity the ladder gains its cheapest rung: when the step
also emits per-stripe verdicts (``abft_stripe_flags``) and a
``stripe_retry_fn`` is given, the guard first attempts a *surgical* repair
— re-execute only the flagged stripes' rows, splice, re-verify
(``engine.localize.surgical_stripe_retry``) — and only escalates to the
per-graph retry, and then to restore->replay, when the repair cannot be
verified.  At slot granularity there is one rung below that: per-(stripe,
ell-slot) verdicts (``abft_slot_flags``) plus a ``slot_retry_fn``
(``engine.localize.surgical_slot_retry``) repair with row-level downstream
propagation, escalating slot -> stripe -> graph -> restore.
``guard.retries`` counts re-executions *performed* on every tier (never
mere intents); ``slot_retries`` / ``stripe_retries`` /
``recomputed_rows`` track the surgical tiers' row economics.

Because the checked step is pure (params, batch) -> outputs, the retry is
exact replay; no optimizer state was committed for a flagged step (the guard
runs *before* state adoption).  ``restore_fn`` either rewinds external state
by side effect (and returns None), or returns the restored *state*, which
the guard substitutes for the step's first positional argument on replay —
so ``restore_fn=lambda: ckpt.restore(state)[0]`` rolls training back to the
checkpoint and the replayed step runs from it.
"""
from __future__ import annotations

import collections
import dataclasses
import logging
import time
from typing import Any, Callable, Optional, Tuple

import numpy as np

log = logging.getLogger(__name__)


@dataclasses.dataclass
class GuardConfig:
    max_retries: int = 2
    max_restores: int = 1        # bounded restore->replay->verify attempts
    evict_rate: float = 1e-3     # flags per step above which chip is suspect
    window: int = 1000           # rolling window (steps) for should_evict
    min_samples: int = 100       # steps seen before eviction is judged
    # sticky-fault discrimination: the same (layer, stripe, slot) site
    # flagging >= persistent_threshold times within the last
    # persistent_window FLAGGED steps is classified *persistent* — a
    # transient SDC does not recur at one coordinate; a stuck-at cell
    # does.  Persistent faults skip the doomed retry tiers (re-executing
    # on the same unit re-reads the same stuck value) and escalate
    # straight to restore->replay.
    persistent_window: int = 8
    persistent_threshold: int = 3
    # exponential backoff between restore escalations: the r-th restore
    # round sleeps restore_backoff * 2^level (capped at max_backoff)
    # before replaying, so a host thrashing on a persistent fault does
    # not hammer the restore path.  0 disables (the default: tests and
    # single-step callers should not sleep).
    restore_backoff: float = 0.0
    max_backoff: float = 30.0


class ABFTGuard:
    def __init__(self, cfg: Optional[GuardConfig] = None,
                 restore_fn: Optional[Callable[[], Any]] = None,
                 sleep_fn: Callable[[float], None] = time.sleep):
        # cfg is constructed per guard — a dataclass default instance would
        # be one shared mutable object across every guard in the process.
        self.cfg = cfg if cfg is not None else GuardConfig()
        self.restore_fn = restore_fn
        self._sleep = sleep_fn   # injectable: tests assert backoff delays
        self.steps = 0
        self.flags = 0           # lifetime count of flagged steps
        self.retries = 0         # re-executions PERFORMED (any tier)
        self.graph_retries = 0   # individual graphs re-run by partial retry
        self.stripe_retries = 0  # individual stripes re-run surgically
        self.slot_retries = 0    # stripes re-run by the slot-surgical tier
        self.recomputed_rows = 0  # rows re-executed by partial retries
        self.restores = 0
        # per-step flagged? outcomes, newest last; drives the rolling rate —
        # a chip that degraded an hour in must look bad *now*, not diluted
        # by its clean history.
        self._recent: collections.deque = collections.deque(
            maxlen=max(self.cfg.window, 1))
        # sticky-fault discrimination state: the finest flagged coordinates
        # of the last persistent_window FLAGGED adjudications, and the set
        # of sites classified persistent from their recurrence
        self._site_history: collections.deque = collections.deque(
            maxlen=max(self.cfg.persistent_window, 1))
        self.persistent_sites: set = set()
        self.persistent_escalations = 0   # tier-skips on persistent sites
        self.suspect = False              # backend marked suspect
        self._backoff_level = 0           # consecutive restore escalations

    # -- sticky-fault discrimination --------------------------------------

    @staticmethod
    def _flag_sites(metrics, flags: np.ndarray) -> frozenset:
        """The finest available coordinates of this step's flags, as
        stable string keys: per-op ids when the step carries op-keyed
        verdicts (``abft_op_flags`` aligned to the static
        ``abft_op_ids`` tuple — the checked-op serving paths: LM
        prefill/decode, GAT), (layer, stripe, slot) when the step carries
        slot corners, (layer, stripe) at stripe granularity, the graph
        slot otherwise.  Capped at 64 sites — a step that floods more
        coordinates than that is a step-wide event, not a stuck cell."""
        ids = metrics.get("abft_op_ids") if isinstance(metrics, dict) \
            else None
        if ids is not None:
            a = np.asarray(metrics.get("abft_op_flags", False),
                           dtype=bool).ravel()
            ids = tuple(ids)
            if a.any() and a.size == len(ids):
                return frozenset(f"op:{ids[int(i)]}"
                                 for i in np.nonzero(a)[0][:64])
        for key, fmt in (("abft_slot_flags",
                          lambda c: "slot:L{}:S{}:E{}".format(*c)),
                         ("abft_stripe_flags",
                          lambda c: "stripe:L{}:S{}".format(*c))):
            a = np.asarray(metrics.get(key, False), dtype=bool)
            if a.ndim and a.any():
                return frozenset(fmt(tuple(int(v) for v in c))
                                 for c in np.argwhere(a)[:64])
        return frozenset(f"graph:{int(g)}"
                         for g in np.nonzero(flags)[0][:64])

    def _note_sites(self, sites: frozenset) -> frozenset:
        """Record one flagged step's sites; classify any site recurring
        ``persistent_threshold`` times within the window as persistent.
        Returns this step's sites that are (now) classified persistent."""
        self._site_history.append(sites)
        for s in sites:
            if s in self.persistent_sites:
                continue
            if sum(s in past for past in self._site_history) \
                    >= self.cfg.persistent_threshold:
                self.persistent_sites.add(s)
                self.suspect = True
                log.error(
                    "ABFT: site %s flagged %d times within the last %d "
                    "flagged steps — classified PERSISTENT (stuck-at); "
                    "backend marked suspect", s,
                    self.cfg.persistent_threshold,
                    len(self._site_history))
        return sites & self.persistent_sites

    def reset_backend_state(self) -> None:
        """Called by the serving layer after it acts on eviction advice
        (drain + checkpoint + swap to a degraded backend): the rolling
        window, site classifications, suspect mark, and backoff level all
        describe the REPLACED execution path.  Lifetime counters stand."""
        self._recent.clear()
        self._site_history.clear()
        self.persistent_sites.clear()
        self.suspect = False
        self._backoff_level = 0

    def run_step(self, step_fn: Callable[..., Tuple[Any, Any]], *args):
        """step_fn returns (new_state, metrics) where metrics['abft_flag'] is
        the replicated detection scalar.  Returns the adopted (state, metrics)
        — always from a *verified* (unflagged) execution.

        When the metrics carry per-op verdicts (``abft_op_ids`` /
        ``abft_op_flags``, as emitted by the checked-op serving engines —
        LM prefill/decode, GAT) the flagged op ids feed the same site
        history that per-graph serving uses, so a recurring ``op:<id>``
        site is classified persistent and short-circuits the doomed
        retries straight to restore-and-replay.
        """
        self.steps += 1
        step_flagged = False
        metrics = None
        for attempt in range(self.cfg.max_retries + 1):
            out, metrics = step_fn(*args)
            if attempt:
                # counted AFTER the call returns: ``retries`` means
                # re-executions performed, never intents — the same
                # convention as run_step_graphs' partial retries
                self.retries += 1
            flagged = bool(metrics["abft_flag"])
            if not flagged:
                if attempt:
                    log.warning("ABFT: retry %d succeeded", attempt)
                else:
                    self._backoff_level = 0   # clean first try
                self._recent.append(step_flagged)
                return out, metrics
            if not step_flagged:
                step_flagged = True
                self.flags += 1
                sites = self._flag_sites(metrics, np.zeros((0,), bool))
                if sites and self._note_sites(sites):
                    # a known-persistent site flagged again: retrying the
                    # same execution path is wasted work
                    log.error("ABFT: persistent site(s) %s re-flagged — "
                              "skipping retries, restoring",
                              sorted(sites & self.persistent_sites))
                    break
            log.error("ABFT flag on step %d (attempt %d): max_rel=%.3e",
                      self.steps, attempt, float(metrics.get("abft_max_rel", -1)))
        # persistent failure: roll back, replay, and re-verify
        self._recent.append(True)
        return self._restore_and_replay(step_fn, args)

    def run_step_graphs(self, step_fn: Callable[..., Tuple[Any, Any]],
                        retry_fn: Callable[[Any, np.ndarray],
                                           Tuple[Any, Any]], *args,
                        stripe_retry_fn: Optional[
                            Callable[[Any, Any], Tuple[Any, Any]]] = None,
                        slot_retry_fn: Optional[
                            Callable[[Any, Any], Tuple[Any, Any]]] = None):
        """Per-graph guarded batch step for multi-graph serving.

        ``step_fn(*args)`` returns (out, metrics) where
        ``metrics['abft_graph_flags']`` is the per-graph verdict vector (the
        packed segmented check corners, or the dense batched checks).
        Equivalent to dispatching the step yourself and handing its outputs
        to :meth:`adjudicate` — which is exactly what the streaming engine
        does to overlap host-side packing with device execution.
        """
        out, metrics = step_fn(*args)
        return self.adjudicate(out, metrics, retry_fn,
                               stripe_retry_fn=stripe_retry_fn,
                               slot_retry_fn=slot_retry_fn,
                               replay=(step_fn, args))

    @staticmethod
    def _adopt(metrics):
        """Adopted-metrics hygiene: the step's intermediate activations
        (``abft_h_layers``, every layer's full input; ``abft_x_layers``,
        the stashed two-pass combination outputs) exist ONLY so a surgical
        retry can re-execute flagged rows.  Once the ladder has resolved
        they are dead weight — a serving loop that retains per-batch
        metrics would pin every batch's activations for the whole run —
        so they never leave the guard."""
        if isinstance(metrics, dict) and (
                "abft_h_layers" in metrics or "abft_x_layers" in metrics):
            metrics = {k: v for k, v in metrics.items()
                       if k not in ("abft_h_layers", "abft_x_layers")}
        return metrics

    def _surgical_adopt(self, metrics, sub, flags, grel, name: str):
        """Adopted metrics of a verified surgical repair: every fault flag
        cleared, the discarded execution's divergence magnitudes dropped
        (the repair does not reconstruct them), the repaired graphs'
        max_rel replaced from the sub-sweep's corners."""
        metrics = {**metrics, "abft_flag": False,
                   "abft_graph_flags": np.asarray(sub["abft_graph_flags"],
                                                  dtype=bool)}
        for key in ("abft_stripe_flags", "abft_slot_flags"):
            if key in metrics:
                metrics[key] = np.zeros_like(
                    np.asarray(metrics[key], dtype=bool))
        metrics.pop("abft_stripe_max_rel", None)
        metrics.pop("abft_slot_max_rel", None)
        if grel is not None and "abft_graph_max_rel" in sub:
            sub_rel = np.asarray(sub["abft_graph_max_rel"], np.float32)
            if sub_rel.shape != grel.shape:
                raise ValueError(
                    f"{name}_retry_fn returned abft_graph_max_rel "
                    f"of shape {sub_rel.shape}; expected the full "
                    f"batch vector {grel.shape}")
            # replace only the repaired graphs' divergences; the
            # untouched graphs' adopted values stand
            grel = np.where(flags, sub_rel, grel)
            metrics["abft_graph_max_rel"] = grel
            metrics["abft_max_rel"] = grel.max(initial=0.0)
        else:
            metrics.pop("abft_max_rel", None)
        return metrics

    def adjudicate(self, out, metrics,
                   retry_fn: Callable[[Any, np.ndarray], Tuple[Any, Any]],
                   *, stripe_retry_fn: Optional[
                       Callable[[Any, Any], Tuple[Any, Any]]] = None,
                   slot_retry_fn: Optional[
                       Callable[[Any, Any], Tuple[Any, Any]]] = None,
                   replay: Optional[Tuple[Callable[..., Tuple[Any, Any]],
                                          tuple]] = None):
        """Adjudicate one already-dispatched batch step's verdicts.

        ``(out, metrics)`` are a step's raw outputs; reading
        ``metrics['abft_graph_flags']`` here is the first host-side
        synchronization, so a caller that dispatches step N, packs batch
        N+1, and only then adjudicates N gets pack/execute overlap for free
        (JAX async dispatch) — the streaming engine's double buffer.

        When any graph flags, ``retry_fn(out, flagged_idx)`` re-runs *only*
        those graphs and returns (patched_out, sub_metrics) with the
        per-graph entries of ``sub_metrics`` aligned to ``flagged_idx`` —
        linearity of the checksum makes the per-graph decomposition exact,
        so the untouched graphs' verified results are kept and the returned
        metrics reflect the *adopted* executions, not the failed attempts.
        The retry's returned vectors are validated against ``flagged_idx``:
        a full-batch-aligned vector would silently misattribute verdicts to
        the wrong graphs, so a shape mismatch raises.  Bounded like
        :meth:`run_step`; persistently flagged graphs fall back to the
        restore->replay->verify path via ``replay=(step_fn, args)`` (no
        ``replay`` -> the escalation raises instead of replaying).

        ``stripe_retry_fn(out, metrics)`` is the optional surgical tier,
        tried when the step carries per-stripe verdicts
        (``metrics['abft_stripe_flags']``, granularity="stripe"): it
        re-executes only the flagged stripes' rows and returns
        (patched_out, sub_metrics) with a FULL-batch
        ``sub_metrics['abft_graph_flags']`` vector (all-False on verified
        success) plus ``abft_rows_recomputed`` / ``abft_stripes_recomputed``
        accounting.  An unverified repair escalates to the per-graph tier.
        ``slot_retry_fn(out, metrics)`` is one rung finer, tried FIRST
        when the step carries per-(stripe, slot) verdicts
        (``metrics['abft_slot_flags']``, granularity="slot"): same
        contract, row-level downstream propagation; an unverified slot
        repair escalates to the stripe tier, then per-graph, then
        restore->replay.

        Adopted metrics never carry ``abft_h_layers`` / ``abft_x_layers``
        (the per-layer operand stashes exist for the surgical closures
        only — retaining them per batch would leak every batch's
        activations over a sustained stream); the closures see the full
        metrics.
        """
        self.steps += 1
        flags = np.array(metrics["abft_graph_flags"], dtype=bool).copy()
        if not flags.any():
            self._recent.append(False)
            self._backoff_level = 0
            return out, self._adopt(metrics)
        self.flags += 1
        # sticky-fault discrimination BEFORE any repair work: a site
        # already classified persistent makes every surgical/graph retry
        # doomed (the re-execution re-reads the same stuck state), so the
        # ladder is skipped and the step escalates straight to the
        # restore->replay path — with exponential backoff, and the
        # backend marked suspect for the serving layer's eviction logic.
        persistent = self._note_sites(self._flag_sites(metrics, flags))
        if persistent:
            self.persistent_escalations += 1
            self._recent.append(True)
            log.error(
                "ABFT: step %d flags persistent site(s) %s — skipping "
                "the doomed retry tiers, escalating to restore",
                self.steps, sorted(persistent)[:4])
            if replay is None:
                raise RuntimeError(
                    f"ABFT: persistent fault at {sorted(persistent)[:4]} "
                    f"and no replay=(step_fn, args) to escalate to — "
                    f"evict or degrade this backend")
            step_fn, args = replay
            out, metrics = self._restore_and_replay(step_fn, args,
                                                    adopt_state=False)
            return out, self._adopt(metrics)
        grel = None
        if "abft_graph_max_rel" in metrics:
            grel = np.array(metrics["abft_graph_max_rel"],
                            dtype=np.float32).copy()
        # --- tier -1: slot-surgical repair -------------------------------
        slflags = np.asarray(metrics.get("abft_slot_flags", False),
                             dtype=bool)
        if slot_retry_fn is not None and slflags.any():
            log.error("ABFT: step %d: %d slot corner(s) flagged; "
                      "attempting slot-surgical repair", self.steps,
                      int(slflags.sum()))
            out2, sub = slot_retry_fn(out, metrics)
            performed = int(sub.get("abft_stripes_recomputed", 0))
            self.retries += int(performed > 0)
            self.slot_retries += performed
            self.recomputed_rows += int(sub.get("abft_rows_recomputed", 0))
            new_flags = np.asarray(sub["abft_graph_flags"], dtype=bool)
            if new_flags.shape != flags.shape:
                raise ValueError(
                    f"slot_retry_fn returned abft_graph_flags of shape "
                    f"{new_flags.shape}; the surgical tier's contract is "
                    f"the FULL batch vector {flags.shape}")
            if not new_flags.any():
                log.warning("ABFT: slot-surgical repair adopted")
                self._recent.append(True)
                metrics = self._surgical_adopt(metrics, sub, flags, grel,
                                               "slot")
                return out2, self._adopt(metrics)
            out, flags = out2, new_flags.copy()
        # --- tier 0: stripe-surgical repair ------------------------------
        sflags = np.asarray(metrics.get("abft_stripe_flags", False),
                            dtype=bool)
        if stripe_retry_fn is not None and sflags.any():
            log.error("ABFT: step %d: %d stripe corner(s) flagged; "
                      "attempting surgical repair", self.steps,
                      int(sflags.sum()))
            out2, sub = stripe_retry_fn(out, metrics)
            performed = int(sub.get("abft_stripes_recomputed", 0))
            # retries counts re-executions PERFORMED: an escalation that
            # bailed before touching any stripe re-executed nothing
            self.retries += int(performed > 0)
            self.stripe_retries += performed
            self.recomputed_rows += int(sub.get("abft_rows_recomputed", 0))
            new_flags = np.asarray(sub["abft_graph_flags"], dtype=bool)
            if new_flags.shape != flags.shape:
                raise ValueError(
                    f"stripe_retry_fn returned abft_graph_flags of shape "
                    f"{new_flags.shape}; the surgical tier's contract is "
                    f"the FULL batch vector {flags.shape}")
            if not new_flags.any():
                log.warning("ABFT: surgical stripe repair adopted")
                self._recent.append(True)
                # adopted metrics only: the per-stripe divergences belong
                # to the discarded execution and are not reconstructed by
                # the repair — drop them rather than report fault-magnitude
                # values under a clean flag
                metrics = self._surgical_adopt(metrics, sub, flags, grel,
                                               "stripe")
                return out2, self._adopt(metrics)
            out, flags = out2, new_flags.copy()
        # --- tier 1: per-graph retry -------------------------------------
        for attempt in range(1, self.cfg.max_retries + 1):
            idx = np.nonzero(flags)[0]
            log.error("ABFT: step %d: %d/%d graphs flagged; retrying them "
                      "(attempt %d)", self.steps, len(idx), len(flags),
                      attempt)
            out, sub = retry_fn(out, idx)
            self.retries += 1
            self.graph_retries += len(idx)
            if "abft_rows_recomputed" in sub:
                self.recomputed_rows += int(sub["abft_rows_recomputed"])
            sub_flags = np.asarray(sub["abft_graph_flags"], dtype=bool)
            if sub_flags.shape != (len(idx),):
                raise ValueError(
                    f"retry_fn returned abft_graph_flags of shape "
                    f"{sub_flags.shape}; expected ({len(idx)},) aligned to "
                    f"flagged_idx — a full-batch vector would be silently "
                    f"misattributed to the wrong graphs")
            flags[idx] = sub_flags
            if grel is not None and "abft_graph_max_rel" in sub:
                sub_rel = np.asarray(sub["abft_graph_max_rel"],
                                     dtype=np.float32)
                if sub_rel.shape != (len(idx),):
                    raise ValueError(
                        f"retry_fn returned abft_graph_max_rel of shape "
                        f"{sub_rel.shape}; expected ({len(idx)},) aligned "
                        f"to flagged_idx")
                grel[idx] = sub_rel
            if not flags.any():
                log.warning("ABFT: per-graph retry %d succeeded", attempt)
                self._recent.append(True)
                metrics = {**metrics, "abft_flag": False,
                           "abft_graph_flags": flags}
                if sflags.any():
                    metrics["abft_stripe_flags"] = np.zeros_like(sflags)
                    metrics.pop("abft_stripe_max_rel", None)
                if slflags.any():
                    metrics["abft_slot_flags"] = np.zeros_like(slflags)
                    metrics.pop("abft_slot_max_rel", None)
                # adopted metrics only: the failed attempts' divergences
                # were replaced along with their outputs — when we cannot
                # reconstruct max_rel per graph, drop it rather than return
                # the discarded execution's value under a clean flag
                if grel is not None:
                    metrics["abft_graph_max_rel"] = grel
                    metrics["abft_max_rel"] = grel.max(initial=0.0)
                else:
                    metrics.pop("abft_max_rel", None)
                return out, self._adopt(metrics)
        self._recent.append(True)
        if replay is None:
            raise RuntimeError(
                "ABFT: persistent per-graph fault and no replay=(step_fn, "
                "args) to escalate to — the dispatching caller must keep "
                "the step closure alive until adjudication")
        # batch steps take data operands, not model state: a state-returning
        # restore_fn cannot be spliced into the args (run_step's convention)
        step_fn, args = replay
        out, metrics = self._restore_and_replay(step_fn, args,
                                                adopt_state=False)
        return out, self._adopt(metrics)

    def _restore_and_replay(self, step_fn, args, *,
                            adopt_state: bool = True) -> Tuple[Any, Any]:
        """Persistent-fault path: restore, replay the step, verify the
        replay.  ``restore_fn`` either rewinds external state by side
        effect (return None) or returns the restored *state*, which — on
        the :meth:`run_step` path, where the first positional argument IS
        the state — replaces it for the replay (the checkpoint-rollback
        convention ``ABFTGuard(restore_fn=lambda: ckpt.restore(state)[0])``
        that train.py uses).  Batch-serving steps (:meth:`run_step_graphs`)
        pass ``adopt_state=False``: their args are data operands, so a
        returned state is ignored.  Never returns flagged metrics; raises
        after ``max_restores`` failed restore+replay rounds."""
        if self.restore_fn is None:
            raise RuntimeError("ABFT: persistent fault and no restore_fn "
                               "given")
        for r in range(1, self.cfg.max_restores + 1):
            if self.cfg.restore_backoff > 0:
                delay = min(self.cfg.restore_backoff
                            * (2 ** self._backoff_level),
                            self.cfg.max_backoff)
                log.error("ABFT: restore backoff %.3fs (level %d)",
                          delay, self._backoff_level)
                self._sleep(delay)
            self._backoff_level += 1
            log.error("ABFT: persistent fault; restore %d/%d + replay",
                      r, self.cfg.max_restores)
            self.restores += 1
            restored = self.restore_fn()
            replay_args = args
            if adopt_state and restored is not None and args:
                replay_args = (restored,) + tuple(args[1:])
            out, metrics = step_fn(*replay_args)
            # batch steps are only required to emit the per-graph vector
            flag = metrics.get(
                "abft_flag",
                np.asarray(metrics["abft_graph_flags"]).any()
                if "abft_graph_flags" in metrics else True)
            if not bool(np.asarray(flag).any()):
                log.warning("ABFT: replay after restore %d verified clean", r)
                return out, metrics
        raise RuntimeError(
            f"ABFT: step still flagged after {self.cfg.max_restores} "
            f"restore+replay attempt(s) — refusing to adopt unverified "
            f"state (suspect persistent hardware fault; evict this host)")

    @property
    def flag_rate(self) -> float:
        """Flagged-step rate over the rolling window (recent behaviour)."""
        if not self._recent:
            return 0.0
        return sum(self._recent) / len(self._recent)

    @property
    def lifetime_flag_rate(self) -> float:
        return self.flags / max(self.steps, 1)

    def should_evict(self) -> bool:
        seen = len(self._recent)
        need = min(self.cfg.min_samples, self.cfg.window)
        return seen >= need and self.flag_rate > self.cfg.evict_rate

    def repair_tiers(self) -> dict:
        """The repair-tier distribution + persistent-fault/backoff state,
        JSON-ready — surfaced by serve() stats, StreamingEngine.stats(),
        and the BENCH payloads."""
        return {
            "slot": self.slot_retries,
            "stripe": self.stripe_retries,
            "graph": self.graph_retries,
            "restore": self.restores,
            "persistent_sites": sorted(self.persistent_sites),
            "persistent_escalations": self.persistent_escalations,
            "suspect": self.suspect,
            "backoff_level": self._backoff_level,
        }
