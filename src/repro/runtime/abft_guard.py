"""ABFT guard: closes the loop from error *detection* to *recovery*.

The paper detects faults; a 1000-node deployment must also act on them.
Policy (per train/serve step):

  1. run the step; the ABFTReport flag is a replicated scalar in the step
     outputs (one host read, no extra collective beyond the checksum psum);
  2. flag set  -> retry the step from the same inputs (bounded retries) —
     transient SDC almost never repeats on identical data;
  3. still flagged -> restore from the last checkpoint and replay — this is
     the persistent-fault path (bad chip), where the scheduler should also
     evict the offending host;
  4. track flag-rate statistics: a chip flagging above `evict_rate` is
     reported via `should_evict` for the cluster layer to act on.

Because the checked step is pure (params, batch) -> outputs, the retry is
exact replay; no optimizer state was committed for a flagged step (the guard
runs *before* state adoption).
"""
from __future__ import annotations

import dataclasses
import logging
from typing import Any, Callable, Optional, Tuple

log = logging.getLogger(__name__)


@dataclasses.dataclass
class GuardConfig:
    max_retries: int = 2
    evict_rate: float = 1e-3     # flags per step above which chip is suspect
    window: int = 1000


class ABFTGuard:
    def __init__(self, cfg: GuardConfig = GuardConfig(),
                 restore_fn: Optional[Callable[[], Any]] = None):
        self.cfg = cfg
        self.restore_fn = restore_fn
        self.steps = 0
        self.flags = 0
        self.retries = 0
        self.restores = 0

    def run_step(self, step_fn: Callable[..., Tuple[Any, Any]], *args):
        """step_fn returns (new_state, metrics) where metrics['abft_flag'] is
        the replicated detection scalar.  Returns the adopted (state, metrics).
        """
        self.steps += 1
        for attempt in range(self.cfg.max_retries + 1):
            out, metrics = step_fn(*args)
            flagged = bool(metrics["abft_flag"])
            if not flagged:
                if attempt:
                    log.warning("ABFT: retry %d succeeded", attempt)
                return out, metrics
            self.flags += 1
            self.retries += int(attempt < self.cfg.max_retries)
            log.error("ABFT flag on step %d (attempt %d): max_rel=%.3e",
                      self.steps, attempt, float(metrics.get("abft_max_rel", -1)))
        # persistent failure: roll back
        self.restores += 1
        if self.restore_fn is not None:
            log.error("ABFT: persistent fault; restoring from checkpoint")
            return self.restore_fn(), metrics
        raise RuntimeError("ABFT: persistent fault and no restore_fn given")

    @property
    def flag_rate(self) -> float:
        return self.flags / max(self.steps, 1)

    def should_evict(self) -> bool:
        return self.steps >= 100 and self.flag_rate > self.cfg.evict_rate
