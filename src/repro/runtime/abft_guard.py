"""ABFT guard: closes the loop from error *detection* to *recovery*.

The paper detects faults; a 1000-node deployment must also act on them.
Policy (per train/serve step):

  1. run the step; the ABFTReport flag is a replicated scalar in the step
     outputs (one host read, no extra collective beyond the checksum psum);
  2. flag set  -> retry the step from the same inputs (bounded retries) —
     transient SDC almost never repeats on identical data;
  3. still flagged -> restore from the last checkpoint and replay — this is
     the persistent-fault path (bad chip), where the scheduler should also
     evict the offending host;
  4. track flag-rate statistics: a chip flagging above `evict_rate` is
     reported via `should_evict` for the cluster layer to act on.

Because the checked step is pure (params, batch) -> outputs, the retry is
exact replay; no optimizer state was committed for a flagged step (the guard
runs *before* state adoption).
"""
from __future__ import annotations

import collections
import dataclasses
import logging
from typing import Any, Callable, Optional, Tuple

log = logging.getLogger(__name__)


@dataclasses.dataclass
class GuardConfig:
    max_retries: int = 2
    evict_rate: float = 1e-3     # flags per step above which chip is suspect
    window: int = 1000           # rolling window (steps) for should_evict
    min_samples: int = 100       # steps seen before eviction is judged


class ABFTGuard:
    def __init__(self, cfg: Optional[GuardConfig] = None,
                 restore_fn: Optional[Callable[[], Any]] = None):
        # cfg is constructed per guard — a dataclass default instance would
        # be one shared mutable object across every guard in the process.
        self.cfg = cfg if cfg is not None else GuardConfig()
        self.restore_fn = restore_fn
        self.steps = 0
        self.flags = 0           # lifetime count of flagged steps
        self.retries = 0
        self.restores = 0
        # per-step flagged? outcomes, newest last; drives the rolling rate —
        # a chip that degraded an hour in must look bad *now*, not diluted
        # by its clean history.
        self._recent: collections.deque = collections.deque(
            maxlen=max(self.cfg.window, 1))

    def run_step(self, step_fn: Callable[..., Tuple[Any, Any]], *args):
        """step_fn returns (new_state, metrics) where metrics['abft_flag'] is
        the replicated detection scalar.  Returns the adopted (state, metrics).
        """
        self.steps += 1
        step_flagged = False
        for attempt in range(self.cfg.max_retries + 1):
            out, metrics = step_fn(*args)
            flagged = bool(metrics["abft_flag"])
            if not flagged:
                if attempt:
                    log.warning("ABFT: retry %d succeeded", attempt)
                self._recent.append(step_flagged)
                return out, metrics
            if not step_flagged:
                step_flagged = True
                self.flags += 1
            self.retries += int(attempt < self.cfg.max_retries)
            log.error("ABFT flag on step %d (attempt %d): max_rel=%.3e",
                      self.steps, attempt, float(metrics.get("abft_max_rel", -1)))
        # persistent failure: roll back
        self._recent.append(True)
        self.restores += 1
        if self.restore_fn is not None:
            log.error("ABFT: persistent fault; restoring from checkpoint")
            return self.restore_fn(), metrics
        raise RuntimeError("ABFT: persistent fault and no restore_fn given")

    @property
    def flag_rate(self) -> float:
        """Flagged-step rate over the rolling window (recent behaviour)."""
        if not self._recent:
            return 0.0
        return sum(self._recent) / len(self._recent)

    @property
    def lifetime_flag_rate(self) -> float:
        return self.flags / max(self.steps, 1)

    def should_evict(self) -> bool:
        seen = len(self._recent)
        need = min(self.cfg.min_samples, self.cfg.window)
        return seen >= need and self.flag_rate > self.cfg.evict_rate
