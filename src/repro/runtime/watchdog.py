"""Straggler watchdog: EWMA step-time tracking with slow-host detection.

In a synchronous data-parallel job every step runs at the pace of the
slowest participant.  The watchdog keeps an exponentially-weighted moving
average and flags steps exceeding `threshold`× the EWMA — the hook the
cluster layer uses to (a) log the event, (b) trigger the elastic path
(checkpoint + reshard without the slow host) when flags persist.

The streaming engine wires one of these around its double-buffered
dispatch (start at dispatch, stop at adjudication): a batch whose
dispatch->verdict time balloons past the EWMA threshold is a straggler
event, and a persistent streak (``should_reshard``) is treated like
eviction advice — the engine degrades to its fallback backend instead of
letting a sick fused path stall the stream.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Callable, Optional


@dataclasses.dataclass
class StragglerWatchdog:
    threshold: float = 2.0
    alpha: float = 0.05
    warmup: int = 10
    # injectable time source (deterministic tests), like the guard's
    # injectable sleep_fn
    clock: Callable[[], float] = time.perf_counter

    ewma: float = 0.0
    n: int = 0
    slow_streak: int = 0
    events: int = 0
    _t0: Optional[float] = None
    _warm_total: float = 0.0

    def start(self):
        self._t0 = self.clock()

    def stop(self) -> bool:
        """Returns True when this step was a straggler event.

        A ``stop()`` with no interval open (never started, or already
        stopped) returns False without recording a step: the streaming
        engine calls stop defensively from resolution paths that may or
        may not own an open dispatch interval, and a phantom 0-duration
        sample would drag the EWMA toward zero and flag every real step.
        """
        if self._t0 is None:
            return False
        dt = self.clock() - self._t0
        self._t0 = None
        self.n += 1
        if self.n <= self.warmup:
            # true running mean over the warmup window — the previous
            # pairwise blend 0.5*(ewma+dt) weighted the latest warmup
            # step 2^-1, the one before 2^-2, ..., so one slow final
            # warmup step could poison the seed
            self._warm_total += dt
            self.ewma = self._warm_total / self.n
            return False
        slow = dt > self.threshold * self.ewma
        # slow steps do not pollute the EWMA
        if not slow:
            self.ewma = (1 - self.alpha) * self.ewma + self.alpha * dt
            self.slow_streak = 0
        else:
            self.events += 1
            self.slow_streak += 1
        return slow

    def should_reshard(self, streak: int = 5) -> bool:
        """Persistent slowness -> advise elastic reconfiguration."""
        return self.slow_streak >= streak
