"""Straggler watchdog: EWMA step-time tracking with slow-host detection.

In a synchronous data-parallel job every step runs at the pace of the
slowest participant.  The watchdog keeps an exponentially-weighted moving
average and flags steps exceeding `threshold`× the EWMA — the hook the
cluster layer uses to (a) log the event, (b) trigger the elastic path
(checkpoint + reshard without the slow host) when flags persist.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Optional


@dataclasses.dataclass
class StragglerWatchdog:
    threshold: float = 2.0
    alpha: float = 0.05
    warmup: int = 10

    ewma: float = 0.0
    n: int = 0
    slow_streak: int = 0
    events: int = 0
    _t0: Optional[float] = None

    def start(self):
        self._t0 = time.perf_counter()

    def stop(self) -> bool:
        """Returns True when this step was a straggler event."""
        dt = time.perf_counter() - self._t0
        self.n += 1
        if self.n <= self.warmup:
            self.ewma = dt if self.ewma == 0 else \
                0.5 * (self.ewma + dt)
            return False
        slow = dt > self.threshold * self.ewma
        # slow steps do not pollute the EWMA
        if not slow:
            self.ewma = (1 - self.alpha) * self.ewma + self.alpha * dt
            self.slow_streak = 0
        else:
            self.events += 1
            self.slow_streak += 1
        return slow

    def should_reshard(self, streak: int = 5) -> bool:
        """Persistent slowness -> advise elastic reconfiguration."""
        return self.slow_streak >= streak
