"""Pallas TPU kernel: ONE GCN-ABFT layer in a single HBM traversal.

``spmm_abft`` executes the aggregation half of a layer: XLA first computes
X = H @ W, writes it to HBM, and the kernel reads X tiles back.  GCN widths
are tiny (16–186 features, paper Table II), so W and the folded right
checksum w_r = W·e fit entirely in VMEM — which means the combination can
be recomputed on the fly *inside* the aggregation sweep and X never has to
touch HBM at all (the flash-attention fusion argument applied to the GCN
layer).  This kernel does exactly that:

  grid (row-stripe i, ell-slot j) — identical to spmm_abft; the
  column-block index table rides as a scalar-prefetch operand so each H
  tile's DMA address is known before the body runs.

  per step:  h    = H[cols[i,j]]                 (bk, f)  DMA'd tile
             x    = h @ W                        (bk, g)  MXU recompute
             x_r  = h @ w_r                      (bk, 1)  eq.-5 column
             acc += S_tile @ x;   ex += S_tile @ x_r

W and w_r use constant index maps, so Pallas DMAs them once and keeps them
resident across the whole grid.  The checksum epilogue is the same as
spmm_abft's: outputs (out, stripe_sums, extra) with the final O(nbm)
reduction left to ops.py.  Recomputing x per stored S tile trades cheap
MXU flops for halved HBM traffic — see ops.hbm_bytes_* for the model.

Check independence: x and x_r come from two *separate* dot products of the
same resident operands, so an MXU/accumulator fault in one side cannot
cancel against the other — the same coverage as the two-pass path.  (A
corrupted H tile DMA feeds both sides consistently and is invisible to
either path; input corruption is outside ABFT's model.)

``inject`` is the CI fault-injection hook: a static (stripe, slot, delta)
triple that perturbs one accumulator element mid-sweep, emulating a
compute-unit upset inside the fused layer.  The delta reaches the output
and the actual checksum but never the predicted side, so the eq.-6 corner
must flag it.
"""
from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _make_kernel(inject: Optional[Tuple[int, int, float]], with_check: bool):
    def _kernel(cols_ref, s_ref, h_ref, w_ref, wr_ref,
                out_ref, sums_ref, extra_ref, acc_ref, ex_ref):
        j = pl.program_id(1)
        nj = pl.num_programs(1)

        @pl.when(j == 0)
        def _init():
            acc_ref[...] = jnp.zeros_like(acc_ref)
            ex_ref[...] = jnp.zeros_like(ex_ref)

        s = s_ref[0, 0]
        h = h_ref[...]
        x = jnp.dot(h, w_ref[...], preferred_element_type=jnp.float32)
        acc_ref[...] += jnp.dot(s, x, preferred_element_type=jnp.float32)
        if with_check:
            # the eq.-5 column, from its own dot so an MXU fault in x
            # cannot cancel — statically elided when checking is off
            # (mode="none" pays zero extra flops over an unchecked sweep)
            xr = jnp.dot(h, wr_ref[...], preferred_element_type=jnp.float32)
            ex_ref[...] += jnp.dot(s, xr, preferred_element_type=jnp.float32)

        if inject is not None:
            ii, jj, delta = inject

            @pl.when((pl.program_id(0) == ii) & (j == jj))
            def _inject():
                acc_ref[0, 0] += jnp.float32(delta)

        @pl.when(j == nj - 1)
        def _epilogue():
            acc = acc_ref[...]
            out_ref[...] = acc.astype(out_ref.dtype)
            sums_ref[0, 0] = jnp.sum(acc)
            extra_ref[...] = ex_ref[...]

    return _kernel


@functools.partial(jax.jit,
                   static_argnames=("interpret", "inject", "with_check"))
def gcn_fused_kernel(block_cols: jax.Array, values: jax.Array, h: jax.Array,
                     w: jax.Array, wr: jax.Array, *, interpret: bool = False,
                     inject: Optional[Tuple[int, int, float]] = None,
                     with_check: bool = True):
    """block_cols: [nbm, width] i32; values: [nbm, width, bm, bk];
    h: [K, F]; w: [F, G]; wr: [F, 1].  K must be a bk multiple covering
    max(block_cols)+1 stripes; F and G lane-padded by the caller (ops.py).
    ``with_check=False`` (mode="none") statically elides the per-tile
    eq.-5 dots; the tiny extra output is then all-zero.
    Returns (out [nbm*bm, G], stripe_sums [nbm, 1], extra [nbm*bm, 1])."""
    nbm, width, bm, bk = values.shape
    k, f = h.shape
    fw, g = w.shape
    assert k % bk == 0 and fw == f and wr.shape == (f, 1)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(nbm, width),
        in_specs=[
            pl.BlockSpec((1, 1, bm, bk), lambda i, j, cols: (i, j, 0, 0)),
            pl.BlockSpec((bk, f), lambda i, j, cols: (cols[i, j], 0)),
            pl.BlockSpec((f, g), lambda i, j, cols: (0, 0)),
            pl.BlockSpec((f, 1), lambda i, j, cols: (0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((bm, g), lambda i, j, cols: (i, 0)),
            pl.BlockSpec((1, 1), lambda i, j, cols: (i, 0)),
            pl.BlockSpec((bm, 1), lambda i, j, cols: (i, 0)),
        ],
        scratch_shapes=[
            pltpu.VMEM((bm, g), jnp.float32),
            pltpu.VMEM((bm, 1), jnp.float32),
        ],
    )
    return pl.pallas_call(
        _make_kernel(inject, with_check),
        grid_spec=grid_spec,
        out_shape=[
            jax.ShapeDtypeStruct((nbm * bm, g), h.dtype),
            jax.ShapeDtypeStruct((nbm, 1), jnp.float32),
            jax.ShapeDtypeStruct((nbm * bm, 1), jnp.float32),
        ],
        interpret=interpret,
    )(block_cols, values, h, w, wr)
