"""Pallas TPU kernel: ONE GCN-ABFT layer in a single HBM traversal.

``spmm_abft`` executes the aggregation half of a layer: XLA first computes
X = H @ W, writes it to HBM, and the kernel reads X tiles back.  GCN widths
are tiny (16–186 features, paper Table II), so W and the folded right
checksum w_r = W·e fit entirely in VMEM — which means the combination can
be recomputed on the fly *inside* the aggregation sweep and X never has to
touch HBM at all (the flash-attention fusion argument applied to the GCN
layer).  This kernel does exactly that:

  grid (row-stripe i, ell-slot j) — identical to spmm_abft; the
  column-block index table rides as a scalar-prefetch operand so each H
  tile's DMA address is known before the body runs.

  per step:  h    = H[cols[i,j]]                 (bk, f)  DMA'd tile
             x    = h @ W                        (bk, g)  MXU recompute
             x_r  = h @ w_r                      (bk, 1)  eq.-5 column
             acc += S_tile @ x;   ex += S_tile @ x_r

W and w_r use constant index maps, so Pallas DMAs them once and keeps them
resident across the whole grid.  The checksum epilogue is the same as
spmm_abft's: outputs (out, stripe_sums, extra) with the final O(nbm)
reduction left to ops.py.  Recomputing x per stored S tile trades cheap
MXU flops for halved HBM traffic — see ops.hbm_bytes_* for the model.

Check independence: x and x_r come from two *separate* dot products of the
same resident operands, so an MXU/accumulator fault in one side cannot
cancel against the other — the same coverage as the two-pass path.  (A
corrupted H tile DMA feeds both sides consistently and is invisible to
either path; input corruption is outside ABFT's model.)

``inject`` is the CI fault-injection hook: a static (stripe, slot, delta)
triple that perturbs one accumulator element mid-sweep, emulating a
compute-unit upset inside the fused layer.  The delta reaches the output
and the actual checksum but never the predicted side, so the eq.-6 corner
must flag it.
"""
from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _make_kernel(inject: Optional[Tuple[int, int, float]], with_check: bool,
                 with_slots: bool):
    def _kernel(cols_ref, s_ref, h_ref, w_ref, wr_ref, out_ref, sums_ref,
                extra_ref, *rest):
        if with_slots:
            sacts_ref, spreds_ref, acc_ref, ex_ref = rest
        else:
            acc_ref, ex_ref = rest
        j = pl.program_id(1)
        nj = pl.num_programs(1)

        @pl.when(j == 0)
        def _init():
            acc_ref[...] = jnp.zeros_like(acc_ref)
            ex_ref[...] = jnp.zeros_like(ex_ref)

        s = s_ref[0, 0]
        h = h_ref[...]
        x = jnp.dot(h, w_ref[...], preferred_element_type=jnp.float32)
        acc_ref[...] += jnp.dot(s, x, preferred_element_type=jnp.float32)
        if with_check:
            # the eq.-5 column, from its own dot so an MXU fault in x
            # cannot cancel — statically elided when checking is off
            # (mode="none" pays zero extra flops over an unchecked sweep)
            xr = jnp.dot(h, wr_ref[...], preferred_element_type=jnp.float32)
            ex_ref[...] += jnp.dot(s, xr, preferred_element_type=jnp.float32)

        if inject is not None:
            ii, jj, delta = inject

            @pl.when((pl.program_id(0) == ii) & (j == jj))
            def _inject():
                acc_ref[0, 0] += jnp.float32(delta)

        if with_slots:
            # telescoped running sums, recorded AFTER the inject hook: slot
            # corner j is the adjacent difference sacts[j] - sacts[j-1], so
            # an accumulator fault between two recordings lands in exactly
            # one slot's corner while the final value stays Σ acc — per-slot
            # sums built from tile products alone would miss it
            sacts_ref[0, j] = jnp.sum(acc_ref[...])
            spreds_ref[0, j] = jnp.sum(ex_ref[...])

        @pl.when(j == nj - 1)
        def _epilogue():
            acc = acc_ref[...]
            out_ref[...] = acc.astype(out_ref.dtype)
            sums_ref[0, 0] = jnp.sum(acc)
            extra_ref[...] = ex_ref[...]

    return _kernel


@functools.partial(jax.jit,
                   static_argnames=("interpret", "inject", "with_check",
                                    "with_slots"))
def gcn_fused_kernel(block_cols: jax.Array, values: jax.Array, h: jax.Array,
                     w: jax.Array, wr: jax.Array, *, interpret: bool = False,
                     inject: Optional[Tuple[int, int, float]] = None,
                     with_check: bool = True, with_slots: bool = False):
    """block_cols: [nbm, width] i32; values: [nbm, width, bm, bk];
    h: [K, F]; w: [F, G]; wr: [F, 1].  K must be a bk multiple covering
    max(block_cols)+1 stripes; F and G lane-padded by the caller (ops.py).
    ``with_check=False`` (mode="none") statically elides the per-tile
    eq.-5 dots; the tiny extra output is then all-zero.
    Returns (out [nbm*bm, G], stripe_sums [nbm, 1], extra [nbm*bm, 1]);
    ``with_slots=True`` appends the telescoped per-slot running sums
    (slot_acts [nbm, width], slot_preds [nbm, width]) for slot-granular
    corners (``ops.slot_check_corners``)."""
    nbm, width, bm, bk = values.shape
    k, f = h.shape
    fw, g = w.shape
    assert k % bk == 0 and fw == f and wr.shape == (f, 1)

    out_specs = [
        pl.BlockSpec((bm, g), lambda i, j, cols: (i, 0)),
        pl.BlockSpec((1, 1), lambda i, j, cols: (i, 0)),
        pl.BlockSpec((bm, 1), lambda i, j, cols: (i, 0)),
    ]
    out_shape = [
        jax.ShapeDtypeStruct((nbm * bm, g), h.dtype),
        jax.ShapeDtypeStruct((nbm, 1), jnp.float32),
        jax.ShapeDtypeStruct((nbm * bm, 1), jnp.float32),
    ]
    if with_slots:
        out_specs += [pl.BlockSpec((1, width), lambda i, j, cols: (i, 0)),
                      pl.BlockSpec((1, width), lambda i, j, cols: (i, 0))]
        out_shape += [jax.ShapeDtypeStruct((nbm, width), jnp.float32),
                      jax.ShapeDtypeStruct((nbm, width), jnp.float32)]

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(nbm, width),
        in_specs=[
            pl.BlockSpec((1, 1, bm, bk), lambda i, j, cols: (i, j, 0, 0)),
            pl.BlockSpec((bk, f), lambda i, j, cols: (cols[i, j], 0)),
            pl.BlockSpec((f, g), lambda i, j, cols: (0, 0)),
            pl.BlockSpec((f, 1), lambda i, j, cols: (0, 0)),
        ],
        out_specs=out_specs,
        scratch_shapes=[
            pltpu.VMEM((bm, g), jnp.float32),
            pltpu.VMEM((bm, 1), jnp.float32),
        ],
    )
    return pl.pallas_call(
        _make_kernel(inject, with_check, with_slots),
        grid_spec=grid_spec,
        out_shape=out_shape,
        interpret=interpret,
    )(block_cols, values, h, w, wr)


# ---------------------------------------------------------------------------
# Whole-network kernel: an L-layer GCN in ONE HBM traversal.
# ---------------------------------------------------------------------------

def _make_network_kernel(n_layers: int, bm: int,
                         inject: Optional[Tuple[int, int, int, float]],
                         with_check: bool, stash_acts: bool):
    def _kernel(cols_ref, s_ref, h0_ref, w_ref, wr_ref, out_ref, tacts_ref,
                tpreds_ref, acts_ref, *rest):
        if n_layers > 1:
            acta_ref, actb_ref, acc_ref, ex_ref = rest
        else:
            acc_ref, ex_ref = rest
        ell = pl.program_id(0)
        i = pl.program_id(1)
        j = pl.program_id(2)
        nj = pl.num_programs(2)

        @pl.when(j == 0)
        def _init():
            acc_ref[...] = jnp.zeros_like(acc_ref)
            ex_ref[...] = jnp.zeros_like(ex_ref)

        s = s_ref[0, 0]
        w = w_ref[0]
        if n_layers > 1:
            # layer ell reads the resident activations the previous layer
            # wrote to buffer (ell-1) % 2; layer 0 streams H0 from HBM.
            # Both VMEM loads are issued and the right one selected —
            # cheaper than predicated control flow, and the unselected
            # buffer's (possibly uninitialized) values never propagate.
            c = cols_ref[i, j]
            ha = acta_ref[pl.ds(c * bm, bm), :]
            hb = actb_ref[pl.ds(c * bm, bm), :]
            h_res = jnp.where((ell % 2) == 1, ha, hb)
            h = jnp.where(ell == 0, h0_ref[...], h_res)
        else:
            h = h0_ref[...]
        x = jnp.dot(h, w, preferred_element_type=jnp.float32)
        acc_ref[...] += jnp.dot(s, x, preferred_element_type=jnp.float32)
        if with_check:
            xr = jnp.dot(h, wr_ref[0], preferred_element_type=jnp.float32)
            ex_ref[...] += jnp.dot(s, xr, preferred_element_type=jnp.float32)

        if inject is not None:
            il, ii, jj, delta = inject

            @pl.when((ell == il) & (i == ii) & (j == jj))
            def _inject():
                acc_ref[0, 0] += jnp.float32(delta)

        # telescoped per-slot running sums (see _make_kernel): the slot
        # corners certify each layer pre-activation, exactly as the
        # sequential per-layer sweep would
        tacts_ref[0, 0, j] = jnp.sum(acc_ref[...])
        tpreds_ref[0, 0, j] = jnp.sum(ex_ref[...])

        last = j == nj - 1

        @pl.when(last & (ell == n_layers - 1))
        def _write_out():
            out_ref[...] = acc_ref[...].astype(out_ref.dtype)

        if n_layers > 1:
            # ReLU in the epilogue, result kept VMEM-resident for the next
            # layer's combination (ping-pong: layer ell writes buffer
            # ell % 2).  All stripes of layer ell complete before layer
            # ell+1 starts (layer is the slowest grid axis), so the
            # write-while-read race cannot occur across the buffers.
            @pl.when(last & (ell < n_layers - 1) & (ell % 2 == 0))
            def _store_a():
                acta_ref[pl.ds(i * bm, bm), :] = \
                    jnp.maximum(acc_ref[...], 0.0)

            @pl.when(last & (ell < n_layers - 1) & (ell % 2 == 1))
            def _store_b():
                actb_ref[pl.ds(i * bm, bm), :] = \
                    jnp.maximum(acc_ref[...], 0.0)

        if stash_acts:
            # repairability stash: the post-ReLU activations also go to HBM
            # (one write per slab, never re-read by this sweep) so the
            # surgical tiers can recompute flagged stripes offline.  The
            # final layer's slab records relu(logits) — sliced off by ops.
            @pl.when(last)
            def _stash():
                acts_ref[0] = jnp.maximum(acc_ref[...], 0.0)

    return _kernel


@functools.partial(jax.jit,
                   static_argnames=("interpret", "inject", "with_check",
                                    "stash_acts"))
def gcn_network_kernel(block_cols: jax.Array, values: jax.Array,
                       h0: jax.Array, ws: jax.Array, wrs: jax.Array, *,
                       interpret: bool = False,
                       inject: Optional[Tuple[int, int, int, float]] = None,
                       with_check: bool = True, stash_acts: bool = False):
    """An L-layer GCN  H_{l+1} = relu(S (H_l W_l))  in one grid sweep.

    block_cols: [nbm, width] i32; values: [nbm, width, bm, bm] (square
    blocks — activations are indexed by the same table on both axes);
    h0: [K, P] with K == nbm*bm (every referenced column block is also an
    output stripe); ws: [L, P, P]; wrs: [L, P, 1].  P is ONE shared
    lane-padded width — the max over all layer widths, zero-padded, so the
    activation matrix ping-pongs between two fixed [K, P] VMEM buffers and
    NEVER touches HBM (zero columns stay zero through relu and through the
    zero-padded weight rows, so padding is exact at every depth).

    grid (layer, row-stripe, ell-slot), layer slowest: all stripes of
    layer l finish before layer l+1 reads them.  W_l / w_r,l are DMA'd once
    per layer (index map (l, 0, 0)) and resident across its stripes; the
    final logits are written once (out block index pins to 0 until the
    last layer).  ``inject=(layer, stripe, slot, delta)`` is the fault
    hook; ``stash_acts=True`` additionally writes each layer's post-ReLU
    slab to HBM for the surgical-repair tiers (the one-traversal byte
    model gains L slab writes but still never re-reads them).

    Returns (out [K, P], tele_acts [L, nbm, width],
    tele_preds [L, nbm, width], acts [L, K, P] | [1, bm, P] garbage when
    not stashing)."""
    nbm, width, bm, bk = values.shape
    k, p = h0.shape
    n_layers, pw, pw2 = ws.shape
    assert bm == bk, "network kernel needs square blocks"
    assert k == nbm * bm, "h0 rows must equal the padded stripe rows"
    assert pw == p and pw2 == p and wrs.shape == (n_layers, p, 1)
    nl = n_layers

    out_specs = [
        pl.BlockSpec((bm, p),
                     lambda l, i, j, cols: (jnp.where(l == nl - 1, i, 0), 0)),
        pl.BlockSpec((1, 1, width), lambda l, i, j, cols: (l, i, 0)),
        pl.BlockSpec((1, 1, width), lambda l, i, j, cols: (l, i, 0)),
    ]
    out_shape = [
        jax.ShapeDtypeStruct((k, p), h0.dtype),
        jax.ShapeDtypeStruct((nl, nbm, width), jnp.float32),
        jax.ShapeDtypeStruct((nl, nbm, width), jnp.float32),
    ]
    if stash_acts:
        out_specs.append(pl.BlockSpec((1, bm, p),
                                      lambda l, i, j, cols: (l, i, 0)))
        out_shape.append(jax.ShapeDtypeStruct((nl, k, p), jnp.float32))
    else:
        out_specs.append(pl.BlockSpec((1, bm, p),
                                      lambda l, i, j, cols: (0, 0, 0)))
        out_shape.append(jax.ShapeDtypeStruct((1, bm, p), jnp.float32))

    scratch = []
    if n_layers > 1:
        scratch += [pltpu.VMEM((k, p), jnp.float32),
                    pltpu.VMEM((k, p), jnp.float32)]
    scratch += [pltpu.VMEM((bm, p), jnp.float32),
                pltpu.VMEM((bm, 1), jnp.float32)]

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(n_layers, nbm, width),
        in_specs=[
            pl.BlockSpec((1, 1, bm, bk),
                         lambda l, i, j, cols: (i, j, 0, 0)),
            pl.BlockSpec((bk, p),
                         lambda l, i, j, cols:
                         (jnp.where(l == 0, cols[i, j], 0), 0)),
            pl.BlockSpec((1, p, p), lambda l, i, j, cols: (l, 0, 0)),
            pl.BlockSpec((1, p, 1), lambda l, i, j, cols: (l, 0, 0)),
        ],
        out_specs=out_specs,
        scratch_shapes=scratch,
    )
    return pl.pallas_call(
        _make_network_kernel(n_layers, bm, inject, with_check, stash_acts),
        grid_spec=grid_spec,
        out_shape=out_shape,
        interpret=interpret,
    )(block_cols, values, h0, ws, wrs)
