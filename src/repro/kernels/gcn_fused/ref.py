"""Numpy reference for the fused GCN-layer kernel (tests / interpret parity).

Computes the same three quantities the kernel emits, in f64, from the dense
reconstruction of the block-ELL operand — the ground truth the single-pass
sweep must reproduce within f32 accumulation tolerance.
"""
from __future__ import annotations

from typing import Optional, Tuple

import numpy as np

from repro.kernels.spmm_abft.layout import BlockEll


def gcn_fused_ref(bell: BlockEll, h: np.ndarray, w: np.ndarray,
                  w_r: Optional[np.ndarray] = None
                  ) -> Tuple[np.ndarray, float, float]:
    """(out [n, g], predicted, actual) in f64 for one layer S (H W).

    ``predicted`` is the eq.-4 corner s_c H w_r computed the offline way
    (column sums of S applied to H w_r); ``actual`` the total checksum of
    the output.  ``w_r`` defaults to the canonical fold W·e.
    """
    n = bell.shape[0]
    s = bell.todense().astype(np.float64)[:n, :n]
    h = np.asarray(h, np.float64)[:n]
    w = np.asarray(w, np.float64)
    w_r = w.sum(axis=1) if w_r is None else np.asarray(w_r, np.float64).ravel()
    out = s @ (h @ w)
    predicted = float(s.sum(axis=0) @ (h @ w_r))
    actual = float(out.sum())
    return out, predicted, actual
