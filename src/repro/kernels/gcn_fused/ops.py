"""Public wrappers for the fused GCN-layer kernel: operand padding, the
final checksum reduction, Check construction, the packed (block-diagonal)
per-graph variant, and the VMEM / HBM cost models that decide when fusion
is worthwhile.

CPU has no Pallas TPU backend: pass ``interpret=True`` (tests and the CPU
engine default do).
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core.abft import Check
from repro.kernels.spmm_abft.layout import BlockEll
from repro.kernels.spmm_abft.ops import (
    device_block_ell,
    fit_rows,
    packed_check_corners,
    stripe_check_corners,
    validate_packed_operands,
)

from .kernel import gcn_fused_kernel

Array = jax.Array

# Conservative per-core VMEM budget for the fused layer's resident + working
# set.  Real TPU cores have ~16 MB; half of it leaves the scheduler slack
# for double-buffered DMA and keeps the fallback decision robust across
# generations.
FUSED_VMEM_BUDGET = 8 * 1024 * 1024


def _pad_axis(a: Array, axis: int, multiple: int) -> Array:
    size = a.shape[axis]
    pad = -size % multiple
    if pad == 0:
        return a
    widths = [(0, 0)] * a.ndim
    widths[axis] = (0, pad)
    return jnp.pad(a, widths)


def _pad_weights(w: Array, wr: Optional[Array], block_g: int
                 ) -> Tuple[Array, Array]:
    """W [f, g] -> f32 [fp, gp] and wr (vector/column/None) -> f32 [fp, 1];
    ``wr=None`` (check disabled) becomes a zero column the specialized
    kernel never reads.  The ONE place the weight-operand contract lives —
    the single-graph and packed entry points both pad through here."""
    f = w.shape[0]
    wr = (jnp.zeros((f, 1), jnp.float32) if wr is None
          else wr.astype(jnp.float32).reshape(f, 1))
    wp = _pad_axis(_pad_axis(w.astype(jnp.float32), 0, block_g), 1, block_g)
    return wp, _pad_axis(wr, 0, block_g)


def prepare_fused_operands(bell: BlockEll, h: Array, w: Array,
                           wr: Optional[Array], block_g: int
                           ) -> Tuple[Array, Array, Array]:
    """The fused kernel's operand contract: H rows padded (or trimmed — see
    :func:`~repro.kernels.spmm_abft.ops.fit_rows`) to cover every referenced
    column stripe, both feature axes padded to ``block_g`` lane multiples,
    and ``wr`` defaulting to zeros (check disabled) in f32.

    Zero padding is exact end to end: padded H columns meet padded W rows
    (both zero), padded W/wr columns add zero output lanes that the caller
    trims, and padded H rows are never referenced by any stored tile.
    """
    k_pad = max(bell.padded_cols, bell.block_k)
    hp = _pad_axis(fit_rows(h, k_pad), 1, block_g)
    wp, wrp = _pad_weights(w, wr, block_g)
    return hp, wp, wrp


def gcn_fused_layer(bell: BlockEll, h: Array, w: Array,
                    w_r: Optional[Array] = None, *, block_g: int = 128,
                    interpret: bool = False,
                    granularity: str = "layer",
                    inject: Optional[Tuple[int, int, float]] = None,
                    _staged: Optional[Tuple[Array, Array]] = None
                    ) -> Tuple[Array, Optional[Check]]:
    """out = S (H W) with the single eq. 4–6 check, in ONE kernel sweep.

    ``w_r`` is the folded right checksum W·e ([g_in] vector or [g_in, 1]
    column; offline at weight-load time — ``engine.fold_w_r``).  ``None``
    disables checking (mode="none"): the kernel still runs single-pass and
    statically elides the eq.-5 dots.  Like the two-pass spmm_abft kernel
    path, checks accumulate in f32 regardless of ``ABFTConfig.dtype``
    (the TPU-production convention; pair with ``kahan`` off-kernel if f32
    noise floors matter).
    ``granularity="stripe"`` keeps the sweep's per-row-stripe partials as
    individual corners instead of one scalar (fault localization).
    ``_staged`` lets a long-lived caller reuse already-staged
    (block_cols, values) device arrays.
    Returns (out [n, g], Check(predicted=Σ S H w_r, actual=Σ out) | None).
    """
    n, _ = bell.shape
    g = w.shape[1]
    cols, vals = _staged if _staged is not None else device_block_ell(bell)
    want_check = w_r is not None
    hp, wp, wrp = prepare_fused_operands(bell, h, w, w_r, block_g)
    out, stripe_sums, extra = gcn_fused_kernel(cols, vals, hp, wp, wrp,
                                               interpret=interpret,
                                               inject=inject,
                                               with_check=want_check)
    out = out[:n, :g]
    if not want_check:
        return out, None
    if granularity == "stripe":
        return out, stripe_check_corners(stripe_sums, extra)
    return out, Check(predicted=extra[:n, 0].sum(),
                      actual=stripe_sums.sum())


def gcn_fused_packed(cols: Array, vals: Array, h: Array, w: Array,
                     w_r: Optional[Array], segments: Array, *,
                     num_segments: int, block_g: int = 128,
                     interpret: bool = False, granularity: str = "graph",
                     inject: Optional[Tuple[int, int, float]] = None
                     ) -> Tuple[Array, Optional[Check]]:
    """Fused layer over a block-diagonal packed batch with *per-graph*
    eq.-6 corners — the single-pass analogue of ``spmm_abft_packed``.

    The kernel's per-stripe checksum partials segment-sum into one corner
    per packed graph exactly as in the two-pass path (the checksum is
    linear and each graph owns whole contiguous stripes), so a fault inside
    the fused sweep flags only the graph whose stripes it landed in.
    ``granularity="stripe"`` keeps the partials un-segmented (one corner
    per row-stripe) so the fault names the exact stripe.
    Everything is shape-static: jits with cols/vals/segments traced.
    """
    validate_packed_operands(vals, h.shape[0], "h")
    g = w.shape[1]
    want_check = w_r is not None
    hp = _pad_axis(h, 1, block_g)
    wp, wrp = _pad_weights(w, w_r, block_g)
    out, stripe_sums, extra = gcn_fused_kernel(cols, vals, hp, wp, wrp,
                                               interpret=interpret,
                                               inject=inject,
                                               with_check=want_check)
    out = out[:, :g]
    if not want_check:
        return out, None
    if granularity == "stripe":
        return out, stripe_check_corners(stripe_sums, extra)
    return out, packed_check_corners(stripe_sums, extra, segments,
                                     num_segments)


# ---------------------------------------------------------------------------
# Cost models: when is fusing the right call?
# ---------------------------------------------------------------------------

def _lanes(n: int, block_g: int) -> int:
    return -(-n // block_g) * block_g


def fused_vmem_bytes(f: int, g: int, bm: int, bk: int, *,
                     block_g: int = 128, itemsize: int = 4) -> int:
    """Model of the fused kernel's peak VMEM working set in bytes.

    Resident across the grid: W [fp, gp] and w_r [fp, 1].  Per step,
    double-buffered by the pipeline: the S tile [bm, bk] and the H tile
    [bk, fp].  Plus the output block [bm, gp], the f32 accumulator scratch
    [bm, gp], the extra-column scratch, and the recomputed x tile [bk, gp].
    """
    fp, gp = _lanes(f, block_g), _lanes(g, block_g)
    resident = fp * gp + fp
    streamed = 2 * (bm * bk + bk * fp)
    working = 2 * bm * gp + bk * gp + bm * gp + 2 * bm
    return itemsize * (resident + streamed + working)


def fused_layer_fits(f: int, g: int, bm: int, bk: int, *,
                     block_g: int = 128,
                     budget: int = FUSED_VMEM_BUDGET) -> bool:
    """True when the fused layer's working set fits the VMEM budget — the
    engine falls back to the two-pass kernel otherwise (W too wide to stay
    resident)."""
    return fused_vmem_bytes(f, g, bm, bk, block_g=block_g) <= budget


def hbm_bytes_twopass(bell: BlockEll, f: int, g: int, *,
                      block_g: int = 128, itemsize: int = 4) -> int:
    """Modeled HBM bytes of one two-pass layer: the XLA combination pass
    (read H and W, write X, plus the independent eq.-5 column H·w_r) then
    the spmm_abft kernel pass (read S tiles + index table, read one X tile
    and one x_r tile per stored slot, write out / sums / extra).

    The tile count is the padded nbm × width table — ELL padding slots are
    scheduled like real tiles in both paths, so the comparison is fair.
    """
    gp = _lanes(g, block_g)
    nbm, width = bell.n_block_rows, bell.width
    bm, bk = bell.block_m, bell.block_k
    tiles = nbm * width
    k_pad = max(bell.padded_cols, bell.block_k)
    n = bell.shape[0]
    combine = n * f + f * g + k_pad * gp            # read H, W; write X
    eq5 = n * f + f + k_pad                         # read H, w_r; write x_r
    aggregate = (tiles * (bm * bk + bk * gp + bk)   # S, X, x_r tiles
                 + nbm * width                      # i32 index table ~ 1 word
                 + nbm * bm * gp + nbm + nbm * bm)  # out, sums, extra
    return itemsize * (combine + eq5 + aggregate)


def hbm_bytes_fused(bell: BlockEll, f: int, g: int, *,
                    block_g: int = 128, itemsize: int = 4) -> int:
    """Modeled HBM bytes of one fused layer: a single kernel pass — read S
    tiles + index table, read one H tile per stored slot, read W and w_r
    once (resident thereafter), write out / sums / extra.  X never exists
    in HBM; H is read through the same tile schedule X was before."""
    fp, gp = _lanes(f, block_g), _lanes(g, block_g)
    nbm, width = bell.n_block_rows, bell.width
    bm, bk = bell.block_m, bell.block_k
    tiles = nbm * width
    return itemsize * (tiles * (bm * bk + bk * fp)  # S, H tiles
                       + nbm * width                # index table
                       + fp * gp + fp               # W, w_r (once)
                       + nbm * bm * gp + nbm + nbm * bm)


def gcn_fused_auto(bell: BlockEll, h: Array, w: Array,
                   w_r: Optional[Array] = None, *, block_g: int = 128
                   ) -> Tuple[Array, Optional[Check]]:
    """Same as :func:`gcn_fused_layer`, interpret-mode off-TPU."""
    on_tpu = jax.default_backend() == "tpu"
    return gcn_fused_layer(bell, h, w, w_r, block_g=block_g,
                           interpret=not on_tpu)
