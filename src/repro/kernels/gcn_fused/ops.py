"""Public wrappers for the fused GCN-layer kernel: operand padding, the
final checksum reduction, Check construction, the packed (block-diagonal)
per-graph variant, and the VMEM / HBM cost models that decide when fusion
is worthwhile.

CPU has no Pallas TPU backend: pass ``interpret=True`` (tests and the CPU
engine default do).
"""
from __future__ import annotations

from typing import List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp

from repro.analysis.vmem import (  # noqa: F401  (re-exported: the runtime
    FUSED_VMEM_BUDGET,             # fallback predicates and the abftlint
    _lanes,                        # static checker are the SAME objects —
    fused_layer_fits,              # see repro/analysis/vmem.py)
    fused_network_fits,
    fused_vmem_bytes,
    network_vmem_bytes,
)
from repro.core.abft import Check
from repro.kernels.spmm_abft.layout import BlockEll
from repro.kernels.spmm_abft.ops import (
    device_block_ell,
    fit_rows,
    packed_check_corners,
    stripe_check_corners,
    validate_packed_operands,
)

from .kernel import gcn_fused_kernel, gcn_network_kernel

Array = jax.Array


def _pad_axis(a: Array, axis: int, multiple: int) -> Array:
    size = a.shape[axis]
    pad = -size % multiple
    if pad == 0:
        return a
    widths = [(0, 0)] * a.ndim
    widths[axis] = (0, pad)
    return jnp.pad(a, widths)


def _pad_weights(w: Array, wr: Optional[Array], block_g: int
                 ) -> Tuple[Array, Array]:
    """W [f, g] -> f32 [fp, gp] and wr (vector/column/None) -> f32 [fp, 1];
    ``wr=None`` (check disabled) becomes a zero column the specialized
    kernel never reads.  The ONE place the weight-operand contract lives —
    the single-graph and packed entry points both pad through here."""
    f = w.shape[0]
    wr = (jnp.zeros((f, 1), jnp.float32) if wr is None
          else wr.astype(jnp.float32).reshape(f, 1))
    wp = _pad_axis(_pad_axis(w.astype(jnp.float32), 0, block_g), 1, block_g)
    return wp, _pad_axis(wr, 0, block_g)


def prepare_fused_operands(bell: BlockEll, h: Array, w: Array,
                           wr: Optional[Array], block_g: int
                           ) -> Tuple[Array, Array, Array]:
    """The fused kernel's operand contract: H rows padded (or trimmed — see
    :func:`~repro.kernels.spmm_abft.ops.fit_rows`) to cover every referenced
    column stripe, both feature axes padded to ``block_g`` lane multiples,
    and ``wr`` defaulting to zeros (check disabled) in f32.

    Zero padding is exact end to end: padded H columns meet padded W rows
    (both zero), padded W/wr columns add zero output lanes that the caller
    trims, and padded H rows are never referenced by any stored tile.
    """
    k_pad = max(bell.padded_cols, bell.block_k)
    hp = _pad_axis(fit_rows(h, k_pad), 1, block_g)
    wp, wrp = _pad_weights(w, wr, block_g)
    return hp, wp, wrp


def slot_check_corners(slot_acts: Array, slot_preds: Array) -> Check:
    """Telescoped per-slot running sums -> one eq.-6 corner PER (stripe,
    ell-slot) grid step — the finest granularity the sweep itself has.

    The kernel records Σ acc and Σ ex after every slot; the slot corner is
    the adjacent difference along the slot axis.  Telescoping is what makes
    detection exact: an accumulator upset between two recordings shifts
    every later running sum by the same delta, so exactly one difference
    diverges — per-slot sums rebuilt from tile products would miss faults
    that corrupt the accumulator itself.  On a clean run each difference is
    bounded by twice the stripe-level f32 noise (both running sums are
    valid partial-sweep eq.-6 comparisons by linearity)."""
    zeros = jnp.zeros((slot_acts.shape[0], 1), slot_acts.dtype)
    return Check(predicted=jnp.diff(slot_preds, axis=1, prepend=zeros),
                 actual=jnp.diff(slot_acts, axis=1, prepend=zeros),
                 granularity="slot")


def gcn_fused_layer(bell: BlockEll, h: Array, w: Array,
                    w_r: Optional[Array] = None, *, block_g: int = 128,
                    interpret: bool = False,
                    granularity: str = "layer",
                    inject: Optional[Tuple[int, int, float]] = None,
                    _staged: Optional[Tuple[Array, Array]] = None
                    ) -> Tuple[Array, Optional[Check]]:
    """out = S (H W) with the single eq. 4–6 check, in ONE kernel sweep.

    ``w_r`` is the folded right checksum W·e ([g_in] vector or [g_in, 1]
    column; offline at weight-load time — ``engine.fold_w_r``).  ``None``
    disables checking (mode="none"): the kernel still runs single-pass and
    statically elides the eq.-5 dots.  Like the two-pass spmm_abft kernel
    path, checks accumulate in f32 regardless of ``ABFTConfig.dtype``
    (the TPU-production convention; pair with ``kahan`` off-kernel if f32
    noise floors matter).
    ``granularity="stripe"`` keeps the sweep's per-row-stripe partials as
    individual corners instead of one scalar (fault localization).
    ``_staged`` lets a long-lived caller reuse already-staged
    (block_cols, values) device arrays.
    Returns (out [n, g], Check(predicted=Σ S H w_r, actual=Σ out) | None).
    """
    n, _ = bell.shape
    g = w.shape[1]
    cols, vals = _staged if _staged is not None else device_block_ell(bell)
    want_check = w_r is not None
    with_slots = want_check and granularity == "slot"
    hp, wp, wrp = prepare_fused_operands(bell, h, w, w_r, block_g)
    res = gcn_fused_kernel(cols, vals, hp, wp, wrp, interpret=interpret,
                           inject=inject, with_check=want_check,
                           with_slots=with_slots)
    out, stripe_sums, extra = res[:3]
    out = out[:n, :g]
    if not want_check:
        return out, None
    if with_slots:
        return out, slot_check_corners(res[3], res[4])
    if granularity == "stripe":
        return out, stripe_check_corners(stripe_sums, extra)
    return out, Check(predicted=extra[:n, 0].sum(),
                      actual=stripe_sums.sum())


def gcn_fused_packed(cols: Array, vals: Array, h: Array, w: Array,
                     w_r: Optional[Array], segments: Array, *,
                     num_segments: int, block_g: int = 128,
                     interpret: bool = False, granularity: str = "graph",
                     inject: Optional[Tuple[int, int, float]] = None
                     ) -> Tuple[Array, Optional[Check]]:
    """Fused layer over a block-diagonal packed batch with *per-graph*
    eq.-6 corners — the single-pass analogue of ``spmm_abft_packed``.

    The kernel's per-stripe checksum partials segment-sum into one corner
    per packed graph exactly as in the two-pass path (the checksum is
    linear and each graph owns whole contiguous stripes), so a fault inside
    the fused sweep flags only the graph whose stripes it landed in.
    ``granularity="stripe"`` keeps the partials un-segmented (one corner
    per row-stripe) so the fault names the exact stripe.
    Everything is shape-static: jits with cols/vals/segments traced.
    """
    validate_packed_operands(vals, h.shape[0], "h")
    g = w.shape[1]
    want_check = w_r is not None
    with_slots = want_check and granularity == "slot"
    hp = _pad_axis(h, 1, block_g)
    wp, wrp = _pad_weights(w, w_r, block_g)
    res = gcn_fused_kernel(cols, vals, hp, wp, wrp, interpret=interpret,
                           inject=inject, with_check=want_check,
                           with_slots=with_slots)
    out, stripe_sums, extra = res[:3]
    out = out[:, :g]
    if not want_check:
        return out, None
    if with_slots:
        return out, slot_check_corners(res[3], res[4])
    if granularity == "stripe":
        return out, stripe_check_corners(stripe_sums, extra)
    return out, packed_check_corners(stripe_sums, extra, segments,
                                     num_segments)


# ---------------------------------------------------------------------------
# Whole-network fusion: L layers in ONE HBM traversal.
# ---------------------------------------------------------------------------

def _network_weight_stacks(ws: Sequence[Array],
                           wrs: Sequence[Optional[Array]], block_g: int
                           ) -> Tuple[Array, Array, int, List[int]]:
    """Pad every layer's W / w_r to ONE shared lane-rounded width P (the max
    over all layer widths) and stack to [L, P, P] / [L, P, 1].  One shared P
    is what lets the activation matrix live in two fixed VMEM buffers
    across the whole depth; the zero padding is exact at every layer
    (zero activation columns meet zero weight rows, and relu(0) = 0 keeps
    the invariant inductive)."""
    dims = [int(ws[0].shape[0])] + [int(w.shape[1]) for w in ws]
    p = _lanes(max(dims), block_g)
    wstack, wrstack = [], []
    for w, wr in zip(ws, wrs):
        f, g = w.shape
        wr_col = (jnp.zeros((f, 1), jnp.float32) if wr is None
                  else wr.astype(jnp.float32).reshape(f, 1))
        wstack.append(jnp.pad(w.astype(jnp.float32),
                              [(0, p - f), (0, p - g)]))
        wrstack.append(jnp.pad(wr_col, [(0, p - f), (0, 0)]))
    return jnp.stack(wstack), jnp.stack(wrstack), p, dims


def _network_checks(tele_acts: Array, tele_preds: Array, granularity: str,
                    segments: Optional[Array], num_segments: Optional[int]
                    ) -> List[Check]:
    """Per-layer Checks from the network kernel's telescoped running sums
    [L, nbm, width].  The final telescope value of a stripe IS its stripe
    corner (the same Σ acc / Σ ex the single-layer sweep emits), so every
    granularity reduces from the telescopes exactly as it would from a
    sequential per-layer run."""
    checks: List[Check] = []
    for ell in range(tele_acts.shape[0]):
        ta, tp = tele_acts[ell], tele_preds[ell]
        if granularity == "slot":
            checks.append(slot_check_corners(ta, tp))
        elif granularity == "stripe":
            checks.append(Check(predicted=tp[:, -1], actual=ta[:, -1],
                                granularity="stripe"))
        elif granularity == "graph":
            pred = jax.ops.segment_sum(tp[:, -1], segments,
                                       num_segments=num_segments + 1,
                                       indices_are_sorted=True
                                       )[:num_segments]
            actual = jax.ops.segment_sum(ta[:, -1], segments,
                                         num_segments=num_segments + 1,
                                         indices_are_sorted=True
                                         )[:num_segments]
            checks.append(Check(predicted=pred, actual=actual,
                                granularity="graph"))
        else:
            checks.append(Check(predicted=tp[:, -1].sum(),
                                actual=ta[:, -1].sum()))
    return checks


def gcn_network_packed(cols: Array, vals: Array, h0: Array,
                       ws: Sequence[Array], wrs: Sequence[Optional[Array]],
                       segments: Optional[Array], *,
                       num_segments: Optional[int] = None,
                       block_g: int = 128, interpret: bool = False,
                       granularity: str = "graph",
                       inject: Optional[Tuple[int, int, int, float]] = None,
                       stash_acts: bool = False
                       ) -> Tuple[Array, List[Optional[Check]],
                                  Optional[Tuple[Array, ...]]]:
    """An L-layer GCN over a block-diagonal packed batch in ONE kernel
    sweep: relu + the next layer's combination fold into the aggregation
    epilogue, the activation matrix ping-pongs between two VMEM buffers,
    and the eq.-5 column is carried across every layer boundary — one check
    per layer, taken pre-activation, exactly as the sequential path.

    ``wrs`` entries are the folded per-layer W·e (all present, or all
    ``None`` to disable checking).  ``inject=(layer, stripe, slot, delta)``
    is the accumulator fault hook.  ``stash_acts=True`` additionally writes
    each layer's post-ReLU slab to HBM and returns the per-layer inputs
    ``h_layers`` (h0, relu(out_0), …) for the surgical-repair tiers.
    Returns (out [rows, g_last], [Check | None] per layer,
    h_layers | None).
    """
    validate_packed_operands(vals, h0.shape[0], "h0")
    n_layers = len(ws)
    want_check = wrs[0] is not None
    wstack, wrstack, p, dims = _network_weight_stacks(ws, wrs, block_g)
    hp = _pad_axis(h0.astype(jnp.float32), 1, p)
    res = gcn_network_kernel(cols, vals, hp, wstack, wrstack,
                             interpret=interpret, inject=inject,
                             with_check=want_check, stash_acts=stash_acts)
    out, tele_acts, tele_preds, acts = res
    out = out[:, :dims[-1]]
    if want_check:
        checks = _network_checks(tele_acts, tele_preds, granularity,
                                 segments, num_segments)
    else:
        checks = [None] * n_layers
    h_layers = None
    if stash_acts:
        h_layers = (h0,) + tuple(acts[ell][:, :dims[ell + 1]]
                                 for ell in range(n_layers - 1))
    return out, checks, h_layers


def gcn_network_layer(bell: BlockEll, h: Array, ws: Sequence[Array],
                      wrs: Sequence[Optional[Array]], *, block_g: int = 128,
                      interpret: bool = False, granularity: str = "layer",
                      inject: Optional[Tuple[int, int, int, float]] = None,
                      stash_acts: bool = False
                      ) -> Tuple[Array, List[Optional[Check]],
                                 Optional[Tuple[Array, ...]]]:
    """Single-graph whole-network fusion (see :func:`gcn_network_packed`).

    Requires square blocks; H is padded to the full nbm*block_m stripe rows
    (the activation buffer must cover every output stripe AND every
    referenced column block — a square adjacency always satisfies this).
    Returns (out [n, g_last], [Check | None] per layer, h_layers | None);
    stashed h_layers keep the padded stripe rows (the repair path indexes
    them by stripe)."""
    if bell.block_m != bell.block_k:
        raise ValueError("whole-network fusion needs square blocks; got "
                         f"block_m={bell.block_m}, block_k={bell.block_k}")
    if granularity == "graph":
        raise ValueError("granularity='graph' needs a packed batch "
                         "(gcn_network_packed with segments)")
    n, _ = bell.shape
    rows = bell.n_block_rows * bell.block_m
    assert bell.padded_cols <= rows
    cols, vals = device_block_ell(bell)
    n_layers = len(ws)
    want_check = wrs[0] is not None
    wstack, wrstack, p, dims = _network_weight_stacks(ws, wrs, block_g)
    hp = _pad_axis(fit_rows(h.astype(jnp.float32), rows), 1, p)
    res = gcn_network_kernel(cols, vals, hp, wstack, wrstack,
                             interpret=interpret, inject=inject,
                             with_check=want_check, stash_acts=stash_acts)
    out, tele_acts, tele_preds, acts = res
    out = out[:n, :dims[-1]]
    if want_check:
        checks = _network_checks(tele_acts, tele_preds, granularity,
                                 None, None)
    else:
        checks = [None] * n_layers
    h_layers = None
    if stash_acts:
        h_layers = (fit_rows(h, rows),) + \
            tuple(acts[ell][:, :dims[ell + 1]] for ell in range(n_layers - 1))
    return out, checks, h_layers


# ---------------------------------------------------------------------------
# Cost models: when is fusing the right call?  The VMEM working-set models
# (fused_vmem_bytes / network_vmem_bytes and their *_fits predicates) live
# in repro.analysis.vmem — imported above — so the static lint and this
# runtime fallback share one model.  The HBM traffic models stay here:
# they price a BlockEll layout, which the analysis layer doesn't know.
# ---------------------------------------------------------------------------

def hbm_bytes_twopass(bell: BlockEll, f: int, g: int, *,
                      block_g: int = 128, itemsize: int = 4) -> int:
    """Modeled HBM bytes of one two-pass layer: the XLA combination pass
    (read H and W, write X, plus the independent eq.-5 column H·w_r) then
    the spmm_abft kernel pass (read S tiles + index table, read one X tile
    and one x_r tile per stored slot, write out / sums / extra).

    The tile count is the padded nbm × width table — ELL padding slots are
    scheduled like real tiles in both paths, so the comparison is fair.
    """
    gp = _lanes(g, block_g)
    nbm, width = bell.n_block_rows, bell.width
    bm, bk = bell.block_m, bell.block_k
    tiles = nbm * width
    k_pad = max(bell.padded_cols, bell.block_k)
    n = bell.shape[0]
    combine = n * f + f * g + k_pad * gp            # read H, W; write X
    eq5 = n * f + f + k_pad                         # read H, w_r; write x_r
    aggregate = (tiles * (bm * bk + bk * gp + bk)   # S, X, x_r tiles
                 + nbm * width                      # i32 index table ~ 1 word
                 + nbm * bm * gp + nbm + nbm * bm)  # out, sums, extra
    return itemsize * (combine + eq5 + aggregate)


def hbm_bytes_fused(bell: BlockEll, f: int, g: int, *,
                    block_g: int = 128, itemsize: int = 4) -> int:
    """Modeled HBM bytes of one fused layer: a single kernel pass — read S
    tiles + index table, read one H tile per stored slot, read W and w_r
    once (resident thereafter), write out / sums / extra.  X never exists
    in HBM; H is read through the same tile schedule X was before."""
    fp, gp = _lanes(f, block_g), _lanes(g, block_g)
    nbm, width = bell.n_block_rows, bell.width
    bm, bk = bell.block_m, bell.block_k
    tiles = nbm * width
    return itemsize * (tiles * (bm * bk + bk * fp)  # S, H tiles
                       + nbm * width                # index table
                       + fp * gp + fp               # W, w_r (once)
                       + nbm * bm * gp + nbm + nbm * bm)


def hbm_bytes_network(bell: BlockEll, dims: Sequence[int], *,
                      block_g: int = 128, stash_acts: bool = False,
                      itemsize: int = 4) -> int:
    """Modeled HBM bytes of the whole-network kernel: S tiles + the index
    table are re-read once per layer (same as running the per-layer fused
    kernel L times), but the H tiles stream from HBM only at layer 0, each
    W/w_r slab is read once, and only the final logits are written —
    every intermediate activation stays in VMEM.  ``stash_acts`` adds one
    [rows, P] slab write per layer (repairability export, never re-read),
    which still strictly undercuts per-layer fusion's write-then-re-read
    of the same activations through the tile schedule.

    All widths pay the shared lane-padded P = max over layer dims — the
    price of fixed activation buffers; compare against
    ``sum(hbm_bytes_fused(bell, f_l, g_l))`` which pads per layer.
    """
    p = _lanes(max(dims), block_g)
    nbm, width = bell.n_block_rows, bell.width
    bm = bell.block_m
    n_layers = len(dims) - 1
    tiles = nbm * width
    rows = nbm * bm
    traffic = (n_layers * tiles * bm * bell.block_k  # S tiles, per layer
               + nbm * width                         # index table (once)
               + tiles * bell.block_k * p            # H0 tiles (layer 0)
               + n_layers * (p * p + p)              # W / w_r stack
               + rows * p                            # final logits, once
               + 2 * n_layers * nbm * width)         # slot telescopes
    if stash_acts:
        traffic += n_layers * rows * p
    return itemsize * traffic


def gcn_fused_auto(bell: BlockEll, h: Array, w: Array,
                   w_r: Optional[Array] = None, *, block_g: int = 128
                   ) -> Tuple[Array, Optional[Check]]:
    """Same as :func:`gcn_fused_layer`, interpret mode resolved by
    :func:`repro.kernels.runtime.resolve_interpret`."""
    from repro.kernels.runtime import resolve_interpret
    return gcn_fused_layer(bell, h, w, w_r, block_g=block_g,
                           interpret=resolve_interpret())
