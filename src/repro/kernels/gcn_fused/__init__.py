"""Single-pass fused GCN-ABFT layer kernel (combination + aggregation +
checksum in one HBM traversal) and the whole-network variant that carries
relu + the next layer's combination across layer boundaries in VMEM (see
kernel.py for the dataflow)."""
from .kernel import gcn_fused_kernel, gcn_network_kernel  # noqa: F401
from .ops import (  # noqa: F401
    FUSED_VMEM_BUDGET,
    fused_layer_fits,
    fused_network_fits,
    fused_vmem_bytes,
    gcn_fused_auto,
    gcn_fused_layer,
    gcn_fused_packed,
    gcn_network_layer,
    gcn_network_packed,
    hbm_bytes_fused,
    hbm_bytes_network,
    hbm_bytes_twopass,
    network_vmem_bytes,
    prepare_fused_operands,
    slot_check_corners,
)
from .ref import gcn_fused_ref  # noqa: F401
