"""Single-pass fused GCN-ABFT layer kernel: combination + aggregation +
checksum in one HBM traversal (see kernel.py for the dataflow)."""
from .kernel import gcn_fused_kernel  # noqa: F401
from .ops import (  # noqa: F401
    FUSED_VMEM_BUDGET,
    fused_layer_fits,
    fused_vmem_bytes,
    gcn_fused_auto,
    gcn_fused_layer,
    gcn_fused_packed,
    hbm_bytes_fused,
    hbm_bytes_twopass,
    prepare_fused_operands,
)
from .ref import gcn_fused_ref  # noqa: F401
