"""Pure-jnp oracle for the matmul_abft kernel."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def matmul_abft_ref(a: jax.Array, b: jax.Array, br: jax.Array):
    """Returns (c, actual_checksum_scalar, extra[M,1]) in f32 accumulation."""
    c = jnp.dot(a, b, preferred_element_type=jnp.float32)
    actual = c.sum()
    extra = jnp.dot(a, br, preferred_element_type=jnp.float32)
    return c.astype(a.dtype), actual, extra.astype(jnp.float32)
