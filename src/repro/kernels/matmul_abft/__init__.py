from .ops import matmul_abft  # noqa: F401
