"""jit'd public wrapper for the matmul_abft Pallas kernel: padding to block
multiples, final block-sum reduction, Check construction."""
from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core.abft import ABFTConfig, Check
from repro.core.checksum import col_checksum

from .kernel import matmul_abft_kernel


def _pad_to(x: jax.Array, mult: int, axis: int) -> jax.Array:
    r = x.shape[axis] % mult
    if r == 0:
        return x
    pad = [(0, 0)] * x.ndim
    pad[axis] = (0, mult - r)
    return jnp.pad(x, pad)


@functools.partial(jax.jit, static_argnames=("block_m", "block_n", "block_k",
                                             "interpret"))
def matmul_abft(a: jax.Array, b: jax.Array, br: Optional[jax.Array] = None, *,
                block_m: int = 128, block_n: int = 128, block_k: int = 128,
                interpret: bool = False) -> Tuple[jax.Array, Check]:
    """C = A @ B with the fused ABFT check computed in the same pass.

    ``br`` is the offline right-checksum column B·e; recomputed here when not
    supplied (weights: fold it at load time).  Returns (C, Check) where
    Check.predicted = (eᵀA)·(B e) and Check.actual = Σ C — both produced by
    the kernel epilogue, not a second HBM pass.
    """
    m, k = a.shape
    _, n = b.shape
    if br is None:
        br = b.astype(jnp.float32).sum(axis=1, keepdims=True)
    ap = _pad_to(_pad_to(a, block_m, 0), block_k, 1)
    bp = _pad_to(_pad_to(b, block_k, 0), block_n, 1)
    brp = _pad_to(br, block_k, 0)
    c, block_sums, extra = matmul_abft_kernel(
        ap, bp, brp, block_m=block_m, block_n=block_n, block_k=block_k,
        interpret=interpret)
    c = c[:m, :n]
    actual = block_sums.sum()                       # O(#blocks) reduce
    predicted = extra[:m, 0].sum()                  # Σ (A b_r) = eᵀA B e
    return c, Check(predicted=predicted, actual=actual)
