"""jit'd public wrapper for the matmul_abft Pallas kernel: padding to block
multiples, final block-sum reduction, Check construction — plus the
:class:`MatmulAbftOp` CheckedOp conforming to the engine protocol."""
from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core.abft import ABFTConfig, Check, CheckedOp, resolve_w_r

from .kernel import matmul_abft_kernel


def _pad_to(x: jax.Array, mult: int, axis: int) -> jax.Array:
    r = x.shape[axis] % mult
    if r == 0:
        return x
    pad = [(0, 0)] * x.ndim
    pad[axis] = (0, mult - r)
    return jnp.pad(x, pad)


@functools.partial(jax.jit, static_argnames=("block_m", "block_n", "block_k",
                                             "interpret"))
def matmul_abft(a: jax.Array, b: jax.Array, br: Optional[jax.Array] = None, *,
                block_m: int = 128, block_n: int = 128, block_k: int = 128,
                interpret: bool = False) -> Tuple[jax.Array, Check]:
    """C = A @ B with the fused ABFT check computed in the same pass.

    ``br`` is the offline right-checksum column B·e (``[k]`` or ``[k, 1]``);
    recomputed here when not supplied (weights: fold it at load time).
    Returns (C, Check) where Check.predicted = (eᵀA)·(B e) and
    Check.actual = Σ C — both produced by the kernel epilogue, not a second
    HBM pass.  The Check is the registered-pytree engine type at explicit
    ``"layer"`` granularity (one scalar corner for the whole product);
    compare it NaN-safely via ``Check.flag(cfg)`` — a NaN divergence flags.
    """
    m, k = a.shape
    _, n = b.shape
    if br is None:
        br = b.astype(jnp.float32).sum(axis=1, keepdims=True)
    elif br.ndim == 1:
        br = br[:, None]
    ap = _pad_to(_pad_to(a, block_m, 0), block_k, 1)
    bp = _pad_to(_pad_to(b, block_k, 0), block_n, 1)
    brp = _pad_to(br, block_k, 0)
    c, block_sums, extra = matmul_abft_kernel(
        ap, bp, brp, block_m=block_m, block_n=block_n, block_k=block_k,
        interpret=interpret)
    c = c[:m, :n]
    actual = block_sums.sum()                       # O(#blocks) reduce
    predicted = extra[:m, 0].sum()                  # Σ (A b_r) = eᵀA B e
    return c, Check(predicted=predicted, actual=actual, granularity="layer")


class MatmulAbftOp(CheckedOp):
    """CheckedOp over the Pallas fused-epilogue matmul kernel.

    ``out, check = op(cfg, a, b, w_r=folded)`` — the kernel computes the
    product and both checksum corners in one HBM pass; a folded ``w_r``
    (validated against ``cfg.dtype``) skips the per-call row-sum of B.
    Drop-in for :class:`~repro.core.abft.MatmulOp` where the operands are
    2-D and the platform compiles Pallas (pass ``interpret=True`` on CPU).
    """

    op_id = "matmul_abft"

    def __init__(self, *, block_m: int = 128, block_n: int = 128,
                 block_k: int = 128, interpret: bool = False):
        self.block_m, self.block_n, self.block_k = block_m, block_n, block_k
        self.interpret = interpret

    def __call__(self, cfg: ABFTConfig, a: jax.Array, b: jax.Array, *,
                 w_r: Optional[jax.Array] = None):
        w_r = resolve_w_r(b, w_r, cfg) if cfg.enabled else None
        c, check = matmul_abft(a, b, w_r,
                               block_m=self.block_m, block_n=self.block_n,
                               block_k=self.block_k,
                               interpret=self.interpret)
        return c, (check if cfg.enabled else None)
