"""Pallas TPU kernel: blocked matmul with FUSED ABFT checksum epilogue.

TPU-native adaptation of the paper's systolic augmented-matrix trick
(DESIGN.md §5): instead of physically appending checksum rows/columns to the
operands (which breaks 128-lane/MXU tiling — a 2048+1-column matrix pads to
2176 and wastes MXU cycles), the operands stay pristine and the checksum
quantities accumulate in VMEM scratch during the SAME HBM pass:

  outputs:  C = A @ B                      [M, N]
            block_sums[mi, ni] = Σ C_blk   (actual checksum, per block —
                                            final reduce is O(M/bm · N/bn))
            extra = A @ b_r                [M]  (the paper's eq. (5) column;
                                            b_r = B·e computed offline)

Grid: (M/bm, N/bn, K/bk), K innermost ("arbitrary"), f32 accumulation in
VMEM scratch; the extra column accumulates only on the n==0 sweep so it
costs one extra MXU column, exactly like the paper's augmented operand.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _kernel(a_ref, b_ref, br_ref, c_ref, sums_ref, extra_ref,
            acc_ref, ex_ref):
    ki = pl.program_id(2)
    nk = pl.num_programs(2)
    ni = pl.program_id(1)

    @pl.when(ki == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    @pl.when((ki == 0) & (ni == 0))
    def _init_ex():
        ex_ref[...] = jnp.zeros_like(ex_ref)

    a = a_ref[...]
    acc_ref[...] += jnp.dot(a, b_ref[...],
                            preferred_element_type=jnp.float32)

    @pl.when(ni == 0)
    def _extra():
        ex_ref[...] += jnp.dot(a, br_ref[...],
                               preferred_element_type=jnp.float32)

    @pl.when(ki == nk - 1)
    def _epilogue():
        acc = acc_ref[...]
        c_ref[...] = acc.astype(c_ref.dtype)
        sums_ref[0, 0] = jnp.sum(acc)

        @pl.when(ni == 0)
        def _write_extra():
            extra_ref[...] = ex_ref[...].astype(extra_ref.dtype)


@functools.partial(jax.jit, static_argnames=("block_m", "block_n", "block_k",
                                             "interpret"))
def matmul_abft_kernel(a: jax.Array, b: jax.Array, br: jax.Array, *,
                       block_m: int = 128, block_n: int = 128,
                       block_k: int = 128, interpret: bool = False):
    """a: [M, K]; b: [K, N]; br: [K, 1] (= B·e, offline).
    Returns (c [M,N], block_sums [M/bm, N/bn], extra [M, 1])."""
    m, k = a.shape
    k2, n = b.shape
    assert k == k2 and br.shape == (k, 1)
    assert m % block_m == 0 and n % block_n == 0 and k % block_k == 0, (
        "caller (ops.py) pads to block multiples")
    grid = (m // block_m, n // block_n, k // block_k)

    return pl.pallas_call(
        _kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_m, block_k), lambda mi, ni, ki: (mi, ki)),
            pl.BlockSpec((block_k, block_n), lambda mi, ni, ki: (ki, ni)),
            pl.BlockSpec((block_k, 1), lambda mi, ni, ki: (ki, 0)),
        ],
        out_specs=[
            pl.BlockSpec((block_m, block_n), lambda mi, ni, ki: (mi, ni)),
            pl.BlockSpec((1, 1), lambda mi, ni, ki: (mi, ni)),
            pl.BlockSpec((block_m, 1), lambda mi, ni, ki: (mi, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((m, n), a.dtype),
            jax.ShapeDtypeStruct(grid[:2], jnp.float32),
            jax.ShapeDtypeStruct((m, 1), jnp.float32),
        ],
        scratch_shapes=[
            pltpu.VMEM((block_m, block_n), jnp.float32),
            pltpu.VMEM((block_m, 1), jnp.float32),
        ],
        interpret=interpret,
    )(a, b, br)
