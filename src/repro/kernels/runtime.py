"""Shared kernel-runtime policy knobs.

:func:`resolve_interpret` is the ONE place the "should Pallas run in
interpret mode?" decision lives.  It used to be re-derived as
``jax.default_backend() != "tpu"`` at six call sites (both localize
retry builders, the streaming serve step, the block-ELL backend, and
the two ``*_auto`` kernel wrappers); abftlint's sync pass exempts this
module by construction, and every other backend query in a hot path is
a finding.

Resolution order:

1. an explicit ``interpret=`` argument (tests and benchmarks pass one);
2. the ``REPRO_PALLAS_INTERPRET`` environment variable (``0``/``false``
   forces compiled, anything else forces interpret) — the escape hatch
   for forcing either mode on unusual hosts without threading a flag
   through every layer;
3. the backend default: interpret everywhere but TPU (CPU/GPU have no
   Pallas TPU backend to compile for).

The result is always a plain ``bool``, safe as a jit static argument.
"""
from __future__ import annotations

import os
from typing import Optional

import jax

_ENV = "REPRO_PALLAS_INTERPRET"
_FALSY = ("0", "false", "no", "off", "")


def resolve_interpret(interpret: Optional[bool] = None) -> bool:
    """Resolve an ``interpret`` override to a concrete bool (see module
    docstring for the precedence)."""
    if interpret is not None:
        return bool(interpret)
    env = os.environ.get(_ENV)
    if env is not None:
        return env.strip().lower() not in _FALSY
    return jax.default_backend() != "tpu"  # abftlint: backend-query-ok
