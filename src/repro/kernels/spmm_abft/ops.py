"""Public wrappers for the spmm_abft Pallas kernel: host layout → device
arrays, padding to block/lane multiples, final stripe-sum reduction, Check
construction, and the fused sparse GCN layer built on top of it.

CPU has no Pallas TPU backend: pass ``interpret=True`` (tests do) or call
through :func:`spmm_abft_auto`, which falls back to interpret mode off-TPU.
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core.abft import Check

from .kernel import spmm_abft_kernel
from .layout import BlockEll


def device_block_ell(bell: BlockEll) -> Tuple[jax.Array, jax.Array]:
    """(block_cols, values) as device arrays — stage once per static graph."""
    return jnp.asarray(bell.block_cols), jnp.asarray(bell.values)


def fit_rows(x: jax.Array, rows: int) -> jax.Array:
    """Pad or trim x's leading axis to ``rows``.  Trimming is sound: it
    only happens when trailing column-blocks of S hold no nonzero tiles,
    so those x rows are never referenced by any stored tile.  Shared with
    the fused-layer kernel's operand prep (``kernels/gcn_fused/ops.py``)."""
    if x.shape[0] > rows:
        return x[:rows]
    if x.shape[0] < rows:
        return jnp.pad(x, [(0, rows - x.shape[0])] + [(0, 0)] * (x.ndim - 1))
    return x


_fit_rows = fit_rows


def prepare_operands(bell: BlockEll, x: jax.Array, xr: Optional[jax.Array],
                     block_g: int) -> Tuple[jax.Array, jax.Array]:
    """The kernel's operand contract, shared by the single-device and the
    shard_map'd caller: rows padded to cover every referenced column
    stripe (>= one block_k), the feature axis to a block_g lane multiple,
    and ``xr`` defaulting to the standalone column X·e in f32."""
    if xr is None:
        xr = x.astype(jnp.float32).sum(axis=1, keepdims=True)
    k_pad = max(bell.padded_cols, bell.block_k)
    g = x.shape[1]
    gp = -(-g // block_g) * block_g
    xp = _fit_rows(x, k_pad)
    if gp != g:
        xp = jnp.pad(xp, [(0, 0), (0, gp - g)])
    return xp, _fit_rows(xr.astype(jnp.float32), k_pad)


def trim_output(bell: BlockEll, out: jax.Array, g: int) -> jax.Array:
    """Drop stripe/lane padding back to the logical [n, g] output."""
    return out[:bell.shape[0], :g]


def stripe_check_corners(stripe_sums: jax.Array, extra: jax.Array) -> Check:
    """Per-stripe kernel partials -> one eq.-6 corner PER ROW-STRIPE.

    The finest check granularity the kernels support: the grid already
    accumulates (actual, predicted) per row-stripe — this just declines to
    collapse them, so a flipped bit names the stripe it landed in and
    recovery can re-execute exactly those rows.  Exact by linearity, same
    argument as the per-graph segmentation; padding stripes (all-zero
    tiles) compare 0 = 0 and can never flag.  Shared by the two-pass
    (``spmm_abft*``) and single-pass (``gcn_fused*``) wrappers."""
    nbm = stripe_sums.shape[0]
    pred = extra[:, 0].reshape(nbm, -1).sum(axis=1)
    return Check(predicted=pred, actual=stripe_sums[:, 0],
                 granularity="stripe")


def spmm_abft(bell: BlockEll, x: jax.Array, xr: Optional[jax.Array] = None,
              *, block_g: int = 128, interpret: bool = False,
              granularity: str = "layer",
              inject: Optional[Tuple[int, int, float]] = None,
              _staged: Optional[Tuple[jax.Array, jax.Array]] = None
              ) -> Tuple[jax.Array, Check]:
    """out = S @ X with the fused ABFT check computed in the same pass.

    ``xr`` is the carried right-checksum column: X·e by default (standalone
    check of this multiply), or H·w_r threaded from the combination matmul
    for the full GCN-ABFT chain (eq. 4) — then Check.predicted equals
    s_c H w_r without s_c ever being applied online.
    ``granularity="stripe"`` keeps the kernel's per-row-stripe partials as
    individual corners ([n_block_rows] fields) instead of collapsing to one
    scalar; ``"layer"`` (default) is the paper's single corner.
    ``_staged`` lets a long-lived caller (the engine's block_ell backend)
    reuse already-staged (block_cols, values) device arrays.
    Returns (out [n, g], Check(predicted=Σ S·xr, actual=Σ out)).
    """
    n, _k_logical = bell.shape
    g = x.shape[1]
    cols, vals = _staged if _staged is not None else device_block_ell(bell)
    xp, xrp = prepare_operands(bell, x, xr, block_g)
    out, stripe_sums, extra = spmm_abft_kernel(cols, vals, xp, xrp,
                                               interpret=interpret,
                                               inject=inject)
    if granularity == "stripe":
        return trim_output(bell, out, g), stripe_check_corners(stripe_sums,
                                                               extra)
    return trim_output(bell, out, g), Check(predicted=extra[:n, 0].sum(),
                                            actual=stripe_sums.sum())


def validate_packed_operands(vals: jax.Array, rows: int, name: str) -> None:
    """Shared contract of the block-diagonal packed kernels: square blocks
    (stripe offset == column-block offset) and a row operand covering every
    padded stripe."""
    nbm, _width, bm, bk = vals.shape
    if bm != bk:
        raise ValueError("block-diagonal packing needs square blocks; "
                         f"got block_m={bm}, block_k={bk}")
    if rows != nbm * bm:
        raise ValueError(f"{name} covers {rows} rows; packed system has "
                         f"{nbm * bm} (= {nbm} stripes x {bm})")


def packed_check_corners(stripe_sums: jax.Array, extra: jax.Array,
                         segments: jax.Array, num_segments: int) -> Check:
    """Per-stripe kernel partials -> one eq.-6 check corner per packed
    graph.  Exact by linearity: each graph owns whole contiguous stripes,
    so segment-summing decomposes the batch checksum with no cross-talk;
    padding stripes fall in the explicit overflow segment (id ==
    num_segments) and are sliced away.  Shared by the two-pass
    (``spmm_abft_packed``) and single-pass (``gcn_fused_packed``) paths —
    the overflow-segment convention lives exactly once."""
    nbm = stripe_sums.shape[0]
    pred_stripe = extra[:, 0].reshape(nbm, -1).sum(axis=1)
    pred = jax.ops.segment_sum(pred_stripe, segments,
                               num_segments=num_segments + 1,
                               indices_are_sorted=True)[:num_segments]
    actual = jax.ops.segment_sum(stripe_sums[:, 0], segments,
                                 num_segments=num_segments + 1,
                                 indices_are_sorted=True)[:num_segments]
    return Check(predicted=pred, actual=actual, granularity="graph")


def spmm_abft_packed(cols: jax.Array, vals: jax.Array, x: jax.Array,
                     xr: Optional[jax.Array], segments: jax.Array,
                     *, num_segments: int, block_g: int = 128,
                     interpret: bool = False, granularity: str = "graph",
                     inject: Optional[Tuple[int, int, float]] = None
                     ) -> Tuple[jax.Array, Optional[Check]]:
    """Block-diagonal packed SpMM with *per-graph* fused check corners.

    ``cols``/``vals`` are the staged (possibly traced) block-ELL arrays of a
    block-diagonal packed system (``engine.batching.pack_graphs``) with
    square blocks, ``x`` the stacked [rows, g] combination output covering
    every padded row, ``xr`` the stacked carried eq.-5 column (or ``None``
    to disable checking), and ``segments`` the [n_block_rows] stripe → graph
    id map (padding stripes carry id ``num_segments`` and are dropped).

    Because the checksum is linear and each graph owns whole contiguous
    stripes, segment-summing the kernel's per-stripe partials decomposes the
    batch check *exactly* into one eq.-6 corner per graph:

        actual[g] = Σ_{stripes of g} Σ out_stripe
        pred[g]   = Σ_{rows of g} (S x_r)_row

    so a flipped bit in one packed graph perturbs only that graph's corner.
    ``granularity="stripe"`` refines further: the per-stripe partials stay
    un-segmented ([n_block_rows] corners), so the fault names the exact
    stripe and a surgical retry can re-execute only those rows.
    Everything here is shape-static, so the whole call jits with
    ``cols``/``vals``/``segments`` as traced per-batch arguments — no
    recompile across batches of the same packed shape.
    Returns (out [rows, g], Check(predicted [G], actual [G]) | None).
    """
    validate_packed_operands(vals, x.shape[0], "x")
    rows = x.shape[0]
    g = x.shape[1]
    gp = -(-g // block_g) * block_g
    xp = jnp.pad(x, [(0, 0), (0, gp - g)]) if gp != g else x
    want_check = xr is not None
    xrp = (jnp.zeros((rows, 1), jnp.float32) if xr is None
           else xr.astype(jnp.float32))
    out, stripe_sums, extra = spmm_abft_kernel(cols, vals, xp, xrp,
                                               interpret=interpret,
                                               inject=inject)
    out = out[:, :g]
    if not want_check:
        return out, None
    if granularity == "stripe":
        return out, stripe_check_corners(stripe_sums, extra)
    return out, packed_check_corners(stripe_sums, extra, segments,
                                     num_segments)


def spmm_abft_auto(bell: BlockEll, x: jax.Array,
                   xr: Optional[jax.Array] = None, *, block_g: int = 128
                   ) -> Tuple[jax.Array, Check]:
    """Same as :func:`spmm_abft`, interpret mode resolved by
    :func:`repro.kernels.runtime.resolve_interpret`."""
    from repro.kernels.runtime import resolve_interpret
    return spmm_abft(bell, x, xr, block_g=block_g,
                     interpret=resolve_interpret())


def gcn_layer_fused_sparse_kernel(bell: BlockEll, h: jax.Array, w: jax.Array,
                                  *, w_r: Optional[jax.Array] = None,
                                  block_g: int = 128,
                                  interpret: bool = False
                                  ) -> Tuple[jax.Array, Check]:
    """One GCN layer H_out = S (H W) with the single fused GCN-ABFT check
    (eqs. 4–6), aggregation through the block-ELL Pallas kernel.

    Thin shim over the unified engine (``repro.engine``): the eq. 4–6
    algebra lives in ``engine/api.py``; this backend only contributes the
    kernel aggregation, whose fused epilogue carries x_r = H w_r so
    Check.predicted = Σ S H w_r = s_c H w_r with no online s_c pass.
    ``w_r`` (= W·e) is offline in a deployment — fold it at weight-load time.
    """
    from repro.core.abft import ABFTConfig
    from repro.engine import gcn_layer, make_backend

    cfg = ABFTConfig(mode="fused", dtype=jnp.float32)
    bk = make_backend(bell, cfg, backend="block_ell", block_g=block_g,
                      interpret=interpret)
    w_r_vec = None if w_r is None else w_r.reshape(-1)
    h_out, checks = gcn_layer(bk, h, w, cfg, w_r=w_r_vec)
    return h_out, checks[0]
