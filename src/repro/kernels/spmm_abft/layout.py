"""Padded block-ELL layout for the sparse aggregation step H_out = S X.

The normalized adjacency S of a static graph is converted ONCE, offline, to
a blocked ELL layout: rows are partitioned into ``block_m``-row stripes and
columns into ``block_k`` stripes; each row-stripe stores its nonzero
(block_m, block_k) tiles densely, padded to the widest stripe (``width`` =
max nonzero tiles per stripe).  Padding tiles point at column-block 0 with
all-zero values, so they contribute nothing and need no masking in the
kernel — the same trick matmul_abft uses for shape padding.

Why ELL and not CSR-of-blocks: the Pallas grid must be static, and a
rectangular [n_block_rows, width] tile table gives every grid step the same
block shape; the column-block indices ride along as a scalar-prefetch
operand (``pltpu.PrefetchScalarGridSpec``) so the X tile DMA can be issued
before the kernel body runs.

The conversion is numpy-only (no jax import at module load) so the fault
engine and dataset code can use it without touching the accelerator path.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Sequence, Tuple

import numpy as np


@dataclasses.dataclass
class BlockEll:
    """Padded block-ELL sparse matrix (host-side numpy buffers).

    values:     [n_block_rows, width, block_m, block_k] f32 tile table
    block_cols: [n_block_rows, width] int32 column-block index per tile
                (padding tiles: index 0, values 0)
    shape:      logical (unpadded) matrix shape
    """

    values: np.ndarray
    block_cols: np.ndarray
    shape: Tuple[int, int]

    @property
    def block_m(self) -> int:
        return self.values.shape[2]

    @property
    def block_k(self) -> int:
        return self.values.shape[3]

    @property
    def n_block_rows(self) -> int:
        return self.values.shape[0]

    @property
    def width(self) -> int:
        return self.values.shape[1]

    @property
    def padded_rows(self) -> int:
        return self.n_block_rows * self.block_m

    @property
    def padded_cols(self) -> int:
        # column-block indices address X row-stripes; X must be padded to
        # cover the largest referenced stripe
        return (int(self.block_cols.max()) + 1) * self.block_k \
            if self.block_cols.size else self.block_k

    @property
    def nnz_tiles(self) -> int:
        """Nonzero tiles actually stored (excludes ELL padding)."""
        return int((np.abs(self.values).sum(axis=(2, 3)) > 0).sum())

    @property
    def fill(self) -> float:
        """Stored-tile fraction of the full dense block grid."""
        n_bk = -(-self.shape[1] // self.block_k)
        return self.width / max(n_bk, 1)

    def todense(self) -> np.ndarray:
        """Dense [rows, cols] reconstruction (tests / small graphs only)."""
        m, k = self.shape
        nbk = -(-k // self.block_k)
        out = np.zeros((self.padded_rows, nbk * self.block_k), np.float32)
        for i in range(self.n_block_rows):
            for t in range(self.width):
                j = int(self.block_cols[i, t])
                out[i * self.block_m:(i + 1) * self.block_m,
                    j * self.block_k:(j + 1) * self.block_k] += \
                    self.values[i, t]
        return out[:m, :k]

    def col_sums(self, dtype=np.float64) -> np.ndarray:
        """e^T S over the logical columns — the offline s_c vector."""
        nbk = -(-self.shape[1] // self.block_k)
        out = np.zeros(nbk * self.block_k, dtype)
        # tile-local column sums scattered to their column-block slot
        local = self.values.astype(dtype).sum(axis=2)     # [nbr, width, bk]
        for i in range(self.n_block_rows):
            for t in range(self.width):
                j = int(self.block_cols[i, t])
                out[j * self.block_k:(j + 1) * self.block_k] += local[i, t]
        return out[:self.shape[1]]


def pad_block_rows(bell: BlockEll, multiple: int) -> BlockEll:
    """Pad the stripe count to a multiple (sharded aggregation: stripes must
    divide the mesh axis).  Padding stripes are all-zero tiles aliasing
    column-block 0 — they produce zero output rows and contribute nothing
    to either side of the check, so no masking anywhere downstream."""
    nbm = bell.n_block_rows
    add = (-nbm) % multiple
    if add == 0:
        return bell
    values = np.concatenate(
        [bell.values,
         np.zeros((add,) + bell.values.shape[1:], np.float32)], axis=0)
    block_cols = np.concatenate(
        [bell.block_cols, np.zeros((add, bell.width), np.int32)], axis=0)
    return BlockEll(values=values, block_cols=block_cols, shape=bell.shape)


def pad_width(bell: BlockEll, width_to: int) -> BlockEll:
    """Pad the ELL width (tiles per stripe) to an exact slot count.

    Canonical serving shapes need every batch of a rung to present the SAME
    [n_block_rows, width] tile table to jit regardless of which graphs
    landed in it.  Padding slots follow the layout's standing convention —
    column-block 0 with all-zero values — so they contribute nothing to the
    product or either side of the check and need no masking downstream."""
    if width_to < bell.width:
        raise ValueError(f"cannot pad ELL width {bell.width} down to "
                         f"{width_to}")
    if width_to == bell.width:
        return bell
    add = width_to - bell.width
    nbm = bell.n_block_rows
    values = np.concatenate(
        [bell.values,
         np.zeros((nbm, add, bell.block_m, bell.block_k), np.float32)],
        axis=1)
    block_cols = np.concatenate(
        [bell.block_cols, np.zeros((nbm, add), np.int32)], axis=1)
    return BlockEll(values=values, block_cols=block_cols, shape=bell.shape)


def pad_block_rows_to(bell: BlockEll, n_block_rows: int) -> BlockEll:
    """Pad the stripe count to an exact value (the rung's stripe capacity).

    Unlike :func:`pad_block_rows` (round up to a multiple) this pins the
    stripe axis, so every batch of a canonical rung shares one jit shape.
    Padding stripes are the usual all-zero tiles aliasing column-block 0."""
    add = n_block_rows - bell.n_block_rows
    if add < 0:
        raise ValueError(f"cannot pad {bell.n_block_rows} block rows down "
                         f"to {n_block_rows}")
    if add == 0:
        return bell
    values = np.concatenate(
        [bell.values,
         np.zeros((add,) + bell.values.shape[1:], np.float32)], axis=0)
    block_cols = np.concatenate(
        [bell.block_cols, np.zeros((add, bell.width), np.int32)], axis=0)
    return BlockEll(values=values, block_cols=block_cols, shape=bell.shape)


def stack_block_ell(bells: Sequence[BlockEll],
                    col_block_offsets: Sequence[int],
                    shape: Optional[Tuple[int, int]] = None,
                    width_multiple: int = 1) -> BlockEll:
    """Stack the row-stripes of several BlockElls into one system, shifting
    each matrix's column-block indices by its offset.

    With ``col_block_offsets`` equal to the cumulative row-stripe offsets of
    square per-graph matrices this builds the *block-diagonal* packed system
    of a batch of graphs: graph g's stripes only reference graph g's column
    stripes, so stripe-local checksum partials segment exactly per graph.
    Widths pad to the widest input (rounded up to ``width_multiple`` to
    quantize jit shapes); padding slots keep column-block 0 with zero values
    — they reference some stripe's X rows but contribute nothing, the same
    no-masking trick as ELL padding within one matrix.
    """
    if not bells:
        raise ValueError("stack_block_ell needs at least one BlockEll")
    bm, bk = bells[0].block_m, bells[0].block_k
    for b in bells:
        if (b.block_m, b.block_k) != (bm, bk):
            raise ValueError("all stacked BlockElls must share block sizes; "
                             f"got {(b.block_m, b.block_k)} vs {(bm, bk)}")
    if len(col_block_offsets) != len(bells):
        raise ValueError("one column-block offset per stacked BlockEll")
    width = max(b.width for b in bells)
    width = -(-width // max(width_multiple, 1)) * max(width_multiple, 1)
    total = sum(b.n_block_rows for b in bells)
    values = np.zeros((total, width, bm, bk), np.float32)
    block_cols = np.zeros((total, width), np.int32)
    off = 0
    for b, coff in zip(bells, col_block_offsets):
        nbr, w = b.n_block_rows, b.width
        values[off:off + nbr, :w] = b.values
        block_cols[off:off + nbr, :w] = b.block_cols + np.int32(coff)
        off += nbr
    if shape is None:
        shape = (total * bm, (int(block_cols.max()) + 1) * bk)
    return BlockEll(values=values, block_cols=block_cols, shape=shape)


def coo_to_block_ell(row: np.ndarray, col: np.ndarray, data: np.ndarray,
                     shape: Tuple[int, int], block_m: int = 128,
                     block_k: int = 128) -> BlockEll:
    """Convert COO triplets to padded block-ELL (duplicates are summed)."""
    m, k = shape
    row = np.asarray(row, np.int64)
    col = np.asarray(col, np.int64)
    data = np.asarray(data, np.float32)
    nbm = -(-m // block_m)
    nbk = -(-k // block_k)

    br = row // block_m
    bc = col // block_k
    tile_id = br * nbk + bc
    order = np.argsort(tile_id, kind="stable")
    tile_sorted = tile_id[order]
    uniq, starts = np.unique(tile_sorted, return_index=True)
    ends = np.append(starts[1:], tile_sorted.size)

    counts = np.zeros(nbm, np.int64)
    np.add.at(counts, uniq // nbk, 1)
    width = max(int(counts.max()) if counts.size else 1, 1)

    values = np.zeros((nbm, width, block_m, block_k), np.float32)
    block_cols = np.zeros((nbm, width), np.int32)
    slot = np.zeros(nbm, np.int64)
    for t, lo, hi in zip(uniq, starts, ends):
        i, j = int(t // nbk), int(t % nbk)
        s = int(slot[i])
        idx = order[lo:hi]
        np.add.at(values[i, s],
                  (row[idx] - i * block_m, col[idx] - j * block_k), data[idx])
        block_cols[i, s] = j
        slot[i] += 1
    return BlockEll(values=values, block_cols=block_cols, shape=(m, k))


def dense_to_block_ell(a: np.ndarray, block_m: int = 128,
                       block_k: int = 128) -> BlockEll:
    """Dense → block-ELL, dropping all-zero tiles (tests / small graphs)."""
    a = np.asarray(a, np.float32)
    r, c = np.nonzero(a)
    return coo_to_block_ell(r, c, a[r, c], a.shape, block_m, block_k)
