"""Pallas TPU kernel: block-ELL SpMM with FUSED ABFT checksum epilogue.

The sparse analogue of ``kernels/matmul_abft``: H_out = S @ X where S is a
padded block-ELL adjacency (see ``layout.py``).  The grid walks
(row-stripe, ell-slot); the column-block index table rides as a
scalar-prefetch operand so each X tile's DMA address is known before the
body runs (``pltpu.PrefetchScalarGridSpec``).  ELL padding tiles alias
column-block 0 with zero values — they add nothing, so no masking.

Checksum epilogue, same trick as matmul_abft: the operands stay pristine
(no physically augmented rows/columns to break 128-lane tiling) and the
check quantities accumulate in VMEM scratch during the same HBM pass:

  outputs: out  = S @ X                 [M, G]
           stripe_sums[i] = Σ out_stripe  (actual checksum — final reduce
                                           is O(M/bm), done by ops.py)
           extra = S @ x_r             [M, 1]  (the carried eq.-5 column:
                    x_r = X e for a standalone check, or H w_r threaded
                    from the combination matmul for the full eq.-4 chain)

The G (output-feature) axis is not tiled: GCN widths (16–186 in the paper)
fit one lane block after ops.py pads them, which keeps the grid 2-D and the
extra column accumulating on every step — there is no ni==0 sweep guard to
get wrong.
"""
from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _make_kernel(inject: Optional[Tuple[int, int, float]]):
    def _kernel(cols_ref, s_ref, x_ref, xr_ref, out_ref, sums_ref, extra_ref,
                acc_ref, ex_ref):
        j = pl.program_id(1)
        nj = pl.num_programs(1)

        @pl.when(j == 0)
        def _init():
            acc_ref[...] = jnp.zeros_like(acc_ref)
            ex_ref[...] = jnp.zeros_like(ex_ref)

        s = s_ref[0, 0]
        acc_ref[...] += jnp.dot(s, x_ref[...],
                                preferred_element_type=jnp.float32)
        ex_ref[...] += jnp.dot(s, xr_ref[...],
                               preferred_element_type=jnp.float32)

        if inject is not None:
            # same accumulator-upset hook as the fused kernel: perturbs one
            # element mid-sweep so the two-pass path's detection + surgical
            # repair can be exercised end to end
            ii, jj, delta = inject

            @pl.when((pl.program_id(0) == ii) & (j == jj))
            def _inject():
                acc_ref[0, 0] += jnp.float32(delta)

        @pl.when(j == nj - 1)
        def _epilogue():
            acc = acc_ref[...]
            out_ref[...] = acc.astype(out_ref.dtype)
            sums_ref[0, 0] = jnp.sum(acc)
            extra_ref[...] = ex_ref[...]

    return _kernel


@functools.partial(jax.jit, static_argnames=("interpret", "inject"))
def spmm_abft_kernel(block_cols: jax.Array, values: jax.Array, x: jax.Array,
                     xr: jax.Array, *, interpret: bool = False,
                     inject: Optional[Tuple[int, int, float]] = None):
    """block_cols: [nbm, width] i32; values: [nbm, width, bm, bk];
    x: [K, G]; xr: [K, 1].  K and G must be padded by the caller (ops.py)
    to bk / lane multiples and to cover max(block_cols)+1 stripes.
    ``inject=(stripe, slot, delta)`` perturbs one accumulator element
    mid-sweep (CI fault hook).
    Returns (out [nbm*bm, G], stripe_sums [nbm, 1], extra [nbm*bm, 1])."""
    nbm, width, bm, bk = values.shape
    k, g = x.shape
    assert k % bk == 0 and xr.shape == (k, 1)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(nbm, width),
        in_specs=[
            pl.BlockSpec((1, 1, bm, bk), lambda i, j, cols: (i, j, 0, 0)),
            pl.BlockSpec((bk, g), lambda i, j, cols: (cols[i, j], 0)),
            pl.BlockSpec((bk, 1), lambda i, j, cols: (cols[i, j], 0)),
        ],
        out_specs=[
            pl.BlockSpec((bm, g), lambda i, j, cols: (i, 0)),
            pl.BlockSpec((1, 1), lambda i, j, cols: (i, 0)),
            pl.BlockSpec((bm, 1), lambda i, j, cols: (i, 0)),
        ],
        scratch_shapes=[
            pltpu.VMEM((bm, g), jnp.float32),
            pltpu.VMEM((bm, 1), jnp.float32),
        ],
    )
    return pl.pallas_call(
        _make_kernel(inject),
        grid_spec=grid_spec,
        out_shape=[
            jax.ShapeDtypeStruct((nbm * bm, g), x.dtype),
            jax.ShapeDtypeStruct((nbm, 1), jnp.float32),
            jax.ShapeDtypeStruct((nbm * bm, 1), jnp.float32),
        ],
        interpret=interpret,
    )(block_cols, values, x, xr)
