"""Pure-jnp oracle for the spmm_abft kernel (densifies S — small shapes)."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def spmm_abft_ref(s_dense: jax.Array, x: jax.Array, xr: jax.Array):
    """Returns (out, actual_checksum_scalar, extra [M,1]) in f32 accumulation.

    s_dense is the dense reconstruction of the block-ELL operand
    (``BlockEll.todense()``); xr is the carried right-checksum column.
    """
    out = jnp.dot(s_dense, x, preferred_element_type=jnp.float32)
    actual = out.sum()
    extra = jnp.dot(s_dense, xr, preferred_element_type=jnp.float32)
    return out.astype(x.dtype), actual, extra.astype(jnp.float32)
