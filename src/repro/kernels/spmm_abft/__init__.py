from .layout import (  # noqa: F401
    BlockEll,
    coo_to_block_ell,
    dense_to_block_ell,
    pad_block_rows,
    stack_block_ell,
)
from .ops import (  # noqa: F401
    gcn_layer_fused_sparse_kernel,
    spmm_abft,
    spmm_abft_auto,
    spmm_abft_packed,
    stripe_check_corners,
)
