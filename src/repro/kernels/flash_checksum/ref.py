"""Pure-jnp oracle for the flash_checksum kernel: materialized-A attention
plus the exact fused chain checksum quantities."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def flash_checksum_ref(q, k, v, vr, *, causal: bool = True):
    """q: [BH,T,dh]; k,v: [BH,S,dh]; vr: [BH,S,1].
    Returns (o [BH,T,dh], o_extra [BH,T,1])."""
    bh, t, dh = q.shape
    s = k.shape[1]
    scale = dh ** -0.5
    logits = jnp.einsum("bqd,bkd->bqk", q.astype(jnp.float32),
                        k.astype(jnp.float32)) * scale
    if causal:
        qpos = jnp.arange(t)[:, None]
        kpos = jnp.arange(s)[None, :]
        logits = jnp.where(kpos <= qpos, logits, -1e30)
    a = jax.nn.softmax(logits, axis=-1)
    o = jnp.einsum("bqk,bkd->bqd", a, v.astype(jnp.float32))
    o_extra = jnp.einsum("bqk,bkd->bqd", a, vr.astype(jnp.float32))
    return o.astype(q.dtype), o_extra.astype(jnp.float32)
