from .ops import flash_attention_checksum  # noqa: F401
