"""jit'd wrapper: head grouping, W_o folding, padding, Check construction —
plus the :class:`FlashAttentionOp` CheckedOp that runs the whole
A·V·W_o chain (flash attention + output projection) as ONE checked op."""
from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core.abft import ABFTConfig, Check, CheckedOp

from .kernel import flash_checksum_kernel


@functools.partial(jax.jit, static_argnames=("causal", "block_q", "block_k",
                                             "interpret"))
def flash_attention_checksum(q, k, v, w_or, *, causal: bool = True,
                             block_q: int = 128, block_k: int = 128,
                             interpret: bool = False
                             ) -> Tuple[jax.Array, jax.Array]:
    """q: [B,T,H,dh]; k,v: [B,S,Kh,dh]; w_or: [H,dh] = per-head W_o·e.

    Returns (o [B,T,H,dh], o_extra [B,T,H]): Σ o_extra equals the fused
    chain checksum eᵀ(A·V·W_o)e — compare against Σ(attn_out·W_o) with
    `Check(predicted=o_extra.sum(), actual=out.sum())`.
    """
    b, t, h, dh = q.shape
    s = k.shape[1]
    kh = k.shape[2]
    g = h // kh
    # expand KV to query heads ([B,S,Kh,dh] -> [B,S,H,dh]) and fold w_or
    k_e = jnp.repeat(k, g, axis=2)
    v_e = jnp.repeat(v, g, axis=2)
    vr = jnp.einsum("bskd,kd->bsk", v_e.astype(jnp.float32),
                    w_or.astype(jnp.float32))[..., None]      # [B,S,H,1]
    qf = q.transpose(0, 2, 1, 3).reshape(b * h, t, dh)
    kf = k_e.transpose(0, 2, 1, 3).reshape(b * h, s, dh)
    vf = v_e.transpose(0, 2, 1, 3).reshape(b * h, s, dh)
    vrf = vr.transpose(0, 2, 1, 3).reshape(b * h, s, 1).astype(q.dtype)

    pad_q = (-t) % block_q
    pad_k = (-s) % block_k
    if pad_q:
        qf = jnp.pad(qf, [(0, 0), (0, pad_q), (0, 0)])
    if pad_k:
        kf = jnp.pad(kf, [(0, 0), (0, pad_k), (0, 0)])
        vf = jnp.pad(vf, [(0, 0), (0, pad_k), (0, 0)])
        vrf = jnp.pad(vrf, [(0, 0), (0, pad_k), (0, 0)])
        # padded keys must never win the softmax: rely on causal mask for
        # causal=True; for bidirectional, bias keys via -inf in kernel is
        # avoided by requiring S % block_k == 0 (caller contract).
        assert causal, "non-causal inputs must be pre-padded to block_k"

    o, ex = flash_checksum_kernel(qf, kf, vf, vrf, causal=causal,
                                  block_q=block_q, block_k=block_k,
                                  interpret=interpret)
    o = o[:, :t].reshape(b, h, t, dh).transpose(0, 2, 1, 3)
    ex = ex[:, :t, 0].reshape(b, h, t).transpose(0, 2, 1)
    return o, ex


def chain_check(o_extra: jax.Array, out_after_wo: jax.Array, *,
                granularity: str = "layer") -> Check:
    """Close the eq. 4–6 chain: Σ o_extra (the kernel's carried column,
    independent of the output path) vs Σ(attn_out·W_o).  Returns the
    registered-pytree :class:`Check` with an explicit granularity aux —
    compare via ``Check.flag(cfg)``, whose ``~(d <= tau*scale)`` form
    flags NaN divergences instead of silently passing them."""
    return Check(predicted=o_extra.astype(jnp.float32).sum(),
                 actual=out_after_wo.astype(jnp.float32).sum(),
                 granularity=granularity)


def fold_w_or(wo: jax.Array, n_heads: int, hd: int) -> jax.Array:
    """Offline fold of the output projection's right checksum into the
    per-head carried-column form: ``w_or[h, dh]`` = the head-``h`` slice of
    W_o·e.  ``wo`` is ``[H*dh, d]`` (the ``init_dense`` layout)."""
    return wo.astype(jnp.float32).sum(axis=1).reshape(n_heads, hd)


class FlashAttentionOp(CheckedOp):
    """CheckedOp over the flash-checksum kernel: the three-matrix chain
    ``out = A · V · W_o`` (A never materialized) with the paper's single
    eq. 4–6 comparison carried as one extra accumulator column.

    ``out, check = op(cfg, q, k, v, wo, w_or=folded)`` where ``wo`` is the
    ``[H*dh, d]`` output projection and ``w_or`` its per-head folded right
    checksum (:func:`fold_w_or`; recomputed when absent).  The predicted
    side rides the kernel's carried column — computed from Q/K/V/w_or only,
    never from the output — so a fault anywhere in the attention
    accumulator or the W_o matmul trips the comparison.
    """

    op_id = "flash_attention"

    def __init__(self, *, causal: bool = True, block_q: int = 128,
                 block_k: int = 128, interpret: bool = False):
        self.causal = causal
        self.block_q, self.block_k = block_q, block_k
        self.interpret = interpret

    def __call__(self, cfg: ABFTConfig, q: jax.Array, k: jax.Array,
                 v: jax.Array, wo: jax.Array, *,
                 w_or: Optional[jax.Array] = None):
        b, t, h, dh = q.shape
        if w_or is None:
            w_or = fold_w_or(wo, h, dh)
        o, o_extra = flash_attention_checksum(
            q, k, v, w_or, causal=self.causal, block_q=self.block_q,
            block_k=self.block_k, interpret=self.interpret)
        out = o.reshape(b, t, h * dh) @ wo.astype(o.dtype)
        if not cfg.enabled:
            return out, None
        return out, chain_check(o_extra, out)
