"""Pallas TPU kernel: flash attention emitting the fused ABFT chain checksum.

Streaming (online-softmax) attention never materializes A = softmax(QKᵀ), so
the paper's `s_c = eᵀA` is unavailable — but the chain checksum of
O = A·V·W_o only needs  Σ_q A[q,:]·(V·w_or)  with w_or = W_o·e offline
(DESIGN.md §5).  The kernel therefore carries ONE extra accumulator column
(`ex`) updated with the same probability block as the output accumulator:

    acc += P_blk @ V_blk          (the flash update)
    ex  += P_blk @ vr_blk         (the ABFT column — T×block_k extra MACs)

Grid (BH, T/bq, S/bk), K innermost; scratch m/l/acc/ex in VMEM, f32.
Inputs are per-(batch·head) slices: q [BH,T,dh], k/v [BH,S,dh], vr [BH,S,1].
Outputs: o [BH,T,dh], o_extra [BH,T,1] with Σ o_extra = eᵀ(A V W_o)e.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG = -1e30


def _kernel(causal: bool, scale: float,
            q_ref, k_ref, v_ref, vr_ref,
            o_ref, ex_ref,
            m_sc, l_sc, acc_sc, exacc_sc):
    qi = pl.program_id(1)
    ki = pl.program_id(2)
    nk = pl.num_programs(2)
    bq = q_ref.shape[1]
    bk = k_ref.shape[1]

    @pl.when(ki == 0)
    def _init():
        m_sc[...] = jnp.full_like(m_sc, NEG)
        l_sc[...] = jnp.zeros_like(l_sc)
        acc_sc[...] = jnp.zeros_like(acc_sc)
        exacc_sc[...] = jnp.zeros_like(exacc_sc)

    def compute():
        q = q_ref[0]                                   # [bq, dh]
        k = k_ref[0]                                   # [bk, dh]
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32) * scale  # [bq, bk]
        if causal:
            qpos = qi * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
            kpos = ki * bk + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
            valid = kpos <= qpos
            s = jnp.where(valid, s, NEG)
        m_prev = m_sc[...]
        m_new = jnp.maximum(m_prev, s.max(axis=1, keepdims=True))
        p = jnp.exp(s - m_new)
        if causal:
            p = jnp.where(valid, p, 0.0)
        corr = jnp.exp(m_prev - m_new)
        l_sc[...] = l_sc[...] * corr + p.sum(axis=1, keepdims=True)
        m_sc[...] = m_new
        acc_sc[...] = acc_sc[...] * corr + jax.lax.dot_general(
            p.astype(v_ref.dtype), v_ref[0], (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        exacc_sc[...] = exacc_sc[...] * corr + jax.lax.dot_general(
            p.astype(vr_ref.dtype), vr_ref[0], (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)

    if causal:
        # skip key blocks strictly above the diagonal
        @pl.when(ki * bk <= qi * bq + bq - 1)
        def _():
            compute()
    else:
        compute()

    @pl.when(ki == nk - 1)
    def _epilogue():
        l = jnp.maximum(l_sc[...], 1e-30)
        o_ref[0] = (acc_sc[...] / l).astype(o_ref.dtype)
        ex_ref[0] = (exacc_sc[...] / l).astype(ex_ref.dtype)


@functools.partial(jax.jit, static_argnames=("causal", "block_q", "block_k",
                                             "interpret"))
def flash_checksum_kernel(q, k, v, vr, *, causal: bool = True,
                          block_q: int = 128, block_k: int = 128,
                          interpret: bool = False):
    bh, t, dh = q.shape
    s = k.shape[1]
    assert t % block_q == 0 and s % block_k == 0
    scale = dh ** -0.5
    grid = (bh, t // block_q, s // block_k)
    kern = functools.partial(_kernel, causal, scale)
    return pl.pallas_call(
        kern,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, block_q, dh), lambda b, qi, ki: (b, qi, 0)),
            pl.BlockSpec((1, block_k, dh), lambda b, qi, ki: (b, ki, 0)),
            pl.BlockSpec((1, block_k, dh), lambda b, qi, ki: (b, ki, 0)),
            pl.BlockSpec((1, block_k, 1), lambda b, qi, ki: (b, ki, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, block_q, dh), lambda b, qi, ki: (b, qi, 0)),
            pl.BlockSpec((1, block_q, 1), lambda b, qi, ki: (b, qi, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((bh, t, dh), q.dtype),
            jax.ShapeDtypeStruct((bh, t, 1), jnp.float32),
        ],
        scratch_shapes=[
            pltpu.VMEM((block_q, 1), jnp.float32),
            pltpu.VMEM((block_q, 1), jnp.float32),
            pltpu.VMEM((block_q, dh), jnp.float32),
            pltpu.VMEM((block_q, 1), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v, vr)
