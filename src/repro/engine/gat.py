"""Guarded GAT serving: attention-weighted aggregation as a checked op.

A GAT layer is ``H' = A (H W)`` where the attention matrix A is a
row-softmax of LeakyReLU pairwise scores masked to the adjacency.
However A is *computed*, the product itself is a three-matrix chain, so
the paper's eq. 4–6 applies verbatim:

    eᵀ(A H W)e  =  (eᵀA) · (H w_r),      w_r = W e  (folded offline)

One scalar corner per layer covers both matmuls: a corruption of
X = H·W that also perturbs A still breaks the identity, because the
predicted side re-reads H and the folded master w_r while the actual
side sums the served output.  Checks are pre-activation (ELU between
layers breaks the chain, exactly like ReLU in the GCN stack).

:class:`GATEngine` serves layers under the same
:class:`~repro.runtime.abft_guard.ABFTGuard` restore→retry→suspect
ladder as the GCN and LM engines, keyed by ``op:gat{i}`` sites.
"""
from __future__ import annotations

from typing import Any, Callable, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core.abft import (
    ABFTConfig,
    Check,
    CheckedOp,
    fold_w_r_tree,
    per_op_report,
    resolve_w_r,
    summarize,
)
from repro.core.checksum import col_checksum
from repro.runtime.abft_guard import ABFTGuard, GuardConfig

Array = jax.Array
Params = Dict[str, Any]

_NEG_INF = -1e30


# ---------------------------------------------------------------------------
# params
# ---------------------------------------------------------------------------

def init_gat(key, dims: Tuple[int, ...]) -> Params:
    """dims = (f_in, g1, ..., gL): L layers, each {w [f,g], a_l [g],
    a_r [g]}."""
    layers = []
    for i in range(len(dims) - 1):
        f, g = dims[i], dims[i + 1]
        kw, kl, kr = jax.random.split(jax.random.fold_in(key, i), 3)
        layers.append({
            "w": jax.random.normal(kw, (f, g), jnp.float32)
            / jnp.sqrt(jnp.float32(f)),
            "a_l": jax.random.normal(kl, (g,), jnp.float32) * 0.1,
            "a_r": jax.random.normal(kr, (g,), jnp.float32) * 0.1,
        })
    return {"layers": layers}


def fold_gat_w_r(params: Params, cfg: ABFTConfig) -> Params:
    """Offline eq.-5 fold for every layer's W (tree-generic; a_l/a_r are
    1-D and pass through untouched)."""
    return fold_w_r_tree(params, cfg)


# ---------------------------------------------------------------------------
# layer / forward
# ---------------------------------------------------------------------------

def gat_layer(p: Params, h: Array, adj: Array, cfg: ABFTConfig, *,
              w_r: Optional[Array] = None,
              inject: Optional[Array] = None
              ) -> Tuple[Array, Optional[Check]]:
    """One GAT layer (single head).  h: [n, f]; adj: [n, n] (nonzero =
    edge, self-loops included by the caller).  Returns pre-activation
    (out, Check|None).

    ``inject`` is the accumulator fault operand: a scalar delta added to
    out[0, 0] *after* the aggregation — the predicted corner is computed
    from the operands, so the upset is strictly detectable."""
    w = p["w"].astype(h.dtype)
    x = h @ w                                            # [n, g]
    scores = x @ p["a_l"].astype(x.dtype)                # [n]
    scores = scores[:, None] + (x @ p["a_r"].astype(x.dtype))[None, :]
    scores = jax.nn.leaky_relu(scores, 0.2)
    scores = jnp.where(adj > 0, scores, _NEG_INF)
    att = jax.nn.softmax(scores, axis=-1)                # [n, n] rows sum 1
    out = att @ x
    if inject is not None:
        out = out.at[0, 0].add(jnp.asarray(inject).astype(out.dtype))
    if not cfg.enabled:
        return out, None
    wr = resolve_w_r(p["w"], w_r if w_r is not None else p.get("w_r"), cfg)
    pred = jnp.dot(col_checksum(att, cfg.dtype),
                   h.astype(cfg.dtype) @ wr.astype(cfg.dtype))
    actual = out.astype(cfg.dtype).sum()
    return out, Check(predicted=pred, actual=actual)


class GATLayerOp(CheckedOp):
    """The GAT layer as a protocol checked op (layer granularity)."""

    op_id = "gat_layer"
    granularity = "layer"

    def __call__(self, cfg: ABFTConfig, h: Array, adj: Array, p: Params,
                 **folded):
        return gat_layer(p, h, adj, cfg, w_r=folded.get("w_r"))


def gat_forward(params: Params, h: Array, adj: Array, cfg: ABFTConfig, *,
                inject_layer: Optional[Array] = None,
                inject_delta: Optional[Array] = None
                ) -> Tuple[Array, List[Optional[Check]]]:
    """Multi-layer GAT with ELU between layers; checks pre-activation.
    ``inject_layer``/``inject_delta`` are runtime operands: the delta
    fires in the one layer whose index matches (layers are a plain
    Python list, so per-layer addressing is exact here)."""
    checks: List[Optional[Check]] = []
    n_layers = len(params["layers"])
    for i, p in enumerate(params["layers"]):
        inj = None
        if inject_delta is not None:
            layer = (jnp.asarray(-1, jnp.int32) if inject_layer is None
                     else jnp.asarray(inject_layer, jnp.int32))
            inj = jnp.where(layer == i, jnp.asarray(inject_delta), 0.0)
        h, c = gat_layer(p, h, adj, cfg, inject=inj)
        checks.append(c)
        if i < n_layers - 1:
            h = jax.nn.elu(h)
    return h, checks


# ---------------------------------------------------------------------------
# guarded serving
# ---------------------------------------------------------------------------

def make_gat_serve_step(cfg: ABFTConfig) -> Callable:
    """Jitted ``step(params, h, adj, inject_layer=-1, inject_delta=0.0)
    -> (out, metrics)`` with per-op verdicts keyed ``gat{i}`` — the
    :meth:`ABFTGuard.run_step` metrics shape."""
    ids_box: dict = {"ids": ()}

    def _step(params, h, adj, inject_layer, inject_delta):
        out, checks = gat_forward(params, h, adj, cfg,
                                  inject_layer=inject_layer,
                                  inject_delta=inject_delta)
        rep = summarize([c for c in checks if c is not None], cfg)
        ids, op_flags, op_rel = per_op_report(checks, cfg, prefix="gat")
        ids_box["ids"] = ids
        return out, {"abft_flag": rep.flag, "abft_max_rel": rep.max_rel,
                     "abft_op_flags": op_flags, "abft_op_rel": op_rel}

    jitted = jax.jit(_step)

    def step(params, h, adj, inject_layer=-1, inject_delta=0.0):
        out, metrics = jitted(params, h, adj,
                              jnp.asarray(inject_layer, jnp.int32),
                              jnp.float32(inject_delta))
        metrics = dict(metrics)
        metrics["abft_op_ids"] = ids_box["ids"]
        return out, metrics

    step.traceable = jitted      # the string-free core, for abftlint traces
    step.ids_box = ids_box
    return step


class GATEngine:
    """Guarded GAT serving, mirroring :class:`~repro.engine.lm.LMEngine`:
    pristine master params host-side, folded working copy, and the
    restore→retry→suspect ladder with ``op:gat{i}`` sites."""

    def __init__(self, cfg: ABFTConfig, params: Params, *,
                 guard_cfg: Optional[GuardConfig] = None):
        self.cfg = cfg
        self._master = params
        self.params = fold_gat_w_r(params, cfg)
        self.guard = ABFTGuard(guard_cfg or GuardConfig(),
                               restore_fn=self._restore)
        self._step = make_gat_serve_step(cfg)

    @classmethod
    def init(cls, cfg: ABFTConfig, key, dims: Tuple[int, ...], **kw
             ) -> "GATEngine":
        return cls(cfg, init_gat(key, dims), **kw)

    def _restore(self) -> Params:
        self.params = fold_gat_w_r(self._master, self.cfg)
        return self.params

    def forward(self, h: Array, adj: Array, *, inject_layer: int = -1,
                inject_delta: float = 0.0) -> Tuple[Array, dict]:
        """One guarded forward.  An inject operand fires once (the
        transient-fault convention — retries re-execute clean)."""
        box = {"l": int(inject_layer), "d": float(inject_delta)}

        def step(params, h_, adj_):
            l, d = box["l"], box["d"]
            box["l"], box["d"] = -1, 0.0
            return self._step(params, h_, adj_, l, d)

        out, m = self.guard.run_step(step, self.params, h, adj)
        return out, m

    def stats(self) -> dict:
        s = {"steps": self.guard.steps, "flags": self.guard.flags,
             "retries": self.guard.retries, "restores": self.guard.restores,
             "flag_rate": self.guard.flag_rate}
        s.update(self.guard.repair_tiers())
        return s
