"""Unified GCN engine: one entry point, three aggregation backends.

This module is the ONE place where the paper's check algebra lives:

  * eq. (5): the extra column x_r = H w_r formed during the combination;
  * eq. (4)/(6): the fused corner comparison s_c H w_r vs e^T H_out e,
    produced by the backend's ``aggregate(x, x_r)``;
  * split baseline (eqs. 2–3): the per-matmul check of X = H W plus the
    same aggregation corner;
  * ReLU chain-breaking: checks are taken pre-activation; every layer is
    one linear chain, activations end it (paper §III);
  * report reduction: ``summarize`` / ``merge_reports`` from core.abft.

``core/abft.py`` / ``core/gcn.py`` / ``kernels/spmm_abft/ops.py`` keep
their historical entry points as thin shims over this engine.

    logits, report = gcn_apply(params, Graph(s, h0), cfg,
                               backend="block_ell",
                               partition=Partition(mesh, "graph"))
"""
from __future__ import annotations

import dataclasses
from typing import Any, List, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core.abft import (
    ABFTConfig,
    ABFTReport,
    Check,
    fold_w_r_tree,
    resolve_w_r,
    summarize,
)

from .backends import AggregationBackend, make_backend

Array = jax.Array
Params = Any


@dataclasses.dataclass
class Graph:
    """One graph as the engine consumes it.

    ``s`` is the normalized adjacency in any backend format (dense array,
    BCOO, or host-side BlockEll); ``h0`` the dense node features; ``s_c``
    the optional offline column checksum e^T S (precompute once per static
    graph — computed once and auto-stashed back here on the first
    ``gcn_forward`` call when absent).  Dense ``s``/``h0`` may carry
    leading batch axes (batched multi-graph serving).

    The auto-stash assumes a *static* graph: it is invalidated when ``s``
    is rebound to a new object or the checksum dtype changes, but cannot
    see in-place mutation of a numpy ``s`` — mutate-in-place callers must
    reset ``s_c = None`` (or build a fresh Graph) themselves.
    """

    s: Any
    h0: Array
    s_c: Optional[Array] = None

    @property
    def n(self) -> int:
        return int(self.h0.shape[-2])


# The per-layer right-checksum resolution (fold validation) is op-generic
# and lives in core/abft.py now — kept under the historical name for the
# localize/streaming callers that import it from here.
_resolve_w_r = resolve_w_r


def gcn_layer(bk: AggregationBackend, h: Array, w: Array, cfg: ABFTConfig,
              *, w_r: Optional[Array] = None, return_x: bool = False
              ) -> Tuple[Array, List[Check]]:
    """One pre-activation GCN layer H_out = S (H W) under ABFT policy.

    The canonical eq. 4–6 algebra: ``w_r = W e`` (offline in deployment —
    pass it in to fold at weight-load time), the eq.-5 column
    ``x_r = H w_r`` taken from the *independent* path (never from row-sums
    of the computed X: a fault in X would cancel), and the backend's fused
    corner check.  ``fused`` emits that single check; ``split`` adds the
    combination-matmul check (eq. 2–3 baseline); ``none`` emits nothing.

    Backends with a whole-layer hook (:meth:`AggregationBackend.layer` —
    the block-ELL backend's single-pass fused kernel) take the fused/none
    modes without ever materializing X; the split baseline needs X for its
    combination check, so it always runs the generic two-pass path below.

    A passed-in ``w_r`` must have been folded at this config's checksum
    dtype: consuming a stale fold verbatim would silently run every check
    at the old precision, so a mismatch raises instead.

    ``return_x=True`` appends the materialized combination output X to the
    result — ``None`` when the backend's fused layer hook ran (X never
    existed).  The stripe-surgical repair uses the stashed X to replay a
    two-pass layer's aggregation bit-for-bit.
    """
    w_r = _resolve_w_r(w, w_r, cfg)
    if cfg.mode != "split":
        fused = bk.layer(h, w, cfg, w_r=w_r)
        if fused is not NotImplemented:
            h_out, chk = fused
            checks = [] if chk is None else [chk]
            return (h_out, checks, None) if return_x else (h_out, checks)
    x = h @ w
    if not cfg.enabled:
        h_out, _ = bk.aggregate(x, None)
        return (h_out, [], x) if return_x else (h_out, [])
    x_r = h.astype(cfg.dtype) @ w_r
    h_out, chk = bk.aggregate(x, x_r)
    if cfg.mode == "split":
        # the backend owns the split check's granularity: generic
        # check_matmul scalars, or per-graph corners on the packed path
        checks = [bk.combination_check(h, w, x, cfg, w_r=w_r), chk]
    else:
        checks = [chk]
    return (h_out, checks, x) if return_x else (h_out, checks)


def fold_w_r(params: Params, cfg: ABFTConfig) -> Params:
    """Fold the per-layer right checksum w_r = W·e into the params, once,
    at weight-load time (the paper's "offline" eq.-5 convention).

    Without the fold :func:`gcn_forward` recomputes ``row_checksum(w)``
    every layer every step; with it, each layer carries a ``w_r`` entry in
    ``cfg.dtype`` that the layer math consumes verbatim — bitwise-identical
    checks, zero per-step recompute.  Re-fold after any weight update (or
    if ``cfg.dtype`` changes).

    Delegates to the tree-generic :func:`repro.core.abft.fold_w_r_tree`:
    any params pytree folds (GCN ``{"layers": [...]}``, transformer trees,
    GAT layers) — every dict with a ``"w"`` weight gains its ``"w_r"``.
    """
    return fold_w_r_tree(params, cfg)


def gcn_forward(params: Params, graph: Graph, cfg: ABFTConfig, *,
                backend=None, partition=None, return_intermediates=False,
                return_x=False, **backend_opts) -> Tuple[Array, List[Check]]:
    """Forward pass through all layers; returns (logits, per-layer checks).

    The backend is constructed once per call (s_c staged/computed once,
    shared by every layer) — or passed in as an already-built
    :class:`AggregationBackend` instance (the jitted packed serving step
    builds one from traced arrays).  For the fused/none check modes the
    backend's whole-network hook (:meth:`AggregationBackend.network`) is
    consulted first — the block-ELL backend's ``fused_network`` option
    runs every layer in one kernel sweep with the activations resident in
    VMEM; on ``NotImplemented`` the per-layer loop below runs (which in
    turn consults the per-layer hook).  ReLU between layers breaks the
    checksum chain, so each layer carries its own check — the paper's
    per-layer fused granularity — on both paths.  Layers carrying a
    folded ``w_r`` (:func:`fold_w_r`) skip the per-step row_checksum
    recompute.

    ``return_intermediates=True`` appends a result: the tuple of every
    layer's *input* activations (h_layers[0] is h0, h_layers[l] the
    post-ReLU input to layer l) — from the loop for free, or stashed by
    the whole-network kernel (one extra write per layer, never re-read).
    The stripe-surgical retry consumes these to re-execute a flagged
    layer's stripes from the exact operands the faulted pass read.
    ``return_x=True`` appends one more: the tuple of per-layer
    combination outputs X (``None`` for layers a fused hook ran), letting
    the repair replay a two-pass layer's aggregation bit-for-bit.
    """
    if isinstance(backend, AggregationBackend):
        bk = backend
    else:
        s_c = graph.s_c
        if s_c is not None and getattr(graph, "_s_c_auto", False) and (
                getattr(graph, "_s_c_dtype", None) != cfg.dtype
                or getattr(graph, "_s_c_src", None) is not graph.s):
            # an auto-stash from an earlier call under a different checksum
            # dtype, or for a since-replaced adjacency operand: reusing it
            # would run this call's checks at a stale precision / against a
            # stale e^T S.  User-provided s_c is trusted verbatim.  (The
            # dtype key is the REQUESTED cfg.dtype, not the realized array
            # dtype, so x64-disabled f64 requests still cache.)
            s_c = None
        bk = make_backend(graph.s, cfg, backend=backend, s_c=s_c,
                          partition=partition, **backend_opts)
        if s_c is None:
            # stash the backend's (possibly O(nnz)-computed) column checksum
            # back on the graph: repeated gcn_apply/gcn_forward calls on the
            # same staged Graph reuse it instead of recomputing every call
            stashed = getattr(bk, "s_c", None)
            graph.s_c = stashed
            graph._s_c_auto = stashed is not None
            graph._s_c_dtype = cfg.dtype
            graph._s_c_src = graph.s
    h = graph.h0
    layers = params["layers"]
    wrs: Optional[List[Optional[Array]]] = None
    if cfg.mode != "split":
        wrs = [_resolve_w_r(layer["w"], layer.get("w_r"), cfg)
               for layer in layers]
        net = bk.network(h, [layer["w"] for layer in layers], wrs, cfg,
                         stash=return_intermediates)
        if net is not NotImplemented:
            logits, layer_checks, net_h_layers = net
            checks = [c for c in layer_checks if c is not None]
            xs = (None,) * len(layers)
            if return_intermediates:
                return ((logits, checks, net_h_layers, xs) if return_x
                        else (logits, checks, net_h_layers))
            return (logits, checks, xs) if return_x else (logits, checks)
    checks = []
    h_layers: List[Array] = []
    x_layers: List[Optional[Array]] = []
    for i, layer in enumerate(layers):
        h_layers.append(h)
        w_r = wrs[i] if wrs is not None else layer.get("w_r")
        h_out, cs, x = gcn_layer(bk, h, layer["w"], cfg, w_r=w_r,
                                 return_x=True)
        checks.extend(cs)
        x_layers.append(x)
        h = jax.nn.relu(h_out) if i < len(layers) - 1 else h_out
    if return_intermediates:
        return ((h, checks, tuple(h_layers), tuple(x_layers)) if return_x
                else (h, checks, tuple(h_layers)))
    return (h, checks, tuple(x_layers)) if return_x else (h, checks)


def gcn_apply(params: Params, graph: Graph, cfg: ABFTConfig, *,
              backend: Optional[str] = None, partition=None,
              **backend_opts) -> Tuple[Array, ABFTReport]:
    """The engine entry point: logits + one replicated ABFTReport.

    ``backend`` is ``"dense" | "bcoo" | "block_ell"`` (inferred from the
    adjacency operand when omitted); ``partition`` a
    :class:`~repro.engine.sharded.Partition` for stripe-sharded block-ELL
    aggregation (per-shard partial checks psum into this same report).
    """
    logits, checks = gcn_forward(params, graph, cfg, backend=backend,
                                 partition=partition, **backend_opts)
    return logits, summarize(checks, cfg)
