"""Batching of variable-size graphs for serving: dense buckets + packed
block-diagonal block-ELL.

Serving traffic is many small-to-medium graphs of *different* sizes; jit
wants fixed shapes.  Two strategies live here:

* **Dense bucketing** (:func:`make_batches`): round every graph up to the
  smallest configured bucket that fits, stack same-bucket graphs into
  [B, N, N] / [B, N, F] dense batches, and let one jitted engine step per
  (bucket, batch) shape serve the whole stream — O(B·N²·F) per bucket
  regardless of sparsity.

* **Block-diagonal packing** (:func:`pack_graphs` /
  :func:`make_packed_batches`): compose a batch of graphs into ONE packed
  block-ELL system — each graph's rows round up only to the block size, its
  row-stripes stack, and its column-block indices shift by its stripe
  offset, so the batch is exactly the block-diagonal matrix
  diag(S_1, …, S_G).  Aggregation then runs through the spmm_abft Pallas
  kernel and costs O(nnz tiles), not O(B·N²); the kernel's per-stripe
  checksum partials segment-sum into *per-graph* eq.-6 corners
  (``kernels.spmm_abft.ops.spmm_abft_packed``), so a flagged batch retries
  only the flagged graphs.

Zero-padding is exact for both the math and the check in both layouts:
padded node rows of S and H0 are all-zero, so they contribute zero to every
matmul, to the eq.-5 column, and to both sides of the checksum — padded
slots can never flag.
"""
from __future__ import annotations

import dataclasses
from typing import Iterable, List, Optional, Sequence, Tuple

import numpy as np

from repro.kernels.spmm_abft.layout import (
    BlockEll,
    dense_to_block_ell,
    pad_block_rows,
    pad_block_rows_to,
    pad_width,
    stack_block_ell,
)


@dataclasses.dataclass
class GraphBatch:
    """Fixed-shape batch of padded graphs (host-side numpy)."""

    s: np.ndarray         # [B, N, N] zero-padded normalized adjacencies
    h0: np.ndarray        # [B, N, F]
    n_nodes: np.ndarray   # [B] logical (unpadded) node counts; 0 = pad slot
    bucket: int           # N
    indices: Optional[np.ndarray] = None  # [B] stream position; -1 = pad slot

    @property
    def n_graphs(self) -> int:
        """Real graphs in the batch (excludes all-zero pad slots)."""
        return int((self.n_nodes > 0).sum())


@dataclasses.dataclass
class PackedGraphs:
    """One block-diagonal packed batch of variable-size graphs.

    ``bell`` is the packed block-ELL system diag(S_1, …, S_G) with every
    graph padded to a whole number of square blocks; ``stripe_graph`` maps
    each row-stripe to its graph slot (padding stripes from
    ``pad_block_rows`` carry id ``n_slots`` — the overflow segment the
    kernel epilogue drops); ``h0`` stacks the node features at each graph's
    padded row offset.  ``items`` keeps the source (S, H0) pairs so a
    flagged graph can be re-packed and retried alone.
    """

    bell: BlockEll
    stripe_graph: np.ndarray   # [n_block_rows] int32 graph slot per stripe
    h0: np.ndarray             # [padded_rows, F] stacked features
    n_nodes: np.ndarray        # [n_slots] logical node counts; 0 = empty slot
    row_offsets: np.ndarray    # [n_slots] first padded row of each graph
    indices: Optional[np.ndarray] = None  # [n_slots] stream position; -1 pad
    items: Optional[List[Tuple[np.ndarray, np.ndarray]]] = \
        dataclasses.field(default=None, repr=False)
    # shape-quantization knobs this batch was packed with — retries re-pack
    # subsets with the SAME knobs so sub-pack shapes hit the jit cache
    stripe_multiple: int = 1
    width_multiple: int = 1

    @property
    def n_slots(self) -> int:
        return int(self.n_nodes.shape[0])

    @property
    def n_graphs(self) -> int:
        return int((self.n_nodes > 0).sum())

    @property
    def block(self) -> int:
        return self.bell.block_m


def pick_bucket(n: int, buckets: Sequence[int]) -> int:
    """Smallest bucket >= n; raises if the graph outgrows every bucket."""
    for b in sorted(buckets):
        if n <= b:
            return b
    raise ValueError(f"graph with {n} nodes exceeds largest bucket "
                     f"{max(buckets)}")


def pad_graph(s: np.ndarray, h0: np.ndarray, n_to: int
              ) -> Tuple[np.ndarray, np.ndarray]:
    """Zero-pad one dense (S, H0) pair to ``n_to`` nodes, keeping dtypes —
    bf16 features and f64 reference streams must survive batching."""
    n = s.shape[0]
    if n > n_to:
        raise ValueError(f"cannot pad {n} nodes down to {n_to}")
    sp = np.zeros((n_to, n_to), s.dtype)
    sp[:n, :n] = s
    hp = np.zeros((n_to, h0.shape[1]), h0.dtype)
    hp[:n] = h0
    return sp, hp


def _validate_feat_dims(graphs: Sequence[Tuple[np.ndarray, np.ndarray]]):
    """All graphs feed one model: feature dims must agree.  Raise up front
    with the offending stream position instead of dying in a buffer
    assignment deep inside batching."""
    if not graphs:
        return
    feat = graphs[0][1].shape[1]
    for gi, (_, h0) in enumerate(graphs):
        if h0.shape[1] != feat:
            raise ValueError(
                f"graph {gi} has feature dim {h0.shape[1]} but graph 0 has "
                f"{feat}; all graphs in one stream must share the model's "
                f"input feature dim")


def make_batches(graphs: Iterable[Tuple[np.ndarray, np.ndarray]],
                 batch_size: int, buckets: Sequence[int]
                 ) -> List[GraphBatch]:
    """Group (S, H0) pairs by bucket and stack into fixed-shape batches.

    Partial batches are padded with empty (all-zero) slots so every batch
    of a given bucket has the same [batch_size, N, ...] shape — one XLA
    compile per bucket, not per residue.  Buffer dtypes are the numpy
    promotion of the inputs' dtypes (f32 in, f32 out; f64 in, f64 out).
    """
    graphs = list(graphs)
    _validate_feat_dims(graphs)
    by_bucket: dict = {}
    for gi, (s, h0) in enumerate(graphs):
        b = pick_bucket(s.shape[0], buckets)
        by_bucket.setdefault(b, []).append((gi, s, h0))
    out: List[GraphBatch] = []
    for b in sorted(by_bucket):
        items = by_bucket[b]
        feat = items[0][2].shape[1]
        s_dt = np.result_type(*[s.dtype for _, s, _ in items])
        h_dt = np.result_type(*[h.dtype for _, _, h in items])
        for lo in range(0, len(items), batch_size):
            chunk = items[lo:lo + batch_size]
            sb = np.zeros((batch_size, b, b), s_dt)
            hb = np.zeros((batch_size, b, feat), h_dt)
            nn = np.zeros(batch_size, np.int64)
            idx = np.full(batch_size, -1, np.int64)
            for i, (gi, s, h0) in enumerate(chunk):
                sb[i], hb[i] = pad_graph(s, h0, b)
                nn[i] = s.shape[0]
                idx[i] = gi
            out.append(GraphBatch(s=sb, h0=hb, n_nodes=nn, bucket=b,
                                  indices=idx))
    return out


def graph_pack_stats(s: np.ndarray, block: int) -> Tuple[int, int]:
    """(stripe count, block-ELL width) one graph contributes to a packed
    batch, computed from the nonzero pattern without building the tile
    table — the online packer calls this per request to fit a capacity
    rung, so it must be cheap."""
    s = np.asarray(s)
    n = s.shape[0]
    stripes = -(-n // block)
    r, c = np.nonzero(s)
    if r.size == 0:
        return stripes, 1
    tiles = np.unique(np.stack([r // block, c // block], axis=1), axis=0)
    width = int(np.bincount(tiles[:, 0], minlength=stripes).max())
    return stripes, max(width, 1)


def pack_graphs(graphs: Sequence[Tuple[np.ndarray, np.ndarray]],
                *, block: int = 32, n_slots: Optional[int] = None,
                stripe_multiple: int = 1, width_multiple: int = 1,
                stripe_cap: Optional[int] = None,
                width_cap: Optional[int] = None,
                indices: Optional[Sequence[int]] = None) -> PackedGraphs:
    """Compose (S, H0) pairs into one block-diagonal packed block-ELL batch.

    Each graph pads only to the next ``block`` multiple (not to a power-of-2
    bucket), converts to block-ELL, and stacks: row-stripes concatenate and
    column-block indices shift by the graph's stripe offset, yielding
    exactly diag(S_1, …, S_G).  ``n_slots`` pads the *graph* count with
    empty slots (zero stripes — their check corner is 0 = 0, never flags)
    and ``stripe_multiple``/``width_multiple`` quantize the stripe count
    (via ``pad_block_rows``) and tile width, so ragged traffic maps to few
    distinct jit shapes.

    ``stripe_cap``/``width_cap`` go further and pin the stripe count and
    ELL width to EXACT values — the canonical-rung contract of the
    streaming engine: every batch packed against the same rung presents
    one jit shape no matter which graphs landed in it.  Raises when the
    contents genuinely exceed a cap (the engine checks fit *before*
    admitting a graph to a rung's open bin).
    """
    if not graphs:
        raise ValueError("pack_graphs needs at least one graph")
    _validate_feat_dims(graphs)
    n_slots = len(graphs) if n_slots is None else n_slots
    if n_slots < len(graphs):
        raise ValueError(f"n_slots={n_slots} < {len(graphs)} graphs")
    feat = graphs[0][1].shape[1]
    h_dt = np.result_type(*[h.dtype for _, h in graphs])

    bells, offsets, stripe_graph = [], [], []
    n_nodes = np.zeros(n_slots, np.int64)
    row_offsets = np.zeros(n_slots, np.int64)
    off = 0  # running stripe offset == column-block offset (square blocks)
    for g, (s, _) in enumerate(graphs):
        bell_g = dense_to_block_ell(np.asarray(s), block_m=block,  # abftlint: sync-ok (host numpy packing, not device data)
                                    block_k=block)
        bells.append(bell_g)
        offsets.append(off)
        stripe_graph.extend([g] * bell_g.n_block_rows)
        n_nodes[g] = s.shape[0]
        row_offsets[g] = off * block
        off += bell_g.n_block_rows

    total_rows = off * block
    bell = stack_block_ell(bells, offsets, shape=(total_rows, total_rows),
                           width_multiple=width_multiple)
    bell = pad_block_rows(bell, stripe_multiple)
    if stripe_cap is not None:
        bell = pad_block_rows_to(bell, stripe_cap)
    if width_cap is not None:
        bell = pad_width(bell, width_cap)
    stripe_graph = np.asarray(stripe_graph, np.int32)
    if bell.n_block_rows > stripe_graph.shape[0]:
        # pad stripes land in the overflow segment (id n_slots), which the
        # segmented epilogue computes and drops
        pad = np.full(bell.n_block_rows - stripe_graph.shape[0], n_slots,
                      np.int32)
        stripe_graph = np.concatenate([stripe_graph, pad])

    h0 = np.zeros((bell.padded_rows, feat), h_dt)
    for g, (_, h) in enumerate(graphs):
        h0[row_offsets[g]:row_offsets[g] + n_nodes[g]] = h

    idx = np.full(n_slots, -1, np.int64)
    if indices is not None:
        idx[:len(graphs)] = np.asarray(indices, np.int64)
    return PackedGraphs(bell=bell, stripe_graph=stripe_graph, h0=h0,
                        n_nodes=n_nodes, row_offsets=row_offsets,
                        indices=idx, items=list(graphs),
                        stripe_multiple=stripe_multiple,
                        width_multiple=width_multiple)


def schedule_packs(stripes: Sequence[int], batch_size: int,
                   stripe_multiple: int = 1) -> List[List[int]]:
    """Size-aware pack scheduling: first-fit-decreasing bin-packing of graph
    indices by stripe count into ``ceil(n / batch_size)`` bins of at most
    ``batch_size`` graphs each.

    Arrival-order chunking makes each batch's stripe total (and therefore
    its padded kernel shape) track whatever sizes happened to arrive
    together — a ragged stream yields many distinct jit shapes and batches
    far above the mean pay ELL/slot padding for their widest member.  FFD
    instead fills every bin toward the same stripe capacity (the mean,
    rounded up to ``stripe_multiple`` — the shape quantum), which equalizes
    packed shapes across batches and cuts padding waste.  Graphs that fit
    no bin under the capacity spill into the currently-emptiest bin, so the
    schedule always places every graph.  Returns the per-bin index lists
    (deterministic: sizes tie-break by arrival position).
    """
    n = len(stripes)
    if n == 0:
        return []
    n_bins = -(-n // batch_size)
    q = max(stripe_multiple, 1)
    mean_up = -(-sum(stripes) // n_bins)
    cap = -(-mean_up // q) * q
    order = sorted(range(n), key=lambda i: (-stripes[i], i))
    bins: List[List[int]] = [[] for _ in range(n_bins)]
    load = [0] * n_bins
    for gi in order:
        placed = next((b for b in range(n_bins)
                       if len(bins[b]) < batch_size
                       and load[b] + stripes[gi] <= cap), None)
        if placed is None:  # doesn't fit anywhere: emptiest open bin
            placed = min((b for b in range(n_bins)
                          if len(bins[b]) < batch_size),
                         key=lambda b: (load[b], b))
        bins[placed].append(gi)
        load[placed] += stripes[gi]
    return [b for b in bins if b]


def make_packed_batches(graphs: Iterable[Tuple[np.ndarray, np.ndarray]],
                        batch_size: int, *, block: int = 32,
                        stripe_multiple: int = 1, width_multiple: int = 1,
                        schedule: str = "size") -> List[PackedGraphs]:
    """Chunk a stream into block-diagonal packed batches of ``batch_size``
    graph slots.  Every batch has exactly ``batch_size`` slots so the
    segmented check shape is fixed; stripe/width quantization bounds the
    number of distinct kernel shapes.

    ``schedule="size"`` (default) bin-packs graphs by stripe count with
    first-fit-decreasing (:func:`schedule_packs`) to equalize packed shapes
    across batches; ``"arrival"`` keeps plain stream-order chunking.
    Stream-order per-graph verdicts are preserved either way through each
    batch's ``indices``.
    """
    graphs = list(graphs)
    _validate_feat_dims(graphs)
    if schedule not in ("size", "arrival"):
        raise ValueError(f"schedule {schedule!r} not in ('size', 'arrival')")
    if schedule == "size":
        stripes = [-(-s.shape[0] // block) for s, _ in graphs]
        groups = schedule_packs(stripes, batch_size, stripe_multiple)
    else:
        groups = [list(range(lo, min(lo + batch_size, len(graphs))))
                  for lo in range(0, len(graphs), batch_size)]
    out: List[PackedGraphs] = []
    for idx in groups:
        out.append(pack_graphs(
            [graphs[i] for i in idx], block=block, n_slots=batch_size,
            stripe_multiple=stripe_multiple, width_multiple=width_multiple,
            indices=idx))
    return out


def synth_graph_stream(n_graphs: int, *, n_lo: int = 24, n_hi: int = 120,
                       feat: int = 16, avg_deg: int = 4, seed: int = 0
                       ) -> List[Tuple[np.ndarray, np.ndarray]]:
    """Deterministic stream of variable-size (S, H0) pairs for smoke runs."""
    from repro.core.gcn import normalized_adjacency_dense

    rng = np.random.default_rng(seed)
    out = []
    for _ in range(n_graphs):
        n = int(rng.integers(n_lo, n_hi + 1))  # abftlint: sync-ok (host RNG)
        m = max(n * avg_deg // 2, 1)
        e = rng.integers(0, n, size=(3 * m + 16, 2), dtype=np.int64)
        e = e[e[:, 0] != e[:, 1]]
        e = np.unique(np.sort(e, axis=1), axis=0)[:m]
        s = normalized_adjacency_dense(e, n)
        h0 = rng.normal(0, 0.5, size=(n, feat)).astype(np.float32)
        out.append((s, h0))
    return out
