"""Bucketed padding + batching of variable-size graphs for serving.

Serving traffic is many small-to-medium graphs of *different* sizes; jit
wants fixed shapes.  The classic bucketing compromise: round every graph up
to the smallest configured bucket that fits, stack same-bucket graphs into
[B, N, N] / [B, N, F] dense batches, and let one jitted engine step per
(bucket, batch) shape serve the whole stream.

Zero-padding is exact for both the math and the check: padded node rows of
S and H0 are all-zero, so they contribute zero to every matmul, to the
eq.-5 column, and to both sides of the checksum — padded slots can never
flag.  The batched dense backend then yields per-graph batched scalar
checks that ``summarize`` reduces to the step's single replicated report.
"""
from __future__ import annotations

import dataclasses
from typing import Iterable, List, Sequence, Tuple

import numpy as np


@dataclasses.dataclass
class GraphBatch:
    """Fixed-shape batch of padded graphs (host-side numpy)."""

    s: np.ndarray         # [B, N, N] zero-padded normalized adjacencies
    h0: np.ndarray        # [B, N, F]
    n_nodes: np.ndarray   # [B] logical (unpadded) node counts; 0 = pad slot
    bucket: int           # N

    @property
    def n_graphs(self) -> int:
        """Real graphs in the batch (excludes all-zero pad slots)."""
        return int((self.n_nodes > 0).sum())


def pick_bucket(n: int, buckets: Sequence[int]) -> int:
    """Smallest bucket >= n; raises if the graph outgrows every bucket."""
    for b in sorted(buckets):
        if n <= b:
            return b
    raise ValueError(f"graph with {n} nodes exceeds largest bucket "
                     f"{max(buckets)}")


def pad_graph(s: np.ndarray, h0: np.ndarray, n_to: int
              ) -> Tuple[np.ndarray, np.ndarray]:
    """Zero-pad one dense (S, H0) pair to ``n_to`` nodes."""
    n = s.shape[0]
    if n > n_to:
        raise ValueError(f"cannot pad {n} nodes down to {n_to}")
    sp = np.zeros((n_to, n_to), np.float32)
    sp[:n, :n] = s
    hp = np.zeros((n_to, h0.shape[1]), np.float32)
    hp[:n] = h0
    return sp, hp


def make_batches(graphs: Iterable[Tuple[np.ndarray, np.ndarray]],
                 batch_size: int, buckets: Sequence[int]
                 ) -> List[GraphBatch]:
    """Group (S, H0) pairs by bucket and stack into fixed-shape batches.

    Partial batches are padded with empty (all-zero) slots so every batch
    of a given bucket has the same [batch_size, N, ...] shape — one XLA
    compile per bucket, not per residue.
    """
    by_bucket: dict = {}
    for s, h0 in graphs:
        b = pick_bucket(s.shape[0], buckets)
        by_bucket.setdefault(b, []).append((s, h0))
    out: List[GraphBatch] = []
    for b in sorted(by_bucket):
        items = by_bucket[b]
        feat = items[0][1].shape[1]
        for lo in range(0, len(items), batch_size):
            chunk = items[lo:lo + batch_size]
            sb = np.zeros((batch_size, b, b), np.float32)
            hb = np.zeros((batch_size, b, feat), np.float32)
            nn = np.zeros(batch_size, np.int64)
            for i, (s, h0) in enumerate(chunk):
                sb[i], hb[i] = pad_graph(s, h0, b)
                nn[i] = s.shape[0]
            out.append(GraphBatch(s=sb, h0=hb, n_nodes=nn, bucket=b))
    return out


def synth_graph_stream(n_graphs: int, *, n_lo: int = 24, n_hi: int = 120,
                       feat: int = 16, avg_deg: int = 4, seed: int = 0
                       ) -> List[Tuple[np.ndarray, np.ndarray]]:
    """Deterministic stream of variable-size (S, H0) pairs for smoke runs."""
    from repro.core.gcn import normalized_adjacency_dense

    rng = np.random.default_rng(seed)
    out = []
    for _ in range(n_graphs):
        n = int(rng.integers(n_lo, n_hi + 1))
        m = max(n * avg_deg // 2, 1)
        e = rng.integers(0, n, size=(3 * m + 16, 2), dtype=np.int64)
        e = e[e[:, 0] != e[:, 1]]
        e = np.unique(np.sort(e, axis=1), axis=0)[:m]
        s = normalized_adjacency_dense(e, n)
        h0 = rng.normal(0, 0.5, size=(n, feat)).astype(np.float32)
        out.append((s, h0))
    return out
