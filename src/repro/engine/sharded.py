"""Sharded block-ELL aggregation: row-stripes over a mesh axis via shard_map.

The checksum is linear, so sharding the aggregation shards the check: each
device owns a contiguous slab of block-ELL row-stripes and computes

    out_local   = S_local @ X          (X replicated: column blocks of any
                                        stripe may reference any X row)
    pred_local  = Σ S_local x_r        (the carried eq.-5 column)
    actual_local= Σ out_local

and a single ``lax.psum`` over the graph axis turns the per-shard partials
into exactly the global eq.-6 comparison — the same scalar the single-device
kernel produces, because Σ over shards commutes with Σ over rows.  The
report stays replicated; the output rows stay sharded (P(axis) on stripes).

Requires ``n_block_rows % n_shards == 0``; the block-ELL backend pads with
all-zero stripes (``pad_block_rows``) before staging, which contribute
nothing to either side of the check.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import jax
from jax.experimental.shard_map import shard_map
from jax.sharding import Mesh

from repro.core.abft import Check

Array = jax.Array


@dataclasses.dataclass(frozen=True)
class Partition:
    """Where the graph's row-stripes live: one mesh axis."""

    mesh: Mesh
    axis: str = "graph"

    def __post_init__(self):
        if self.axis not in self.mesh.axis_names:
            raise ValueError(f"axis {self.axis!r} not in mesh axes "
                             f"{self.mesh.axis_names}")

    @property
    def n_shards(self) -> int:
        return self.mesh.shape[self.axis]


def _check_specs(rules, granularity: str):
    """Check-partial out_specs: psum'd scalars stay replicated; stripe
    corners stay sharded on the stripe axis and concatenate globally."""
    spec = rules.stripe_report_spec() if granularity == "stripe" \
        else rules.report_spec()
    return (rules.out_spec(), spec, spec)


def sharded_spmm_abft(bell, cols: Array, vals: Array, x: Array,
                      xr: Optional[Array], partition: Partition, *,
                      block_g: int = 128, interpret: bool = False,
                      granularity: str = "layer"
                      ) -> Tuple[Array, Optional[Check]]:
    """out = S @ X over stripe-sharded (cols, vals) with the psum'd check.

    ``cols``/``vals`` are the staged device arrays of ``bell`` (already
    padded so stripes divide the axis); ``x`` is [n, g] replicated; ``xr``
    the carried [n, 1] checksum column or None (check disabled).
    ``granularity="stripe"`` keeps each shard's per-stripe partials as
    corners: instead of psum-collapsing, the [nbm_local] vectors stay
    sharded on the stripe axis and *concatenate* into the global
    [n_block_rows] per-stripe check — exactly the single-device stripe
    corners, because each stripe lives on exactly one shard.
    Returns (out [n, g] row-sharded then trimmed, Check | None).
    """
    from repro.kernels.spmm_abft.kernel import spmm_abft_kernel
    from repro.kernels.spmm_abft.ops import prepare_operands, trim_output
    from repro.launch.mesh import GraphShardingRules

    g = x.shape[1]
    want_check = xr is not None
    xp, xrp = prepare_operands(bell, x, xr, block_g)

    axis = partition.axis
    rules = GraphShardingRules(partition.mesh, axis)

    def body(cols_l, vals_l, x_rep, xr_rep):
        out_l, sums_l, extra_l = spmm_abft_kernel(
            cols_l, vals_l, x_rep, xr_rep, interpret=interpret)
        if granularity == "stripe":
            nbm_l = sums_l.shape[0]
            return (out_l, extra_l[:, 0].reshape(nbm_l, -1).sum(axis=1),
                    sums_l[:, 0])
        pred = jax.lax.psum(extra_l.sum(), axis)
        actual = jax.lax.psum(sums_l.sum(), axis)
        return out_l, pred, actual

    shard = shard_map(
        body, mesh=partition.mesh,
        in_specs=(rules.stripe_spec(), rules.tile_spec(),
                  rules.activation_spec(), rules.activation_spec()),
        out_specs=_check_specs(rules, granularity),
        check_rep=False)  # pallas_call has no replication rule
    out, pred, actual = shard(cols, vals, xp, xrp)
    out = trim_output(bell, out, g)
    if not want_check:
        return out, None
    return out, Check(predicted=pred, actual=actual, granularity=granularity)


def sharded_gcn_fused(bell, cols: Array, vals: Array, h: Array, w: Array,
                      wr: Optional[Array], partition: Partition, *,
                      block_g: int = 128, interpret: bool = False,
                      granularity: str = "layer"
                      ) -> Tuple[Array, Optional[Check]]:
    """One whole GCN layer out = S (H W) over stripe-sharded (cols, vals)
    through the single-pass fused kernel, with the psum'd check.

    The fusion composes with the sharding unchanged: H, W, and w_r are
    replicated (any stripe's column blocks may reference any H row, and W
    is tiny), each shard sweeps its own stripes recomputing X tiles in
    VMEM, and the per-shard (predicted, actual) partials psum into the
    same global eq.-6 corner as the two-pass path — Σ over shards commutes
    with Σ over rows.  ``wr`` is the folded right checksum W·e (vector or
    column) or None (check disabled — the kernel statically elides the
    eq.-5 dots).  Returns (out [n, g] trimmed, Check | None).
    """
    from repro.kernels.gcn_fused.kernel import gcn_fused_kernel
    from repro.kernels.gcn_fused.ops import prepare_fused_operands
    from repro.kernels.spmm_abft.ops import trim_output
    from repro.launch.mesh import GraphShardingRules

    g = w.shape[1]
    want_check = wr is not None
    hp, wp, wrp = prepare_fused_operands(bell, h, w, wr, block_g)

    axis = partition.axis
    rules = GraphShardingRules(partition.mesh, axis)

    def body(cols_l, vals_l, h_rep, w_rep, wr_rep):
        out_l, sums_l, extra_l = gcn_fused_kernel(
            cols_l, vals_l, h_rep, w_rep, wr_rep, interpret=interpret,
            with_check=want_check)
        if granularity == "stripe":
            nbm_l = sums_l.shape[0]
            return (out_l, extra_l[:, 0].reshape(nbm_l, -1).sum(axis=1),
                    sums_l[:, 0])
        pred = jax.lax.psum(extra_l.sum(), axis)
        actual = jax.lax.psum(sums_l.sum(), axis)
        return out_l, pred, actual

    shard = shard_map(
        body, mesh=partition.mesh,
        in_specs=(rules.stripe_spec(), rules.tile_spec(),
                  rules.activation_spec(), rules.activation_spec(),
                  rules.activation_spec()),
        out_specs=_check_specs(rules, granularity),
        check_rep=False)  # pallas_call has no replication rule
    out, pred, actual = shard(cols, vals, hp, wp, wrp)
    out = trim_output(bell, out, g)
    if not want_check:
        return out, None
    return out, Check(predicted=pred, actual=actual, granularity=granularity)
