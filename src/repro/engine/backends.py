"""Aggregation backends for the unified GCN engine.

A backend owns exactly one thing: the aggregation matmul H_out = S @ X and
the eq.-6 corner of the fused check for that multiply.  Everything else —
the eq.-5 extra column x_r = H w_r, split-vs-fused policy, ReLU
chain-breaking, report reduction — lives once in ``engine/api.py``.

The protocol is deliberately narrow::

    aggregate(x, x_r) -> (h_out, Check | None)

``x`` is the combination output X = H W; ``x_r`` is the carried checksum
column H w_r (a [..., n]-vector, or ``None`` when checking is disabled).
When ``x_r`` is given, the returned :class:`~repro.core.abft.Check` holds
``predicted = s_c @ x_r`` (equivalently ``Σ S x_r`` — the kernel backend
never materializes s_c online) and ``actual = Σ H_out``.

Three built-in backends, selected by name or inferred from the operand:

  * ``dense``     — jnp matmul over a dense S; batched leading axes ok.
  * ``bcoo``      — ``jax.experimental.sparse`` BCOO aggregation with the
                    O(nnz) offline s_c (``sparse_col_checksum``).
  * ``block_ell`` — the Pallas spmm_abft kernel over a padded block-ELL
                    layout; the check rides the kernel's fused epilogue,
                    and a :class:`~repro.engine.sharded.Partition` shards
                    row-stripes across a mesh axis with psum'd partials.

New backends register with :func:`register_backend`; the registry is the
single dispatch point for ``gcn_apply(..., backend=...)``.
"""
from __future__ import annotations

from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core.abft import ABFTConfig, Check, _total
from repro.core.checksum import col_checksum

Array = jax.Array

_REGISTRY: Dict[str, Callable[..., "AggregationBackend"]] = {}


def register_backend(name: str):
    """Class decorator: make ``name`` resolvable by :func:`get_backend`."""
    def deco(cls):
        _REGISTRY[name] = cls
        cls.name = name
        return cls
    return deco


def get_backend(name: str) -> Callable[..., "AggregationBackend"]:
    if name not in _REGISTRY:
        raise ValueError(f"unknown engine backend {name!r}; "
                         f"registered: {sorted(_REGISTRY)}")
    return _REGISTRY[name]


def backend_names() -> Tuple[str, ...]:
    return tuple(sorted(_REGISTRY))


def infer_backend(s: Any) -> str:
    """Map an adjacency operand to its natural backend name."""
    from repro.kernels.spmm_abft.layout import BlockEll
    from jax.experimental import sparse as jsparse
    if isinstance(s, BlockEll):
        return "block_ell"
    if isinstance(s, jsparse.BCOO):
        return "bcoo"
    return "dense"


class AggregationBackend:
    """Protocol base; subclasses implement :meth:`aggregate`.

    Constructors take only the options they honour — an unknown or
    inapplicable keyword (``block_g`` on dense, a typo'd ``interpet``)
    raises TypeError instead of being silently dropped.
    """

    name = "abstract"

    def __init__(self, s: Any, cfg: ABFTConfig, *, s_c: Optional[Array] = None,
                 partition=None):
        raise NotImplementedError

    def aggregate(self, x: Array, x_r: Optional[Array]
                  ) -> Tuple[Array, Optional[Check]]:
        raise NotImplementedError


@register_backend("dense")
class DenseBackend(AggregationBackend):
    """S as a dense jnp array.  Leading batch axes broadcast: S [..., n, n]
    with X [..., n, g] yields batched scalar checks, which ``summarize``
    reduces — this is what batched multi-graph serving runs on."""

    def __init__(self, s: Array, cfg: ABFTConfig, *,
                 s_c: Optional[Array] = None, partition=None):
        if partition is not None:
            raise ValueError("dense backend does not support partition=; "
                             "use backend='block_ell'")
        self.s = jnp.asarray(s)
        self.cfg = cfg
        self.s_c = s_c if s_c is not None else (
            col_checksum(self.s, cfg.dtype) if cfg.enabled else None)

    def aggregate(self, x, x_r):
        h_out = jnp.matmul(self.s, x)
        if x_r is None:
            return h_out, None
        pred = jnp.einsum("...k,...k->...", self.s_c, x_r)
        return h_out, Check(predicted=pred, actual=_total(h_out, self.cfg))


@register_backend("bcoo")
class BcooBackend(AggregationBackend):
    """S as a jax.experimental.sparse BCOO; s_c is the O(nnz) offline
    segment-sum (``sparse_col_checksum``) shared across layers/steps."""

    def __init__(self, s: Any, cfg: ABFTConfig, *,
                 s_c: Optional[Array] = None, partition=None):
        if partition is not None:
            raise ValueError("bcoo backend does not support partition=; "
                             "use backend='block_ell'")
        from repro.core.abft import sparse_col_checksum
        self.s = s
        self.cfg = cfg
        self.s_c = s_c if s_c is not None else (
            sparse_col_checksum(s, cfg.dtype) if cfg.enabled else None)

    def aggregate(self, x, x_r):
        h_out = self.s @ x
        if x_r is None:
            return h_out, None
        pred = jnp.einsum("...k,...k->...", self.s_c, x_r)
        return h_out, Check(predicted=pred, actual=_total(h_out, self.cfg))


@register_backend("block_ell")
class BlockEllBackend(AggregationBackend):
    """S as a host-side padded block-ELL (``kernels/spmm_abft/layout.py``);
    aggregation runs through the Pallas spmm_abft kernel, whose fused
    epilogue carries the eq.-5 column so predicted = Σ S x_r = s_c H w_r
    without an online s_c pass.

    With ``partition=Partition(mesh, axis)`` the row-stripes shard across
    the mesh axis via shard_map; each shard contributes a partial
    (predicted, actual) pair that psums into the replicated global check —
    exactly the single-device eq.-6 scalar, because the checksum is linear.
    """

    def __init__(self, s: Any, cfg: ABFTConfig, *,
                 s_c: Optional[Array] = None, partition=None,
                 block_g: int = 128, interpret: Optional[bool] = None):
        from repro.kernels.spmm_abft.layout import BlockEll, pad_block_rows
        if not isinstance(s, BlockEll):
            raise TypeError("block_ell backend needs a BlockEll operand; "
                            "convert with dense_to_block_ell/coo_to_block_ell")
        self.cfg = cfg
        self.block_g = block_g
        self.partition = partition
        self.interpret = (jax.default_backend() != "tpu"
                          if interpret is None else interpret)
        if partition is not None:
            s = pad_block_rows(s, partition.n_shards)
        self.bell = s
        from repro.kernels.spmm_abft.ops import device_block_ell
        self.cols, self.vals = device_block_ell(s)

    def aggregate(self, x, x_r):
        if x.ndim != 2:
            raise ValueError("block_ell backend is single-graph ([n, g]); "
                             "batch via engine.batching or the dense backend")
        from repro.kernels.spmm_abft.ops import spmm_abft
        xr_col = None if x_r is None else x_r.astype(jnp.float32)[:, None]
        if self.partition is None:
            out, chk = spmm_abft(self.bell, x, xr_col, block_g=self.block_g,
                                 interpret=self.interpret,
                                 _staged=(self.cols, self.vals))
            return out, (chk if x_r is not None else None)
        from .sharded import sharded_spmm_abft
        return sharded_spmm_abft(
            self.bell, self.cols, self.vals, x, xr_col, self.partition,
            block_g=self.block_g, interpret=self.interpret)


def make_backend(s: Any, cfg: ABFTConfig, *, backend: Optional[str] = None,
                 s_c: Optional[Array] = None, partition=None,
                 **opts) -> AggregationBackend:
    """Resolve + construct the aggregation backend for operand ``s``."""
    name = backend or infer_backend(s)
    return get_backend(name)(s, cfg, s_c=s_c, partition=partition, **opts)
