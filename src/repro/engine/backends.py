"""Aggregation backends for the unified GCN engine.

A backend owns exactly one thing: the aggregation matmul H_out = S @ X and
the eq.-6 corner of the fused check for that multiply.  Everything else —
the eq.-5 extra column x_r = H w_r, split-vs-fused policy, ReLU
chain-breaking, report reduction — lives once in ``engine/api.py``.

The protocol is deliberately narrow::

    aggregate(x, x_r) -> (h_out, Check | None)

``x`` is the combination output X = H W; ``x_r`` is the carried checksum
column H w_r (a [..., n]-vector, or ``None`` when checking is disabled).
When ``x_r`` is given, the returned :class:`~repro.core.abft.Check` holds
``predicted = s_c @ x_r`` (equivalently ``Σ S x_r`` — the kernel backend
never materializes s_c online) and ``actual = Σ H_out``.

Three built-in backends, selected by name or inferred from the operand:

  * ``dense``     — jnp matmul over a dense S; batched leading axes ok.
  * ``bcoo``      — ``jax.experimental.sparse`` BCOO aggregation with the
                    O(nnz) offline s_c (``sparse_col_checksum``).
  * ``block_ell`` — the Pallas spmm_abft kernel over a padded block-ELL
                    layout; the check rides the kernel's fused epilogue,
                    and a :class:`~repro.engine.sharded.Partition` shards
                    row-stripes across a mesh axis with psum'd partials.

New backends register with :func:`register_backend`; the registry is the
single dispatch point for ``gcn_apply(..., backend=...)``.
"""
from __future__ import annotations

from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core.abft import (GRANULARITIES, ABFTConfig, Check, CheckedOp,
                             _total)
from repro.core.checksum import col_checksum
from repro.kernels.runtime import resolve_interpret

Array = jax.Array

_REGISTRY: Dict[str, Callable[..., "AggregationBackend"]] = {}


def _validate_granularity(name: str, granularity: str,
                          supported: Tuple[str, ...]) -> str:
    if granularity not in GRANULARITIES:
        raise ValueError(f"granularity {granularity!r} not in "
                         f"{GRANULARITIES}")
    if granularity not in supported:
        raise ValueError(
            f"{name} backend supports granularity in {supported}, not "
            f"{granularity!r}; stripe-granular corners need the block_ell "
            f"kernel path (per-row-stripe checksum partials)")
    return granularity


def register_backend(name: str):
    """Class decorator: make ``name`` resolvable by :func:`get_backend`."""
    def deco(cls):
        _REGISTRY[name] = cls
        cls.name = name
        return cls
    return deco


def get_backend(name: str) -> Callable[..., "AggregationBackend"]:
    if name not in _REGISTRY:
        raise ValueError(f"unknown engine backend {name!r}; "
                         f"registered: {sorted(_REGISTRY)}")
    return _REGISTRY[name]


def backend_names() -> Tuple[str, ...]:
    return tuple(sorted(_REGISTRY))


def infer_backend(s: Any) -> str:
    """Map an adjacency operand to its natural backend name."""
    from repro.kernels.spmm_abft.layout import BlockEll
    from repro.engine.batching import PackedGraphs
    from jax.experimental import sparse as jsparse
    if isinstance(s, (BlockEll, PackedGraphs)):
        return "block_ell"
    if isinstance(s, jsparse.BCOO):
        return "bcoo"
    return "dense"


class AggregationBackend(CheckedOp):
    """Protocol base; subclasses implement :meth:`aggregate`.

    An aggregation backend is a :class:`~repro.core.abft.CheckedOp`
    implementation: calling it runs one whole GCN layer under the engine's
    eq. 4–6 algebra —

        h_out, checks = bk(cfg, h, w, w_r=folded_w_r)

    — delegating to ``engine.gcn_layer`` (which in turn consults the
    backend's :meth:`layer`/:meth:`network` fusion hooks and
    :meth:`aggregate`).  Subclassers that only ever implemented
    ``aggregate`` keep working unchanged; the CheckedOp surface is additive.

    Constructors take only the options they honour — an unknown or
    inapplicable keyword (``block_g`` on dense, a typo'd ``interpet``)
    raises TypeError instead of being silently dropped.

    ``granularity`` declares what one element of the emitted Check
    attributes a fault to: ``"layer"`` (one scalar corner per linear
    chain — the paper's check), ``"graph"`` (one corner per packed /
    batched graph), or ``"stripe"`` (one corner per block-ELL row-stripe —
    fault localization; block_ell only).
    """

    name = "abstract"
    op_id = "gcn_layer"
    granularity = "layer"

    def __init__(self, s: Any, cfg: ABFTConfig, *, s_c: Optional[Array] = None,
                 partition=None):
        raise NotImplementedError

    def __call__(self, cfg: ABFTConfig, h: Array, w: Array, *,
                 w_r: Optional[Array] = None):
        """CheckedOp entry point: one pre-activation GCN layer
        ``H_out = S (H W)`` with its declared-granularity check(s)."""
        from .api import gcn_layer
        h_out, checks = gcn_layer(self, h, w, cfg, w_r=w_r)
        if not checks:
            return h_out, None
        return h_out, (checks[0] if len(checks) == 1 else checks)

    def aggregate(self, x: Array, x_r: Optional[Array]
                  ) -> Tuple[Array, Optional[Check]]:
        raise NotImplementedError

    def layer(self, h: Array, w: Array, cfg: ABFTConfig, *,
              w_r: Optional[Array] = None):
        """Whole-layer hook: execute H_out = S (H W) plus the eq. 4–6 check
        in one backend-fused step, returning (h_out, Check | None) — or
        ``NotImplemented`` to make the engine run the generic two-pass path
        (combination via XLA, then :meth:`aggregate`).

        Only consulted for the fused/none check modes: the split baseline
        (eqs. 2–3) checks the combination product X itself, and a layer
        that never materializes X has nothing for that check to read.

        The ``fused_hits``/``fused_fallbacks`` counters on implementing
        backends count *decisions*, taken eagerly or at trace time — a
        jitted step counts once per compile, not once per batch (the
        serving driver surfaces trace-time fallbacks eagerly instead).
        """
        return NotImplemented

    def network(self, h0: Array, ws, wrs, cfg: ABFTConfig, *,
                stash: bool = False):
        """Whole-network hook: execute EVERY layer — combination,
        aggregation, ReLU, and the next layer's combination — in one
        backend-fused sweep, returning ``(logits, [Check | None] per
        layer, h_layers | None)``, or ``NotImplemented`` to make the
        engine run its per-layer loop (which still consults
        :meth:`layer` for each).

        ``ws``/``wrs`` are the per-layer weights and folded eq.-5
        columns (``wrs`` all ``None`` when checking is off — the checks
        stay per-layer and pre-activation either way).  ``stash=True``
        asks for the per-layer input activations ``h_layers`` (the
        surgical-repair tiers replay from them); a backend that cannot
        export them must return ``NotImplemented`` rather than a
        ``None`` third element when stash is requested.

        Like :meth:`layer`, only consulted for the fused/none modes:
        the split baseline checks the combination product X itself,
        which whole-network fusion never materializes.
        """
        return NotImplemented

    def combination_check(self, h: Array, w: Array, x: Array,
                          cfg: ABFTConfig, *, w_r: Optional[Array] = None
                          ) -> Check:
        """Split-mode (eq. 2–3) check of the combination matmul x = h w.

        The default is the generic :func:`~repro.core.abft.check_matmul`;
        backends whose check granularity is finer than "one scalar per
        operand" (the packed block-diagonal batch) override it so the split
        check matches their aggregate corner's per-graph shape.
        """
        from repro.core.abft import check_matmul
        return check_matmul(h, w, x, cfg)


@register_backend("dense")
class DenseBackend(AggregationBackend):
    """S as a dense jnp array.  Leading batch axes broadcast: S [..., n, n]
    with X [..., n, g] yields batched scalar checks, which ``summarize``
    reduces — this is what batched multi-graph serving runs on."""

    def __init__(self, s: Array, cfg: ABFTConfig, *,
                 s_c: Optional[Array] = None, partition=None,
                 granularity: str = "layer"):
        if partition is not None:
            raise ValueError("dense backend does not support partition=; "
                             "use backend='block_ell'")
        # "graph" is what the batched leading axes already deliver (one
        # scalar corner per batch element); "stripe" has no meaning without
        # the block-ELL row-stripe partials.
        self.granularity = _validate_granularity("dense", granularity,
                                                 ("layer", "graph"))
        self.s = jnp.asarray(s)
        self.cfg = cfg
        self.s_c = s_c if s_c is not None else (
            col_checksum(self.s, cfg.dtype) if cfg.enabled else None)

    def aggregate(self, x, x_r):
        h_out = jnp.matmul(self.s, x)
        if x_r is None:
            return h_out, None
        pred = jnp.einsum("...k,...k->...", self.s_c, x_r)
        return h_out, Check(predicted=pred, actual=_total(h_out, self.cfg),
                            granularity=self.granularity)


@register_backend("bcoo")
class BcooBackend(AggregationBackend):
    """S as a jax.experimental.sparse BCOO; s_c is the O(nnz) offline
    segment-sum (``sparse_col_checksum``) shared across layers/steps."""

    def __init__(self, s: Any, cfg: ABFTConfig, *,
                 s_c: Optional[Array] = None, partition=None,
                 granularity: str = "layer"):
        if partition is not None:
            raise ValueError("bcoo backend does not support partition=; "
                             "use backend='block_ell'")
        self.granularity = _validate_granularity("bcoo", granularity,
                                                 ("layer",))
        from repro.core.abft import sparse_col_checksum
        self.s = s
        self.cfg = cfg
        self.s_c = s_c if s_c is not None else (
            sparse_col_checksum(s, cfg.dtype) if cfg.enabled else None)

    def aggregate(self, x, x_r):
        h_out = self.s @ x
        if x_r is None:
            return h_out, None
        pred = jnp.einsum("...k,...k->...", self.s_c, x_r)
        return h_out, Check(predicted=pred, actual=_total(h_out, self.cfg))


@register_backend("block_ell")
class BlockEllBackend(AggregationBackend):
    """S as a host-side padded block-ELL (``kernels/spmm_abft/layout.py``);
    aggregation runs through the Pallas spmm_abft kernel, whose fused
    epilogue carries the eq.-5 column so predicted = Σ S x_r = s_c H w_r
    without an online s_c pass.

    With ``partition=Partition(mesh, axis)`` the row-stripes shard across
    the mesh axis via shard_map; each shard contributes a partial
    (predicted, actual) pair that psums into the replicated global check —
    exactly the single-device eq.-6 scalar, because the checksum is linear.

    A :class:`~repro.engine.batching.PackedGraphs` operand (block-diagonal
    packed batch) routes through the segmented epilogue instead: the
    kernel's per-stripe checksum partials segment-sum into one eq.-6 corner
    *per packed graph*, so the Check fields are [n_slots] batched scalars
    and a fault in one graph flags only that graph's corner.

    ``fused_layer=True`` additionally activates the whole-layer hook
    (:meth:`layer`): fused/none-mode layers run through the single-pass
    ``kernels/gcn_fused`` kernel — combination, aggregation, and checksum
    in one HBM traversal — falling back to the two-pass path above when
    the layer's [f, g] working set exceeds ``vmem_budget``.

    ``fused_network=True`` activates the whole-network hook
    (:meth:`network`): an entire fused/none-mode forward runs through the
    ``gcn_network_kernel`` sweep — the activation matrix ping-pongs
    between two VMEM buffers and never touches HBM — falling back to the
    per-layer ladder (fused layer, then two-pass) when the depth-wide
    working set exceeds ``vmem_budget`` or the blocks are not square.
    ``network_hits``/``network_fallbacks`` count those decisions.

    ``granularity="stripe"`` declines every collapse: the kernels' per-
    row-stripe checksum partials stay individual corners ([n_block_rows]
    Check fields), so a detected fault names the stripe it corrupted and
    the guard's surgical retry re-executes only those rows.
    ``granularity="slot"`` refines below stripes on the fused kernel
    paths ([n_block_rows, width] telescope-difference corners naming the
    exact ell-slot); the two-pass fallback cannot split a stripe's sweep,
    so it degrades slot corners to stripe corners for that layer.
    Defaults to ``"graph"`` for packed batches and ``"layer"`` otherwise.

    ``inject=(layer, stripe, slot, delta)`` is the CI fault-injection
    hook: the given layer's aggregation sweep perturbs one accumulator
    element mid-flight, in whichever kernel runs that layer (whole-
    network, fused single-layer, or the two-pass spmm — all three carry
    the hook, so fallback paths are injectable too).
    """

    def __init__(self, s: Any, cfg: ABFTConfig, *,
                 s_c: Optional[Array] = None, partition=None,
                 block_g: int = 128, interpret: Optional[bool] = None,
                 fused_layer: bool = False,
                 fused_network: bool = False,
                 vmem_budget: Optional[int] = None,
                 granularity: Optional[str] = None,
                 inject: Optional[Tuple[int, int, int, float]] = None):
        from repro.kernels.spmm_abft.layout import BlockEll, pad_block_rows
        from repro.engine.batching import PackedGraphs
        self.cfg = cfg
        self.block_g = block_g
        self.partition = partition
        self.interpret = resolve_interpret(interpret)
        self.fused_layer = fused_layer
        self.fused_network = fused_network
        self.vmem_budget = vmem_budget
        self.fused_hits = 0
        self.fused_fallbacks = 0
        self.network_hits = 0
        self.network_fallbacks = 0
        self.segments = None
        self.n_slots = None
        packed = isinstance(s, PackedGraphs)
        self._set_granularity(granularity, packed=packed)
        self._set_inject(inject)
        if packed:
            if partition is not None:
                raise ValueError("packed block-diagonal batches do not "
                                 "support partition= (stripes already "
                                 "interleave graphs)")
            self.segments = jnp.asarray(s.stripe_graph)
            self.n_slots = s.n_slots
            s = s.bell
        elif not isinstance(s, BlockEll):
            raise TypeError("block_ell backend needs a BlockEll or "
                            "PackedGraphs operand; convert with "
                            "dense_to_block_ell/coo_to_block_ell or "
                            "engine.batching.pack_graphs")
        elif partition is not None:
            s = pad_block_rows(s, partition.n_shards)
        self.bell = s
        from repro.kernels.spmm_abft.ops import device_block_ell
        self.cols, self.vals = device_block_ell(s)

    def _set_granularity(self, granularity: Optional[str], *, packed: bool):
        if granularity is None:
            granularity = "graph" if packed else "layer"
        # packed batches must stay at least graph-attributable (the guard's
        # per-graph retry reads per-graph corners); single systems have no
        # graph segmentation to offer
        supported = (("graph", "stripe", "slot") if packed
                     else ("layer", "stripe", "slot"))
        if granularity == "slot" and self.partition is not None:
            raise ValueError(
                "granularity='slot' is not plumbed through the sharded "
                "path (sharded_gcn_fused collapses each shard's partials "
                "before the psum) — use granularity='stripe' there")
        self.granularity = _validate_granularity("block_ell", granularity,
                                                 supported)

    def _set_inject(self, inject):
        if inject is not None:
            if self.partition is not None:
                raise ValueError("inject= is not plumbed through the "
                                 "sharded path (sharded_gcn_fused runs the "
                                 "kernel without the hook) — injecting "
                                 "there would silently run clean")
            if len(inject) != 4:
                raise ValueError("inject is (layer, stripe, slot, delta); "
                                 f"got {inject!r}")
        self.inject = inject
        # which whole-layer call the injection lands in — advanced at trace
        # time, so a jitted step injects into the same layer every batch
        self._layer_calls = 0

    @classmethod
    def from_staged(cls, cols: Array, vals: Array, segments: Array,
                    n_slots: int, cfg: ABFTConfig, *, block_g: int = 128,
                    interpret: bool = False, fused_layer: bool = False,
                    fused_network: bool = False,
                    vmem_budget: Optional[int] = None,
                    granularity: Optional[str] = None,
                    inject: Optional[Tuple[int, int, int, float]] = None
                    ) -> "BlockEllBackend":
        """Packed backend over already-staged (possibly traced) arrays.

        This is the jit-friendly constructor for batched serving: a jitted
        step takes (cols, vals, segments, h0) as *arguments*, so batches of
        the same packed shape share one compile instead of baking each
        batch's tile table in as constants.
        """
        bk = cls.__new__(cls)
        bk.cfg = cfg
        bk.block_g = block_g
        bk.partition = None
        bk.interpret = interpret
        bk.fused_layer = fused_layer
        bk.fused_network = fused_network
        bk.vmem_budget = vmem_budget
        bk.fused_hits = 0
        bk.fused_fallbacks = 0
        bk.network_hits = 0
        bk.network_fallbacks = 0
        bk.bell = None
        bk.cols, bk.vals = cols, vals
        bk.segments = segments
        bk.n_slots = n_slots
        bk._set_granularity(granularity, packed=True)
        bk._set_inject(inject)
        return bk

    def layer(self, h, w, cfg, *, w_r=None):
        """Single-pass fused layer (``kernels/gcn_fused``): the combination
        H W is recomputed tile-by-tile inside the aggregation sweep with W
        and w_r VMEM-resident, so X never touches HBM.  Falls back to the
        engine's two-pass path (returns ``NotImplemented``) when the option
        is off or the layer's [f, g] working set exceeds the VMEM budget.
        """
        if not self.fused_layer:
            return NotImplemented
        from repro.kernels.gcn_fused.ops import (
            FUSED_VMEM_BUDGET,
            fused_layer_fits,
            gcn_fused_layer,
            gcn_fused_packed,
        )
        f, g = w.shape
        bm, bk_ = self.vals.shape[2], self.vals.shape[3]
        budget = FUSED_VMEM_BUDGET if self.vmem_budget is None \
            else self.vmem_budget
        if not fused_layer_fits(f, g, bm, bk_, block_g=self.block_g,
                                budget=budget):
            self.fused_fallbacks += 1
            return NotImplemented
        self.fused_hits += 1
        inject = None
        if self.inject is not None and self._layer_calls == self.inject[0]:
            inject = tuple(self.inject[1:])
        self._layer_calls += 1
        if self.segments is not None:
            return gcn_fused_packed(self.cols, self.vals, h, w, w_r,
                                    self.segments, num_segments=self.n_slots,
                                    block_g=self.block_g,
                                    granularity=self.granularity,
                                    interpret=self.interpret, inject=inject)
        if self.partition is None:
            return gcn_fused_layer(self.bell, h, w, w_r,
                                   block_g=self.block_g,
                                   granularity=self.granularity,
                                   interpret=self.interpret, inject=inject,
                                   _staged=(self.cols, self.vals))
        from .sharded import sharded_gcn_fused
        return sharded_gcn_fused(self.bell, self.cols, self.vals, h, w, w_r,
                                 self.partition, block_g=self.block_g,
                                 granularity=self.granularity,
                                 interpret=self.interpret)

    def network(self, h0, ws, wrs, cfg, *, stash=False):
        """Whole-network fusion (``kernels/gcn_fused``'s network kernel):
        every layer's combination + aggregation + ReLU runs in one sweep
        with the activation matrix ping-ponging between two VMEM buffers —
        it never touches HBM — and the eq.-5 column carried across each
        layer boundary, so the checks stay per-layer and pre-activation.

        Falls back to the per-layer ladder (returns ``NotImplemented``)
        when the option is off, the operand is sharded or non-square, or
        the depth-wide working set (ping-pong buffers at the shared
        lane-rounded max width) exceeds the VMEM budget.
        """
        if not self.fused_network or self.partition is not None:
            return NotImplemented
        from repro.kernels.gcn_fused.ops import (
            FUSED_VMEM_BUDGET,
            fused_network_fits,
            gcn_network_layer,
            gcn_network_packed,
        )
        nbm, _width, bm, bk_ = self.vals.shape
        dims = [int(ws[0].shape[0])] + [int(w.shape[1]) for w in ws]
        budget = FUSED_VMEM_BUDGET if self.vmem_budget is None \
            else self.vmem_budget
        if bm != bk_ or not fused_network_fits(dims, bm, nbm * bm,
                                               block_g=self.block_g,
                                               budget=budget):
            self.network_fallbacks += 1
            return NotImplemented
        self.network_hits += 1
        self._layer_calls += len(ws)     # the sweep consumed every layer
        if self.segments is not None:
            return gcn_network_packed(self.cols, self.vals, h0, ws, wrs,
                                      self.segments,
                                      num_segments=self.n_slots,
                                      block_g=self.block_g,
                                      granularity=self.granularity,
                                      interpret=self.interpret,
                                      inject=self.inject, stash_acts=stash)
        return gcn_network_layer(self.bell, h0, ws, wrs,
                                 block_g=self.block_g,
                                 granularity=self.granularity,
                                 interpret=self.interpret,
                                 inject=self.inject, stash_acts=stash)

    def combination_check(self, h, w, x, cfg, *, w_r=None):
        if self.granularity in ("stripe", "slot"):
            # slot corners need the fused kernels' telescopes; split mode's
            # two-pass combination check localizes at stripe granularity
            # per-stripe eq. 2–3 corners: rows group by stripe (row ->
            # stripe is just a reshape), matching the aggregate corner's
            # [n_block_rows] shape so split mode localizes too
            from repro.core.checksum import row_checksum
            nbm, bm = self.vals.shape[0], self.vals.shape[2]
            if w_r is None:
                w_r = row_checksum(w, cfg.dtype)
            rows = nbm * bm
            if h.shape[0] != rows:    # single-graph: pad the stripe residue
                h = jnp.pad(h, ((0, rows - h.shape[0]), (0, 0)))
                x = jnp.pad(x, ((0, rows - x.shape[0]), (0, 0)))
            hsum = h.astype(cfg.dtype).reshape(nbm, bm, -1).sum(axis=1)
            actual = x.astype(cfg.dtype).reshape(nbm, bm, -1).sum(axis=(1, 2))
            return Check(predicted=hsum @ w_r, actual=actual,
                         granularity="stripe")
        if self.segments is None:
            return super().combination_check(h, w, x, cfg, w_r=w_r)
        # per-graph eq. 2–3 corners: rows of h/x are contiguous per graph
        # (row -> stripe -> graph), so both checksum sides segment exactly —
        #   predicted[g] = (Σ_{rows∈g} h) · w_r,  actual[g] = Σ_{rows∈g} x
        from repro.core.checksum import row_checksum
        bm = self.vals.shape[2]
        row_graph = jnp.repeat(self.segments, bm)
        nseg = self.n_slots + 1                    # + overflow (pad stripes)
        hsum = jax.ops.segment_sum(h.astype(cfg.dtype), row_graph,
                                   num_segments=nseg,
                                   indices_are_sorted=True)[:self.n_slots]
        if w_r is None:
            w_r = row_checksum(w, cfg.dtype)
        pred = hsum @ w_r
        actual = jax.ops.segment_sum(x.astype(cfg.dtype).sum(axis=1),
                                     row_graph, num_segments=nseg,
                                     indices_are_sorted=True)[:self.n_slots]
        return Check(predicted=pred, actual=actual, granularity="graph")

    def aggregate(self, x, x_r):
        if x.ndim != 2:
            raise ValueError("block_ell backend is single-graph ([n, g]); "
                             "batch via engine.batching or the dense backend")
        xr_col = None if x_r is None else x_r.astype(jnp.float32)[:, None]
        # the two-pass kernel cannot split a stripe's ell-sweep into slot
        # corners; slot-granularity layers that fall through to this path
        # degrade to stripe corners (still surgical, one rung coarser)
        gran = "stripe" if self.granularity == "slot" else self.granularity
        inject = None
        if self.inject is not None and self._layer_calls == self.inject[0]:
            inject = tuple(self.inject[1:])
        self._layer_calls += 1
        if self.segments is not None:
            from repro.kernels.spmm_abft.ops import spmm_abft_packed
            return spmm_abft_packed(self.cols, self.vals, x, xr_col,
                                    self.segments, num_segments=self.n_slots,
                                    block_g=self.block_g,
                                    granularity=gran,
                                    interpret=self.interpret, inject=inject)
        from repro.kernels.spmm_abft.ops import spmm_abft
        if self.partition is None:
            out, chk = spmm_abft(self.bell, x, xr_col, block_g=self.block_g,
                                 granularity=gran,
                                 interpret=self.interpret, inject=inject,
                                 _staged=(self.cols, self.vals))
            return out, (chk if x_r is not None else None)
        from .sharded import sharded_spmm_abft
        return sharded_spmm_abft(
            self.bell, self.cols, self.vals, x, xr_col, self.partition,
            block_g=self.block_g, granularity=gran,
            interpret=self.interpret)


def make_backend(s: Any, cfg: ABFTConfig, *, backend: Optional[str] = None,
                 s_c: Optional[Array] = None, partition=None,
                 **opts) -> AggregationBackend:
    """Resolve + construct the aggregation backend for operand ``s``."""
    name = backend or infer_backend(s)
    return get_backend(name)(s, cfg, s_c=s_c, partition=partition, **opts)
