"""Unified checked-op engine: backend-dispatched layers, sharding, batching.

Public surface:
  api       — Graph, gcn_layer, gcn_forward, gcn_apply (the entry point)
  backends  — AggregationBackend (a CheckedOp) + dense/bcoo/block_ell registry
  sharded   — Partition + shard_map'd stripe-sharded block-ELL aggregation
  batching  — bucketed padding of variable-size graphs for batched serving
  streaming — continuous-traffic serving: canonical rungs, online packing,
              double-buffered guarded dispatch, latency SLOs, backpressure
  lm        — guarded transformer LM serving (fold_lm_w_r, LMEngine)
  gat       — guarded GAT serving (attention-weighted aggregation under
              the same eq. 4–6 chain checks)
"""
from .api import (  # noqa: F401
    Graph,
    fold_w_r,
    gcn_apply,
    gcn_forward,
    gcn_layer,
)
from .backends import (  # noqa: F401
    AggregationBackend,
    backend_names,
    get_backend,
    infer_backend,
    make_backend,
    register_backend,
)
from .localize import (  # noqa: F401
    gather_stripe_system,
    surgical_stripe_retry,
)
from .batching import (  # noqa: F401
    GraphBatch,
    PackedGraphs,
    graph_pack_stats,
    make_batches,
    make_packed_batches,
    pack_graphs,
    pad_graph,
    pick_bucket,
    schedule_packs,
    synth_graph_stream,
)
from .sharded import (  # noqa: F401
    Partition,
    sharded_gcn_fused,
    sharded_spmm_abft,
)
from .streaming import (  # noqa: F401
    PackedRunner,
    RequestResult,
    Rung,
    RungTable,
    StreamingEngine,
    plan_rungs,
)
from .lm import (  # noqa: F401
    LMEngine,
    fold_lm_w_r,
    make_guarded_decode_step,
    make_guarded_prefill_step,
)
from .gat import (  # noqa: F401
    GATEngine,
    gat_forward,
    gat_layer,
    init_gat,
    make_gat_serve_step,
)
