"""Stripe-surgical fault recovery: re-execute ONLY the rows a fault hit.

The eq. 4–6 corner is linear, so the packed kernels can keep their
per-row-stripe checksum partials as individual corners
(``granularity="stripe"``) — a detected fault then *names the stripe* it
corrupted instead of condemning a whole graph.  This module turns that
name into the cheapest exact repair the layout admits:

  1. **gather** the flagged stripes' tile rows + column-index table into a
     sub-system (:func:`gather_stripe_system`) — the cols table keeps its
     original column-block indices, so the FULL packed H stays the operand
     and no re-packing happens;
  2. **recompute** those stripes through the kernel THAT RAN THEM.  A
     fused-pass layer replays through the single-pass fused kernel
     (``kernels/gcn_fused``); a two-pass layer whose combination output X
     was stashed (``abft_x_layers``, ``gcn_forward(..., return_x=True)``)
     replays its aggregation through the two-pass spmm kernel against
     that exact X.  Each grid stripe accumulates independently in the
     same slot order over the same tiles, so either way the recomputed
     rows are *bit-for-bit* the values a clean full sweep would have
     produced.  (A two-pass original with no stashed X falls back to the
     fused recompute — exact up to f32 reassociation, re-verified by its
     own corners, just not bitwise — and a layer whose [f, g] working set
     exceeds the fused VMEM budget escalates instead of running a kernel
     the engine rejected.);
  3. **splice** the rows back (through ReLU for non-final layers) and
     propagate: a repaired stripe's rows are column blocks of the next
     layer, so only the stripes whose cols table references them (nonzero
     tiles — block-diagonal keeps this inside the owning graph) need
     re-execution downstream, not the whole graph;
  4. **re-verify**: the sub-sweep carries its own per-stripe corners; any
     corner still flagged aborts the repair and the guard escalates to the
     per-graph retry tier.

Recovery cost is counted in re-executed rows (``abft_rows_recomputed``):
a last-layer fault costs one stripe; an early-layer fault costs one stripe
plus the reachable downstream stripes — strictly less than the per-graph
retry's rows(graph) x layers whenever a graph spans more than one stripe.

:func:`surgical_slot_retry` is the tier below: at ``granularity="slot"``
the fused kernels' telescoped corners name the exact (stripe, ell-slot)
the fault landed in, and the repair refines downstream propagation to the
*rows that actually changed*.  After recomputing a flagged stripe it diffs
the new post-ReLU rows against the stashed activations; a downstream
stripe re-executes only if one of its stored tiles has a nonzero column
AT a changed row (0·x = 0 exactly, so skipping a zero column is sound —
and a fault ReLU already masked to zero propagates nowhere).  That is
strictly fewer rows than the stripe tier's any-nonzero-tile reach
whenever the changed-row footprint is narrower than the whole column
block.
"""
from __future__ import annotations

import logging
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.abft import ABFTConfig
from repro.core.checksum import row_checksum
from repro.kernels.runtime import resolve_interpret
from repro.kernels.spmm_abft.layout import BlockEll

log = logging.getLogger(__name__)


def gather_stripe_system(bell: BlockEll, stripe_idx) -> BlockEll:
    """Sub-system holding only ``stripe_idx``'s tile rows.

    The column-block indices are NOT remapped: the sub-system's stripes
    still gather from the full packed H/X rows, which is what makes the
    recompute a pure row-subset of the original sweep (same tiles, same
    slot order, same operand values — bitwise-identical stripe outputs).
    """
    idx = np.asarray(stripe_idx, np.int64)
    return BlockEll(values=bell.values[idx],
                    block_cols=bell.block_cols[idx],
                    shape=(int(idx.size) * bell.block_m, bell.shape[1]))


def _layer_stripe_flags(sflags: np.ndarray, n_layers: int) -> np.ndarray:
    """[n_checks, nbm] per-check stripe flags -> [n_layers, nbm].

    Fused mode emits one check per layer; split mode two (combination +
    corner).  Rows group contiguously per layer, so OR-reducing each
    layer's group attributes every flag to the layer that must re-execute.
    """
    if sflags.ndim != 2 or sflags.shape[0] % n_layers or not sflags.shape[0]:
        raise ValueError(
            f"abft_stripe_flags has shape {sflags.shape}; expected "
            f"[k*{n_layers} checks, n_stripes] (k checks per layer)")
    per = sflags.shape[0] // n_layers
    return sflags.reshape(n_layers, per, sflags.shape[1]).any(axis=1)


def _layer_slot_flags(slflags: np.ndarray, n_layers: int) -> np.ndarray:
    """[n_checks, nbm, width] per-check slot flags -> [n_layers, nbm,
    width], same contiguous-per-layer grouping as the stripe reduction."""
    if slflags.ndim != 3 or slflags.shape[0] % n_layers \
            or not slflags.shape[0]:
        raise ValueError(
            f"abft_slot_flags has shape {slflags.shape}; expected "
            f"[k*{n_layers} checks, n_stripes, width]")
    per = slflags.shape[0] // n_layers
    return slflags.reshape((n_layers, per) + slflags.shape[1:]).any(axis=1)


def _stashed_x_layers(metrics, n_layers: int):
    """Writable copies of the step's per-layer combination outputs
    (``abft_x_layers``), or None when the step didn't stash them.  Entries
    are None for layers a fused hook ran (no X ever existed)."""
    xs = metrics.get("abft_x_layers")
    if xs is None:
        return None
    xs = [None if x is None else np.array(x) for x in xs]
    if len(xs) != n_layers:
        raise ValueError(f"abft_x_layers carries {len(xs)} arrays; "
                         f"the model has {n_layers} layers")
    return xs


def _recompute_stripes(bell: BlockEll, todo, w, w_r, h_ell, x_ell,
                       cfg: ABFTConfig, *, block_g: int, interpret: bool):
    """Re-execute ``todo``'s stripes of one layer through the kernel that
    ran them originally: the two-pass spmm against the stashed X when
    ``x_ell`` is given (bit-for-bit replay of a two-pass layer), else the
    single-pass fused kernel (bit-for-bit for a fused original).  Returns
    (sub_out, per-stripe Check), or None when the layer exceeds the fused
    VMEM budget and no X is stashed — the caller escalates rather than
    forcing a kernel the engine itself refused to run."""
    sub = gather_stripe_system(bell, todo)
    if x_ell is not None:
        from repro.kernels.spmm_abft.ops import spmm_abft
        xr = (jnp.asarray(h_ell).astype(cfg.dtype)
              @ jnp.asarray(w_r))[:, None]
        return spmm_abft(sub, jnp.asarray(x_ell), xr, block_g=block_g,
                         granularity="stripe", interpret=interpret)
    from repro.kernels.gcn_fused.ops import fused_layer_fits, gcn_fused_layer
    if not fused_layer_fits(*w.shape, bell.block_m, bell.block_k,
                            block_g=block_g):
        return None
    return gcn_fused_layer(sub, jnp.asarray(h_ell), w, w_r, block_g=block_g,
                           granularity="stripe", interpret=interpret)


def surgical_stripe_retry(pb, params, cfg: ABFTConfig, out, metrics,
                          *, block_g: int = 128,
                          interpret: Optional[bool] = None
                          ) -> Tuple[np.ndarray, Dict[str, Any]]:
    """Repair a flagged packed step by re-executing only the hit stripes.

    ``pb`` is the :class:`~repro.engine.batching.PackedGraphs` batch the
    step ran; ``metrics`` must carry ``abft_stripe_flags`` (the
    per-(check, stripe) verdicts) and ``abft_h_layers`` (every layer's
    input activations, ``gcn_forward(..., return_intermediates=True)``);
    ``abft_x_layers`` (the stashed two-pass combination outputs,
    ``return_x=True``), when present, lets two-pass layers replay through
    the spmm kernel bit-for-bit instead of escalating on VMEM-fallback
    layers.  Returns ``(repaired_out, sub_metrics)`` in the guard's
    stripe-tier contract: ``sub_metrics['abft_graph_flags']`` is the FULL
    [n_slots] vector (all-False on verified success; the original flags
    when the repair could not be verified, so the guard escalates), plus
    the ``abft_rows_recomputed`` / ``abft_stripes_recomputed`` accounting.
    """
    interpret = resolve_interpret(interpret)
    layers = params["layers"]
    n_layers = len(layers)
    sflags = _layer_stripe_flags(
        np.asarray(metrics["abft_stripe_flags"], bool), n_layers)
    h_layers = [np.array(h) for h in metrics["abft_h_layers"]]  # writable
    if len(h_layers) != n_layers:
        raise ValueError(f"abft_h_layers carries {len(h_layers)} arrays; "
                         f"the model has {n_layers} layers")
    x_layers = _stashed_x_layers(metrics, n_layers)
    bell = pb.bell
    bm = bell.block_m
    stripe_graph = np.asarray(pb.stripe_graph)
    n_slots = pb.n_slots
    orig_flags = np.asarray(metrics["abft_graph_flags"], bool).copy()

    def escalate(reason: str):
        log.error("ABFT stripe repair escalating: %s", reason)
        return np.asarray(out), {
            "abft_graph_flags": orig_flags,
            "abft_rows_recomputed": rows_recomputed,
            "abft_stripes_recomputed": stripes_recomputed,
        }

    rows_recomputed = 0
    stripes_recomputed = 0
    repaired = np.array(out)                                    # writable
    graph_rel = np.zeros(n_slots, np.float32)
    dirty_cols: set = set()          # column blocks whose H rows changed
    for ell in range(n_layers):
        flagged = set(np.nonzero(sflags[ell])[0].tolist())  # abftlint: sync-ok (post-flag repair path)
        if any(stripe_graph[s] >= n_slots for s in flagged):
            # a padding stripe's corner is 0 = 0 by construction; it
            # flagging means the batch invariants are broken — do not
            # guess, hand the step to the coarser tiers
            return escalate("padding stripe flagged")
        reach = _reachable_stripes(bell, dirty_cols)
        reached = {s for s in np.nonzero(reach)[0].tolist()  # abftlint: sync-ok
                   if stripe_graph[s] < n_slots}
        todo = sorted(flagged | reached)
        if not todo:
            continue
        w = layers[ell]["w"]
        w_r = layers[ell].get("w_r")
        if w_r is None:
            w_r = row_checksum(w, cfg.dtype)
        x_ell = x_layers[ell] if x_layers is not None else None
        res = _recompute_stripes(bell, todo, w, w_r, h_layers[ell], x_ell,
                                 cfg, block_g=block_g, interpret=interpret)
        if res is None:
            # the engine itself would refuse to run this layer fused
            # (resident W exceeds the VMEM budget) and no X was stashed —
            # recovery must not be the one place that kernel is forced to
            # run
            return escalate(f"layer {ell} [f, g]={tuple(w.shape)} exceeds "
                            f"the fused VMEM budget and no X is stashed")
        sub_out, chk = res
        rows_recomputed += len(todo) * bm
        stripes_recomputed += len(todo)
        if bool(chk.flag(cfg)):  # abftlint: sync-ok
            return escalate(f"recomputed stripes still flagged at layer "
                            f"{ell}")
        _, rel = chk.elementwise(cfg)
        rel = np.asarray(rel)  # abftlint: sync-ok
        sub_out = np.asarray(sub_out)  # abftlint: sync-ok
        for k, s in enumerate(todo):
            r0 = s * bm
            rows = sub_out[k * bm:(k + 1) * bm]
            if ell < n_layers - 1:
                h_layers[ell + 1][r0:r0 + bm] = np.maximum(rows, 0.0)
                if x_layers is not None and x_layers[ell + 1] is not None:
                    # the spliced activations invalidate the NEXT layer's
                    # stashed combination rows — refresh them so its
                    # replay consumes the repaired operands
                    x_layers[ell + 1][r0:r0 + bm] = np.asarray(  # abftlint: sync-ok
                        jnp.asarray(h_layers[ell + 1][r0:r0 + bm])
                        @ jnp.asarray(layers[ell + 1]["w"]))
            else:
                repaired[r0:r0 + bm] = rows
            graph_rel[stripe_graph[s]] = max(graph_rel[stripe_graph[s]],
                                             float(rel[k]))  # abftlint: sync-ok
        dirty_cols = set(todo)       # square blocks: stripe s == col block s
    log.warning("ABFT: stripe-surgical repair verified clean "
                "(%d stripes / %d rows re-executed)",
                stripes_recomputed, rows_recomputed)
    return repaired, {
        "abft_graph_flags": np.zeros(n_slots, bool),
        "abft_graph_max_rel": graph_rel,
        "abft_rows_recomputed": rows_recomputed,
        "abft_stripes_recomputed": stripes_recomputed,
    }


def _reachable_stripes(bell: BlockEll, col_blocks: set) -> np.ndarray:
    """[n_block_rows] mask of stripes that read any of ``col_blocks``' rows
    through a stored (nonzero) tile.  ELL padding tiles alias column-block
    0 with all-zero values — they must not mark graph 0's stripes dirty."""
    if not col_blocks:
        return np.zeros(bell.n_block_rows, bool)
    hit = np.isin(bell.block_cols,
                  np.fromiter(col_blocks, np.int64, len(col_blocks)))
    stored = np.abs(bell.values).max(axis=(2, 3)) > 0
    return (hit & stored).any(axis=1)


def _rows_reachable_stripes(bell: BlockEll,
                            dirty: Dict[int, np.ndarray]) -> np.ndarray:
    """[n_block_rows] mask of stripes that read a CHANGED row of a dirty
    column block through a nonzero tile column — the slot tier's row-level
    refinement of :func:`_reachable_stripes`.  A tile column that is all
    zero contributes exactly 0 regardless of the operand row (0·x = 0 in
    f32), so skipping it cannot change the recomputed output bitwise."""
    mask = np.zeros(bell.n_block_rows, bool)
    if not dirty:
        return mask
    # nonzero per tile COLUMN: tile columns index the operand's local rows
    colnz = np.abs(bell.values).max(axis=2) > 0      # [nbm, width, bk]
    for cb, rowmask in dirty.items():
        if not rowmask.any():
            continue
        hit = bell.block_cols == cb                  # [nbm, width]
        mask |= (hit[:, :, None] & colnz
                 & rowmask[None, None, :]).any(axis=(1, 2))
    return mask


def surgical_slot_retry(pb, params, cfg: ABFTConfig, out, metrics,
                        *, block_g: int = 128,
                        interpret: Optional[bool] = None
                        ) -> Tuple[np.ndarray, Dict[str, Any]]:
    """The ladder's finest tier: repair from per-(stripe, slot) verdicts
    with row-level downstream propagation.

    Same contract as :func:`surgical_stripe_retry` (FULL-batch
    ``abft_graph_flags``, rows/stripes accounting; the guard escalates to
    the stripe tier when the repair cannot be verified), but consumes
    ``metrics['abft_slot_flags']`` ([n_checks, n_stripes, width] telescope
    corners) and refines propagation: after recomputing a flagged stripe
    it diffs the new post-ReLU rows against the stashed activations and
    marks ONLY the changed rows dirty — a downstream stripe re-executes
    only if a stored tile reads a changed row through a nonzero column.
    A fault whose corruption ReLU masks to zero (or that never alters the
    post-activation rows) therefore propagates to nothing, and the tier
    re-executes strictly fewer rows than the stripe tier whenever the
    changed-row footprint is narrower than the whole column block.
    """
    interpret = resolve_interpret(interpret)
    layers = params["layers"]
    n_layers = len(layers)
    slflags = _layer_slot_flags(
        np.asarray(metrics["abft_slot_flags"], bool), n_layers)
    h_layers = [np.array(h) for h in metrics["abft_h_layers"]]  # writable
    if len(h_layers) != n_layers:
        raise ValueError(f"abft_h_layers carries {len(h_layers)} arrays; "
                         f"the model has {n_layers} layers")
    x_layers = _stashed_x_layers(metrics, n_layers)
    bell = pb.bell
    bm = bell.block_m
    stripe_graph = np.asarray(pb.stripe_graph)
    n_slots = pb.n_slots
    orig_flags = np.asarray(metrics["abft_graph_flags"], bool).copy()

    def escalate(reason: str):
        log.error("ABFT slot repair escalating: %s", reason)
        return np.asarray(out), {
            "abft_graph_flags": orig_flags,
            "abft_rows_recomputed": rows_recomputed,
            "abft_stripes_recomputed": stripes_recomputed,
        }

    rows_recomputed = 0
    stripes_recomputed = 0
    repaired = np.array(out)                                    # writable
    graph_rel = np.zeros(n_slots, np.float32)
    dirty: Dict[int, np.ndarray] = {}    # col block -> [bm] changed rows
    for ell in range(n_layers):
        flagged = set(np.nonzero(slflags[ell].any(axis=1))[0].tolist())  # abftlint: sync-ok (post-flag repair path)
        if any(stripe_graph[s] >= n_slots for s in flagged):
            return escalate("padding stripe flagged")
        reach = _rows_reachable_stripes(bell, dirty)
        reached = {s for s in np.nonzero(reach)[0].tolist()  # abftlint: sync-ok
                   if stripe_graph[s] < n_slots}
        todo = sorted(flagged | reached)
        dirty = {}
        if not todo:
            continue
        w = layers[ell]["w"]
        w_r = layers[ell].get("w_r")
        if w_r is None:
            w_r = row_checksum(w, cfg.dtype)
        x_ell = x_layers[ell] if x_layers is not None else None
        res = _recompute_stripes(bell, todo, w, w_r, h_layers[ell], x_ell,
                                 cfg, block_g=block_g, interpret=interpret)
        if res is None:
            return escalate(f"layer {ell} [f, g]={tuple(w.shape)} exceeds "
                            f"the fused VMEM budget and no X is stashed")
        sub_out, chk = res
        rows_recomputed += len(todo) * bm
        stripes_recomputed += len(todo)
        if bool(chk.flag(cfg)):  # abftlint: sync-ok
            return escalate(f"recomputed stripes still flagged at layer "
                            f"{ell}")
        _, rel = chk.elementwise(cfg)
        rel = np.asarray(rel)  # abftlint: sync-ok
        sub_out = np.asarray(sub_out)  # abftlint: sync-ok
        for k, s in enumerate(todo):
            r0 = s * bm
            rows = sub_out[k * bm:(k + 1) * bm]
            if ell < n_layers - 1:
                act = np.maximum(rows, 0.0)
                changed = (act != h_layers[ell + 1][r0:r0 + bm]).any(axis=1)
                h_layers[ell + 1][r0:r0 + bm] = act
                if changed.any():
                    # square blocks: stripe s == column block s; only the
                    # rows that actually changed can perturb downstream
                    dirty[s] = changed
                    if x_layers is not None and x_layers[ell + 1] is not None:
                        x_layers[ell + 1][r0:r0 + bm] = np.asarray(  # abftlint: sync-ok
                            jnp.asarray(act)
                            @ jnp.asarray(layers[ell + 1]["w"]))
            else:
                repaired[r0:r0 + bm] = rows
            graph_rel[stripe_graph[s]] = max(graph_rel[stripe_graph[s]],
                                             float(rel[k]))  # abftlint: sync-ok
    log.warning("ABFT: slot-surgical repair verified clean "
                "(%d stripes / %d rows re-executed)",
                stripes_recomputed, rows_recomputed)
    return repaired, {
        "abft_graph_flags": np.zeros(n_slots, bool),
        "abft_graph_max_rel": graph_rel,
        "abft_rows_recomputed": rows_recomputed,
        "abft_stripes_recomputed": stripes_recomputed,
    }
