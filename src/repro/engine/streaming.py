"""Streaming GCN serving: a bounded request queue, online FFD packing into
canonical rung shapes, and double-buffered guarded dispatch.

The paper's point is *online* error checking, and a server that
materializes its whole stream before packing is not online.  This module
serves continuous traffic:

* **Canonical rungs** (:func:`plan_rungs` / :class:`RungTable`) — a small
  fixed set of packed shapes (stripe capacity x ELL width x slot count)
  chosen from a traffic profile.  Every batch is padded to its rung's
  EXACT shape (``pack_graphs(stripe_cap=, width_cap=)``), so the number of
  jit compiles is bounded by the rung table, not by whatever graph sizes
  happen to arrive together.
* **Online first-fit packing** (:class:`StreamingEngine.submit`) — each
  request is fitted to the smallest rung whose capacity admits it and
  appended to that rung's open bin; a bin seals (dispatches) when its
  slots fill or the next request would overflow the stripe capacity.
  This is the incremental form of ``engine.batching.schedule_packs``:
  same capacity logic, applied per arrival instead of over a closed list.
* **Double-buffered dispatch** — sealing a bin packs it on the host while
  the previous batch is still executing on the device (JAX async
  dispatch); only then is the previous batch *adjudicated*
  (``ABFTGuard.adjudicate`` — the first host sync) and the new one
  dispatched.  Pack and execute overlap; the guard ladder (stripe ->
  graph -> restore) is unchanged.
* **Latency SLOs** — every request records enqueue, dispatch, and verdict
  times; :meth:`StreamingEngine.stats` reports p50/p99 enqueue->verdict
  latency per request, not just graphs/sec.
* **Flush-on-deadline** — an open bin whose oldest request has waited
  ``flush_deadline`` seconds is sealed partial, so a trickle stream is
  never starved behind a bin that will not fill.
* **Backpressure** — ``queue_capacity`` bounds the requests parked in
  open bins; a submit beyond it returns an explicit ``rejected`` verdict
  immediately.  The server never grows an unbounded buffer.
* **Oversized requests degrade gracefully** — a graph exceeding every
  rung (stripes or ELL width) is routed to a dedicated singleton shape
  (power-of-two quantized, so even pathological traffic compiles O(log)
  shapes) or, under ``oversize_policy="reject"``, answered with a
  per-request rejection verdict.  It never kills the stream.

The closed-batch driver (``launch/serve_gcn.py``) is a thin client of the
same machinery: :class:`PackedRunner` and the jitted step builders below
are shared, so benchmarks and the streaming server run identical kernels,
checks, and retry ladders.
"""
from __future__ import annotations

import dataclasses
import logging
import time
from typing import Any, Callable, Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.abft import ABFTConfig, per_graph_report, \
    per_slot_report, per_stripe_report, summarize
from repro.engine.api import Graph, fold_w_r, gcn_forward
from repro.engine.backends import BlockEllBackend
from repro.kernels.runtime import resolve_interpret
from repro.engine.batching import GraphBatch, PackedGraphs, \
    graph_pack_stats, pack_graphs
from repro.runtime import ABFTGuard

log = logging.getLogger(__name__)


# ---------------------------------------------------------------------------
# jitted serve steps (shared by closed-batch serve_gcn and the stream engine)
# ---------------------------------------------------------------------------

def make_serve_step(params, cfg: ABFTConfig):
    """Jitted (s, h0) -> (logits, metrics) batched dense engine step.

    One compile per distinct (batch, bucket) shape; the dense backend
    broadcasts over the leading batch axis, so the batch contributes
    batched scalar checks — reduced into one replicated report AND kept
    per-graph for the guard's partial retry.
    """
    @jax.jit
    def step(s, h0):
        logits, checks = gcn_forward(params, Graph(s=s, h0=h0), cfg,
                                     backend="dense")
        report = summarize(checks, cfg)
        gflags, grel = per_graph_report(checks, cfg, s.shape[0])
        return logits, {"abft_flag": report.flag,
                        "abft_max_rel": report.max_rel,
                        "abft_n_checks": report.n_checks,
                        "abft_graph_flags": gflags,
                        "abft_graph_max_rel": grel}
    return step


def make_packed_serve_step(params, cfg: ABFTConfig, n_slots: int, *,
                           block_g: int = 128,
                           interpret: Optional[bool] = None,
                           fused_layer: bool = False,
                           fused_network: bool = False,
                           vmem_budget: Optional[int] = None,
                           granularity: str = "graph",
                           inject=None):
    """Jitted (cols, vals, segments, h0) -> (logits, metrics) packed step.

    The packed block-ELL arrays are *arguments*, not baked-in constants, so
    every batch of the same packed shape shares one compile; the segmented
    epilogue's per-graph corners feed both the replicated report and the
    per-graph verdict vector.  ``fused_layer=True`` runs each layer through
    the single-pass gcn_fused kernel (combination + aggregation + check in
    one HBM traversal) instead of the two-pass combination-then-spmm path;
    ``fused_network=True`` goes further and runs the WHOLE forward in one
    sweep (``gcn_network_kernel``) with the activations resident in VMEM,
    falling back to the per-layer ladder when the depth-wide working set
    exceeds ``vmem_budget``.

    ``granularity="stripe"`` keeps the per-row-stripe corners: the metrics
    gain ``abft_stripe_flags`` / ``abft_stripe_max_rel`` ([checks,
    n_stripes] verdicts, the per-graph vector now segment-reduced from
    them), ``abft_h_layers`` (every layer's input activations — stashed by
    the network kernel when it runs), and ``abft_x_layers`` (two-pass
    layers' combination outputs, for the bit-for-bit spmm replay) — the
    operands the guard's surgical tiers need.  ``granularity="slot"``
    refines to per-(stripe, ell-slot) telescope corners on the fused
    kernel paths, adding ``abft_slot_flags`` / ``abft_slot_max_rel``
    ([checks, n_stripes, width]); two-pass fallback layers degrade to
    stripe corners and contribute all-False slot slabs.  ``inject`` is the
    benchmark/CI accumulator fault hook, ``(layer, stripe, slot, delta)``,
    honoured by all three kernels.
    """
    interpret = resolve_interpret(interpret)
    want_localize = granularity in ("stripe", "slot")

    @jax.jit
    def step(cols, vals, segments, h0):
        bk = BlockEllBackend.from_staged(cols, vals, segments, n_slots, cfg,
                                         block_g=block_g,
                                         interpret=interpret,
                                         fused_layer=fused_layer,
                                         fused_network=fused_network,
                                         vmem_budget=vmem_budget,
                                         granularity=granularity,
                                         inject=inject)
        if want_localize:
            logits, checks, h_layers, x_layers = gcn_forward(
                params, Graph(s=None, h0=h0), cfg, backend=bk,
                return_intermediates=True, return_x=True)
        else:
            # no surgical tier to feed: skip the operand stashes (the
            # network kernel then runs its pure one-traversal form)
            logits, checks = gcn_forward(
                params, Graph(s=None, h0=h0), cfg, backend=bk)
        report = summarize(checks, cfg)
        metrics = {"abft_flag": report.flag,
                   "abft_max_rel": report.max_rel,
                   "abft_n_checks": report.n_checks}
        if want_localize:
            gflags, grel = per_graph_report(checks, cfg, n_slots,
                                            segments=segments)
            sflags, srel = per_stripe_report(checks, cfg, vals.shape[0])
            metrics.update(abft_stripe_flags=sflags,
                           abft_stripe_max_rel=srel,
                           abft_h_layers=h_layers,
                           abft_x_layers=x_layers)
            if granularity == "slot":
                slflags, slrel = per_slot_report(checks, cfg, vals.shape[0],
                                                 vals.shape[1])
                metrics.update(abft_slot_flags=slflags,
                               abft_slot_max_rel=slrel)
        else:
            gflags, grel = per_graph_report(checks, cfg, n_slots)
        metrics.update(abft_graph_flags=gflags, abft_graph_max_rel=grel)
        return logits, metrics
    return step


def packed_step_args(pb: PackedGraphs) -> Tuple[jax.Array, ...]:
    """The jitted packed step's positional operands for one batch."""
    return (jnp.asarray(pb.bell.block_cols), jnp.asarray(pb.bell.values),
            jnp.asarray(pb.stripe_graph), jnp.asarray(pb.h0))


def next_pow2(n: int) -> int:
    """Smallest power of two >= n (>= 1) — the retry/singleton shape
    ladder's quantizer: distinct counts collapse onto O(log) shapes."""
    return 1 << max(0, int(n - 1).bit_length())


class PackedRunner:
    """Per-shape jitted packed steps + the per-graph retry closure.

    ``_steps`` is the compile cache: one entry per distinct packed shape.
    Its length IS the jit-compile count the streaming engine's
    bounded-compile contract is asserted against.
    """

    def __init__(self, params, cfg: ABFTConfig, block_g: int,
                 fused_layer: bool = False, granularity: str = "graph",
                 fused_network: bool = False,
                 vmem_budget: Optional[int] = None,
                 inject=None):
        self.params, self.cfg = params, cfg
        self.block_g = block_g
        self.fused_layer = fused_layer
        self.fused_network = fused_network
        self.vmem_budget = vmem_budget
        self.granularity = granularity
        # chaos hook: the kernel accumulator fault (layer, stripe, slot,
        # delta), baked into every step this runner builds — the fault-
        # campaign / e2e degrade tests' device-side injection surface
        self.inject = inject
        self._steps = {}

    @property
    def compile_count(self) -> int:
        return len(self._steps)

    def step_for(self, pb: PackedGraphs):
        key = (pb.bell.values.shape, pb.h0.shape, pb.n_slots)
        if key not in self._steps:
            if self.fused_layer or self.fused_network:
                self._warn_fallbacks(pb)
            self._steps[key] = make_packed_serve_step(
                self.params, self.cfg, pb.n_slots, block_g=self.block_g,
                fused_layer=self.fused_layer,
                fused_network=self.fused_network,
                vmem_budget=self.vmem_budget,
                granularity=self.granularity,
                inject=self.inject)
        return self._steps[key]

    def _budget(self) -> int:
        from repro.kernels.gcn_fused.ops import FUSED_VMEM_BUDGET
        return FUSED_VMEM_BUDGET if self.vmem_budget is None \
            else self.vmem_budget

    def _network_dims(self) -> list:
        layers = self.params["layers"]
        return ([int(layers[0]["w"].shape[0])]
                + [int(layer["w"].shape[1]) for layer in layers])

    def fusion_counts(self, pb: PackedGraphs) -> Dict[str, int]:
        """Per-batch fusion decisions, recomputed eagerly from the SAME
        static shape predicates the backend evaluates at trace time — the
        backend's own counters tick once per compile (the decision is
        trace-time), which under-reports a serving run where every batch
        takes the decision.  One whole-network hit subsumes the per-layer
        decisions; a network fallback drops to the per-layer ladder, whose
        hit/fallback split is evaluated layer by layer."""
        from repro.kernels.gcn_fused.ops import fused_layer_fits, \
            fused_network_fits

        counts = {"fused_hits": 0, "fused_fallbacks": 0,
                  "network_hits": 0, "network_fallbacks": 0}
        if self.cfg.mode == "split":
            return counts
        nbm, _w, bm, bk = pb.bell.values.shape
        if self.fused_network:
            if bm == bk and fused_network_fits(self._network_dims(), bm,
                                               nbm * bm,
                                               block_g=self.block_g,
                                               budget=self._budget()):
                counts["network_hits"] = 1
                return counts
            counts["network_fallbacks"] = 1
        if self.fused_layer:
            for layer in self.params["layers"]:
                if fused_layer_fits(*layer["w"].shape, bm, bk,
                                    block_g=self.block_g,
                                    budget=self._budget()):
                    counts["fused_hits"] += 1
                else:
                    counts["fused_fallbacks"] += 1
        return counts

    def _warn_fallbacks(self, pb: PackedGraphs):
        """The VMEM-budget decision happens at trace time inside the jitted
        step, where it is invisible to the operator — so surface it eagerly,
        once per packed shape, from the layer widths we already know."""
        import warnings

        from repro.kernels.gcn_fused.ops import fused_layer_fits, \
            fused_network_fits

        nbm, _w, bm, bk = pb.bell.values.shape
        if self.fused_network:
            if bm == bk and fused_network_fits(self._network_dims(), bm,
                                               nbm * bm,
                                               block_g=self.block_g,
                                               budget=self._budget()):
                return          # whole network fused; nothing falls back
            warnings.warn(
                "--fused-network: the depth-wide working set (activation "
                "ping-pong buffers at the shared max width) exceeds the "
                "VMEM budget for this packed shape; the batch runs the "
                "per-layer ladder instead")
        if not self.fused_layer:
            return
        wide = [tuple(layer["w"].shape) for layer in self.params["layers"]
                if not fused_layer_fits(*layer["w"].shape, bm, bk,
                                        block_g=self.block_g,
                                        budget=self._budget())]
        if wide:
            warnings.warn(
                f"--fused-layer: layer widths {wide} exceed the fused VMEM "
                f"budget; those layers run the two-pass kernel instead")

    def _retry_shape(self, pb: PackedGraphs, items) -> Dict[str, int]:
        """Canonical sub-pack shape for a flagged subset: slot count,
        stripe capacity, and ELL width each rounded up a power-of-two
        ladder (respecting the parent's quantization multiples), so every
        flagged-graph count on a flaky host maps onto O(log) shapes that
        hit the ``_steps`` cache instead of compiling per batch."""
        sq = max(pb.stripe_multiple, 1)
        wq = max(pb.width_multiple, 1)
        stats = [graph_pack_stats(s, pb.block) for s, _ in items]
        stripes = sum(st for st, _ in stats)
        width = max(w for _, w in stats)
        return {"n_slots": next_pow2(len(items)),
                "stripe_cap": sq * next_pow2(-(-stripes // sq)),
                "width_cap": wq * next_pow2(-(-width // wq))}

    def pack_retry(self, pb: PackedGraphs, items,
                   indices: Optional[Sequence[int]] = None) -> PackedGraphs:
        shape = self._retry_shape(pb, items)
        return pack_graphs(items, block=pb.block,
                           stripe_multiple=pb.stripe_multiple,
                           width_multiple=pb.width_multiple,
                           indices=indices, **shape)

    def retry_fn(self, pb: PackedGraphs):
        """retry(out, idx): re-pack ONLY the flagged graphs into a small
        block-diagonal system (same block size as the parent batch),
        re-run, and patch their logit rows back — the unflagged graphs'
        verified rows are untouched.  Sub-packs pad onto the power-of-two
        retry ladder (slots 1, 2, 4, …; stripes/width likewise), so a
        flaky chip retrying a different flagged count every batch compiles
        O(log) shapes total, all shared through the ``_steps`` cache.

        ``abft_rows_recomputed`` counts LOGICAL rows (Σ n_nodes x layers):
        block/stripe/width quantization padding is shape bookkeeping, not
        recomputed work, and counting it would skew the stripe-vs-graph
        economics in BENCH_localization.json."""
        def retry(out, idx):
            items = [pb.items[i] for i in idx]
            sub = self.pack_retry(pb, items)
            sub_logits, sub_metrics = self.step_for(sub)(
                *packed_step_args(sub))
            n_layers = len(self.params["layers"])
            k = len(idx)
            sub_metrics = {
                **sub_metrics,
                "abft_graph_flags":
                    np.asarray(sub_metrics["abft_graph_flags"])[:k],
                "abft_graph_max_rel":
                    np.asarray(sub_metrics["abft_graph_max_rel"])[:k],
                "abft_rows_recomputed":
                    int(sub.n_nodes.sum()) * n_layers}
            out = np.asarray(out).copy()
            for j, gi in enumerate(idx):
                o, n = pb.row_offsets[gi], pb.n_nodes[gi]
                so, sn = sub.row_offsets[j], sub.n_nodes[j]
                out[o:o + n] = np.asarray(sub_logits)[so:so + sn]  # abftlint: sync-ok (post-flag retry path)
            return out, sub_metrics
        return retry

    def stripe_retry_fn(self, pb: PackedGraphs):
        """Surgical tier: gather the flagged stripes' tile rows, re-execute
        them through the fused kernel against the SAME packed operands,
        splice the rows back, and re-verify — no re-packing, no whole-graph
        replay (``engine.localize.surgical_stripe_retry``)."""
        from repro.engine.localize import surgical_stripe_retry

        def sretry(out, metrics):
            return surgical_stripe_retry(pb, self.params, self.cfg, out,
                                         metrics, block_g=self.block_g)
        return sretry

    def slot_retry_fn(self, pb: PackedGraphs):
        """Finest tier: repair from the per-(stripe, slot) telescope
        corners with row-level downstream propagation
        (``engine.localize.surgical_slot_retry``); the guard escalates to
        the stripe tier when the repair cannot be verified."""
        from repro.engine.localize import surgical_slot_retry

        def slretry(out, metrics):
            return surgical_slot_retry(pb, self.params, self.cfg, out,
                                       metrics, block_g=self.block_g)
        return slretry


def dense_retry_fn(step, b: GraphBatch):
    """retry(out, idx): re-run only the flagged slots as a smaller dense
    sub-batch and patch their logits back.  The sub-batch pads up the
    power-of-two slot ladder (1, 2, 4, …) with empty all-zero graphs —
    which contribute 0 = 0 to every check and can never flag — so distinct
    flagged counts share O(log) compiles of ``step`` instead of one each."""
    def retry(out, idx):
        k = len(idx)
        pad = next_pow2(k)
        sub_s = np.zeros((pad,) + b.s.shape[1:], b.s.dtype)
        sub_h = np.zeros((pad,) + b.h0.shape[1:], b.h0.dtype)
        sub_s[:k] = b.s[idx]
        sub_h[:k] = b.h0[idx]
        sub_logits, sub_metrics = step(jnp.asarray(sub_s),
                                       jnp.asarray(sub_h))
        sub_metrics = {
            **sub_metrics,
            "abft_graph_flags":
                np.asarray(sub_metrics["abft_graph_flags"])[:k],
            "abft_graph_max_rel":
                np.asarray(sub_metrics["abft_graph_max_rel"])[:k]}
        out = np.asarray(out).copy()
        out[idx] = np.asarray(sub_logits)[:k]
        return out, sub_metrics
    return retry


# ---------------------------------------------------------------------------
# canonical shape rungs
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class Rung:
    """One canonical packed shape: a batch padded against this rung always
    presents [stripe_cap stripes x width_cap ELL slots x n_slots graph
    segments] to jit."""

    stripe_cap: int
    width_cap: int
    n_slots: int


@dataclasses.dataclass(frozen=True)
class RungTable:
    """The fixed shape menu of a streaming server.

    ``fit`` returns the smallest rung admitting a request (by stripe count
    AND ELL width), or None — the oversize path.  The table's length bounds
    the server's steady-state jit-compile count.
    """

    rungs: Tuple[Rung, ...]
    block: int
    stripe_multiple: int = 1
    width_multiple: int = 1

    def __len__(self) -> int:
        return len(self.rungs)

    def fit(self, stripes: int, width: int) -> Optional[Rung]:
        for r in self.rungs:
            if stripes <= r.stripe_cap and width <= r.width_cap:
                return r
        return None


def plan_rungs(profile: Sequence[Tuple[np.ndarray, np.ndarray]], *,
               n_slots: int, block: int = 32, stripe_multiple: int = 4,
               width_multiple: int = 4, max_rungs: int = 4) -> RungTable:
    """Choose canonical rungs from a traffic profile (a sample of (S, H0)
    pairs representative of the stream).

    The base rung's stripe capacity is the profile's mean stripe count x
    ``n_slots`` (a full bin of typical graphs), rounded up to the
    ``stripe_multiple`` quantum — the same capacity ``schedule_packs``
    fills closed batches toward.  Capacities then double until the largest
    profiled graph fits alone (so no profiled size is oversized), capped
    at ``max_rungs`` entries with the last rung forced large enough.
    Width is one shared cap: the profile's max, quantized.
    """
    if not profile:
        raise ValueError("plan_rungs needs a non-empty traffic profile")
    if n_slots < 1:
        raise ValueError(f"n_slots must be >= 1, got {n_slots}")
    stats = [graph_pack_stats(s, block) for s, _ in profile]
    stripes = [st for st, _ in stats]
    sq = max(stripe_multiple, 1)
    wq = max(width_multiple, 1)
    width_cap = -(-max(w for _, w in stats) // wq) * wq
    mean_up = -(-sum(stripes) // len(stripes))
    base = -(-mean_up * n_slots // sq) * sq
    need = -(-max(stripes) // sq) * sq      # largest single profiled graph
    caps = [base]
    while caps[-1] < need and len(caps) < max_rungs:
        caps.append(caps[-1] * 2)
    caps[-1] = max(caps[-1], need)
    rungs = tuple(Rung(stripe_cap=c, width_cap=width_cap, n_slots=n_slots)
                  for c in caps)
    return RungTable(rungs=rungs, block=block, stripe_multiple=sq,
                     width_multiple=wq)


# ---------------------------------------------------------------------------
# the streaming engine
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class RequestResult:
    """Per-request verdict + latency accounting."""

    rid: int
    status: str                       # "served" | "rejected" |
    #                                   "rejected_oversize"
    flag: Optional[bool] = None       # final adopted ABFT verdict
    max_rel: float = 0.0
    logits: Optional[np.ndarray] = None
    reason: str = ""
    t_enqueue: float = 0.0
    t_dispatch: Optional[float] = None
    t_verdict: Optional[float] = None

    @property
    def latency(self) -> Optional[float]:
        """Enqueue -> verdict seconds (None until adjudicated)."""
        if self.t_verdict is None:
            return None
        return self.t_verdict - self.t_enqueue


@dataclasses.dataclass
class _OpenBin:
    rung: Rung
    items: List[Tuple[int, np.ndarray, np.ndarray]]  # (rid, s, h0)
    load: int = 0                     # total stripes parked here
    first_enqueue: float = 0.0


class StreamingEngine:
    """Continuous-traffic GCN serving with bounded compiles and an explicit
    latency/backpressure contract.  See the module docstring for the
    architecture; the per-batch check/retry semantics are exactly
    ``launch/serve_gcn.py``'s (same :class:`PackedRunner`, same
    ``ABFTGuard`` ladder).

    Single-threaded and cooperative: ``submit`` packs and dispatches as
    bins fill, ``pump`` applies the flush deadline to a trickle stream,
    ``drain`` flushes everything and adjudicates the tail.  Completed
    verdicts are collected with ``take_results``.

    **Robustness wiring (PR 9).**  The engine owns a *backend degrade
    ladder* — level 0 is the configured backend (fused-network or
    fused-layer), falling back to the two-pass packed path and finally to
    the dense batched engine.  Three signals advance the ladder, each
    after draining the in-flight batch and checkpointing via the
    ``checkpoint/`` machinery: (a) an unverifiable batch (the guard's
    persistent-fault escalation raised — the batch is re-dispatched on
    the fallback, so nothing is dropped), (b) eviction advice
    (``guard.suspect`` from sticky-site classification, or
    ``guard.should_evict()`` flag-rate), and (c) a
    ``StragglerWatchdog`` slow-streak around dispatch->adjudication
    (``watchdog=``), with ``hang_timeout=`` forcing adjudication of a
    wedged in-flight batch from ``pump``.  ``selfcheck_interval=`` adds
    the check-the-check cadence: every N dispatches the folded ``w_r``
    operands are re-derived bitwise (:mod:`repro.faults.selfcheck`) and
    a mismatch refolds + rebuilds the jitted steps.  ``inject=`` is the
    level-0 chaos hook (the kernel accumulator fault) — degraded levels
    are always built clean, which is what lets the ladder actually
    recover from a sticky backend fault in the e2e tests.
    """

    def __init__(self, params, cfg: ABFTConfig, rungs: RungTable, *,
                 guard: Optional[ABFTGuard] = None,
                 queue_capacity: int = 64,
                 flush_deadline: Optional[float] = None,
                 oversize_policy: str = "singleton",
                 block_g: Optional[int] = None,
                 fused_layer: bool = False,
                 fused_network: bool = False,
                 vmem_budget: Optional[int] = None,
                 granularity: str = "graph",
                 keep_logits: bool = True,
                 inject=None,
                 watchdog=None,
                 hang_timeout: Optional[float] = None,
                 checkpoint_dir: Optional[str] = None,
                 selfcheck_interval: Optional[int] = None,
                 clock: Callable[[], float] = time.perf_counter):
        if oversize_policy not in ("singleton", "reject"):
            raise ValueError(f"oversize_policy {oversize_policy!r} not in "
                             f"('singleton', 'reject')")
        if granularity not in ("graph", "stripe", "slot"):
            raise ValueError(f"granularity {granularity!r} not in "
                             f"('graph', 'stripe', 'slot')")
        if queue_capacity < 1:
            raise ValueError("queue_capacity must be >= 1")
        if hang_timeout is not None and hang_timeout <= 0:
            raise ValueError("hang_timeout must be > 0 (or None)")
        self.cfg = cfg
        self.rungs = rungs
        self.params = fold_w_r(params, cfg)
        self.vmem_budget = vmem_budget
        self._block_g = rungs.block if block_g is None else block_g
        self._inject = inject
        # the backend degrade ladder: level 0 is the configured backend
        # (and the only level carrying the chaos inject hook); fusion
        # levels fall back to the two-pass packed path, which falls back
        # to the dense batched engine — the terminal, simplest backend.
        name0 = ("fused-network" if fused_network else
                 "fused-layer" if fused_layer else "two-pass")
        ladder = [{"name": name0, "fused_layer": fused_layer,
                   "fused_network": fused_network, "dense": False}]
        if fused_layer or fused_network:
            ladder.append({"name": "two-pass", "fused_layer": False,
                           "fused_network": False, "dense": False})
        ladder.append({"name": "dense", "fused_layer": False,
                       "fused_network": False, "dense": True})
        self._ladder = ladder
        self._degrade_level = 0
        self._level_runners: Dict[int, PackedRunner] = {}
        self._dense_step_fn = None
        self._dense_shapes: set = set()
        self._retired_compiles = 0
        self.guard = guard if guard is not None else ABFTGuard()
        self.watchdog = watchdog
        self.hang_timeout = hang_timeout
        self._ckpt = None
        if checkpoint_dir is not None:
            from repro.checkpoint.ckpt import CheckpointManager
            # synchronous writes: the save happens at the degrade moment,
            # where a half-written checkpoint racing the backend swap is
            # the last thing anyone wants
            self._ckpt = CheckpointManager(checkpoint_dir, keep=3,
                                           async_write=False)
        self._selfcheck = None
        if selfcheck_interval is not None:
            from repro.faults.selfcheck import CheckPathSelfCheck
            self._selfcheck = CheckPathSelfCheck(cfg,
                                                 interval=selfcheck_interval)
        self.queue_capacity = queue_capacity
        self.flush_deadline = flush_deadline
        self.oversize_policy = oversize_policy
        self.granularity = granularity
        self.keep_logits = keep_logits
        self.clock = clock
        self._bins: Dict[Rung, _OpenBin] = {}
        # one in-flight batch, tagged by dispatch kind:
        #   {"kind": "packed", "runner", "pb", "out", "metrics", "rids"}
        #   {"kind": "dense", "step", "batch", "items", "out", "metrics",
        #    "rids"}
        self._inflight: Optional[Dict[str, Any]] = None
        self._inflight_t: Optional[float] = None
        self._results: Dict[int, RequestResult] = {}
        self._done: List[RequestResult] = []
        # adjudicated batches whose logits / max_rel are still device
        # arrays; materialized lazily in take_results (the stats flush)
        self._pending_mat: List[Tuple[str, Any, Any, Any,
                                      List[Tuple[int, RequestResult]]]] = []
        self._next_rid = 0
        self.submitted = 0
        self.served = 0
        self.rejected = 0
        self.rejected_oversize = 0
        self.singleton_dispatches = 0
        self.batches_dispatched = 0
        self.fused_hits = 0
        self.fused_fallbacks = 0
        self.network_hits = 0
        self.network_fallbacks = 0
        self.degrades = 0
        self.failovers = 0
        self.dense_dispatches = 0
        self.hang_flushes = 0
        self.selfcheck_repairs = 0
        self._runner_for(0)           # eager level-0 runner (warmup path)

    # -- backend ladder ----------------------------------------------------

    @property
    def runner(self) -> PackedRunner:
        """The ACTIVE packed runner (the deepest packed level once the
        ladder has degraded all the way to dense)."""
        last_packed = len(self._ladder) - 2
        return self._runner_for(min(self._degrade_level, last_packed))

    def _runner_for(self, level: int) -> PackedRunner:
        spec = self._ladder[level]
        if spec["dense"]:
            raise ValueError("the dense ladder level has no packed runner")
        if level not in self._level_runners:
            self._level_runners[level] = PackedRunner(
                self.params, self.cfg, self._block_g,
                spec["fused_layer"], self.granularity,
                fused_network=spec["fused_network"],
                vmem_budget=self.vmem_budget,
                inject=self._inject if level == 0 else None)
        return self._level_runners[level]

    def _at_last_level(self) -> bool:
        return self._degrade_level >= len(self._ladder) - 1

    def _active_dense(self) -> bool:
        return self._ladder[self._degrade_level]["dense"]

    def _degrade(self, reason: str) -> None:
        """Swap to the next ladder level: checkpoint the folded params,
        advance, and reset the guard's per-backend state (its site
        classifications and rolling window describe the replaced
        execution path — lifetime counters stand)."""
        old = self._ladder[self._degrade_level]["name"]
        self._checkpoint(reason)
        self._degrade_level += 1
        self.degrades += 1
        self.guard.reset_backend_state()
        if self.watchdog is not None:
            # the streak judged the replaced backend; the fallback gets a
            # fresh verdict (the EWMA itself carries over: step-time scale
            # is a property of the workload more than the backend)
            self.watchdog.slow_streak = 0
        log.error("stream: degrading backend %s -> %s (%s); continuing "
                  "to serve", old,
                  self._ladder[self._degrade_level]["name"], reason)

    def _checkpoint(self, reason: str) -> None:
        if self._ckpt is None:
            return
        self._ckpt.save(self.batches_dispatched, self.params,
                        extra={"reason": reason,
                               "backend":
                                   self._ladder[self._degrade_level]["name"],
                               "degrade_level": self._degrade_level})

    def _failover(self, inf: Dict[str, Any], reason: str) -> None:
        """A batch the guard could not verify on this backend (persistent
        fault with the retry tiers and restore path exhausted): degrade
        and re-dispatch the SAME requests on the fallback, so the stream
        keeps serving with nothing dropped.  Raises only when the ladder
        is exhausted — the dense terminal backend failed too."""
        if self._at_last_level():
            raise RuntimeError(
                f"stream: backend ladder exhausted at "
                f"{self._ladder[-1]['name']!r} — {reason}")
        self.failovers += 1
        self._degrade(f"unverifiable batch: {reason}")
        now = self.clock()
        items = (list(inf["pb"].items) if inf["kind"] == "packed"
                 else inf["items"])
        rids = inf["rids"]
        if self._active_dense():
            self._dispatch_dense(items, rids, now)
        else:
            # packed operands are backend-independent: the same block-ELL
            # pack re-runs through the degraded level's kernels
            self._dispatch(inf["pb"], rids, now)

    # -- check-the-check ---------------------------------------------------

    def _maybe_selfcheck(self) -> None:
        """Sampled-cadence self-check of the checksum operands: re-derive
        every folded w_r bitwise; a mismatch means the CHECK path is
        corrupt (every verdict a lie), so refold and rebuild the jitted
        steps that baked the stale fold in at trace time."""
        if self._selfcheck is None:
            return
        bad = self._selfcheck.maybe_check(self.params,
                                          self.batches_dispatched)
        if bad:
            log.error("stream: check-path self-check tripped on layer(s) "
                      "%s — refolding w_r and rebuilding serve steps", bad)
            self.params = self._selfcheck.repair(self.params)
            self.selfcheck_repairs += 1
            self._rebuild_steps()

    def _rebuild_steps(self) -> None:
        """Discard every jitted step after a params repair (steps bake the
        params as trace-time constants); compile accounting stays
        cumulative so the bounded-compile contract still reports honestly."""
        self._retired_compiles += (
            sum(r.compile_count for r in self._level_runners.values())
            + len(self._dense_shapes))
        self._level_runners = {}
        self._dense_step_fn = None
        self._dense_shapes = set()

    # -- intake ------------------------------------------------------------

    def warmup(self) -> int:
        """Compile every rung's canonical shape up front (a one-node empty
        graph padded to the rung) so the first real batches don't pay the
        trace+compile inside their measured latency.  Returns the compile
        count afterwards."""
        feat = self.params["layers"][0]["w"].shape[0]
        probe = (np.zeros((1, 1), np.float32), np.zeros((1, feat),
                                                        np.float32))
        for r in self.rungs.rungs:
            pb = pack_graphs([probe], block=self.rungs.block,
                             n_slots=r.n_slots,
                             stripe_multiple=self.rungs.stripe_multiple,
                             width_multiple=self.rungs.width_multiple,
                             stripe_cap=r.stripe_cap, width_cap=r.width_cap)
            out, metrics = self.runner.step_for(pb)(*packed_step_args(pb))
            jax.block_until_ready(metrics["abft_graph_flags"])  # abftlint: sync-ok (warmup is the sync)
        return self.compile_count

    def submit(self, s: np.ndarray, h0: np.ndarray, *,
               now: Optional[float] = None) -> int:
        """Enqueue one request; returns its request id.

        Backpressure and oversize rejections resolve *immediately* (the
        result is already in ``take_results`` when submit returns);
        admitted requests resolve when their batch is adjudicated.
        ``now`` overrides the clock (deterministic deadline tests).
        """
        now = self.clock() if now is None else now
        self._sweep_deadlines(now)
        rid = self._next_rid
        self._next_rid += 1
        self.submitted += 1
        res = RequestResult(rid=rid, status="served", t_enqueue=now)
        self._results[rid] = res
        s = np.asarray(s)
        h0 = np.asarray(h0)
        stripes, width = graph_pack_stats(s, self.rungs.block)
        rung = self.rungs.fit(stripes, width)
        if rung is None:
            self._take_oversized(rid, s, h0, stripes, width, now)
            return rid
        if self._queued() >= self.queue_capacity:
            self._finish_rejected(
                res, "rejected",
                f"queue full ({self.queue_capacity} requests parked)", now)
            self.rejected += 1
            return rid
        b = self._bins.get(rung)
        if b is not None and (len(b.items) >= rung.n_slots
                              or b.load + stripes > rung.stripe_cap):
            self._seal(rung, now)
            b = None
        if b is None:
            b = _OpenBin(rung=rung, items=[], first_enqueue=now)
            self._bins[rung] = b
        b.items.append((rid, s, h0))
        b.load += stripes
        if len(b.items) >= rung.n_slots or b.load >= rung.stripe_cap:
            self._seal(rung, now)
        return rid

    def pump(self, now: Optional[float] = None) -> None:
        """Advance time-driven work: flush bins past the deadline, and
        force adjudication of an in-flight batch that has been pending
        past ``hang_timeout`` (a hung dispatch must resolve — blocking on
        the device sync surfaces the wedge to the guard/watchdog instead
        of letting the stream silently stall behind it).  Call
        periodically on a trickle stream (the driver calls it between
        arrivals)."""
        now = self.clock() if now is None else now
        if (self.hang_timeout is not None and self._inflight is not None
                and self._inflight_t is not None
                and now - self._inflight_t >= self.hang_timeout):
            self.hang_flushes += 1
            log.warning("stream: in-flight batch pending > hang_timeout="
                        "%.3fs; forcing adjudication", self.hang_timeout)
            self._resolve_inflight()
        self._sweep_deadlines(now)

    def drain(self, now: Optional[float] = None) -> List[RequestResult]:
        """Seal every open bin, adjudicate everything in flight, and return
        ALL completed results collected since the last ``take_results``."""
        now = self.clock() if now is None else now
        for rung in list(self._bins):
            self._seal(rung, now)
        self._drain_inflight()
        return self.take_results()

    def take_results(self) -> List[RequestResult]:
        """Completed verdicts since the last call (rid order)."""
        self._materialize_pending()
        done, self._done = self._done, []
        return sorted(done, key=lambda r: r.rid)

    # -- internals ---------------------------------------------------------

    def _queued(self) -> int:
        return sum(len(b.items) for b in self._bins.values())

    def _finish_rejected(self, res: RequestResult, status: str, reason: str,
                         now: float) -> None:
        res.status = status
        res.reason = reason
        res.t_verdict = now
        self._done.append(self._results.pop(res.rid))

    def _take_oversized(self, rid: int, s, h0, stripes: int, width: int,
                        now: float) -> None:
        res = self._results[rid]
        if self.oversize_policy == "reject":
            self._finish_rejected(
                res, "rejected_oversize",
                f"graph needs {stripes} stripes / width {width}; largest "
                f"rung is {self.rungs.rungs[-1]}", now)
            self.rejected_oversize += 1
            return
        if self._active_dense():
            # degraded to the terminal backend: the dense engine has no
            # rung limit, just its own power-of-two bucket ladder
            self.singleton_dispatches += 1
            self._dispatch_dense([(s, h0)], [rid], now)
            return
        # dedicated singleton shape: power-of-two quantized so repeat
        # offenders share compiles; the request still runs fully checked
        sq, wq = self.rungs.stripe_multiple, self.rungs.width_multiple
        pb = pack_graphs([(s, h0)], block=self.rungs.block, n_slots=1,
                         stripe_multiple=sq, width_multiple=wq,
                         stripe_cap=sq * next_pow2(-(-stripes // sq)),
                         width_cap=wq * next_pow2(-(-width // wq)),
                         indices=[rid])
        self.singleton_dispatches += 1
        self._dispatch(pb, [rid], now)

    def _sweep_deadlines(self, now: float) -> None:
        if self.flush_deadline is None:
            return
        for rung, b in list(self._bins.items()):
            if b.items and now - b.first_enqueue >= self.flush_deadline:
                self._seal(rung, now)

    def _seal(self, rung: Rung, now: float) -> None:
        b = self._bins.pop(rung, None)
        if b is None or not b.items:
            return
        # pack on the host FIRST (overlaps the in-flight batch's device
        # execution), then adjudicate the previous batch, then dispatch
        rids = [rid for rid, _, _ in b.items]
        items = [(s, h0) for _, s, h0 in b.items]
        if self._active_dense():
            self._dispatch_dense(items, rids, now)
            return
        pb = pack_graphs(items,
                         block=self.rungs.block, n_slots=rung.n_slots,
                         stripe_multiple=self.rungs.stripe_multiple,
                         width_multiple=self.rungs.width_multiple,
                         stripe_cap=rung.stripe_cap,
                         width_cap=rung.width_cap, indices=rids)
        self._dispatch(pb, rids, now)

    def _drain_inflight(self) -> None:
        """Resolve the in-flight batch AND any batch a failover re-
        dispatched in its place, until the line is clear: a dispatcher
        about to install its own in-flight entry must never clobber an
        unresolved one (the re-dispatched batch would silently never be
        adjudicated and its requests would hang)."""
        while self._inflight is not None:
            self._resolve_inflight()

    def _dispatch(self, pb: PackedGraphs, rids: List[int],
                  now: float) -> None:
        self._drain_inflight()
        if self._active_dense():
            # the resolution above degraded the ladder to its terminal
            # level mid-seal; this batch must follow, not run packed on
            # the replaced backend
            self._dispatch_dense(list(pb.items), rids, now)
            return
        self._maybe_selfcheck()
        runner = self.runner
        step = runner.step_for(pb)
        out, metrics = step(*packed_step_args(pb))   # async dispatch
        t = self.clock()
        for rid in rids:
            self._results[rid].t_dispatch = t
        self.batches_dispatched += 1
        for key, n in runner.fusion_counts(pb).items():
            setattr(self, key, getattr(self, key) + n)
        self._inflight = {"kind": "packed", "runner": runner, "pb": pb,
                          "out": out, "metrics": metrics, "rids": rids}
        self._inflight_t = t
        if self.watchdog is not None:
            self.watchdog.start()

    def _dispatch_dense(self, items: List[Tuple[np.ndarray, np.ndarray]],
                        rids: List[int], now: float) -> None:
        """Terminal ladder level: serve a bin through the dense batched
        engine.  Slot count and node bucket quantize up the power-of-two
        ladder so repeat shapes share compiles; pad slots are all-zero
        graphs, which contribute 0 = 0 to every check and can never
        flag."""
        self._drain_inflight()
        self._maybe_selfcheck()
        k = len(items)
        pad = next_pow2(k)
        bucket = next_pow2(max(s.shape[0] for s, _ in items))
        feat = items[0][1].shape[1]
        dt = np.result_type(*[s.dtype for s, _ in items])
        sub_s = np.zeros((pad, bucket, bucket), dt)
        sub_h = np.zeros((pad, bucket, feat),
                         np.result_type(*[h.dtype for _, h in items]))
        n_nodes = np.zeros(pad, np.int64)
        for i, (s, h0) in enumerate(items):
            n = s.shape[0]
            sub_s[i, :n, :n] = s
            sub_h[i, :n] = h0
            n_nodes[i] = n
        b = GraphBatch(s=sub_s, h0=sub_h, n_nodes=n_nodes, bucket=bucket,
                       indices=np.array(rids + [-1] * (pad - k)))
        if self._dense_step_fn is None:
            self._dense_step_fn = make_serve_step(self.params, self.cfg)
        self._dense_shapes.add((pad, bucket, feat))
        step = self._dense_step_fn
        out, metrics = step(jnp.asarray(b.s), jnp.asarray(b.h0))
        t = self.clock()
        for rid in rids:
            self._results[rid].t_dispatch = t
        self.batches_dispatched += 1
        self.dense_dispatches += 1
        self._inflight = {"kind": "dense", "step": step, "batch": b,
                          "items": list(items), "out": out,
                          "metrics": metrics, "rids": rids}
        self._inflight_t = t
        if self.watchdog is not None:
            self.watchdog.start()

    def _resolve_inflight(self) -> None:
        if self._inflight is None:
            return
        inf = self._inflight
        self._inflight = None
        self._inflight_t = None
        rids = inf["rids"]
        try:
            if inf["kind"] == "packed":
                runner, pb = inf["runner"], inf["pb"]
                stripe_retry = (runner.stripe_retry_fn(pb)
                                if self.granularity in ("stripe", "slot")
                                else None)
                slot_retry = (runner.slot_retry_fn(pb)
                              if self.granularity == "slot" else None)
                step = runner.step_for(pb)
                out, metrics = self.guard.adjudicate(
                    inf["out"], inf["metrics"], runner.retry_fn(pb),
                    stripe_retry_fn=stripe_retry,
                    slot_retry_fn=slot_retry,
                    replay=(step, packed_step_args(pb)))
            else:
                step, b = inf["step"], inf["batch"]
                out, metrics = self.guard.adjudicate(
                    inf["out"], inf["metrics"], dense_retry_fn(step, b),
                    replay=(step, (jnp.asarray(b.s), jnp.asarray(b.h0))))
        except RuntimeError as err:
            # the guard refused to adopt this batch on this backend
            # (persistent fault, restore path exhausted or absent):
            # degrade the ladder and re-dispatch the same requests there
            if self.watchdog is not None:
                self.watchdog.stop()
            self._failover(inf, str(err))
            return
        slow_streak = False
        if self.watchdog is not None:
            self.watchdog.stop()
            slow_streak = self.watchdog.should_reshard()
        t = self.clock()
        # the verdict itself costs one bounded host read per batch: the
        # guard just adjudicated on these same graph flags, so this
        # asarray is (re)reading an already-transferred vector
        gflags = np.asarray(metrics["abft_graph_flags"], bool)  # abftlint: sync-ok
        batch: List[Tuple[int, RequestResult]] = []
        for k, rid in enumerate(rids):
            res = self._results.pop(rid)
            res.status = "served"
            res.flag = bool(gflags[k])  # abftlint: sync-ok (host array, verdict read)
            res.t_verdict = t
            batch.append((k, res))
            self._done.append(res)
            self.served += 1
        # logits and per-request max_rel are NOT read here: converting
        # them per request would block the dispatch loop on a device
        # transfer mid-stream.  They stay device-side until the caller
        # collects results (take_results), by which point the transfer
        # overlaps nothing.
        payload = inf["pb"] if inf["kind"] == "packed" else inf["batch"]
        self._pending_mat.append((inf["kind"], out,
                                  metrics.get("abft_graph_max_rel"),
                                  payload, batch))
        # eviction advice: a suspect guard (persistent site classified),
        # an over-threshold rolling flag rate, or a straggling-dispatch
        # streak all advise swapping this backend.  The in-flight batch
        # just drained, so checkpoint + degrade NOW and keep serving on
        # the fallback.
        advice = []
        if self.guard.suspect:
            advice.append("guard suspect (persistent site classified)")
        elif self.guard.should_evict():
            advice.append("guard flag rate over evict threshold")
        if slow_streak:
            advice.append("watchdog slow-dispatch streak")
        if advice and not self._at_last_level():
            self._degrade("eviction advice: " + "; ".join(advice))

    def _materialize_pending(self) -> None:
        """The deferred device->host flush: one bulk transfer per
        adjudicated batch instead of per-request ``float()``/slice syncs
        in the dispatch hot loop."""
        for kind, out, grel, payload, batch in self._pending_mat:
            out_np = np.asarray(out) if self.keep_logits else None  # abftlint: sync-ok
            n_slots = (payload.n_slots if kind == "packed"
                       else payload.s.shape[0])
            grel_np = (np.zeros(n_slots, np.float32) if grel is None
                       else np.asarray(grel, np.float32))  # abftlint: sync-ok
            for k, res in batch:
                res.max_rel = float(grel_np[k])  # abftlint: sync-ok (host array, stats flush)
                if out_np is None:
                    continue
                if kind == "packed":
                    o, n = payload.row_offsets[k], payload.n_nodes[k]
                    res.logits = out_np[o:o + n].copy()
                else:
                    res.logits = out_np[k, :payload.n_nodes[k]].copy()
        self._pending_mat = []

    # -- accounting --------------------------------------------------------

    @property
    def compile_count(self) -> int:
        """Distinct jitted step shapes built so far, summed over every
        ladder level's runner plus the dense fallback's shape set (and the
        steps retired by a self-check rebuild) — the bounded-compile
        contract compares this against ``len(self.rungs)`` (+ the O(log)
        singleton/retry/degrade ladder shapes when those paths fired)."""
        return (self._retired_compiles
                + sum(r.compile_count
                      for r in self._level_runners.values())
                + len(self._dense_shapes))

    def stats(self, results: Optional[Sequence[RequestResult]] = None
              ) -> Dict[str, Any]:
        """Latency/throughput SLO summary over ``results`` (or everything
        completed-and-not-yet-taken plus nothing — pass the collected
        results for a whole-run view)."""
        rs = list(results) if results is not None else list(self._done)
        lat = np.asarray([r.latency for r in rs
                          if r.status == "served" and r.latency is not None])
        served = [r for r in rs if r.status == "served"]
        span = ((max(r.t_verdict for r in served)
                 - min(r.t_enqueue for r in served))
                if served else 0.0)
        return {
            "submitted": self.submitted,
            "served": len(served),
            "rejected": sum(r.status == "rejected" for r in rs),
            "rejected_oversize": sum(r.status == "rejected_oversize"
                                     for r in rs),
            "flagged": sum(bool(r.flag) for r in served),
            "batches": self.batches_dispatched,
            "singleton_dispatches": self.singleton_dispatches,
            "compiles": self.compile_count,
            "rung_table_size": len(self.rungs),
            "latency_p50_ms": float(np.percentile(lat, 50) * 1e3)
            if lat.size else None,
            "latency_p99_ms": float(np.percentile(lat, 99) * 1e3)
            if lat.size else None,
            "latency_max_ms": float(lat.max() * 1e3) if lat.size else None,
            "graphs_per_sec": len(served) / span if span > 0 else None,
            "guard_flags": self.guard.flags,
            "guard_retries": self.guard.retries,
            "fused_hits": self.fused_hits,
            "fused_fallbacks": self.fused_fallbacks,
            "network_hits": self.network_hits,
            "network_fallbacks": self.network_fallbacks,
            "repair_tiers": (self.guard.repair_tiers()
                             if hasattr(self.guard, "repair_tiers")
                             else {}),
            "backend_ladder": [lv["name"] for lv in self._ladder],
            "active_backend": self._ladder[self._degrade_level]["name"],
            "degrade_level": self._degrade_level,
            "degrades": self.degrades,
            "failovers": self.failovers,
            "dense_dispatches": self.dense_dispatches,
            "hang_flushes": self.hang_flushes,
            "watchdog_events": (self.watchdog.events
                                if self.watchdog is not None else 0),
            "selfcheck_runs": (self._selfcheck.checks_run
                               if self._selfcheck is not None else 0),
            "selfcheck_trips": (self._selfcheck.trips
                                if self._selfcheck is not None else 0),
            "selfcheck_repairs": self.selfcheck_repairs,
        }
