"""Guarded transformer LM serving on the checked-op protocol.

The same eq. 4–6 algebra that checks a GCN layer checks every linear
chain in a transformer step: QKV/attention-out/MLP matmuls are checked
ops (split corners via :func:`repro.models.common.dense`), attention is
the fused chain ``eᵀ(A V W_o)e = Σ o_extra`` with the carried column
``vr = V·w_or`` (:mod:`repro.models.attention`).  This module adds the
serving shell:

* :func:`fold_lm_w_r` — one offline pass at weight load folding every
  dense weight in the tree to its right checksum ``w_r`` (the paper's
  eq.-5 offline convention, tree-generic via
  :func:`repro.core.abft.fold_w_r_tree`).  The predicted side of every
  check then comes from the *master* weights, so post-load weight
  corruption is detectable.
* :func:`make_guarded_prefill_step` / :func:`make_guarded_decode_step`
  — jitted steps that emit per-op verdict vectors (``abft_op_flags``
  aligned to a static ``abft_op_ids`` tuple) alongside the scalar
  ``abft_flag``, in the metrics shape :class:`ABFTGuard` adjudicates.
* :class:`LMEngine` — holds the pristine master params host-side and
  serves prefill/decode under the guard's restore→retry→suspect ladder:
  a transient flag is retried, a persistent one refolds the working
  params from the master and replays, recurring ``op:<id>`` sites mark
  the backend suspect.

Checks are side computations: guarded logits are bit-identical to the
unguarded forward on clean runs.
"""
from __future__ import annotations

from typing import Any, Callable, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.core.abft import ABFTConfig, fold_w_r_tree, per_op_report
from repro.models.common import cdtype
from repro.models.transformer import (
    init_model,
    model_decode,
    model_prefill,
)
from repro.runtime.abft_guard import ABFTGuard, GuardConfig

Array = jax.Array
Params = Dict[str, Any]


# ---------------------------------------------------------------------------
# offline fold (eq. 5): every dense weight gains its right checksum
# ---------------------------------------------------------------------------

def fold_lm_w_r(params: Params, cfg: ModelConfig, abft: ABFTConfig) -> Params:
    """Fold right checksums into an LM param tree at weight load.

    Segment trees are layer-stacked on a leading axis (regardless of
    ``cfg.scan_layers`` — unrolled application slices them), so they fold
    with ``lead_axes=1``: ``w [L, d_in, *out] -> w_r [L, d_in]``, sliced
    per layer to the ``[d_in]`` vector :func:`~repro.models.common.dense`
    consumes.  The head folds flat.  Folds are taken through the compute
    dtype so the comparison sees the same quantization the product does.
    The embed table is left alone — the tied head checks against the
    table directly.  Returns a new tree; ``params`` is not mutated."""
    if not abft.enabled:
        return params
    cdt = cdtype(cfg)
    out = dict(params)
    out["segments"] = [fold_w_r_tree(seg, abft, lead_axes=1,
                                     compute_dtype=cdt)
                       for seg in params["segments"]]
    if "head" in params:
        out["head"] = fold_w_r_tree(params["head"], abft, compute_dtype=cdt)
    if "encoder" in params and isinstance(params["encoder"], dict):
        enc = dict(params["encoder"])
        if "segments" in enc:
            enc["segments"] = [fold_w_r_tree(seg, abft, lead_axes=1,
                                             compute_dtype=cdt)
                               for seg in enc["segments"]]
        out["encoder"] = enc
    return out


# ---------------------------------------------------------------------------
# guarded step factories — per-op verdicts in the guard's metrics shape
# ---------------------------------------------------------------------------

def _metrics(rep, checks, abft: ABFTConfig, ids_box: dict):
    ids, op_flags, op_rel = per_op_report(checks, abft, prefix="op")
    ids_box["ids"] = ids          # static; captured at trace time
    return {"abft_flag": rep.flag, "abft_max_rel": rep.max_rel,
            "abft_op_flags": op_flags, "abft_op_rel": op_rel}


def make_guarded_prefill_step(cfg: ModelConfig, abft: ABFTConfig,
                              cache_len: int) -> Callable:
    """Jitted ``step(params, batch, inject=0.0) -> ((logits, states),
    metrics)`` — the :meth:`ABFTGuard.run_step` shape, with per-op
    verdicts.  ``inject`` is the attention-accumulator fault operand
    (0.0 = clean); it is a runtime operand, not a trace constant.

    The static op-id tuple cannot cross the jit boundary, so it is
    captured in a box at trace time and attached to the metrics dict
    host-side after each call."""
    ids_box: dict = {"ids": ()}

    def _step(params, batch, inject):
        logits, states, rep, checks = model_prefill(
            params, cfg, batch, abft, cache_len,
            return_checks=True, attn_inject=inject)
        return (logits, states), _metrics(rep, checks, abft, ids_box)

    jitted = jax.jit(_step)

    def step(params, batch, inject=0.0):
        out, metrics = jitted(params, batch, jnp.float32(inject))
        metrics = dict(metrics)
        metrics["abft_op_ids"] = ids_box["ids"]
        return out, metrics

    step.traceable = jitted      # the string-free core, for abftlint traces
    step.ids_box = ids_box
    return step


def make_guarded_decode_step(cfg: ModelConfig, abft: ABFTConfig) -> Callable:
    """Jitted ``step(params, states, tokens, pos, inject=0.0) ->
    ((logits, states), metrics)`` with per-op verdicts (see
    :func:`make_guarded_prefill_step`)."""
    ids_box: dict = {"ids": ()}

    def _step(params, states, tokens, pos, inject):
        logits, new_states, rep, checks = model_decode(
            params, cfg, states, tokens, pos, abft,
            return_checks=True, attn_inject=inject)
        return (logits, new_states), _metrics(rep, checks, abft, ids_box)

    jitted = jax.jit(_step)

    def step(params, states, tokens, pos, inject=0.0):
        out, metrics = jitted(params, states, tokens,
                              jnp.asarray(pos, jnp.int32),
                              jnp.float32(inject))
        metrics = dict(metrics)
        metrics["abft_op_ids"] = ids_box["ids"]
        return out, metrics

    step.traceable = jitted      # the string-free core, for abftlint traces
    step.ids_box = ids_box
    return step


# ---------------------------------------------------------------------------
# engine
# ---------------------------------------------------------------------------

class LMEngine:
    """Guarded LM serving: prefill + decode under the ABFT ladder.

    Keeps the pristine master params host-side; the working copy carries
    the folded checksums.  ``restore_fn`` refolds from the master — this
    both rewinds any in-memory weight corruption and refreshes every
    ``w_r``, and its return value is adopted as the step's params operand
    by :meth:`ABFTGuard.run_step`'s checkpoint-rollback convention.
    """

    def __init__(self, cfg: ModelConfig, abft: ABFTConfig, params: Params,
                 *, cache_len: int = 128,
                 guard_cfg: Optional[GuardConfig] = None):
        self.cfg = cfg
        self.abft = abft
        self.cache_len = cache_len
        self._master = params
        self.params = fold_lm_w_r(params, cfg, abft)
        self.guard = ABFTGuard(guard_cfg or GuardConfig(),
                               restore_fn=self._restore)
        self._prefill = make_guarded_prefill_step(cfg, abft, cache_len)
        self._decode = make_guarded_decode_step(cfg, abft)

    @classmethod
    def init(cls, cfg: ModelConfig, abft: ABFTConfig, key, **kw
             ) -> "LMEngine":
        return cls(cfg, abft, init_model(cfg, key), **kw)

    def _restore(self) -> Params:
        self.params = fold_lm_w_r(self._master, self.cfg, self.abft)
        return self.params

    @staticmethod
    def _fire_once(inject: float):
        """A transient fault strikes one execution, not every replay: the
        inject operand is consumed by the first attempt, so the guard's
        retry re-executes clean (persistent faults live in the params and
        survive retries on their own)."""
        box = {"v": float(inject)}

        def pop():
            v, box["v"] = box["v"], 0.0
            return v
        return pop

    def prefill(self, tokens: Array, *, inject: float = 0.0
                ) -> Tuple[Array, List[Params], dict]:
        """Run the prompt under the guard.  Returns (last-token logits,
        decode states, metrics)."""
        pop = self._fire_once(inject)
        (logits, states), m = self.guard.run_step(
            lambda params, batch: self._prefill(params, batch, pop()),
            self.params, {"tokens": tokens})
        return logits, states, m

    def decode(self, states: List[Params], tokens: Array, pos,
               *, inject: float = 0.0
               ) -> Tuple[Array, List[Params], dict]:
        """One guarded decode step.  tokens: [B,1]; pos: scalar."""
        pop = self._fire_once(inject)
        (logits, new_states), m = self.guard.run_step(
            lambda params, states_, tokens_, pos_:
                self._decode(params, states_, tokens_, pos_, pop()),
            self.params, states, tokens, pos)
        return logits, new_states, m

    def generate(self, tokens: Array, n_steps: int,
                 *, inject_at: Optional[int] = None,
                 inject_delta: float = 0.0) -> Tuple[Array, dict]:
        """Greedy generation loop: prefill then ``n_steps`` decode steps.
        ``inject_at`` fires the accumulator fault operand on that decode
        step (−1 = during prefill).  Returns ([B, n_steps] token ids,
        final stats)."""
        b, t = tokens.shape
        inj = inject_delta if inject_at == -1 else 0.0
        logits, states, _ = self.prefill(tokens, inject=inj)
        outs = []
        for i in range(n_steps):
            nxt = jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32)
            outs.append(nxt)
            inj = inject_delta if inject_at == i else 0.0
            logits, states, _ = self.decode(states, nxt[:, None], t + i,
                                            inject=inj)
        return jnp.stack(outs, axis=1), self.stats()

    def stats(self) -> dict:
        s = {"steps": self.guard.steps, "flags": self.guard.flags,
             "retries": self.guard.retries, "restores": self.guard.restores,
             "flag_rate": self.guard.flag_rate}
        s.update(self.guard.repair_tiers())
        return s
