"""Analytic operation-count model for ABFT variants (paper Table II).

Conventions (reverse-engineered from the paper's "True Out" column, which we
match to <1 % — see datasets.py header):

  * multiplications and additions are counted equally (a MAC = 2 ops);
  * a sparse @ dense matmul with nnz nonzeros in the sparse operand and G
    output columns costs 2·nnz·G;
  * a dense [M,K] @ [K,G] matmul costs 2·M·K·G;
  * the combination step of layer 1 uses the *sparse* feature matrix
    (combination-first dataflow, as in the paper's accelerators);
  * augmented-systolic convention: multiplying enhanced matrices computes the
    *full* extra checksum row and column (eqs. 2/3/5/6), not just the corner;
  * offline checksums are free at inference time: w_r = W e always, and
    s_c = e^T S for static graphs;
  * the online actual checksum (grand sum of an output with M·G entries)
    costs M·G additions;
  * the final comparison is 1 op (ignored, sub-ppm).

Split ABFT per layer (S:[N,N] nnz_s, H:[N,F] nnz_h (or dense), W:[F,G]):
  check 1 (X = H W):      h_c = e^T H            nnz_h   adds   (online!)
                          extra col  H w_r       2·nnz_h
                          extra row  h_c [W|w_r] 2·F·(G+1)
                          actual     sum(X)      N·G
  check 2 (H_out = S X):  extra col  S x_r       2·nnz_s
                          extra row  s_c [X|x_r] 2·N·(G+1)
                          actual     sum(H_out)  N·G

GCN-ABFT per layer:
  first multiply:         extra col  H w_r       2·nnz_h      (eq. 5 — only this)
  second multiply:        extra col  S x_r       2·nnz_s
                          extra row  s_c [X|x_r] 2·N·(G+1)
                          actual     sum(H_out)  N·G          (eq. 6)

Savings = split − fused = nnz_h + 2·F·(G+1) + N·G per layer: exactly the
paper's narrative — no h_c state, no first-step actual checksum.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional

from .datasets import STATS, GraphStats


@dataclasses.dataclass(frozen=True)
class LayerShape:
    n: int          # nodes (rows of S and H)
    f: int          # input features
    g: int          # output features
    nnz_s: int      # nonzeros of S (adjacency + self loops)
    nnz_h: int      # nonzeros of H (== n*f when dense)

    @property
    def h_dense(self) -> bool:
        return self.nnz_h == self.n * self.f


def gcn_layer_shapes(stats: GraphStats) -> List[LayerShape]:
    """Two-layer GCN as evaluated in the paper (layer 2 input is dense)."""
    f, h, c = stats.layer_dims
    return [
        LayerShape(stats.nodes, f, h, stats.adj_nnz, stats.feat_nnz),
        LayerShape(stats.nodes, h, c, stats.adj_nnz, stats.nodes * h),
    ]


def true_ops(ls: LayerShape) -> int:
    comb = 2 * ls.nnz_h * ls.g          # X = H W   (sparse or dense H)
    agg = 2 * ls.nnz_s * ls.g           # H_out = S X
    return comb + agg


def split_check_ops(ls: LayerShape, h_static: bool = False) -> int:
    """``h_static``: layer-1 input features are known statically, so h_c is
    computed offline — the paper states this explicitly ("except only for the
    first GCN layer")."""
    ops = 0
    if not h_static:
        ops += ls.nnz_h                  # h_c = e^T H  (online)
    ops += 2 * ls.nnz_h                  # H w_r extra column
    ops += 2 * ls.f * (ls.g + 1)         # h_c @ [W | w_r] extra row
    ops += ls.n * ls.g                   # actual sum(X)
    ops += 2 * ls.nnz_s                  # S x_r extra column
    ops += 2 * ls.n * (ls.g + 1)         # s_c @ [X | x_r] extra row
    ops += ls.n * ls.g                   # actual sum(H_out)
    return ops


def fused_check_ops(ls: LayerShape) -> int:
    ops = 0
    ops += 2 * ls.nnz_h                  # H w_r extra column (eq. 5)
    ops += 2 * ls.nnz_s                  # S x_r extra column
    ops += 2 * ls.n * (ls.g + 1)         # s_c @ [X | x_r] extra row
    ops += ls.n * ls.g                   # actual sum(H_out)
    return ops


@dataclasses.dataclass(frozen=True)
class OpCounts:
    name: str
    true_out: int
    split_check: int
    fused_check: int

    @property
    def split_total(self) -> int:
        return self.true_out + self.split_check

    @property
    def fused_total(self) -> int:
        return self.true_out + self.fused_check

    @property
    def check_savings(self) -> float:
        return 1.0 - self.fused_check / self.split_check

    @property
    def total_savings(self) -> float:
        return 1.0 - self.fused_total / self.split_total


def gcn_op_counts(name: str, stats: Optional[GraphStats] = None) -> OpCounts:
    st = stats or STATS[name]
    layers = gcn_layer_shapes(st)
    return OpCounts(
        name=st.name,
        true_out=sum(true_ops(l) for l in layers),
        split_check=sum(split_check_ops(l, h_static=(i == 0))
                        for i, l in enumerate(layers)),
        fused_check=sum(fused_check_ops(l) for l in layers),
    )


def all_gcn_op_counts() -> Dict[str, OpCounts]:
    return {n: gcn_op_counts(n) for n in STATS}


# ---------------------------------------------------------------------------
# Per-site op counts — drives fault-injection site sampling (site chosen
# proportionally to its op count, per the paper's setup section).
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class SiteOps:
    layer: int
    phase: str      # 'comb' | 'agg'
    target: str     # 'mm' | 'check'
    ops: int


def fault_sites(stats: GraphStats, mode: str) -> List[SiteOps]:
    sites: List[SiteOps] = []
    for i, ls in enumerate(gcn_layer_shapes(stats)):
        sites.append(SiteOps(i, "comb", "mm", 2 * ls.nnz_h * ls.g))
        sites.append(SiteOps(i, "agg", "mm", 2 * ls.nnz_s * ls.g))
        if mode == "split":
            h_c = 0 if i == 0 else ls.nnz_h   # layer-1 h_c is offline
            comb_chk = h_c + 2 * ls.nnz_h + 2 * ls.f * (ls.g + 1) + ls.n * ls.g
            agg_chk = 2 * ls.nnz_s + 2 * ls.n * (ls.g + 1) + ls.n * ls.g
        elif mode == "fused":
            comb_chk = 2 * ls.nnz_h
            agg_chk = 2 * ls.nnz_s + 2 * ls.n * (ls.g + 1) + ls.n * ls.g
        else:
            comb_chk = agg_chk = 0
        if comb_chk:
            sites.append(SiteOps(i, "comb", "check", comb_chk))
        if agg_chk:
            sites.append(SiteOps(i, "agg", "check", agg_chk))
    return sites


# ---------------------------------------------------------------------------
# Beyond-paper: ABFT op counts for transformer linear-chain sites.
# Used by benchmarks/abft_overhead.py to show the paper's savings transpose
# to attention (A·V·W_o) and MoE (C·G·W2) chains.  Dims per layer; batch*seq
# = t tokens, h heads, dh head dim, d model dim.
# ---------------------------------------------------------------------------

def attention_chain_counts(t: int, h: int, dh: int, d: int) -> Dict[str, int]:
    """Ops for checking O = A·(X W_v)·W_o per layer (single sequence)."""
    true = 2 * t * t * h * dh * 2 + 2 * t * d * (3 * h * dh) + 2 * t * h * dh * d
    # split: check qk^T? (not a chain member), AV, (AV)Wo, XWv separately.
    split = 0
    split += t * h * dh + 2 * t * h * dh + 2 * h * t * (dh + 1) + t * h * dh  # AV check
    split += t * h * dh + 2 * t * h * dh + 2 * h * dh * (d + 1) + t * d      # (AV)Wo
    split += t * d + 2 * t * d + 2 * d * (h * dh + 1) + t * h * dh           # XWv
    # fused chain (e^T A)·V·(W_o e): col-sums of A accumulate online in the
    # flash pass (t*t*h adds), then s_c·V (2 t h dh), fold through W_o offline.
    fused = t * t * h + 2 * t * h * dh + 2 * h * dh + t * d
    # plus split check on XWv (chain broken upstream by softmax? no — V=XW_v is
    # inside the chain; the fused check covers it end-to-end).
    return {"true": true, "split": split, "fused": fused}


def moe_chain_counts(t: int, k: int, e_cap: int, dff: int, d: int) -> Dict[str, int]:
    """Ops for checking Y = C·G·W2 (combine, per layer)."""
    nnz_c = t * k
    true = 2 * e_cap * dff * d + 2 * nnz_c * d
    split = (e_cap * dff + 2 * e_cap * dff + 2 * dff * (d + 1) + e_cap * d
             + 2 * nnz_c + 2 * t * (d + 1) + t * d)
    fused = 2 * e_cap * dff + 2 * nnz_c + 2 * t * (d + 1) + t * d
    return {"true": true, "split": split, "fused": fused}
