"""Fault-injection engine reproducing the paper's Table I campaign.

Faithful to the paper's setup (§IV-A):
  * single random bit flips in the *results of arithmetic operations* —
    multiplies and adds inside matrix multiplication (float32) and checksum
    accumulation (float64);
  * injection site chosen proportionally to its operation count (faults are
    more likely in longer-running steps), time point uniform within the site;
  * memory assumed protected (inputs fault-free);
  * categories at the end of a layer: detected / false positive / silent;
  * absolute detection thresholds swept over 1e-4 .. 1e-7;
  * criticality: a fault is critical if it flips the argmax class of ≥1 node;
    we also record how many nodes flip (paper's "Avg. Nodes Affected").

Implementation note — the *prefix-delta model*: flipping a bit of the running
partial sum at accumulation step t changes the final element by exactly
``delta = flip(p_t) - p_t`` (the remaining additions are unaffected by where
the perturbation entered, modulo O(eps) re-rounding).  This makes a campaign
cost one prefix dot product instead of an O(ops) scalar-level emulation, so
thousands of campaigns run in CPU-budget.  Downstream criticality is computed
by exact sparse *delta propagation* through the remaining layers (ReLU
re-evaluated on affected entries only).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from .datasets import Coo, GraphDataset
from .opcount import SiteOps, fault_sites, gcn_layer_shapes

THRESHOLDS = (1e-4, 1e-5, 1e-6, 1e-7)


# ---------------------------------------------------------------------------
# bit flips
# ---------------------------------------------------------------------------

def flip_bit_f32(x: np.float32, bit: int) -> np.float32:
    i = np.float32(x).view(np.uint32) ^ np.uint32(1 << bit)
    return i.view(np.float32)


def flip_bit_f64(x: np.float64, bit: int) -> np.float64:
    i = np.float64(x).view(np.uint64) ^ np.uint64(1 << bit)
    return i.view(np.float64)


# ---------------------------------------------------------------------------
# fault-free forward with cached intermediates + checksum state
# ---------------------------------------------------------------------------

def glorot_weights(dims: Sequence[int], seed: int = 0) -> List[np.ndarray]:
    rng = np.random.default_rng(seed)
    ws = []
    for fin, fout in zip(dims[:-1], dims[1:]):
        s = np.sqrt(6.0 / (fin + fout))
        ws.append(rng.uniform(-s, s, size=(fin, fout)).astype(np.float32))
    return ws


@dataclasses.dataclass
class LayerState:
    h_in: object                 # Coo (layer 0) or dense np.ndarray
    w: np.ndarray                # [F, G] f32
    x: np.ndarray                # X = H W           (pre-aggregation)
    h_out: np.ndarray            # H_out = S X       (pre-activation)
    # f64 checksum state
    w_r: np.ndarray              # W e
    h_c: np.ndarray              # e^T H (split check state)
    x_r: np.ndarray              # H w_r  (shared by split chk2 and fused)
    sum_x: float                 # actual checksum of X (split chk1)
    sum_hout: float              # actual checksum of H_out
    pred1: float                 # h_c . w_r
    pred2: float                 # s_c . x_r  (== fused prediction)


class NumpyGCN:
    """Fault-free reference forward over a GraphDataset (combination-first)."""

    def __init__(self, ds: GraphDataset, weights: Optional[List[np.ndarray]] = None,
                 seed: int = 0):
        self.ds = ds
        dims = ds.stats.layer_dims
        self.weights = weights or glorot_weights(dims, seed)
        self.s_c = ds.s.col_sums()                       # e^T S (f64, offline)
        self.layers: List[LayerState] = []
        h: object = ds.features
        for k, w in enumerate(self.weights):
            if isinstance(h, Coo):
                x = h.matmul_dense(w)
                h_c = h.col_sums()                        # f64
                w_r = w.astype(np.float64).sum(axis=1)
                x_r = np.zeros(h.shape[0], np.float64)    # x_r = H w_r (f64)
                np.add.at(x_r, h.row, h.data.astype(np.float64) * w_r[h.col])
            else:
                x = h @ w
                h_c = h.astype(np.float64).sum(axis=0)
                w_r = w.astype(np.float64).sum(axis=1)
                x_r = h.astype(np.float64) @ w_r
            h_out = ds.s.matmul_dense(x)
            st = LayerState(
                h_in=h, w=w, x=x, h_out=h_out,
                w_r=w_r, h_c=h_c, x_r=x_r,
                sum_x=float(x.astype(np.float64).sum()),
                sum_hout=float(h_out.astype(np.float64).sum()),
                pred1=float(h_c @ w_r),
                pred2=float(self.s_c @ x_r),
            )
            self.layers.append(st)
            h = np.maximum(h_out, 0.0) if k < len(self.weights) - 1 else h_out
        self.logits = h
        self.pred_cls = np.argmax(self.logits, axis=1)

    # -- accumulation-order prefixes -------------------------------------

    def comb_prefix(self, k: int, i: int, j: int, t: int) -> Tuple[np.float32, np.float32]:
        """(partial sum after t MACs, t-th product) of X_k[i, j]."""
        st = self.layers[k]
        if isinstance(st.h_in, Coo):
            cols, vals = st.h_in.row_slice(i)
        else:
            cols, vals = np.arange(st.h_in.shape[1]), st.h_in[i]
        terms = (vals.astype(np.float32) * st.w[cols, j]).astype(np.float32)
        part = np.float32(terms[: t + 1].sum(dtype=np.float32))
        return part, np.float32(terms[t])

    def agg_prefix(self, k: int, i: int, j: int, t: int) -> Tuple[np.float32, np.float32]:
        st = self.layers[k]
        cols, vals = self.ds.s.row_slice(i)
        terms = (vals.astype(np.float32) * st.x[cols, j]).astype(np.float32)
        part = np.float32(terms[: t + 1].sum(dtype=np.float32))
        return part, np.float32(terms[t])

    def comb_terms(self, k: int, i: int) -> int:
        st = self.layers[k]
        if isinstance(st.h_in, Coo):
            indptr, _, _ = st.h_in.csr()
            return max(int(indptr[i + 1] - indptr[i]), 1)
        return st.h_in.shape[1]

    def agg_terms(self, i: int) -> int:
        indptr, _, _ = self.ds.s.csr()
        return max(int(indptr[i + 1] - indptr[i]), 1)


# ---------------------------------------------------------------------------
# delta propagation for criticality
# ---------------------------------------------------------------------------

def _propagate(model: NumpyGCN, k: int, rows: np.ndarray, cols_j: int,
               dvals: np.ndarray) -> Tuple[bool, int]:
    """Exact effect of H_out_k[rows, j] += dvals on the final argmax.

    Returns (critical?, #nodes whose class flips).  Sparse all the way:
    only affected rows are recomputed.
    """
    ds = model.ds
    n_layers = len(model.layers)
    # current sparse delta on H_out_k: (rows, single column j, dvals).
    # rows must be sorted & unique (searchsorted below relies on it).
    order = np.argsort(rows)
    cur_rows, cur_j, cur_vals = rows[order], cols_j, dvals[order].astype(np.float32)
    for kk in range(k, n_layers):
        st = model.layers[kk]
        last = kk == n_layers - 1
        if kk > k:
            # delta arrived on X_kk (dense rows x all cols): aggregate S @ dX
            dx_rows, dx = cur_rows, cur_dense          # [m, G]
            mask = np.isin(ds.s.col, dx_rows)
            r_idx = ds.s.row[mask]
            c_idx = ds.s.col[mask]
            v = ds.s.data[mask]
            pos = np.searchsorted(dx_rows, c_idx)
            contrib = v[:, None] * dx[pos]
            out_rows = np.unique(r_idx)
            acc = np.zeros((out_rows.size, dx.shape[1]), np.float32)
            np.add.at(acc, np.searchsorted(out_rows, r_idx), contrib)
            hout_rows, hout_delta = out_rows, acc      # full-width delta
        else:
            hout_rows = cur_rows
            hout_delta = None                          # single-column delta
        if last:
            if hout_delta is None:
                new = model.logits[hout_rows].copy()
                new[:, cur_j] += cur_vals
            else:
                new = model.logits[hout_rows] + hout_delta
            flips = int((np.argmax(new, axis=1)
                         != model.pred_cls[hout_rows]).sum())
            return flips > 0, flips
        # ReLU re-evaluation on affected entries, then push through W_{kk+1}
        nxt = model.layers[kk + 1]
        if hout_delta is None:
            old = st.h_out[hout_rows, cur_j]
            dh = np.maximum(old + cur_vals, 0.0) - np.maximum(old, 0.0)
            keep = dh != 0.0
            rows2 = hout_rows[keep]
            if rows2.size == 0:
                return False, 0
            cur_dense = dh[keep, None].astype(np.float32) * nxt.w[cur_j][None, :]
            cur_rows = rows2
        else:
            old = st.h_out[hout_rows]
            dh = np.maximum(old + hout_delta, 0.0) - np.maximum(old, 0.0)
            keep = np.any(dh != 0.0, axis=1)
            rows2 = hout_rows[keep]
            if rows2.size == 0:
                return False, 0
            cur_dense = dh[keep].astype(np.float32) @ nxt.w
            cur_rows = rows2
    return False, 0


# ---------------------------------------------------------------------------
# campaigns
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class CampaignOutcome:
    mode: str
    target: str                  # 'mm' | 'check'
    output_corrupted: bool
    critical: bool
    nodes_flipped: int
    diffs: Dict[float, bool]     # threshold -> flagged?


def _flag(diff: float, tau: float) -> bool:
    # NaN/Inf in a checksum must flag (real divergence), hence the negation.
    return not (abs(diff) <= tau)


def _sample_element(rng, n_rows: int, n_cols: int) -> Tuple[int, int]:
    return int(rng.integers(n_rows)), int(rng.integers(n_cols))


def run_campaign(model: NumpyGCN, mode: str, rng: np.random.Generator,
                 thresholds: Sequence[float] = THRESHOLDS,
                 mm_bias: float = 1.0) -> CampaignOutcome:
    """Inject one fault under ABFT policy ``mode`` ('split' | 'fused').

    ``mm_bias`` scales the probability of hitting the matmul datapath
    relative to op-count-proportional sampling.  1.0 = pure op counts (our
    default).  The paper's accelerator has a wide MAC array vs a one-column
    checker, so its effective bias is larger; benchmarks report both.
    """
    ds = model.ds
    sites = fault_sites(ds.stats, mode)
    weights = np.array([s.ops * (mm_bias if s.target == "mm" else 1.0)
                        for s in sites], np.float64)
    site = sites[rng.choice(len(sites), p=weights / weights.sum())]
    st = model.layers[site.layer]
    n, g = st.h_out.shape

    # residuals of the fault-free run (float rounding noise floor)
    r1 = st.sum_x - st.pred1
    r2 = st.sum_hout - st.pred2

    if site.target == "mm":
        if site.phase == "comb":
            i, j = _sample_element(rng, st.x.shape[0], st.x.shape[1])
            nt = model.comb_terms(site.layer, i)
            t = int(rng.integers(nt))
            part, prod = model.comb_prefix(site.layer, i, j, t)
            victim = part if rng.integers(2) else prod     # add vs multiply
            delta = float(flip_bit_f32(victim, int(rng.integers(32)))) - float(victim)
            # detection: chk1 sees delta in sum(X); chk2/fused see the
            # aggregated delta sum(S[:, i]) * delta in sum(H_out).
            d1 = r1 + delta
            agg_gain = float(model.s_c[i])
            d2 = r2 + delta * agg_gain
            if mode == "split":
                flags = {tau: _flag(d1, tau) or _flag(d2, tau) for tau in thresholds}
            else:
                flags = {tau: _flag(d2, tau) for tau in thresholds}
            # criticality: delta lands on X[i,j] -> H_out[:, j] += S[:, i]*delta
            rows, vals = ds.s_col(i)
            crit, flips = _propagate(model, site.layer, rows,
                                     j, vals.astype(np.float64) * delta)
            corrupted = delta != 0.0
        else:  # 'agg': fault in H_out[i, j]
            i, j = _sample_element(rng, n, g)
            nt = model.agg_terms(i)
            t = int(rng.integers(nt))
            part, prod = model.agg_prefix(site.layer, i, j, t)
            victim = part if rng.integers(2) else prod
            delta = float(flip_bit_f32(victim, int(rng.integers(32)))) - float(victim)
            d2 = r2 + delta
            if mode == "split":
                flags = {tau: _flag(r1, tau) or _flag(d2, tau) for tau in thresholds}
            else:
                flags = {tau: _flag(d2, tau) for tau in thresholds}
            crit, flips = _propagate(model, site.layer, np.array([i]), j,
                                     np.array([delta]))
            corrupted = delta != 0.0
        return CampaignOutcome(mode, "mm", corrupted, crit, flips, flags)

    # --- checksum-accumulation fault (float64 state) ----------------------
    # choose which accumulator ∝ its op share within this site
    accs: List[Tuple[str, float]] = []
    ls = gcn_layer_shapes(ds.stats)[site.layer]
    if site.phase == "comb":
        if mode == "split":
            if site.layer > 0:
                accs.append(("h_c", ls.nnz_h))
            accs.append(("x_r", 2 * ls.nnz_h))
            accs.append(("pred1", 2 * ls.f * (ls.g + 1)))
            accs.append(("sum_x", ls.n * ls.g))
        else:
            accs.append(("x_r", 2 * ls.nnz_h))
    else:
        accs.append(("sx_r", 2 * ls.nnz_s))
        accs.append(("pred2", 2 * ls.n * (ls.g + 1)))
        accs.append(("sum_hout", ls.n * ls.g))
    w = np.array([a[1] for a in accs], np.float64)
    which = accs[rng.choice(len(accs), p=w / w.sum())][0]
    bit = int(rng.integers(64))

    def f64_delta(value: float) -> float:
        return float(flip_bit_f64(np.float64(value), bit)) - float(value)

    d1, d2 = r1, r2
    if which == "h_c":
        # corrupts predicted1 via one h_c component: pred1 = Σ h_c[c] w_r[c]
        c = int(rng.integers(st.h_c.size))
        # flip a prefix of the h_c[c] accumulation — approximate the partial
        # by a uniform fraction of the final value (distribution-equivalent
        # for the magnitudes that matter).
        frac = rng.uniform()
        dd = f64_delta(st.h_c[c] * frac) * float(st.w_r[c])
        d1 = r1 - dd
    elif which == "x_r":
        c = int(rng.integers(st.x_r.size))
        dd = f64_delta(st.x_r[c] * rng.uniform())
        d2 = r2 - dd * float(model.s_c[c])
    elif which == "pred1":
        d1 = r1 - f64_delta(st.pred1 * rng.uniform())
    elif which == "sx_r":
        # extra column S x_r — feeds the (unused-for-flagging) upper right
        # block; corrupts nothing the scalar check reads.  Still an injected
        # checksum op per the paper; flags only via rounding floor.
        pass
    elif which == "pred2":
        d2 = r2 - f64_delta(st.pred2 * rng.uniform())
    elif which == "sum_x":
        d1 = r1 + f64_delta(st.sum_x * rng.uniform())
    elif which == "sum_hout":
        d2 = r2 + f64_delta(st.sum_hout * rng.uniform())

    if mode == "split":
        flags = {tau: _flag(d1, tau) or _flag(d2, tau) for tau in thresholds}
    else:
        flags = {tau: _flag(d2, tau) for tau in thresholds}
    return CampaignOutcome(mode, "check", False, False, 0, flags)


@dataclasses.dataclass
class CampaignSummary:
    mode: str
    n: int
    detected: Dict[float, float]
    false_pos: Dict[float, float]
    silent: Dict[float, float]
    masked: Dict[float, float]
    critical_rate: float          # over output-corrupting faults
    avg_nodes_affected: float     # % of nodes flipped, over critical faults


def run_campaigns(model: NumpyGCN, mode: str, n: int, seed: int = 0,
                  thresholds: Sequence[float] = THRESHOLDS,
                  mm_bias: float = 1.0) -> CampaignSummary:
    """Paper taxonomy (§IV-A): every campaign falls into exactly one of
    detected / false-positive / silent per threshold:
      * matmul fault, flagged      -> detected
      * matmul fault, unflagged    -> silent
      * checksum fault, flagged    -> false positive
      * checksum fault, unflagged  -> silent (no separate 'benign' bucket;
        ``masked`` tracks this sub-population for analysis)
    """
    rng = np.random.default_rng(seed)
    det = {t: 0 for t in thresholds}
    fp = {t: 0 for t in thresholds}
    sil = {t: 0 for t in thresholds}
    msk = {t: 0 for t in thresholds}
    crit = 0
    corrupted = 0
    node_pcts: List[float] = []
    n_nodes = model.ds.stats.nodes
    for _ in range(n):
        o = run_campaign(model, mode, rng, thresholds, mm_bias=mm_bias)
        if o.target == "mm" and o.output_corrupted:
            corrupted += 1
            if o.critical:
                crit += 1
                node_pcts.append(100.0 * o.nodes_flipped / n_nodes)
        for t in thresholds:
            flagged = o.diffs[t]
            if o.target == "mm" and o.output_corrupted:
                if flagged:
                    det[t] += 1
                else:
                    sil[t] += 1
            else:
                if flagged:
                    fp[t] += 1
                else:
                    sil[t] += 1
                    msk[t] += 1
    pct = lambda d: {t: 100.0 * v / n for t, v in d.items()}
    return CampaignSummary(
        mode=mode, n=n,
        detected=pct(det), false_pos=pct(fp), silent=pct(sil), masked=pct(msk),
        critical_rate=100.0 * crit / max(corrupted, 1),
        avg_nodes_affected=float(np.mean(node_pcts)) if node_pcts else 0.0,
    )


# ---------------------------------------------------------------------------
# numpy full-batch training — the paper evaluates *trained* GCNs, and trained
# weights set the activation magnitudes that detection thresholds see.
# ---------------------------------------------------------------------------

def train_weights_numpy(ds: GraphDataset, epochs: int = 100, lr: float = 0.5,
                        seed: int = 0) -> List[np.ndarray]:
    """Full-batch GD on softmax cross-entropy over the synthetic labels.
    2-layer combination-first GCN; S is symmetric so S^T = S."""
    dims = ds.stats.layer_dims
    ws = glorot_weights(dims, seed)
    h0, s = ds.features, ds.s
    y = ds.labels
    n = ds.stats.nodes
    onehot = np.zeros((n, dims[-1]), np.float32)
    onehot[np.arange(n), y] = 1.0

    def sp_T_dense(coo: Coo, m: np.ndarray) -> np.ndarray:
        """coo^T @ m  (scatter over transposed indices)."""
        out = np.zeros((coo.shape[1], m.shape[1]), np.float32)
        np.add.at(out, coo.col, coo.data[:, None] * m[coo.row])
        return out

    for _ in range(epochs):
        x1 = h0.matmul_dense(ws[0])
        a1 = s.matmul_dense(x1)
        h1 = np.maximum(a1, 0.0)
        x2 = h1 @ ws[1]
        z = s.matmul_dense(x2)
        zs = z - z.max(1, keepdims=True)
        p = np.exp(zs)
        p /= p.sum(1, keepdims=True)
        dz = (p - onehot) / n
        dx2 = s.matmul_dense(dz)            # S^T = S
        dw2 = h1.T @ dx2
        dh1 = dx2 @ ws[1].T
        da1 = dh1 * (a1 > 0)
        dx1 = s.matmul_dense(da1)
        dw1 = sp_T_dense(h0, dx1)
        ws[0] -= lr * dw1
        ws[1] -= lr * dw2
    return ws
