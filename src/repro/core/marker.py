"""Check-sink tagging: a trace-time marker that makes ABFT coverage
statically verifiable.

``abftlint``'s coverage pass (``repro.analysis.coverage``) proves that
every matmul in a traced step flows into an eq. 4-6 checksum comparison.
"Flows into a comparison" must be a property of the *jaxpr*, not of the
Python source, so the comparison site needs a recognizable footprint in
the trace.  This module provides it:

* :data:`check_sink_p` — an identity primitive ``abft_check_sink`` whose
  equation marks "these values are being consumed by a checksum
  comparison".  It carries the check's declared ``granularity`` as a
  static parameter, so the analysis can report per-site granularity.
* :func:`tag_check` — routes a Check's (predicted, actual) pair through
  the primitive.  Called by ``Check.diff`` / ``Check.elementwise`` (the
  two reduction cores every report path funnels through) **only while
  tagging is enabled**.
* :func:`check_tagging` — the enabling context manager.  The lint traces
  under it; production traces never see the primitive, so runtime jaxprs,
  compiles, and numerics are bit-for-bit unchanged by this module.

The primitive is a full citizen anyway (impl, abstract eval, lowering,
batching, JVP/transpose are all identity), so a trace taken under
tagging still *executes* correctly — the verifier's own fixtures rely on
that, and a train step traced through ``jax.value_and_grad`` needs the
differentiation rules.
"""
from __future__ import annotations

import contextlib
import threading
from typing import Iterator, Tuple

import jax
from jax import core as jax_core
from jax.interpreters import ad, batching, mlir

Array = jax.Array

CHECK_SINK = "abft_check_sink"

_state = threading.local()


def tagging_enabled() -> bool:
    return getattr(_state, "tagging", False)


@contextlib.contextmanager
def check_tagging() -> Iterator[None]:
    """Enable check-sink tagging for traces taken inside the block.

    Nesting is fine; tagging is thread-local, so a lint trace on one
    thread never perturbs a serving trace on another.
    """
    prev = tagging_enabled()
    _state.tagging = True
    try:
        yield
    finally:
        _state.tagging = prev


check_sink_p = jax_core.Primitive(CHECK_SINK)
check_sink_p.multiple_results = True


@check_sink_p.def_impl
def _check_sink_impl(*args, granularity):
    del granularity
    return list(args)


@check_sink_p.def_abstract_eval
def _check_sink_abstract(*avals, granularity):
    del granularity
    return list(avals)


mlir.register_lowering(check_sink_p,
                       lambda ctx, *args, granularity: list(args))


def _check_sink_batch(args, dims, *, granularity):
    return check_sink_p.bind(*args, granularity=granularity), dims


batching.primitive_batchers[check_sink_p] = _check_sink_batch


def _check_sink_jvp(primals, tangents, *, granularity):
    out = check_sink_p.bind(*primals, granularity=granularity)
    # tangents pass through untagged: the coverage property belongs to the
    # primal check comparison, and instantiating symbolic-zero tangents
    # just to re-tag them would change the trace shape
    tans = [ad.instantiate_zeros(t) if isinstance(t, ad.Zero) else t
            for t in tangents]
    return out, tans


ad.primitive_jvps[check_sink_p] = _check_sink_jvp


def _check_sink_transpose(cts, *args, granularity):
    del granularity, args
    return list(cts)


ad.primitive_transposes[check_sink_p] = _check_sink_transpose


def tag_check(predicted: Array, actual: Array, granularity: str
              ) -> Tuple[Array, Array]:
    """Identity on (predicted, actual); emits the ``abft_check_sink``
    equation when tagging is enabled (see module docstring)."""
    if not tagging_enabled():
        return predicted, actual
    p, a = check_sink_p.bind(predicted, actual, granularity=granularity)
    return p, a
