"""The paper's contribution: GCN-ABFT fused checksums + the ABFT substrate.

Public surface:
  checksum  — checksum primitives (col/row/total, Kahan, fused-chain)
  abft      — ABFTConfig + split/fused checks, GCN layer policies, reports
  gcn       — JAX GCN model (Kipf & Welling) with ABFT threading
  datasets  — synthetic stand-ins for Cora/Citeseer/PubMed/Nell
  opcount   — analytic op-count model (paper Table II)
  fault     — bit-flip fault-injection engine (paper Table I)
"""
from .abft import (  # noqa: F401
    ABFTConfig,
    ABFTReport,
    ChainOp,
    Check,
    CheckedOp,
    MatmulOp,
    check_chain,
    check_matmul,
    checked_matmul,
    fold_w_r_tree,
    gcn_layer,
    per_op_report,
    resolve_w_r,
    gcn_layer_fused,
    gcn_layer_fused_sparse,
    gcn_layer_sparse,
    gcn_layer_split,
    gcn_layer_split_sparse,
    merge_reports,
    sparse_col_checksum,
    sparse_matmul,
    summarize,
)
from .checksum import (  # noqa: F401
    col_checksum,
    fused_chain_checksum,
    kahan_total,
    predicted_matmul_checksum,
    row_checksum,
    total_checksum,
)
