"""Checksum primitives shared by every ABFT variant.

Notation follows the paper (Peltekis & Dimitrakopoulos, 2024):
  col_checksum(A) = e^T A   (sum over rows   -> one value per column)
  row_checksum(A) = A e     (sum over cols   -> one value per row)
  total(A)        = e^T A e (grand sum)

The fundamental ABFT identity for a matmul C = A @ B:
  e^T C e = (e^T A) (B e)            -- eq. (2) corner
and for the paper's three-matrix GCN product H_out = S H W:
  e^T H_out e = (e^T S) H (W e) = s_c H w_r          -- eq. (4)

All helpers take an explicit accumulation ``dtype``.  The paper accumulates
checksums in float64; TPUs have no f64 datapath, so the production default is
float32 with optional Kahan (compensated) summation to recover most of the
lost precision (see DESIGN.md §6).
"""
from __future__ import annotations

from functools import partial
from typing import Any, Optional

import jax
import jax.numpy as jnp

Array = jax.Array


def _acc(x: Array, dtype: Optional[Any]) -> Array:
    return x if dtype is None else x.astype(dtype)


def col_checksum(a: Array, dtype: Optional[Any] = None) -> Array:
    """e^T A: sum over the second-to-last axis (rows)."""
    return _acc(a, dtype).sum(axis=-2)


def row_checksum(a: Array, dtype: Optional[Any] = None) -> Array:
    """A e: sum over the last axis (columns)."""
    return _acc(a, dtype).sum(axis=-1)


def total_checksum(a: Array, dtype: Optional[Any] = None) -> Array:
    """e^T A e: grand sum over the trailing two axes."""
    return _acc(a, dtype).sum(axis=(-2, -1))


def kahan_sum(x: Array, axis: int) -> Array:
    """Compensated (Kahan/Neumaier) summation along ``axis``.

    Used when checksums must accumulate in f32 on hardware without f64
    (TPU); recovers ~f64-grade absolute error for the magnitudes seen in
    normalized activations.  Implemented as a lax.scan so it lowers to a
    compact HLO loop rather than an unrolled chain.
    """
    x = jnp.moveaxis(x, axis, 0)

    def step(carry, xi):
        s, c = carry
        t = s + xi
        # Neumaier variant: pick the larger-magnitude operand for the
        # compensation term so it also handles |xi| > |s|.
        big = jnp.where(jnp.abs(s) >= jnp.abs(xi), s, xi)
        small = jnp.where(jnp.abs(s) >= jnp.abs(xi), xi, s)
        c = c + ((big - t) + small)
        return (t, c), None

    zero = jnp.zeros(x.shape[1:], x.dtype)
    (s, c), _ = jax.lax.scan(step, (zero, zero), x)
    return s + c


def kahan_total(a: Array) -> Array:
    """Compensated grand sum over trailing two axes (f32-safe)."""
    return kahan_sum(kahan_sum(a, -1), -1)


@partial(jax.jit, static_argnames=("dtype",))
def fused_chain_checksum(mats: tuple[Array, ...], dtype: Any = jnp.float32) -> Array:
    """Predicted checksum of the product ``mats[0] @ ... @ mats[-1]``.

    Generic form of the paper's eq. (4): (e^T M0) M1 ... (M_{k-1} e).
    Cost is O(sum of matrix sizes) instead of O(product) — the whole point.
    The contraction is evaluated left-to-right as vector-matrix products.
    """
    assert len(mats) >= 2
    v = col_checksum(mats[0], dtype)           # [k0]
    for m in mats[1:-1]:
        v = v @ _acc(m, dtype)                 # stays a vector
    return v @ row_checksum(mats[-1], dtype)   # scalar


def predicted_matmul_checksum(a: Array, b: Array, dtype: Any = jnp.float32) -> Array:
    """(e^T A)(B e) — predicted grand checksum of A @ B (batched-ok)."""
    ca = col_checksum(a, dtype)
    rb = row_checksum(b, dtype)
    return jnp.einsum("...k,...k->...", ca, rb)
