"""The paper's target model: a Kipf & Welling GCN with ABFT checking.

JAX path (this file): dense normalized adjacency — used by tests, examples
and the pjit'd distributed demo on synthetic graphs.  The large-scale sparse
realism (CSR, per-MAC fault injection) lives in the numpy engine
(``core/fault.py``), matching the paper's accelerator-level evaluation.
"""
from __future__ import annotations

from typing import Any, Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from .abft import (
    ABFTConfig,
    ABFTReport,
    Check,
    sparse_col_checksum,
    summarize,
)

Array = jax.Array
Params = Dict[str, Any]


def init_gcn(rng: jax.Array, dims: Sequence[int]) -> Params:
    """Glorot-initialized weights for a len(dims)-1 layer GCN."""
    keys = jax.random.split(rng, len(dims) - 1)
    layers = []
    for k, (fin, fout) in zip(keys, zip(dims[:-1], dims[1:])):
        scale = jnp.sqrt(6.0 / (fin + fout))
        layers.append({"w": jax.random.uniform(k, (fin, fout), jnp.float32,
                                               -scale, scale)})
    return {"layers": layers}


def gcn_forward(params: Params, s: Array, h0: Array, cfg: ABFTConfig
                ) -> Tuple[Array, List[Check]]:
    """Forward pass; checks are taken pre-activation (as in the paper).

    Delegates to the adjacency-generic loop (dense S dispatches through
    the same layer math; s_c is then computed once and shared by layers).
    """
    return gcn_forward_sparse(params, s, h0, cfg)


def gcn_apply(params: Params, s: Array, h0: Array, cfg: ABFTConfig
              ) -> Tuple[Array, ABFTReport]:
    logits, checks = gcn_forward(params, s, h0, cfg)
    return logits, summarize(checks, cfg)


def gcn_loss(params: Params, s: Array, h0: Array, labels: Array,
             mask: Optional[Array], cfg: ABFTConfig
             ) -> Tuple[Array, ABFTReport]:
    logits, report = gcn_apply(params, s, h0, cfg)
    logp = jax.nn.log_softmax(logits, axis=-1)
    nll = -jnp.take_along_axis(logp, labels[:, None], axis=-1)[:, 0]
    if mask is not None:
        nll = jnp.where(mask, nll, 0.0)
        loss = nll.sum() / jnp.maximum(mask.sum(), 1)
    else:
        loss = nll.mean()
    return loss, report


# ---------------------------------------------------------------------------
# Sparse-adjacency path.  S stays a BCOO; the per-graph s_c = e^T S is
# computed once offline (:func:`precompute_s_c`) and reused across every
# layer and step — the paper's "offline for static graphs" convention.
# ---------------------------------------------------------------------------

def precompute_s_c(s, cfg: ABFTConfig) -> Array:
    """Offline e^T S in the checksum accumulation dtype."""
    return sparse_col_checksum(s, cfg.dtype)


def gcn_forward_sparse(params: Params, s, h0: Array, cfg: ABFTConfig,
                       s_c: Optional[Array] = None
                       ) -> Tuple[Array, List[Check]]:
    """Forward loop, generic over the adjacency (BCOO or dense).

    Thin shim over the unified engine (``repro.engine``), which owns the
    canonical loop (ReLU chain-breaking, pre-activation checks) and the
    backend dispatch; kept as the historical core entry point.
    """
    from repro.engine import Graph, gcn_forward as engine_forward
    return engine_forward(params, Graph(s=s, h0=h0, s_c=s_c), cfg)


def gcn_apply_sparse(params: Params, s, h0: Array, cfg: ABFTConfig,
                     s_c: Optional[Array] = None
                     ) -> Tuple[Array, ABFTReport]:
    """Sparse twin of :func:`gcn_apply`: same logits, same report semantics.

    ``s`` is a ``jax.experimental.sparse.BCOO`` normalized adjacency (dense
    also accepted — the layer math dispatches).  BCOO is a pytree, so this
    jits with ``s`` as a regular argument.
    """
    logits, checks = gcn_forward_sparse(params, s, h0, cfg, s_c)
    return logits, summarize(checks, cfg)


def normalized_adjacency_bcoo(edges: np.ndarray, n: int):
    """D^-1/2 (A + I) D^-1/2 as a BCOO sparse matrix (any graph size)."""
    from jax.experimental import sparse as jsparse
    src = np.concatenate([edges[:, 0], edges[:, 1], np.arange(n)])
    dst = np.concatenate([edges[:, 1], edges[:, 0], np.arange(n)])
    # dedupe (symmetrization may duplicate bidirectional input edges)
    key = src * n + dst
    _, keep = np.unique(key, return_index=True)
    src, dst = src[keep], dst[keep]
    deg = np.bincount(src, minlength=n).astype(np.float64)
    dinv = 1.0 / np.sqrt(np.maximum(deg, 1.0))
    vals = (dinv[src] * dinv[dst]).astype(np.float32)
    idx = np.stack([src, dst], axis=1).astype(np.int32)
    return jsparse.BCOO((jnp.asarray(vals), jnp.asarray(idx)),
                        shape=(n, n))


def dataset_to_sparse(ds) -> Tuple[Any, Array, np.ndarray]:
    """(S as BCOO, dense H0, labels) views of a core.datasets.GraphDataset.

    H0 stays dense on device: after the first combination every activation
    is dense anyway, and the paper's sparse-H0 op accounting lives in the
    analytic model (core/opcount.py), not the JAX path.
    """
    return ds.s.to_bcoo(), jnp.asarray(ds.features.todense()), ds.labels


def normalized_adjacency_dense(edges: np.ndarray, n: int) -> np.ndarray:
    """D^-1/2 (A + I) D^-1/2 as a dense float32 matrix (small graphs)."""
    a = np.zeros((n, n), np.float32)
    a[edges[:, 0], edges[:, 1]] = 1.0
    a[edges[:, 1], edges[:, 0]] = 1.0
    np.fill_diagonal(a, 1.0)
    d = a.sum(1)
    dinv = 1.0 / np.sqrt(np.maximum(d, 1.0))
    return (a * dinv[None, :]) * dinv[:, None]


def dataset_to_dense(ds) -> Tuple[np.ndarray, np.ndarray, np.ndarray]:
    """(S, H0, labels) dense views of a core.datasets.GraphDataset."""
    return ds.s.todense(), ds.features.todense(), ds.labels
