"""The paper's target model: a Kipf & Welling GCN with ABFT checking.

JAX path (this file): dense normalized adjacency — used by tests, examples
and the pjit'd distributed demo on synthetic graphs.  The large-scale sparse
realism (CSR, per-MAC fault injection) lives in the numpy engine
(``core/fault.py``), matching the paper's accelerator-level evaluation.
"""
from __future__ import annotations

from typing import Any, Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from .abft import ABFTConfig, ABFTReport, Check, gcn_layer, summarize

Array = jax.Array
Params = Dict[str, Any]


def init_gcn(rng: jax.Array, dims: Sequence[int]) -> Params:
    """Glorot-initialized weights for a len(dims)-1 layer GCN."""
    keys = jax.random.split(rng, len(dims) - 1)
    layers = []
    for k, (fin, fout) in zip(keys, zip(dims[:-1], dims[1:])):
        scale = jnp.sqrt(6.0 / (fin + fout))
        layers.append({"w": jax.random.uniform(k, (fin, fout), jnp.float32,
                                               -scale, scale)})
    return {"layers": layers}


def gcn_forward(params: Params, s: Array, h0: Array, cfg: ABFTConfig
                ) -> Tuple[Array, List[Check]]:
    """Forward pass; checks are taken pre-activation (as in the paper)."""
    h = h0
    checks: List[Check] = []
    n_layers = len(params["layers"])
    for i, layer in enumerate(params["layers"]):
        h_out, cs = gcn_layer(s, h, layer["w"], cfg)
        checks.extend(cs)
        h = jax.nn.relu(h_out) if i < n_layers - 1 else h_out
    return h, checks


def gcn_apply(params: Params, s: Array, h0: Array, cfg: ABFTConfig
              ) -> Tuple[Array, ABFTReport]:
    logits, checks = gcn_forward(params, s, h0, cfg)
    return logits, summarize(checks, cfg)


def gcn_loss(params: Params, s: Array, h0: Array, labels: Array,
             mask: Optional[Array], cfg: ABFTConfig
             ) -> Tuple[Array, ABFTReport]:
    logits, report = gcn_apply(params, s, h0, cfg)
    logp = jax.nn.log_softmax(logits, axis=-1)
    nll = -jnp.take_along_axis(logp, labels[:, None], axis=-1)[:, 0]
    if mask is not None:
        nll = jnp.where(mask, nll, 0.0)
        loss = nll.sum() / jnp.maximum(mask.sum(), 1)
    else:
        loss = nll.mean()
    return loss, report


def normalized_adjacency_dense(edges: np.ndarray, n: int) -> np.ndarray:
    """D^-1/2 (A + I) D^-1/2 as a dense float32 matrix (small graphs)."""
    a = np.zeros((n, n), np.float32)
    a[edges[:, 0], edges[:, 1]] = 1.0
    a[edges[:, 1], edges[:, 0]] = 1.0
    np.fill_diagonal(a, 1.0)
    d = a.sum(1)
    dinv = 1.0 / np.sqrt(np.maximum(d, 1.0))
    return (a * dinv[None, :]) * dinv[:, None]


def dataset_to_dense(ds) -> Tuple[np.ndarray, np.ndarray, np.ndarray]:
    """(S, H0, labels) dense views of a core.datasets.GraphDataset."""
    return ds.s.todense(), ds.features.todense(), ds.labels
