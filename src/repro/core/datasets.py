"""Synthetic stand-ins for the paper's four GCN applications.

The container is offline, so Cora/Citeseer/PubMed/Nell are generated to the
*published* statistics (nodes, undirected edges, feature nnz, feature dim,
hidden width, classes).  The statistics below reproduce the paper's Table II
"True Out" operation counts to <1 % (see ``core/opcount.py`` and
``benchmarks/table2_op_counts.py``), which pins down both the dataset shapes
and the paper's counting conventions:

    Cora     2.79 M  (paper:   2.8 M)
    Citeseer 4.56 M  (paper:   4.6 M)
    PubMed  37.52 M  (paper:  37.6 M)
    Nell    1743  M  (paper: 1745.9 M)

Generation is deterministic (seeded) and cheap: edges are sampled uniformly
(Erdos–Renyi by pair sampling, symmetrized, self-loops added), features are
sparse nonnegative "bag-of-words"-style rows, row-normalized as in Kipf &
Welling.  Fault-detection mechanics (bit flip -> checksum divergence) depend
on magnitudes, not topology; EXPERIMENTS.md notes this as the one deviation
forced by the offline container.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Tuple

import numpy as np


@dataclasses.dataclass(frozen=True)
class GraphStats:
    name: str
    nodes: int
    und_edges: int          # undirected edges, without self loops
    feat_dim: int
    feat_nnz: int           # total nonzeros in the feature matrix
    hidden: int
    classes: int

    @property
    def adj_nnz(self) -> int:
        # directed nnz of A + I  (symmetric edges counted twice + self loops)
        return 2 * self.und_edges + self.nodes

    @property
    def layer_dims(self) -> Tuple[int, int, int]:
        return (self.feat_dim, self.hidden, self.classes)


# Published statistics (Planetoid splits; Nell from graphlearning / planetoid
# nell.0.001 preprocessing — hidden 64 per the GCN paper's Nell setup).
STATS: Dict[str, GraphStats] = {
    "cora":     GraphStats("cora",     2708,   5278,  1433,   49216, 16,   7),
    "citeseer": GraphStats("citeseer", 3327,   4552,  3703,  105165, 16,   6),
    "pubmed":   GraphStats("pubmed",  19717,  44324,   500,  985850, 16,   3),
    "nell":     GraphStats("nell",    65755, 133072,  5414,   92057, 64, 186),
}


class Coo:
    """Minimal COO sparse matrix for the numpy-side fault-injection engine."""

    __slots__ = ("data", "row", "col", "shape", "_csr")

    def __init__(self, data: np.ndarray, row: np.ndarray, col: np.ndarray,
                 shape: Tuple[int, int]):
        self.data = np.asarray(data, np.float32)
        self.row = np.asarray(row, np.int64)
        self.col = np.asarray(col, np.int64)
        self.shape = shape
        self._csr = None

    def csr(self):
        """(indptr, cols, data) sorted by row — the per-row accumulation
        order used by the fault engine's prefix-sum delta model."""
        if self._csr is None:
            order = np.argsort(self.row, kind="stable")
            rows = self.row[order]
            indptr = np.zeros(self.shape[0] + 1, np.int64)
            np.add.at(indptr, rows + 1, 1)
            np.cumsum(indptr, out=indptr)
            self._csr = (indptr, self.col[order], self.data[order])
        return self._csr

    def row_slice(self, i: int):
        """(cols, vals) of row i in accumulation order."""
        indptr, cols, data = self.csr()
        lo, hi = indptr[i], indptr[i + 1]
        return cols[lo:hi], data[lo:hi]

    @property
    def nnz(self) -> int:
        return int(self.data.size)

    def matmul_dense(self, x: np.ndarray) -> np.ndarray:
        """self @ x for dense x — vectorized scatter-add."""
        out = np.zeros((self.shape[0], x.shape[1]), np.float32)
        np.add.at(out, self.row, self.data[:, None] * x[self.col])
        return out

    def col_sums(self) -> np.ndarray:
        out = np.zeros(self.shape[1], np.float64)
        np.add.at(out, self.col, self.data.astype(np.float64))
        return out

    def col_slice_dense(self, j: int) -> np.ndarray:
        """Return column j as a dense vector (used by delta propagation)."""
        out = np.zeros(self.shape[0], np.float32)
        m = self.col == j
        np.add.at(out, self.row[m], self.data[m])
        return out

    def rows_of_col(self, j: int) -> np.ndarray:
        return self.row[self.col == j]

    def todense(self) -> np.ndarray:
        out = np.zeros(self.shape, np.float32)
        np.add.at(out, (self.row, self.col), self.data)
        return out

    def to_bcoo(self):
        """jax.experimental.sparse.BCOO view (device side; duplicates kept —
        BCOO matmul accumulates them, matching todense + np.add.at)."""
        import jax.numpy as jnp
        from jax.experimental import sparse as jsparse
        idx = np.stack([self.row, self.col], axis=1).astype(np.int32)
        return jsparse.BCOO((jnp.asarray(self.data), jnp.asarray(idx)),
                            shape=self.shape)

    def to_block_ell(self, block_m: int = 128, block_k: int = 128):
        """Padded block-ELL layout for the spmm_abft Pallas kernel."""
        from repro.kernels.spmm_abft.layout import coo_to_block_ell
        return coo_to_block_ell(self.row, self.col, self.data, self.shape,
                                block_m, block_k)


@dataclasses.dataclass
class GraphDataset:
    stats: GraphStats
    s: Coo                     # normalized adjacency  D^-1/2 (A+I) D^-1/2
    features: Coo              # sparse H^0
    labels: np.ndarray         # [nodes] int — synthetic classes
    # CSC-style views of S used by the delta-propagation fault engine
    _s_by_col: dict = dataclasses.field(default_factory=dict, repr=False)

    @property
    def name(self) -> str:
        return self.stats.name

    def s_col(self, j: int):
        """(rows, vals) of column j of S, cached."""
        hit = self._s_by_col.get(j)
        if hit is None:
            m = self.s.col == j
            hit = (self.s.row[m], self.s.data[m])
            self._s_by_col[j] = hit
        return hit


def _sample_edges(n: int, m: int, rng: np.random.Generator) -> np.ndarray:
    """m distinct undirected edges (i<j), uniform."""
    want = m
    got = np.empty((0, 2), np.int64)
    while got.shape[0] < want:
        k = int((want - got.shape[0]) * 1.3) + 16
        e = rng.integers(0, n, size=(k, 2), dtype=np.int64)
        e = e[e[:, 0] != e[:, 1]]
        e = np.sort(e, axis=1)
        got = np.unique(np.concatenate([got, e], axis=0), axis=0)
    return got[:want]


def _stable_hash(name: str) -> int:
    import zlib
    return zlib.crc32(name.encode()) & 0xFFFF


def make_dataset(name: str, seed: int = 0, normalize: bool = True) -> GraphDataset:
    """``normalize=True``: Kipf row-normalized features (activations ~1e-2).
    ``normalize=False``: raw bag-of-words-scale features (~1) — the
    magnitude-calibrated variant whose trained second-layer partial sums reach
    ~1e3, matching the scales implied by the paper's Table I thresholds."""
    st = STATS[name]
    rng = np.random.default_rng(np.random.SeedSequence([_stable_hash(name), seed]))

    # --- adjacency: ER edges, symmetrized, self loops, sym-normalized
    e = _sample_edges(st.nodes, st.und_edges, rng)
    src = np.concatenate([e[:, 0], e[:, 1], np.arange(st.nodes)])
    dst = np.concatenate([e[:, 1], e[:, 0], np.arange(st.nodes)])
    deg = np.bincount(src, minlength=st.nodes).astype(np.float64)
    dinv = 1.0 / np.sqrt(deg)
    vals = (dinv[src] * dinv[dst]).astype(np.float32)
    s = Coo(vals, src, dst, (st.nodes, st.nodes))

    # --- features: sparse nonnegative rows, ≥1 nnz per row, row-normalized
    per_row = np.full(st.nodes, st.feat_nnz // st.nodes, np.int64)
    extra = st.feat_nnz - per_row.sum()
    if extra > 0:
        per_row[rng.choice(st.nodes, size=extra, replace=False)] += 1
    per_row = np.maximum(per_row, 1)
    rows = np.repeat(np.arange(st.nodes), per_row)
    cols = rng.integers(0, st.feat_dim, size=rows.size, dtype=np.int64)
    fvals = rng.uniform(0.5, 1.5, size=rows.size).astype(np.float32)
    if normalize:
        # row-normalize (Kipf preprocessing)
        rsum = np.zeros(st.nodes, np.float64)
        np.add.at(rsum, rows, fvals.astype(np.float64))
        fvals = (fvals / rsum[rows]).astype(np.float32)
    features = Coo(fvals, rows, cols, (st.nodes, st.feat_dim))

    # --- labels from a random *teacher* GCN so the task is learnable and
    # trained weights reach realistic magnitudes (the paper evaluates trained
    # GCNs; detection thresholds see trained-activation scales).
    t1 = rng.normal(0, 1.0, size=(st.feat_dim, st.hidden)).astype(np.float32)
    t2 = rng.normal(0, 1.0, size=(st.hidden, st.classes)).astype(np.float32)
    x1 = s.matmul_dense(features.matmul_dense(t1))
    z = s.matmul_dense(np.maximum(x1, 0.0) @ t2)
    labels = np.argmax(z + 0.1 * rng.normal(size=z.shape), axis=1).astype(np.int64)
    return GraphDataset(stats=st, s=s, features=features, labels=labels)


def reduced_stats(name: str, scale: int = 8) -> GraphStats:
    """A smaller same-shape dataset for CPU-budget fault campaigns/tests."""
    st = STATS[name]
    f = max(1, scale)
    return GraphStats(
        name=f"{name}-r{f}",
        nodes=max(64, st.nodes // f),
        und_edges=max(128, st.und_edges // f),
        feat_dim=max(16, st.feat_dim // f),
        feat_nnz=max(256, st.feat_nnz // f),
        hidden=st.hidden,
        classes=st.classes,
    )


def make_reduced(name: str, scale: int = 8, seed: int = 0) -> GraphDataset:
    st = reduced_stats(name, scale)
    STATS_BACKUP = STATS.get(st.name)
    STATS[st.name] = st
    try:
        return make_dataset(st.name, seed)
    finally:
        if STATS_BACKUP is None:
            del STATS[st.name]
        else:
            STATS[st.name] = STATS_BACKUP
