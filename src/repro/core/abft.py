"""ABFT checking layer: split (baseline) and fused (GCN-ABFT) checks.

Every check produces a :class:`Check` — a (predicted, actual) pair of scalars
(or batched scalars).  Checks are pytrees, so they flow through jit/pjit/scan
unchanged; a training step collects all layer checks and reduces them with
:func:`summarize` into a single replicated flag + max divergence that the
runtime layer (``runtime/abft_guard.py``) acts on.

Three policies (``ABFTConfig.mode``):
  * ``none``  — no checks (perf baseline).
  * ``split`` — the paper's baseline: one check per matmul (eqs. 2–3).
  * ``fused`` — GCN-ABFT: one check per *linear chain* (eq. 4).  Chains are
    broken by nonlinearities; isolated matmuls degrade to split checks.

The engine-facing contract is the :class:`CheckedOp` protocol: a checked op
takes its operands plus folded check vectors and returns ``(out, Check)`` at
a declared granularity.  The eq. 4–6 chaining/fold/report algebra that
backs every implementation — :func:`resolve_w_r`, :func:`fold_w_r_tree`,
:func:`check_chain`, :func:`per_op_report` — lives here, op-generically:
none of it mentions GCNs.  ``engine/api.py`` (GCN layers), ``engine/lm.py``
(transformer prefill/decode), ``engine/gat.py`` (GAT aggregation) and the
``kernels/matmul_abft`` / ``kernels/flash_checksum`` Pallas ops are all
implementations of this one protocol.
"""
from __future__ import annotations

import dataclasses
from typing import Any, NamedTuple, Optional, Sequence

import jax
import jax.numpy as jnp

from .checksum import (
    col_checksum,
    kahan_total,
    predicted_matmul_checksum,
    row_checksum,
    total_checksum,
)
from .marker import tag_check

Array = jax.Array

MODES = ("none", "split", "fused")

# Check granularities, coarsest to finest.  "layer" is one scalar corner per
# linear chain (the paper's granularity); "graph" segments the corner per
# packed graph (exact by linearity — PR 3); "stripe" keeps the kernel's
# per-row-stripe partials as individual corners, so a detected fault names
# the stripe it corrupted and recovery can re-execute just those rows;
# "slot" differences the kernel's telescoped per-ell-slot running sums into
# one corner per (stripe, slot) grid step — a fault names the exact tile
# product (or accumulator step) that produced it.
GRANULARITIES = ("layer", "graph", "stripe", "slot")


@dataclasses.dataclass(frozen=True)
class ABFTConfig:
    """Static configuration for ABFT checking (hashable; safe as jit static)."""

    mode: str = "fused"
    # Accumulation dtype for checksums.  Paper: float64 (CPU repro benches);
    # TPU production: float32 (+ kahan=True to compensate).
    dtype: Any = jnp.float32
    kahan: bool = False
    # Detection threshold tau.  relative=True flags when
    #   |pred - actual| > threshold * max(1, |actual|)
    # which is what a deployment wants; the paper's Table I uses absolute
    # thresholds (relative=False) in 1e-4..1e-7.
    threshold: float = 1e-3
    relative: bool = True

    def __post_init__(self):
        if self.mode not in MODES:
            raise ValueError(f"abft mode {self.mode!r} not in {MODES}")

    @property
    def enabled(self) -> bool:
        return self.mode != "none"


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass(frozen=True)
class Check:
    """One checksum comparison.  Fields may be scalars or batched scalars.

    ``granularity`` records what one element of the comparison attributes a
    fault to — ``"layer"`` (scalar corner per chain), ``"graph"`` (one
    corner per packed graph), or ``"stripe"`` (one corner per block-ELL
    row-stripe).  It is static pytree metadata, not a traced value, so
    checks flow through jit/shard_map unchanged and report reducers can
    dispatch on it without a device read.
    """

    predicted: Array
    actual: Array
    granularity: str = "layer"

    def __post_init__(self):
        if self.granularity not in GRANULARITIES:
            raise ValueError(f"check granularity {self.granularity!r} not "
                             f"in {GRANULARITIES}")

    def diff(self) -> Array:
        # every report path (flag/elementwise/summarize/per_*_report)
        # funnels through this subtraction, so routing the pair through
        # the check-sink marker here is what lets `abftlint`'s coverage
        # pass see "this value reached an eq. 4-6 comparison" in the
        # jaxpr.  tag_check is identity (and a no-op outside lint traces).
        p, a = tag_check(self.predicted, self.actual, self.granularity)
        return jnp.abs(p - a)

    def _scale(self) -> Array:
        # the relative scale must stay FINITE: an overflowed output
        # (actual = ±inf, e.g. a high exponent bit flip in a weight)
        # would make tau*scale infinite and the comparison pass silently
        # (inf <= inf).  Clamped to 1.0, the infinite divergence flags.
        scale = jnp.maximum(1.0, jnp.abs(self.actual))
        return jnp.where(jnp.isfinite(scale), scale, 1.0)

    def flag(self, cfg: ABFTConfig) -> Array:
        # NaN-safe: a NaN divergence (corrupted checksum path — a bit
        # flip in w_r/s_c/the carried eq.-5 column propagating to pred)
        # must FLAG.  ``d > tau`` is False for NaN, which would silently
        # disable ABFT, so the comparison is negated: not (d <= tau).
        d = self.diff()
        if cfg.relative:
            return jnp.any(~(d <= cfg.threshold * self._scale()))
        return jnp.any(~(d <= cfg.threshold))

    def elementwise(self, cfg: ABFTConfig) -> tuple[Array, Array]:
        """Per-element (flags, rel divergence) — the shared reduction core
        of :func:`per_graph_report` / :func:`per_stripe_report`.  NaN-safe
        like :meth:`flag`: a NaN comparison flags its element."""
        d = self.diff()
        scale = self._scale()
        f = ~(d <= cfg.threshold * (scale if cfg.relative else 1.0))
        return f, (d / scale).astype(jnp.float32)

    def tree_flatten(self):
        return (self.predicted, self.actual), self.granularity

    @classmethod
    def tree_unflatten(cls, aux, children):
        return cls(children[0], children[1], aux)


class ABFTReport(NamedTuple):
    """Aggregated result of all checks in one step (pytree of scalars)."""

    flag: Array       # bool — any check tripped
    max_rel: Array    # worst relative divergence seen
    n_checks: Array   # number of scalar comparisons performed


def _total(a: Array, cfg: ABFTConfig) -> Array:
    if cfg.kahan:
        return kahan_total(a.astype(cfg.dtype))
    return total_checksum(a, cfg.dtype)


def check_matmul(a: Array, b: Array, c: Array, cfg: ABFTConfig,
                 *, b_r: Optional[Array] = None) -> Check:
    """Split-ABFT check of an already-computed product c = a @ b.

    Batched operands are fine (leading axes broadcast): one scalar check per
    batch element, reduced later by :func:`summarize`.  A folded right
    checksum ``b_r = B·e`` (from :func:`fold_w_r_tree` at weight-load time)
    skips the per-step row-sum of B; it must have been folded at this
    config's checksum dtype (validated — a stale fold raises).
    """
    if b_r is None:
        pred = predicted_matmul_checksum(a, b, cfg.dtype)
    else:
        b_r = resolve_w_r(b, b_r, cfg)
        pred = jnp.einsum("...k,...k->...", col_checksum(a, cfg.dtype), b_r)
    return Check(predicted=pred, actual=_total(c, cfg))


def checked_matmul(a: Array, b: Array, cfg: ABFTConfig,
                   precision=None) -> tuple[Array, Optional[Check]]:
    """Compute a @ b and (mode-dependent) its ABFT check."""
    c = jnp.matmul(a, b, precision=precision)
    if not cfg.enabled:
        return c, None
    return c, check_matmul(a, b, c, cfg)


def check_chain(mats: Sequence[Array], out: Array, cfg: ABFTConfig) -> Check:
    """Fused (GCN-ABFT) check of out = mats[0] @ ... @ mats[-1].

    Supports batched leading axes on any operand: the left checksum vector is
    pushed through the chain with einsum-free matmuls (broadcasting applies).
    """
    v = col_checksum(mats[0], cfg.dtype)                    # [..., k0]
    for m in mats[1:-1]:
        v = jnp.einsum("...k,...kj->...j", v, m.astype(cfg.dtype))
    pred = jnp.einsum("...k,...k->...", v, row_checksum(mats[-1], cfg.dtype))
    return Check(predicted=pred, actual=_total(out, cfg))


# ---------------------------------------------------------------------------
# The CheckedOp protocol and its op-generic fold/report algebra.
#
# Hoisted out of engine/api.py::gcn_layer/gcn_forward: nothing below is
# GCN-specific.  An op's check vectors fold once at weight-load time
# (resolve_w_r / fold_w_r_tree — the paper's "offline" eq.-5 convention),
# the op returns (out, Check) at its declared granularity, and the report
# algebra (summarize / per_op_report / per_graph_report / ...) reduces the
# checks into verdicts the runtime guard acts on.
# ---------------------------------------------------------------------------

def resolve_w_r(w: Array, w_r: Optional[Array],
                cfg: ABFTConfig) -> Optional[Array]:
    """Resolve one op's right checksum w_r = W·e: computed at ``cfg.dtype``
    when absent, validated against the REALIZED checksum dtype when folded
    (x64-disabled f64 requests realize as f32), ``None`` when checking is
    off.  Every CheckedOp implementation shares this so a stale fold raises
    identically everywhere."""
    if not cfg.enabled:
        return None
    if w_r is None:
        return row_checksum(w, cfg.dtype)
    want = jax.dtypes.canonicalize_dtype(jnp.dtype(cfg.dtype))
    if jnp.asarray(w_r).dtype != want:
        raise ValueError(
            f"folded w_r has dtype {jnp.asarray(w_r).dtype} but "
            f"cfg.dtype realizes as {want}: the checks would run at a "
            f"stale precision.  Re-fold the params (fold_w_r_tree / "
            f"engine.fold_w_r) after changing ABFTConfig.dtype (or drop "
            f"the fold to recompute w_r per step)")
    return w_r


def fold_w_r_tree(params: Any, cfg: ABFTConfig, *, lead_axes: int = 0,
                  compute_dtype: Any = None) -> Any:
    """Tree-generic offline fold: walk any params pytree and add a folded
    right checksum ``"w_r"`` next to every ``"w"`` weight leaf.

    The convention is ``init_dense``'s: ``w`` is ``[d_in, *d_out]`` and the
    fold sums over every output axis — ``w_r = W·e`` of the 2-D flattened
    weight, one value per input feature.  ``lead_axes`` names leading
    batch/stack axes to preserve (1 for scan-stacked transformer segment
    params: each unit keeps its own fold).  Existing ``"w_r"`` entries are
    overwritten — re-fold after any weight update or ``cfg.dtype`` change.
    Non-dict leaves and dicts without a ``"w"`` array pass through
    untouched, so one call folds a whole model: GCN ``params["layers"]``,
    transformer QKV/MLP/head denses, GAT layers.

    ``compute_dtype`` quantizes the weights to the model's compute dtype
    *before* the checksum accumulation — pass the model's activation dtype
    (e.g. bfloat16) so the folded prediction matches the weights the
    product actually consumed; leaving it off on a low-precision model
    injects the master-vs-compute quantization gap into every comparison.
    """
    if not cfg.enabled:
        return params

    def _fold(node):
        if isinstance(node, dict):
            out = {k: _fold(v) for k, v in node.items()}
            w = node.get("w")
            if w is not None and hasattr(w, "ndim") and \
                    w.ndim >= 2 + lead_axes:
                # fold on the array as-is (numpy stays numpy): the
                # self-check re-derives with the SAME summation so the
                # comparison is bitwise, and converting would change the
                # reduction order
                if compute_dtype is not None:
                    w = w.astype(compute_dtype)
                w = w.astype(cfg.dtype)
                out["w_r"] = w.reshape(*w.shape[:1 + lead_axes], -1).sum(-1)
            return out
        if isinstance(node, (list, tuple)):
            return type(node)(_fold(v) for v in node)
        return node

    return _fold(params)


class CheckedOp:
    """Protocol for one checked op — the engine's unit of ABFT coverage.

    A checked op takes its operands plus folded check vectors and returns
    ``(out, Check)`` at a declared granularity::

        op = SomeOp(...)
        params = op.fold(params, cfg)          # offline, at weight load
        out, check = op(cfg, *operands, **folded_check_vectors)

    ``check`` is the registered-pytree :class:`Check` (or ``None`` when
    ``cfg.mode == "none"``; ops whose policy emits several comparisons —
    e.g. the split eq. 2–3 baseline — may return a list of Checks).  The
    contract implementations must honour:

      * the *predicted* side is computed only from the op's inputs and
        folded vectors — never from the output (a fault would cancel);
      * ``granularity`` declares what one comparison element attributes a
        fault to (see :data:`GRANULARITIES`);
      * ``op_id`` keys the op's verdicts in per-op reports and guard
        repair sites (``"op:<id>"``) — stable across steps of one serving
        trace.

    Implementations: the GCN ``AggregationBackend``s (``engine/backends``),
    the transformer LM ops (``engine/lm``), GAT layers (``engine/gat``),
    and the Pallas kernels ``kernels/matmul_abft`` / ``flash_checksum``.
    """

    op_id: str = "op"
    granularity: str = "layer"

    def fold(self, params: Any, cfg: ABFTConfig) -> Any:
        """Fold this op's check vectors into ``params`` at load time."""
        return fold_w_r_tree(params, cfg)

    def __call__(self, cfg: ABFTConfig, *operands, **folded):
        raise NotImplementedError


class MatmulOp(CheckedOp):
    """Reference split-ABFT op (eqs. 2–3): ``out = A @ B``, one scalar
    comparison, optional folded ``b_r``."""

    op_id = "matmul"

    def __call__(self, cfg: ABFTConfig, a: Array, b: Array, *,
                 b_r: Optional[Array] = None):
        c = jnp.matmul(a, b)
        if not cfg.enabled:
            return c, None
        return c, check_matmul(a, b, c, cfg, b_r=b_r)


class ChainOp(CheckedOp):
    """Reference fused op (eqs. 4–6): ``out = M0 @ ... @ Mk`` with ONE
    comparison for the whole linear chain, optional folded right checksum
    of the last matrix."""

    op_id = "chain"

    def __call__(self, cfg: ABFTConfig, *mats: Array,
                 w_r: Optional[Array] = None):
        out = mats[0]
        for m in mats[1:]:
            out = jnp.matmul(out, m)
        if not cfg.enabled:
            return out, None
        if w_r is None:
            return out, check_chain(mats, out, cfg)
        w_r = resolve_w_r(mats[-1], w_r, cfg)
        v = col_checksum(mats[0], cfg.dtype)
        for m in mats[1:-1]:
            v = jnp.einsum("...k,...kj->...j", v, m.astype(cfg.dtype))
        pred = jnp.einsum("...k,...k->...", v, w_r)
        return out, Check(predicted=pred, actual=_total(out, cfg))


def per_op_report(checks: Sequence[Optional[Check]], cfg: ABFTConfig, *,
                  prefix: str = "op") -> tuple[tuple, Array, Array]:
    """Per-op twin of :func:`summarize`: one verdict per check element,
    keyed by a static op id.

    Returns ``(op_ids, flags, max_rel)`` where ``op_ids`` is a tuple of
    static strings and ``flags``/``max_rel`` are aligned ``[n_ops]``
    vectors.  A check whose fields are batched — e.g. a scanned transformer
    segment stacks one comparison per layer into ``[count]`` leaves —
    contributes one verdict per element with a ``:L{j}`` suffix, so a
    flagged op names the layer it fired in.  The ids are positional within
    one step's static check structure: stable across steps of a compiled
    serving trace, which is all the guard's persistent-site discrimination
    needs.
    """
    checks = [c for c in checks if c is not None]
    if not checks or not cfg.enabled:
        return (), jnp.zeros((0,), bool), jnp.zeros((0,), jnp.float32)
    ids: list = []
    flags, rels = [], []
    for i, c in enumerate(checks):
        f, r = c.elementwise(cfg)
        f, r = jnp.ravel(f), jnp.ravel(r)
        n = int(f.shape[0])
        if n == 1:
            ids.append(f"{prefix}{i}")
        else:
            ids.extend(f"{prefix}{i}:L{j}" for j in range(n))
        flags.append(f)
        rels.append(r.astype(jnp.float32))
    return tuple(ids), jnp.concatenate(flags), jnp.concatenate(rels)


# ---------------------------------------------------------------------------
# The paper's GCN layer checks, both dataflows.
# ---------------------------------------------------------------------------

def gcn_layer_split(s: Array, h: Array, w: Array, cfg: ABFTConfig
                    ) -> tuple[Array, tuple[Check, Check]]:
    """Baseline ABFT (eqs. 2–3): combination-first, two separate checks."""
    return gcn_layer_split_sparse(s, h, w, cfg)


def gcn_layer_fused(s: Array, h: Array, w: Array, cfg: ABFTConfig
                    ) -> tuple[Array, Check]:
    """GCN-ABFT (eqs. 4–6): single fused check s_c H w_r vs e^T H_out e.

    H carries *no* check state: we only form w_r = W e (offline in a real
    deployment), the extra column x_r = H w_r during the first multiply, and
    s_c = e^T S (offline for static graphs).
    """
    return gcn_layer_fused_sparse(s, h, w, cfg)


def gcn_layer(s: Array, h: Array, w: Array, cfg: ABFTConfig
              ) -> tuple[Array, list[Check]]:
    """Policy dispatch used by the GCN model."""
    return gcn_layer_sparse(s, h, w, cfg)


# ---------------------------------------------------------------------------
# Canonical layer implementations, generic over the adjacency (BCOO or
# dense S — the dense gcn_layer* wrappers above delegate here).  Only the
# aggregation matmul and the s_c checksum honour sparsity.  For a static
# graph s_c = e^T S never changes — compute it once offline
# (:func:`sparse_col_checksum`) and pass it to every layer/step.
# ---------------------------------------------------------------------------

def _is_bcoo(s: Any) -> bool:
    from jax.experimental import sparse as jsparse
    return isinstance(s, jsparse.BCOO)


def sparse_matmul(s: Any, x: Array) -> Array:
    """S @ X for BCOO or dense S (BCOO lowers to scatter-add dot_general)."""
    return (s @ x) if _is_bcoo(s) else jnp.matmul(s, x)


def sparse_col_checksum(s: Any, dtype: Any = jnp.float32) -> Array:
    """e^T S without densifying: O(nnz) segment-sum over column indices.

    This is the offline s_c precompute for static graphs — call it once per
    graph and thread the result through :func:`gcn_layer_fused_sparse`.
    """
    if not _is_bcoo(s):
        return col_checksum(s, dtype)
    data = s.data.astype(dtype)
    cols = s.indices[..., 1]
    return jax.ops.segment_sum(data, cols, num_segments=s.shape[1])


def _engine_layer(s: Any, h: Array, w: Array, cfg: ABFTConfig,
                  s_c: Optional[Array], mode: str
                  ) -> tuple[Array, list[Check]]:
    """Delegate one layer to the unified engine under a forced mode.

    The eq. 4–6 algebra formerly written out here lives in
    ``repro/engine/api.py`` now; these entry points stay for callers that
    address a single layer directly.  Imports are deferred: the engine
    imports this module for Check/summarize.
    """
    from repro.engine import gcn_layer as engine_gcn_layer
    from repro.engine import make_backend

    if cfg.mode != mode:
        cfg = dataclasses.replace(cfg, mode=mode)
    bk = make_backend(s, cfg, s_c=s_c if cfg.enabled else None)
    return engine_gcn_layer(bk, h, w, cfg)


def gcn_layer_fused_sparse(s: Any, h: Array, w: Array, cfg: ABFTConfig,
                           s_c: Optional[Array] = None
                           ) -> tuple[Array, Check]:
    """GCN-ABFT (eqs. 4–6) with a sparse (BCOO) aggregation operand.

    Identical check algebra to :func:`gcn_layer_fused`; ``s_c`` should be
    the offline precompute for static graphs (recomputed O(nnz) when not
    supplied, which is still cheap but wasteful across layers/steps).
    """
    h_out, checks = _engine_layer(s, h, w, cfg, s_c, "fused")
    return h_out, checks[0]


def gcn_layer_split_sparse(s: Any, h: Array, w: Array, cfg: ABFTConfig,
                           s_c: Optional[Array] = None
                           ) -> tuple[Array, tuple[Check, Check]]:
    """Baseline split ABFT (eqs. 2–3) over a sparse aggregation operand."""
    h_out, checks = _engine_layer(s, h, w, cfg, s_c, "split")
    return h_out, (checks[0], checks[1])


def gcn_layer_sparse(s: Any, h: Array, w: Array, cfg: ABFTConfig,
                     s_c: Optional[Array] = None
                     ) -> tuple[Array, list[Check]]:
    """Policy dispatch used by the sparse GCN model path."""
    return _engine_layer(s, h, w, cfg, s_c, cfg.mode)


# ---------------------------------------------------------------------------
# Aggregation
# ---------------------------------------------------------------------------

def summarize(checks: Sequence[Optional[Check]], cfg: ABFTConfig) -> ABFTReport:
    """Reduce an arbitrary collection of checks to one replicated report."""
    checks = [c for c in checks if c is not None]
    if not checks or not cfg.enabled:
        z = jnp.zeros((), jnp.float32)
        return ABFTReport(flag=jnp.zeros((), bool), max_rel=z, n_checks=z)
    flags, rels, n = [], [], 0
    for c in checks:
        d = c.diff()
        scale = jnp.maximum(1.0, jnp.abs(c.actual))
        rels.append(jnp.max(d / scale))
        flags.append(c.flag(cfg))
        n += int(np_size(c.actual))
    return ABFTReport(
        flag=jnp.stack(flags).any(),
        max_rel=jnp.stack(rels).max().astype(jnp.float32),
        n_checks=jnp.asarray(float(n), jnp.float32),
    )


def per_graph_report(checks: Sequence[Optional[Check]], cfg: ABFTConfig,
                     n: int, *, segments: Optional[Array] = None
                     ) -> tuple[Array, Array]:
    """Elementwise twin of :func:`summarize` for batched checks: one verdict
    per graph instead of one reduced step flag.

    Every check's fields must be [n] batched scalars (the dense batched
    backend and the packed block-ELL segmented epilogue both emit these) —
    OR, when ``segments`` (the [n_stripes] stripe → graph map) is given,
    stripe-granular checks whose fields match the segments shape: their
    per-stripe verdicts reduce onto the owning graphs (OR of flags, max of
    divergences; padding stripes carry id ``n`` — the overflow segment —
    and are dropped).  Returns (flags [n] bool, max_rel [n] f32) — OR / max
    across checks (i.e. across layers), *not* across graphs, so the serving
    layer can retry only the flagged graphs.
    """
    checks = [c for c in checks if c is not None]
    if not checks or not cfg.enabled:
        return jnp.zeros((n,), bool), jnp.zeros((n,), jnp.float32)
    seg_shape = None if segments is None else tuple(jnp.shape(segments))
    flags, rels = None, None
    for c in checks:
        # dispatch on the check's DECLARED granularity, not on shape alone:
        # a packed batch whose stripe count happens to equal its slot count
        # would otherwise read stripe corners as per-graph verdicts and
        # retry the wrong graphs (adopting the corrupted one)
        if c.granularity not in ("stripe", "slot") and c.actual.shape == (n,):
            f, r = c.elementwise(cfg)
        elif c.granularity == "slot" and seg_shape is not None \
                and c.actual.shape[:1] == seg_shape:
            # slot-granular corners [n_stripes, width]: reduce the slot axis
            # (OR / max) to per-stripe verdicts, then segment-reduce onto
            # the owning graphs exactly like stripe corners below
            fs, rs = c.elementwise(cfg)
            fs, rs = fs.any(axis=1), rs.max(axis=1)
            seg = jnp.asarray(segments)
            f = jax.ops.segment_sum(fs.astype(jnp.int32), seg,
                                    num_segments=n + 1,
                                    indices_are_sorted=True)[:n] > 0
            r = jnp.maximum(jax.ops.segment_max(rs, seg,
                                                num_segments=n + 1,
                                                indices_are_sorted=True)[:n],
                            0.0)
        elif c.granularity == "stripe" and seg_shape is not None \
                and c.actual.shape == seg_shape:
            # stripe-granular corners: segment-reduce onto the graphs.
            # segment_sum-of-bools ORs (empty slots own no stripes -> 0 ->
            # False); max of rels floors at 0 so the -inf identity of empty
            # segments never leaks into reporting.
            fs, rs = c.elementwise(cfg)
            seg = jnp.asarray(segments)
            f = jax.ops.segment_sum(fs.astype(jnp.int32), seg,
                                    num_segments=n + 1,
                                    indices_are_sorted=True)[:n] > 0
            r = jnp.maximum(jax.ops.segment_max(rs, seg,
                                                num_segments=n + 1,
                                                indices_are_sorted=True)[:n],
                            0.0)
        else:
            # a scalar (or otherwise-shaped) check cannot be attributed to
            # one graph; silently broadcasting it would mark every graph
            # flagged and defeat the per-graph retry
            raise ValueError(
                f"per_graph_report needs [n={n}]-batched checks, got "
                f"shape {c.actual.shape}; use a backend that emits "
                f"per-graph corners (dense batched / packed block_ell)")
        flags = f if flags is None else flags | f
        rels = r if rels is None else jnp.maximum(rels, r)
    return flags, rels


def per_stripe_report(checks: Sequence[Optional[Check]], cfg: ABFTConfig,
                      n_stripes: int) -> tuple[Array, Array]:
    """Finest-granularity report: one verdict per (check, row-stripe).

    Every check's fields must be [n_stripes] per-stripe corners (the
    block-ELL backends at ``granularity="stripe"``) or [n_stripes, width]
    slot corners (``granularity="slot"``; the slot axis reduces by OR/max —
    a stripe is flagged when any of its slots is).  Returns
    (flags [L, n_stripes] bool, max_rel [L, n_stripes] f32) with one row per
    check — the layer axis is preserved, NOT reduced, because the surgical
    retry must know *which layer's* stripe to re-execute (a fault at layer
    L only dirties downstream values computed from it).
    """
    checks = [c for c in checks if c is not None]
    if not checks or not cfg.enabled:
        return (jnp.zeros((0, n_stripes), bool),
                jnp.zeros((0, n_stripes), jnp.float32))
    flags, rels = [], []
    for c in checks:
        if c.granularity == "slot" and c.actual.ndim == 2 \
                and c.actual.shape[0] == n_stripes:
            f, r = c.elementwise(cfg)
            f, r = f.any(axis=1), r.max(axis=1)
        elif c.actual.shape == (n_stripes,) and c.granularity == "stripe":
            f, r = c.elementwise(cfg)
        else:
            raise ValueError(
                f"per_stripe_report needs [n_stripes={n_stripes}] "
                f"stripe-granular checks, got shape {c.actual.shape} "
                f"(granularity={c.granularity!r}); build the backend with "
                f"granularity='stripe'")
        flags.append(f)
        rels.append(r)
    return jnp.stack(flags), jnp.stack(rels)


def per_slot_report(checks: Sequence[Optional[Check]], cfg: ABFTConfig,
                    n_stripes: int, width: int) -> tuple[Array, Array]:
    """Finest-granularity report: one verdict per (check, stripe, ell-slot).

    Slot-granular checks carry [n_stripes, width] corners (adjacent
    differences of the kernel's telescoped running sums — see
    ``slot_check_corners``); stripe-granular checks in the same forward
    (e.g. a layer that fell back to the two-pass kernel mid-network)
    contribute an all-False slab — they still flag at stripe granularity
    via :func:`per_stripe_report`, they just cannot attribute a slot.
    Returns (flags [L, n_stripes, width] bool, max_rel [...] f32).
    """
    checks = [c for c in checks if c is not None]
    if not checks or not cfg.enabled:
        return (jnp.zeros((0, n_stripes, width), bool),
                jnp.zeros((0, n_stripes, width), jnp.float32))
    flags, rels = [], []
    for c in checks:
        if c.granularity == "slot" and \
                c.actual.shape == (n_stripes, width):
            f, r = c.elementwise(cfg)
        elif c.granularity == "stripe" and c.actual.shape == (n_stripes,):
            f = jnp.zeros((n_stripes, width), bool)
            r = jnp.zeros((n_stripes, width), jnp.float32)
        else:
            raise ValueError(
                f"per_slot_report needs [n_stripes={n_stripes}, "
                f"width={width}] slot-granular checks, got shape "
                f"{c.actual.shape} (granularity={c.granularity!r}); build "
                f"the backend with granularity='slot'")
        flags.append(f)
        rels.append(r)
    return jnp.stack(flags), jnp.stack(rels)


def np_size(x: Array) -> int:
    try:
        return int(x.size)
    except Exception:  # traced value — shape is static anyway
        import numpy as _np
        return int(_np.prod(x.shape)) if x.shape else 1


def merge_reports(reports: Sequence[ABFTReport]) -> ABFTReport:
    """Combine reports from scanned layers / multiple blocks."""
    reports = list(reports)
    if not reports:
        z = jnp.zeros((), jnp.float32)
        return ABFTReport(jnp.zeros((), bool), z, z)
    return ABFTReport(
        flag=jnp.stack([r.flag for r in reports]).any(),
        max_rel=jnp.stack([r.max_rel for r in reports]).max(),
        n_checks=jnp.stack([r.n_checks for r in reports]).sum(),
    )
