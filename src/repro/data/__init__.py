from .synthetic import SyntheticLM, make_batch_specs  # noqa: F401
from .loader import ShardedLoader, Prefetcher  # noqa: F401
