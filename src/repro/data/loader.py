"""Host-sharded loading + double-buffered device prefetch.

Each host generates only its shard of the global batch (deterministic from
(seed, host_id)); `Prefetcher` keeps `depth` batches in flight on device so
host-side generation overlaps device compute — the standard input-pipeline
overlap trick, which matters at scale where the step time shrinks per-chip.
"""
from __future__ import annotations

import collections
import threading
from typing import Any, Callable, Iterator, Optional

import jax
import numpy as np


class ShardedLoader:
    """Wraps a per-host batch iterator and a global->local slicing rule."""

    def __init__(self, it: Iterator, global_batch: int, n_hosts: int,
                 host_id: int):
        assert global_batch % n_hosts == 0
        self.it = it
        self.local = global_batch // n_hosts
        self.host_id = host_id

    def __iter__(self):
        return self

    def __next__(self):
        return next(self.it)


class Prefetcher:
    """Double-buffers device_put'd batches ahead of compute."""

    def __init__(self, it: Iterator, sharding=None, depth: int = 2):
        self.it = it
        self.sharding = sharding
        self.depth = depth
        self.buf: collections.deque = collections.deque()
        self.lock = threading.Lock()
        self._fill()

    def _put(self, batch):
        if self.sharding is None:
            return jax.tree.map(jax.numpy.asarray, batch)
        return jax.tree.map(
            lambda x: jax.device_put(x, self.sharding), batch)

    def _fill(self):
        while len(self.buf) < self.depth:
            try:
                self.buf.append(self._put(next(self.it)))
            except StopIteration:
                break

    def __iter__(self):
        return self

    def __next__(self):
        if not self.buf:
            raise StopIteration
        out = self.buf.popleft()
        self._fill()
        return out
