"""Deterministic synthetic token pipeline.

Serves two purposes: (1) runnable end-to-end training/serving examples
without external corpora; (2) ShapeDtypeStruct specs for the dry-run.

The stream is a seeded Markov-ish mixture so the LM loss actually decreases
(pure-uniform tokens would have irreducible loss = log V): token t is a
deterministic function of token t-1 with probability q, else fresh.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Iterator, Optional

import jax
import numpy as np

from repro.configs.base import ModelConfig, ShapeConfig


@dataclasses.dataclass
class SyntheticLM:
    vocab_size: int
    seq_len: int
    batch_size: int          # per-host batch
    seed: int = 0
    structure: float = 0.75  # P(next token is a deterministic successor)

    def __post_init__(self):
        rng = np.random.default_rng(self.seed)
        self._succ = rng.permutation(self.vocab_size)

    def batches(self, host_id: int = 0) -> Iterator[Dict[str, np.ndarray]]:
        rng = np.random.default_rng(
            np.random.SeedSequence([self.seed, host_id]))
        while True:
            fresh = rng.integers(0, self.vocab_size,
                                 size=(self.batch_size, self.seq_len + 1))
            keep = rng.random((self.batch_size, self.seq_len + 1)) \
                < self.structure
            toks = fresh.copy()
            for t in range(1, self.seq_len + 1):
                toks[:, t] = np.where(keep[:, t],
                                      self._succ[toks[:, t - 1]],
                                      fresh[:, t])
            yield {
                "tokens": toks[:, :-1].astype(np.int32),
                "labels": toks[:, 1:].astype(np.int32),
            }


def make_batch_specs(cfg: ModelConfig, shape: ShapeConfig,
                     prefix_len: int = 64) -> Dict[str, jax.ShapeDtypeStruct]:
    """Abstract input specs for every model input of a given shape cell —
    the dry-run pattern: weak-type-correct, shardable, no allocation."""
    b, t = shape.global_batch, shape.seq_len
    f32 = jax.numpy.float32
    i32 = jax.numpy.int32
    if shape.kind == "train":
        specs = {"tokens": jax.ShapeDtypeStruct((b, t), i32),
                 "labels": jax.ShapeDtypeStruct((b, t), i32)}
        if cfg.family == "encdec":
            specs["src_embeds"] = jax.ShapeDtypeStruct((b, t, cfg.d_model), f32)
        elif cfg.frontend:
            specs["prefix_embeds"] = jax.ShapeDtypeStruct(
                (b, prefix_len, cfg.d_model), f32)
            specs["labels"] = jax.ShapeDtypeStruct((b, t), i32)
        return specs
    if shape.kind == "prefill":
        specs = {"tokens": jax.ShapeDtypeStruct((b, t), i32)}
        if cfg.family == "encdec":
            specs["src_embeds"] = jax.ShapeDtypeStruct((b, t, cfg.d_model), f32)
        elif cfg.frontend:
            specs["prefix_embeds"] = jax.ShapeDtypeStruct(
                (b, prefix_len, cfg.d_model), f32)
        return specs
    # decode: one new token; the KV cache/state specs come from the model
    return {"tokens": jax.ShapeDtypeStruct((b, 1), i32)}
