"""Sharded checkpointing: per-host shard files + manifest, atomic rename,
optional async writer thread.

Layout:  <dir>/step_<N>/
            manifest.json        — tree structure, shapes, dtypes, step,
                                   mesh shape, config fingerprint
            host<h>.npz          — this host's contiguous shard of every leaf
         <dir>/LATEST            — atomic pointer file

Restore is *elastic*: the manifest stores logical (global) shapes, so a
checkpoint written on one mesh restores onto any other mesh/host count —
each host reads the union of files overlapping its new shards
(``elastic.reshard_restore``).  On this single-process container host
count is 1, but the layout and code paths are the production ones.
"""
from __future__ import annotations

import json
import os
import shutil
import threading
import time
from typing import Any, Callable, Dict, List, Optional, Tuple

import jax
import numpy as np

SEP = "/"


def _flatten(tree) -> List[Tuple[str, Any]]:
    flat = jax.tree_util.tree_flatten_with_path(tree)[0]
    out = []
    for path, leaf in flat:
        key = SEP.join(_path_str(p) for p in path)
        out.append((key, leaf))
    return out


def _path_str(p) -> str:
    if hasattr(p, "key"):
        return str(p.key)
    if hasattr(p, "idx"):
        return str(p.idx)
    return str(p)


def save_checkpoint(dirpath: str, step: int, tree, *, host_id: int = 0,
                    n_hosts: int = 1, extra: Optional[Dict] = None) -> str:
    """Write this host's shard + (host 0) the manifest; atomic rename."""
    stepdir = os.path.join(dirpath, f"step_{step:08d}")
    tmpdir = stepdir + f".tmp{host_id}"
    os.makedirs(tmpdir, exist_ok=True)
    flat = _flatten(tree)
    arrays: Dict[str, np.ndarray] = {}
    manifest = {"step": step, "n_hosts": n_hosts,
                "extra": extra or {}, "leaves": {}}
    for key, leaf in flat:
        arr = np.asarray(leaf)
        manifest["leaves"][key] = {"shape": list(arr.shape),
                                   "dtype": str(arr.dtype)}
        arrays[key.replace(SEP, "__")] = arr
    np.savez(os.path.join(tmpdir, f"host{host_id}.npz"), **arrays)
    if host_id == 0:
        with open(os.path.join(tmpdir, "manifest.json"), "w") as f:
            json.dump(manifest, f)
    # atomic publish
    os.makedirs(dirpath, exist_ok=True)
    if os.path.isdir(stepdir):
        shutil.rmtree(stepdir)
    os.rename(tmpdir, stepdir)
    with open(os.path.join(dirpath, "LATEST.tmp"), "w") as f:
        f.write(os.path.basename(stepdir))
    os.replace(os.path.join(dirpath, "LATEST.tmp"),
               os.path.join(dirpath, "LATEST"))
    return stepdir


def latest_step_dir(dirpath: str) -> Optional[str]:
    ptr = os.path.join(dirpath, "LATEST")
    if not os.path.exists(ptr):
        return None
    with open(ptr) as f:
        name = f.read().strip()
    p = os.path.join(dirpath, name)
    return p if os.path.isdir(p) else None


def load_checkpoint(dirpath: str, tree_like, *, host_id: int = 0):
    """Restore the latest checkpoint into the structure of ``tree_like``."""
    stepdir = latest_step_dir(dirpath)
    if stepdir is None:
        return None, -1
    with open(os.path.join(stepdir, "manifest.json")) as f:
        manifest = json.load(f)
    data = np.load(os.path.join(stepdir, f"host{host_id}.npz"))
    flat = _flatten(tree_like)
    restored = []
    for key, leaf in flat:
        arr = data[key.replace(SEP, "__")]
        want = tuple(np.shape(leaf))
        assert tuple(arr.shape) == want, (key, arr.shape, want)
        restored.append(jax.numpy.asarray(arr))
    treedef = jax.tree_util.tree_structure(tree_like)
    return treedef.unflatten(restored), manifest["step"]


class CheckpointManager:
    """Async, bounded-keep checkpoint writer with a step-retention policy."""

    def __init__(self, dirpath: str, keep: int = 3, async_write: bool = True):
        self.dirpath = dirpath
        self.keep = keep
        self.async_write = async_write
        self._thread: Optional[threading.Thread] = None
        self.last_saved = -1

    def save(self, step: int, tree, extra: Optional[Dict] = None):
        # snapshot to host memory synchronously (cheap), write async
        host_tree = jax.tree.map(np.asarray, tree)
        if self._thread is not None:
            self._thread.join()

        def work():
            save_checkpoint(self.dirpath, step, host_tree, extra=extra)
            self._gc()
            self.last_saved = step

        if self.async_write:
            self._thread = threading.Thread(target=work, daemon=True)
            self._thread.start()
        else:
            work()

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def restore(self, tree_like):
        return load_checkpoint(self.dirpath, tree_like)

    def _gc(self):
        if not os.path.isdir(self.dirpath):
            return
        steps = sorted(d for d in os.listdir(self.dirpath)
                       if d.startswith("step_") and not d.endswith("tmp"))
        for d in steps[:-self.keep]:
            shutil.rmtree(os.path.join(self.dirpath, d), ignore_errors=True)
