"""Elastic restore: map a checkpoint onto a different mesh.

The manifest stores *logical* shapes, so restoring under a new mesh is:
read leaves (full arrays on this single-host container; per-host unions in
multi-host deployments) then ``jax.device_put`` with the NEW sharding specs.
This is what lets a 512-chip job resume on 448 chips after losing a pod
slice — combined with `launch.mesh.make_production_mesh(degraded=...)`.
"""
from __future__ import annotations

from typing import Any, Callable, Optional

import jax

from .ckpt import load_checkpoint


def reshard_restore(dirpath: str, tree_like, shardings) -> tuple[Any, int]:
    """Restore the latest checkpoint and place each leaf with the sharding
    from ``shardings`` (a pytree of NamedSharding matching tree_like)."""
    restored, step = load_checkpoint(dirpath, tree_like)
    if restored is None:
        return None, -1
    flat_r, treedef = jax.tree.flatten(restored)
    flat_s = treedef.flatten_up_to(shardings)
    placed = [jax.device_put(r, s) if s is not None else r
              for r, s in zip(flat_r, flat_s)]
    return treedef.unflatten(placed), step
