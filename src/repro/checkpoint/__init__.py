from .ckpt import (  # noqa: F401
    CheckpointManager,
    load_checkpoint,
    save_checkpoint,
)
from .elastic import reshard_restore  # noqa: F401
