"""Host-sync & retrace lint: AST rules over the dispatch layers.

The streaming engine's performance contract is "one bounded host sync
per adjudicated batch, compiles bounded by the rung table".  Previous
PRs enforced that by hand, one bug at a time (PR 6's compile-cardinality
fixes, this PR's latency-stat sync fix); this pass enforces it
statically over ``src/repro/engine/`` and ``src/repro/launch/``.

Rules (all stdlib ``ast`` — no new dependencies):

* ``implicit-sync-in-loop`` — ``float()``, ``int()``, ``bool()``,
  ``.item()``, ``.tolist()``, ``np.asarray()`` / ``np.array()``,
  ``jax.device_get()``, ``.block_until_ready()`` inside a ``for`` /
  ``while`` body.  On a traced/device value each of these blocks the
  Python thread on a device transfer; inside a dispatch loop that
  serializes the stream.
* ``backend-query-in-loop`` — ``jax.default_backend()`` /
  ``jax.devices()`` in a loop; the answer never changes and the lookup
  isn't free.  The canonical resolution site is
  ``repro.kernels.runtime.resolve_interpret`` (exempted).
* ``jit-in-loop`` — ``jax.jit`` / ``functools.partial(jax.jit, ...)``
  called inside a loop: every iteration builds a NEW jitted callable
  with an empty compile cache — the PR-6 unbounded-retrace bug class.
* ``pack-without-caps`` — a ``pack_graphs(...)`` call with none of
  ``stripe_cap`` / ``width_cap`` / ``stripe_multiple`` /
  ``width_multiple``: every distinct graph shape then mints a distinct
  packed shape, i.e. a distinct compile (bounded-compile discipline).
* ``mutable-default`` — list/dict/set (or call) default argument
  values; and
* ``fold-in-loop`` — ``fold_w_r(...)`` inside a loop body: the fold is
  weight-load-time work, re-folding per step recomputes every layer's
  w_r (and on stale params reintroduces the stale-``fold_w_r`` bug).

Suppression: append ``# abftlint: <rule>-ok`` (or the generic
``# abftlint: ok``) to the flagged line — intended syncs (the guard's
verdict read, a benchmark's result collection) are annotated at the
site, so the gate stays zero-findings.
"""
from __future__ import annotations

import ast
import dataclasses
import re
from pathlib import Path
from typing import Dict, Iterable, List, Optional, Sequence, Set

# call names that force a device->host transfer when applied to a traced
# or device value
_SYNC_BUILTINS = {"float", "int", "bool"}
_SYNC_METHODS = {"item", "tolist", "block_until_ready"}
_SYNC_NP_FUNCS = {"asarray", "array"}
_BACKEND_QUERIES = {"default_backend", "devices", "local_devices"}

_SUPPRESS_RE = re.compile(r"#\s*abftlint:\s*([a-z0-9_,\- ]+)")

DEFAULT_SCAN_DIRS = ("src/repro/engine", "src/repro/launch",
                     "src/repro/faults")
# the single blessed resolution site for backend queries
EXEMPT_FILES = ("kernels/runtime.py",)


@dataclasses.dataclass(frozen=True)
class SyncFinding:
    rule: str
    path: str
    line: int
    col: int
    message: str

    def to_dict(self) -> dict:
        return dataclasses.asdict(self)

    def __str__(self) -> str:
        return f"{self.path}:{self.line}:{self.col}: [{self.rule}] " \
               f"{self.message}"


def _suppressions(source: str) -> Dict[int, Set[str]]:
    out: Dict[int, Set[str]] = {}
    for i, text in enumerate(source.splitlines(), start=1):
        m = _SUPPRESS_RE.search(text)
        if m:
            tags = {t.strip() for t in m.group(1).replace(",", " ").split()}
            out[i] = tags
    return out


def _dotted(node: ast.AST) -> str:
    """Best-effort dotted name of a call target ('np.asarray', 'jax.jit')."""
    parts: List[str] = []
    while isinstance(node, ast.Attribute):
        parts.append(node.attr)
        node = node.value
    if isinstance(node, ast.Name):
        parts.append(node.id)
    return ".".join(reversed(parts))


class _Visitor(ast.NodeVisitor):
    def __init__(self, path: str):
        self.path = path
        self.loop_depth = 0
        self.findings: List[SyncFinding] = []

    def _flag(self, rule: str, node: ast.AST, message: str) -> None:
        self.findings.append(SyncFinding(
            rule=rule, path=self.path, line=getattr(node, "lineno", 0),
            col=getattr(node, "col_offset", 0), message=message))

    # -- loops ------------------------------------------------------------

    def visit_For(self, node: ast.For) -> None:
        self.loop_depth += 1
        self.generic_visit(node)
        self.loop_depth -= 1

    visit_While = visit_For  # type: ignore[assignment]
    visit_AsyncFor = visit_For  # type: ignore[assignment]

    # -- defs: mutable defaults ------------------------------------------

    def visit_FunctionDef(self, node) -> None:
        for default in list(node.args.defaults) + \
                [d for d in node.args.kw_defaults if d is not None]:
            if isinstance(default, (ast.List, ast.Dict, ast.Set, ast.Call)):
                self._flag("mutable-default", default,
                           f"mutable default argument in {node.name}(); "
                           f"shared across calls — default to None")
        self.generic_visit(node)

    visit_AsyncFunctionDef = visit_FunctionDef  # type: ignore[assignment]

    # -- calls ------------------------------------------------------------

    def visit_Call(self, node: ast.Call) -> None:
        name = _dotted(node.func)
        tail = name.rsplit(".", 1)[-1]

        if self.loop_depth > 0:
            if name in _SYNC_BUILTINS and node.args and \
                    not isinstance(node.args[0], ast.Constant):
                self._flag("implicit-sync-in-loop", node,
                           f"{name}(...) in a loop blocks on device "
                           f"transfer when its operand is traced/device "
                           f"data; hoist to the stats flush or annotate")
            elif tail in _SYNC_METHODS and isinstance(node.func,
                                                      ast.Attribute):
                self._flag("implicit-sync-in-loop", node,
                           f".{tail}() in a loop is a per-iteration host "
                           f"sync; batch the transfer outside the loop")
            elif tail in _SYNC_NP_FUNCS and name.split(".")[0] in \
                    ("np", "numpy", "onp"):
                self._flag("implicit-sync-in-loop", node,
                           f"{name}(...) in a loop copies device data to "
                           f"host per iteration; hoist one bulk transfer")
            elif name == "jax.device_get":
                self._flag("implicit-sync-in-loop", node,
                           "jax.device_get in a loop; batch it")
            elif tail in _BACKEND_QUERIES and name.startswith("jax"):
                self._flag("backend-query-in-loop", node,
                           f"{name}() in a loop; resolve once via "
                           f"repro.kernels.runtime.resolve_interpret")
            if name in ("jax.jit", "jit") or (
                    tail == "partial" and node.args and
                    _dotted(node.args[0]) in ("jax.jit", "jit")):
                self._flag("jit-in-loop", node,
                           "jax.jit inside a loop mints a fresh compile "
                           "cache every iteration (unbounded retraces); "
                           "build the jitted callable once outside")

        if tail == "pack_graphs":
            kw = {k.arg for k in node.keywords}
            if not kw & {"stripe_cap", "width_cap", "stripe_multiple",
                         "width_multiple"}:
                self._flag("pack-without-caps", node,
                           "pack_graphs without stripe/width caps or "
                           "multiples: every graph-shape mix mints a new "
                           "packed shape -> a new compile; quantize the "
                           "shape menu")
        if tail == "fold_w_r" and self.loop_depth > 0:
            self._flag("fold-in-loop", node,
                       "fold_w_r inside a loop re-derives every layer's "
                       "w_r per iteration; fold once at weight load")
        self.generic_visit(node)


def scan_source(source: str, path: str = "<string>") -> List[SyncFinding]:
    """Lint one module's source; suppressed findings are dropped."""
    tree = ast.parse(source, filename=path)
    v = _Visitor(path)
    v.visit(tree)
    sup = _suppressions(source)
    out = []
    for f in v.findings:
        if not any(_suppresses(t, f.rule) for t in sup.get(f.line, ())):
            out.append(f)
    return out


def _suppresses(tag: str, rule: str) -> bool:
    """``# abftlint: ok`` silences everything on the line; a rule tag
    (``implicit-sync-in-loop-ok``) or an unambiguous shorthand whose stem
    appears in the rule name (``sync-ok``, ``backend-query-ok``) silences
    just that rule."""
    if tag == "ok" or tag == rule or tag == f"{rule}-ok":
        return True
    return tag.endswith("-ok") and tag[:-3] in rule


def scan_file(path: Path) -> List[SyncFinding]:
    return scan_source(path.read_text(), str(path))


def scan_paths(paths: Iterable[Path], *,
               exempt: Sequence[str] = EXEMPT_FILES) -> List[SyncFinding]:
    findings: List[SyncFinding] = []
    for p in sorted(paths):
        if any(str(p).endswith(e) for e in exempt):
            continue
        findings.extend(scan_file(p))
    return findings


def scan_tree(root: Path, *, dirs: Sequence[str] = DEFAULT_SCAN_DIRS
              ) -> List[SyncFinding]:
    """Lint the repo's dispatch layers (engine/ + launch/) under ``root``."""
    files: List[Path] = []
    for d in dirs:
        base = root / d
        if base.is_dir():
            files.extend(base.rglob("*.py"))
    return scan_paths(files)
