"""ABFT coverage verifier: prove every matmul in a traced step flows into
an eq. 4-6 checksum comparison.

The paper's value proposition is *total* coverage — every three-matrix
GCN product guarded by one fused checksum — but until this pass existed
nothing could verify that property; it was asserted by hand-written
parity tests per kernel.  This module makes it a theorem about the
jaxpr:

1. Trace the step under :func:`repro.core.marker.check_tagging`, so
   every ``Check.diff()`` comparison leaves an ``abft_check_sink``
   equation in the trace (see ``core/marker.py``).
2. Flatten the ClosedJaxpr recursively — pjit, custom_jvp/vjp, scan,
   while, cond sub-jaxprs are walked with *alias* edges tying inner
   binders to outer operands (scan carries additionally loop back), so
   dataflow is tracked precisely across call boundaries instead of
   smearing "output depends on every input" over them.
3. Collect **op sites**: every ``dot_general`` equation, and every
   ``pallas_call`` whose kernel jaxpr contains a ``dot_general``
   (matmul-shaped — the spmm/fused/network kernels all are).  A
   pallas_call is one site, not many: its internal matmuls are covered
   by the checksum its own epilogue emits, so the site is checked iff
   any of its outputs (the actual-checksum corners included) reaches a
   sink.
4. Run backward reachability from every sink's inputs over the def-use
   graph.  A site is **checked** iff one of its outputs is an ancestor
   of a sink input; the granularities of the sinks it reaches are
   recorded per site.

Anything that fails step 4 is reported with its jaxpr provenance
(``file:line (fn)`` via ``source_info_util``) and serialized into a
machine-readable :class:`CoverageManifest` that tests and CI diff
against golden values — the LM example's manifest doubles as ROADMAP
item 2's TODO list.

Sub-jaxprs of primitives this walker does not understand are traversed
conservatively (no alias edges, coarse in->out dependence): matmuls
inside them still become sites, and they stay *unchecked* unless a sink
reaches them through the coarse edges — the lint fails loud rather than
silently trusting unknown control flow.
"""
from __future__ import annotations

import dataclasses
import json
from typing import Any, Dict, Iterator, List, Optional, Sequence, Set, Tuple

from repro.core.marker import CHECK_SINK

# primitives that never carry payload dataflow we care about tracing
# through sub-jaxprs specially; everything else with a jaxpr param gets
# the conservative fallback
_CALL_PRIMS = ("pjit", "closed_call", "core_call", "remat", "remat2",
               "checkpoint", "custom_jvp_call", "custom_vjp_call",
               "custom_vjp_call_jaxpr")


def _closed(j: Any) -> Any:
    """Normalize Jaxpr vs ClosedJaxpr param values to (jaxpr, ok)."""
    inner = getattr(j, "jaxpr", None)
    return j.jaxpr if inner is not None and hasattr(j, "consts") else j


def _is_var(v: Any) -> bool:
    # Literals carry .val; Vars don't.  DropVars are Vars (never read, so
    # keeping them is harmless).
    return not hasattr(v, "val")


@dataclasses.dataclass
class OpSite:
    """One matmul-shaped operation occurrence in the traced step."""

    kind: str                 # "dot_general" | "pallas_call"
    name: str                 # primitive or kernel name
    out_shape: Tuple[int, ...]
    provenance: str           # "file:line (fn)"
    path: str                 # jaxpr nesting path, e.g. "pjit/pjit"
    checked: bool = False
    granularities: Tuple[str, ...] = ()

    def to_dict(self) -> dict:
        d = dataclasses.asdict(self)
        d["out_shape"] = list(self.out_shape)
        d["granularities"] = list(self.granularities)
        return d


@dataclasses.dataclass
class CoverageManifest:
    """Machine-readable result of one coverage run — the golden artifact
    tests and CI assert against."""

    step: str
    n_sinks: int
    sink_granularities: Tuple[str, ...]
    checked_ops: List[OpSite]
    unchecked_ops: List[OpSite]

    @property
    def n_checked(self) -> int:
        return len(self.checked_ops)

    @property
    def n_unchecked(self) -> int:
        return len(self.unchecked_ops)

    @property
    def coverage(self) -> float:
        total = self.n_checked + self.n_unchecked
        return 1.0 if total == 0 else self.n_checked / total

    def to_dict(self) -> dict:
        return {
            "step": self.step,
            "n_sinks": self.n_sinks,
            "sink_granularities": list(self.sink_granularities),
            "n_checked": self.n_checked,
            "n_unchecked": self.n_unchecked,
            "coverage": round(self.coverage, 6),
            "checked_ops": [s.to_dict() for s in self.checked_ops],
            "unchecked_ops": [s.to_dict() for s in self.unchecked_ops],
        }

    def to_json(self, **kw) -> str:
        kw.setdefault("indent", 2)
        return json.dumps(self.to_dict(), **kw)


def _provenance(eqn: Any) -> str:
    from jax._src import source_info_util
    try:
        return source_info_util.summarize(eqn.source_info)
    except Exception:
        return "<unknown>"


def _pallas_name(eqn: Any) -> str:
    nsi = eqn.params.get("name_and_src_info")
    name = getattr(nsi, "name", None) or eqn.params.get("name")
    return str(name) if name else "pallas_call"


def _kernel_has_dot(jaxpr: Any) -> bool:
    """Matmul-shaped test: the pallas kernel's jaxpr (recursively)
    contains a dot_general."""
    for eqn in jaxpr.eqns:
        if eqn.primitive.name == "dot_general":
            return True
        for v in eqn.params.values():
            inner = _maybe_jaxpr(v)
            if inner is not None and _kernel_has_dot(inner):
                return True
    return False


def _maybe_jaxpr(v: Any) -> Optional[Any]:
    if hasattr(v, "eqns") and hasattr(v, "invars"):
        return v
    inner = getattr(v, "jaxpr", None)
    if inner is not None and hasattr(inner, "eqns"):
        return inner
    return None


def iter_eqns(closed_jaxpr: Any, *, into_pallas: bool = False
              ) -> Iterator[Tuple[Any, str]]:
    """Yield (eqn, nesting_path) over a ClosedJaxpr and its sub-jaxprs.

    ``pallas_call`` kernel bodies are skipped unless ``into_pallas`` —
    coverage treats a kernel as one opaque checked unit, and the VMEM
    pass only needs the call equation itself.
    """
    def walk(jaxpr, path):
        for eqn in jaxpr.eqns:
            yield eqn, path
            if eqn.primitive.name == "pallas_call" and not into_pallas:
                continue
            for v in eqn.params.values():
                inner = _maybe_jaxpr(v)
                if inner is not None:
                    yield from walk(inner, f"{path}/{eqn.primitive.name}")
                elif isinstance(v, (tuple, list)):
                    for item in v:
                        inner = _maybe_jaxpr(item)
                        if inner is not None:
                            yield from walk(
                                inner, f"{path}/{eqn.primitive.name}")

    yield from walk(closed_jaxpr.jaxpr, "")


@dataclasses.dataclass
class _Graph:
    """Reverse def-use graph over Var object ids.

    Def-use: each outvar points back at its equation's invars.  Alias
    (an inner jaxpr binder standing for an outer operand, or a scan
    carry looping back) is *equality*, so it contributes edges in BOTH
    directions — backward reachability may cross it either way.  Keying
    by raw ``id(var)`` (SSA: one defining equation per Var) avoids any
    stale-representative hazards a union-find over a growing edge map
    would have.
    """

    rev: Dict[int, Set[int]]
    sites: List[Tuple[OpSite, List[Any]]]   # site, its outvars
    sinks: List[Tuple[str, List[Any]]]      # granularity, sink invars


def _add_edges(g: _Graph, invars: Sequence[Any], outvars: Sequence[Any]):
    ins = {id(v) for v in invars if _is_var(v)}
    for o in outvars:
        if _is_var(o):
            g.rev.setdefault(id(o), set()).update(ins)


def _alias_all(g: _Graph, outer: Sequence[Any], inner: Sequence[Any]):
    for a, b in zip(outer, inner):
        if _is_var(a) and _is_var(b):
            g.rev.setdefault(id(a), set()).add(id(b))
            g.rev.setdefault(id(b), set()).add(id(a))


def _walk(g: _Graph, jaxpr: Any, path: str) -> None:
    for eqn in jaxpr.eqns:
        prim = eqn.primitive.name
        params = eqn.params

        if prim == CHECK_SINK:
            g.sinks.append((str(params.get("granularity", "?")),
                            [v for v in eqn.invars if _is_var(v)]))
            _add_edges(g, eqn.invars, eqn.outvars)
            continue

        if prim == "dot_general":
            site = OpSite(kind="dot_general", name="dot_general",
                          out_shape=tuple(eqn.outvars[0].aval.shape),
                          provenance=_provenance(eqn),
                          path=path or "/")
            g.sites.append((site, list(eqn.outvars)))
            _add_edges(g, eqn.invars, eqn.outvars)
            continue

        if prim == "pallas_call":
            if _kernel_has_dot(params["jaxpr"]):
                site = OpSite(kind="pallas_call", name=_pallas_name(eqn),
                              out_shape=tuple(eqn.outvars[0].aval.shape),
                              provenance=_provenance(eqn),
                              path=path or "/")
                g.sites.append((site, list(eqn.outvars)))
            # opaque unit: every output depends on every input; the
            # kernel's internal dot_generals are the site itself
            _add_edges(g, eqn.invars, eqn.outvars)
            continue

        if prim in _CALL_PRIMS:
            inner = params.get("jaxpr") or params.get("call_jaxpr") \
                or params.get("fun_jaxpr")
            inner = _closed(inner) if inner is not None else None
            if inner is not None:
                n_consts = int(params.get("num_consts", 0) or 0)
                outer_in = list(eqn.invars)[n_consts:]
                # align from the tail when lengths disagree (some custom
                # calls prepend residuals/consts we didn't account for)
                k = min(len(outer_in), len(inner.invars))
                _alias_all(g, outer_in[-k:], list(inner.invars)[-k:])
                _alias_all(g, eqn.outvars, inner.outvars)
                _walk(g, inner, f"{path}/{prim}")
                continue

        elif prim == "scan":
            inner = _closed(params["jaxpr"])
            nc, ncar = int(params["num_consts"]), int(params["num_carry"])
            _alias_all(g, eqn.invars, inner.invars)
            _alias_all(g, eqn.outvars, inner.outvars)
            # carry loop-back: iteration i+1's carry binder is iteration
            # i's carry output
            _alias_all(g, list(inner.outvars)[:ncar],
                       list(inner.invars)[nc:nc + ncar])
            _walk(g, inner, f"{path}/scan")
            continue

        elif prim == "while":
            body = _closed(params["body_jaxpr"])
            cond = _closed(params["cond_jaxpr"])
            cn, bn = int(params["cond_nconsts"]), int(params["body_nconsts"])
            carry = list(eqn.invars)[cn + bn:]
            _alias_all(g, list(eqn.invars)[cn:cn + bn],
                       list(body.invars)[:bn])
            _alias_all(g, carry, list(body.invars)[bn:])
            _alias_all(g, list(eqn.invars)[:cn], list(cond.invars)[:cn])
            _alias_all(g, carry, list(cond.invars)[cn:])
            _alias_all(g, eqn.outvars, body.outvars)
            _alias_all(g, list(body.outvars), list(body.invars)[bn:])
            _walk(g, body, f"{path}/while")
            _walk(g, cond, f"{path}/while")
            continue

        elif prim == "cond":
            ops = list(eqn.invars)[1:]
            for br in params["branches"]:
                inner = _closed(br)
                _alias_all(g, ops, inner.invars)
                _alias_all(g, eqn.outvars, inner.outvars)
                _walk(g, inner, f"{path}/cond")
            continue

        # conservative fallback for any other primitive carrying
        # sub-jaxprs: traverse (sites inside still get reported) but
        # don't pretend we know the dataflow — coarse in->out edges only
        for v in params.values():
            for item in (v if isinstance(v, (tuple, list)) else (v,)):
                inner = _maybe_jaxpr(item)
                if inner is not None:
                    _walk(g, inner, f"{path}/{prim}")
        _add_edges(g, eqn.invars, eqn.outvars)


def analyze_jaxpr(closed_jaxpr: Any, *, step: str = "") -> CoverageManifest:
    """Run the coverage analysis on an already-traced ClosedJaxpr.

    The trace must have been taken under
    :func:`repro.core.marker.check_tagging` for sinks to exist; a trace
    with zero sinks reports every matmul unchecked (which is exactly
    what an unguarded model should look like).
    """
    g = _Graph(rev={}, sites=[], sinks=[])
    _walk(g, closed_jaxpr.jaxpr, "")

    # backward reachability, one sweep per granularity so each checked
    # site can name the granularities of the comparisons it feeds
    ancestors_by_gran: Dict[str, Set[int]] = {}
    for gran, invars in g.sinks:
        seen = ancestors_by_gran.setdefault(gran, set())
        frontier = [id(v) for v in invars]
        while frontier:
            node = frontier.pop()
            if node in seen:
                continue
            seen.add(node)
            frontier.extend(g.rev.get(node, ()))

    checked, unchecked = [], []
    for site, outvars in g.sites:
        classes = {id(v) for v in outvars if _is_var(v)}
        grans = sorted(gran for gran, anc in ancestors_by_gran.items()
                       if classes & anc)
        if grans:
            site.checked = True
            site.granularities = tuple(grans)
            checked.append(site)
        else:
            unchecked.append(site)

    return CoverageManifest(
        step=step, n_sinks=len(g.sinks),
        sink_granularities=tuple(sorted({gr for gr, _ in g.sinks})),
        checked_ops=checked, unchecked_ops=unchecked)


def analyze_step(fn: Any, *args: Any, step: str = "",
                 **make_jaxpr_kwargs: Any) -> CoverageManifest:
    """Trace ``fn(*args)`` under check tagging and analyze coverage.

    ``fn`` must close over everything static; ``args`` are example
    operands (shapes matter, values don't — nothing executes).
    """
    import jax

    from repro.core.marker import check_tagging

    with check_tagging():
        closed = jax.make_jaxpr(fn, **make_jaxpr_kwargs)(*args)
    return analyze_jaxpr(closed, step=step)


def format_report(m: CoverageManifest, *, verbose: bool = False) -> str:
    """Human-readable lint report for one manifest."""
    lines = [f"[coverage] step={m.step or '<unnamed>'}: "
             f"{m.n_checked} checked, {m.n_unchecked} unchecked matmul "
             f"site(s); {m.n_sinks} check sink(s) "
             f"({', '.join(m.sink_granularities) or 'none'})"]
    for s in m.unchecked_ops:
        lines.append(f"  UNCHECKED {s.kind} {s.name} out={list(s.out_shape)}"
                     f" at {s.provenance}  [{s.path}]")
    if verbose:
        for s in m.checked_ops:
            lines.append(f"  checked   {s.kind} {s.name} "
                         f"out={list(s.out_shape)} at {s.provenance} "
                         f"-> {','.join(s.granularities)}")
    return "\n".join(lines)
