"""`abftlint` — static analysis for the GCN-ABFT serving stack.

Four passes, one CLI (``python -m repro.analysis.lint``):

* :mod:`repro.analysis.coverage` — jaxpr-level proof that every matmul
  flows into an eq. 4-6 checksum comparison;
* :mod:`repro.analysis.vmem` — the shared VMEM working-set model (also
  the runtime fallback predicate) + static per-``pallas_call`` and
  per-rung budget checks;
* :mod:`repro.analysis.syncs` — AST lint for implicit host syncs,
  unbounded jit cardinality, and mutable-default hazards in the engine
  and launch layers;
* :mod:`repro.analysis.lint` — the CLI tying them together and the CI
  gate's entry point.

This package is imported by ``repro.kernels.gcn_fused.ops`` (for the
shared VMEM model), so ``__init__`` stays import-light: submodules load
lazily.
"""
from __future__ import annotations

import importlib

_SUBMODULES = ("coverage", "vmem", "syncs", "lint")


def __getattr__(name):
    if name in _SUBMODULES:
        return importlib.import_module(f"{__name__}.{name}")
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")


def __dir__():
    return sorted(list(globals()) + list(_SUBMODULES))
