"""``abftlint`` CLI: run the static-analysis passes over a traced step.

    PYTHONPATH=src python -m repro.analysis.lint --step gcn-serve \
        --granularity slot --fused-network

Steps (each builds a tiny synthetic instance of the real serving path —
shapes matter to a trace, values don't):

* ``gcn-serve``    — the packed block-ELL serve step
  (``make_packed_serve_step``), exactly what ``launch/serve_gcn.py``
  dispatches;
* ``gcn-stream``   — the same step at every rung of a ``plan_rungs``
  shape menu, plus the rung-table VMEM lint *before* anything compiles;
* ``gcn-forward``  — the engine forward (``--backend dense|bcoo``);
* ``gcn-train``    — a jitted ``value_and_grad`` GCN train step (the
  backward pass's dot_generals are expected-unchecked: ABFT covers the
  forward products, which is the paper's scope — so this step reports
  them rather than gating on them);
* ``lm-prefill`` / ``lm-decode`` — the guarded LM serving steps
  (``engine/lm.py``'s checked-op factories, what ``launch/serve_lm.py``
  dispatches): folded-``w_r`` dense checks + the fused attention chain
  check + per-op verdict vectors.  These default to ``--mode fused``
  and gate on zero unchecked matmuls — ROADMAP item 2 is done; the old
  unguarded baseline manifest is still available via ``--mode none``
  (with ``--expect-unchecked``);
* ``gat-serve``    — the guarded GAT serve step (``engine/gat.py``):
  the attention-weighted aggregation's eq. 4–6 chain corner per layer.

Passes (``--passes coverage,vmem,syncs``; default all that apply):
coverage traces the step under check tagging and verifies every
dot_general / matmul-shaped pallas_call reaches an eq. 4-6 comparison;
vmem statically prices every traced pallas_call's BlockSpecs and (for
gcn-stream) every rung against the budget; syncs AST-lints
``src/repro/engine`` + ``src/repro/launch``.

Exit status: 0 clean, 1 findings, 2 usage/build error.
"""
from __future__ import annotations

import argparse
import json
import sys
from pathlib import Path
from typing import List, Optional

STEPS = ("gcn-serve", "gcn-stream", "gcn-forward", "gcn-train",
         "lm-prefill", "lm-decode", "gat-serve")
PASSES = ("coverage", "vmem", "syncs")


def _synth_graphs(n_graphs: int, nodes: int, feat: int, seed: int = 0):
    import numpy as np
    rng = np.random.default_rng(seed)
    graphs = []
    for _ in range(n_graphs):
        s = (rng.random((nodes, nodes)) < 0.3).astype(np.float32)
        s += np.eye(nodes, dtype=np.float32)
        graphs.append((s, rng.random((nodes, feat)).astype(np.float32)))
    return graphs


def _gcn_params(dims, seed: int = 0):
    import jax

    from repro.core.gcn import init_gcn
    return init_gcn(jax.random.PRNGKey(seed), dims)


def _trace(fn, *args):
    import jax

    from repro.core.marker import check_tagging
    with check_tagging():
        return jax.make_jaxpr(fn)(*args)


def _packed_step_trace(args, granularity: str):
    """(closed_jaxpr, pb, dims) for the packed GCN serve step."""
    from repro.engine.api import fold_w_r
    from repro.engine.batching import pack_graphs
    from repro.engine.streaming import make_packed_serve_step, \
        packed_step_args

    from repro.core.abft import ABFTConfig

    dims = [args.feat, args.hidden, args.classes]
    cfg = ABFTConfig(mode=args.mode)
    params = fold_w_r(_gcn_params(dims), cfg)
    graphs = _synth_graphs(args.graphs, args.nodes, args.feat)
    pb = pack_graphs(graphs, block=args.block, n_slots=args.graphs)
    step = make_packed_serve_step(
        params, cfg, pb.n_slots, granularity=granularity,
        fused_layer=args.fused_layer, fused_network=args.fused_network,
        vmem_budget=args.vmem_budget)
    closed = _trace(step, *packed_step_args(pb))
    return closed, pb, dims


def _build_traces(args) -> List[tuple]:
    """[(name, closed_jaxpr)] for the requested step, plus any extra
    findings produced while building (rung-table lint)."""
    import jax
    import jax.numpy as jnp

    from repro.core.abft import ABFTConfig

    step, gran = args.step, args.granularity
    if step == "gcn-serve":
        closed, _pb, _dims = _packed_step_trace(args, gran)
        return [(f"gcn-serve/{gran}", closed)], []

    if step == "gcn-stream":
        import numpy as np

        from repro.analysis.vmem import lint_rung_table
        from repro.engine.batching import pack_graphs
        from repro.engine.streaming import make_packed_serve_step, \
            packed_step_args, plan_rungs

        dims = [args.feat, args.hidden, args.classes]
        cfg = ABFTConfig(mode=args.mode)
        from repro.engine.api import fold_w_r
        params = fold_w_r(_gcn_params(dims), cfg)
        graphs = _synth_graphs(max(args.graphs, 4), args.nodes, args.feat)
        rungs = plan_rungs(graphs, n_slots=4, block=args.block)
        # VMEM lint FIRST — an over-budget rung is rejected before any
        # rung shape is traced, let alone compiled
        verdicts = lint_rung_table(
            rungs, dims, block=args.block,
            budget=args.vmem_budget or _default_budget(),
            fused_network=args.fused_network)
        extra = [f"rung {v.stripe_cap}x{v.width_cap}x{v.n_slots}: "
                 f"{(v.network_bytes or v.layer_bytes)} bytes over budget "
                 f"{v.budget}" for v in verdicts if not v.fits]
        traces = []
        for r in rungs.rungs:
            pb = pack_graphs(graphs[:1], block=rungs.block,
                             n_slots=r.n_slots,
                             stripe_cap=r.stripe_cap, width_cap=r.width_cap,
                             stripe_multiple=rungs.stripe_multiple,
                             width_multiple=rungs.width_multiple)
            s = make_packed_serve_step(
                params, cfg, pb.n_slots, granularity=gran,
                fused_layer=args.fused_layer,
                fused_network=args.fused_network,
                vmem_budget=args.vmem_budget)
            traces.append((
                f"gcn-stream/rung{r.stripe_cap}x{r.width_cap}/{gran}",
                _trace(s, *packed_step_args(pb))))
        return traces, extra

    if step == "gcn-forward":
        from repro.core.abft import summarize
        from repro.engine import Graph, gcn_forward

        dims = [args.feat, args.hidden, args.classes]
        cfg = ABFTConfig(mode=args.mode)
        params = _gcn_params(dims)
        g = _synth_graphs(1, args.nodes, args.feat)[0]
        s, h0 = jnp.asarray(g[0]), jnp.asarray(g[1])
        if args.backend == "bcoo":
            from jax.experimental import sparse as jsparse
            s = jsparse.BCOO.fromdense(s)

        def fwd(h0):
            logits, checks = gcn_forward(params, Graph(s=s, h0=h0), cfg,
                                         backend=args.backend)
            rep = summarize(checks, cfg)
            return logits, rep.flag, rep.max_rel

        return [(f"gcn-forward/{args.backend}", _trace(jax.jit(fwd), h0))], []

    if step == "gcn-train":
        from repro.core.abft import ABFTConfig
        from repro.core.gcn import gcn_loss

        dims = [args.feat, args.hidden, args.classes]
        cfg = ABFTConfig(mode=args.mode)
        params = _gcn_params(dims)
        g = _synth_graphs(1, args.nodes, args.feat)[0]
        s, h0 = jnp.asarray(g[0]), jnp.asarray(g[1])
        import numpy as np
        labels = jnp.asarray(
            np.arange(args.nodes) % args.classes, jnp.int32)

        def train(params, h0):
            (loss, rep), grads = jax.value_and_grad(
                lambda p: gcn_loss(p, s, h0, labels, None, cfg),
                has_aux=True)(params)
            new = jax.tree.map(lambda p, g: p - 1e-2 * g, params, grads)
            return loss, rep.flag, new

        return [("gcn-train", _trace(jax.jit(train), params, h0))], []

    if step in ("lm-prefill", "lm-decode"):
        import numpy as np

        from repro.configs import get_config, smoke_config
        from repro.engine.lm import (
            fold_lm_w_r,
            make_guarded_decode_step,
            make_guarded_prefill_step,
        )
        from repro.models.transformer import init_model

        cfg = smoke_config(get_config(args.arch))
        abft = ABFTConfig(mode=args.mode)
        params = fold_lm_w_r(init_model(cfg, jax.random.PRNGKey(0)),
                             cfg, abft)
        rng = np.random.default_rng(0)
        prompt, cache_len = 8, 16
        batch = {"tokens": jnp.asarray(
            rng.integers(0, cfg.vocab_size, size=(2, prompt)), jnp.int32)}
        if cfg.family == "encdec":
            batch["src_embeds"] = jnp.asarray(
                rng.normal(size=(2, prompt, cfg.d_model)), jnp.float32)
        # trace the string-free jitted cores (.traceable): the host-side
        # wrappers attach the static op-id tuple, which is not a JAX type
        prefill = make_guarded_prefill_step(cfg, abft, cache_len).traceable
        inj = jnp.float32(0.0)
        if step == "lm-prefill":
            return [(f"lm-prefill/{cfg.name}",
                     _trace(prefill, params, batch, inj))], []
        (_logits, states), _m = jax.eval_shape(prefill, params, batch, inj)
        states = jax.tree.map(
            lambda sd: jnp.zeros(sd.shape, sd.dtype), states)
        tok = jnp.zeros((2, 1), jnp.int32)
        pos = jnp.asarray(prompt, jnp.int32)
        fn = make_guarded_decode_step(cfg, abft).traceable
        return [(f"lm-decode/{cfg.name}",
                 _trace(fn, params, states, tok, pos, inj))], []

    if step == "gat-serve":
        from repro.engine.gat import (
            fold_gat_w_r,
            init_gat,
            make_gat_serve_step,
        )

        cfg = ABFTConfig(mode=args.mode)
        dims = (args.feat, args.hidden, args.hidden, args.classes)
        params = fold_gat_w_r(init_gat(jax.random.PRNGKey(0), dims), cfg)
        g = _synth_graphs(1, args.nodes, args.feat)[0]
        adj, h0 = jnp.asarray(g[0]), jnp.asarray(g[1])
        fn = make_gat_serve_step(cfg).traceable
        return [("gat-serve", _trace(fn, params, h0, adj,
                                     jnp.asarray(-1, jnp.int32),
                                     jnp.float32(0.0)))], []

    raise SystemExit(2)


def _default_budget() -> int:
    from repro.analysis.vmem import FUSED_VMEM_BUDGET
    return FUSED_VMEM_BUDGET


def main(argv: Optional[List[str]] = None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.analysis.lint",
        description="abftlint: static ABFT coverage / VMEM / sync analysis")
    ap.add_argument("--step", choices=STEPS, default="gcn-serve")
    ap.add_argument("--granularity", default="graph",
                    choices=["layer", "graph", "stripe", "slot"])
    ap.add_argument("--backend", default="dense",
                    choices=["dense", "bcoo", "block_ell"],
                    help="gcn-forward engine backend")
    ap.add_argument("--mode", default=None,
                    choices=["none", "split", "fused"],
                    help="ABFT mode for the traced step; default fused "
                         "everywhere (lm-* now trace the guarded engine "
                         "steps; --mode none recovers the historical "
                         "unguarded baseline manifest)")
    ap.add_argument("--arch", default="gemma-2b",
                    help="lm-* architecture (smoke-sized)")
    ap.add_argument("--fused-layer", action="store_true")
    ap.add_argument("--fused-network", action="store_true")
    ap.add_argument("--graphs", type=int, default=3)
    ap.add_argument("--nodes", type=int, default=24)
    ap.add_argument("--block", type=int, default=8)
    ap.add_argument("--feat", type=int, default=8)
    ap.add_argument("--hidden", type=int, default=8)
    ap.add_argument("--classes", type=int, default=3)
    ap.add_argument("--vmem-budget", type=int, default=None)
    ap.add_argument("--passes", default="coverage,vmem,syncs",
                    help="comma list of: coverage,vmem,syncs")
    ap.add_argument("--manifest", type=Path, default=None,
                    help="write the coverage manifest(s) as JSON")
    ap.add_argument("--expect-unchecked", action="store_true",
                    help="invert the coverage gate: succeed when unchecked "
                         "matmuls exist (the historical lm-* --mode none "
                         "baseline manifest; the guarded lanes gate on "
                         "zero unchecked)")
    ap.add_argument("--verbose", "-v", action="store_true")
    args = ap.parse_args(argv)
    if args.mode is None:
        args.mode = "fused"

    passes = [p.strip() for p in args.passes.split(",") if p.strip()]
    bad = [p for p in passes if p not in PASSES]
    if bad:
        print(f"abftlint: unknown pass(es) {bad}; choose from {PASSES}",
              file=sys.stderr)
        return 2
    if args.backend == "block_ell" and args.step == "gcn-forward":
        print("abftlint: --backend block_ell is exercised via --step "
              "gcn-serve (the packed path); gcn-forward takes dense|bcoo",
              file=sys.stderr)
        return 2

    failures = 0
    manifests = []

    need_trace = "coverage" in passes or "vmem" in passes
    traces, extra = _build_traces(args) if need_trace else ([], [])
    for msg in extra:
        print(f"[vmem] RUNG OVER BUDGET: {msg}")
        failures += 1

    if "coverage" in passes:
        from repro.analysis.coverage import analyze_jaxpr, format_report
        for name, closed in traces:
            m = analyze_jaxpr(closed, step=name)
            manifests.append(m)
            print(format_report(m, verbose=args.verbose))
            if args.expect_unchecked:
                if m.n_unchecked == 0:
                    print(f"[coverage] {name}: expected unchecked matmuls "
                          f"but found none — remove --expect-unchecked "
                          f"(this path is now fully covered)")
                    failures += 1
            elif m.n_unchecked:
                failures += 1

    if "vmem" in passes:
        from repro.analysis.vmem import jaxpr_vmem_report
        budget = args.vmem_budget or _default_budget()
        for name, closed in traces:
            for est in jaxpr_vmem_report(closed, budget=budget):
                status = "ok" if est.fits else "OVER BUDGET"
                print(f"[vmem] {name}: {est.name} grid={est.grid} "
                      f"blocks={est.block_bytes}B scratch="
                      f"{est.scratch_bytes}B total={est.total_bytes}B "
                      f"/ {est.budget}B {status}")
                if not est.fits:
                    failures += 1

    if "syncs" in passes:
        from repro.analysis.syncs import scan_tree
        root = Path(__file__).resolve().parents[3]
        findings = scan_tree(root)
        for f in findings:
            try:
                print(f"[syncs] {Path(f.path).relative_to(root)}:{f.line}:"
                      f"{f.col}: [{f.rule}] {f.message}")
            except ValueError:
                print(f"[syncs] {f}")
        print(f"[syncs] {len(findings)} finding(s) over engine/ + launch/")
        failures += len(findings)

    if args.manifest is not None:
        payload = [m.to_dict() for m in manifests]
        args.manifest.write_text(json.dumps(
            payload[0] if len(payload) == 1 else payload, indent=2) + "\n")
        print(f"[coverage] manifest -> {args.manifest}")

    if failures:
        print(f"abftlint: {failures} failure(s)")
        return 1
    print("abftlint: clean")
    return 0


if __name__ == "__main__":
    sys.exit(main())
