"""Static VMEM-budget model — the single source of truth.

This module owns the fused-kernel VMEM cost models that were born in
``repro.kernels.gcn_fused.ops``.  They moved here so that the *runtime*
fallback predicates (``fused_layer_fits`` / ``fused_network_fits``,
consulted at trace time by ``engine/backends.py``) and the *static*
checker (``abftlint --passes vmem``, run before anything compiles) are
literally the same objects — ``repro.kernels.gcn_fused.ops`` re-exports
them, and ``tests/test_abftlint.py`` asserts the identity.  A lint
verdict of "fits" is therefore a guarantee about what the engine will
decide, not a parallel model that can drift.

Three layers of API, coarse to fine:

* the analytic models (``fused_vmem_bytes`` / ``network_vmem_bytes``)
  and their budget predicates — pure integer arithmetic on layer widths
  and block shapes;
* :func:`lint_rung_table` — evaluate every rung of a streaming
  ``RungTable`` against the budget for a given layer stack, *before*
  ``warmup()`` compiles anything;
* :func:`pallas_call_vmem_bytes` / :func:`jaxpr_vmem_report` — estimate
  any traced ``pallas_call``'s footprint directly from its BlockSpecs /
  grid, without executing, for kernels the analytic models don't know.

Nothing here imports kernels or the engine at module level (they import
*us*); jaxpr introspection imports are deferred into the functions that
need them.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Any, List, Optional, Sequence

# Conservative per-core VMEM budget for the fused layer's resident + working
# set.  Real TPU cores have ~16 MB; half of it leaves the scheduler slack
# for double-buffered DMA and keeps the fallback decision robust across
# generations.
FUSED_VMEM_BUDGET = 8 * 1024 * 1024


def _lanes(n: int, block_g: int) -> int:
    return -(-n // block_g) * block_g


def fused_vmem_bytes(f: int, g: int, bm: int, bk: int, *,
                     block_g: int = 128, itemsize: int = 4) -> int:
    """Model of the fused kernel's peak VMEM working set in bytes.

    Resident across the grid: W [fp, gp] and w_r [fp, 1].  Per step,
    double-buffered by the pipeline: the S tile [bm, bk] and the H tile
    [bk, fp].  Plus the output block [bm, gp], the f32 accumulator scratch
    [bm, gp], the extra-column scratch, and the recomputed x tile [bk, gp].
    """
    fp, gp = _lanes(f, block_g), _lanes(g, block_g)
    resident = fp * gp + fp
    streamed = 2 * (bm * bk + bk * fp)
    working = 2 * bm * gp + bk * gp + bm * gp + 2 * bm
    return itemsize * (resident + streamed + working)


def fused_layer_fits(f: int, g: int, bm: int, bk: int, *,
                     block_g: int = 128,
                     budget: int = FUSED_VMEM_BUDGET) -> bool:
    """True when the fused layer's working set fits the VMEM budget — the
    engine falls back to the two-pass kernel otherwise (W too wide to stay
    resident)."""
    return fused_vmem_bytes(f, g, bm, bk, block_g=block_g) <= budget


def network_vmem_bytes(dims: Sequence[int], bm: int, rows: int, *,
                       block_g: int = 128, itemsize: int = 4) -> int:
    """Model of the whole-network kernel's peak VMEM working set.

    Dominant term: the two ping-pong activation buffers [rows, P] that keep
    the whole activation matrix resident across layer boundaries (absent
    for a single layer).  Resident per layer: one W slab [P, P] + w_r [P].
    Per step, double-buffered: the S tile and (layer 0 only, but the
    pipeline allocates it throughout) the H0 tile.  Plus the output block,
    the f32 accumulator, the recomputed x tile, and the extra column.
    """
    p = _lanes(max(dims), block_g)
    n_layers = len(dims) - 1
    act = 2 * rows * p if n_layers > 1 else 0
    resident = p * p + p
    streamed = 2 * (bm * bm + bm * p)
    working = 2 * bm * p + bm * p + bm * p + 2 * bm
    return itemsize * (act + resident + streamed + working)


def fused_network_fits(dims: Sequence[int], bm: int, rows: int, *,
                       block_g: int = 128,
                       budget: int = FUSED_VMEM_BUDGET) -> bool:
    """True when the whole-network working set — activation ping-pong
    buffers included — fits the VMEM budget; the engine falls back to
    per-layer fused (then two-pass) otherwise."""
    return network_vmem_bytes(dims, bm, rows, block_g=block_g) <= budget


# ---------------------------------------------------------------------------
# RungTable lint: evaluate the streaming server's whole shape menu against
# the budget before warmup() compiles a single rung.
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class RungVerdict:
    """Static VMEM verdict for one rung of a streaming shape menu."""

    stripe_cap: int
    width_cap: int
    n_slots: int
    rows: int                 # stripe_cap * block — padded row count
    network_bytes: Optional[int]   # whole-network working set (if requested)
    layer_bytes: int          # widest per-layer fused working set
    budget: int
    network_fits: Optional[bool]
    layer_fits: bool

    @property
    def fits(self) -> bool:
        """The rung is lint-clean when its *requested* fusion tier fits:
        the whole-network tier when enabled, else the per-layer tier."""
        if self.network_fits is not None:
            return self.network_fits
        return self.layer_fits

    def to_dict(self) -> dict:
        return dataclasses.asdict(self)


def lint_rung_table(table: Any, dims: Sequence[int], *, block: int,
                    block_g: int = 128,
                    budget: int = FUSED_VMEM_BUDGET,
                    fused_network: bool = False) -> List[RungVerdict]:
    """Evaluate every rung in a ``RungTable`` against the VMEM budget.

    ``table`` is duck-typed (anything with ``.rungs`` whose entries carry
    ``stripe_cap``/``width_cap``/``n_slots``) so this module never imports
    the engine.  ``dims`` is the layer-width stack ``[f0, f1, ..., fL]``
    of the model the server will run; ``block`` is the packed block size
    (bm == bk for the packed kernels).  Uses the exact predicates the
    runtime consults, so a "fits" here is the compile-time decision.
    """
    dims = [int(d) for d in dims]
    out: List[RungVerdict] = []
    for r in table.rungs:
        rows = int(r.stripe_cap) * int(block)
        layer_bytes = max(
            fused_vmem_bytes(dims[ell], dims[ell + 1], block, block,
                             block_g=block_g)
            for ell in range(len(dims) - 1))
        net_bytes = net_fits = None
        if fused_network:
            net_bytes = network_vmem_bytes(dims, block, rows,
                                           block_g=block_g)
            net_fits = fused_network_fits(dims, block, rows,
                                          block_g=block_g, budget=budget)
        out.append(RungVerdict(
            stripe_cap=int(r.stripe_cap), width_cap=int(r.width_cap),
            n_slots=int(r.n_slots), rows=rows,
            network_bytes=net_bytes, layer_bytes=layer_bytes,
            budget=int(budget), network_fits=net_fits,
            layer_fits=all(
                fused_layer_fits(dims[ell], dims[ell + 1], block, block,
                                 block_g=block_g, budget=budget)
                for ell in range(len(dims) - 1))))
    return out


def assert_rung_table_fits(table: Any, dims: Sequence[int], *, block: int,
                           block_g: int = 128,
                           budget: int = FUSED_VMEM_BUDGET,
                           fused_network: bool = False) -> List[RungVerdict]:
    """:func:`lint_rung_table`, raising ``ValueError`` naming each
    over-budget rung — the lint-time rejection the streaming server wants
    *before* ``warmup()`` compiles anything."""
    verdicts = lint_rung_table(table, dims, block=block, block_g=block_g,
                               budget=budget, fused_network=fused_network)
    bad = [v for v in verdicts if not v.fits]
    if bad:
        tiers = [(f"rung(stripes={v.stripe_cap}, width={v.width_cap}, "
                  f"slots={v.n_slots}): "
                  f"{(v.network_bytes if v.network_fits is not None else v.layer_bytes)} "
                  f"bytes > budget {v.budget}") for v in bad]
        raise ValueError(
            "RungTable exceeds the VMEM budget at its requested fusion "
            "tier; these rungs would silently fall back at every step:\n  "
            + "\n  ".join(tiers))
    return verdicts


# ---------------------------------------------------------------------------
# Generic static estimator: any traced pallas_call, from its BlockSpecs.
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class PallasVmemEstimate:
    """Static footprint of one traced ``pallas_call`` equation."""

    name: str
    provenance: str
    grid: tuple
    block_bytes: int      # in/out blocks, double-buffered
    scratch_bytes: int
    total_bytes: int
    budget: int

    @property
    def fits(self) -> bool:
        return self.total_bytes <= self.budget

    def to_dict(self) -> dict:
        return dataclasses.asdict(self)


def _block_nbytes(block_shape, aval) -> int:
    """Bytes of one pipeline block: the BlockSpec's block shape (mapped
    axes contribute 1) at the operand dtype; a None mapping means the
    whole operand is resident."""
    itemsize = getattr(getattr(aval, "dtype", None), "itemsize", 4)
    if block_shape is None:
        shape = tuple(getattr(aval, "shape", ()) or ())
    else:
        shape = tuple(1 if (d is None or isinstance(d, type(None))) else int(d)
                      for d in block_shape)
    n = 1
    for d in shape:
        n *= int(d)
    return n * itemsize


def pallas_call_vmem_bytes(eqn: Any, *,
                           budget: int = FUSED_VMEM_BUDGET
                           ) -> PallasVmemEstimate:
    """Estimate a ``pallas_call`` equation's VMEM footprint WITHOUT
    executing it: every in/out BlockSpec block is double-buffered by the
    pipeline, scratch avals are resident once.

    This is deliberately a lower bound — it models buffers, not register
    pressure or compiler-inserted spills — but it is computed from the
    same BlockSpecs the compiler will honor, so an over-budget verdict
    here is already fatal.
    """
    from jax._src import source_info_util

    params = eqn.params
    gm = params["grid_mapping"]
    grid = tuple(int(g) for g in getattr(gm, "grid", ()) or ())
    jaxpr = params["jaxpr"]

    mappings = list(getattr(gm, "block_mappings", ()) or ())
    # operand avals, positionally aligned with block_mappings: index/scalar
    # prefetch operands precede them, scratch avals live only on the inner
    # jaxpr's tail invars
    n_scratch = int(getattr(gm, "num_scratch_operands", 0) or 0)
    op_avals = [v.aval for v in eqn.invars] + [v.aval for v in eqn.outvars]
    block_bytes = 0
    for i, bm in enumerate(mappings):
        aval = op_avals[i] if i < len(op_avals) else None
        bshape = getattr(bm, "block_shape", None)
        block_bytes += 2 * _block_nbytes(bshape, aval)   # double-buffered

    scratch_bytes = 0
    if n_scratch:
        for v in jaxpr.invars[len(jaxpr.invars) - n_scratch:]:
            aval = getattr(v, "aval", None)
            shape = tuple(getattr(aval, "shape", ()) or ())
            itemsize = getattr(getattr(aval, "dtype", None), "itemsize", 4)
            scratch_bytes += int(math.prod(shape)) * itemsize if shape \
                else itemsize

    name = getattr(params.get("name_and_src_info"), "name", None) \
        or params.get("name", "pallas_call")
    prov = source_info_util.summarize(eqn.source_info)
    total = block_bytes + scratch_bytes
    return PallasVmemEstimate(name=str(name), provenance=prov, grid=grid,
                              block_bytes=block_bytes,
                              scratch_bytes=scratch_bytes,
                              total_bytes=total, budget=int(budget))


def jaxpr_vmem_report(closed_jaxpr: Any, *,
                      budget: int = FUSED_VMEM_BUDGET
                      ) -> List[PallasVmemEstimate]:
    """Walk a ClosedJaxpr (recursing through pjit/scan/etc. sub-jaxprs)
    and statically estimate every ``pallas_call`` found."""
    from repro.analysis.coverage import iter_eqns

    out = []
    for eqn, _path in iter_eqns(closed_jaxpr):
        if eqn.primitive.name == "pallas_call":
            out.append(pallas_call_vmem_bytes(eqn, budget=budget))
    return out
