"""End-to-end driver 1: train a GCN on a synthetic Cora-sized graph with
ABFT-checked steps (a few hundred steps on CPU).

    PYTHONPATH=src python examples/train_gcn.py --steps 300 --mode fused
"""
import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import ABFTConfig
from repro.core.datasets import make_reduced
from repro.core.gcn import dataset_to_dense, gcn_loss, init_gcn
from repro.optim import AdamWConfig, adamw_init, adamw_update, cosine_warmup
from repro.runtime import ABFTGuard


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--mode", default="fused",
                    choices=["none", "split", "fused"])
    ap.add_argument("--scale", type=int, default=4)
    args = ap.parse_args()

    ds = make_reduced("cora", scale=args.scale, seed=0)
    s_np, h_np, y_np = dataset_to_dense(ds)
    s, h, y = jnp.asarray(s_np), jnp.asarray(h_np), jnp.asarray(y_np)
    dims = ds.stats.layer_dims
    abft = ABFTConfig(mode=args.mode, threshold=1e-2, relative=True)
    opt_cfg = AdamWConfig(lr=1e-2, weight_decay=1e-4)

    params = init_gcn(jax.random.PRNGKey(0), dims)
    state = {"params": params, "opt": adamw_init(params)}

    @jax.jit
    def step(state):
        (loss, report), grads = jax.value_and_grad(
            lambda p: gcn_loss(p, s, h, y, None, abft), has_aux=True
        )(state["params"])
        lr = cosine_warmup(state["opt"]["step"], 20, args.steps)
        p2, o2 = adamw_update(state["params"], grads, state["opt"],
                              opt_cfg, lr)
        return {"params": p2, "opt": o2}, {
            "loss": loss, "abft_flag": report.flag,
            "abft_max_rel": report.max_rel}

    guard = ABFTGuard()
    t0 = time.time()
    for i in range(args.steps):
        state, m = guard.run_step(step, state)
        if i % 50 == 0 or i == args.steps - 1:
            print(f"step {i:4d} loss={float(m['loss']):.4f} "
                  f"abft_max_rel={float(m['abft_max_rel']):.2e} "
                  f"flags={guard.flags}")
    dt = time.time() - t0
    print(f"\n{args.steps} steps in {dt:.1f}s "
          f"({dt/args.steps*1e3:.1f} ms/step); ABFT mode={args.mode}; "
          f"flagged steps: {guard.flags}")


if __name__ == "__main__":
    main()
