"""Fault-injection walkthrough: one campaign, narrated.

Shows the exact mechanics behind benchmarks/table1_fault_detection.py:
bit flip -> delta -> checksum divergence -> detection category, for both
ABFT variants on the same fault.

    PYTHONPATH=src python examples/fault_injection_demo.py
"""
import numpy as np

from repro.core.datasets import make_dataset
from repro.core.fault import (
    NumpyGCN,
    flip_bit_f32,
    run_campaign,
    train_weights_numpy,
)


def main():
    print("=== single-fault walkthrough (synthetic Cora) ===\n")
    ds = make_dataset("cora", seed=0, normalize=False)
    ws = train_weights_numpy(ds, epochs=60, lr=0.02, seed=0)
    model = NumpyGCN(ds, weights=ws)
    acc = (model.pred_cls == ds.labels).mean()
    print(f"trained 2-layer GCN, train-acc {acc:.2f}")

    # manual single fault: flip a high mantissa bit of a partial sum
    st = model.layers[1]
    i, j, t = 7, 2, 3
    part, _ = model.comb_prefix(1, i, j, t)
    for bit in (30, 23, 12, 2):
        flipped = flip_bit_f32(part, bit)
        delta = float(flipped) - float(part)
        d2 = (st.sum_hout - st.pred2) + delta * float(model.s_c[i])
        print(f"bit {bit:2d}: partial {float(part):+.4e} -> "
              f"{float(flipped):+.4e}  delta={delta:+.3e}  "
              f"|checksum diff|={abs(d2):.3e}  "
              f"detected@1e-4={abs(d2) > 1e-4}")

    print("\n100 random campaigns, paired per mode:")
    rng = np.random.default_rng(0)
    for mode in ("split", "fused"):
        det = sil = fp = 0
        rngm = np.random.default_rng(0)
        for _ in range(100):
            o = run_campaign(model, mode, rngm)
            if o.target == "mm" and o.output_corrupted:
                det += o.diffs[1e-7]
                sil += not o.diffs[1e-7]
            else:
                fp += o.diffs[1e-7]
        print(f"  {mode:6s}: detected {det}, silent {sil}, "
              f"false-positive {fp}  (tau=1e-7)")


if __name__ == "__main__":
    main()
