"""Quickstart: the paper in 60 seconds.

Builds a synthetic Cora-sized GCN, runs inference with both ABFT variants,
injects a fault, and shows (a) identical clean behaviour, (b) detection by
both, (c) the op-count savings of the fused checksum.

    PYTHONPATH=src python examples/quickstart.py
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.core import ABFTConfig, gcn_layer_fused, gcn_layer_split
from repro.core.datasets import make_reduced
from repro.core.gcn import (
    dataset_to_dense,
    dataset_to_sparse,
    gcn_apply,
    gcn_apply_sparse,
    init_gcn,
    precompute_s_c,
)
from repro.core.opcount import gcn_op_counts


def main():
    print("=== GCN-ABFT quickstart ===\n")
    ds = make_reduced("cora", scale=4, seed=0)
    s_np, h_np, _ = dataset_to_dense(ds)
    s, h = jnp.asarray(s_np), jnp.asarray(h_np)
    dims = ds.stats.layer_dims
    params = init_gcn(jax.random.PRNGKey(0), dims)

    for mode in ("split", "fused"):
        cfg = ABFTConfig(mode=mode, threshold=1e-3, relative=True)
        logits, report = jax.jit(
            lambda p, s, h: gcn_apply(p, s, h, cfg))(params, s, h)
        print(f"{mode:6s}: clean forward  flag={bool(report.flag)} "
              f"max_rel={float(report.max_rel):.2e} "
              f"checks={int(report.n_checks)}")

    # inject a fault into the first layer's combination output
    w = params["layers"][0]["w"]
    cfg = ABFTConfig(mode="fused", threshold=1e-3, relative=True)
    h_out, chk = gcn_layer_fused(s, h, w, cfg)
    bad = h_out.at[5, 3].add(h_out.std() * 1e3)
    diff = abs(float(chk.predicted) - float(bad.sum()))
    print(f"\ninjected fault: |predicted - actual| = {diff:.3e} "
          f"-> detected: {diff > 1e-3 * abs(float(bad.sum()))}")

    # sparse aggregation path: same logits, same checks, scales past toy
    # graphs — S stays a BCOO and s_c = e^T S is precomputed once offline
    cfg = ABFTConfig(mode="fused", threshold=1e-3, relative=True)
    s_sp, h_sp, _ = dataset_to_sparse(ds)
    s_c = precompute_s_c(s_sp, cfg)
    logits_sp, rep_sp = jax.jit(
        lambda p, s, x, sc: gcn_apply_sparse(p, s, x, cfg, sc)
    )(params, s_sp, h_sp, s_c)
    logits_d, _ = gcn_apply(params, s, h, cfg)
    err = float(jnp.abs(logits_sp - logits_d).max())
    print(f"\nsparse (BCOO) path: max |logit diff| vs dense = {err:.2e} "
          f"flag={bool(rep_sp.flag)}")

    # unified engine: every backend behind one entry point — identical
    # logits and report semantics from dense, BCOO, and the block-ELL
    # Pallas kernel (repro.engine.gcn_apply; the core entry points above
    # are thin compat shims over this).
    from repro.engine import Graph, gcn_apply as engine_apply
    from repro.kernels.spmm_abft import dense_to_block_ell

    bell = dense_to_block_ell(s_np, block_m=32, block_k=32)
    print("\nunified engine, one entry point per backend:")
    for backend, graph in (("dense", Graph(s, h)),
                           ("bcoo", Graph(s_sp, h_sp, s_c=s_c)),
                           ("block_ell", Graph(bell, h))):
        lg, rep = engine_apply(params, graph, cfg, backend=backend,
                               **({"block_g": 32}
                                  if backend == "block_ell" else {}))
        err = float(jnp.abs(lg - logits_d).max())
        print(f"  {backend:9s} |logit diff|={err:.2e} "
              f"flag={bool(rep.flag)} checks={int(rep.n_checks)}")
    print("  (batched multi-graph serving: python -m repro.launch.serve_gcn)")

    print("\nop-count savings (full-size graphs, paper Table II):")
    for name in ("cora", "citeseer", "pubmed", "nell"):
        oc = gcn_op_counts(name)
        print(f"  {name:9s} check ops: split {oc.split_check/1e6:7.3f}M "
              f"fused {oc.fused_check/1e6:7.3f}M  "
              f"(saves {oc.check_savings*100:.1f}%)")


if __name__ == "__main__":
    main()
