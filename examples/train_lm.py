"""End-to-end driver 2: train an LM (reduced config of any assigned arch)
on the synthetic token stream, ABFT-checked, with checkpoint/restore.

    PYTHONPATH=src python examples/train_lm.py --arch gemma-2b --steps 200
    PYTHONPATH=src python examples/train_lm.py --arch deepseek-moe-16b \
        --steps 50 --width 128   # MoE routing exercised end to end
"""
import argparse
import dataclasses
import time

import jax
import numpy as np

from repro.checkpoint import CheckpointManager
from repro.configs import get_config, smoke_config
from repro.core.abft import ABFTConfig
from repro.data.synthetic import SyntheticLM
from repro.launch.steps import init_train_state, make_train_step
from repro.optim import AdamWConfig
from repro.runtime import ABFTGuard, StragglerWatchdog


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="gemma-2b")
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--width", type=int, default=0,
                    help="override d_model for a bigger run")
    ap.add_argument("--layers", type=int, default=0)
    ap.add_argument("--mode", default="fused",
                    choices=["none", "split", "fused"])
    ap.add_argument("--ckpt", default="results/ckpt_lm")
    args = ap.parse_args()

    cfg = smoke_config(get_config(args.arch))
    over = {}
    if args.width:
        over["d_model"] = args.width
        over["head_dim"] = max(16, args.width // cfg.n_heads)
    if args.layers:
        over["n_layers"] = args.layers
    if over:
        cfg = dataclasses.replace(cfg, **over)
    abft = ABFTConfig(mode=args.mode, threshold=5e-2, relative=True)

    data = SyntheticLM(vocab_size=cfg.vocab_size, seq_len=args.seq,
                       batch_size=args.batch, seed=0)
    it = data.batches()

    state = init_train_state(cfg, jax.random.PRNGKey(0))
    n_params = sum(x.size for x in jax.tree.leaves(state["params"]))
    print(f"arch={cfg.name} params={n_params/1e6:.2f}M abft={args.mode}")

    step_fn = jax.jit(make_train_step(cfg, abft, AdamWConfig(lr=1e-3),
                                      total_steps=args.steps, warmup=20))
    ckpt = CheckpointManager(args.ckpt, keep=2)
    restored, at = ckpt.restore(state)
    if restored is not None:
        state = restored
        print(f"restored from step {at}")

    guard = ABFTGuard()
    wd = StragglerWatchdog()
    losses = []
    t0 = time.time()
    for i in range(args.steps):
        batch = {k: jax.numpy.asarray(v) for k, v in next(it).items()}
        if cfg.family == "encdec":
            batch["src_embeds"] = jax.numpy.asarray(
                np.random.default_rng(i).normal(
                    size=(args.batch, args.seq, cfg.d_model)), jax.numpy.float32)
        elif cfg.frontend:
            batch["prefix_embeds"] = jax.numpy.zeros(
                (args.batch, 4, cfg.d_model), jax.numpy.float32)
        wd.start()
        state, m = guard.run_step(lambda s, b=batch: step_fn(s, b), state)
        slow = wd.stop()
        losses.append(float(m["loss"]))
        if i % 25 == 0 or i == args.steps - 1:
            print(f"step {i:4d} loss={losses[-1]:.4f} "
                  f"gnorm={float(m['grad_norm']):.2f} "
                  f"abft_rel={float(m['abft_max_rel']):.1e} "
                  f"{'SLOW' if slow else ''}")
        if i and i % 100 == 0:
            ckpt.save(i, state)
    ckpt.save(args.steps, state)
    ckpt.wait()
    dt = time.time() - t0
    improved = losses[-1] < losses[0] - 0.1
    print(f"\n{args.steps} steps in {dt:.1f}s ({dt/args.steps*1e3:.0f} "
          f"ms/step); loss {losses[0]:.3f} -> {losses[-1]:.3f} "
          f"(improved: {improved}); ABFT flags: {guard.flags}")


if __name__ == "__main__":
    main()
