"""End-to-end driver 3: batched serving with KV cache + fused ABFT checks
on every decode step (the paper's error detection running live in an
inference server loop).

    PYTHONPATH=src python examples/serve_lm.py --arch gemma-2b --new 32
"""
import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config, smoke_config
from repro.core.abft import ABFTConfig
from repro.launch.steps import make_decode_step, make_prefill_step
from repro.models.transformer import init_model


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="gemma-2b")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt", type=int, default=32)
    ap.add_argument("--new", type=int, default=32)
    ap.add_argument("--mode", default="fused",
                    choices=["none", "split", "fused"])
    args = ap.parse_args()

    cfg = smoke_config(get_config(args.arch))
    abft = ABFTConfig(mode=args.mode, threshold=5e-2, relative=True)
    params = init_model(cfg, jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)

    cache_len = args.prompt + args.new
    batch = {"tokens": jnp.asarray(
        rng.integers(0, cfg.vocab_size, size=(args.batch, args.prompt)),
        jnp.int32)}
    if cfg.family == "encdec":
        batch["src_embeds"] = jnp.asarray(
            rng.normal(size=(args.batch, args.prompt, cfg.d_model)),
            jnp.float32)

    prefill = jax.jit(make_prefill_step(cfg, abft, cache_len))
    decode = jax.jit(make_decode_step(cfg, abft))

    t0 = time.time()
    logits, states, m = prefill(params, batch)
    tok = jnp.argmax(logits[:, -1], -1)[:, None].astype(jnp.int32)
    t_prefill = time.time() - t0
    print(f"prefill {args.batch}×{args.prompt}: {t_prefill*1e3:.0f} ms  "
          f"abft_flag={bool(m['abft_flag'])}")

    out_tokens = [tok]
    flags = 0
    t0 = time.time()
    for i in range(args.new - 1):
        pos = jnp.asarray(args.prompt + i, jnp.int32)
        logits, states, m = decode(params, states, tok, pos)
        flags += int(bool(m["abft_flag"]))
        tok = jnp.argmax(logits[:, -1], -1)[:, None].astype(jnp.int32)
        out_tokens.append(tok)
    dt = time.time() - t0
    toks = np.concatenate([np.asarray(t) for t in out_tokens], axis=1)
    print(f"decoded {args.new} tokens × {args.batch} seqs in {dt:.2f}s "
          f"({dt/max(args.new-1,1)*1e3:.1f} ms/step), ABFT flags: {flags}")
    print(f"sample continuation (seq 0): {toks[0][:16].tolist()}")


if __name__ == "__main__":
    main()
