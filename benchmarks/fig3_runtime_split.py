"""Paper Fig. 3: share of each GCN layer's runtime spent in the first
(combination) vs second (aggregation) matmul step.

Primary: op-count model (hardware-neutral, matches the paper's systolic
setting).  Secondary: measured numpy wall-times on this CPU (documented as
indicative only — np.add.at scatter is far from an accelerator's SpMM).
The paper's claim: the first step dominates (>90 % for PubMed/Nell), making
GCN-ABFT's end-of-layer detection latency negligible.
"""
from __future__ import annotations

import time
from typing import List

import numpy as np


def run(csv: List[str]) -> None:
    from repro.core.datasets import STATS, make_dataset
    from repro.core.fault import glorot_weights
    from repro.core.opcount import gcn_layer_shapes

    print("\n=== Fig. 3: combination vs aggregation runtime share ===")
    print(f"{'GCN':9s} {'L1 comb%':>9s} {'L2 comb%':>9s} {'total comb%':>11s}"
          f"  (op-count model | measured)")
    for name in STATS:
        st = STATS[name]
        shapes = gcn_layer_shapes(st)
        comb = [2 * ls.nnz_h * ls.g for ls in shapes]
        agg = [2 * ls.nnz_s * ls.g for ls in shapes]
        model_pct = [100 * c / (c + a) for c, a in zip(comb, agg)]
        model_tot = 100 * sum(comb) / (sum(comb) + sum(agg))

        # measured (small datasets only — nell's dense L2 is fine, its
        # scatter-based agg is the slow path on CPU)
        ds = make_dataset(name, seed=0)
        ws = glorot_weights(st.layer_dims, seed=0)
        t = {}
        h0 = ds.features
        t0 = time.perf_counter(); x1 = h0.matmul_dense(ws[0]); t["c1"] = time.perf_counter() - t0
        t0 = time.perf_counter(); a1 = ds.s.matmul_dense(x1); t["a1"] = time.perf_counter() - t0
        h1 = np.maximum(a1, 0)
        t0 = time.perf_counter(); x2 = h1 @ ws[1]; t["c2"] = time.perf_counter() - t0
        t0 = time.perf_counter(); ds.s.matmul_dense(x2); t["a2"] = time.perf_counter() - t0
        meas = [100 * t["c1"] / (t["c1"] + t["a1"]),
                100 * t["c2"] / (t["c2"] + t["a2"])]
        meas_tot = 100 * (t["c1"] + t["c2"]) / sum(t.values())
        print(f"{name:9s} {model_pct[0]:8.1f}% {model_pct[1]:8.1f}% "
              f"{model_tot:10.1f}%  | measured {meas[0]:5.1f}% {meas[1]:5.1f}% "
              f"tot {meas_tot:5.1f}%")
        csv.append(f"fig3_{name}_comb_share_pct,"
                   f"{sum(t.values())*1e6:.1f},{model_tot:.2f}")


if __name__ == "__main__":
    out: List[str] = []
    run(out)
