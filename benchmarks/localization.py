"""Fault-localization economics: how many rows must be re-executed to
recover from one detected fault, per recovery tier?

For each mix, a packed block-ELL batch runs the single-pass fused layer at
``granularity="slot"`` while the kernel's accumulator fault-injection
hook (``inject=(layer, stripe, slot, delta)``) perturbs one accumulator
element — one experiment per (layer, stripe, slot) point.  Detection is
asserted to be *exact* twice over: the injected (stripe, slot) telescoped
corner — and only it — flags, and the derived stripe corner agrees.  The
four tiers of the guard's escalation ladder are then costed in re-executed
rows (row x layer re-executions), the slot and stripe tiers from the SAME
injected metrics (slot-granularity reports carry both):

  * **slot**    — the sub-stripe surgical repair
    (``engine.localize.surgical_slot_retry``): the flagged stripe's rows
    at the flagged layer, then only the downstream stripes whose cols
    table references a row the splice actually CHANGED (ReLU masking and
    0·x=0 prune the rest).  Bit-for-bit equal to a clean run.
  * **stripe**  — the stripe-surgical repair (``engine.localize``): the
    flagged stripe's rows at the flagged layer, plus every stripe whose
    cols table references the repaired rows downstream, changed or not.
    Also asserted bit-for-bit.
  * **graph**   — PR 3's per-graph retry: every LOGICAL row of the flagged
    graph (its n_nodes, not its padded stripe rows), at every layer — the
    same basis ``PackedRunner.retry_fn`` reports in
    ``abft_rows_recomputed``, asserted equal here once per mix.
  * **step**    — whole-step replay (restore tier): every padded row of
    the batch, at every layer.

Writes ``BENCH_localization.json`` with the recomputed-rows fractions
(tier rows / step rows); the strict ordering slot < stripe < graph < step
is asserted per mix.  CPU runs the kernel in interpret mode — the row
counts are exact either way, only wall-clock is pessimistic.

    PYTHONPATH=src python -m benchmarks.localization --graphs 6
"""
from __future__ import annotations

import argparse
import json
from typing import List, Optional, Sequence

MIXES = (
    # name, node range, block — n_lo >= 2*block so every graph spans >= 2
    # stripes (single-stripe graphs make stripe and graph retry coincide)
    ("small", (32, 64), 16),
    ("wide", (48, 120), 16),
)


def run_mix(name: str, nodes, block: int, *, graphs: int, feat: int,
            hidden: int, classes: int, seed: int, stride: int,
            delta: float) -> dict:
    import jax
    import numpy as np

    from repro.core.abft import ABFTConfig
    from repro.core.gcn import init_gcn
    from repro.engine import fold_w_r, pack_graphs, synth_graph_stream
    from repro.engine.localize import surgical_slot_retry, \
        surgical_stripe_retry
    from repro.engine.streaming import (PackedRunner, make_packed_serve_step,
                                        packed_step_args as _packed_args)

    cfg = ABFTConfig(mode="fused", threshold=1e-3, relative=True)
    stream = synth_graph_stream(graphs, n_lo=nodes[0], n_hi=nodes[1],
                                feat=feat, seed=seed)
    pb = pack_graphs(stream, block=block, stripe_multiple=4,
                     width_multiple=2)
    params = fold_w_r(init_gcn(jax.random.PRNGKey(seed),
                               (feat, hidden, classes)), cfg)
    n_layers = len(params["layers"])
    args = _packed_args(pb)
    nbm = pb.bell.n_block_rows
    width = pb.bell.width
    bm = pb.block
    stripe_graph = np.asarray(pb.stripe_graph)
    stripes_of = {g: int((stripe_graph == g).sum())
                  for g in range(pb.n_slots)}
    step_rows_once = nbm * bm * n_layers

    clean_step = make_packed_serve_step(params, cfg, pb.n_slots,
                                        block_g=block, fused_layer=True,
                                        granularity="slot")
    logits_clean, m_clean = clean_step(*args)
    assert not bool(np.asarray(m_clean["abft_graph_flags"]).any()), \
        "clean packed run flagged — raise the threshold or reseed"
    logits_clean = np.asarray(logits_clean)

    # same-basis guard: the graph-tier rows this benchmark charges must be
    # the rows the engine's own retry accounting reports, or the
    # stripe-vs-graph fractions silently compare different units
    runner = PackedRunner(params, cfg, block, fused_layer=True,
                          granularity="slot")
    _, m_retry = runner.retry_fn(pb)(logits_clean, [0])
    assert int(m_retry["abft_rows_recomputed"]) == \
        int(pb.n_nodes[0]) * n_layers, \
        (name, int(m_retry["abft_rows_recomputed"]),
         int(pb.n_nodes[0]) * n_layers,
         "engine retry accounting is not on the logical-rows basis")

    real_stripes = [s for s in range(nbm) if stripe_graph[s] < pb.n_slots
                    and stripes_of[int(stripe_graph[s])] > 0][::stride]
    rows = {"slot": 0, "stripe": 0, "graph": 0, "step": 0}
    n_inj = 0
    for layer in range(n_layers):
        for stripe in real_stripes:
            for slot in (0, width - 1):
                inj_step = make_packed_serve_step(
                    params, cfg, pb.n_slots, block_g=block,
                    fused_layer=True, granularity="slot",
                    inject=(layer, stripe, slot, delta))
                out_bad, m_bad = inj_step(*args)
                slf = np.asarray(m_bad["abft_slot_flags"])
                sf = np.asarray(m_bad["abft_stripe_flags"])
                gf = np.asarray(m_bad["abft_graph_flags"])
                slot_hits = np.argwhere(slf)
                assert slot_hits.shape == (1, 3) and \
                    tuple(slot_hits[0]) == (layer, stripe, slot), \
                    (name, layer, stripe, slot, slot_hits.tolist())
                flagged = np.argwhere(sf)
                assert flagged.shape == (1, 2) and \
                    tuple(flagged[0]) == (layer, stripe), \
                    (name, layer, stripe, slot, flagged.tolist())
                victim = int(stripe_graph[stripe])
                assert gf.sum() == 1 and gf[victim], (name, layer, stripe)
                # slot and stripe tiers costed from the SAME injected
                # metrics — slot-granularity reports carry both ladders
                rep_sl, sub_sl = surgical_slot_retry(
                    pb, params, cfg, out_bad, m_bad, block_g=block)
                assert not sub_sl["abft_graph_flags"].any(), \
                    (name, layer, stripe, slot)
                assert np.array_equal(rep_sl, logits_clean), \
                    (name, layer, stripe, slot, "slot splice not bit-exact")
                repaired, sub = surgical_stripe_retry(
                    pb, params, cfg, out_bad, m_bad, block_g=block)
                assert not sub["abft_graph_flags"].any(), \
                    (name, layer, stripe, slot)
                assert np.array_equal(repaired, logits_clean), \
                    (name, layer, stripe, slot, "splice not bit-exact")
                assert int(sub_sl["abft_rows_recomputed"]) <= \
                    int(sub["abft_rows_recomputed"]), \
                    (name, layer, stripe, slot, "slot reach exceeds stripe")
                rows["slot"] += int(sub_sl["abft_rows_recomputed"])
                rows["stripe"] += int(sub["abft_rows_recomputed"])
                rows["graph"] += int(pb.n_nodes[victim]) * n_layers
                rows["step"] += step_rows_once
                n_inj += 1
    frac = {k: v / max(rows["step"], 1) for k, v in rows.items()}
    assert rows["slot"] < rows["stripe"] < rows["graph"] < rows["step"], \
        (name, rows)
    return {"mix": name, "nodes": list(nodes), "block": block,
            "stripes": nbm, "graphs": pb.n_graphs, "layers": n_layers,
            "injections": n_inj, "rows": rows, "rows_fraction": frac}


def main(argv: Optional[Sequence[str]] = None) -> List[dict]:
    import jax

    ap = argparse.ArgumentParser()
    ap.add_argument("--graphs", type=int, default=6)
    ap.add_argument("--feat", type=int, default=8)
    ap.add_argument("--hidden", type=int, default=8)
    ap.add_argument("--classes", type=int, default=3)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--stride", type=int, default=1,
                    help="inject at every stride-th stripe (sweep thinning "
                         "for CI; 1 = every real stripe)")
    ap.add_argument("--delta", type=float, default=64.0,
                    help="accumulator perturbation magnitude")
    ap.add_argument("--json", default="BENCH_localization.json",
                    help="write machine-readable results here ('' disables)")
    args = ap.parse_args(argv)

    print(f"=== localization: {args.graphs} graphs/mix, stride "
          f"{args.stride} ({jax.default_backend()}) ===")
    print(f"{'mix':>8} {'inj':>5} {'slot rows':>10} {'stripe rows':>12} "
          f"{'graph rows':>12} {'step rows':>12}  fraction sl/s/g/step")
    results = []
    for name, nodes, block in MIXES:
        r = run_mix(name, nodes, block, graphs=args.graphs, feat=args.feat,
                    hidden=args.hidden, classes=args.classes,
                    seed=args.seed, stride=args.stride, delta=args.delta)
        results.append(r)
        f = r["rows_fraction"]
        print(f"{name:>8} {r['injections']:>5} {r['rows']['slot']:>10} "
              f"{r['rows']['stripe']:>12} "
              f"{r['rows']['graph']:>12} {r['rows']['step']:>12}  "
              f"{f['slot']:.3f}/{f['stripe']:.3f}/{f['graph']:.3f}/1.000")
    if args.json:
        rec = {"bench": "localization",
               "device_backend": jax.default_backend(),
               "config": {"graphs": args.graphs, "feat": args.feat,
                          "hidden": args.hidden, "classes": args.classes,
                          "seed": args.seed, "stride": args.stride,
                          "delta": args.delta},
               "mixes": results}
        with open(args.json, "w") as fh:
            json.dump(rec, fh, indent=2, sort_keys=True)
            fh.write("\n")
        print(f"wrote {args.json}")
    return results


if __name__ == "__main__":
    main()
