"""Beyond-paper: the paper's op-savings transposed to LM architectures.

For each assigned arch × shape, the analytic model (flops_model.py, which
mirrors the implementation op-by-op) gives step FLOPs under abft ∈
{none, split, fused}.  Reported:

  * check overhead  = (flops(mode) − flops(none)) / flops(none)
  * fused savings   = (flops(split) − flops(fused)) /
                      (flops(split) − flops(none))      — the Table II
                      "check savings" analogue at LM scale.

The attention-dominant shapes show the structural result: split ABFT needs
a second scoring pass (eᵀA), fused needs one extra accumulator column —
so savings approach ~50 % of check cost at long context, far beyond the
paper's 21 % GCN average.  Wall-clock microbenches of the checked-matmul
kernel path (interpret) are in tests; HLO-level deltas in §Perf.
"""
from __future__ import annotations

import time
from typing import List

from repro.configs import SHAPES, get_config, list_archs

from .flops_model import count_step


def run(csv: List[str]) -> None:
    print("\n=== ABFT overhead / fused savings at LM scale (analytic) ===")
    print(f"{'arch':22s} {'shape':12s} {'split ovh%':>10s} {'fused ovh%':>10s}"
          f" {'fused sav%':>10s}")
    t0 = time.perf_counter()
    for arch in list_archs():
        cfg = get_config(arch)
        for sname in ("train_4k", "prefill_32k", "decode_32k"):
            shape = SHAPES[sname]
            f_none = count_step(cfg, shape, "none")["flops"]
            f_split = count_step(cfg, shape, "split")["flops"]
            f_fused = count_step(cfg, shape, "fused")["flops"]
            ovh_s = 100 * (f_split - f_none) / f_none
            ovh_f = 100 * (f_fused - f_none) / f_none
            sav = 100 * (f_split - f_fused) / max(f_split - f_none, 1.0)
            print(f"{arch:22s} {sname:12s} {ovh_s:10.2f} {ovh_f:10.2f} "
                  f"{sav:10.1f}")
            csv.append(f"abft_{arch}_{sname}_fused_savings_pct,"
                       f"{(time.perf_counter()-t0)*1e6:.0f},{sav:.2f}")


if __name__ == "__main__":
    out: List[str] = []
    run(out)
