"""Paper Table II: operations for executing + validating the four GCN apps.

Analytic (exact integer) counts from core/opcount.py under the documented
conventions; paper values alongside for comparison.  This is the paper's
headline result: fused GCN-ABFT cuts checking ops by >21 % on average.
"""
from __future__ import annotations

import time
from typing import List

PAPER = {  # true_out(M), split_chk(M), fused_chk(M), chk_sav(%), tot_sav(%)
    "cora": (2.8, 0.55, 0.44, 20.0, 3.3),
    "citeseer": (4.6, 0.80, 0.60, 25.0, 3.7),
    "pubmed": (37.6, 4.60, 4.04, 12.2, 1.3),
    "nell": (1745.9, 84.30, 59.90, 28.9, 1.3),
}


def run(csv: List[str]) -> None:
    from repro.core.opcount import all_gcn_op_counts

    t0 = time.perf_counter()
    rows = all_gcn_op_counts()
    dt = (time.perf_counter() - t0) * 1e6
    print("\n=== Table II: arithmetic operations (millions) ===")
    hdr = (f"{'GCN':9s} {'true':>9s} {'split':>7s} {'fused':>7s} "
           f"{'chk sav%':>8s} {'tot sav%':>8s} | paper: true split fused sav%")
    print(hdr)
    savs = []
    for name, oc in rows.items():
        p = PAPER[name]
        savs.append(oc.check_savings * 100)
        print(f"{name:9s} {oc.true_out/1e6:9.2f} {oc.split_check/1e6:7.3f} "
              f"{oc.fused_check/1e6:7.3f} {oc.check_savings*100:8.1f} "
              f"{oc.total_savings*100:8.2f} |  {p[0]:7.1f} {p[1]:5.2f} "
              f"{p[2]:5.2f} {p[3]:4.1f}")
        csv.append(f"table2_{name}_check_savings_pct,{dt:.1f},"
                   f"{oc.check_savings*100:.2f}")
    avg = sum(savs) / len(savs)
    print(f"average check savings: {avg:.1f}%  (paper: >21% on average)")
    csv.append(f"table2_avg_check_savings_pct,{dt:.1f},{avg:.2f}")


if __name__ == "__main__":
    out: List[str] = []
    run(out)
