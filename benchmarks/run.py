"""Benchmark driver — one module per paper table/figure + beyond-paper.

Prints ``name,us_per_call,derived`` CSV at the end.  Individual benches:
  python -m benchmarks.table2_op_counts        (paper Table II)
  python -m benchmarks.table1_fault_detection  (paper Table I)
  python -m benchmarks.fig3_runtime_split      (paper Fig. 3)
  python -m benchmarks.abft_overhead           (Table II transposed to LMs)
  python -m benchmarks.roofline                (reads results/dryrun JSONs)
  python -m benchmarks.sparse_vs_dense         (sparse aggregation path)
"""
from __future__ import annotations

import argparse
from typing import List


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default="all",
                    help="comma list: table2,table1,fig3,abft,roofline,sparse")
    args = ap.parse_args()
    want = set(args.only.split(",")) if args.only != "all" else {
        "table2", "table1", "fig3", "abft", "roofline", "sparse"}

    csv: List[str] = []
    if "table2" in want:
        from benchmarks import table2_op_counts
        table2_op_counts.run(csv)
    if "fig3" in want:
        from benchmarks import fig3_runtime_split
        fig3_runtime_split.run(csv)
    if "abft" in want:
        from benchmarks import abft_overhead
        abft_overhead.run(csv)
    if "table1" in want:
        from benchmarks import table1_fault_detection
        table1_fault_detection.run(csv)
    if "roofline" in want:
        from benchmarks import roofline
        roofline.run(csv)
    if "sparse" in want:
        from benchmarks import sparse_vs_dense
        sparse_vs_dense.run(csv)

    print("\nname,us_per_call,derived")
    for line in csv:
        print(line)


if __name__ == "__main__":
    main()
